//! Fig. 17 / Appendix A.2 — Δt_iteration and Δt_overlap traces of selected
//! TC-ResNet8 layers on 2×2 and 4×4 systolic arrays, with the fixed-point
//! stop marker k_stop.
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::expt::{dt_iteration_series, dt_overlap_series, systolic_sweep_point};
use acadl_perf::metrics::sample_variance;
use acadl_perf::report::{Csv, Table};

fn main() {
    section("Fig. 17 — Δt_iteration / Δt_overlap traces (Appendix A.2)");
    let net = zoo::tc_resnet8();
    let picks = ["conv1", "fc", "clip1", "block1_add", "block3_add"];
    let mut t = Table::new(
        "Fig. 17 — per-layer oscillation (variance beyond k_stop)",
        &["size", "layer", "k", "k_stop", "Var(Δt_iter)", "Var(Δt_overlap)"],
    );
    let mut csv = Csv::new("fig17_traces", &["size", "layer", "iter", "dt_iteration", "dt_overlap"]);
    for s in [2u32, 4] {
        let p = systolic_sweep_point(s, s, &net, true).unwrap();
        for l in &p.layers {
            if l.fused || !picks.contains(&l.name.as_str()) {
                continue;
            }
            // analyze the compute kernel (last trace)
            let trace = l.traces.last().unwrap();
            let dt = dt_iteration_series(trace);
            let ov = dt_overlap_series(trace);
            let k_stop = *l.k_stops.last().unwrap();
            let s0 = (k_stop as usize).min(dt.len().saturating_sub(1));
            t.row(&[
                format!("{s}x{s}"),
                l.name.clone(),
                dt.len().to_string(),
                k_stop.to_string(),
                format!("{:.2}", sample_variance(&dt[s0..])),
                format!("{:.2}", sample_variance(&ov[s0.min(ov.len())..])),
            ]);
            let take = dt.len().min(256);
            for i in 0..take {
                csv.row(&[
                    s.to_string(),
                    l.name.clone(),
                    i.to_string(),
                    format!("{}", dt[i]),
                    if i < ov.len() { format!("{}", ov[i]) } else { String::new() },
                ]);
            }
        }
    }
    t.emit("fig17_oscillation").unwrap();
    csv.finish().unwrap();
    println!("paper: non-optimal mappings (adds) oscillate more; Δt grows with array depth");
}
