//! Table 3 — AlexNet on the 16×16 Gemmini (paper §7.2).
//!
//! The reduced-resolution variant carries the DES ground truth (the
//! full-size Verilator run took the paper 43.5 h); the full-size network is
//! estimated with the AIDG fixed point alone, demonstrating the paper's
//! headline: billions of instructions estimated from a few hundred
//! evaluated iterations.
use std::sync::Arc;

use acadl_perf::accel::{Gemmini, GemminiConfig};
use acadl_perf::bench_harness::section;
use acadl_perf::coordinator::Arch;
use acadl_perf::dnn::zoo;
use acadl_perf::engine::{EstimationEngine, DEFAULT_CACHE_CAP};
use acadl_perf::expt::Comparison;
use acadl_perf::mapping::{gemm_tile::GemmTileMapper, Mapper};
use acadl_perf::report::fmt_cycles;

fn main() {
    section("Table 3 — AlexNet (reduced) on 16×16 Gemmini vs DES");
    let mapper = GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()));
    let net = zoo::alexnet_reduced();
    let mapped = mapper.map_network(&net).unwrap();
    let c = Comparison::run(&mapper, &net, &mapped, Some(16)).unwrap();
    c.table("Table 3 — AlexNet (67×67 reduced) on 16×16 Gemmini")
        .emit("table3_gemmini_alexnet")
        .unwrap();
    println!("paper (227×227, vs Verilator 43.5 h): AIDG −2.02% PE, 9.78% MAPE in 37.9 s\n");

    section("Table 3b — full-size AlexNet, AIDG estimate only (cold engine)");
    let full = zoo::alexnet();
    let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let e = engine
        .estimate_network(
            &Arch::Gemmini(GemminiConfig::default()),
            &full,
            &acadl_perf::aidg::FixedPointConfig::default(),
        )
        .unwrap();
    println!(
        "alexnet: {} cycles | {} of {} iterations evaluated ({:.4}%) | {} instructions | {}",
        fmt_cycles(e.total_cycles()),
        e.evaluated_iters(),
        e.total_iters(),
        100.0 * e.evaluated_iters() as f64 / e.total_iters().max(1) as f64,
        e.total_insts(),
        acadl_perf::bench_harness::fmt_dur(e.runtime),
    );
    println!(
        "engine: {} kernels, {} unique, {} deduped",
        e.stats.total_kernels, e.stats.unique_kernels, e.stats.deduped,
    );
}
