//! Fig. 15 — design-space exploration over the Plasticine-derived
//! architecture: rows × cols × PCU GEMM tile size, ranked by estimated
//! whole-DNN cycles (paper §7.4). The roofline pre-filter runs through the
//! AOT-compiled XLA estimator when artifacts are built.
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::bench_harness::section;
use acadl_perf::coordinator::{explore, DseSpec, Pool, RooflineBackend};
use acadl_perf::report::{fmt_cycles, Csv, Table};

fn main() {
    section("Fig. 15 — Plasticine-derived DSE");
    let full = std::env::var_os("ACADL_BENCH_FULL").is_some();
    let nets: &[&str] =
        if full { &["tc_resnet8", "efficientnet_reduced"] } else { &["tc_resnet8"] };
    let backend = RooflineBackend::auto();
    println!(
        "roofline backend: {}",
        match &backend {
            RooflineBackend::Xla(_) => "XLA (AOT artifact via PJRT)",
            RooflineBackend::Native => "native mirror (artifacts not built)",
        }
    );
    let pool = Pool::new(0);
    let mut csv = Csv::new(
        "fig15_plasticine_dse",
        &["dnn", "rows", "cols", "tile", "roofline", "aidg"],
    );
    for name in nets {
        let spec = DseSpec {
            rows: vec![2, 3, 4],
            cols: vec![2, 4, 6],
            tiles: vec![4, 8, 16],
            network: name.to_string(),
            keep_frac: 1.0, // Fig. 15 plots every grid point
            fp: FixedPointConfig::default(),
        };
        let t0 = std::time::Instant::now();
        let points = explore(&spec, &pool, &backend).unwrap();
        let mut t = Table::new(
            format!("Fig. 15 — {} ({} design points, {:.1}s)", name, points.len(),
                t0.elapsed().as_secs_f64()),
            &["rows", "cols", "tile", "roofline cycles", "AIDG cycles"],
        );
        for p in &points {
            t.row(&[
                p.rows.to_string(),
                p.cols.to_string(),
                p.tile.to_string(),
                fmt_cycles(p.roofline_cycles as u64),
                p.aidg_cycles.map(fmt_cycles).unwrap_or_default(),
            ]);
            csv.row(&[
                name.to_string(), p.rows.to_string(), p.cols.to_string(), p.tile.to_string(),
                format!("{:.0}", p.roofline_cycles),
                p.aidg_cycles.map(|c| c.to_string()).unwrap_or_default(),
            ]);
        }
        t.emit(&format!("fig15_dse_{name}")).unwrap();
        let best = points.first().unwrap();
        println!("best for {name}: {}x{} tile {}\n", best.rows, best.cols, best.tile);
    }
    csv.finish().unwrap();
    println!("paper: larger grids/tiles win except small TC-ResNet8 layers at tile 16 (communication bound)");
}
