//! Table 2 — TC-ResNet8 on the 16×16 Gemmini: AIDG vs roofline vs
//! simplex-fitted Timeloop-like model vs DES (paper §7.2).
use std::sync::Arc;

use acadl_perf::accel::{Gemmini, GemminiConfig};
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::expt::Comparison;
use acadl_perf::mapping::{gemm_tile::GemmTileMapper, Mapper};

fn main() {
    section("Table 2 — TC-ResNet8 on 16×16 Gemmini");
    let net = zoo::tc_resnet8();
    let mapper = GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()));
    let mapped = mapper.map_network(&net).unwrap();
    let c = Comparison::run(&mapper, &net, &mapped, Some(16)).unwrap();
    c.table("Table 2 — TC-ResNet8 on 16×16 Gemmini").emit("table2_gemmini_tcresnet").unwrap();
    println!(
        "evaluated {} of {} iterations; paper: AIDG 37 384 (+1.1% PE, 3.67% MAPE) vs \
         Verilator 36 979 (8.8 min); Timeloop −23.56% PE\n",
        c.evaluated_iters, c.total_iters
    );
}
