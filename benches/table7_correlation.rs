//! Table 7 — Pearson correlation between the per-mapping MAPE and the mean
//! Δt variances / fallback share (paper Appendix A.2).
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::expt::{dt_iteration_series, dt_overlap_series, systolic_sweep_point};
use acadl_perf::metrics::{mean, pearson, sample_variance};
use acadl_perf::report::Table;

fn main() {
    section("Table 7 — ρ(MAPE, variance) per DNN across systolic sizes");
    let full = std::env::var_os("ACADL_BENCH_FULL").is_some();
    let sizes: &[u32] = if full { &[2, 4, 6, 8, 16] } else { &[2, 4, 6, 8] };
    let nets: &[&str] = if full {
        &["tc_resnet8", "alexnet_reduced", "efficientnet_reduced"]
    } else {
        &["tc_resnet8", "efficientnet_reduced"]
    };
    let mut t = Table::new(
        "Table 7 — Pearson ρ",
        &["DNN", "ρ(MAPE, Var Δt_iter)", "ρ(MAPE, Var Δt_overlap)", "ρ(MAPE, fallback%)"],
    );
    for name in nets {
        let net = zoo::by_name(name).unwrap();
        let mut mapes = Vec::new();
        let mut vits = Vec::new();
        let mut vovs = Vec::new();
        let mut fbs = Vec::new();
        for &s in sizes {
            let p = systolic_sweep_point(s, s, &net, true).unwrap();
            let mut v_it = Vec::new();
            let mut v_ov = Vec::new();
            for l in p.layers.iter().filter(|l| !l.fused) {
                for (trace, &k_stop) in l.traces.iter().zip(&l.k_stops) {
                    let dt = dt_iteration_series(trace);
                    let ov = dt_overlap_series(trace);
                    let s0 = (k_stop as usize).min(dt.len().saturating_sub(1));
                    v_it.push(sample_variance(&dt[s0..]));
                    if s0 < ov.len() {
                        v_ov.push(sample_variance(&ov[s0..]));
                    }
                }
            }
            mapes.push(p.mape_est());
            vits.push(mean(&v_it));
            vovs.push(mean(&v_ov));
            fbs.push(p.fallback_pct());
        }
        t.row(&[
            name.to_string(),
            format!("{:.2}", pearson(&mapes, &vits)),
            format!("{:.2}", pearson(&mapes, &vovs)),
            format!("{:.2}", pearson(&mapes, &fbs)),
        ]);
    }
    t.emit("table7_correlation").unwrap();
    println!("paper: strong ρ for TC-ResNet8/AlexNet variance; EfficientNet correlates with fallback share");
}
