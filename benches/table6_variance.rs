//! Table 6 — mean sample variance of Δt_iteration / Δt_overlap after the
//! fixed-point stop, and the fallback-heuristic usage rate, per systolic
//! mapping (paper Appendix A.2).
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::expt::{dt_iteration_series, dt_overlap_series, systolic_sweep_point};
use acadl_perf::metrics::{mean, sample_variance};
use acadl_perf::report::Table;

fn main() {
    section("Table 6 — Δt variance after k_stop + fallback usage");
    let full = std::env::var_os("ACADL_BENCH_FULL").is_some();
    let sizes: &[u32] = if full { &[2, 4, 6, 8, 16] } else { &[2, 4, 8] };
    let nets: &[&str] = if full {
        &["tc_resnet8", "alexnet_reduced", "efficientnet_reduced"]
    } else {
        &["tc_resnet8"]
    };
    let mut t = Table::new(
        "Table 6 — MAPE, mean Var(Δt_iteration), mean Var(Δt_overlap), fallback share",
        &["size", "DNN", "MAPE", "Var(Δt_iter)", "Var(Δt_overlap)", "fallback layers"],
    );
    for name in nets {
        let net = zoo::by_name(name).unwrap();
        for &s in sizes {
            let p = systolic_sweep_point(s, s, &net, true).unwrap();
            // per-layer variance from k_stop to k, averaged over layers
            let mut v_it = Vec::new();
            let mut v_ov = Vec::new();
            for l in p.layers.iter().filter(|l| !l.fused) {
                for (trace, &k_stop) in l.traces.iter().zip(&l.k_stops) {
                    let dt = dt_iteration_series(trace);
                    let ov = dt_overlap_series(trace);
                    let s0 = (k_stop as usize).min(dt.len().saturating_sub(1));
                    v_it.push(sample_variance(&dt[s0..]));
                    if s0 < ov.len() {
                        v_ov.push(sample_variance(&ov[s0..]));
                    }
                }
            }
            t.row(&[
                format!("{s}x{s}"),
                name.to_string(),
                format!("{:.2}%", p.mape_est()),
                format!("{:.2}", mean(&v_it)),
                format!("{:.2}", mean(&v_ov)),
                format!("{:.1}%", p.fallback_pct()),
            ]);
        }
    }
    t.emit("table6_variance").unwrap();
    println!("paper: variance grows with array size; fallback share grows with array size");
}
