//! Fig. 16 / Appendix A.1 — fallback-heuristic percentage sweep: MAPE vs
//! whole-graph ground truth and estimation runtime for 0.1%, 1%, 5% of k.
use acadl_perf::aidg::{estimate_layer, evaluate_whole, FixedPointConfig};
use acadl_perf::accel::{Systolic, SystolicConfig};
use acadl_perf::bench_harness::{fmt_dur, section};
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::{scalar::ScalarMapper, Mapper};
use acadl_perf::metrics::mape;
use acadl_perf::report::{Csv, Table};
use std::sync::Arc;

fn main() {
    section("Fig. 16 — fallback percentage sweep (Appendix A.1)");
    let full = std::env::var_os("ACADL_BENCH_FULL").is_some();
    let sizes: &[u32] = if full { &[2, 4, 6, 8, 16] } else { &[2, 4, 8] };
    let net = zoo::tc_resnet8();
    let mut t = Table::new(
        "Fig. 16 — MAPE and runtime vs fallback fraction (TC-ResNet8)",
        &["size", "0.1% MAPE", "0.1% time", "1% MAPE", "1% time", "5% MAPE", "5% time"],
    );
    let mut csv = Csv::new("fig16_fallback_sweep", &["size", "frac", "mape", "runtime_us"]);
    for &s in sizes {
        let sys = Arc::new(Systolic::new(SystolicConfig::new(s, s)).unwrap());
        let mapper = ScalarMapper::new(sys);
        let mapped = mapper.map_network(&net).unwrap();
        // whole-graph ground truth per layer
        let mut truth = Vec::new();
        for ml in &mapped {
            if ml.fused {
                truth.push(0.0);
                continue;
            }
            let mut c = 0u64;
            for k in &ml.kernels {
                c += evaluate_whole(mapper.diagram(), k).unwrap().cycles;
            }
            truth.push(c as f64);
        }
        let mut cells = vec![format!("{s}x{s}")];
        for frac in [0.001, 0.01, 0.05] {
            let cfg = FixedPointConfig { fallback_frac: frac, keep_trace: false };
            let t0 = std::time::Instant::now();
            let mut est = Vec::new();
            for ml in &mapped {
                if ml.fused {
                    est.push(0.0);
                    continue;
                }
                let mut c = 0u64;
                for k in &ml.kernels {
                    c += estimate_layer(mapper.diagram(), k, &cfg).unwrap().cycles;
                }
                est.push(c as f64);
            }
            let dt = t0.elapsed();
            let m = mape(&truth, &est);
            cells.push(format!("{m:.2}%"));
            cells.push(fmt_dur(dt));
            csv.row(&[
                s.to_string(),
                frac.to_string(),
                format!("{m:.4}"),
                dt.as_micros().to_string(),
            ]);
        }
        t.row(&cells);
    }
    t.emit("fig16_fallback_sweep").unwrap();
    csv.finish().unwrap();
    println!("paper: 1% is the accuracy/runtime sweet spot");
}
