//! Ablation — the two Algorithm-1 refinements DESIGN.md §4 documents,
//! quantified against the DES ground truth:
//!
//! 1. **fallback sensitivity**: how much accuracy the §6.3 fixed-point
//!    criterion contributes vs always using the 1% fallback average;
//! 2. **evaluated-fraction sensitivity**: estimate quality as the fallback
//!    budget shrinks toward zero (the cost of stopping too early).
use std::sync::Arc;

use acadl_perf::accel::{Systolic, SystolicConfig};
use acadl_perf::aidg::{estimate_layer, evaluate_whole, FixedPointConfig};
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::{scalar::ScalarMapper, Mapper};
use acadl_perf::metrics::mape;
use acadl_perf::report::Table;

fn main() {
    section("Ablation — fixed-point criterion vs fallback-only estimation");
    let net = zoo::tc_resnet8();
    let mut t = Table::new(
        "Ablation — estimate MAPE vs whole graph (TC-ResNet8)",
        &["size", "fixed point (default)", "fallback-only (frac=1e-9)", "budget 0.1%", "budget 5%"],
    );
    for s in [2u32, 4, 8] {
        let sys = Arc::new(Systolic::new(SystolicConfig::new(s, s)).unwrap());
        let mapper = ScalarMapper::new(sys);
        let mapped = mapper.map_network(&net).unwrap();
        let mut truth = Vec::new();
        for ml in &mapped {
            if ml.fused {
                truth.push(0.0);
                continue;
            }
            let mut c = 0u64;
            for k in &ml.kernels {
                c += evaluate_whole(mapper.diagram(), k).unwrap().cycles;
            }
            truth.push(c as f64);
        }
        let run = |frac: f64| -> f64 {
            let cfg = FixedPointConfig { fallback_frac: frac, keep_trace: false };
            let est: Vec<f64> = mapped
                .iter()
                .map(|ml| {
                    if ml.fused {
                        return 0.0;
                    }
                    ml.kernels
                        .iter()
                        .map(|k| estimate_layer(mapper.diagram(), k, &cfg).unwrap().cycles)
                        .sum::<u64>() as f64
                })
                .collect();
            mape(&truth, &est)
        };
        t.row(&[
            format!("{s}x{s}"),
            format!("{:.3}%", run(0.01)),
            format!("{:.3}%", run(1e-9)), // budget below 3·k_block: forces minimum evaluation
            format!("{:.3}%", run(0.001)),
            format!("{:.3}%", run(0.05)),
        ]);
    }
    t.emit("ablation_model_semantics").unwrap();
    println!("the eq. 5 criterion + ≥3·k_block floor keeps estimates exact even at tiny budgets");
}
