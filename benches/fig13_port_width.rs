//! Fig. 13 — estimated cycles of the AIDG fixed-point evaluation vs the
//! refined roofline for a 12×12 systolic array while varying the memory
//! port width; divisible (C=12, K=72) vs non-divisible (C=20, K=70)
//! convolutions (paper §7.3 case study).
use std::sync::Arc;

use acadl_perf::accel::{Systolic, SystolicConfig};
use acadl_perf::aidg::{estimate_layer, FixedPointConfig};
use acadl_perf::baselines::roofline::{roofline_cycles, LayerFeatures};
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::{Layer, LayerKind};
use acadl_perf::mapping::{scalar::ScalarMapper, Mapper};
use acadl_perf::report::{Csv, Table};

fn conv(c: u32, k: u32) -> Layer {
    Layer::new(
        format!("conv_c{c}_k{k}"),
        LayerKind::Conv1d { c_in: c, l_in: 12, c_out: k, kernel: 9, stride: 1, pad: true },
    )
}

fn main() {
    section("Fig. 13 — port-width sweep on a 12×12 systolic array");
    let mut csv = Csv::new("fig13_port_width", &["case", "port_width", "aidg", "roofline"]);
    for (case, layer) in [("divisible", conv(12, 72)), ("non_divisible", conv(20, 70))] {
        let mut t = Table::new(
            format!("Fig. 13 — {case} conv (C={}, K={})",
                if case == "divisible" { 12 } else { 20 },
                if case == "divisible" { 72 } else { 70 }),
            &["port width", "AIDG cycles", "roofline cycles"],
        );
        for pw in 1..=13u32 {
            let sys =
                Arc::new(Systolic::new(SystolicConfig::new(12, 12).with_port_width(pw)).unwrap());
            let mapper = ScalarMapper::new(sys);
            let ml = mapper.map_layer(&layer).unwrap();
            let mut aidg = 0u64;
            for kern in &ml.kernels {
                aidg += estimate_layer(mapper.diagram(), kern, &FixedPointConfig::default())
                    .unwrap()
                    .cycles;
            }
            let roof =
                roofline_cycles(&LayerFeatures::from_mapping(&layer, &ml), &mapper.hw_features());
            t.row(&[pw.to_string(), aidg.to_string(), format!("{roof:.0}")]);
            csv.row(&[case.into(), pw.to_string(), aidg.to_string(), format!("{roof:.0}")]);
        }
        t.emit(&format!("fig13_{case}")).unwrap();
    }
    csv.finish().unwrap();
    println!("paper: plateaus where ⌈12/pw⌉ is constant (no change 7..11); AIDG tracks the non-divisible case better");
}
