//! Table 1 — TC-ResNet8 mapped onto UltraTrail: AIDG vs refined roofline vs
//! regression constant vs the DES ground truth (paper §7.1).
use std::sync::Arc;

use acadl_perf::accel::{UltraTrail, UltraTrailConfig};
use acadl_perf::bench_harness::{bench, section};
use acadl_perf::dnn::zoo;
use acadl_perf::expt::Comparison;
use acadl_perf::mapping::{tensor_op::TensorOpMapper, Mapper};

fn main() {
    section("Table 1 — TC-ResNet8 on UltraTrail");
    let net = zoo::tc_resnet8();
    let mapper = TensorOpMapper::new(Arc::new(UltraTrail::new(UltraTrailConfig::default()).unwrap()));
    let mapped = mapper.map_network(&net).unwrap();
    let c = Comparison::run(&mapper, &net, &mapped, None).unwrap();
    c.table("Table 1 — latency estimators, TC-ResNet8 on UltraTrail")
        .emit("table1_ultratrail")
        .unwrap();
    println!(
        "paper: AIDG 22 484 (22 ms) vs Xcelium 22 481; roofline 24 168 (+7.5% PE, 6.37% MAPE)\n"
    );
    // estimation-runtime microbenchmark (the paper's 22 ms column)
    bench("table1/aidg_estimate_runtime", 2, 10, || {
        let mapper =
            TensorOpMapper::new(Arc::new(UltraTrail::new(UltraTrailConfig::default()).unwrap()));
        let mapped = mapper.map_network(&net).unwrap();
        for ml in &mapped {
            for k in &ml.kernels {
                acadl_perf::aidg::estimate_layer(
                    mapper.diagram(),
                    k,
                    &acadl_perf::aidg::FixedPointConfig::default(),
                )
                .unwrap();
            }
        }
    });
}
