//! Table 4 — EfficientNet on the 16×16 Gemmini (paper §7.2), reduced
//! variant vs DES plus the full-size AIDG-only estimate.
use std::sync::Arc;

use acadl_perf::accel::{Gemmini, GemminiConfig};
use acadl_perf::bench_harness::section;
use acadl_perf::coordinator::Arch;
use acadl_perf::dnn::zoo;
use acadl_perf::engine::{EstimationEngine, DEFAULT_CACHE_CAP};
use acadl_perf::expt::Comparison;
use acadl_perf::mapping::{gemm_tile::GemmTileMapper, Mapper};
use acadl_perf::report::fmt_cycles;

fn main() {
    section("Table 4 — EfficientNet (reduced) on 16×16 Gemmini vs DES");
    let mapper = GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()));
    let net = zoo::efficientnet_reduced();
    let mapped = mapper.map_network(&net).unwrap();
    let c = Comparison::run(&mapper, &net, &mapped, Some(16)).unwrap();
    c.table("Table 4 — EfficientNet (56×56 reduced) on 16×16 Gemmini")
        .emit("table4_gemmini_efficientnet")
        .unwrap();
    println!("paper (224×224, vs Verilator 11.9 h): AIDG −0.56% PE, 7.51% MAPE in 17.3 s\n");

    section("Table 4b — full-size EfficientNet, AIDG estimate only (cold engine)");
    let full = zoo::efficientnet();
    let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let e = engine
        .estimate_network(
            &Arch::Gemmini(GemminiConfig::default()),
            &full,
            &acadl_perf::aidg::FixedPointConfig::default(),
        )
        .unwrap();
    println!(
        "efficientnet: {} cycles | {} of {} iterations evaluated ({:.4}%) | {}",
        fmt_cycles(e.total_cycles()),
        e.evaluated_iters(),
        e.total_iters(),
        100.0 * e.evaluated_iters() as f64 / e.total_iters().max(1) as f64,
        acadl_perf::bench_harness::fmt_dur(e.runtime),
    );
    println!(
        "engine: {} kernels, {} unique, {} deduped (MBConv blocks repeat)",
        e.stats.total_kernels, e.stats.unique_kernels, e.stats.deduped,
    );
}
