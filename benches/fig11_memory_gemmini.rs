//! Fig. 11 — peak tracked-state footprint of the AIDG fixed-point
//! evaluation per layer, Gemmini × three DNNs (box plots; see DESIGN.md —
//! this measures the evaluator's live frontier, the analog of the paper's
//! per-process peak memory).
use std::sync::Arc;

use acadl_perf::accel::{Gemmini, GemminiConfig};
use acadl_perf::aidg::{estimate_layer, FixedPointConfig};
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::{gemm_tile::GemmTileMapper, Mapper};
use acadl_perf::metrics::box_stats;
use acadl_perf::report::{fmt_bytes, Csv, Table};

fn main() {
    section("Fig. 11 — peak evaluator state per layer, 16×16 Gemmini");
    let mapper = GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()));
    let mut t = Table::new(
        "Fig. 11 — peak tracked state (per-layer box stats)",
        &["DNN", "min", "q1", "median", "q3", "max", "mean", "outliers"],
    );
    let mut csv = Csv::new("fig11_memory_gemmini", &["dnn", "layer", "peak_bytes"]);
    for name in ["tc_resnet8", "alexnet_reduced", "efficientnet_reduced"] {
        let net = zoo::by_name(name).unwrap();
        let mut peaks = Vec::new();
        for ml in mapper.map_network(&net).unwrap() {
            if ml.fused {
                continue;
            }
            let mut peak = 0u64;
            for k in &ml.kernels {
                let e = estimate_layer(mapper.diagram(), k, &FixedPointConfig::default()).unwrap();
                peak = peak.max(e.peak_state_bytes);
            }
            csv.row(&[name.into(), ml.layer_name.clone(), peak.to_string()]);
            peaks.push(peak as f64);
        }
        let b = box_stats(&peaks);
        t.row(&[
            name.into(),
            fmt_bytes(b.min as u64),
            fmt_bytes(b.q1 as u64),
            fmt_bytes(b.median as u64),
            fmt_bytes(b.q3 as u64),
            fmt_bytes(b.max as u64),
            fmt_bytes(b.mean as u64),
            b.outliers.len().to_string(),
        ]);
    }
    t.emit("fig11_memory_gemmini").unwrap();
    csv.finish().unwrap();
    println!("paper: all three DNNs stay below 1200 MiB process RSS");
}
