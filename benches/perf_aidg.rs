//! §Perf — AIDG evaluator throughput and end-to-end estimation latency
//! microbenchmarks (the EXPERIMENTS.md §Perf numbers).
use std::sync::Arc;

use acadl_perf::accel::{Systolic, SystolicConfig};
use acadl_perf::aidg::{estimate_layer, Evaluator, FixedPointConfig};
use acadl_perf::bench_harness::{bench, section};
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::{scalar::ScalarMapper, Mapper};

fn main() {
    section("perf — evaluator throughput (whole-graph sweep)");
    let sys = Arc::new(Systolic::new(SystolicConfig::new(4, 4)).unwrap());
    let mapper = ScalarMapper::new(Arc::clone(&sys) as Arc<Systolic>);
    let net = zoo::tc_resnet8();
    let mapped = mapper.map_network(&net).unwrap();
    let kern = mapped
        .iter()
        .filter(|m| !m.fused)
        .flat_map(|m| &m.kernels)
        .max_by_key(|k| k.total_insts())
        .unwrap();
    let iters = kern.k.min(20_000);
    let insts = iters * kern.insts_per_iter as u64;
    let st = bench(&format!("evaluator/{}x{} {} insts", 4, 4, insts), 1, 5, || {
        let mut ev = Evaluator::new(mapper.diagram());
        ev.run(kern, 0..iters).unwrap();
    });
    println!(
        "  => {:.2} M instructions/s\n",
        insts as f64 / st.median.as_secs_f64() / 1e6
    );

    section("perf — end-to-end estimation latency per network");
    for name in ["tc_resnet8", "efficientnet_reduced"] {
        let net = zoo::by_name(name).unwrap();
        let mapped = mapper.map_network(&net).unwrap();
        bench(&format!("estimate/{name} on systolic4x4"), 1, 5, || {
            for ml in &mapped {
                for k in &ml.kernels {
                    estimate_layer(mapper.diagram(), k, &FixedPointConfig::default()).unwrap();
                }
            }
        });
    }
}
