//! §Perf — AIDG evaluator throughput, end-to-end estimation latency,
//! unified-engine cold/warm microbenchmarks, and the DSE sweep phase (the
//! EXPERIMENTS.md §Perf numbers). Emits `BENCH_eval.json` (evaluator
//! nodes/sec, iterations/sec, and peak frontier bytes per arch × net, plus
//! a `dispatch` section comparing the threaded superinstruction tape
//! against the node-table walk: nodes/sec under both modes, fusion rate,
//! and dynamic-latency memo hit rate),
//! `BENCH_engine.json` (cold/warm wall-times, hit rates) and
//! `BENCH_dse.json` (points/sec, pre-filter survival, cross-candidate warm
//! hit rate, and the lane-batched sweep's `batch_nodes_per_sec` /
//! `avg_lanes` / `divergence_rate`), `BENCH_accuracy.json` (raw vs
//! calibrated MAPE + CI coverage on a seeded train/held-out corpus — the
//! input to CI's hard accuracy gate), and `BENCH_serve.json` (loopback TCP
//! requests/sec at 1/4/16 concurrent clients, the persistent store's
//! warm-hit rate after a simulated restart, and p95 request latency from
//! the `obs` histograms) so future PRs have a perf trajectory.
//! `--smoke` runs the evaluator, DSE, accuracy, and serve phases (CI's
//! artifact-shape checks cover all four emitted files).
use std::sync::Arc;
use std::time::Instant;

use acadl_perf::accel::{
    Gemmini, GemminiConfig, Systolic, SystolicConfig, UltraTrail, UltraTrailConfig,
};
use acadl_perf::acadl::text::ast::{Param, Span, Spanned, Sweep, SweepDim, SweepItem};
use acadl_perf::acadl::text::{parse, PExpr};
use acadl_perf::aidg::{
    estimate_layer, DispatchMode, DispatchStats, Evaluator, FixedPointConfig, FusionStats,
};
use acadl_perf::bench_harness::{bench, section, smoke, time_once};
use acadl_perf::coordinator::{Arch, Pool};
use acadl_perf::dnn::text::NetRegistry;
use acadl_perf::dnn::zoo;
use acadl_perf::dse::{explore_space, RooflineBackend, SweepOptions, SweepSpace};
use acadl_perf::engine::{EstimationEngine, DEFAULT_CACHE_CAP};
use acadl_perf::mapping::{
    gemm_tile::GemmTileMapper, scalar::ScalarMapper, tensor_op::TensorOpMapper, Mapper,
};
use acadl_perf::metrics::counters;

/// The `bench_eval` phase: evaluator-level throughput per arch × net
/// through the iteration-program hot path, emitted as `BENCH_eval.json`
/// (nodes/sec, iterations/sec, peak frontier bytes). `iter_cap` bounds the
/// iterations evaluated per kernel so the smoke pass stays fast.
fn bench_eval(iter_cap: u64, nets: &[&str]) {
    section("perf — evaluator iteration programs per arch × net (BENCH_eval.json)");
    let mappers: Vec<(&str, Box<dyn Mapper>)> = vec![
        (
            "systolic4x4",
            Box::new(ScalarMapper::new(Arc::new(
                Systolic::new(SystolicConfig::new(4, 4)).unwrap(),
            ))),
        ),
        (
            "gemmini16",
            Box::new(GemmTileMapper::new(Arc::new(
                Gemmini::new(GemminiConfig::default()).unwrap(),
            ))),
        ),
        (
            "ultratrail",
            Box::new(TensorOpMapper::new(Arc::new(
                UltraTrail::new(UltraTrailConfig::default()).unwrap(),
            ))),
        ),
    ];
    let mut records = Vec::new();
    for (arch, mapper) in &mappers {
        for net_name in nets {
            let net = zoo::by_name(net_name).unwrap();
            let Ok(mapped) = mapper.map_network(&net) else {
                continue; // e.g. 2-D networks on UltraTrail
            };
            let mut nodes = 0u64;
            let mut iters = 0u64;
            let mut kernels = 0u64;
            let mut peak = 0u64;
            let t0 = Instant::now();
            for ml in mapped.iter().filter(|l| !l.fused) {
                for kernel in &ml.kernels {
                    // bound per-kernel work in iterations AND instructions
                    // (GEMM kernels can carry hundreds of insts/iteration)
                    let insts_budget =
                        (200 * iter_cap / kernel.insts_per_iter.max(1) as u64).max(1);
                    let range = 0..kernel.k.min(iter_cap).min(insts_budget);
                    let mut ev = Evaluator::new(mapper.diagram());
                    ev.run(kernel, range).unwrap();
                    nodes += ev.st.nodes;
                    iters += ev.iter_stats.len() as u64;
                    peak = peak.max(ev.st.peak_bytes as u64);
                    kernels += 1;
                }
            }
            let wall = t0.elapsed();
            let secs = wall.as_secs_f64().max(1e-9);
            println!(
                "  eval/{arch} x {net_name}: {:.2} M nodes/s, {:.1} k iters/s, peak {} B",
                nodes as f64 / secs / 1e6,
                iters as f64 / secs / 1e3,
                peak
            );
            records.push(format!(
                "    {{\n      \"arch\": \"{arch}\",\n      \"network\": \"{net_name}\",\n      \
                 \"kernels\": {kernels},\n      \"nodes\": {nodes},\n      \
                 \"evaluated_iters\": {iters},\n      \"wall_ms\": {:.3},\n      \
                 \"nodes_per_sec\": {:.1},\n      \"iters_per_sec\": {:.1},\n      \
                 \"peak_frontier_bytes\": {peak}\n    }}",
                secs * 1e3,
                nodes as f64 / secs,
                iters as f64 / secs,
            ));
        }
    }
    // ---- obs_overhead: evaluator throughput, tracing off vs on ----
    // The tracing layer's contract is "free when off, allocation-free when
    // on"; this pins the second half with numbers (the evaluator's phase
    // timing is raw clock reads, so "on" should cost low single digits).
    let (ov_arch, ov_mapper) = &mappers[0];
    let ov_net = zoo::by_name(nets[0]).unwrap();
    let ov_mapped = ov_mapper.map_network(&ov_net).unwrap();
    let measure = || {
        let mut nodes = 0u64;
        let t0 = Instant::now();
        for ml in ov_mapped.iter().filter(|l| !l.fused) {
            for kernel in &ml.kernels {
                let insts_budget =
                    (200 * iter_cap / kernel.insts_per_iter.max(1) as u64).max(1);
                let range = 0..kernel.k.min(iter_cap).min(insts_budget);
                let mut ev = Evaluator::new(ov_mapper.diagram());
                ev.run(kernel, range).unwrap();
                nodes += ev.st.nodes;
            }
        }
        nodes as f64 / t0.elapsed().as_secs_f64().max(1e-9)
    };
    acadl_perf::obs::set_enabled(false);
    let off_nps = measure();
    acadl_perf::obs::set_enabled(true);
    let on_nps = measure();
    acadl_perf::obs::set_enabled(false);
    println!(
        "  obs_overhead/{ov_arch} x {}: {:.2} M nodes/s off, {:.2} M nodes/s on ({:.1}% ratio)",
        nets[0],
        off_nps / 1e6,
        on_nps / 1e6,
        100.0 * on_nps / off_nps.max(1e-9),
    );

    // ---- dispatch: fused superinstruction tape vs node-table walk ----
    // Same workload through both dispatch modes (they are pinned
    // bit-identical by the differential suite, so this is a pure throughput
    // comparison), plus the tape's static fusion rate and the
    // dynamic-latency memo's hit rate from the threaded run.
    let measure_mode = |mode: DispatchMode| {
        let mut nodes = 0u64;
        let mut dstats = DispatchStats::default();
        let mut fusion = FusionStats::default();
        let t0 = Instant::now();
        for ml in ov_mapped.iter().filter(|l| !l.fused) {
            for kernel in &ml.kernels {
                let insts_budget =
                    (200 * iter_cap / kernel.insts_per_iter.max(1) as u64).max(1);
                let range = 0..kernel.k.min(iter_cap).min(insts_budget);
                let mut ev = Evaluator::new_with_dispatch(ov_mapper.diagram(), mode);
                ev.run(kernel, range).unwrap();
                nodes += ev.st.nodes;
                let s = ev.dispatch_stats();
                dstats.threaded_instrs += s.threaded_instrs;
                dstats.fallback_instrs += s.fallback_instrs;
                dstats.fused_ops += s.fused_ops;
                dstats.memo_hits += s.memo_hits;
                dstats.memo_misses += s.memo_misses;
                let f = ev.fusion_stats();
                fusion.offsets += f.offsets;
                fusion.fusible_offsets += f.fusible_offsets;
                fusion.ops += f.ops;
                fusion.nodes += f.nodes;
                fusion.fused_cycles += f.fused_cycles;
            }
        }
        (nodes as f64 / t0.elapsed().as_secs_f64().max(1e-9), dstats, fusion)
    };
    let (table_nps, _, _) = measure_mode(DispatchMode::NodeTable);
    let (threaded_nps, dstats, fusion) = measure_mode(DispatchMode::Threaded);
    let memo_total = dstats.memo_hits + dstats.memo_misses;
    let memo_hit_rate = dstats.memo_hits as f64 / memo_total.max(1) as f64;
    let fusible_frac = fusion.fusible_offsets as f64 / fusion.offsets.max(1) as f64;
    println!(
        "  dispatch/{ov_arch} x {}: {:.2} M nodes/s node-table, {:.2} M nodes/s threaded \
         ({:.2}x) | fusion rate {:.1}%, memo hit rate {:.1}%",
        nets[0],
        table_nps / 1e6,
        threaded_nps / 1e6,
        threaded_nps / table_nps.max(1e-9),
        fusion.fusion_rate() * 100.0,
        memo_hit_rate * 100.0,
    );

    let json = format!(
        "{{\n  \"bench\": \"eval_program\",\n  \"iter_cap\": {iter_cap},\n  \
         \"obs_overhead\": {{\n    \"arch\": \"{ov_arch}\",\n    \"network\": \"{}\",\n    \
         \"nodes_per_sec_tracing_off\": {off_nps:.1},\n    \
         \"nodes_per_sec_tracing_on\": {on_nps:.1},\n    \
         \"on_off_ratio\": {:.4}\n  }},\n  \
         \"dispatch\": {{\n    \"arch\": \"{ov_arch}\",\n    \"network\": \"{}\",\n    \
         \"nodes_per_sec_node_table\": {table_nps:.1},\n    \
         \"nodes_per_sec_threaded\": {threaded_nps:.1},\n    \
         \"speedup\": {:.4},\n    \"fusion_rate\": {:.4},\n    \
         \"fusible_offset_frac\": {fusible_frac:.4},\n    \
         \"dyn_memo_hit_rate\": {memo_hit_rate:.4},\n    \
         \"threaded_instrs\": {},\n    \"fallback_instrs\": {}\n  }},\n  \
         \"records\": [\n{}\n  ]\n}}\n",
        nets[0],
        on_nps / off_nps.max(1e-9),
        nets[0],
        threaded_nps / table_nps.max(1e-9),
        fusion.fusion_rate(),
        dstats.threaded_instrs,
        dstats.fallback_instrs,
        records.join(",\n")
    );
    std::fs::write("BENCH_eval.json", &json).expect("writing BENCH_eval.json");
    println!(
        "  => wrote BENCH_eval.json ({} records + obs_overhead + dispatch)",
        records.len()
    );
}

fn main() {
    if smoke() {
        // CI's fast pass: emit + shape-check the evaluator, DSE, and
        // accuracy artifacts (the DSE phase is the only producer of the
        // lane-batched throughput record, and the accuracy gate needs
        // BENCH_accuracy.json, so smoke must run all three)
        bench_eval(500, &["tc_resnet8"]);
        bench_dse();
        bench_accuracy();
        bench_serve(4);
        return;
    }
    bench_eval(20_000, &["tc_resnet8", "efficientnet_reduced"]);

    section("perf — evaluator throughput (whole-graph sweep)");
    let sys = Arc::new(Systolic::new(SystolicConfig::new(4, 4)).unwrap());
    let mapper = ScalarMapper::new(Arc::clone(&sys) as Arc<Systolic>);
    let net = zoo::tc_resnet8();
    let mapped = mapper.map_network(&net).unwrap();
    let kern = mapped
        .iter()
        .filter(|m| !m.fused)
        .flat_map(|m| &m.kernels)
        .max_by_key(|k| k.total_insts())
        .unwrap();
    let iters = kern.k.min(20_000);
    let insts = iters * kern.insts_per_iter as u64;
    let st = bench(&format!("evaluator/{}x{} {} insts", 4, 4, insts), 1, 5, || {
        let mut ev = Evaluator::new(mapper.diagram());
        ev.run(kern, 0..iters).unwrap();
    });
    println!(
        "  => {:.2} M instructions/s\n",
        insts as f64 / st.median.as_secs_f64() / 1e6
    );

    section("perf — end-to-end estimation latency per network");
    for name in ["tc_resnet8", "efficientnet_reduced"] {
        let net = zoo::by_name(name).unwrap();
        let mapped = mapper.map_network(&net).unwrap();
        bench(&format!("estimate/{name} on systolic4x4"), 1, 5, || {
            for ml in &mapped {
                for k in &ml.kernels {
                    estimate_layer(mapper.diagram(), k, &FixedPointConfig::default()).unwrap();
                }
            }
        });
    }

    section("perf — unified engine: cold vs warm (content-addressed cache)");
    let arch = Arch::Systolic(SystolicConfig::new(4, 4));
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let (cold, cold_dt) =
        time_once("engine/tc_resnet8 on systolic4x4 (cold)", || {
            engine.estimate_network(&arch, &net, &fp).unwrap()
        });
    let (warm, warm_dt) =
        time_once("engine/tc_resnet8 on systolic4x4 (warm)", || {
            engine.estimate_network(&arch, &net, &fp).unwrap()
        });
    assert_eq!(cold.total_cycles(), warm.total_cycles(), "cache must be cycle-identical");
    let hit_rate = (warm.stats.cache_hits + warm.stats.deduped) as f64
        / warm.stats.total_kernels.max(1) as f64;

    section("perf — described networks (net/*.toml through the same cache)");
    // the textual description compiles to the zoo builder's exact layer
    // list, so its kernels carry the same content-addressed keys — the
    // zoo-warmed engine serves the described network without evaluating
    // anything
    let src =
        std::fs::read_to_string("net/tc_resnet8.toml").expect("reading net/tc_resnet8.toml");
    let (described, compile_dt) = time_once("compile net/tc_resnet8.toml", || {
        NetRegistry::global().get_or_compile(&src, "net/tc_resnet8.toml").unwrap()
    });
    let (net_est, _net_dt) =
        time_once("engine/net:tc_resnet8 on systolic4x4 (described, zoo-warmed)", || {
            engine.estimate_network(&arch, &described, &fp).unwrap()
        });
    assert_eq!(
        net_est.total_cycles(),
        cold.total_cycles(),
        "described network must be cycle-identical to the zoo builder"
    );
    let net_hit_rate = (net_est.stats.cache_hits + net_est.stats.deduped) as f64
        / net_est.stats.total_kernels.max(1) as f64;

    let json = format!(
        "{{\n  \"bench\": \"engine_cold_warm\",\n  \"network\": \"tc_resnet8\",\n  \
         \"arch\": \"systolic4x4\",\n  \"cold_ms\": {:.3},\n  \"warm_ms\": {:.3},\n  \
         \"speedup\": {:.2},\n  \"total_kernels\": {},\n  \"unique_kernels\": {},\n  \
         \"deduped\": {},\n  \"warm_hit_rate\": {:.4},\n  \"net_compile_ms\": {:.3},\n  \
         \"net_warm_hit_rate\": {:.4}\n}}\n",
        cold_dt.as_secs_f64() * 1e3,
        warm_dt.as_secs_f64() * 1e3,
        cold_dt.as_secs_f64() / warm_dt.as_secs_f64().max(1e-9),
        cold.stats.total_kernels,
        cold.stats.unique_kernels,
        cold.stats.deduped,
        hit_rate,
        compile_dt.as_secs_f64() * 1e3,
        net_hit_rate,
    );
    std::fs::write("BENCH_engine.json", &json).expect("writing BENCH_engine.json");
    println!(
        "  => warm hit rate {:.1}% | described-net warm hit rate {:.1}% — wrote BENCH_engine.json",
        hit_rate * 100.0,
        net_hit_rate * 100.0
    );

    section("perf — tracing layer: span profile of one traced estimate");
    acadl_perf::obs::set_enabled(true);
    EstimationEngine::new(DEFAULT_CACHE_CAP)
        .estimate_network(&arch, &net, &fp)
        .expect("traced estimate");
    acadl_perf::obs::set_enabled(false);
    print!("{}", acadl_perf::report::profile(&acadl_perf::obs::snapshot()).to_markdown());

    bench_dse();
    bench_accuracy();
    bench_serve(12);
}

/// The accuracy phase: train the stacked calibration model on a seeded
/// (machine × kernel) corpus, then score raw AIDG vs calibrated estimates
/// against the DES on a *held-out* corpus — same machines, disjoint kernel
/// seed — and prove the model threads through the engine. Emitted as
/// `BENCH_accuracy.json`, which CI gates hard: calibration must not make
/// estimates worse, and the confidence bounds must actually cover the DES.
/// Every seed is pinned, so the gate is deterministic.
fn bench_accuracy() {
    use acadl_perf::calib::{self, SampleSpec};

    section("perf — accuracy: raw vs calibrated MAPE, train + held-out (BENCH_accuracy.json)");
    let train_spec = SampleSpec::default();
    let holdout_spec = SampleSpec { kernel_seed: 0xD0_7E57, ..train_spec };
    let (model, corpus) = calib::train_from_spec(&train_spec).expect("calibration training");
    let train_acc = calib::evaluate(&model, &corpus.samples);
    let holdout =
        calib::sample_corpus(&holdout_spec).expect("held-out corpus (same machines, new kernels)");
    let holdout_acc = calib::evaluate(&model, &holdout.samples);
    println!(
        "  train:   {} samples, raw MAPE {:.2}% -> calibrated {:.2}%, coverage {:.1}%",
        train_acc.samples,
        train_acc.raw_mape,
        train_acc.calibrated_mape,
        train_acc.ci_coverage * 100.0
    );
    println!(
        "  holdout: {} samples, raw MAPE {:.2}% -> calibrated {:.2}%, coverage {:.1}%",
        holdout_acc.samples,
        holdout_acc.raw_mape,
        holdout_acc.calibrated_mape,
        holdout_acc.ci_coverage * 100.0
    );

    // engine-threading proof: a calibrated engine must stamp whole-network
    // estimates (the serve/CLI surface reads exactly these accessors)
    let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    engine.set_calibration(Some(Arc::new(model.clone())));
    let est = engine
        .estimate_network(
            &Arch::Gemmini(GemminiConfig::default()),
            &zoo::tc_resnet8(),
            &FixedPointConfig::default(),
        )
        .expect("calibrated engine estimate");
    let engine_total =
        est.calibrated_cycles().expect("calibrated cycles must thread through the engine");
    let (ci_lo, ci_hi) = est.ci_bounds().expect("CI bounds must thread through the engine");

    let acc_json = |a: &calib::Accuracy| {
        format!(
            "{{\n    \"samples\": {},\n    \"raw_mape\": {:.4},\n    \
             \"calibrated_mape\": {:.4},\n    \"ci_coverage\": {:.4}\n  }}",
            a.samples, a.raw_mape, a.calibrated_mape, a.ci_coverage
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"accuracy\",\n  \"machine_seed\": {},\n  \
         \"train_kernel_seed\": {},\n  \"holdout_kernel_seed\": {},\n  \
         \"machines\": {},\n  \"classes\": {},\n  \"train\": {},\n  \"holdout\": {},\n  \
         \"engine\": {{\n    \"arch\": \"gemmini16\",\n    \"network\": \"tc_resnet8\",\n    \
         \"calibrated_total\": {engine_total},\n    \"ci_lo\": {ci_lo},\n    \
         \"ci_hi\": {ci_hi}\n  }}\n}}\n",
        train_spec.machine_seed,
        train_spec.kernel_seed,
        holdout_spec.kernel_seed,
        corpus.machines,
        model.class_count(),
        acc_json(&train_acc),
        acc_json(&holdout_acc),
    );
    std::fs::write("BENCH_accuracy.json", &json).expect("writing BENCH_accuracy.json");
    println!(
        "  => holdout raw {:.2}% vs calibrated {:.2}%, coverage {:.1}% — wrote BENCH_accuracy.json",
        holdout_acc.raw_mape,
        holdout_acc.calibrated_mape,
        holdout_acc.ci_coverage * 100.0
    );
}

/// The DSE phase: `[sweep]` throughput with the pre-filter, cross-candidate
/// kernel reuse under locality scheduling, and the lane-batched evaluator's
/// throughput over the shipped Fig.-15 space — emitted as `BENCH_dse.json`.
/// Runs in both smoke and full mode so CI's artifact-shape check always
/// sees the batch record.
fn bench_dse() {
    section("perf — DSE: [sweep] throughput, pre-filter survival, kernel reuse");
    let net = zoo::tc_resnet8();
    let pool = Pool::new(0);
    let backend = RooflineBackend::auto();
    let src = std::fs::read_to_string("arch/systolic_16x16.toml")
        .expect("reading arch/systolic_16x16.toml");
    let space = SweepSpace::from_source(&src, "arch/systolic_16x16.toml", None)
        .expect("compiling the shipped systolic sweep");
    let dse_engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let opts = SweepOptions { keep_frac: 0.5, ..Default::default() };
    let (outcome, dse_dt) = time_once("dse/systolic [sweep] x tc_resnet8 (keep 0.5)", || {
        explore_space(&space, &net, &opts, &pool, &backend, &dse_engine).unwrap()
    });
    let mappable = (outcome.enumerated - outcome.skipped).max(1);
    let points_per_sec = outcome.enumerated as f64 / dse_dt.as_secs_f64().max(1e-9);
    let survival = outcome.estimated as f64 / mappable as f64;

    // cross-candidate kernel reuse: sweep a structure-neutral `rev`
    // dimension next to a structural `cols` dimension — same-`cols`
    // candidates digest equally, so under locality scheduling the second
    // and third members of each group are served from the estimate cache
    let mut dup = parse(&src).expect("parsing systolic description");
    for p in &mut dup.params {
        if p.name.node == "rows" {
            p.value = Spanned::bare(2);
        }
    }
    dup.params.push(Param { name: Spanned::bare("rev".into()), value: Spanned::bare(0) });
    let rev_range = SweepItem::Range { lo: PExpr::Const(0), hi: PExpr::Const(3), step: None };
    dup.sweep = Some(Sweep {
        dims: vec![
            SweepDim {
                name: Spanned::bare("rev".into()),
                items: vec![rev_range],
                span: Span::default(),
            },
            SweepDim {
                name: Spanned::bare("cols".into()),
                items: vec![
                    SweepItem::Scalar(PExpr::Const(2)),
                    SweepItem::Scalar(PExpr::Const(3)),
                ],
                span: Span::default(),
            },
        ],
        when: None,
        cap: None,
        span: Span::default(),
    });
    let dup_space = SweepSpace::from_description(dup, "systolic-dup", None)
        .expect("compiling the duplicate-structure sweep");
    let dup_engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let (dup_outcome, _) = time_once("dse/duplicate-structure sweep (locality)", || {
        explore_space(
            &dup_space,
            &net,
            // serial dispatch: this record measures cross-candidate *cache*
            // reuse; the batched path below carries its own record
            &SweepOptions { batch: false, ..Default::default() },
            &pool,
            &backend,
            &dup_engine,
        )
        .unwrap()
    });
    let warm_hit_rate = dup_outcome.warm_hit_rate();
    assert!(
        warm_hit_rate > 0.0,
        "multi-point sweep must reuse KernelKeys across candidates: {:?}",
        dup_outcome.stats
    );

    section("perf — DSE: lane-batched evaluation (shipped plasticine sweep)");
    // `tile` parameterizes the mapper binding, not the datapath, so the
    // shipped 18-point rows × cols × tile space digests into 9 two-member
    // groups whose members carry *different* kernels — exactly the shape
    // the lane-batched evaluator amortizes. Counter deltas around the
    // sweep turn into the throughput record; avg_lanes > 1 is the proof
    // that lockstep sharing actually engaged.
    let psrc = std::fs::read_to_string("arch/plasticine_3x6.toml")
        .expect("reading arch/plasticine_3x6.toml");
    let pspace = SweepSpace::from_source(&psrc, "arch/plasticine_3x6.toml", None)
        .expect("compiling the shipped plasticine sweep");
    let batch_engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let groups0 = counters::AIDG_BATCH_GROUPS.get();
    let lanes0 = counters::AIDG_BATCH_LANES.get();
    let evict0 = counters::AIDG_BATCH_EVICTIONS.get();
    let nodes0 = counters::AIDG_NODES.get();
    let (batch_outcome, batch_dt) =
        time_once("dse/plasticine [sweep] x tc_resnet8 (lane-batched, keep 1.0)", || {
            explore_space(
                &pspace,
                &net,
                &SweepOptions { keep_frac: 1.0, ..Default::default() },
                &pool,
                &backend,
                &batch_engine,
            )
            .unwrap()
        });
    let groups = counters::AIDG_BATCH_GROUPS.get() - groups0;
    let lanes = counters::AIDG_BATCH_LANES.get() - lanes0;
    let evictions = counters::AIDG_BATCH_EVICTIONS.get() - evict0;
    let batch_nodes = counters::AIDG_NODES.get() - nodes0;
    let batch_secs = batch_dt.as_secs_f64().max(1e-9);
    let avg_lanes = lanes as f64 / groups.max(1) as f64;
    let divergence_rate = evictions as f64 / lanes.max(1) as f64;
    let batch_nodes_per_sec = batch_nodes as f64 / batch_secs;
    let batch_points_per_sec = batch_outcome.enumerated as f64 / batch_secs;
    assert!(groups > 0, "the shipped plasticine sweep must drive the batched evaluator");
    assert!(
        avg_lanes > 1.0,
        "lockstep sharing must engage on the shipped space \
         ({lanes} lanes over {groups} groups)"
    );
    println!(
        "  batch/plasticine_3x6 x tc_resnet8: {:.1} points/s, {:.2} M nodes/s, \
         {avg_lanes:.2} avg lanes, {:.1}% divergence",
        batch_points_per_sec,
        batch_nodes_per_sec / 1e6,
        divergence_rate * 100.0
    );

    // three sweeps, three labeled records: the shipped-file sweep carries
    // the throughput/survival numbers, the synthetic duplicate-structure
    // sweep carries the cross-candidate reuse numbers, and the batched
    // plasticine sweep carries the lockstep-sharing numbers — mixing them
    // under one arch label would make the perf trajectory lie about its
    // workload
    let dse_json = format!(
        "{{\n  \"bench\": \"dse_sweep\",\n  \"arch\": \"arch/systolic_16x16.toml\",\n  \
         \"network\": \"tc_resnet8\",\n  \"points\": {},\n  \"wall_ms\": {:.3},\n  \
         \"points_per_sec\": {:.2},\n  \"prefilter_survival\": {:.4},\n  \
         \"dup_sweep\": {{\n    \"arch\": \"systolic-dup (rev x cols, locality)\",\n    \
         \"points\": {},\n    \"warm_hit_rate\": {:.4},\n    \"reuse_rate\": {:.4}\n  }},\n  \
         \"batch_sweep\": {{\n    \"bench\": \"dse_batch\",\n    \
         \"arch\": \"arch/plasticine_3x6.toml\",\n    \"points\": {},\n    \
         \"wall_ms\": {:.3},\n    \"points_per_sec\": {:.2},\n    \
         \"batch_nodes_per_sec\": {:.1},\n    \"groups\": {},\n    \"lanes\": {},\n    \
         \"avg_lanes\": {:.4},\n    \"divergence_rate\": {:.4}\n  }}\n}}\n",
        outcome.enumerated,
        dse_dt.as_secs_f64() * 1e3,
        points_per_sec,
        survival,
        dup_outcome.enumerated,
        warm_hit_rate,
        dup_outcome.reuse_rate(),
        batch_outcome.enumerated,
        batch_secs * 1e3,
        batch_points_per_sec,
        batch_nodes_per_sec,
        groups,
        lanes,
        avg_lanes,
        divergence_rate,
    );
    std::fs::write("BENCH_dse.json", &dse_json).expect("writing BENCH_dse.json");
    println!(
        "  => {points_per_sec:.1} points/s | pre-filter kept {:.0}% | cross-candidate warm \
         hit rate {:.1}% | batch avg lanes {avg_lanes:.2} — wrote BENCH_dse.json",
        survival * 100.0,
        warm_hit_rate * 100.0
    );
}

/// One bench client: drive `estimates` round-trip requests over one TCP
/// connection, asserting every reply, then quit. Returns requests served.
fn drive_serve_client(addr: std::net::SocketAddr, estimates: usize) -> usize {
    use std::io::{BufRead as _, BufReader, Write as _};
    let conn = std::net::TcpStream::connect(addr).expect("connecting bench client");
    let mut writer = conn.try_clone().expect("cloning bench stream");
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    for i in 0..estimates {
        let spec = ["ultratrail", "gemmini", "systolic:4x4"][i % 3];
        writeln!(writer, "estimate {spec} tc_resnet8").expect("bench request");
        line.clear();
        reader.read_line(&mut line).expect("bench reply");
        assert!(line.contains("cycles="), "bench reply: {line:?}");
    }
    writer.write_all(b"quit\n").expect("bench quit");
    estimates
}

/// The serve phase: loopback TCP round-trip throughput at 1/4/16
/// concurrent clients against a warmed engine, the persistent store's
/// warm-hit rate after a simulated restart (cache cleared, store kept),
/// and p95 request latency from the `serve.request` span histogram —
/// emitted as `BENCH_serve.json`. Runs last: it attaches (and detaches) a
/// store on the process-global engine.
fn bench_serve(reqs_per_client: usize) {
    use acadl_perf::coordinator::{NetServer, ServeOptions};

    section("perf — serve: loopback TCP throughput + store warm hits (BENCH_serve.json)");
    let store_dir =
        std::env::temp_dir().join(format!("acadl-bench-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let opts = ServeOptions { store: Some(store_dir.clone()), ..Default::default() };
    // p95 comes from the serve.request span histogram
    acadl_perf::obs::set_enabled(true);
    let srv = NetServer::bind("127.0.0.1:0", opts).expect("binding loopback bench server");
    let addr = srv.local_addr();
    let handle = srv.shutdown_handle();
    let server = std::thread::spawn(move || srv.run().expect("bench serve run"));

    // warm the engine and the store so the measured rounds are steady-state
    drive_serve_client(addr, reqs_per_client);

    let mut round_records = Vec::new();
    for &clients in &[1usize, 4, 16] {
        let t0 = Instant::now();
        let threads: Vec<_> = (0..clients)
            .map(|_| std::thread::spawn(move || drive_serve_client(addr, reqs_per_client)))
            .collect();
        let requests: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        let wall = t0.elapsed();
        let rps = requests as f64 / wall.as_secs_f64().max(1e-9);
        println!(
            "  serve/{clients:>2} clients: {requests} requests in {:.1} ms ({rps:.0} req/s)",
            wall.as_secs_f64() * 1e3
        );
        round_records.push(format!(
            "    {{\n      \"clients\": {clients},\n      \"requests\": {requests},\n      \
             \"wall_ms\": {:.3},\n      \"requests_per_sec\": {rps:.1}\n    }}",
            wall.as_secs_f64() * 1e3
        ));
    }

    // simulated restart: the in-memory cache dies, the store survives —
    // one more round must be served from store promotions, not evaluations
    EstimationEngine::global().clear_cache();
    let h0 = counters::STORE_HITS.get();
    let m0 = counters::STORE_MISSES.get();
    drive_serve_client(addr, reqs_per_client.max(3));
    let store_hits = counters::STORE_HITS.get() - h0;
    let store_misses = counters::STORE_MISSES.get() - m0;
    let store_warm_hit_rate =
        store_hits as f64 / (store_hits + store_misses).max(1) as f64;
    assert!(
        store_hits > 0,
        "the cold-cache round must hit the persistent store \
         ({store_hits} hits / {store_misses} misses)"
    );

    handle.shutdown();
    server.join().expect("bench server thread");
    let p95_request_ns = acadl_perf::obs::snapshot()
        .spans
        .iter()
        .find(|s| s.name == "serve.request")
        .map_or(0, |s| s.summary.p95_ns);
    acadl_perf::obs::set_enabled(false);
    EstimationEngine::global().attach_store(None);
    let _ = std::fs::remove_dir_all(&store_dir);

    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"requests_per_client\": {reqs_per_client},\n  \
         \"clients\": [\n{}\n  ],\n  \
         \"store_warm_hit_rate\": {store_warm_hit_rate:.4},\n  \
         \"p95_request_ns\": {p95_request_ns}\n}}\n",
        round_records.join(",\n")
    );
    std::fs::write("BENCH_serve.json", &json).expect("writing BENCH_serve.json");
    println!(
        "  => store warm hit rate {:.1}% | p95 request {:.2} ms — wrote BENCH_serve.json",
        store_warm_hit_rate * 100.0,
        p95_request_ns as f64 / 1e6
    );
}
