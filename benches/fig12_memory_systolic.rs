//! Fig. 12 — peak tracked-state footprint of the fixed-point evaluation
//! across systolic array sizes and DNNs (box plots).
use acadl_perf::bench_harness::section;
use acadl_perf::dnn::zoo;
use acadl_perf::expt::systolic_sweep_point;
use acadl_perf::metrics::box_stats;
use acadl_perf::report::{fmt_bytes, Csv, Table};

fn main() {
    section("Fig. 12 — peak evaluator state across systolic sizes");
    let full = std::env::var_os("ACADL_BENCH_FULL").is_some();
    let sizes: &[u32] = if full { &[2, 4, 6, 8, 16] } else { &[2, 4, 8, 16] };
    let nets: &[&str] = if full {
        &["tc_resnet8", "alexnet_reduced", "efficientnet_reduced"]
    } else {
        &["tc_resnet8"]
    };
    let mut t = Table::new(
        "Fig. 12 — peak tracked state (per-layer box stats)",
        &["size", "DNN", "min", "median", "max", "mean", "outliers"],
    );
    let mut csv = Csv::new("fig12_memory_systolic", &["size", "dnn", "layer", "peak_bytes"]);
    for name in nets {
        let net = zoo::by_name(name).unwrap();
        for &s in sizes {
            let p = systolic_sweep_point(s, s, &net, false).unwrap();
            let peaks: Vec<f64> = p
                .layers
                .iter()
                .filter(|l| !l.fused)
                .map(|l| l.peak_state_bytes as f64)
                .collect();
            for l in p.layers.iter().filter(|l| !l.fused) {
                csv.row(&[
                    s.to_string(),
                    name.to_string(),
                    l.name.clone(),
                    l.peak_state_bytes.to_string(),
                ]);
            }
            let b = box_stats(&peaks);
            t.row(&[
                format!("{s}x{s}"),
                name.to_string(),
                fmt_bytes(b.min as u64),
                fmt_bytes(b.median as u64),
                fmt_bytes(b.max as u64),
                fmt_bytes(b.mean as u64),
                b.outliers.len().to_string(),
            ]);
        }
    }
    t.emit("fig12_memory_systolic").unwrap();
    csv.finish().unwrap();
    println!("paper: memory grows with array size and instructions/iteration (max 158.68 GiB RSS outlier)");
}
