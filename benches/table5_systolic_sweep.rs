//! Table 5 — AIDG fixed-point evaluation vs refined roofline for varying
//! systolic array sizes (paper §7.3). As in the paper, the whole-graph AIDG
//! evaluation is the measured-cycles ground truth.
//!
//! Default sweep: TC-ResNet8 on 2×2…16×16 and the reduced EfficientNet /
//! AlexNet on 4×4. Set `ACADL_BENCH_FULL=1` for the full grid (minutes).
use acadl_perf::bench_harness::{fmt_dur, section};
use acadl_perf::dnn::zoo;
use acadl_perf::expt::systolic_sweep_point;
use acadl_perf::report::{fmt_cycles, Csv, Table};

fn main() {
    let full = std::env::var_os("ACADL_BENCH_FULL").is_some();
    section("Table 5 — systolic array sweep (fixed point vs roofline vs whole graph)");
    let sizes: &[u32] = if full { &[2, 4, 6, 8, 16] } else { &[2, 4, 6, 8, 16] };
    let mut nets = vec![("tc_resnet8", sizes.to_vec())];
    if full {
        nets.push(("alexnet_reduced", sizes.to_vec()));
        nets.push(("efficientnet_reduced", sizes.to_vec()));
    } else {
        nets.push(("efficientnet_reduced", vec![4]));
        nets.push(("alexnet_reduced", vec![4]));
    }

    let mut t = Table::new(
        "Table 5 — AIDG fixed point vs refined roofline, varying systolic sizes",
        &[
            "size", "DNN", "Σ iters", "Σ insts", "eval iters", "runtime",
            "est cycles", "PE", "MAPE", "roofline", "roof PE", "roof MAPE", "meas cycles",
        ],
    );
    let mut csv = Csv::new(
        "table5_systolic_sweep",
        &["size", "dnn", "iters", "insts", "eval_iters", "est", "pe", "mape", "roof", "roof_pe", "roof_mape", "measured"],
    );
    for (name, sizes) in &nets {
        let net = zoo::by_name(name).unwrap();
        for &s in sizes {
            let p = systolic_sweep_point(s, s, &net, false).unwrap();
            t.row(&[
                format!("{s}x{s}"),
                name.to_string(),
                p.total_iters().to_string(),
                p.total_insts().to_string(),
                format!("{} ({:.4}%)", p.evaluated_iters(),
                    100.0 * p.evaluated_iters() as f64 / p.total_iters().max(1) as f64),
                fmt_dur(p.fp_runtime),
                fmt_cycles(p.total_est()),
                format!("{:.2}%", p.pe_est()),
                format!("{:.2}%", p.mape_est()),
                fmt_cycles(p.total_roofline() as u64),
                format!("{:.2}%", p.pe_roofline()),
                format!("{:.2}%", p.mape_roofline()),
                fmt_cycles(p.total_whole()),
            ]);
            csv.row(&[
                s.to_string(), name.to_string(), p.total_iters().to_string(),
                p.total_insts().to_string(), p.evaluated_iters().to_string(),
                p.total_est().to_string(), format!("{:.4}", p.pe_est()),
                format!("{:.4}", p.mape_est()), format!("{:.0}", p.total_roofline()),
                format!("{:.4}", p.pe_roofline()), format!("{:.4}", p.mape_roofline()),
                p.total_whole().to_string(),
            ]);
            println!(
                "  {s}x{s} {name}: est {} vs measured {} (whole-graph {})",
                fmt_cycles(p.total_est()),
                fmt_cycles(p.total_whole()),
                fmt_dur(p.whole_runtime)
            );
        }
    }
    t.emit("table5_systolic_sweep").unwrap();
    csv.finish().unwrap();
    println!("paper best case: 154 evaluated iterations for 4.19e9 instructions (AlexNet, 2×2)");
}
