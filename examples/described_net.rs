//! Described networks: estimate a TC-ResNet8 loaded from a textual network
//! description on a described architecture — the fully file-driven path
//! (no Rust builders anywhere) — and show it is cycle-identical to the
//! zoo builder, sharing the engine's content-addressed estimate cache.
//!
//! ```text
//! cargo run --release --example described_net
//! ```

use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{resolve_network, Arch, DescribedArch};
use acadl_perf::dnn::zoo;
use acadl_perf::engine::EstimationEngine;
use acadl_perf::report::fmt_cycles;
use acadl_perf::Result;

fn main() -> Result<()> {
    let fp = FixedPointConfig::default();
    let engine = EstimationEngine::new(1 << 12);

    // 1. Both sides of the workload described in files: the architecture
    //    from arch/*.toml, the network from net/*.toml. Nothing here is
    //    hardcoded in Rust.
    let arch = Arch::Described(DescribedArch::file("arch/gemmini_16.toml"));
    let described = resolve_network("net:net/tc_resnet8.toml")?;
    let de = engine.estimate_network(&arch, &described, &fp)?;

    // 2. The same workload from the hardcoded zoo builder — through the
    //    same engine, so identical kernels hit the cache the described run
    //    just filled.
    let hand = zoo::tc_resnet8();
    let he = engine.estimate_network(&arch, &hand, &fp)?;

    println!("TC-ResNet8 on {}:", de.arch);
    println!(
        "  described  (net/tc_resnet8.toml): {:>14} cycles  ({} kernels evaluated)",
        fmt_cycles(de.total_cycles()),
        de.stats.evaluated,
    );
    println!(
        "  zoo builder (dnn::zoo)          : {:>14} cycles  ({} kernels evaluated, {} cache hits)",
        fmt_cycles(he.total_cycles()),
        he.stats.evaluated,
        he.stats.cache_hits,
    );
    assert_eq!(de.total_cycles(), he.total_cycles(), "estimates must be cycle-identical");
    assert_eq!(he.stats.evaluated, 0, "the zoo run must be served entirely from cache");
    println!("  => cycle-identical, and the described run pre-warmed the cache");
    Ok(())
}
