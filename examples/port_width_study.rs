//! Memory port-width case study (paper §7.3, Fig. 13): a 12×12 systolic
//! array with varying data-memory port width, mapping
//!
//! - (a) a *divisible* convolution (C=12, K=72): all rows/columns utilized;
//! - (b) a *non-divisible* convolution (C=20, K=70): the mapper unrolls
//!   10×10, leaving two idle rows and columns.
//!
//! The AIDG fixed-point evaluation captures the stepwise latency plateaus
//! (7..11 port widths need the same two transactions for 12 weights) and
//! the sub-optimal-mapping behavior the refined roofline misses.
//!
//! ```text
//! cargo run --release --example port_width_study
//! ```

use std::sync::Arc;

use acadl_perf::accel::{Systolic, SystolicConfig};
use acadl_perf::aidg::{estimate_layer, FixedPointConfig};
use acadl_perf::baselines::roofline::{roofline_cycles, LayerFeatures};
use acadl_perf::dnn::{Layer, LayerKind};
use acadl_perf::mapping::{scalar::ScalarMapper, Mapper};
use acadl_perf::report::{Csv, Table};
use acadl_perf::Result;

fn conv(c: u32, k: u32) -> Layer {
    // short spatial extent + wide filter: the weight-column loads (whose
    // transaction count is ⌈rows/port_width⌉) are a visible fraction of the
    // layer, as in the paper's case study
    Layer::new(
        format!("conv_c{c}_k{k}"),
        LayerKind::Conv1d { c_in: c, l_in: 12, c_out: k, kernel: 9, stride: 1, pad: true },
    )
}

fn main() -> Result<()> {
    let mut csv = Csv::new("fig13_port_width", &["case", "port_width", "aidg", "roofline"]);
    for (case, layer) in [("divisible", conv(12, 72)), ("non-divisible", conv(20, 70))] {
        let mut t = Table::new(
            format!("Fig. 13{} — 12×12 systolic array, {case} conv",
                if case == "divisible" { "(a)" } else { "(b)" }),
            &["port width", "AIDG cycles", "roofline cycles"],
        );
        for pw in 1..=13u32 {
            let sys = Arc::new(Systolic::new(SystolicConfig::new(12, 12).with_port_width(pw))?);
            let mapper = ScalarMapper::new(sys);
            let ml = mapper.map_layer(&layer)?;
            let mut aidg = 0u64;
            for kern in &ml.kernels {
                aidg += estimate_layer(mapper.diagram(), kern, &FixedPointConfig::default())?
                    .cycles;
            }
            let roof =
                roofline_cycles(&LayerFeatures::from_mapping(&layer, &ml), &mapper.hw_features());
            t.row(&[pw.to_string(), aidg.to_string(), format!("{roof:.0}")]);
            csv.row(&[case.into(), pw.to_string(), aidg.to_string(), format!("{roof:.0}")]);
        }
        println!("{}", t.to_markdown());
    }
    let path = csv.finish()?;
    println!("series written to {}", path.display());
    Ok(())
}
