//! Quickstart: model an accelerator, map a DNN onto it, and estimate its
//! end-to-end latency — the library's core loop in ~40 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use acadl_perf::accel::{Systolic, SystolicConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::estimate_network;
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::scalar::ScalarMapper;
use acadl_perf::report::{fmt_cycles, Table};
use acadl_perf::Result;

fn main() -> Result<()> {
    // 1. Model a 4×4 systolic array as an ACADL object diagram (paper Fig. 4).
    let sys = Arc::new(Systolic::new(SystolicConfig::new(4, 4))?);

    // 2. Map TC-ResNet8 onto it: weight-stationary scalar loop kernels.
    let mapper = ScalarMapper::new(sys);
    let net = zoo::tc_resnet8();

    // 3. Estimate every layer with the AIDG fixed-point evaluation (§6.3):
    //    only a handful of loop-kernel iterations are analyzed per layer.
    let est = estimate_network(&mapper, &net, &FixedPointConfig::default())?;

    let mut t = Table::new(
        format!("{} on {} — AIDG fixed-point estimate", est.network, est.arch),
        &["layer", "cycles", "evaluated iters", "total iters"],
    );
    for l in &est.layers {
        t.row(&[
            l.layer_name.clone(),
            if l.estimate.is_some() { fmt_cycles(l.cycles()) } else { "fused".into() },
            l.evaluated_iters().to_string(),
            l.total_iters().to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "end-to-end: {} cycles — evaluated {} of {} iterations ({:.4}%) in {:.1} ms",
        fmt_cycles(est.total_cycles()),
        est.evaluated_iters(),
        est.total_iters(),
        100.0 * est.evaluated_iters() as f64 / est.total_iters() as f64,
        est.runtime.as_secs_f64() * 1e3,
    );
    Ok(())
}
