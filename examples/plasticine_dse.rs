//! Plasticine-derived design-space exploration (paper §7.4, Fig. 15).
//!
//! Sweeps grid rows × cols × PCU GEMM tile size for TC-ResNet8, pre-filters
//! with the AOT-compiled XLA roofline estimator (falling back to the native
//! mirror when `make artifacts` hasn't run), and ranks survivors with the
//! accurate AIDG pass on the worker pool.
//!
//! ```text
//! cargo run --release --example plasticine_dse
//! ```

use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{explore, DseSpec, Pool, RooflineBackend};
use acadl_perf::report::{fmt_cycles, Table};
use acadl_perf::Result;

fn main() -> Result<()> {
    let spec = DseSpec {
        rows: vec![2, 3, 4],
        cols: vec![2, 4, 6],
        tiles: vec![4, 8, 16],
        network: "tc_resnet8".into(),
        keep_frac: 0.5,
        fp: FixedPointConfig::default(),
    };
    let backend = RooflineBackend::auto();
    println!(
        "roofline pre-filter backend: {}",
        match &backend {
            RooflineBackend::Xla(_) => "XLA (AOT artifact)",
            RooflineBackend::Native => "native mirror (run `make artifacts` for XLA)",
        }
    );
    let pool = Pool::new(0);
    let t0 = std::time::Instant::now();
    let points = explore(&spec, &pool, &backend)?;
    let mut t = Table::new(
        format!(
            "Fig. 15 DSE — {} over {} design points ({:.1} s)",
            spec.network,
            points.len(),
            t0.elapsed().as_secs_f64()
        ),
        &["rows", "cols", "tile", "roofline cycles", "AIDG cycles"],
    );
    for p in &points {
        t.row(&[
            p.rows.to_string(),
            p.cols.to_string(),
            p.tile.to_string(),
            fmt_cycles(p.roofline_cycles as u64),
            p.aidg_cycles.map(fmt_cycles).unwrap_or_else(|| "filtered out".into()),
        ]);
    }
    println!("{}", t.to_markdown());
    if let Some(best) = points.first() {
        println!(
            "best design: {}x{} grid, tile {} — {} cycles",
            best.rows,
            best.cols,
            best.tile,
            best.aidg_cycles.map(fmt_cycles).unwrap_or_default()
        );
    }
    Ok(())
}
