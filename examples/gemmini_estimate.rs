//! Gemmini tiled-GEMM estimation (paper §7.2, Tables 2–4): decoupled
//! access-execute modeling with the linear DRAM burst latency model.
//!
//! Estimates TC-ResNet8 and the reduced EfficientNet on a 16×16 Gemmini,
//! with the DES cross-check on TC-ResNet8 and the Timeloop-like +
//! refined-roofline baselines (including the simplex bandwidth fit the
//! paper performed against Verilator measurements).
//!
//! ```text
//! cargo run --release --example gemmini_estimate
//! ```

use std::sync::Arc;

use acadl_perf::accel::{Gemmini, GemminiConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::baselines::{fit_bandwidths, roofline_network};
use acadl_perf::coordinator::estimate_network;
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::{gemm_tile::GemmTileMapper, Mapper};
use acadl_perf::metrics::{mape, percentage_error};
use acadl_perf::report::{fmt_cycles, Table};
use acadl_perf::{sim, Result};

fn main() -> Result<()> {
    let g = Arc::new(Gemmini::new(GemminiConfig::default())?);
    let mapper = GemmTileMapper::new(Arc::clone(&g));

    // ---- TC-ResNet8: full estimator comparison -----------------------------
    let net = zoo::tc_resnet8();
    let est = estimate_network(&mapper, &net, &FixedPointConfig::default())?;
    let mapped = mapper.map_network(&net)?;

    let mut des_layers = Vec::new();
    for ml in &mapped {
        if ml.fused {
            des_layers.push(0.0);
        } else {
            des_layers.push(sim::simulate_layer(mapper.diagram(), &ml.kernels)?.cycles as f64);
        }
    }
    let des_total: f64 = des_layers.iter().sum();

    // Timeloop-like model with simplex-fitted bandwidths (paper §7.2)
    let tl = fit_bandwidths(g.cfg.dim, &net.layers, &des_layers)?;
    let tl_layers = tl.network_cycles(&net.layers);
    let roof = roofline_network(&net.layers, &mapped, &mapper.hw_features());

    let mut t = Table::new(
        "Table 2 — TC-ResNet8 on 16×16 Gemmini",
        &["estimator", "estimated cycles", "PE", "MAPE"],
    );
    let rows: [(&str, &[f64]); 3] = [
        ("AIDG fixed point", &est.layer_cycles()),
        ("Refined roofline [28]", &roof),
        ("Timeloop-like [21] (simplex-fit)", &tl_layers),
    ];
    for (name, layers) in rows {
        let total: f64 = layers.iter().sum();
        t.row(&[
            name.into(),
            fmt_cycles(total as u64),
            format!("{:.2}%", percentage_error(total, des_total)),
            format!("{:.2}%", mape(&des_layers, layers)),
        ]);
    }
    t.row(&[
        "DES (RTL stand-in)".into(),
        fmt_cycles(des_total as u64),
        "ground truth".into(),
        "".into(),
    ]);
    println!("{}", t.to_markdown());

    // ---- EfficientNet (reduced): estimate-only ------------------------------
    let eff = zoo::efficientnet_reduced();
    let e2 = estimate_network(&mapper, &eff, &FixedPointConfig::default())?;
    println!(
        "{}: {} cycles | {} of {} iterations evaluated | {:.1} ms",
        e2.network,
        fmt_cycles(e2.total_cycles()),
        e2.evaluated_iters(),
        e2.total_iters(),
        e2.runtime.as_secs_f64() * 1e3
    );
    Ok(())
}
