//! Described architectures: estimate TC-ResNet8 on a systolic array loaded
//! from a textual ACADL description, and show it is cycle-identical to the
//! hand-built builder.
//!
//! ```text
//! cargo run --release --example described_arch
//! ```

use acadl_perf::accel::SystolicConfig;
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{estimate_network, Arch, DescribedArch};
use acadl_perf::dnn::zoo;
use acadl_perf::report::fmt_cycles;
use acadl_perf::Result;

fn main() -> Result<()> {
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();

    // 1. The textual description: parsed, validated, compiled to an ACADL
    //    object diagram, and bound to the scalar mapper family. Compiled
    //    models are cached by content, so re-running a request against an
    //    unchanged file never recompiles it.
    let described = Arch::Described(DescribedArch::file("arch/systolic_16x16.toml"));
    let dm = described.mapper()?;
    let de = estimate_network(dm.as_ref(), &net, &fp)?;

    // 2. The same architecture from the hardcoded Rust builder.
    let hand = Arch::Systolic(SystolicConfig::new(16, 16));
    let hm = hand.mapper()?;
    let he = estimate_network(hm.as_ref(), &net, &fp)?;

    println!("TC-ResNet8 on {}:", de.arch);
    println!(
        "  described  (arch/systolic_16x16.toml): {:>14} cycles  ({} of {} iterations evaluated)",
        fmt_cycles(de.total_cycles()),
        de.evaluated_iters(),
        de.total_iters(),
    );
    println!(
        "  hand-built (accel::Systolic)         : {:>14} cycles  ({} of {} iterations evaluated)",
        fmt_cycles(he.total_cycles()),
        he.evaluated_iters(),
        he.total_iters(),
    );
    assert_eq!(de.total_cycles(), he.total_cycles(), "estimates must be cycle-identical");
    println!("  => cycle-identical");
    Ok(())
}
