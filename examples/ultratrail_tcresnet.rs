//! UltraTrail × TC-ResNet8 (paper §7.1, Table 1): fused-tensor modeling.
//!
//! The whole accelerator compute path is a single FunctionalUnit whose
//! latency is the CONV-EXT analytical model evaluated per instruction —
//! the coarsest abstraction level ACADL supports. The AIDG estimate is
//! compared against the cycle-accurate DES (the repo's RTL stand-in) and
//! the refined roofline baseline.
//!
//! ```text
//! cargo run --release --example ultratrail_tcresnet
//! ```

use std::sync::Arc;

use acadl_perf::accel::{UltraTrail, UltraTrailConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::baselines::{roofline_network, BOUZIDI_SVR_MAPE};
use acadl_perf::coordinator::estimate_network;
use acadl_perf::dnn::zoo;
use acadl_perf::mapping::{tensor_op::TensorOpMapper, Mapper};
use acadl_perf::metrics::{mape, percentage_error};
use acadl_perf::report::{fmt_cycles, Table};
use acadl_perf::{sim, Result};

fn main() -> Result<()> {
    let ut = Arc::new(UltraTrail::new(UltraTrailConfig::default())?);
    let mapper = TensorOpMapper::new(ut);
    let net = zoo::tc_resnet8();

    // AIDG estimate
    let t0 = std::time::Instant::now();
    let est = estimate_network(&mapper, &net, &FixedPointConfig::default())?;
    let aidg_rt = t0.elapsed();

    // DES ground truth (the mapper is stateful: remap for a fresh stream)
    let mapper2 = TensorOpMapper::new(Arc::new(UltraTrail::new(UltraTrailConfig::default())?));
    let mapped = mapper2.map_network(&net)?;
    let t1 = std::time::Instant::now();
    let mut des_layers = Vec::new();
    let mut des_total = 0u64;
    for ml in &mapped {
        if ml.fused {
            des_layers.push(0.0);
            continue;
        }
        let r = sim::simulate_layer(mapper2.diagram(), &ml.kernels)?;
        des_total += r.cycles;
        des_layers.push(r.cycles as f64);
    }
    let des_rt = t1.elapsed();

    // refined roofline
    let roof = roofline_network(&net.layers, &mapped, &mapper2.hw_features());

    let aidg_layers = est.layer_cycles();
    let mut t = Table::new(
        "Table 1 — latency estimators, TC-ResNet8 on UltraTrail",
        &["estimator", "runtime", "estimated cycles", "PE", "MAPE"],
    );
    t.row(&[
        "AIDG".into(),
        format!("{:.1} ms", aidg_rt.as_secs_f64() * 1e3),
        fmt_cycles(est.total_cycles()),
        format!("{:.3}%", percentage_error(est.total_cycles() as f64, des_total as f64)),
        format!("{:.4}%", mape(&des_layers, &aidg_layers)),
    ]);
    t.row(&[
        "Regression model [5]".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{BOUZIDI_SVR_MAPE}%"),
    ]);
    t.row(&[
        "Refined roofline [28]".into(),
        "< 1 ms".into(),
        fmt_cycles(roof.iter().sum::<f64>() as u64),
        format!("{:.2}%", percentage_error(roof.iter().sum(), des_total as f64)),
        format!("{:.2}%", mape(&des_layers, &roof)),
    ]);
    t.row(&[
        "DES (RTL stand-in)".into(),
        format!("{:.2} ms", des_rt.as_secs_f64() * 1e3),
        fmt_cycles(des_total),
        "ground truth".into(),
        "".into(),
    ]);
    println!("{}", t.to_markdown());
    println!("paper: AIDG 22 484 vs Xcelium 22 481 (+3 cycles from instruction fetch)");
    Ok(())
}
