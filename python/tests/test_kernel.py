"""Kernel-vs-reference correctness: the core L1 signal.

The Pallas kernels run under interpret=True (CPU); references are pure jnp.
Hypothesis sweeps shapes/values; fixed cases pin the paper-relevant regimes
(compute-bound, memory-bound, unroll underutilization, huge f64 counts).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import features as F
from compile.kernels.gemm import gemm
from compile.kernels.ref import gemm_ref, roofline_ref
from compile.kernels.roofline import roofline_batch


def mk_hw(rows=4, cols=4, pw=2, rl=4, wl=4, mac=1, fetch=0):
    return jnp.array([rows, cols, pw, rl, wl, mac, fetch, 0.0], dtype=jnp.float64)


def mk_layer(macs, in_w, w_w, out_w, ur_c, ur_k, k_iters=1):
    v = np.zeros(F.LF)
    v[F.L_MACS] = macs
    v[F.L_IN_WORDS] = in_w
    v[F.L_W_WORDS] = w_w
    v[F.L_OUT_WORDS] = out_w
    v[F.L_UR_C] = ur_c
    v[F.L_UR_K] = ur_k
    v[F.L_K_ITERS] = k_iters
    return v


class TestRooflineFixed:
    def _run(self, layers_np, hw):
        layers = jnp.asarray(layers_np, dtype=jnp.float64)
        got = roofline_batch(layers, hw, block=layers.shape[0])
        want = roofline_ref(layers, hw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        return np.asarray(got)

    def test_compute_bound(self):
        # many MACs, little data -> compute term dominates
        layers = np.stack([mk_layer(1e6, 10, 10, 10, 2, 2)])
        hw = mk_hw(pw=8, rl=1, wl=1)
        out = self._run(layers, hw)
        assert out[0] >= 1e6 / 4

    def test_memory_bound(self):
        # little compute, lots of data, narrow port -> memory term dominates
        layers = np.stack([mk_layer(10, 1e6, 1e6, 1e6, 4, 4)])
        hw = mk_hw(pw=1, rl=4, wl=4)
        out = self._run(layers, hw)
        assert out[0] >= 2e6 * 4

    def test_underutilization_increases_cycles(self):
        # ur 2x2 vs 4x4 on the same layer: fewer active PEs -> more cycles
        full = np.stack([mk_layer(1e6, 10, 10, 10, 4, 4)])
        under = np.stack([mk_layer(1e6, 10, 10, 10, 2, 2)])
        hw = mk_hw()
        assert self._run(under, hw)[0] > self._run(full, hw)[0]

    def test_huge_counts_exact_f64(self):
        # 4.19e9 instructions regime: f64 must represent counts exactly
        layers = np.stack([mk_layer(4.19e9, 1e9, 1e9, 1e9, 1, 1)])
        out = self._run(layers, mk_hw(pw=1, rl=1, wl=1))
        assert out[0] == float(int(out[0]))  # integral

    def test_zero_unroll_clamped(self):
        layers = np.stack([mk_layer(100, 10, 10, 10, 0, 0)])
        self._run(layers, mk_hw())

    def test_multi_block_grid(self):
        # batch spanning several grid blocks agrees with single-block ref
        rng = np.random.default_rng(0)
        layers = rng.integers(1, 10**6, size=(F.ROOFLINE_BATCH, F.LF)).astype(float)
        hw = mk_hw()
        got = roofline_batch(jnp.asarray(layers), hw)
        want = roofline_ref(jnp.asarray(layers), hw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=50, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    macs=st.integers(0, 10**12),
    words=st.integers(0, 10**9),
    ur=st.integers(0, 64),
    pw=st.integers(1, 16),
    lat=st.integers(1, 16),
)
def test_roofline_property(b, macs, words, ur, pw, lat):
    layers = np.tile(mk_layer(macs, words, words // 2, words // 3, ur, ur, 7), (b, 1))
    hw = mk_hw(pw=pw, rl=lat, wl=lat, mac=1, fetch=1)
    got = roofline_batch(jnp.asarray(layers, dtype=jnp.float64), hw, block=b)
    want = roofline_ref(jnp.asarray(layers, dtype=jnp.float64), hw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # cycles are nonnegative and monotone in macs
    assert (np.asarray(got) >= 0).all()


class TestGemmFixed:
    def test_aot_shape(self):
        rng = np.random.default_rng(1)
        a = rng.standard_normal((F.GEMM_M, F.GEMM_K)).astype(np.float32)
        b = rng.standard_normal((F.GEMM_K, F.GEMM_N)).astype(np.float32)
        got = gemm(jnp.asarray(a), jnp.asarray(b))
        want = gemm_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)

    def test_identity(self):
        eye = jnp.eye(F.GEMM_BM, dtype=jnp.float32)
        a = jnp.arange(F.GEMM_BM * F.GEMM_BM, dtype=jnp.float32).reshape(F.GEMM_BM, -1)
        got = gemm(a, eye, bm=F.GEMM_BM, bn=F.GEMM_BM, bk=F.GEMM_BM)
        np.testing.assert_allclose(np.asarray(got), np.asarray(a), rtol=0, atol=0)

    def test_bad_shapes_rejected(self):
        a = jnp.zeros((100, 128), dtype=jnp.float32)
        b = jnp.zeros((128, 128), dtype=jnp.float32)
        with pytest.raises(AssertionError):
            gemm(a, b)


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    ki=st.integers(1, 3),
    tile=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_property(mi, ni, ki, tile, seed):
    m, n, k = mi * tile, ni * tile, ki * tile
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = gemm(jnp.asarray(a), jnp.asarray(b), bm=tile, bn=tile, bk=tile)
    want = gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)
