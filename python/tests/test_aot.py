"""AOT pipeline smoke tests: both entry points lower to parseable HLO text
with the module signatures the Rust runtime expects."""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot, features as F, model


def test_entry_points_lower():
    for stem, (fn, args_fn) in aot.ENTRY_POINTS.items():
        lowered = jax.jit(fn).lower(*args_fn())
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, stem
        assert "ROOT" in text, stem


def test_build_writes_artifacts():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d)
        for stem in aot.ENTRY_POINTS:
            path = os.path.join(d, f"{stem}.hlo.txt")
            assert os.path.exists(path)
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head


def test_roofline_artifact_shapes():
    lowered = jax.jit(model.batched_roofline).lower(*model.roofline_example_args())
    text = aot.to_hlo_text(lowered)
    # batch and feature dims must appear in the entry signature
    assert f"f64[{F.ROOFLINE_BATCH},{F.LF}]" in text
    assert f"f64[{F.HF}]" in text


def test_gemm_artifact_shapes():
    lowered = jax.jit(model.model_gemm).lower(*model.gemm_example_args())
    text = aot.to_hlo_text(lowered)
    assert f"f32[{F.GEMM_M},{F.GEMM_K}]" in text
