"""L2: the JAX compute graph lowered to the AOT artifacts.

Two entry points, both calling the L1 Pallas kernels:

- ``batched_roofline(layers[B,LF], hw[HF]) -> (cycles[B],)``
  The refined-roofline estimator evaluated for a whole batch of design
  points in one call. The Rust coordinator uses it (a) for every
  "Refined roofline" baseline column and (b) as the cheap pre-filter in
  Plasticine design-space exploration, padding requests to ROOFLINE_BATCH.

- ``model_gemm(a[M,K], b[K,N]) -> (c[M,N],)``
  The weight-stationary tiled GEMM functional model used to validate the
  im2col mapping path's numerics end-to-end from Rust.

Both return 1-tuples: the AOT pipeline lowers with ``return_tuple=True`` and
the Rust side unwraps with ``to_tuple1()`` (see /opt/xla-example).
"""

import jax
import jax.numpy as jnp

from . import features as F
from .kernels import gemm as gemm_kernel
from .kernels import roofline as roofline_kernel

# f64 keeps cycle counts exact up to 2^53; must be enabled before tracing.
jax.config.update("jax_enable_x64", True)


def batched_roofline(layers: jnp.ndarray, hw: jnp.ndarray):
    cycles = roofline_kernel.roofline_batch(layers, hw)
    return (cycles,)


def model_gemm(a: jnp.ndarray, b: jnp.ndarray):
    return (gemm_kernel.gemm(a, b),)


def roofline_example_args():
    layers = jax.ShapeDtypeStruct((F.ROOFLINE_BATCH, F.LF), jnp.float64)
    hw = jax.ShapeDtypeStruct((F.HF,), jnp.float64)
    return layers, hw


def gemm_example_args():
    a = jax.ShapeDtypeStruct((F.GEMM_M, F.GEMM_K), jnp.float32)
    b = jax.ShapeDtypeStruct((F.GEMM_K, F.GEMM_N), jnp.float32)
    return a, b
