"""L1 Pallas kernel: batched refined-roofline latency estimation.

One grid step processes a ROOFLINE_BLOCK-sized slab of design points that is
fully VMEM-resident (BLOCK x LF f64 = 8 KiB per operand slab); the hardware
feature vector is broadcast to every block. The kernel is element-wise over
the batch, so on a real TPU it is VPU work with a trivially double-buffered
HBM->VMEM stream; interpret=True is mandatory here (CPU PJRT cannot execute
Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import features as F


def _roofline_kernel(layers_ref, hw_ref, out_ref):
    layers = layers_ref[...]
    hw = hw_ref[...]

    macs = layers[:, F.L_MACS]
    in_w = layers[:, F.L_IN_WORDS]
    w_w = layers[:, F.L_W_WORDS]
    out_w = layers[:, F.L_OUT_WORDS]
    ur_c = jnp.maximum(layers[:, F.L_UR_C], 1.0)
    ur_k = jnp.maximum(layers[:, F.L_UR_K], 1.0)
    k_iters = jnp.maximum(layers[:, F.L_K_ITERS], 1.0)

    pw = jnp.maximum(hw[F.H_PORT_WIDTH], 1.0)
    read_lat = hw[F.H_READ_LAT]
    write_lat = hw[F.H_WRITE_LAT]
    mac_lat = jnp.maximum(hw[F.H_MAC_LAT], 1.0)
    fetch = hw[F.H_FETCH_OVERHEAD]

    compute = jnp.ceil(macs / (ur_c * ur_k)) * mac_lat
    reads = (jnp.ceil(in_w / pw) + jnp.ceil(w_w / pw)) * read_lat
    writes = jnp.ceil(out_w / pw) * write_lat
    mem = reads + writes
    prolog = read_lat + mac_lat + write_lat + fetch * k_iters
    out_ref[...] = jnp.maximum(compute, mem) + prolog


@functools.partial(jax.jit, static_argnames=("block",))
def roofline_batch(layers: jnp.ndarray, hw: jnp.ndarray, *, block: int = F.ROOFLINE_BLOCK) -> jnp.ndarray:
    """Pallas-blocked refined roofline over a padded batch.

    layers: [B, LF] f64 with B % block == 0; hw: [HF] f64 -> cycles [B] f64.
    """
    b, lf = layers.shape
    assert lf == F.LF, f"layer feature width {lf} != {F.LF}"
    assert b % block == 0, f"batch {b} not a multiple of block {block}"
    grid = (b // block,)
    return pl.pallas_call(
        _roofline_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, F.LF), lambda i: (i, 0)),
            pl.BlockSpec((F.HF,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), layers.dtype),
        interpret=True,
    )(layers, hw)
