"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
(interpret=True) match these references exactly (roofline, integer-valued
f64 arithmetic) or to float tolerance (GEMM).
"""

import jax.numpy as jnp

from .. import features as F


def roofline_ref(layers: jnp.ndarray, hw: jnp.ndarray) -> jnp.ndarray:
    """Refined roofline cycles for a batch of design points.

    layers: [B, LF] f64, hw: [HF] f64 -> [B] f64.

    The *refined* roofline (after Wess et al. [28]) replaces peak compute by
    the compute rate achievable with the layer's actual unroll factors
    (UR_C x UR_K PEs active out of ROWS x COLS) and models memory as
    transaction-granular (ceil(words / port_width) * latency). Compute and
    memory streams overlap (max), the pipeline fill does not (additive).
    """
    macs = layers[:, F.L_MACS]
    in_w = layers[:, F.L_IN_WORDS]
    w_w = layers[:, F.L_W_WORDS]
    out_w = layers[:, F.L_OUT_WORDS]
    ur_c = jnp.maximum(layers[:, F.L_UR_C], 1.0)
    ur_k = jnp.maximum(layers[:, F.L_UR_K], 1.0)
    k_iters = jnp.maximum(layers[:, F.L_K_ITERS], 1.0)

    pw = jnp.maximum(hw[F.H_PORT_WIDTH], 1.0)
    read_lat = hw[F.H_READ_LAT]
    write_lat = hw[F.H_WRITE_LAT]
    mac_lat = jnp.maximum(hw[F.H_MAC_LAT], 1.0)
    fetch = hw[F.H_FETCH_OVERHEAD]

    compute = jnp.ceil(macs / (ur_c * ur_k)) * mac_lat
    reads = (jnp.ceil(in_w / pw) + jnp.ceil(w_w / pw)) * read_lat
    writes = jnp.ceil(out_w / pw) * write_lat
    mem = reads + writes
    # pipeline fill: one read + one mac + one write wave, plus fetch overhead
    prolog = read_lat + mac_lat + write_lat + fetch * k_iters
    return jnp.maximum(compute, mem) + prolog


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle, f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
