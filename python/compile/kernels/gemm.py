"""L1 Pallas kernel: weight-stationary tiled GEMM.

This is the functional model of the systolic-array / Gemmini mapping path:
the im2col-transformed layer is computed as C = A @ B with (BM, BN, BK)
tiling. The BlockSpec schedule expresses the same dataflow the paper's
systolic array realizes spatially — the B (weight) tile is held while A
streams through, with accumulation over the K grid dimension — i.e. the
HBM<->VMEM schedule plays the role of the weight-stationary PE array.

Tile defaults (128x128x128 f32) keep the working set at 3 * 64 KiB, MXU
aligned (multiples of 8x128). interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import features as F


def _gemm_kernel(a_ref, b_ref, o_ref):
    # Grid is (M/BM, N/BN, K/BK) with K innermost: zero the accumulator tile
    # on the first K step, then accumulate partial products.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = F.GEMM_BM,
    bn: int = F.GEMM_BN,
    bk: int = F.GEMM_BK,
) -> jnp.ndarray:
    """Tiled matmul a[M,K] @ b[K,N] -> [M,N], f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tile ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
