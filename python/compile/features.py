"""Feature vector layout shared between the JAX/Pallas estimator and the Rust
coordinator (rust/src/runtime/roofline_exec.rs mirrors these indices).

A *design point* is (layer features, hardware features). The batched refined
roofline estimator consumes `layers[B, LF]` (f64) and `hw[HF]` (f64) and
returns `cycles[B]` (f64). f64 keeps cycle counts exact up to 2^53 (paper
workloads reach 4.19e9 instructions, beyond f32's 2^24 integer range).
"""

# --- layer features -------------------------------------------------------
LF = 8
L_MACS = 0       # total multiply-accumulate operations in the layer
L_IN_WORDS = 1   # input activation words streamed from memory
L_W_WORDS = 2    # weight words streamed from memory
L_OUT_WORDS = 3  # output words written back
L_UR_C = 4       # achieved unroll along input channels (rows occupied)
L_UR_K = 5       # achieved unroll along output channels (cols occupied)
L_K_ITERS = 6    # loop-kernel iterations k of the mapped layer
L_RESERVED = 7

# --- hardware features ----------------------------------------------------
HF = 8
H_ROWS = 0        # PE rows
H_COLS = 1        # PE cols
H_PORT_WIDTH = 2  # words per memory transaction
H_READ_LAT = 3    # cycles per read transaction
H_WRITE_LAT = 4   # cycles per write transaction
H_MAC_LAT = 5     # cycles per (vectorized) MAC wave
H_FETCH_OVERHEAD = 6  # non-overlapped fetch/issue cycles per iteration
H_RESERVED = 7

# Batch block size for the Pallas roofline kernel; AOT batch is a multiple.
ROOFLINE_BLOCK = 128
ROOFLINE_BATCH = 1024  # fixed AOT batch; Rust pads/splits to this

# Tiled GEMM AOT shape (functional check of the im2col mapping path).
GEMM_M = 256
GEMM_N = 256
GEMM_K = 256
GEMM_BM = 128
GEMM_BN = 128
GEMM_BK = 128
