"""AOT pipeline: lower the L2 model entry points to HLO *text* artifacts.

HLO text (not ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md and gen_hlo.py there.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Python runs only here, at build time; the Rust binary is self-contained
once artifacts/ exists (``make artifacts`` is incremental).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ENTRY_POINTS = {
    # artifact stem -> (callable, example args factory)
    "roofline": (model.batched_roofline, model.roofline_example_args),
    "gemm": (model.model_gemm, model.gemm_example_args),
}


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for stem, (fn, args_fn) in ENTRY_POINTS.items():
        lowered = jax.jit(fn).lower(*args_fn())
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{stem}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help="unused compat alias")
    args = parser.parse_args()
    out_dir = args.out_dir
    if args.out:  # legacy single-file interface: treat as directory of file
        out_dir = os.path.dirname(args.out) or "."
    build(out_dir)


if __name__ == "__main__":
    main()
