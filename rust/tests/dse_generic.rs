//! Architecture-generic DSE integration tests (ISSUE 4):
//!
//! - sweep enumeration order is deterministic;
//! - the legacy Plasticine shim grid and the described `[sweep]` grid are
//!   cycle-for-cycle identical;
//! - the roofline pre-filter at `keep_frac = 1.0` never drops the true
//!   best point;
//! - the cache hit-rate counter strictly improves under locality
//!   scheduling vs. the digest-interleaved enumeration order.

use acadl_perf::acadl::text::ast::{Param, Span, Spanned, Sweep, SweepDim, SweepItem};
use acadl_perf::acadl::text::{parse, PExpr};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{self, DseSpec, Pool, RooflineBackend};
use acadl_perf::dse::{
    explore_space, plan_groups, plan_order, Schedule, SweepOptions, SweepOutcome, SweepSpace,
};
use acadl_perf::engine::EstimationEngine;

fn file_space(path: &str) -> SweepSpace {
    let src = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {path}: {e}"));
    SweepSpace::from_source(&src, path, None)
        .unwrap_or_else(|e| panic!("compiling {path} sweep: {e:#}"))
}

#[test]
fn enumeration_order_is_deterministic_and_row_major() {
    let space = file_space("arch/plasticine_3x6.toml");
    let labels = |space: &SweepSpace| -> Vec<String> {
        space.candidates().map(|c| c.unwrap().label()).collect()
    };
    let first = labels(&space);
    assert_eq!(first.len(), 18, "rows(3) x cols(3) x tile(2)");
    // row-major: the last dimension (tile) varies fastest
    assert_eq!(first[0], "rows=2,cols=2,tile=8");
    assert_eq!(first[1], "rows=2,cols=2,tile=16");
    assert_eq!(first[2], "rows=2,cols=4,tile=8");
    assert_eq!(first[6], "rows=3,cols=2,tile=8");
    assert_eq!(first, labels(&space), "re-enumeration must be identical");
    // every shipped architecture description declares a usable space
    for path in
        ["arch/systolic_16x16.toml", "arch/ultratrail_8x8.toml", "arch/gemmini_16.toml"]
    {
        assert!(file_space(path).len_bound() >= 2, "{path} sweep too small");
    }
}

#[test]
fn plasticine_shim_grid_matches_described_sweep_cycle_for_cycle() {
    let spec = DseSpec {
        rows: vec![2, 3],
        cols: vec![2],
        tiles: vec![8, 16],
        network: "tc_resnet8".into(),
        keep_frac: 1.0,
        fp: FixedPointConfig::default(),
    };
    let pool = Pool::new(0);
    let shim = coordinator::explore(&spec, &pool, &RooflineBackend::Native).unwrap();
    assert_eq!(shim.len(), 4);

    let desc = spec.to_sweep_description().unwrap();
    let space = SweepSpace::from_description(desc, "plasticine-shim", None).unwrap();
    let net = coordinator::resolve_network(&spec.network).unwrap();
    let outcome = explore_space(
        &space,
        &net,
        &SweepOptions::default(),
        &pool,
        &RooflineBackend::Native,
        EstimationEngine::global(),
    )
    .unwrap();
    assert_eq!(outcome.points.len(), 4);
    for p in &outcome.points {
        let (r, c, t) = (
            p.assignment[0].1 as u32,
            p.assignment[1].1 as u32,
            p.assignment[2].1 as u32,
        );
        let twin = shim
            .iter()
            .find(|s| s.rows == r && s.cols == c && s.tile == t)
            .unwrap_or_else(|| panic!("no shim point for {}", p.label));
        assert_eq!(
            p.aidg_cycles, twin.aidg_cycles,
            "described {} disagrees with the hand-built grid",
            p.label
        );
        assert_eq!(
            p.roofline_cycles.to_bits(),
            twin.roofline_cycles.to_bits(),
            "roofline of {} disagrees",
            p.label
        );
    }
}

#[test]
fn prefilter_at_keep_one_never_drops_the_true_best() {
    let space = file_space("arch/ultratrail_8x8.toml");
    let net = coordinator::resolve_network("tc_resnet8").unwrap();
    let pool = Pool::new(2);
    let engine = EstimationEngine::new(1 << 12);
    let outcome = explore_space(
        &space,
        &net,
        &SweepOptions { keep_frac: 1.0, ..Default::default() },
        &pool,
        &RooflineBackend::Native,
        &engine,
    )
    .unwrap();
    assert_eq!(outcome.estimated, outcome.enumerated - outcome.skipped);
    assert!(outcome.points.iter().all(|p| p.aidg_cycles.is_some()));
    // brute force: estimate every candidate independently; the explorer's
    // best must be the global best
    let fp = FixedPointConfig::default();
    let brute_best = space
        .candidates()
        .map(|c| {
            let arch = space.candidate_arch(&c.unwrap());
            engine.estimate_network(&arch, &net, &fp).unwrap().total_cycles()
        })
        .min()
        .unwrap();
    assert_eq!(outcome.points[0].aidg_cycles, Some(brute_best));
    // the cycle-best point is on the Pareto frontier by construction
    assert!(outcome.points[0].on_frontier);

    // and a 0.5 pre-filter estimates only the roofline-best half
    let engine2 = EstimationEngine::new(1 << 12);
    let half = explore_space(
        &space,
        &net,
        &SweepOptions { keep_frac: 0.5, ..Default::default() },
        &pool,
        &RooflineBackend::Native,
        &engine2,
    )
    .unwrap();
    let estimated = half.points.iter().filter(|p| p.aidg_cycles.is_some()).count() as u64;
    assert_eq!(estimated, half.estimated);
    assert!(estimated < half.enumerated - half.skipped);
    let worst_kept = half
        .points
        .iter()
        .filter(|p| p.aidg_cycles.is_some())
        .map(|p| p.roofline_cycles)
        .fold(f64::MIN, f64::max);
    let best_dropped = half
        .points
        .iter()
        .filter(|p| p.aidg_cycles.is_none())
        .map(|p| p.roofline_cycles)
        .fold(f64::MAX, f64::min);
    assert!(worst_kept <= best_dropped, "pre-filter must keep the roofline-best points");
}

/// A small scalar-family space with one structural dimension (`cols`) and
/// one structure-neutral dimension (`rev` — declared but referenced by no
/// template), so same-`cols` candidates share their architecture digest
/// and their `KernelKey`s.
fn dup_structure_space() -> SweepSpace {
    let src = std::fs::read_to_string("arch/systolic_16x16.toml").unwrap();
    let mut desc = parse(&src).unwrap();
    for p in &mut desc.params {
        if p.name.node == "rows" {
            p.value = Spanned::bare(2);
        }
    }
    desc.params
        .push(Param { name: Spanned::bare("rev".into()), value: Spanned::bare(0) });
    let dim = |name: &str, items: Vec<SweepItem>| SweepDim {
        name: Spanned::bare(name.to_string()),
        items,
        span: Span::default(),
    };
    let range = SweepItem::Range { lo: PExpr::Const(0), hi: PExpr::Const(3), step: None };
    desc.sweep = Some(Sweep {
        dims: vec![
            // rev varies slowest, so plain enumeration interleaves digests
            dim("rev", vec![range]),
            dim(
                "cols",
                vec![
                    SweepItem::Scalar(PExpr::Const(2)),
                    SweepItem::Scalar(PExpr::Const(3)),
                    SweepItem::Scalar(PExpr::Const(4)),
                ],
            ),
        ],
        when: None,
        cap: None,
        span: Span::default(),
    });
    SweepSpace::from_description(desc, "dup-structure", None).unwrap()
}

fn run_scheduled(space: &SweepSpace, schedule: Schedule, cache_cap: usize) -> SweepOutcome {
    let net = coordinator::resolve_network("tc_resnet8").unwrap();
    let pool = Pool::new(2);
    let engine = EstimationEngine::new(cache_cap);
    explore_space(
        space,
        &net,
        // serial dispatch isolates the cache-locality effect under test:
        // the batched path estimates a whole digest group in one engine
        // call (its own cache accounting is pinned by
        // rust/tests/batch_differential.rs)
        &SweepOptions { schedule, batch: false, ..Default::default() },
        &pool,
        &RooflineBackend::Native,
        &engine,
    )
    .unwrap()
}

#[test]
fn locality_scheduling_strictly_improves_cache_hit_rate() {
    let space = dup_structure_space();
    assert_eq!(space.len_bound(), 9, "rev(3) x cols(3)");

    // probe one candidate's unique-kernel count to size the cache so it
    // holds roughly one architecture's working set but not two
    let net = coordinator::resolve_network("tc_resnet8").unwrap();
    let probe_engine = EstimationEngine::new(1 << 12);
    let probe_cand = space.candidates().next().unwrap().unwrap();
    let probe = probe_engine
        .estimate_network(
            &space.candidate_arch(&probe_cand),
            &net,
            &FixedPointConfig::default(),
        )
        .unwrap();
    // one working set: the shard-granular LRU then comfortably holds one
    // architecture's kernels but nowhere near three architectures' worth
    let u = probe.stats.unique_kernels as usize;
    assert!(u >= 8, "cache-pressure sizing assumes a non-trivial working set (u={u})");
    let cap = u;

    // `rev` varies slowest, so plain enumeration visits the digests as
    // A,B,C,A,B,C,A,B,C — no two same-digest candidates are ever adjacent.
    // (Schedule::Shuffled can no longer serve as the interleaved baseline:
    // it now permutes digest *groups*, keeping members adjacent.) The
    // pattern below pins that interleaving shape statically.
    let pattern = [1u64, 2, 3, 1, 2, 3, 1, 2, 3];
    assert_eq!(plan_order(&pattern, Schedule::Enumerated), (0..9).collect::<Vec<_>>());
    assert_eq!(plan_groups(&pattern, Schedule::Enumerated).len(), 9, "all-singleton runs");
    assert_eq!(plan_groups(&pattern, Schedule::Locality).len(), 3);

    let local = run_scheduled(&space, Schedule::Locality, cap);
    let interleaved = run_scheduled(&space, Schedule::Enumerated, cap);
    assert_eq!(local.estimated, 9);
    assert_eq!(interleaved.estimated, 9);
    // same-digest candidates share every KernelKey, so locality keeps the
    // LRU warm across them; the interleaved order thrashes it
    assert!(local.stats.cache_hits > 0, "{:?}", local.stats);
    assert!(
        local.stats.cache_hits > interleaved.stats.cache_hits,
        "locality {:?} must strictly beat interleaved {:?}",
        local.stats,
        interleaved.stats
    );
    // scheduling never changes results, only wall time and cache traffic
    let cycles = |o: &SweepOutcome| -> Vec<(String, Option<u64>)> {
        let mut v: Vec<_> =
            o.points.iter().map(|p| (p.label.clone(), p.aidg_cycles)).collect();
        v.sort();
        v
    };
    assert_eq!(cycles(&local), cycles(&interleaved));
}
