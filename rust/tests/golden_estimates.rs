//! Golden snapshot: exact per-layer cycle counts for every shipped
//! architecture description × every shipped network description, through
//! the uncached reference path. Any change to the estimator, the mappers,
//! the latency semantics, or the description frontends that moves a single
//! cycle shows up as a diff against the checked-in fixture.
//!
//! Blessing a new baseline: run with `GOLDEN_UPDATE=1` (or check in a
//! fixture containing the `UNINITIALIZED` sentinel) and the test rewrites
//! `rust/tests/golden/estimates.txt` from the current build, then commit
//! the diff alongside the change that explains it.

use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{estimate_network, resolve_network, Arch, DescribedArch};

const ARCHS: [&str; 4] = [
    "arch/gemmini_16.toml",
    "arch/plasticine_3x6.toml",
    "arch/systolic_16x16.toml",
    "arch/ultratrail_8x8.toml",
];

const NETS: [&str; 5] = [
    "net/alexnet.toml",
    "net/alexnet_reduced.toml",
    "net/efficientnet.toml",
    "net/efficientnet_reduced.toml",
    "net/tc_resnet8.toml",
];

/// Render the full golden text: one `arch × net` block per combination, in
/// the fixed order above, with per-layer and total cycles. Combinations a
/// mapper rejects (e.g. 2-D networks on a 1-D accelerator) are recorded as
/// `unmappable` so a *new* rejection is as loud as a cycle change.
fn render() -> String {
    let fp = FixedPointConfig::default();
    let mut out = String::from(
        "# Golden per-layer cycle estimates (uncached reference path).\n\
         # Regenerate with: GOLDEN_UPDATE=1 cargo test --test golden_estimates\n",
    );
    for arch_file in ARCHS {
        let mapper = Arch::Described(DescribedArch::file(arch_file))
            .mapper()
            .unwrap_or_else(|e| panic!("{arch_file}: {e:#}"));
        for net_file in NETS {
            let net = resolve_network(&format!("net:{net_file}"))
                .unwrap_or_else(|e| panic!("{net_file}: {e:#}"));
            out.push_str(&format!("\narch {arch_file} net {net_file}\n"));
            match estimate_network(mapper.as_ref(), &net, &fp) {
                Ok(e) => {
                    for l in &e.layers {
                        match &l.estimate {
                            None => out.push_str(&format!("layer {} fused\n", l.layer_name)),
                            Some(_) => out.push_str(&format!(
                                "layer {} cycles {}\n",
                                l.layer_name,
                                l.cycles()
                            )),
                        }
                    }
                    out.push_str(&format!("total {}\n", e.total_cycles()));
                }
                Err(_) => out.push_str("unmappable\n"),
            }
        }
    }
    out
}

#[test]
fn golden_per_layer_estimates_are_pinned() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/estimates.txt");
    let current = render();
    let pinned = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading golden fixture {path}: {e}"));
    if pinned.contains("UNINITIALIZED") || std::env::var_os("GOLDEN_UPDATE").is_some() {
        std::fs::write(path, &current)
            .unwrap_or_else(|e| panic!("blessing golden fixture {path}: {e}"));
        eprintln!("golden fixture blessed: {path}");
        return;
    }
    if pinned != current {
        // a full diff dump would be unreadable; locate the first divergence
        let mismatch = pinned
            .lines()
            .zip(current.lines())
            .enumerate()
            .find(|(_, (a, b))| a != b)
            .map(|(i, (a, b))| format!("first difference at line {}:\n  pinned:  {a}\n  current: {b}", i + 1))
            .unwrap_or_else(|| {
                format!(
                    "one output is a prefix of the other (pinned {} lines, current {} lines)",
                    pinned.lines().count(),
                    current.lines().count()
                )
            });
        panic!(
            "golden estimates diverged from {path}\n{mismatch}\n\
             If the change is intentional, bless a new baseline: \
             GOLDEN_UPDATE=1 cargo test --test golden_estimates"
        );
    }
}
