//! Integration tests for the calibration subsystem: off-state bit-identity
//! (the tentpole's hard requirement), model persistence, and the threading
//! of calibrated values through every engine path.

use std::sync::Arc;

use acadl_perf::accel::GemminiConfig;
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::calib::{self, CalibrationModel, SampleSpec};
use acadl_perf::coordinator::{Arch, Pool};
use acadl_perf::dnn::zoo;
use acadl_perf::engine::EstimationEngine;

/// A corpus small enough that its DES runs stay test-suite-fast, but still
/// covering the paper architectures and a couple of random machines.
fn tiny_spec() -> SampleSpec {
    SampleSpec {
        random_machines: 2,
        kernels_per_machine: 2,
        paper_kernels_per_arch: 1,
        max_kernel_insts: 50_000,
        ..SampleSpec::default()
    }
}

#[test]
fn calibration_off_is_bit_identical() {
    let arch = Arch::Gemmini(GemminiConfig::default());
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();

    let plain = EstimationEngine::new(1 << 10);
    let baseline = plain.estimate_network(&arch, &net, &fp).unwrap();
    assert!(baseline.calibrated_cycles().is_none());
    for l in &baseline.layers {
        assert!(l.calibrated_cycles().is_none());
        assert!(l.ci_bounds().is_none());
        for e in l.estimate.iter().flatten() {
            assert_eq!((e.calibrated_cycles, e.ci_lo, e.ci_hi), (None, None, None));
        }
    }

    // install a model, estimate (stamped), remove it, estimate again: the
    // third run must be bit-identical to the baseline — in particular the
    // cache entries written under calibration must not leak stamps
    let (model, _) = calib::train_from_spec(&tiny_spec()).unwrap();
    let engine = EstimationEngine::new(1 << 10);
    engine.set_calibration(Some(Arc::new(model)));
    let stamped = engine.estimate_network(&arch, &net, &fp).unwrap();
    assert!(stamped.calibrated_cycles().is_some());
    engine.set_calibration(None);
    assert!(engine.calibration().is_none());
    let after = engine.estimate_network(&arch, &net, &fp).unwrap();
    assert!(after.stats.evaluated < after.stats.total_kernels, "warm run: {:?}", after.stats);
    assert!(after.calibrated_cycles().is_none());
    assert_eq!(after.total_cycles(), baseline.total_cycles());
    for (a, b) in after.layers.iter().zip(&baseline.layers) {
        assert_eq!(a.cycles(), b.cycles(), "{}", a.layer_name);
        for e in a.estimate.iter().flatten() {
            assert_eq!((e.calibrated_cycles, e.ci_lo, e.ci_hi), (None, None, None));
        }
    }
    // raw cycles are untouched even while the model is installed
    assert_eq!(stamped.total_cycles(), baseline.total_cycles());
}

#[test]
fn model_persists_and_reloads_exactly() {
    let (model, corpus) = calib::train_from_spec(&tiny_spec()).unwrap();
    assert!(!corpus.samples.is_empty());
    let path = std::env::temp_dir()
        .join(format!("acadl_calib_roundtrip_{}.txt", std::process::id()));
    model.save(&path).unwrap();
    let reloaded = CalibrationModel::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(model, reloaded);
    // the reloaded model predicts identically on the training corpus
    let a = calib::evaluate(&model, &corpus.samples);
    let b = calib::evaluate(&reloaded, &corpus.samples);
    assert_eq!(a, b);
}

#[test]
fn training_set_is_fully_covered_and_never_worse() {
    let (model, corpus) = calib::train_from_spec(&tiny_spec()).unwrap();
    let acc = calib::evaluate(&model, &corpus.samples);
    assert_eq!(acc.samples, corpus.samples.len());
    // the residual band is built from training residuals with margin, so
    // training coverage is total by construction
    assert_eq!(acc.ci_coverage, 1.0, "{acc:?}");
    // the identity guard: calibration may not hurt the set it trained on
    assert!(
        acc.calibrated_mape <= acc.raw_mape + 1e-9,
        "calibration made training estimates worse: {acc:?}"
    );
}

#[test]
fn calibrated_values_thread_through_serial_and_pooled_paths() {
    let (model, _) = calib::train_from_spec(&tiny_spec()).unwrap();
    let model = Arc::new(model);
    let arch = Arch::Gemmini(GemminiConfig::default());
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();

    let serial_engine = EstimationEngine::new(1 << 10);
    serial_engine.set_calibration(Some(Arc::clone(&model)));
    let serial = serial_engine.estimate_network(&arch, &net, &fp).unwrap();

    let pooled_engine = EstimationEngine::new(1 << 10);
    pooled_engine.set_calibration(Some(Arc::clone(&model)));
    let pool = Pool::new(2);
    let pooled = pooled_engine.estimate_network_pooled(&arch, &net, &fp, &pool).unwrap();

    let cal = serial.calibrated_cycles().expect("serial path must stamp");
    assert_eq!(Some(cal), pooled.calibrated_cycles(), "pooled path must stamp identically");
    assert_eq!(serial.ci_bounds(), pooled.ci_bounds());
    let (lo, hi) = serial.ci_bounds().unwrap();
    assert!(lo <= cal && cal <= hi, "bounds must bracket the calibrated value");
    for l in serial.layers.iter().filter(|l| l.estimate.is_some()) {
        let lc = l.calibrated_cycles().expect("every non-fused layer is stamped");
        let (llo, lhi) = l.ci_bounds().unwrap();
        assert!(llo <= lc && lc <= lhi, "{}", l.layer_name);
    }

    // trace-carrying requests bypass the cache but still get stamped
    let traced = serial_engine
        .estimate_network(&arch, &net, &FixedPointConfig { keep_trace: true, ..fp })
        .unwrap();
    assert!(traced.calibrated_cycles().is_some());
}
