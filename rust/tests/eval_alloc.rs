//! Steady-state allocation audit of the AIDG evaluator hot path.
//!
//! The iteration-program rework's headline claim is that a warmed-up
//! evaluation performs **zero heap allocations per iteration**: the
//! emission arena reuses its pools, the lowered program is read-only, the
//! address plane touches resident pages, the buffer-fill rings reuse their
//! counters, and the structural rings reuse their event deques. This test
//! installs a counting global allocator, warms an evaluator past lowering
//! and capacity growth, then evaluates thousands more iterations and
//! asserts the allocation counter did not move.
//!
//! The audit runs **five phases in one test**: the node-table serial walk
//! with the `obs` tracing layer disabled, the same walk with tracing
//! enabled (span open/drop, histogram observe, ring record), the threaded
//! superinstruction tape (whose fused dispatch, folded address guard, and
//! dynamic-latency memo — all allocated at fuse time — must be just as
//! allocation-free), and both dispatch modes of the lane-batched evaluator
//! ([`BatchEvaluator`]) — whose SoA hot path (shared program walk, laned
//! address plane, ring matrix) must be just as allocation-free per
//! iteration as the serial path it transcribes. Tracing warmup — name
//! interning, histogram registration, the global ring's one-time
//! construction — happens inside the warmup window, so the enabled steady
//! state must also be allocation-free. All phases share one test function
//! deliberately: the allocation counter is process-global, and a second
//! parallel test (or even the harness spawning its thread) would pollute
//! the measurement window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use acadl_perf::acadl::{Diagram, Latency};
use acadl_perf::aidg::{BatchEvaluator, DispatchMode, Evaluator};
use acadl_perf::isa::LoopKernel;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Scalar machine with a concurrent memory (capacity 2) so the test also
/// exercises the interval-occupancy ring representation, plus an
/// expression latency to exercise the dynamic-latency escape hatch.
fn machine() -> (Diagram, Ops) {
    let mut d = Diagram::new("m");
    let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
    let es = d.add_execute_stage("es");
    let (rf, regs) = d.add_regfile("rf", "r", 4);
    let mem = d.add_memory("dmem", 4, 4, 1, 2, 0, 4096);
    let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load", "store"]);
    let alu = d.add_fu(es, "alu", Latency::parse("1 + imm0 % 2").unwrap(), &["mac"]);
    d.forward(ifs, es);
    d.fu_writes(lsu, rf);
    d.fu_reads(lsu, rf);
    d.fu_reads(alu, rf);
    d.fu_writes(alu, rf);
    d.mem_reads(lsu, mem);
    d.mem_writes(lsu, mem);
    let ops = Ops { load: d.op("load"), mac: d.op("mac"), store: d.op("store"), regs };
    d.finalize().unwrap();
    (d, ops)
}

struct Ops {
    load: acadl_perf::ids::OpId,
    mac: acadl_perf::ids::OpId,
    store: acadl_perf::ids::OpId,
    regs: Vec<acadl_perf::ids::RegId>,
}

#[test]
fn steady_state_iterations_do_not_allocate() {
    let (d, ops) = machine();
    let (load, mac, store) = (ops.load, ops.mac, ops.store);
    let (r0, r1, r2) = (ops.regs[0], ops.regs[1], ops.regs[2]);
    // addresses cycle through a fixed window so the warmup touches every
    // address-plane page the steady state will ever see
    let kernel = LoopKernel::new(
        "t",
        1 << 20,
        4,
        Box::new(move |it, buf| {
            buf.instr(load).writes(&[r0]).read_mem(&[it % 256]).imm((it % 3) as i64);
            buf.instr(load).writes(&[r1]).read_mem(&[1024 + it % 256]);
            buf.instr(mac).reads(&[r0, r1]).writes(&[r2]).imm((it % 2) as i64);
            buf.instr(store).reads(&[r2]).write_mem(&[2048 + it % 256]);
        }),
    );
    let mut ev = Evaluator::new_with_dispatch(&d, DispatchMode::NodeTable);
    // warmup: lowering, arena/ring/plane capacity growth
    ev.run(&kernel, 0..256).unwrap();
    // pre-reserve the per-iteration stats so their amortized growth can't
    // masquerade as a hot-path allocation (two measured phases below)
    ev.iter_stats.reserve(16384);

    // ---- phase 1: node-table walk, tracing disabled ----
    acadl_perf::obs::set_enabled(false);
    let before = ALLOCS.load(Ordering::SeqCst);
    ev.run(&kernel, 256..4096).unwrap();
    let after = ALLOCS.load(Ordering::SeqCst);

    assert_eq!(ev.iter_stats.len(), 4096);
    assert_eq!(
        after - before,
        0,
        "steady-state evaluation must not allocate ({} allocations in 3840 iterations)",
        after - before
    );
    // sanity: the run actually did work
    assert!(ev.dt_aidg() > 4096);

    // ---- phase 2: node-table walk, tracing enabled ----
    acadl_perf::obs::set_enabled(true);
    {
        // tracing warmup: interns every name used below, registers their
        // histograms, and constructs the global span ring on first drop
        let mut sp = acadl_perf::obs::span("eval_alloc.traced");
        sp.arg("iters", 256);
        sp.note("measure");
        acadl_perf::obs::record_duration("eval_alloc.raw", 1);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    {
        let mut sp = acadl_perf::obs::span("eval_alloc.traced");
        sp.arg("iters", 4096);
        sp.note("measure");
        ev.run(&kernel, 4096..8192).unwrap();
        acadl_perf::obs::record_duration("eval_alloc.raw", 1);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    acadl_perf::obs::set_enabled(false);

    assert_eq!(ev.iter_stats.len(), 8192);
    assert_eq!(
        after - before,
        0,
        "traced steady-state evaluation must not allocate \
         ({} allocations in 4096 iterations with tracing on)",
        after - before
    );

    // ---- phase 3: threaded superinstruction tape ----
    // the warmup window covers fusion (which happens alongside lowering)
    // and the fuse-time memo-table allocation; the measured window must hit
    // the memo (the mac immediate cycles mod 2) without allocating
    let mut tev = Evaluator::new_with_dispatch(&d, DispatchMode::Threaded);
    tev.run(&kernel, 0..256).unwrap();
    tev.iter_stats.reserve(16384);

    let before = ALLOCS.load(Ordering::SeqCst);
    tev.run(&kernel, 256..4096).unwrap();
    let after = ALLOCS.load(Ordering::SeqCst);

    assert_eq!(tev.iter_stats.len(), 4096);
    assert_eq!(
        after - before,
        0,
        "threaded steady-state evaluation must not allocate \
         ({} allocations in 3840 iterations)",
        after - before
    );
    // sanity: the tape actually ran (no silent node-table fallback) and
    // the dynamic-latency memo absorbed the immediate tuples
    let tstats = tev.dispatch_stats();
    assert!(tstats.threaded_instrs > 0, "tape must dispatch: {tstats:?}");
    assert_eq!(tstats.fallback_instrs, 0, "no fallback expected: {tstats:?}");
    assert!(tstats.memo_hits > 0, "dyn-latency memo must hit: {tstats:?}");
    assert_eq!(tev.iter_stats, ev.iter_stats[..4096], "modes must agree");

    // ---- phase 4: lane-batched evaluator, node-table walk ----
    // three digest-equal lanes over separately built diagrams, kernels
    // differing only in their address windows and immediates
    let lane_kernel = |ops: &Ops, base: u64, imm_mod: u64| -> LoopKernel {
        let (load, mac, store) = (ops.load, ops.mac, ops.store);
        let (r0, r1, r2) = (ops.regs[0], ops.regs[1], ops.regs[2]);
        LoopKernel::new(
            "b",
            1 << 20,
            4,
            Box::new(move |it, buf| {
                buf.instr(load)
                    .writes(&[r0])
                    .read_mem(&[base + it % 256])
                    .imm((it % 3) as i64);
                buf.instr(load).writes(&[r1]).read_mem(&[1024 + it % 256]);
                buf.instr(mac).reads(&[r0, r1]).writes(&[r2]).imm((it % imm_mod) as i64);
                buf.instr(store).reads(&[r2]).write_mem(&[2048 + it % 256]);
            }),
        )
    };
    let builds: Vec<(Diagram, Ops)> = (0..3).map(|_| machine()).collect();
    let kernels: Vec<LoopKernel> = vec![
        lane_kernel(&builds[0].1, 0, 2),
        lane_kernel(&builds[1].1, 256, 3),
        lane_kernel(&builds[2].1, 512, 2),
    ];
    let lanes: Vec<(&Diagram, &LoopKernel)> =
        builds.iter().zip(&kernels).map(|((d, _), k)| (d, k)).collect();
    let mut batch = BatchEvaluator::new_with_dispatch(&lanes, DispatchMode::NodeTable);
    assert_eq!(batch.live_lanes(), 3, "digest-equal lanes must all be live");
    // warmup: lowering, route verification, page/ring/arena capacity
    // growth across every lane; the address windows cycle mod 256, so the
    // warmup touches every laned page the steady state will ever see
    batch.run(0..256).unwrap();
    batch.reserve(16384);

    let before = ALLOCS.load(Ordering::SeqCst);
    batch.run(256..4096).unwrap();
    let after = ALLOCS.load(Ordering::SeqCst);

    for lane in 0..3 {
        assert_eq!(batch.iter_stats(lane).len(), 4096);
    }
    assert_eq!(
        after - before,
        0,
        "batched steady-state evaluation must not allocate \
         ({} allocations in 3840 iterations across 3 lanes)",
        after - before
    );
    // sanity: every lane actually did work, and no lane diverged
    assert_eq!(batch.evictions(), 0);
    for lane in 0..3 {
        assert!(batch.dt_aidg(lane) > 4096);
    }

    // ---- phase 5: lane-batched evaluator, threaded tape ----
    let mut tbatch = BatchEvaluator::new_with_dispatch(&lanes, DispatchMode::Threaded);
    assert_eq!(tbatch.live_lanes(), 3);
    tbatch.run(0..256).unwrap();
    tbatch.reserve(16384);

    let before = ALLOCS.load(Ordering::SeqCst);
    tbatch.run(256..4096).unwrap();
    let after = ALLOCS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "threaded batched steady-state evaluation must not allocate \
         ({} allocations in 3840 iterations across 3 lanes)",
        after - before
    );
    assert_eq!(tbatch.evictions(), 0, "no lane may trip the folded guard");
    let tbstats = tbatch.dispatch_stats();
    assert!(tbstats.threaded_instrs > 0, "tape must dispatch: {tbstats:?}");
    assert_eq!(tbstats.fallback_instrs, 0, "no fallback expected: {tbstats:?}");
    assert!(tbstats.memo_hits > 0, "dyn-latency memo must hit: {tbstats:?}");
    // the threaded batch must agree with the node-table batch lane-for-lane
    for lane in 0..3 {
        assert_eq!(tbatch.iter_stats(lane), batch.iter_stats(lane), "lane {lane}");
        assert_eq!(tbatch.nodes(lane), batch.nodes(lane), "lane {lane}");
        assert_eq!(tbatch.dt_aidg(lane), batch.dt_aidg(lane), "lane {lane}");
    }
}
