//! Correctness of the unified estimation engine (`crate::engine`): cached,
//! deduplicated, and pool-parallel estimation must be **cycle-identical**
//! to the uncached reference path (`coordinator::estimate_network`) on
//! every paper architecture — hand-built and description-compiled — cold
//! and warm; repeated-layer networks must evaluate strictly fewer unique
//! kernels than total kernels; and one shared engine must survive being
//! hammered from many threads.

use std::sync::Arc;

use acadl_perf::accel::{GemminiConfig, PlasticineConfig, SystolicConfig, UltraTrailConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{estimate_network, Arch, DescribedArch, NetworkEstimate, Pool};
use acadl_perf::dnn::zoo;
use acadl_perf::engine::{ArchDigest, EstimationEngine, DEFAULT_CACHE_CAP};

/// The four paper architectures as hand builders.
fn builder_archs() -> Vec<Arch> {
    vec![
        Arch::Systolic(SystolicConfig::new(2, 2)),
        Arch::UltraTrail(UltraTrailConfig::default()),
        Arch::Gemmini(GemminiConfig::default()),
        Arch::Plasticine(PlasticineConfig::new(2, 3, 8)),
    ]
}

/// The four paper architectures as shipped textual descriptions.
fn described_archs() -> Vec<Arch> {
    [
        "arch/systolic_16x16.toml",
        "arch/ultratrail_8x8.toml",
        "arch/gemmini_16.toml",
        "arch/plasticine_3x6.toml",
    ]
    .into_iter()
    .map(|f| Arch::Described(DescribedArch::file(f)))
    .collect()
}

/// Everything cycle-relevant must match, layer by layer.
fn assert_cycle_identical(what: &str, a: &NetworkEstimate, b: &NetworkEstimate) {
    assert_eq!(a.layer_cycles(), b.layer_cycles(), "{what}: per-layer cycles differ");
    assert_eq!(a.total_cycles(), b.total_cycles(), "{what}: total cycles differ");
    assert_eq!(a.evaluated_iters(), b.evaluated_iters(), "{what}: evaluated iters differ");
    assert_eq!(a.total_iters(), b.total_iters(), "{what}: total iters differ");
    assert_eq!(a.total_insts(), b.total_insts(), "{what}: instruction totals differ");
}

/// Cold engine == uncached reference == warm engine, for every hand-built
/// and description-compiled paper architecture on TC-ResNet8.
#[test]
fn cold_and_warm_cycle_identical_across_all_architectures() {
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    for arch in builder_archs().into_iter().chain(described_archs()) {
        let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
        let mapper = arch.mapper().unwrap();
        let reference = estimate_network(mapper.as_ref(), &net, &fp).unwrap();
        let name = reference.arch.clone();

        let cold = engine.estimate_network(&arch, &net, &fp).unwrap();
        assert_cycle_identical(&format!("{name} cold"), &reference, &cold);
        assert_eq!(cold.stats.cache_hits, 0, "{name}: fresh engine cannot hit");

        let warm = engine.estimate_network(&arch, &net, &fp).unwrap();
        assert_cycle_identical(&format!("{name} warm"), &reference, &warm);
        assert_eq!(warm.stats.evaluated, 0, "{name}: warm run must not re-evaluate");
        assert_eq!(
            warm.stats.cache_hits + warm.stats.deduped,
            warm.stats.total_kernels,
            "{name}: warm run must be fully reused ({:?})",
            warm.stats
        );
    }
}

/// The acceptance property: a repeated-layer network (TC-ResNet8 repeats
/// the clip-layer shape inside every residual block) evaluates strictly
/// fewer unique kernels than total kernels, and the counters prove it. The
/// scalar (systolic) mapper maps activations explicitly, so the duplicates
/// are visible there; the other mappers fuse activations, so for them only
/// the accounting invariants are asserted.
#[test]
fn repeated_layers_deduplicate() {
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    for arch in builder_archs() {
        let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
        let e = engine.estimate_network(&arch, &net, &fp).unwrap();
        if matches!(arch, Arch::Systolic(_)) {
            assert!(
                e.stats.unique_kernels < e.stats.total_kernels,
                "{}: expected unique < total, got {:?}",
                e.arch,
                e.stats
            );
            // one clip kernel per residual block is a repeat of its sibling
            assert!(e.stats.deduped >= 3, "{}: {:?}", e.arch, e.stats);
        }
        assert!(e.stats.unique_kernels <= e.stats.total_kernels, "{}: {:?}", e.arch, e.stats);
        assert_eq!(e.stats.evaluated, e.stats.unique_kernels, "{}: {:?}", e.arch, e.stats);
        assert_eq!(
            e.stats.evaluated + e.stats.deduped + e.stats.cache_hits,
            e.stats.total_kernels,
            "{}: {:?}",
            e.arch,
            e.stats
        );
        // the engine's own accounting agrees with the request's
        let s = engine.stats();
        assert_eq!(s.requests, 1);
        assert_eq!(s.kernels_evaluated, e.stats.evaluated);
        assert_eq!(s.cache.entries as u64, e.stats.unique_kernels);
    }
}

/// Kernel-granular pooled evaluation returns the same estimate (cycles and
/// accounting) as the serial engine path.
#[test]
fn pooled_path_matches_serial() {
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    let pool = Pool::new(4);
    for arch in builder_archs() {
        let serial = EstimationEngine::new(DEFAULT_CACHE_CAP)
            .estimate_network(&arch, &net, &fp)
            .unwrap();
        let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
        let cold = engine.estimate_network_pooled(&arch, &net, &fp, &pool).unwrap();
        assert_cycle_identical(&format!("{} pooled cold", serial.arch), &serial, &cold);
        assert_eq!(cold.stats, serial.stats, "{}: accounting differs", serial.arch);
        let warm = engine.estimate_network_pooled(&arch, &net, &fp, &pool).unwrap();
        assert_cycle_identical(&format!("{} pooled warm", serial.arch), &serial, &warm);
        assert_eq!(warm.stats.evaluated, 0, "{}: {:?}", serial.arch, warm.stats);
        // warm accounting mirrors the serial path: one hit per unique key,
        // repeats classed as intra-request dedup
        assert_eq!(warm.stats.cache_hits, warm.stats.unique_kernels, "{:?}", warm.stats);
        assert_eq!(
            warm.stats.deduped,
            warm.stats.total_kernels - warm.stats.unique_kernels,
            "{:?}",
            warm.stats
        );
    }
}

/// Estimating through a shut-down pool surfaces an error, never a panic.
#[test]
fn pooled_path_errors_on_closed_pool() {
    let net = zoo::tc_resnet8();
    let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    let pool = Pool::new(1);
    pool.close();
    let r = engine.estimate_network_pooled(
        &Arch::Systolic(SystolicConfig::new(2, 2)),
        &net,
        &FixedPointConfig::default(),
        &pool,
    );
    assert!(r.is_err(), "closed pool must be an error");
}

/// Many threads hammering one shared engine: every result cycle-identical
/// to the single-threaded reference, cache size bounded by unique kernels.
#[test]
fn multithreaded_stress_on_shared_engine() {
    let fp = FixedPointConfig::default();
    let engine = Arc::new(EstimationEngine::new(DEFAULT_CACHE_CAP));
    let workloads: Vec<(Arch, &str)> = vec![
        (Arch::Systolic(SystolicConfig::new(2, 2)), "tc_resnet8"),
        (Arch::UltraTrail(UltraTrailConfig::default()), "tc_resnet8"),
    ];
    let reference: Vec<u64> = workloads
        .iter()
        .map(|(arch, net)| {
            let mapper = arch.mapper().unwrap();
            estimate_network(mapper.as_ref(), &zoo::by_name(net).unwrap(), &fp)
                .unwrap()
                .total_cycles()
        })
        .collect();

    let threads: Vec<_> = (0..8usize)
        .map(|t| {
            let engine = Arc::clone(&engine);
            let workloads: Vec<(Arch, String)> = workloads
                .iter()
                .map(|(a, n)| (a.clone(), n.to_string()))
                .collect();
            std::thread::spawn(move || {
                let fp = FixedPointConfig::default();
                let mut cycles = Vec::new();
                for round in 0..3usize {
                    let (arch, net) = &workloads[(t + round) % workloads.len()];
                    let e = engine
                        .estimate_network(arch, &zoo::by_name(net).unwrap(), &fp)
                        .unwrap();
                    cycles.push(((t + round) % workloads.len(), e.total_cycles()));
                }
                cycles
            })
        })
        .collect();
    for th in threads {
        for (which, cycles) in th.join().unwrap() {
            assert_eq!(cycles, reference[which], "thread result diverged");
        }
    }
    // 24 requests, but almost all kernel work reused across threads. Racing
    // cold misses may each evaluate (both insert the same entry), so the
    // bound is deliberately loose — yet far below the 24 cold runs the old
    // per-request path would have paid.
    let s = engine.stats();
    assert_eq!(s.requests, 24);
    assert!(
        s.kernels_evaluated < s.kernels_total / 2,
        "expected substantial cross-thread reuse: {s:?}"
    );
}

/// A structurally identical description and hand builder share one
/// architecture digest — and therefore one set of cache entries.
#[test]
fn described_and_builder_archs_share_cache_entries() {
    let described = Arch::Described(DescribedArch::file("arch/ultratrail_8x8.toml"));
    let hand = Arch::UltraTrail(UltraTrailConfig::default());
    let dd = ArchDigest::of(described.mapper().unwrap().diagram());
    let hd = ArchDigest::of(hand.mapper().unwrap().diagram());
    if dd != hd {
        // digests are allowed to differ if the diagrams differ structurally
        // (they are pinned cycle-identical, not structure-identical); in that
        // case the engine simply keeps separate entries — nothing to assert
        eprintln!("note: described/builder ultratrail digests differ; no cache sharing");
        return;
    }
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
    engine.estimate_network(&hand, &net, &fp).unwrap();
    let cross = engine.estimate_network(&described, &net, &fp).unwrap();
    assert_eq!(cross.stats.evaluated, 0, "{:?}", cross.stats);
}

/// A tight cache capacity bounds memory (entries evicted LRU) without ever
/// compromising correctness.
#[test]
fn bounded_cache_stays_correct_under_eviction() {
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    let arch = Arch::Systolic(SystolicConfig::new(2, 2));
    let reference = {
        let mapper = arch.mapper().unwrap();
        estimate_network(mapper.as_ref(), &net, &fp).unwrap()
    };
    // capacity 4 over 16 shards -> at most 1 entry per shard
    let engine = EstimationEngine::new(4);
    for round in 0..3 {
        let e = engine.estimate_network(&arch, &net, &fp).unwrap();
        assert_cycle_identical(&format!("evicting round {round}"), &reference, &e);
    }
    assert!(engine.cache_len() <= 16, "cap 4 -> at most one entry per shard");
    assert!(engine.stats().cache.evictions > 0, "{:?}", engine.stats());
}
