//! Round-trip fidelity of the textual network frontend: each shipped
//! `net/*.toml` description must compile to the **exact layer list** its
//! `dnn::zoo` builder produces (structural pin), estimate **cycle-identical**
//! to it across all four described paper architectures, share the engine's
//! content-addressed estimate cache with the zoo spelling (the KernelKey
//! proof: a zoo-warmed engine serves the described network without
//! evaluating anything), and the validator must report the documented error
//! classes with file/line spans.

use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{
    estimate_network, resolve_network, serve, Arch, DescribedArch,
};
use acadl_perf::dnn::text::{check_net_source, NetRegistry, Severity};
use acadl_perf::dnn::zoo;
use acadl_perf::dnn::Network;
use acadl_perf::engine::EstimationEngine;

const NET_FILES: [(&str, fn() -> Network); 5] = [
    ("net/tc_resnet8.toml", zoo::tc_resnet8),
    ("net/alexnet.toml", zoo::alexnet),
    ("net/alexnet_reduced.toml", zoo::alexnet_reduced),
    ("net/efficientnet.toml", zoo::efficientnet),
    ("net/efficientnet_reduced.toml", zoo::efficientnet_reduced),
];

const ARCH_FILES: [&str; 4] = [
    "arch/systolic_16x16.toml",
    "arch/ultratrail_8x8.toml",
    "arch/gemmini_16.toml",
    "arch/plasticine_3x6.toml",
];

/// The strongest pin: the described network's layer list — every name,
/// kind, and hyper-parameter — equals the zoo builder's. Cycle-identity on
/// any architecture follows, since estimation is a function of the layers.
#[test]
fn shipped_descriptions_match_zoo_layer_lists() {
    for (file, builder) in NET_FILES {
        let described = resolve_network(&format!("net:{file}"))
            .unwrap_or_else(|e| panic!("compiling {file}: {e:#}"));
        let hand = builder();
        assert_eq!(described.name, hand.name, "{file}: network names differ");
        assert_eq!(
            described.layers.len(),
            hand.layers.len(),
            "{file}: layer counts differ"
        );
        for (i, (d, h)) in described.layers.iter().zip(&hand.layers).enumerate() {
            assert_eq!(d, h, "{file}: layer {i} differs from the zoo builder");
        }
    }
}

#[test]
fn shipped_descriptions_validate_cleanly() {
    for (file, _) in NET_FILES {
        let src = std::fs::read_to_string(file).unwrap();
        let (net, diags) = check_net_source(&src);
        assert!(net.is_some(), "{file} did not compile: {diags:?}");
        assert!(diags.is_empty(), "{file}: unexpected diagnostics {diags:?}");
    }
}

/// Estimate `network` on a described architecture through both network
/// spellings and require identical results, layer by layer.
fn assert_cycle_identical(arch_file: &str, net_file: &str, builder: fn() -> Network) {
    let fp = FixedPointConfig::default();
    let arch = Arch::Described(DescribedArch::file(arch_file));
    let mapper = arch.mapper().unwrap_or_else(|e| panic!("compiling {arch_file}: {e:#}"));

    let described = resolve_network(&format!("net:{net_file}")).unwrap();
    let de = estimate_network(mapper.as_ref(), &described, &fp).unwrap();
    let he = estimate_network(mapper.as_ref(), &builder(), &fp).unwrap();

    assert_eq!(de.network, he.network, "{net_file} on {arch_file}: names differ");
    assert_eq!(
        de.layer_cycles(),
        he.layer_cycles(),
        "{net_file} on {arch_file}: per-layer cycles differ from the zoo builder"
    );
    assert_eq!(de.total_cycles(), he.total_cycles());
    assert_eq!(
        de.evaluated_iters(),
        he.evaluated_iters(),
        "{net_file} on {arch_file}: fixed-point evaluation took a different path"
    );
}

#[test]
fn tc_resnet8_matches_zoo_on_all_described_architectures() {
    for arch_file in ARCH_FILES {
        assert_cycle_identical(arch_file, "net/tc_resnet8.toml", zoo::tc_resnet8);
    }
}

#[test]
fn reduced_networks_match_zoo_on_gemmini() {
    assert_cycle_identical("arch/gemmini_16.toml", "net/alexnet_reduced.toml", zoo::alexnet_reduced);
    assert_cycle_identical(
        "arch/gemmini_16.toml",
        "net/efficientnet_reduced.toml",
        zoo::efficientnet_reduced,
    );
}

/// The KernelKey proof: described networks produce the same content-
/// addressed kernel fingerprints as the zoo builders, so a zoo-warmed
/// engine serves the described spelling entirely from cache (and vice
/// versa) — zero kernels evaluated, cycle-identical totals.
#[test]
fn described_networks_share_the_engine_cache_with_zoo() {
    let engine = EstimationEngine::new(1 << 12);
    let arch = Arch::Described(DescribedArch::file("arch/gemmini_16.toml"));
    let fp = FixedPointConfig::default();

    let hand = zoo::tc_resnet8();
    let cold = engine.estimate_network(&arch, &hand, &fp).unwrap();
    assert!(cold.stats.evaluated > 0);

    let described = resolve_network("net:net/tc_resnet8.toml").unwrap();
    let warm = engine.estimate_network(&arch, &described, &fp).unwrap();
    assert_eq!(warm.total_cycles(), cold.total_cycles());
    assert_eq!(
        warm.stats.evaluated, 0,
        "described network must hit the zoo-warmed cache: {:?}",
        warm.stats
    );
    assert_eq!(
        warm.stats.cache_hits + warm.stats.deduped,
        warm.stats.total_kernels,
        "{:?}",
        warm.stats
    );
}

#[test]
fn net_registry_cache_hit_skips_recompilation() {
    let src = std::fs::read_to_string("net/tc_resnet8.toml").unwrap();
    let reg = NetRegistry::new();
    let a = reg.get_or_compile(&src, "tc").unwrap();
    assert_eq!(reg.compile_count(), 1);
    let b = reg.get_or_compile(&src, "tc").unwrap();
    assert_eq!(reg.compile_count(), 1, "cache hit must not recompile");
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    let changed = format!("{src}\n# tweaked\n");
    reg.get_or_compile(&changed, "tc").unwrap();
    assert_eq!(reg.compile_count(), 2);
}

/// The acceptance-criteria path end to end: a described architecture and a
/// described network through the serve front-end, cycle-identical to the
/// builder + zoo-name spelling, warm on the second request.
#[test]
fn described_net_estimates_flow_through_the_server() {
    let input = "estimate file:arch/gemmini_16.toml net:net/tc_resnet8.toml\n\
                 estimate gemmini:16 tc_resnet8\n\
                 estimate file:arch/gemmini_16.toml net:net/tc_resnet8.toml\nquit\n";
    let mut out = Vec::new();
    let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
    assert_eq!(served, 3);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let field = |line: &str, name: &str| -> String {
        line.split_whitespace()
            .find_map(|t| t.strip_prefix(name))
            .unwrap_or_else(|| panic!("no {name} in {line}"))
            .to_string()
    };
    assert!(lines[0].starts_with("gemmini16x16 tc_resnet8 cycles="), "{}", lines[0]);
    // all three spellings agree on cycles
    assert_eq!(field(lines[0], "cycles="), field(lines[1], "cycles="));
    assert_eq!(field(lines[0], "cycles="), field(lines[2], "cycles="));
    // the repeat request is served without evaluating any kernel
    let total: u64 = field(lines[2], "kernels=").parse().unwrap();
    let hits: u64 = field(lines[2], "cache_hits=").parse().unwrap();
    let dedup: u64 = field(lines[2], "deduped=").parse().unwrap();
    assert_eq!(hits + dedup, total, "{}", lines[2]);
}

#[test]
fn check_reports_spanned_errors_for_broken_descriptions() {
    let src = std::fs::read_to_string("net/tc_resnet8.toml").unwrap();
    // break it three ways: a dangling skip reference, an impossible conv
    // window, and a shape-incompatible residual add
    let broken = format!(
        "{src}\n[[layer]]\nname = \"extra\"\nkind = \"conv1d\"\nfrom = \"ghost\"\n\
         out_channels = 4\nkernel = 3\n\n\
         [[layer]]\nname = \"widepool\"\nkind = \"maxpool1d\"\nfrom = \"avgpool\"\nkernel = 99\n\n\
         [[layer]]\nname = \"badadd\"\nkind = \"add\"\nfrom = \"clip1\"\nwith = \"block1_clip2\"\n"
    );
    let (net, diags) = check_net_source(&broken);
    assert!(net.is_none());
    let errors: Vec<String> = diags
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .map(|d| d.render("net.toml"))
        .collect();
    assert!(
        errors.iter().any(|e| e.contains("unknown layer or input `ghost`")),
        "missing dangling-reference error: {errors:?}"
    );
    assert!(
        errors.iter().any(|e| e.contains("produces no output")),
        "missing dead-window error: {errors:?}"
    );
    assert!(
        errors.iter().any(|e| e.contains("operand")),
        "missing add-shape error: {errors:?}"
    );
    // every rendered diagnostic carries file:line:col
    for e in &errors {
        let rest = e.strip_prefix("net.toml:").unwrap_or_else(|| panic!("no origin in {e}"));
        let mut parts = rest.splitn(3, ':');
        let line: u32 = parts.next().unwrap().parse().unwrap();
        let _col: u32 = parts.next().unwrap().parse().unwrap();
        assert!(line >= 1, "bad line in {e}");
    }
}
