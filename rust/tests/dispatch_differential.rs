//! Differential fuzz for the threaded-code dispatch path: the fused
//! superinstruction tape must be **bit-identical** to the node-table walk
//! it lowers from — across random architectures × random template kernels
//! (chunked across `run()` calls), across the lane-batched evaluator, on
//! every paper architecture × TC-ResNet8, and through both fusion
//! fallbacks (the structural multi-range sentinel and the run-time folded
//! address guard). The node-table walk itself is pinned against the
//! retained reference evaluator by `aidg::program`'s unit tests, closing
//! the chain threaded == node-table == reference.

use std::sync::Arc;

use acadl_perf::accel::{
    Gemmini, GemminiConfig, Plasticine, PlasticineConfig, Systolic, SystolicConfig, UltraTrail,
    UltraTrailConfig,
};
use acadl_perf::acadl::Diagram;
use acadl_perf::aidg::{default_dispatch, BatchEvaluator, DispatchMode, Evaluator, LaneStatus};
use acadl_perf::dnn::zoo;
use acadl_perf::isa::LoopKernel;
use acadl_perf::mapping::{
    gemm_tile::GemmTileMapper, plasticine_map::PlasticineMapper, scalar::ScalarMapper,
    tensor_op::TensorOpMapper, Mapper,
};
use acadl_perf::testkit::{
    migrating_kernel, multirange_machine, random_kernel, random_machine, Prop, RandMachine, Rng,
};

/// Run `kernel` through both dispatch modes (chunked at `cut`) and assert
/// they agree observation-for-observation.
fn assert_modes_agree(d: &Diagram, kernel: &LoopKernel, k: u64, cut: u64, tag: &str) {
    let mut threaded = Evaluator::new_with_dispatch(d, DispatchMode::Threaded);
    let mut table = Evaluator::new_with_dispatch(d, DispatchMode::NodeTable);
    threaded.run(kernel, 0..cut).unwrap();
    threaded.run(kernel, cut..k).unwrap();
    table.run(kernel, 0..cut).unwrap();
    table.run(kernel, cut..k).unwrap();
    assert_eq!(threaded.iter_stats, table.iter_stats, "{tag}: iter_stats");
    assert_eq!(threaded.st.nodes, table.st.nodes, "{tag}: nodes");
    assert_eq!(threaded.dt_aidg(), table.dt_aidg(), "{tag}: dt");
}

/// The headline fuzz: threaded == node-table on random machines × random
/// kernels, chunked so tape reuse crosses `run()` boundaries. Also checks
/// the fleet-wide dispatch accounting: across the whole corpus the tape
/// must actually run, and the dynamic-latency memo must actually hit.
#[test]
fn threaded_matches_node_table_on_random_machines() {
    let mut total_threaded = 0u64;
    let mut total_memo_hits = 0u64;
    Prop::new(0xD15B).cases(40).run(|rng| {
        let m = random_machine(rng);
        let k = rng.range_u64(8, 48);
        let kernel = random_kernel(rng, &m, k);
        let cut = rng.range_u64(1, k - 1);
        let mut threaded = Evaluator::new_with_dispatch(&m.d, DispatchMode::Threaded);
        let mut table = Evaluator::new_with_dispatch(&m.d, DispatchMode::NodeTable);
        threaded.run(&kernel, 0..cut).unwrap();
        threaded.run(&kernel, cut..k).unwrap();
        table.run(&kernel, 0..cut).unwrap();
        table.run(&kernel, cut..k).unwrap();
        assert_eq!(threaded.iter_stats, table.iter_stats, "k={k} cut={cut}");
        assert_eq!(threaded.st.nodes, table.st.nodes, "k={k} cut={cut}");
        assert_eq!(threaded.dt_aidg(), table.dt_aidg(), "k={k} cut={cut}");
        let stats = threaded.dispatch_stats();
        total_threaded += stats.threaded_instrs;
        total_memo_hits += stats.memo_hits;
    });
    assert!(total_threaded > 0, "the corpus must exercise the tape");
    assert!(total_memo_hits > 0, "the corpus must exercise the dyn-latency memo");
}

/// Every paper architecture × TC-ResNet8: the default (threaded) dispatch
/// is pinned against the node-table walk kernel-for-kernel.
#[test]
fn threaded_matches_node_table_on_paper_architectures() {
    let mappers: Vec<(&str, Box<dyn Mapper>)> = vec![
        (
            "systolic4x4",
            Box::new(ScalarMapper::new(Arc::new(
                Systolic::new(SystolicConfig::new(4, 4)).unwrap(),
            ))),
        ),
        (
            "gemmini",
            Box::new(GemmTileMapper::new(Arc::new(
                Gemmini::new(GemminiConfig::default()).unwrap(),
            ))),
        ),
        (
            "ultratrail",
            Box::new(TensorOpMapper::new(Arc::new(
                UltraTrail::new(UltraTrailConfig::default()).unwrap(),
            ))),
        ),
        (
            "plasticine",
            Box::new(PlasticineMapper::new(Arc::new(
                Plasticine::new(PlasticineConfig::new(2, 3, 8)).unwrap(),
            ))),
        ),
    ];
    let net = zoo::tc_resnet8();
    for (name, mapper) in &mappers {
        let mapped = mapper.map_network(&net).unwrap();
        for ml in mapped.iter().filter(|l| !l.fused) {
            for kernel in &ml.kernels {
                let iters = kernel.k.min(12);
                let cut = (iters / 2).max(1);
                let tag = format!("{name}: {}", kernel.label);
                assert_modes_agree(mapper.diagram(), kernel, iters, cut, &tag);
            }
        }
    }
}

/// Structural fallback: offsets touching a multi-range memory never fuse,
/// and the threaded evaluator's node-table detour stays bit-identical.
#[test]
fn structural_fallback_matches_node_table() {
    let m = multirange_machine();
    let mut rng = Rng::new(0x5EED);
    let kernel = random_kernel(&mut rng, &m, 32);
    assert_modes_agree(&m.d, &kernel, 32, 9, "multirange");
    let mut threaded = Evaluator::new_with_dispatch(&m.d, DispatchMode::Threaded);
    threaded.run(&kernel, 0..32).unwrap();
    let fusion = threaded.fusion_stats();
    assert!(
        fusion.fusible_offsets < fusion.offsets,
        "multi-range offsets must be structurally non-fusible: {fusion:?}"
    );
}

/// Run-time fallback: a kernel that abandons iteration 0's address→memory
/// partition trips the folded guard; the fallback is bit-identical and the
/// dispatch stats record both the fused iterations and the detour.
#[test]
fn runtime_guard_fallback_matches_node_table() {
    let mut rng = Rng::new(0xFA11);
    let m = two_memory_machine(&mut rng);
    let kernel = migrating_kernel(&m, 8);
    assert_modes_agree(&m.d, &kernel, 8, 3, "migrating");
    let mut threaded = Evaluator::new_with_dispatch(&m.d, DispatchMode::Threaded);
    threaded.run(&kernel, 0..8).unwrap();
    let stats = threaded.dispatch_stats();
    assert!(stats.threaded_instrs > 0, "iteration 0 must run fused: {stats:?}");
    assert!(stats.fallback_instrs > 0, "later iterations must fall back: {stats:?}");
}

/// Batched lanes: digest-equal candidates evaluated in SoA lockstep must
/// agree between dispatch modes lane-for-lane, and a partition-migrating
/// lane must be evicted identically under both modes (the folded guard is
/// the same predicate as the partition check).
#[test]
fn batch_modes_agree_and_evict_identically() {
    // three digest-equal builds (same seed → same structure), kernels
    // differing per lane only in iteration count handling below
    let builds: Vec<RandMachine> =
        (0..3).map(|_| random_machine(&mut Rng::new(0xBA7C))).collect();
    let kernels: Vec<LoopKernel> = builds
        .iter()
        .map(|m| random_kernel(&mut Rng::new(0x6E0), m, 24))
        .collect();
    let lanes: Vec<(&Diagram, &LoopKernel)> =
        builds.iter().zip(&kernels).map(|(m, k)| (&m.d, k)).collect();

    let mut threaded = BatchEvaluator::new_with_dispatch(&lanes, DispatchMode::Threaded);
    let mut table = BatchEvaluator::new_with_dispatch(&lanes, DispatchMode::NodeTable);
    assert_eq!(threaded.live_lanes(), 3);
    assert_eq!(table.live_lanes(), 3);
    threaded.run(0..11).unwrap();
    threaded.run(11..24).unwrap();
    table.run(0..11).unwrap();
    table.run(11..24).unwrap();
    assert_eq!(threaded.evictions(), table.evictions(), "evictions must match");
    for lane in 0..3 {
        assert_eq!(threaded.iter_stats(lane), table.iter_stats(lane), "lane {lane}");
        assert_eq!(threaded.nodes(lane), table.nodes(lane), "lane {lane}");
        assert_eq!(threaded.dt_aidg(lane), table.dt_aidg(lane), "lane {lane}");
    }

    // a migrating lane diverges from the shared partition after iteration
    // 0 — both modes must evict it (guard fail == partition fail) and the
    // surviving serial evaluation must still be bit-identical
    let mut rng = Rng::new(0xFA12);
    let m2 = two_memory_machine(&mut rng);
    let mk = migrating_kernel(&m2, 16);
    let solo: Vec<(&Diagram, &LoopKernel)> = vec![(&m2.d, &mk)];
    for mode in [DispatchMode::Threaded, DispatchMode::NodeTable] {
        let mut b = BatchEvaluator::new_with_dispatch(&solo, mode);
        b.run(0..16).unwrap();
        assert_eq!(b.evictions(), 1, "{}: the migrating lane must evict", mode.name());
        assert_eq!(b.status(0), LaneStatus::Evicted, "{}: status must record it", mode.name());
    }
}

/// The CLI knob's domain: mode names round-trip through parse, unknown
/// names are rejected, and the process default is the threaded tape.
#[test]
fn dispatch_mode_parse_round_trips() {
    for mode in [DispatchMode::Threaded, DispatchMode::NodeTable] {
        assert_eq!(DispatchMode::parse(mode.name()), Some(mode));
    }
    assert_eq!(DispatchMode::parse("threaded"), Some(DispatchMode::Threaded));
    assert_eq!(DispatchMode::parse("node-table"), Some(DispatchMode::NodeTable));
    assert_eq!(DispatchMode::parse("goto"), None);
    assert_eq!(default_dispatch(), DispatchMode::Threaded);
    let d = multirange_machine();
    assert_eq!(Evaluator::new(&d.d).dispatch_mode(), DispatchMode::Threaded);
}

/// Draw random machines until one has two memories (the migrating kernel
/// needs two addressable regions backed by distinct objects).
fn two_memory_machine(rng: &mut Rng) -> RandMachine {
    loop {
        let m = random_machine(rng);
        if m.mem_bases.len() >= 2 {
            return m;
        }
    }
}
