//! Integration coverage for the `obs` tracing layer against the real
//! estimation stack: span nesting across pool threads, gauge lifecycles,
//! Chrome-trace schema (checked with the testkit JSON parser), ring
//! wraparound, and — the load-bearing guarantee — **cycle-identity**:
//! estimates with tracing enabled are bit-identical to estimates with
//! tracing disabled on every paper architecture.

use std::sync::Mutex;

use acadl_perf::accel::{GemminiConfig, PlasticineConfig, SystolicConfig, UltraTrailConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{Arch, Pool};
use acadl_perf::dnn::zoo;
use acadl_perf::engine::EstimationEngine;
use acadl_perf::obs;
use acadl_perf::testkit::json::Json;

/// Serializes tests that toggle the process-global tracing flag (the test
/// harness runs this binary's tests in parallel).
static FLAG_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn paper_archs() -> Vec<Arch> {
    vec![
        Arch::Systolic(SystolicConfig::new(2, 2)),
        Arch::UltraTrail(UltraTrailConfig::default()),
        Arch::Gemmini(GemminiConfig::default()),
        Arch::Plasticine(PlasticineConfig::new(2, 3, 8)),
    ]
}

/// A pooled estimate produces a span tree that crosses threads: the
/// request span parents every `pool.job`, each job parents the
/// `engine.kernel` it ran, and the pool gauges return to zero when the
/// work drains.
#[test]
fn pooled_estimate_nests_spans_across_threads() {
    let _l = lock();
    obs::set_enabled(true);
    let t0 = obs::now_ns();
    let net = zoo::tc_resnet8();
    {
        let engine = EstimationEngine::new(1 << 10);
        let pool = Pool::new(2);
        let e = engine
            .estimate_network_pooled(
                &Arch::Gemmini(GemminiConfig::default()),
                &net,
                &FixedPointConfig::default(),
                &pool,
            )
            .unwrap();
        assert!(e.total_cycles() > 0);
        assert!(e.stats.evaluated > 0, "fresh engine must evaluate: {:?}", e.stats);
        // `pool` drops here and joins its workers, so every job's span and
        // gauge update is complete before the assertions below
    }
    obs::set_enabled(false);

    let events: Vec<obs::SpanEvent> =
        obs::ring::events().into_iter().filter(|e| e.start_ns >= t0).collect();
    let request = events
        .iter()
        .find(|e| e.name() == "engine.estimate_network_pooled")
        .expect("request span recorded");
    let jobs: Vec<&obs::SpanEvent> =
        events.iter().filter(|e| e.name() == "pool.job").collect();
    assert!(!jobs.is_empty(), "pooled evaluation must run pool jobs");
    for j in &jobs {
        assert_eq!(j.parent, request.id, "pool.job must parent to the request span");
        assert_ne!(j.tid, request.tid, "pool.job runs on a worker thread");
        assert_eq!(obs::resolve_name(j.arg0_key), "queued_ns");
    }
    let job_ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
    let kernels: Vec<&obs::SpanEvent> = events
        .iter()
        .filter(|e| e.name() == "engine.kernel" && job_ids.contains(&e.parent))
        .collect();
    assert!(!kernels.is_empty(), "worker kernel spans must nest under pool.job");
    for k in &kernels {
        assert_eq!(k.note(), Some("evaluated"));
        assert_eq!(obs::resolve_name(k.arg0_key), "kernel_hi");
    }
    // plan spans nest under the request on the calling thread
    assert!(events
        .iter()
        .any(|e| e.name() == "engine.kernel.plan" && e.parent == request.id));

    // pool drained and dropped: both pool gauges are back to zero
    let snap = obs::snapshot();
    let gauge = |name: &str| {
        snap.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
    };
    assert_eq!(gauge("pool.queue_depth"), 0);
    assert_eq!(gauge("pool.inflight"), 0);
}

/// Tracing must never perturb results: estimates with the tracing layer
/// enabled are cycle-identical to estimates with it disabled, on all four
/// paper architectures.
#[test]
fn tracing_on_is_cycle_identical_to_tracing_off() {
    let _l = lock();
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    for arch in paper_archs() {
        obs::set_enabled(false);
        let off = EstimationEngine::new(1 << 10).estimate_network(&arch, &net, &fp).unwrap();
        obs::set_enabled(true);
        let on = EstimationEngine::new(1 << 10).estimate_network(&arch, &net, &fp).unwrap();
        obs::set_enabled(false);
        assert_eq!(off.layer_cycles(), on.layer_cycles(), "{}: per-layer cycles", off.arch);
        assert_eq!(off.total_cycles(), on.total_cycles(), "{}: total cycles", off.arch);
        assert_eq!(off.total_iters(), on.total_iters(), "{}: iteration totals", off.arch);
        assert_eq!(off.total_insts(), on.total_insts(), "{}: instruction totals", off.arch);
    }
}

/// The Chrome trace export is valid JSON with the trace-event schema keys
/// Perfetto requires, and it round-trips through the testkit parser.
#[test]
fn chrome_trace_export_round_trips_the_schema() {
    let _l = lock();
    obs::set_enabled(true);
    {
        let engine = EstimationEngine::new(1 << 10);
        let mut net = zoo::tc_resnet8();
        net.layers.truncate(3);
        engine
            .estimate_network(
                &Arch::UltraTrail(UltraTrailConfig::default()),
                &net,
                &FixedPointConfig::default(),
            )
            .unwrap();
    }
    obs::set_enabled(false);

    let doc = Json::parse(&obs::chrome_trace_string()).expect("export must be valid JSON");
    assert_eq!(
        doc.get("displayTimeUnit").and_then(Json::as_str),
        Some("ns"),
        "displayTimeUnit present"
    );
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "an estimate must leave events in the ring");
    for ev in events {
        assert!(ev.get("name").and_then(Json::as_str).is_some(), "name: {ev:?}");
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"), "ph: {ev:?}");
        assert!(ev.get("ts").and_then(Json::as_f64).is_some(), "ts: {ev:?}");
        assert!(ev.get("dur").and_then(Json::as_f64).is_some(), "dur: {ev:?}");
        assert_eq!(ev.get("pid").and_then(Json::as_f64), Some(1.0), "pid: {ev:?}");
        assert!(ev.get("tid").and_then(Json::as_f64).is_some(), "tid: {ev:?}");
        let args = ev.get("args").expect("args object");
        assert!(args.get("span_id").and_then(Json::as_f64).is_some(), "span_id: {ev:?}");
        assert!(args.get("parent").and_then(Json::as_f64).is_some(), "parent: {ev:?}");
    }
    // the taxonomy's request span made it into the export by name
    assert!(events.iter().any(|e| {
        e.get("name").and_then(Json::as_str) == Some("engine.estimate_network")
    }));
}

/// Ring wraparound at integration scale: a small private ring keeps the
/// newest events, oldest-first, and reports the drop count.
#[test]
fn private_ring_wraparound_keeps_newest_oldest_first() {
    let ring = obs::SpanRing::new(8);
    let name = obs::intern("obs_trace.wrap");
    for id in 1..=20u64 {
        ring.record(&acadl_perf::obs::SpanEvent {
            name_idx: name,
            tid: 1,
            id,
            parent: 0,
            start_ns: id * 10,
            dur_ns: 5,
            arg0_key: obs::NO_NAME,
            arg0_val: 0,
            arg1_key: obs::NO_NAME,
            arg1_val: 0,
            note_idx: obs::NO_NAME,
        });
    }
    let (events, recorded, dropped) = ring.snapshot();
    assert_eq!((recorded, dropped), (20, 12));
    assert_eq!(events.iter().map(|e| e.id).collect::<Vec<_>>(), (13..=20).collect::<Vec<_>>());
}

/// Histogram bucket edges hold at the public API: 0, 1, powers of two,
/// and `u64::MAX` all land in buckets whose bounds contain them, and
/// quantiles never over-report past the recorded max.
#[test]
fn histogram_boundaries_hold_at_the_public_api() {
    use acadl_perf::obs::hist::{bucket_index, bucket_upper_bound};
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_index(1), 1);
    assert_eq!(bucket_index(u64::MAX), 64);
    for ns in [0u64, 1, 2, 3, 4, 1023, 1024, 1 << 40, u64::MAX - 1, u64::MAX] {
        let i = bucket_index(ns);
        assert!(ns <= bucket_upper_bound(i), "{ns} above its bucket bound");
        if i > 0 {
            assert!(ns > bucket_upper_bound(i - 1), "{ns} fits an earlier bucket");
        }
    }
    let h = obs::Histogram::new();
    h.observe(0, 0);
    h.observe(1, 1);
    h.observe(u64::MAX, u64::MAX);
    let s = h.summary();
    assert_eq!(s.count, 3);
    assert_eq!(s.max_ns, u64::MAX);
    assert_eq!(s.p50_ns, 1, "median clamps to real observations");
}

/// The global engine publishes per-shard cache occupancy gauges, and the
/// aggregate matches the cache's own length.
#[test]
fn global_cache_occupancy_is_gauged_per_shard() {
    let _l = lock();
    let engine = EstimationEngine::global();
    engine.clear_cache();
    let net = zoo::tc_resnet8();
    engine
        .estimate_network(
            &Arch::Systolic(SystolicConfig::new(2, 2)),
            &net,
            &FixedPointConfig::default(),
        )
        .unwrap();
    assert!(engine.cache_len() > 0);
    let snap = obs::snapshot();
    let total: i64 = snap
        .gauges
        .iter()
        .filter(|(n, _)| n.starts_with("cache.shard"))
        .map(|(_, v)| *v)
        .sum();
    assert_eq!(total, engine.cache_len() as i64, "shard gauges sum to cache length");
    let agg = snap.gauges.iter().find(|(n, _)| n == "cache.entries").unwrap().1;
    assert_eq!(agg, total, "aggregate gauge matches shard sum");
    engine.clear_cache();
    let snap = obs::snapshot();
    let agg = snap.gauges.iter().find(|(n, _)| n == "cache.entries").unwrap().1;
    assert_eq!(agg, 0, "clear resets the gauges");
}
