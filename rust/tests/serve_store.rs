//! End-to-end coverage for the concurrent TCP front end and the
//! persistent estimate store (ISSUE 10 acceptance):
//!
//! - N concurrent loopback clients get per-session transcripts
//!   byte-identical to the serial stdio loop (runtimes masked);
//! - a cold engine with a warm store serves TC-ResNet8 against every
//!   shipped `arch/*.toml` with zero kernel evaluations and identical
//!   cycles (calibration off stays bit-identical through the store path);
//! - a repeated `sweep` resumes from the persisted Pareto frontier;
//! - `shutdown` from a client drains the whole listener.
//!
//! Everything here shares the process-global engine, so tests serialize
//! on a file-local lock and detach the store before returning.

use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Mutex;

use acadl_perf::coordinator::{serve, serve_with, NetServer, ServeOptions};
use acadl_perf::engine::EstimationEngine;

/// The four shipped paper-architecture descriptions.
const ARCH_FILES: [&str; 4] = [
    "arch/systolic_16x16.toml",
    "arch/ultratrail_8x8.toml",
    "arch/gemmini_16.toml",
    "arch/plasticine_3x6.toml",
];

/// Serializes tests in this binary: they all mutate the global engine's
/// store attachment and cache.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh per-test scratch directory (removed first in case a previous
/// run of the same test leaked one).
fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("acadl-serve-store-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Mask the only nondeterministic tokens in protocol replies (wall-clock
/// runtimes) so transcripts compare byte-identically.
fn mask(line: &str) -> String {
    line.split_whitespace()
        .map(|t| {
            if t.starts_with("runtime_ms=") {
                "runtime_ms=X"
            } else if t.starts_with("wall_ms=") {
                "wall_ms=X"
            } else {
                t
            }
        })
        .collect::<Vec<_>>()
        .join(" ")
}

/// The value of a `key=`-prefixed token in a reply line.
fn token<'a>(line: &'a str, key: &str) -> &'a str {
    line.split_whitespace()
        .find_map(|t| t.strip_prefix(key))
        .unwrap_or_else(|| panic!("no {key} in {line:?}"))
}

#[test]
fn concurrent_tcp_clients_match_the_serial_stdio_loop() {
    let _lock = lock();
    // estimates, a per-session inline-arch error (isolation), a protocol
    // error — everything deterministic once runtimes are masked
    const TRANSCRIPT: &str = "estimate ultratrail tc_resnet8\n\
                              estimate systolic:2x2 tc_resnet8\n\
                              estimate gemmini tc_resnet8\n\
                              estimate @nope tc_resnet8\n\
                              bogus\n\
                              quit\n";
    // warm the global cache with the same requests first: the reference
    // serial run and every TCP client then see identical cache_hits= /
    // deduped= accounting (a cold reference would differ from the
    // clients, which run after it warmed the cache)
    serve(Cursor::new(TRANSCRIPT), &mut Vec::new()).unwrap();
    let mut serial = Vec::new();
    serve(Cursor::new(TRANSCRIPT), &mut serial).unwrap();
    let serial: Vec<String> =
        String::from_utf8(serial).unwrap().lines().map(mask).collect();
    assert_eq!(serial.len(), 5, "{serial:?}");

    let srv = NetServer::bind("127.0.0.1:0", ServeOptions::default()).unwrap();
    let addr = srv.local_addr();
    let handle = srv.shutdown_handle();
    let server = std::thread::spawn(move || srv.run().unwrap());
    let clients: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let conn = TcpStream::connect(addr).unwrap();
                let mut writer = conn.try_clone().unwrap();
                let reader = BufReader::new(conn);
                writer.write_all(TRANSCRIPT.as_bytes()).unwrap();
                reader.lines().map(|l| mask(&l.unwrap())).collect::<Vec<String>>()
            })
        })
        .collect();
    for client in clients {
        assert_eq!(client.join().unwrap(), serial, "TCP transcript diverged from stdio");
    }
    handle.shutdown();
    let out = server.join().unwrap();
    assert_eq!(out.sessions, 4);
    assert_eq!(out.requests, 4 * serial.len());
}

#[test]
fn warm_store_serves_every_described_arch_with_zero_evaluations() {
    let _lock = lock();
    let dir = scratch("warm");
    let opts = ServeOptions { store: Some(dir.clone()), ..Default::default() };
    let transcript: String = ARCH_FILES
        .iter()
        .map(|a| format!("estimate file:{a} tc_resnet8\n"))
        .chain(["quit\n".to_string()])
        .collect();

    let mut cold = Vec::new();
    serve_with(Cursor::new(&transcript), &mut cold, &opts).unwrap();
    let cold = String::from_utf8(cold).unwrap();

    // a process restart in miniature: drop the store handle and every
    // in-memory cache entry, then reopen the same directory
    EstimationEngine::global().attach_store(None);
    EstimationEngine::global().clear_cache();
    let mut warm = Vec::new();
    serve_with(Cursor::new(&transcript), &mut warm, &opts).unwrap();
    EstimationEngine::global().attach_store(None);
    let warm = String::from_utf8(warm).unwrap();

    for (c, w) in cold.lines().zip(warm.lines()) {
        assert!(c.contains("cycles="), "cold reply {c:?}");
        // bit-identical through the store path (calibration off)
        assert_eq!(token(c, "cycles="), token(w, "cycles="), "{c} vs {w}");
        assert_eq!(token(c, "evaluated_iters="), token(w, "evaluated_iters="));
        // zero kernel evaluations: every slot a (store-promoted) cache hit
        // or an intra-request dedup
        let kernels: u64 = token(w, "kernels=").parse().unwrap();
        let hits: u64 = token(w, "cache_hits=").parse().unwrap();
        let deduped: u64 = token(w, "deduped=").parse().unwrap();
        assert_eq!(hits + deduped, kernels, "warm run evaluated kernels: {w}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repeated_sweep_resumes_the_frontier_from_the_store() {
    let _lock = lock();
    let dir = scratch("frontier");
    let opts = ServeOptions { store: Some(dir.clone()), ..Default::default() };
    let transcript = "sweep file:arch/ultratrail_8x8.toml tc_resnet8\nquit\n";

    let mut first = Vec::new();
    serve_with(Cursor::new(transcript), &mut first, &opts).unwrap();
    EstimationEngine::global().attach_store(None);
    let first = String::from_utf8(first).unwrap();
    let first_line = first.lines().next().unwrap();
    assert_eq!(token(first_line, "resumed="), "0", "{first_line}");
    let frontier: u64 = token(first_line, "frontier=").parse().unwrap();
    assert!(frontier >= 1, "{first_line}");

    let mut second = Vec::new();
    serve_with(Cursor::new(transcript), &mut second, &opts).unwrap();
    EstimationEngine::global().attach_store(None);
    let second = String::from_utf8(second).unwrap();
    let second_line = second.lines().next().unwrap();
    let resumed: u64 = token(second_line, "resumed=").parse().unwrap();
    assert!(resumed >= 1, "prior frontier not resumed: {second_line}");
    // the same sweep merged with its own persisted frontier must agree
    assert_eq!(token(first_line, "frontier="), token(second_line, "frontier="));
    assert_eq!(token(first_line, "best="), token(second_line, "best="));
    assert_eq!(token(first_line, "best_cycles="), token(second_line, "best_cycles="));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_commands_work_over_an_attached_session() {
    let _lock = lock();
    let dir = scratch("cmds");
    let opts = ServeOptions { store: Some(dir.clone()), ..Default::default() };
    let transcript = "estimate ultratrail tc_resnet8\nstore stats\nstore flush\nstore gc\nquit\n";
    let mut out = Vec::new();
    serve_with(Cursor::new(transcript), &mut out, &opts).unwrap();
    EstimationEngine::global().attach_store(None);
    let out = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = out.lines().collect();
    assert!(lines[0].contains("cycles="), "{}", lines[0]);
    assert!(lines[1].starts_with("store dir="), "{}", lines[1]);
    let entries: u64 = token(lines[1], "entries=").parse().unwrap();
    assert!(entries >= 1, "{}", lines[1]);
    assert!(lines[2].starts_with("store flushed records="), "{}", lines[2]);
    // everything was referenced this generation: gc must keep it all
    let kept: u64 = token(lines[3], "kept=").parse().unwrap();
    assert_eq!(token(lines[3], "dropped="), "0", "{}", lines[3]);
    assert_eq!(kept, entries, "{}", lines[3]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn client_shutdown_drains_the_whole_listener() {
    let _lock = lock();
    let srv = NetServer::bind("127.0.0.1:0", ServeOptions::default()).unwrap();
    let addr = srv.local_addr();
    let server = std::thread::spawn(move || srv.run().unwrap());
    let conn = TcpStream::connect(addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    writer.write_all(b"estimate ultratrail tc_resnet8\nshutdown\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("cycles="), "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "shutting down\n");
    // no ShutdownHandle needed: the client's own `shutdown` stops run()
    let out = server.join().unwrap();
    assert_eq!(out.sessions, 1);
    assert_eq!(out.requests, 2);
}
