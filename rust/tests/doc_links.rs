//! Documentation link checker: every repo-relative path referenced from
//! the docs book, the READMEs, and the directory guides must exist, so the
//! docs cannot silently rot as files move. Runs as a plain test (CI's
//! doc-link pass) — no external tooling needed.

use std::collections::BTreeSet;
use std::path::Path;

/// The documentation set this repo ships. Presence is itself asserted, so
/// deleting a book chapter without updating this list fails the build.
const DOC_FILES: [&str; 12] = [
    "README.md",
    "arch/README.md",
    "net/README.md",
    "docs/architecture.md",
    "docs/arch-format.md",
    "docs/net-format.md",
    "docs/serve-protocol.md",
    "docs/performance.md",
    "docs/dse.md",
    "docs/observability.md",
    "docs/accuracy.md",
    "ROADMAP.md",
    // CHANGES.md is a log, not documentation: not checked
];

/// Directories whose mention in backticks is treated as a path reference.
const PATH_ROOTS: [&str; 9] = [
    "docs/", "arch/", "net/", "rust/", "benches/", "examples/", "python/", ".github/", "target/",
];

/// Extract path references from one markdown document, resolved to
/// repo-relative paths: `](relative/path)` markdown links (relative to the
/// document's own directory) plus `` `path/like/this` `` inline code spans
/// starting with a known repo directory (always repo-relative).
fn referenced_paths(doc: &str, text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let dir = Path::new(doc).parent().unwrap_or_else(|| Path::new(""));

    // markdown links, resolved against the document's directory
    let mut rest = text;
    while let Some(i) = rest.find("](") {
        rest = &rest[i + 2..];
        let Some(end) = rest.find(')') else { break };
        let target = &rest[..end];
        rest = &rest[end..];
        if target.starts_with("http://")
            || target.starts_with("https://")
            || target.starts_with('#')
            || target.is_empty()
        {
            continue;
        }
        let target = target.split('#').next().unwrap_or(target);
        let resolved = dir.join(target);
        out.insert(resolved.to_string_lossy().replace('\\', "/"));
    }

    // backticked path-like spans (repo-relative by construction)
    for span in text.split('`').skip(1).step_by(2) {
        if span.contains(|c: char| c.is_whitespace())
            || span.contains('*')
            || span.contains('<')
            || span.contains('$')
            || span.contains("::")
        {
            continue; // globs, placeholders, Rust paths, code
        }
        if PATH_ROOTS.iter().any(|root| span.starts_with(root)) {
            out.insert(span.trim_end_matches(['.', ',', ';']).to_string());
        }
    }
    out
}

#[test]
fn documented_paths_exist() {
    let mut missing = Vec::new();
    for doc in DOC_FILES {
        let text = std::fs::read_to_string(doc)
            .unwrap_or_else(|e| panic!("documentation file {doc} must exist: {e}"));
        for path in referenced_paths(doc, &text) {
            // `target/` artifacts are build outputs, not repo contents
            if path.starts_with("target/") {
                continue;
            }
            if !Path::new(&path).exists() {
                missing.push(format!("{doc} -> {path}"));
            }
        }
    }
    assert!(missing.is_empty(), "dangling documentation references:\n{}", missing.join("\n"));
}

#[test]
fn docs_book_is_linked_from_the_readme() {
    let readme = std::fs::read_to_string("README.md").unwrap();
    for chapter in [
        "docs/architecture.md",
        "docs/arch-format.md",
        "docs/net-format.md",
        "docs/serve-protocol.md",
        "docs/performance.md",
        "docs/dse.md",
        "docs/observability.md",
        "docs/accuracy.md",
    ] {
        assert!(readme.contains(chapter), "README.md must link {chapter}");
    }
}

#[test]
fn performance_doc_covers_threaded_dispatch() {
    // the dispatch rework's operator guide: the chapter heading, the CLI
    // knob, and the fallback contract must stay documented
    let doc = std::fs::read_to_string("docs/performance.md").unwrap();
    assert!(
        doc.contains("## Threaded dispatch & superinstruction fusion"),
        "docs/performance.md must keep the threaded-dispatch chapter"
    );
    for needle in ["--dispatch", "node-table", "AdvanceClock", "dyn_memo_hit_rate"] {
        assert!(doc.contains(needle), "docs/performance.md must mention {needle}");
    }
}

#[test]
fn performance_doc_covers_the_store_and_single_flight() {
    // the persistence layer's operator guide: the chapter heading, the
    // on-disk format anchor, and the dedup contract must stay documented
    let doc = std::fs::read_to_string("docs/performance.md").unwrap();
    assert!(
        doc.contains("## The persistent store & single-flight dedup"),
        "docs/performance.md must keep the store chapter"
    );
    for needle in ["ACPSTOR1", "--store", "store gc", "single-flight", "serve.inflight_waits"] {
        assert!(doc.contains(needle), "docs/performance.md must mention {needle}");
    }
}

#[test]
fn serve_doc_covers_the_network_front_end() {
    // the TCP mode's protocol additions: flags, overload/idle replies,
    // the store commands, and the sweep resume token
    let doc = std::fs::read_to_string("docs/serve-protocol.md").unwrap();
    assert!(
        doc.contains("## Network serve"),
        "docs/serve-protocol.md must keep the network-serve chapter"
    );
    for needle in [
        "--listen",
        "--max-clients",
        "--read-timeout-ms",
        "`busy`",
        "`timeout`",
        "store stats",
        "resumed=",
        "shutdown",
    ] {
        assert!(doc.contains(needle), "docs/serve-protocol.md must mention {needle}");
    }
}

#[test]
fn every_docs_markdown_file_is_checked() {
    // a chapter added to docs/ must also be added to DOC_FILES above
    for entry in std::fs::read_dir("docs").expect("docs/ directory must exist") {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|e| e == "md") {
            let rel = path.to_string_lossy().replace('\\', "/");
            assert!(
                DOC_FILES.contains(&rel.as_str()),
                "{rel} is not covered by the doc-link checker's DOC_FILES list"
            );
        }
    }
}
