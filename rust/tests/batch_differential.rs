//! Differential properties of the lane-batched evaluator (ISSUE 7).
//!
//! The batch contract is *bit-identity*: for every lane,
//! `estimate_layer_batch` must produce a [`LayerEstimate`] whose every
//! numeric field equals what serial `estimate_layer` returns for that lane
//! alone — including lanes the batch evicts (digest mismatch at
//! construction, route-template mismatch, address-partition divergence),
//! which transparently fall back to the serial path. On top of the layer
//! level, the engine's `estimate_batch` must mirror a sequential
//! per-candidate schedule (same cycles, same hit/dedup accounting), and a
//! DSE sweep must produce identical cycles with batching on and off.

use acadl_perf::acadl::text::ast::{Span, Spanned, Sweep, SweepDim, SweepItem};
use acadl_perf::acadl::text::{parse, PExpr};
use acadl_perf::acadl::{Diagram, Latency};
use acadl_perf::accel::SystolicConfig;
use acadl_perf::aidg::{
    estimate_layer, estimate_layer_batch, FixedPointConfig, LayerEstimate,
};
use acadl_perf::coordinator::{self, Arch, Pool, RooflineBackend};
use acadl_perf::dse::{explore_space, SweepOptions, SweepSpace};
use acadl_perf::engine::EstimationEngine;
use acadl_perf::ids::{OpId, RegId};
use acadl_perf::isa::LoopKernel;

/// Scalar machine with two address-disjoint memories (so a kernel can make
/// its addresses migrate between them — the partition-divergence case) and
/// an expression ALU latency (the dynamic-latency path).
fn machine(imem_read_lat: u64) -> (Diagram, Ops) {
    let mut d = Diagram::new("m");
    let (_im, ifs) = d.add_fetch("imem", imem_read_lat, 2, "ifs", 1, 4);
    let es = d.add_execute_stage("es");
    let (rf, regs) = d.add_regfile("rf", "r", 4);
    let m0 = d.add_memory("dmem0", 4, 4, 1, 2, 0, 4096);
    let m1 = d.add_memory("dmem1", 4, 4, 1, 1, 4096, 4096);
    let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load", "store"]);
    let alu = d.add_fu(es, "alu", Latency::parse("1 + imm0 % 2").unwrap(), &["mac"]);
    d.forward(ifs, es);
    d.fu_writes(lsu, rf);
    d.fu_reads(lsu, rf);
    d.fu_reads(alu, rf);
    d.fu_writes(alu, rf);
    d.mem_reads(lsu, m0);
    d.mem_writes(lsu, m0);
    d.mem_reads(lsu, m1);
    d.mem_writes(lsu, m1);
    let ops = Ops { load: d.op("load"), mac: d.op("mac"), store: d.op("store"), regs };
    d.finalize().unwrap();
    (d, ops)
}

struct Ops {
    load: OpId,
    mac: OpId,
    store: OpId,
    regs: Vec<RegId>,
}

/// A 4-instruction kernel whose addresses stride a window at `base` and
/// whose ALU immediate cycles mod `imm_mod` (lane-varying operands over the
/// digest-shared structure).
fn kernel(ops: &Ops, label: &str, k: u64, base: u64, imm_mod: u64) -> LoopKernel {
    let (load, mac, store) = (ops.load, ops.mac, ops.store);
    let (r0, r1, r2) = (ops.regs[0], ops.regs[1], ops.regs[2]);
    LoopKernel::new(
        label,
        k,
        4,
        Box::new(move |it, buf| {
            buf.instr(load).writes(&[r0]).read_mem(&[base + it % 64]).imm((it % 3) as i64);
            buf.instr(load).writes(&[r1]).read_mem(&[1024 + it % 64]);
            buf.instr(mac).reads(&[r0, r1]).writes(&[r2]).imm((it % imm_mod) as i64);
            buf.instr(store).reads(&[r2]).write_mem(&[2048 + it % 64]);
        }),
    )
}

/// Field-by-field bit-identity. `runtime` (wall clock) is the only excluded
/// field; `trace` is compared because both paths honor `keep_trace`.
fn assert_bit_identical(batched: &LayerEstimate, serial: &LayerEstimate, ctx: &str) {
    assert_eq!(batched.label, serial.label, "{ctx}: label");
    assert_eq!(batched.k, serial.k, "{ctx}: k");
    assert_eq!(batched.insts_per_iter, serial.insts_per_iter, "{ctx}: insts_per_iter");
    assert_eq!(batched.cycles, serial.cycles, "{ctx}: cycles");
    assert_eq!(batched.evaluated_iters, serial.evaluated_iters, "{ctx}: evaluated_iters");
    assert_eq!(batched.k_block, serial.k_block, "{ctx}: k_block");
    assert_eq!(batched.k_prolog, serial.k_prolog, "{ctx}: k_prolog");
    assert_eq!(batched.dt_iteration, serial.dt_iteration, "{ctx}: dt_iteration");
    assert_eq!(batched.dt_overlap, serial.dt_overlap, "{ctx}: dt_overlap");
    assert_eq!(batched.used_fallback, serial.used_fallback, "{ctx}: used_fallback");
    assert_eq!(batched.whole_graph, serial.whole_graph, "{ctx}: whole_graph");
    assert_eq!(batched.nodes, serial.nodes, "{ctx}: nodes");
    assert_eq!(
        batched.peak_state_bytes, serial.peak_state_bytes,
        "{ctx}: peak_state_bytes"
    );
    assert_eq!(batched.trace.is_some(), serial.trace.is_some(), "{ctx}: trace presence");
}

#[test]
fn batched_group_is_bit_identical_to_serial() {
    // every lane gets its *own* identically-built diagram: digest equality,
    // not pointer equality, is what admits a lane
    let builds: Vec<(Diagram, Ops)> = (0..5).map(|_| machine(1)).collect();
    let kernels: Vec<LoopKernel> = vec![
        // k=2 with kb=1 → whole graph; large k with a constant span →
        // fixed point; oscillating imm latency → stability is harder
        kernel(&builds[0].1, "whole", 2, 0, 2),
        kernel(&builds[1].1, "tiny", 13, 8, 2),
        kernel(&builds[2].1, "steady", 300, 16, 1),
        kernel(&builds[3].1, "long", 4000, 128, 2),
        kernel(&builds[4].1, "steady2", 300, 512, 5),
    ];
    let lanes: Vec<(&Diagram, &LoopKernel)> =
        builds.iter().zip(&kernels).map(|((d, _), k)| (d, k)).collect();
    let cfg = FixedPointConfig::default();
    let outcome = estimate_layer_batch(&lanes, &cfg).unwrap();
    assert_eq!(outcome.estimates.len(), 5);
    assert_eq!(outcome.evicted, 0, "digest-equal lanes must not evict");
    for (i, ((d, _), k)) in builds.iter().zip(&kernels).enumerate() {
        let serial = estimate_layer(d, k, &cfg).unwrap();
        assert_bit_identical(&outcome.estimates[i], &serial, &k.label);
    }
    // the mix covers both estimator exits at least
    assert!(outcome.estimates[0].whole_graph, "k=2 must evaluate whole");
    assert!(!outcome.estimates[3].whole_graph, "k=4000 must not evaluate whole");
}

#[test]
fn divergent_lanes_are_evicted_and_still_bit_identical() {
    let builds: Vec<(Diagram, Ops)> = (0..4).map(|_| machine(1)).collect();
    // a structurally different machine (slower instruction memory):
    // different content digest → construction-time eviction
    let (d_odd, ops_odd) = machine(3);

    let (load, store) = (builds[1].1.load, builds[1].1.store);
    let (r0, r1, r2) = (builds[1].1.regs[0], builds[1].1.regs[1], builds[1].1.regs[2]);
    // route divergence: instruction 2 is a load (lsu) instead of a mac
    // (alu) — same insts_per_iter, different route template at offset 2
    let k_route = LoopKernel::new(
        "route-mismatch",
        200,
        4,
        Box::new(move |it, buf| {
            buf.instr(load).writes(&[r0]).read_mem(&[it % 64]).imm(0);
            buf.instr(load).writes(&[r1]).read_mem(&[1024 + it % 64]);
            buf.instr(load).writes(&[r2]).read_mem(&[3000 + it % 64]);
            buf.instr(store).reads(&[r2]).write_mem(&[2048 + it % 64]);
        }),
    );
    // partition divergence: the first load's address migrates from dmem0
    // into dmem1's range at iteration 32 — after the program lowered its
    // address→memory partition from iteration 0
    let (load2, mac2, store2) = (builds[2].1.load, builds[2].1.mac, builds[2].1.store);
    let (s0, s1, s2) = (builds[2].1.regs[0], builds[2].1.regs[1], builds[2].1.regs[2]);
    let k_part = LoopKernel::new(
        "partition-migrates",
        200,
        4,
        Box::new(move |it, buf| {
            let a = if it < 32 { 100 + it % 8 } else { 5000 + it % 8 };
            buf.instr(load2).writes(&[s0]).read_mem(&[a]).imm(0);
            buf.instr(load2).writes(&[s1]).read_mem(&[1024 + it % 64]);
            buf.instr(mac2).reads(&[s0, s1]).writes(&[s2]).imm((it % 2) as i64);
            buf.instr(store2).reads(&[s2]).write_mem(&[2048 + it % 64]);
        }),
    );
    let k0 = kernel(&builds[0].1, "conforming", 200, 0, 2);
    let k_odd = kernel(&ops_odd, "digest-mismatch", 200, 64, 2);

    let lanes: Vec<(&Diagram, &LoopKernel)> = vec![
        (&builds[0].0, &k0),
        (&builds[1].0, &k_route),
        (&builds[2].0, &k_part),
        (&d_odd, &k_odd),
    ];
    let cfg = FixedPointConfig::default();
    let outcome = estimate_layer_batch(&lanes, &cfg).unwrap();
    assert_eq!(outcome.estimates.len(), 4);
    assert_eq!(
        outcome.evicted, 3,
        "route mismatch, partition migration and digest mismatch must all evict"
    );
    for (i, (d, k)) in lanes.iter().enumerate() {
        let serial = estimate_layer(d, k, &cfg).unwrap();
        assert_bit_identical(&outcome.estimates[i], &serial, &k.label);
    }
}

#[test]
fn singleton_batch_and_kept_traces_match_serial() {
    let (d, ops) = machine(1);
    let k = kernel(&ops, "solo", 500, 0, 3);
    let cfg = FixedPointConfig { keep_trace: true, ..Default::default() };
    let outcome = estimate_layer_batch(&[(&d, &k)], &cfg).unwrap();
    assert_eq!(outcome.estimates.len(), 1);
    assert_eq!(outcome.evicted, 0);
    let serial = estimate_layer(&d, &k, &cfg).unwrap();
    assert_bit_identical(&outcome.estimates[0], &serial, "solo");
    assert_eq!(
        outcome.estimates[0].trace, serial.trace,
        "kept traces must be identical iteration-for-iteration"
    );
}

#[test]
fn engine_batch_matches_sequential_engine() {
    // two digest-equal candidates plus one digest-different one: the batch
    // path must reproduce a *sequential* shared-cache schedule exactly —
    // cycles and hit/dedup accounting both
    let archs = [
        Arch::Systolic(SystolicConfig::new(2, 2)),
        Arch::Systolic(SystolicConfig::new(2, 2)),
        Arch::Systolic(SystolicConfig::new(2, 3)),
    ];
    let net = coordinator::resolve_network("tc_resnet8").unwrap();
    let fp = FixedPointConfig::default();
    let pool = Pool::new(2);

    let batch_engine = EstimationEngine::new(1 << 12);
    let refs: Vec<&Arch> = archs.iter().collect();
    let batched = batch_engine.estimate_batch(&refs, &net, &fp, &pool).unwrap();

    let seq_engine = EstimationEngine::new(1 << 12);
    let sequential: Vec<_> = archs
        .iter()
        .map(|a| seq_engine.estimate_network_pooled(a, &net, &fp, &pool).unwrap())
        .collect();

    assert_eq!(batched.len(), 3);
    for (lane, (b, s)) in batched.iter().zip(&sequential).enumerate() {
        assert_eq!(b.arch, s.arch, "lane {lane}: arch label");
        assert_eq!(b.total_cycles(), s.total_cycles(), "lane {lane}: cycles");
        assert_eq!(b.layer_cycles(), s.layer_cycles(), "lane {lane}: per-layer cycles");
        assert_eq!(b.stats.total_kernels, s.stats.total_kernels, "lane {lane}");
        assert_eq!(b.stats.unique_kernels, s.stats.unique_kernels, "lane {lane}");
        assert_eq!(b.stats.cache_hits, s.stats.cache_hits, "lane {lane}");
        assert_eq!(b.stats.deduped, s.stats.deduped, "lane {lane}");
        assert_eq!(b.stats.evaluated, s.stats.evaluated, "lane {lane}");
    }
    // the digest-equal twin must have been served from lane 0's work
    assert!(batched[1].stats.cache_hits > 0, "{:?}", batched[1].stats);
    assert_eq!(batched[1].stats.evaluated, 0, "{:?}", batched[1].stats);
}

/// `arch/plasticine_3x6.toml` with a 4-point sweep: `tile` is
/// digest-neutral, so rows×cols fixes two digest groups of two lanes each.
fn small_plasticine_space() -> SweepSpace {
    let src = std::fs::read_to_string("arch/plasticine_3x6.toml").unwrap();
    let mut desc = parse(&src).unwrap();
    let dim = |name: &str, values: &[i64]| SweepDim {
        name: Spanned::bare(name.to_string()),
        items: values.iter().map(|&v| SweepItem::Scalar(PExpr::Const(v))).collect(),
        span: Span::default(),
    };
    desc.sweep = Some(Sweep {
        dims: vec![dim("rows", &[2]), dim("cols", &[2, 4]), dim("tile", &[8, 16])],
        when: None,
        cap: None,
        span: Span::default(),
    });
    SweepSpace::from_description(desc, "batch-diff", None).unwrap()
}

#[test]
fn dse_sweep_cycles_match_with_and_without_batching() {
    let space = small_plasticine_space();
    assert_eq!(space.len_bound(), 4);
    let net = coordinator::resolve_network("tc_resnet8").unwrap();
    let pool = Pool::new(2);
    let run = |batch: bool| {
        let engine = EstimationEngine::new(1 << 12);
        explore_space(
            &space,
            &net,
            &SweepOptions { keep_frac: 1.0, batch, ..Default::default() },
            &pool,
            &RooflineBackend::Native,
            &engine,
        )
        .unwrap()
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.estimated, 4);
    assert_eq!(off.estimated, 4);
    let cycles = |o: &acadl_perf::dse::SweepOutcome| -> Vec<(String, Option<u64>)> {
        let mut v: Vec<_> =
            o.points.iter().map(|p| (p.label.clone(), p.aidg_cycles)).collect();
        v.sort();
        v
    };
    assert_eq!(cycles(&on), cycles(&off), "batching must never change results");
    assert!(on.points.iter().all(|p| p.aidg_cycles.is_some()));
}
