//! Property suites over the estimator stack: fixed-point extrapolation,
//! streaming-evaluation, mapper, and baseline invariants.

use std::sync::Arc;

use acadl_perf::accel::{
    Gemmini, GemminiConfig, Plasticine, PlasticineConfig, Systolic, SystolicConfig,
};
use acadl_perf::aidg::{estimate_layer, evaluate_whole, Evaluator, FixedPointConfig};
use acadl_perf::baselines::roofline::{roofline_cycles, LayerFeatures};
use acadl_perf::dnn::{ActKind, Layer, LayerKind};
use acadl_perf::mapping::{
    gemm_tile::GemmTileMapper, plasticine_map::PlasticineMapper, scalar::ScalarMapper, Mapper,
};
use acadl_perf::testkit::{Prop, Rng};

fn random_layer(rng: &mut Rng) -> Layer {
    match rng.range_u32(0, 5) {
        0 => Layer::new(
            "c1",
            LayerKind::Conv1d {
                c_in: rng.range_u32(1, 24),
                l_in: rng.range_u32(4, 40),
                c_out: rng.range_u32(1, 24),
                kernel: rng.range_u32(1, 5),
                stride: rng.range_u32(1, 2),
                pad: rng.bool(),
            },
        ),
        1 => Layer::new(
            "c2",
            LayerKind::Conv2d {
                c_in: rng.range_u32(1, 8),
                h: rng.range_u32(4, 12),
                w: rng.range_u32(4, 12),
                c_out: rng.range_u32(1, 12),
                kh: rng.range_u32(1, 3),
                kw: rng.range_u32(1, 3),
                stride: 1,
                pad: rng.bool(),
            },
        ),
        2 => Layer::new(
            "fc",
            LayerKind::Dense { c_in: rng.range_u32(1, 64), c_out: rng.range_u32(1, 32) },
        ),
        3 => Layer::new(
            "act",
            LayerKind::Act {
                kind: if rng.bool() { ActKind::Relu } else { ActKind::Clip },
                c: rng.range_u32(1, 32),
                spatial: rng.range_u32(1, 64),
            },
        ),
        4 => Layer::new(
            "add",
            LayerKind::Add { c: rng.range_u32(1, 32), spatial: rng.range_u32(1, 64) },
        ),
        _ => Layer::new(
            "dw",
            LayerKind::DwConv2d {
                c: rng.range_u32(1, 12),
                h: rng.range_u32(4, 10),
                w: rng.range_u32(4, 10),
                kh: 3,
                kw: 3,
                stride: 1,
                pad: true,
            },
        ),
    }
}

/// Every instruction every mapper emits must route through its diagram,
/// with the declared constant per-iteration instruction count.
#[test]
fn property_mapped_instructions_route() {
    let sys = ScalarMapper::new(Arc::new(Systolic::new(SystolicConfig::new(3, 4)).unwrap()));
    let gem = GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()));
    let pls =
        PlasticineMapper::new(Arc::new(Plasticine::new(PlasticineConfig::new(2, 3, 8)).unwrap()));
    let mappers: [&dyn Mapper; 3] = [&sys, &gem, &pls];
    Prop::new(0x11AD).cases(30).run(|rng| {
        let layer = random_layer(rng);
        for mapper in mappers {
            let Ok(ml) = mapper.map_layer(&layer) else { continue };
            for k in &ml.kernels {
                assert!(k.k >= 1, "{}: empty kernel", k.label);
                // sample iterations incl. first and last
                for it in [0, k.k / 2, k.k - 1] {
                    let mut buf = Vec::new();
                    k.emit(it, &mut buf);
                    assert_eq!(buf.len(), k.insts_per_iter, "{} iter {it}", k.label);
                    for i in &buf {
                        mapper.diagram().route(i).unwrap_or_else(|e| {
                            panic!("{} iter {it}: {e}", k.label);
                        });
                    }
                }
            }
        }
    });
}

/// Chunked streaming evaluation must be bit-identical to one pass, at
/// arbitrary chunk boundaries.
#[test]
fn property_chunked_evaluation_identical() {
    let sys = Arc::new(Systolic::new(SystolicConfig::new(2, 3)).unwrap());
    let mapper = ScalarMapper::new(sys);
    Prop::new(0xC41C).cases(20).run(|rng| {
        let layer = random_layer(rng);
        let Ok(ml) = mapper.map_layer(&layer) else { return };
        let Some(kern) = ml.kernels.last() else { return };
        let k = kern.k.min(60);
        let mut whole = Evaluator::new(mapper.diagram());
        whole.run(kern, 0..k).unwrap();
        let mut chunked = Evaluator::new(mapper.diagram());
        let mut at = 0;
        while at < k {
            let step = rng.range_u64(1, 7).min(k - at);
            chunked.run(kern, at..at + step).unwrap();
            at += step;
        }
        assert_eq!(whole.iter_stats, chunked.iter_stats, "{}", kern.label);
    });
}

/// The fixed-point estimate stays within the fallback accuracy envelope of
/// the whole-graph evaluation on random layers.
#[test]
fn property_fixed_point_accuracy_envelope() {
    let sys = Arc::new(Systolic::new(SystolicConfig::new(4, 4)).unwrap());
    let mapper = ScalarMapper::new(sys);
    Prop::new(0xF1F0).cases(20).run(|rng| {
        let layer = random_layer(rng);
        let Ok(ml) = mapper.map_layer(&layer) else { return };
        for kern in &ml.kernels {
            if kern.total_insts() > 200_000 {
                continue;
            }
            let e = estimate_layer(mapper.diagram(), kern, &FixedPointConfig::default()).unwrap();
            let w = evaluate_whole(mapper.diagram(), kern).unwrap();
            assert!(e.evaluated_iters <= w.k);
            let err = (e.cycles as f64 - w.cycles as f64).abs() / w.cycles.max(1) as f64;
            assert!(err < 0.15, "{}: {} vs {} ({err:.4})", kern.label, e.cycles, w.cycles);
            if e.whole_graph {
                assert_eq!(e.cycles, w.cycles, "{}", kern.label);
            }
        }
    });
}

/// eq. 2 linearity: doubling k adds exactly (k·stride) cycles once the
/// iteration latency stabilized.
#[test]
fn property_estimate_linear_in_k() {
    let sys = Arc::new(Systolic::new(SystolicConfig::new(2, 2)).unwrap());
    let mapper = ScalarMapper::new(sys);
    Prop::new(0x11EA).cases(12).run(|rng| {
        let c = rng.range_u32(2, 8) * 2;
        let k_out = rng.range_u32(2, 8) * 2;
        let mk = |l: u32| {
            Layer::new(
                "c",
                LayerKind::Conv1d { c_in: c, l_in: l, c_out: k_out, kernel: 3, stride: 1, pad: true },
            )
        };
        let m1 = mapper.map_layer(&mk(64)).unwrap();
        let m2 = mapper.map_layer(&mk(128)).unwrap();
        let e1 =
            estimate_layer(mapper.diagram(), &m1.kernels[1], &FixedPointConfig::default()).unwrap();
        let e2 =
            estimate_layer(mapper.diagram(), &m2.kernels[1], &FixedPointConfig::default()).unwrap();
        if e1.used_fallback || e2.used_fallback {
            return; // linearity asserted only for stabilized estimates
        }
        let stride1 = e1.dt_iteration as i64 - e1.dt_overlap;
        let extra = (m2.kernels[1].k - m1.kernels[1].k) as i64;
        assert_eq!(e2.cycles as i64 - e1.cycles as i64, extra * stride1);
    });
}

/// Roofline sanity: non-negative, monotone in port width, decreasing with
/// more parallelism.
#[test]
fn property_roofline_monotonicity() {
    Prop::new(0x800F).cases(50).run(|rng| {
        let lf = LayerFeatures {
            macs: rng.range_u64(1, 1 << 20) as f64,
            in_words: rng.range_u64(1, 1 << 14) as f64,
            w_words: rng.range_u64(1, 1 << 14) as f64,
            out_words: rng.range_u64(1, 1 << 12) as f64,
            ur_c: rng.range_u64(1, 16) as f64,
            ur_k: rng.range_u64(1, 16) as f64,
            k_iters: rng.range_u64(1, 1 << 12) as f64,
        };
        let base: [f64; 8] =
            [16.0, 16.0, 4.0, rng.range_u64(1, 8) as f64, rng.range_u64(1, 8) as f64, 1.0, 0.0, 0.0];
        let c0 = roofline_cycles(&lf, &base);
        assert!(c0 > 0.0);
        let mut wider = base;
        wider[2] = 8.0;
        assert!(roofline_cycles(&lf, &wider) <= c0, "wider port must not slow down");
        let more_ur = LayerFeatures { ur_c: lf.ur_c * 2.0, ur_k: lf.ur_k, ..lf };
        assert!(roofline_cycles(&more_ur, &base) <= c0, "more parallelism must not slow down");
    });
}
