//! Cross-validation: the streaming AIDG sweep must agree with the
//! independent cycle-accurate DES — on the paper's architectures and on
//! randomized machines/kernels (the repo's central accuracy property).

use std::sync::Arc;

use acadl_perf::accel::{
    Gemmini, GemminiConfig, Plasticine, PlasticineConfig, Systolic, SystolicConfig, UltraTrail,
    UltraTrailConfig,
};
use acadl_perf::acadl::{Diagram, Latency};
use acadl_perf::aidg::{estimate_layer, estimate_layer_batch, evaluate_whole, FixedPointConfig};
use acadl_perf::coordinator::{Arch, DescribedArch};
use acadl_perf::dnn::zoo;
use acadl_perf::isa::{Instruction, LoopKernel};
use acadl_perf::mapping::{
    gemm_tile::GemmTileMapper, plasticine_map::PlasticineMapper, scalar::ScalarMapper,
    tensor_op::TensorOpMapper, Mapper,
};
use acadl_perf::sim::simulate;
use acadl_perf::testkit::{Prop, Rng};

/// AIDG whole-graph vs DES per layer (and for the network total, at half
/// the layer tolerance) on a network/mapper pair.
fn assert_layers_agree(mapper: &(impl Mapper + ?Sized), net: &acadl_perf::dnn::Network, tol: f64) {
    let mapped = mapper.map_network(net).unwrap();
    let mut aidg_total = 0u64;
    let mut des_total = 0u64;
    for ml in &mapped {
        if ml.fused {
            continue;
        }
        let mut aidg = 0u64;
        let mut des = 0u64;
        let mut skipped = false;
        for k in &ml.kernels {
            // cap DES cost: skip layers with huge instruction totals
            if k.total_insts() > 400_000 {
                skipped = true;
                break;
            }
            aidg += evaluate_whole(mapper.diagram(), k).unwrap().cycles;
            des += simulate(mapper.diagram(), k, 0..k.k).unwrap().cycles;
        }
        if skipped {
            continue;
        }
        let err = (aidg as f64 - des as f64).abs() / des.max(1) as f64;
        assert!(err <= tol, "{}: AIDG {aidg} vs DES {des} (err {err:.4})", ml.layer_name);
        aidg_total += aidg;
        des_total += des;
    }
    let total_err = (aidg_total as f64 - des_total as f64).abs() / des_total.max(1) as f64;
    assert!(
        total_err <= tol / 2.0,
        "network total: AIDG {aidg_total} vs DES {des_total} (err {total_err:.4})"
    );
}

#[test]
fn systolic_2x2_exact() {
    let sys = Arc::new(Systolic::new(SystolicConfig::new(2, 2)).unwrap());
    assert_layers_agree(&ScalarMapper::new(sys), &zoo::tc_resnet8(), 0.0);
}

#[test]
fn systolic_4x4_exact() {
    let sys = Arc::new(Systolic::new(SystolicConfig::new(4, 4)).unwrap());
    assert_layers_agree(&ScalarMapper::new(sys), &zoo::tc_resnet8(), 0.0);
}

#[test]
fn systolic_non_divisible_exact() {
    // the Fig. 13b underutilized mapping
    let sys = Arc::new(Systolic::new(SystolicConfig::new(12, 12)).unwrap());
    let net = acadl_perf::dnn::Network {
        name: "nondiv".into(),
        layers: vec![acadl_perf::dnn::Layer::new(
            "c",
            acadl_perf::dnn::LayerKind::Conv1d {
                c_in: 20,
                l_in: 12,
                c_out: 70,
                kernel: 3,
                stride: 1,
                pad: true,
            },
        )],
    };
    assert_layers_agree(&ScalarMapper::new(sys), &net, 0.0);
}

#[test]
fn ultratrail_exact() {
    let ut = Arc::new(UltraTrail::new(UltraTrailConfig::default()).unwrap());
    assert_layers_agree(&TensorOpMapper::new(ut), &zoo::tc_resnet8(), 0.0);
}

#[test]
fn gemmini_close() {
    let g = Arc::new(Gemmini::new(GemminiConfig::default()).unwrap());
    // decoupled access-execute with out-of-order slot reuse: the analytical
    // sweep and the physical machine diverge per layer about as much as the
    // paper's AIDG diverged from Verilator (3.7–9.8% MAPE); the network
    // total stays within ~10%
    assert_layers_agree(&GemmTileMapper::new(g), &zoo::tc_resnet8(), 0.22);
}

#[test]
fn plasticine_close() {
    let p = Arc::new(Plasticine::new(PlasticineConfig::new(2, 3, 8)).unwrap());
    assert_layers_agree(&PlasticineMapper::new(p), &zoo::tc_resnet8(), 0.02);
}

#[test]
fn fixed_point_matches_whole_graph_on_every_arch() {
    // §6.3's headline: the extrapolated estimate tracks the full evaluation
    let net = zoo::tc_resnet8();
    let fp = FixedPointConfig::default();
    let mappers: Vec<Box<dyn Mapper>> = vec![
        Box::new(ScalarMapper::new(Arc::new(Systolic::new(SystolicConfig::new(4, 4)).unwrap()))),
        Box::new(GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()))),
        Box::new(PlasticineMapper::new(
            Arc::new(Plasticine::new(PlasticineConfig::new(2, 3, 8)).unwrap()),
        )),
    ];
    for mapper in &mappers {
        let mapped = mapper.map_network(&net).unwrap();
        for ml in mapped.iter().filter(|m| !m.fused) {
            for k in &ml.kernels {
                let est = estimate_layer(mapper.diagram(), k, &fp).unwrap();
                let whole = evaluate_whole(mapper.diagram(), k).unwrap();
                let err =
                    (est.cycles as f64 - whole.cycles as f64).abs() / whole.cycles.max(1) as f64;
                assert!(
                    err < 0.12,
                    "{} on {}: fp {} vs whole {} ({:.2}%)",
                    k.label,
                    mapper.diagram().name,
                    est.cycles,
                    whole.cycles,
                    err * 100.0
                );
            }
        }
    }
}

#[test]
fn described_archs_agree_with_des() {
    // the four shipped arch/*.toml descriptions must satisfy the same
    // differential as the builder architectures they describe — the textual
    // frontend is not allowed to drift from the DES
    let net = zoo::tc_resnet8();
    for (file, tol) in [
        ("arch/systolic_16x16.toml", 0.0),
        ("arch/ultratrail_8x8.toml", 0.0),
        ("arch/gemmini_16.toml", 0.25),
        ("arch/plasticine_3x6.toml", 0.06),
    ] {
        let mapper = Arch::Described(DescribedArch::file(file))
            .mapper()
            .unwrap_or_else(|e| panic!("{file}: {e:#}"));
        assert_layers_agree(mapper.as_ref(), &net, tol);
    }
}

#[test]
fn batch_evaluator_matches_des_and_serial() {
    // PR-7's lane-batched evaluator must stay inside the same differential:
    // every lane of a same-kernel batch is bitwise-identical to the serial
    // estimate, and whole-graph lanes match the DES exactly
    let fp = FixedPointConfig::default();
    let net = zoo::tc_resnet8();
    let mappers: Vec<Box<dyn Mapper>> = vec![
        Box::new(ScalarMapper::new(Arc::new(Systolic::new(SystolicConfig::new(2, 2)).unwrap()))),
        Box::new(TensorOpMapper::new(
            Arc::new(UltraTrail::new(UltraTrailConfig::default()).unwrap()),
        )),
    ];
    for mapper in &mappers {
        let d = mapper.diagram();
        for ml in mapper.map_network(&net).unwrap().iter().filter(|m| !m.fused) {
            for k in &ml.kernels {
                if k.total_insts() > 400_000 {
                    continue;
                }
                let serial = estimate_layer(d, k, &fp).unwrap();
                let lanes = vec![(d, k), (d, k), (d, k)];
                let batch = estimate_layer_batch(&lanes, &fp).unwrap();
                assert_eq!(batch.estimates.len(), 3);
                for (lane, e) in batch.estimates.iter().enumerate() {
                    assert_eq!(
                        (e.cycles, e.evaluated_iters, e.k_block, e.dt_iteration, e.dt_overlap),
                        (
                            serial.cycles,
                            serial.evaluated_iters,
                            serial.k_block,
                            serial.dt_iteration,
                            serial.dt_overlap
                        ),
                        "{} on {}: batch lane {lane} diverged from serial",
                        k.label,
                        d.name
                    );
                    assert_eq!((e.whole_graph, e.used_fallback),
                        (serial.whole_graph, serial.used_fallback));
                }
                if serial.whole_graph {
                    let des = simulate(d, k, 0..k.k).unwrap().cycles;
                    assert_eq!(
                        batch.estimates[0].cycles, des,
                        "{} on {}: whole-graph batch lane vs DES",
                        k.label, d.name
                    );
                }
            }
        }
    }
}

/// Randomized machines + kernels: AIDG == DES bit-exactly.
#[test]
fn property_random_machines_agree() {
    Prop::new(0xACAD1).cases(40).run(|rng: &mut Rng| {
        // random scalar machine
        let mut d = Diagram::new("rand");
        let p = rng.range_u32(1, 3);
        let ib = rng.range_u32(1, 4).max(p);
        let (_im, ifs) = d.add_fetch("imem", 1, p, "ifs", 1, ib);
        let n_fu = rng.range_usize(1, 3);
        let (rf, regs) = d.add_regfile("rf", "r", 6);
        let mem = d.add_memory(
            "m",
            rng.range_u64(1, 4),
            rng.range_u64(1, 4),
            rng.range_u32(1, 2),
            rng.range_u32(1, 2),
            0,
            1 << 20,
        );
        let mut fus = Vec::new();
        for i in 0..n_fu {
            let es = d.add_execute_stage(&format!("es{i}"));
            let fu = d.add_fu(
                es,
                &format!("fu{i}"),
                Latency::Fixed(rng.range_u64(1, 3)),
                &[&format!("op{i}"), &format!("ld{i}"), &format!("st{i}")],
            );
            d.forward(ifs, es);
            d.fu_reads(fu, rf);
            d.fu_writes(fu, rf);
            d.mem_reads(fu, mem);
            d.mem_writes(fu, mem);
            fus.push(i);
        }
        let ops: Vec<_> = (0..n_fu)
            .flat_map(|i| {
                [
                    d.op(&format!("op{i}")),
                    d.op(&format!("ld{i}")),
                    d.op(&format!("st{i}")),
                ]
            })
            .collect();
        d.finalize().unwrap();

        // random kernel: 2..6 instructions over the ops
        let n_instr = rng.range_usize(2, 6);
        let mut protos = Vec::new();
        for _ in 0..n_instr {
            let op = *rng.pick(&ops);
            let r1 = regs[rng.range_usize(0, regs.len() - 1)];
            let r2 = regs[rng.range_usize(0, regs.len() - 1)];
            let mode = rng.range_u32(0, 2);
            protos.push((op, r1, r2, mode));
        }
        let k = rng.range_u64(3, 40);
        let kernel = LoopKernel::new(
            "rand",
            k,
            n_instr,
            Box::new(move |it, buf| {
                for (i, &(op, r1, r2, mode)) in protos.iter().enumerate() {
                    let mut instr = Instruction::new(op);
                    match mode {
                        0 => instr = instr.reads(&[r1]).writes(&[r2]),
                        1 => instr = instr.writes(&[r1]).read_mem(&[it * 8 + i as u64]),
                        _ => {
                            instr =
                                instr.reads(&[r1]).write_mem(&[4096 + it * 8 + i as u64])
                        }
                    }
                    buf.push(instr);
                }
            }),
        );
        let aidg = evaluate_whole(&d, &kernel).unwrap().cycles;
        let des = simulate(&d, &kernel, 0..k).unwrap().cycles;
        assert_eq!(aidg, des, "machine {d:?}");
    });
}
