//! End-to-end validation: the paper's headline claims reproduced on real
//! (small) workloads through the full stack — mapper → AIDG fixed point →
//! coordinator → (XLA runtime where artifacts exist).

use acadl_perf::accel::{SystolicConfig, UltraTrailConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{
    explore, parse_arch, run_request, serve, Arch, DseSpec, EstimateRequest, Pool,
    RooflineBackend,
};
use acadl_perf::dnn::zoo;
use acadl_perf::expt::systolic_sweep_point;

/// Paper §7.3 headline: a tiny evaluated fraction reproduces the
/// whole-graph result exactly on the 2×2 systolic array.
#[test]
fn headline_iteration_reduction() {
    let net = zoo::tc_resnet8();
    let p = systolic_sweep_point(2, 2, &net, false).unwrap();
    assert_eq!(p.total_est(), p.total_whole(), "fixed point == whole graph");
    let frac = p.evaluated_iters() as f64 / p.total_iters() as f64;
    assert!(frac < 0.001, "evaluated fraction {frac}");
    assert!(p.total_insts() > 3_000_000);
    // the estimation runtime beats the whole-graph evaluation by orders of
    // magnitude
    assert!(p.whole_runtime > 20 * p.fp_runtime, "{:?} vs {:?}", p.whole_runtime, p.fp_runtime);
}

/// Estimation must be deterministic across runs and across the worker pool.
#[test]
fn estimation_is_deterministic() {
    let req = EstimateRequest {
        arch: Arch::Systolic(SystolicConfig::new(4, 4)),
        network: "tc_resnet8".into(),
        fp: FixedPointConfig::default(),
    };
    let a = run_request(&req).unwrap().total_cycles();
    // independent fresh engines on pool workers: every request genuinely
    // re-evaluates on its own thread (the typed `run_all` path would be
    // served from the global engine's cache, proving nothing about
    // concurrent evaluation determinism)
    let pool = Pool::new(4);
    let (tx, rx) = std::sync::mpsc::channel();
    for _ in 0..3 {
        let req = req.clone();
        let tx = tx.clone();
        pool.spawn(move || {
            let net = acadl_perf::dnn::zoo::by_name(&req.network).unwrap();
            let e = acadl_perf::engine::EstimationEngine::new(64)
                .estimate_network(&req.arch, &net, &req.fp)
                .unwrap();
            tx.send(e.total_cycles()).unwrap();
        })
        .unwrap();
    }
    drop(tx);
    let cycles: Vec<u64> = rx.iter().collect();
    assert_eq!(cycles.len(), 3);
    for c in cycles {
        assert_eq!(c, a);
    }
    // the typed request path (global engine, possibly cached) agrees too
    let pooled = pool.run_all(vec![req]).pop().unwrap().unwrap();
    assert_eq!(pooled.total_cycles(), a);
}

/// Full DSE loop over the Plasticine grid with the auto backend (XLA when
/// artifacts are built, native mirror otherwise).
#[test]
fn dse_end_to_end() {
    let spec = DseSpec {
        rows: vec![2, 3],
        cols: vec![2, 4],
        tiles: vec![8, 16],
        network: "tc_resnet8".into(),
        keep_frac: 1.0,
        fp: FixedPointConfig::default(),
    };
    let pool = Pool::new(0);
    let backend = RooflineBackend::auto();
    let points = explore(&spec, &pool, &backend).unwrap();
    assert_eq!(points.len(), 8);
    assert!(points.iter().all(|p| p.aidg_cycles.is_some() && p.roofline_cycles > 0.0));
    // AIDG ranking is sorted
    let c: Vec<u64> = points.iter().filter_map(|p| p.aidg_cycles).collect();
    assert!(c.windows(2).all(|w| w[0] <= w[1]));
}

/// XLA batched roofline == native mirror over a mapped network (skipped
/// when artifacts are missing).
#[test]
fn xla_roofline_matches_native_on_network() {
    use acadl_perf::baselines::roofline::{roofline_cycles, LayerFeatures};
    use acadl_perf::mapping::Mapper;
    if !acadl_perf::runtime::artifacts_dir().join("roofline.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let exec = acadl_perf::runtime::RooflineExec::load().unwrap();
    let arch = Arch::Systolic(SystolicConfig::new(8, 8));
    let mapper = arch.mapper().unwrap();
    let net = zoo::efficientnet_reduced();
    let mapped = mapper.map_network(&net).unwrap();
    let feats: Vec<LayerFeatures> = net
        .layers
        .iter()
        .zip(&mapped)
        .filter(|(_, m)| !m.fused)
        .map(|(l, m)| LayerFeatures::from_mapping(l, m))
        .collect();
    let hw = mapper.hw_features();
    let xla = exec.estimate(&feats, &hw).unwrap();
    for (f, x) in feats.iter().zip(&xla) {
        let native = roofline_cycles(f, &hw);
        assert!((x - native).abs() < 1e-6, "{x} vs {native}");
    }
}

/// The request server round-trips estimates for every architecture family.
#[test]
fn serve_all_architectures() {
    let input = "estimate systolic:2x2 tc_resnet8\n\
                 estimate ultratrail tc_resnet8\n\
                 estimate gemmini:16 tc_resnet8\n\
                 estimate plasticine:2x3:8 tc_resnet8\nquit\n";
    let mut out = Vec::new();
    let n = serve(std::io::Cursor::new(input), &mut out).unwrap();
    assert_eq!(n, 4);
    let text = String::from_utf8(out).unwrap();
    for line in text.lines() {
        assert!(line.contains("cycles="), "{line}");
    }
}

/// UltraTrail matches the analytical model's scale (paper Table 1 magnitude).
#[test]
fn ultratrail_latency_scale() {
    let e = run_request(&EstimateRequest {
        arch: Arch::UltraTrail(UltraTrailConfig::default()),
        network: "tc_resnet8".into(),
        fp: FixedPointConfig::default(),
    })
    .unwrap();
    // paper: 22 484 cycles with the original CONV-EXT constants; our
    // analytic mirror lands in the same scale
    let c = e.total_cycles();
    assert!((15_000..40_000).contains(&c), "cycles {c}");
}

/// Architecture spec grammar accepted by the CLI.
#[test]
fn arch_specs_cover_the_paper() {
    for s in ["systolic:16x16", "systolic:12x12:pw7", "ultratrail:8", "gemmini:16", "plasticine:3x6:16"] {
        parse_arch(s).unwrap();
    }
}
