//! Seed stability of the testkit generator family: the same seed must
//! produce the byte-identical generated case on every run. The calibration
//! corpus ([`acadl_perf::calib::sample`]), the property tests, and CI's
//! accuracy gate all assume this — a generator that silently consumed
//! entropy differently across runs would turn every pinned threshold into
//! a flake.

use acadl_perf::testkit::{
    arbitrary_description, arbitrary_net_description, arbitrary_pexpr, arbitrary_template,
    Prop, Rng,
};

const SEEDS: [u64; 4] = [1, 0xACAD1, 0xDEADBEEF, u64::MAX];

#[test]
fn rng_streams_are_seed_deterministic() {
    for seed in SEEDS {
        let mut a = Rng::new(seed);
        let mut b = Rng::new(seed);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64(), "seed {seed:#x}");
        }
        // the derived draws consume the same entropy in the same order
        for _ in 0..100 {
            assert_eq!(a.range_u64(3, 4000), b.range_u64(3, 4000));
            assert_eq!(a.bool(), b.bool());
            assert_eq!(a.f64(), b.f64());
        }
    }
}

#[test]
fn rng_seeds_actually_differ() {
    let mut a = Rng::new(1);
    let mut b = Rng::new(2);
    let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
    assert_eq!(same, 0, "distinct seeds must give distinct streams");
}

#[test]
fn arch_generator_is_byte_stable_per_seed() {
    for seed in SEEDS {
        let first = arbitrary_description(&mut Rng::new(seed)).to_toml();
        let second = arbitrary_description(&mut Rng::new(seed)).to_toml();
        assert_eq!(first, second, "seed {seed:#x}");
        assert!(!first.is_empty());
    }
    // and the sub-generators, compared structurally
    for seed in SEEDS {
        assert_eq!(
            format!("{:?}", arbitrary_pexpr(&mut Rng::new(seed), 3, true)),
            format!("{:?}", arbitrary_pexpr(&mut Rng::new(seed), 3, true)),
        );
        assert_eq!(
            format!("{:?}", arbitrary_template(&mut Rng::new(seed))),
            format!("{:?}", arbitrary_template(&mut Rng::new(seed))),
        );
    }
}

#[test]
fn net_generator_is_byte_stable_per_seed() {
    for seed in SEEDS {
        let first = arbitrary_net_description(&mut Rng::new(seed)).to_toml();
        let second = arbitrary_net_description(&mut Rng::new(seed)).to_toml();
        assert_eq!(first, second, "seed {seed:#x}");
        assert!(!first.is_empty());
    }
}

#[test]
fn prop_replays_the_same_cases() {
    let record = |seed: u64| -> Vec<u64> {
        let mut draws = Vec::new();
        Prop::new(seed).cases(25).run(|rng: &mut Rng| {
            draws.push(rng.next_u64());
        });
        draws
    };
    for seed in SEEDS {
        let a = record(seed);
        let b = record(seed);
        assert_eq!(a.len(), 25);
        assert_eq!(a, b, "seed {seed:#x}");
    }
}
