//! Round-trip fidelity of the textual ACADL frontend: each shipped
//! `arch/*.toml` description must compile to a diagram whose fixed-point
//! AIDG estimates are **cycle-identical** to the hand-built `accel::*`
//! builder on a paper workload, the registry cache must skip recompilation
//! on unchanged content, and the validator must report the documented error
//! classes with file/line spans.

use acadl_perf::acadl::text::{check_source, ArchRegistry, Severity};
use acadl_perf::accel::{GemminiConfig, PlasticineConfig, SystolicConfig, UltraTrailConfig};
use acadl_perf::aidg::FixedPointConfig;
use acadl_perf::coordinator::{estimate_network, serve, Arch, DescribedArch};
use acadl_perf::dnn::zoo;

/// Estimate `network` on both the description-compiled and the hand-built
/// architecture and require identical results, layer by layer.
fn assert_cycle_identical(file: &str, hand: Arch, network: &str) {
    let net = zoo::by_name(network).expect("workload in zoo");
    let fp = FixedPointConfig::default();

    let described = Arch::Described(DescribedArch::file(file));
    let dm = described.mapper().unwrap_or_else(|e| panic!("compiling {file}: {e:#}"));
    let hm = hand.mapper().unwrap();

    let de = estimate_network(dm.as_ref(), &net, &fp).unwrap();
    let he = estimate_network(hm.as_ref(), &net, &fp).unwrap();

    assert_eq!(de.arch, he.arch, "{file}: architecture names differ");
    assert_eq!(
        de.layer_cycles(),
        he.layer_cycles(),
        "{file}: per-layer cycles differ from the hand-built builder"
    );
    assert_eq!(de.total_cycles(), he.total_cycles(), "{file}: total cycles differ");
    assert_eq!(
        de.evaluated_iters(),
        he.evaluated_iters(),
        "{file}: fixed-point evaluation took a different path"
    );
    assert_eq!(de.total_iters(), he.total_iters());
}

#[test]
fn systolic_description_matches_builder() {
    assert_cycle_identical(
        "arch/systolic_16x16.toml",
        Arch::Systolic(SystolicConfig::new(16, 16)),
        "tc_resnet8",
    );
}

#[test]
fn ultratrail_description_matches_builder() {
    assert_cycle_identical(
        "arch/ultratrail_8x8.toml",
        Arch::UltraTrail(UltraTrailConfig::default()),
        "tc_resnet8",
    );
}

#[test]
fn gemmini_description_matches_builder() {
    assert_cycle_identical(
        "arch/gemmini_16.toml",
        Arch::Gemmini(GemminiConfig::default()),
        "tc_resnet8",
    );
}

#[test]
fn plasticine_description_matches_builder() {
    assert_cycle_identical(
        "arch/plasticine_3x6.toml",
        Arch::Plasticine(PlasticineConfig::new(3, 6, 16)),
        "tc_resnet8",
    );
}

#[test]
fn shipped_descriptions_validate_cleanly() {
    for file in [
        "arch/systolic_16x16.toml",
        "arch/ultratrail_8x8.toml",
        "arch/gemmini_16.toml",
        "arch/plasticine_3x6.toml",
    ] {
        let src = std::fs::read_to_string(file).unwrap();
        let (flat, diags) = check_source(&src);
        assert!(flat.is_some(), "{file} did not parse");
        let errors: Vec<_> = diags.iter().filter(|d| d.is_error()).collect();
        assert!(errors.is_empty(), "{file}: {errors:?}");
    }
}

#[test]
fn registry_cache_hit_skips_recompilation() {
    let src = std::fs::read_to_string("arch/ultratrail_8x8.toml").unwrap();
    let reg = ArchRegistry::new();

    let a = reg.get_or_compile(&src, "ultratrail").unwrap();
    assert_eq!(reg.compile_count(), 1);
    assert_eq!(reg.len(), 1);

    // identical content: cache hit, no recompilation, same shared model
    let b = reg.get_or_compile(&src, "ultratrail").unwrap();
    assert_eq!(reg.compile_count(), 1, "cache hit must not recompile");
    assert!(std::sync::Arc::ptr_eq(&a, &b), "cache hit must return the shared model");

    // changed content (even just a comment) is a different architecture key
    let changed = format!("{src}\n# tweaked\n");
    reg.get_or_compile(&changed, "ultratrail").unwrap();
    assert_eq!(reg.compile_count(), 2);
    assert_eq!(reg.len(), 2);
}

#[test]
fn described_estimates_flow_through_the_server() {
    let src = std::fs::read_to_string("arch/ultratrail_8x8.toml").unwrap();
    let input = format!("describe ut\n{src}end\nestimate @ut tc_resnet8\nquit\n");
    let mut out = Vec::new();
    let served = serve(std::io::Cursor::new(input), &mut out).unwrap();
    assert_eq!(served, 2);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "described @ut");
    assert!(
        lines[1].starts_with("ultratrail8x8 tc_resnet8 cycles="),
        "unexpected server reply: {}",
        lines[1]
    );
}

#[test]
fn check_reports_spanned_errors_for_broken_descriptions() {
    let src = std::fs::read_to_string("arch/ultratrail_8x8.toml").unwrap();
    // break it three ways: an op outside [isa], a dangling edge target, and
    // a containment cycle via explicit [[contains]] edges
    let broken = format!(
        "{src}\n[[mem_read]]\nfu = \"macArrayAndOPU\"\nmem = \"ghost_mem\"\n\n\
         [[execute_stage]]\nname = \"esA\"\n\n[[execute_stage]]\nname = \"esB\"\n\n\
         [[contains]]\nparent = \"esA\"\nchild = \"esB\"\n\n\
         [[contains]]\nparent = \"esB\"\nchild = \"esA\"\n"
    );
    let broken = broken.replace("ops = [\"add_ext\"]", "ops = [\"warp_ext\"]");
    let (_, diags) = check_source(&broken);
    let errors: Vec<String> = diags
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .map(|d| d.render("arch.toml"))
        .collect();
    assert!(
        errors.iter().any(|e| e.contains("unknown op `warp_ext`")),
        "missing unknown-op error: {errors:?}"
    );
    assert!(
        errors.iter().any(|e| e.contains("dangling route: no object named `ghost_mem`")),
        "missing dangling-route error: {errors:?}"
    );
    assert!(
        errors.iter().any(|e| e.contains("containment cycle")),
        "missing containment-cycle error: {errors:?}"
    );
    // every rendered diagnostic carries file:line:col
    for e in &errors {
        let rest = e.strip_prefix("arch.toml:").unwrap_or_else(|| panic!("no origin in {e}"));
        let mut parts = rest.splitn(3, ':');
        let line: u32 = parts.next().unwrap().parse().unwrap();
        let _col: u32 = parts.next().unwrap().parse().unwrap();
        assert!(line >= 1, "bad line in {e}");
    }
}
