//! Cycle-accurate discrete-event simulation over ACADL diagrams — the
//! repo's ground-truth substitute for the paper's RTL simulators (see
//! DESIGN.md §3 substitution table).

pub mod cycle;

pub use cycle::{simulate, simulate_layer, CycleSim, SimResult};
