//! Cycle-accurate discrete-event simulation of ACADL object diagrams — the
//! in-repo stand-in for the paper's Verilator / Cadence Xcelium RTL ground
//! truth (see DESIGN.md §3).
//!
//! The simulator executes the *same* instruction streams on the *same*
//! diagrams as the AIDG estimator, but as an actual time-stepped machine:
//! instructions are tokens that occupy objects, objects hold live occupancy
//! counts, and hazards are resolved through a ticket scoreboard that
//! serializes accesses to each register and memory address in program order
//! (the reorder-buffer/interlock behavior real hardware implements).
//! Nothing is extrapolated — every instruction is executed and every stall
//! cycle stepped. Agreement between this machine and the analytical AIDG
//! sweep is the repo's accuracy check; the runtime gap between them
//! reproduces the paper's estimator-vs-RTL-simulation gap.
//!
//! Semantics per the paper (§4.1, Algorithm 1):
//! - one instruction-memory transaction at a time, `port_width` instructions
//!   each, the next transaction starting once the previous group has been
//!   forwarded into the issue buffer (fetch backpressure);
//! - at most `issue_buffer_size` instructions forwarded from fetch and
//!   entering the fetch stage per cycle;
//! - an instruction resides `latency` cycles in each pipeline stage /
//!   functional unit after its data dependencies resolve, and continues to
//!   occupy the module until the next module in its route has capacity;
//! - register and memory accesses serialize in program order: a module
//!   starts processing only after the previous accessor of every register /
//!   address the instruction touches has moved on (RAW/WAR/WAW/RAR, the
//!   "last node that accessed" semantics of §6.1).

use std::collections::HashMap;


use anyhow::{bail, Context};

use crate::acadl::{Diagram, ObjectKind};
use crate::ids::{Addr, Cycle, ObjId, RegId};
use crate::isa::{EmitBuf, Instruction, LoopKernel};
use crate::Result;

static TRACE: once_cell::sync::Lazy<bool> =
    once_cell::sync::Lazy::new(|| std::env::var_os("ACADL_TRACE").is_some());
static TRACE_NODES: once_cell::sync::Lazy<bool> =
    once_cell::sync::Lazy::new(|| std::env::var_os("ACADL_TRACE_NODES").is_some());

/// Result of one simulation run.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    /// End-to-end latency: last token's leave time minus first fetch start.
    pub cycles: Cycle,
    /// Instructions executed.
    pub instructions: u64,
    /// Distinct simulation times visited (diagnostic).
    pub ticks: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tag {
    Fu,
    Stage,
    ReadMem,
    WriteBack,
    WriteMem,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum TState {
    /// Fetched at `ready`, awaiting a forward slot out of the fetch group.
    AwaitForward { ready: Cycle },
    /// Forwarded at `ready`, awaiting an issue-buffer entry slot.
    AwaitIssue { ready: Cycle },
    /// Residing in the fetch stage until `finish`.
    Ifs { finish: Cycle },
    /// Fetch-stage residency over, waiting for the first route object.
    IfsStalled,
    /// Occupying tail node `idx`; `finish` is None while the scoreboard
    /// still blocks the node's data dependencies.
    Node { idx: usize, finish: Option<Cycle> },
    /// Done in node `idx`, waiting for node `idx + 1` to have capacity.
    NodeStalled { idx: usize },
    Done,
}

/// Program-order access serialization for one resource (register/address):
/// accesses take tickets at token creation; an access may observe the
/// resource once all earlier tickets are served.
#[derive(Debug, Clone, Copy, Default)]
struct ResState {
    next_ticket: u64,
    served: u64,
    last_leave: Cycle,
}

impl ResState {
    #[inline]
    fn take(&mut self) -> u64 {
        let t = self.next_ticket;
        self.next_ticket += 1;
        t
    }

    /// Predecessors all served?
    #[inline]
    fn ready(&self, ticket: u64) -> bool {
        self.served >= ticket
    }

    #[inline]
    fn serve(&mut self, t: Cycle) {
        self.served += 1;
        self.last_leave = self.last_leave.max(t);
    }
}

struct Token {
    instr: Instruction,
    tail: Vec<(ObjId, Tag)>,
    /// Unique registers the instruction accesses, with their tickets and
    /// whether the access is served at the WriteBack node (written regs of
    /// memory-reading instructions) or the FU node.
    reg_tickets: Vec<(RegId, u64, bool)>,
    /// (addr, ticket) per read address, served at its ReadMem node.
    raddr_tickets: Vec<(Addr, u64)>,
    /// (addr, ticket) per write address, served at its WriteMem node.
    waddr_tickets: Vec<(Addr, u64)>,
}

/// The simulation machine over one diagram.
pub struct CycleSim<'d> {
    d: &'d Diagram,
    /// Live occupancy per lock owner.
    occupancy: Vec<u32>,
    reg_res: Vec<ResState>,
    addr_res: HashMap<Addr, ResState>,
    now: Cycle,
    next_fetch_start: Cycle,
    /// Group instructions not yet forwarded (backpressures fetch).
    group_pending: usize,
    /// Per-cycle forward/enter counters (reset when time advances).
    fwd_count: u32,
    enter_count: u32,
    max_leave: Cycle,
    ticks: u64,
    instructions: u64,
}

impl<'d> CycleSim<'d> {
    /// A fresh simulator over `d`.
    pub fn new(d: &'d Diagram) -> Self {
        Self {
            d,
            occupancy: vec![0; d.num_objects()],
            reg_res: vec![ResState::default(); d.num_regs()],
            addr_res: HashMap::new(),
            now: 0,
            next_fetch_start: 0,
            group_pending: 0,
            fwd_count: 0,
            enter_count: 0,
            max_leave: 0,
            ticks: 0,
            instructions: 0,
        }
    }

    /// Route + take scoreboard tickets (program order = creation order).
    fn make_token(&mut self, instr: Instruction) -> Result<Token> {
        let route = self.d.route(&instr)?;
        let wb = self.d.writeback_obj();
        let mut tail = Vec::with_capacity(route.tail_len());
        for &s in &route.stages {
            tail.push((s, Tag::Stage));
        }
        tail.push((route.fu, Tag::Fu));
        for &m in &route.read_mems {
            tail.push((m, Tag::ReadMem));
        }
        if route.has_writeback {
            tail.push((wb, Tag::WriteBack));
        }
        for &m in &route.write_mems {
            tail.push((m, Tag::WriteMem));
        }

        // one ticket per unique register; written regs of memory-reading
        // instructions are served at the writeBack node
        let mut reg_tickets: Vec<(RegId, u64, bool)> = Vec::new();
        for r in instr.read_regs.iter().chain(instr.write_regs.iter()) {
            if !reg_tickets.iter().any(|&(rr, _, _)| rr == *r) {
                let at_wb = route.has_writeback && instr.write_regs.contains(r);
                let ticket = self.reg_res[r.0 as usize].take();
                reg_tickets.push((*r, ticket, at_wb));
            }
        }
        let mut raddr_tickets = Vec::with_capacity(instr.read_addrs.len());
        for &a in &instr.read_addrs {
            raddr_tickets.push((a, self.addr_res.entry(a).or_default().take()));
        }
        let mut waddr_tickets = Vec::with_capacity(instr.write_addrs.len());
        for &a in &instr.write_addrs {
            waddr_tickets.push((a, self.addr_res.entry(a).or_default().take()));
        }

        Ok(Token { instr, tail, reg_tickets, raddr_tickets, waddr_tickets })
    }

    #[inline]
    fn has_capacity(&self, obj: ObjId) -> bool {
        let lock = self.d.lock(obj);
        lock.capacity == u32::MAX || self.occupancy[lock.owner.idx()] < lock.capacity
    }

    #[inline]
    fn occupy(&mut self, obj: ObjId) {
        let lock = self.d.lock(obj);
        if lock.capacity != u32::MAX {
            self.occupancy[lock.owner.idx()] += 1;
        }
    }

    #[inline]
    fn release_obj(&mut self, obj: ObjId) {
        let lock = self.d.lock(obj);
        if lock.capacity != u32::MAX {
            self.occupancy[lock.owner.idx()] -= 1;
        }
    }

    /// Scoreboard gate + dependency time + latency for tail node `idx`.
    /// Returns None while a predecessor access is still pending.
    fn node_ready(&self, tok: &Token, idx: usize) -> Option<(Cycle, Cycle)> {
        let (obj, tag) = tok.tail[idx];
        let instr = &tok.instr;
        let mut deps = 0;
        let lat = match tag {
            Tag::Stage => match &self.d.object(obj).kind {
                ObjectKind::PipelineStage { latency } => latency.eval(instr),
                _ => 0,
            },
            Tag::Fu => {
                for &(r, ticket, _) in &tok.reg_tickets {
                    let st = &self.reg_res[r.0 as usize];
                    if !st.ready(ticket) {
                        return None;
                    }
                    deps = deps.max(st.last_leave);
                }
                match &self.d.object(obj).kind {
                    ObjectKind::FunctionalUnit { latency, .. } => latency.eval(instr),
                    _ => 0,
                }
            }
            Tag::ReadMem => {
                let mut n = 0usize;
                for &(a, ticket) in &tok.raddr_tickets {
                    if self.d.memory_of(a) == Some(obj) {
                        n += 1;
                        let st = &self.addr_res[&a];
                        if !st.ready(ticket) {
                            return None;
                        }
                        deps = deps.max(st.last_leave);
                    }
                }
                self.d.mem_latency(obj, n, false, instr)
            }
            Tag::WriteBack => 0,
            Tag::WriteMem => {
                let mut n = 0usize;
                for &(a, ticket) in &tok.waddr_tickets {
                    if self.d.memory_of(a) == Some(obj) {
                        n += 1;
                        let st = &self.addr_res[&a];
                        if !st.ready(ticket) {
                            return None;
                        }
                        deps = deps.max(st.last_leave);
                    }
                }
                self.d.mem_latency(obj, n, true, instr)
            }
        };
        Some((deps, lat))
    }

    /// Scoreboard updates when a token leaves tail node `idx` at `t`.
    fn on_release(&mut self, tok: &Token, idx: usize, t: Cycle) {
        let (obj, tag) = tok.tail[idx];
        match tag {
            Tag::Fu => {
                for &(r, _, at_wb) in &tok.reg_tickets {
                    if !at_wb {
                        self.reg_res[r.0 as usize].serve(t);
                    }
                }
            }
            Tag::WriteBack => {
                for &(r, _, at_wb) in &tok.reg_tickets {
                    if at_wb {
                        self.reg_res[r.0 as usize].serve(t);
                    }
                }
            }
            Tag::ReadMem => {
                for &(a, _) in &tok.raddr_tickets {
                    if self.d.memory_of(a) == Some(obj) {
                        self.addr_res.get_mut(&a).unwrap().serve(t);
                    }
                }
            }
            Tag::WriteMem => {
                for &(a, _) in &tok.waddr_tickets {
                    if self.d.memory_of(a) == Some(obj) {
                        self.addr_res.get_mut(&a).unwrap().serve(t);
                    }
                }
            }
            Tag::Stage => {}
        }
    }

    /// Run `range` iterations of `kernel` to completion.
    pub fn run(&mut self, kernel: &LoopKernel, range: std::ops::Range<u64>) -> Result<SimResult> {
        let f = *self.d.fetch_config();
        let issue_cap = f.issue_buffer_size;
        let ifs_lat = f.ifs_latency;
        let ifs_obj = f.fetch_stage;
        let p = f.port_width as usize;

        // instruction stream, materialized one iteration at a time through
        // a reused emission arena (no throwaway buffer per iteration)
        let mut stream: Vec<Instruction> = Vec::new();
        let mut emit = EmitBuf::new();
        let mut stream_pos = 0usize;
        let mut next_iter = range.start;

        let mut tokens: Vec<Token> = Vec::new();
        let mut states: Vec<TState> = Vec::new();
        // live token ids in program order (tokens/states are never shrunk;
        // `base` tracks how many leading entries were retired)
        let mut live: std::collections::VecDeque<usize> = std::collections::VecDeque::new();

        loop {
            // ---- fixpoint: fetch + advance tokens in program order ----------
            let mut cap_denied = false;
            loop {
                let mut progressed = false;

                // fetch a new group when the port is free and the previous
                // group has drained into the issue buffer
                if self.group_pending == 0 && self.now >= self.next_fetch_start {
                    if stream_pos >= stream.len() && next_iter < range.end {
                        stream.clear();
                        stream_pos = 0;
                        emit.clear();
                        kernel.emit_into(next_iter, &mut emit);
                        stream.extend(emit.iter().map(|v| v.to_instruction()));
                        next_iter += 1;
                    }
                    if stream_pos < stream.len() {
                        let finish = self.now + f.read_latency;
                        let n = p.min(stream.len() - stream_pos);
                        for _ in 0..n {
                            let tok = self
                                .make_token(stream[stream_pos].clone())
                                .context("routing failed during simulation")?;
                            stream_pos += 1;
                            tokens.push(tok);
                            states.push(TState::AwaitForward { ready: finish });
                            live.push_back(tokens.len() - 1);
                            self.instructions += 1;
                        }
                        self.group_pending = n;
                        self.next_fetch_start = finish;
                        progressed = true;
                    }
                }

                for &ti in &live {
                    let st = states[ti];
                    match st {
                        TState::AwaitForward { ready } => {
                            if self.now >= ready {
                                if self.fwd_count < issue_cap {
                                    self.fwd_count += 1;
                                    states[ti] = TState::AwaitIssue { ready: self.now };
                                    progressed = true;
                                } else {
                                    cap_denied = true;
                                }
                            }
                        }
                        TState::AwaitIssue { ready } => {
                            // entering the fetch stage requires a free
                            // issue-buffer slot (IFS occupancy) plus the
                            // per-cycle entry cap
                            if self.now >= ready && self.has_capacity(ifs_obj) {
                                if self.enter_count < issue_cap {
                                    self.enter_count += 1;
                                    self.occupy(ifs_obj);
                                    self.group_pending -= 1;
                                    states[ti] = TState::Ifs { finish: self.now + ifs_lat };
                                    progressed = true;
                                } else {
                                    cap_denied = true;
                                }
                            }
                        }
                        TState::Ifs { finish } => {
                            if self.now >= finish {
                                states[ti] = TState::IfsStalled;
                                progressed = true;
                            }
                        }
                        TState::IfsStalled => {
                            let first = tokens[ti].tail[0].0;
                            if self.has_capacity(first) {
                                self.release_obj(ifs_obj);
                                self.occupy(first);
                                let finish = self
                                    .node_ready(&tokens[ti], 0)
                                    .map(|(deps, lat)| self.now.max(deps) + lat);
                                states[ti] = TState::Node { idx: 0, finish };
                                progressed = true;
                            }
                        }
                        TState::Node { idx, finish: None } => {
                            if let Some((deps, lat)) = self.node_ready(&tokens[ti], idx) {
                                states[ti] =
                                    TState::Node { idx, finish: Some(self.now.max(deps) + lat) };
                                progressed = true;
                            }
                        }
                        TState::Node { idx, finish: Some(finish) } => {
                            if self.now >= finish {
                                if *TRACE_NODES {
                                    eprintln!(
                                        "DES  i{} node {} stop={}",
                                        ti,
                                        self.d.object(tokens[ti].tail[idx].0).name,
                                        finish
                                    );
                                }
                                states[ti] = TState::NodeStalled { idx };
                                progressed = true;
                            }
                        }
                        TState::NodeStalled { idx } => {
                            if idx + 1 < tokens[ti].tail.len() {
                                let next = tokens[ti].tail[idx + 1].0;
                                if self.has_capacity(next) {
                                    let cur = tokens[ti].tail[idx].0;
                                    self.release_obj(cur);
                                    self.occupy(next);
                                    let now = self.now;
                                    // scoreboard updates at the leave time
                                    let tok = &tokens[ti];
                                    self.on_release(tok, idx, now);
                                    let finish = self
                                        .node_ready(&tokens[ti], idx + 1)
                                        .map(|(deps, lat)| now.max(deps) + lat);
                                    states[ti] = TState::Node { idx: idx + 1, finish };
                                    progressed = true;
                                }
                            } else {
                                let cur = tokens[ti].tail[idx].0;
                                self.release_obj(cur);
                                let now = self.now;
                                self.on_release(&tokens[ti], idx, now);
                                self.max_leave = self.max_leave.max(now);
                                states[ti] = TState::Done;
                                progressed = true;
                                if *TRACE {
                                    eprintln!(
                                        "DES  i{} op={} leave={}",
                                        ti,
                                        self.d.op_name(tokens[ti].instr.op),
                                        now
                                    );
                                }
                            }
                        }
                        TState::Done => {}
                    }
                }
                // retire completed tokens from the front of the window
                while let Some(&front) = live.front() {
                    if states[front] == TState::Done {
                        live.pop_front();
                    } else {
                        break;
                    }
                }
                if !progressed {
                    break;
                }
            }

            // ---- termination -------------------------------------------------
            let stream_done = stream_pos >= stream.len() && next_iter >= range.end;
            if stream_done && live.is_empty() {
                break;
            }

            // ---- advance time to the next event ------------------------------
            let mut next_t = Cycle::MAX;
            for &ti in &live {
                match states[ti] {
                    TState::AwaitForward { ready } | TState::AwaitIssue { ready } => {
                        if ready > self.now {
                            next_t = next_t.min(ready);
                        }
                    }
                    TState::Ifs { finish } | TState::Node { finish: Some(finish), .. } => {
                        if finish > self.now {
                            next_t = next_t.min(finish);
                        }
                    }
                    _ => {}
                }
            }
            if self.group_pending == 0 && !stream_done && self.next_fetch_start > self.now {
                next_t = next_t.min(self.next_fetch_start);
            }
            if cap_denied {
                next_t = next_t.min(self.now + 1);
            }
            if next_t == Cycle::MAX {
                bail!(
                    "simulation deadlock at cycle {} with {} live tokens",
                    self.now,
                    live.len()
                );
            }
            self.now = next_t;
            self.fwd_count = 0;
            self.enter_count = 0;
            self.ticks += 1;
        }

        Ok(SimResult {
            cycles: self.max_leave,
            instructions: self.instructions,
            ticks: self.ticks,
        })
    }
}

/// Simulate iterations `range` of `kernel` on `d`.
pub fn simulate(d: &Diagram, kernel: &LoopKernel, range: std::ops::Range<u64>) -> Result<SimResult> {
    CycleSim::new(d).run(kernel, range)
}

/// Simulate a whole mapped layer (kernels in sequence, fresh machine each —
/// matches how [`crate::aidg::fixed_point`] chains per-kernel estimates).
pub fn simulate_layer(d: &Diagram, kernels: &[LoopKernel]) -> Result<SimResult> {
    let mut total_cycles = 0;
    let mut insts = 0;
    let mut ticks = 0;
    for k in kernels {
        let r = simulate(d, k, 0..k.k)?;
        total_cycles += r.cycles;
        insts += r.instructions;
        ticks += r.ticks;
    }
    Ok(SimResult { cycles: total_cycles, instructions: insts, ticks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl::Latency;
    use crate::aidg;
    use crate::ids::RegId;

    fn machine() -> (Diagram, Ops) {
        let mut d = Diagram::new("m");
        let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
        let es = d.add_execute_stage("es");
        let (rf, regs) = d.add_regfile("rf", "r", 4);
        let mem = d.add_memory("dmem", 4, 4, 1, 1, 0, 1 << 20);
        let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load", "store"]);
        let alu = d.add_fu(es, "alu", Latency::Fixed(1), &["mac"]);
        d.forward(ifs, es);
        d.fu_writes(lsu, rf);
        d.fu_reads(lsu, rf);
        d.fu_reads(alu, rf);
        d.fu_writes(alu, rf);
        d.mem_reads(lsu, mem);
        d.mem_writes(lsu, mem);
        let ops = Ops { load: d.op("load"), mac: d.op("mac"), store: d.op("store"), regs };
        d.finalize().unwrap();
        (d, ops)
    }

    struct Ops {
        load: crate::ids::OpId,
        mac: crate::ids::OpId,
        store: crate::ids::OpId,
        regs: Vec<RegId>,
    }

    fn lk(ops: &Ops, k: u64) -> LoopKernel {
        let (load, mac, store) = (ops.load, ops.mac, ops.store);
        let (r0, r1, r2) = (ops.regs[0], ops.regs[1], ops.regs[2]);
        LoopKernel::new(
            "t",
            k,
            4,
            Box::new(move |it, buf| {
                buf.push(Instruction::new(load).writes(&[r0]).read_mem(&[it]));
                buf.push(Instruction::new(load).writes(&[r1]).read_mem(&[1000 + it]));
                buf.push(Instruction::new(mac).reads(&[r0, r1]).writes(&[r2]));
                buf.push(Instruction::new(store).reads(&[r2]).write_mem(&[2000 + it]));
            }),
        )
    }

    #[test]
    fn des_matches_aidg_whole_graph() {
        // the repo's central accuracy check: independent DES == AIDG sweep
        let (d, ops) = machine();
        for k in [1u64, 2, 8, 64] {
            let kernel = lk(&ops, k);
            let aidg = aidg::evaluate_whole(&d, &kernel).unwrap();
            let des = simulate(&d, &kernel, 0..k).unwrap();
            assert_eq!(des.cycles, aidg.cycles, "k={k}");
            assert_eq!(des.instructions, 4 * k);
        }
    }

    #[test]
    fn des_executes_every_instruction() {
        let (d, ops) = machine();
        let kernel = lk(&ops, 10);
        let r = simulate(&d, &kernel, 0..10).unwrap();
        assert_eq!(r.instructions, 40);
        assert!(r.ticks > 10);
    }

    #[test]
    fn empty_range_is_zero() {
        let (d, ops) = machine();
        let kernel = lk(&ops, 4);
        let r = simulate(&d, &kernel, 0..0).unwrap();
        assert_eq!(r.cycles, 0);
        assert_eq!(r.instructions, 0);
    }

    #[test]
    fn throughput_scales_with_memory_latency() {
        let build = |mem_lat: u64| {
            let mut d = Diagram::new("m");
            let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
            let es = d.add_execute_stage("es");
            let (rf, regs) = d.add_regfile("rf", "r", 2);
            let mem = d.add_memory("dmem", mem_lat, mem_lat, 1, 1, 0, 1 << 20);
            let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load"]);
            d.forward(ifs, es);
            d.fu_writes(lsu, rf);
            d.mem_reads(lsu, mem);
            let load = d.op("load");
            d.finalize().unwrap();
            let r0 = regs[0];
            let kernel = LoopKernel::new(
                "t",
                32,
                1,
                Box::new(move |it, buf| {
                    buf.push(Instruction::new(load).writes(&[r0]).read_mem(&[it]));
                }),
            );
            simulate(&d, &kernel, 0..32).unwrap().cycles
        };
        let fast = build(1);
        let slow = build(8);
        assert!(slow > fast + 32, "slow {slow} fast {fast}");
    }
}
