//! Random [`NetDescription`] AST generation for the textual-network
//! round-trip property: AST → pretty-print → parse → same AST (the
//! workload-side mirror of [`super::arch_gen`]).
//!
//! Generated trees stay inside the canonical-printable subset shared with
//! the ACADL generator: literal segments avoid `$`, negations never wrap
//! constants directly, and `foreach` bounds avoid function calls. On top of
//! that, the network grammar's own invariants hold by construction:
//! `add`/`mul` always carry `with`, no other kind does, and groups are
//! non-empty and never nested.

use crate::dnn::layer::{ActKind, PoolKind};
use crate::dnn::text::ast::{
    ForRange, Group, InputDecl, InputShape, Item, LayerBody, LayerDecl, NetDescription, Param,
    PExpr, Span, Spanned,
};

use super::arch_gen::{arbitrary_pexpr, arbitrary_template};
use super::prop::Rng;

const VARS: &[&str] = &["r", "c", "rows", "cols", "idx", "n", "depth_x"];

fn sp<T>(node: T) -> Spanned<T> {
    Spanned::bare(node)
}

fn spanned_pexpr(rng: &mut Rng, calls: bool) -> Spanned<PExpr> {
    sp(arbitrary_pexpr(rng, 2, calls))
}

fn arbitrary_body(rng: &mut Rng) -> LayerBody {
    let pexpr = |rng: &mut Rng| spanned_pexpr(rng, true);
    match rng.range_u32(0, 9) {
        0 => LayerBody::Conv1d {
            out_channels: pexpr(rng),
            kernel: pexpr(rng),
            stride: pexpr(rng),
            pad: sp(rng.bool()),
        },
        1 => LayerBody::Conv2d {
            out_channels: pexpr(rng),
            kernel: pexpr(rng),
            stride: pexpr(rng),
            pad: sp(rng.bool()),
        },
        2 => LayerBody::DwConv2d { kernel: pexpr(rng), stride: pexpr(rng), pad: sp(rng.bool()) },
        3 => LayerBody::Dense {
            out_channels: pexpr(rng),
            in_features: if rng.bool() { Some(pexpr(rng)) } else { None },
        },
        4 => LayerBody::Pool1d {
            pool: if rng.bool() { PoolKind::Max } else { PoolKind::Avg },
            kernel: pexpr(rng),
            stride: pexpr(rng),
        },
        5 => LayerBody::Pool2d {
            pool: if rng.bool() { PoolKind::Max } else { PoolKind::Avg },
            kernel: pexpr(rng),
            stride: pexpr(rng),
        },
        6 => LayerBody::Act { act: if rng.bool() { ActKind::Relu } else { ActKind::Clip } },
        7 => LayerBody::Add,
        _ => LayerBody::Mul,
    }
}

fn arbitrary_ranges(rng: &mut Rng, max: usize) -> Vec<ForRange> {
    (0..rng.range_usize(1, max))
        .map(|_| ForRange {
            var: sp(rng.pick(VARS).to_string()),
            // no calls: the foreach splitter treats `,` as a separator
            lo: sp(arbitrary_pexpr(rng, 1, false)),
            hi: sp(arbitrary_pexpr(rng, 1, false)),
        })
        .collect()
}

/// A random `[[layer]]` declaration honoring the grammar's invariants.
pub fn arbitrary_layer(rng: &mut Rng) -> LayerDecl {
    let body = arbitrary_body(rng);
    let with = if body.takes_with() { Some(arbitrary_template(rng)) } else { None };
    LayerDecl {
        name: arbitrary_template(rng),
        from: if rng.bool() { Some(arbitrary_template(rng)) } else { None },
        with,
        body,
        foreach: if rng.bool() { arbitrary_ranges(rng, 2) } else { Vec::new() },
        when: if rng.bool() { Some(spanned_pexpr(rng, true)) } else { None },
        span: Span::default(),
    }
}

fn arbitrary_input(rng: &mut Rng) -> InputDecl {
    let shape = if rng.bool() {
        InputShape::OneD { length: spanned_pexpr(rng, true) }
    } else {
        InputShape::TwoD { height: spanned_pexpr(rng, true), width: spanned_pexpr(rng, true) }
    };
    InputDecl {
        name: arbitrary_template(rng),
        channels: spanned_pexpr(rng, true),
        shape,
        span: Span::default(),
    }
}

/// A random network description: always named, with random params, inputs,
/// layers, and (non-nested, non-empty) `[[foreach]]` groups.
pub fn arbitrary_net_description(rng: &mut Rng) -> NetDescription {
    let mut params = Vec::new();
    for i in 0..rng.range_usize(0, 3) {
        params.push(Param {
            name: sp(format!("p{i}_{}", rng.range_u64(0, 999))),
            value: sp(rng.range_u64(0, 1 << 40) as i64),
        });
    }
    let items = (0..rng.range_usize(0, 5))
        .map(|_| {
            if rng.range_u32(0, 3) == 0 {
                Item::Group(Group {
                    ranges: arbitrary_ranges(rng, 2),
                    when: if rng.bool() { Some(spanned_pexpr(rng, true)) } else { None },
                    layers: (0..rng.range_usize(1, 3)).map(|_| arbitrary_layer(rng)).collect(),
                    span: Span::default(),
                })
            } else {
                Item::Layer(arbitrary_layer(rng))
            }
        })
        .collect();
    NetDescription {
        name: Some(arbitrary_template(rng)),
        params,
        inputs: (0..rng.range_usize(0, 2)).map(|_| arbitrary_input(rng)).collect(),
        items,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::text::parse_net;
    use crate::testkit::Prop;

    #[test]
    fn net_description_roundtrips_through_pretty_printer() {
        Prop::new(0xD0_0E7).cases(256).run(|rng| {
            let ast = arbitrary_net_description(rng);
            let printed = ast.to_toml();
            let reparsed = parse_net(&printed)
                .unwrap_or_else(|e| panic!("reparse failed: {e}\n---\n{printed}"));
            assert_eq!(ast, reparsed, "pretty-printed form:\n{printed}");
        });
    }
}
