//! Mini property-testing: seeded xorshift64* case generation with
//! failure-case reporting. Stands in for proptest (not vendored offline);
//! the API is intentionally tiny — generate random cases, run the property,
//! report the seed + case index on failure so runs are reproducible.

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// A seeded generator (seed 0 is mapped to 1).
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1) }
    }

    #[inline]
    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.range_u64(lo as u64, hi as u64) as u32
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

/// Property runner: `Prop::new(seed).cases(n).run(|rng| ...)`.
pub struct Prop {
    seed: u64,
    cases: usize,
}

impl Prop {
    /// A property with `seed` and the default 64 cases.
    pub fn new(seed: u64) -> Self {
        Self { seed, cases: 64 }
    }

    /// Set the number of generated cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run the property for each case; panics with the seed and case index
    /// on the first failure (the closure should itself assert/panic).
    pub fn run(&self, mut prop: impl FnMut(&mut Rng)) {
        for case in 0..self.cases {
            let mut rng = Rng::new(self.seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15)));
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut rng);
            }));
            if let Err(e) = result {
                eprintln!(
                    "property failed: seed={} case={case} (re-run with Prop::new({}).cases(1))",
                    self.seed,
                    self.seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15))
                );
                std::panic::resume_unwind(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_u64(3, 9);
            assert!((3..=9).contains(&v));
        }
        // degenerate range
        assert_eq!(r.range_u64(5, 5), 5);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn prop_runs_all_cases() {
        let mut n = 0;
        Prop::new(1).cases(10).run(|_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic]
    fn prop_propagates_failure() {
        Prop::new(1).cases(5).run(|rng| {
            assert!(rng.range_u64(0, 10) <= 10); // fine
            panic!("boom");
        });
    }
}
