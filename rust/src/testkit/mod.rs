//! Test utilities: a small deterministic property-testing helper (proptest
//! is not vendored in this offline image), random AST generation for the
//! textual-ACADL and textual-network frontend round-trip properties, and
//! shared fixtures.

pub mod arch_gen;
pub mod gen;
pub mod json;
pub mod net_gen;
pub mod prop;

pub use arch_gen::{arbitrary_description, arbitrary_pexpr, arbitrary_template};
pub use gen::{migrating_kernel, multirange_machine, random_kernel, random_machine, RandMachine};
pub use net_gen::{arbitrary_layer, arbitrary_net_description};
pub use prop::{Prop, Rng};
