//! Test utilities: a small deterministic property-testing helper (proptest
//! is not vendored in this offline image), random textual-ACADL AST
//! generation for the frontend round-trip property, and shared fixtures.

pub mod arch_gen;
pub mod prop;

pub use arch_gen::{arbitrary_description, arbitrary_pexpr, arbitrary_template};
pub use prop::{Prop, Rng};
