//! Test utilities: a small deterministic property-testing helper (proptest
//! is not vendored in this offline image) and shared fixtures.

pub mod prop;

pub use prop::{Prop, Rng};
