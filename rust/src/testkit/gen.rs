//! Randomized machine/kernel generators for differential testing.
//!
//! Moved out of `aidg::program`'s unit tests so integration suites (the
//! dispatch differential fuzz) and unit tests share one generator set — and
//! one seeded draw sequence: [`random_machine`] and [`random_kernel`]
//! consume the [`Rng`] in the exact order the original in-module versions
//! did, preserving historical test vectors.
//!
//! On top of the original pair, this module adds the fusion-fallback
//! forcers: [`multirange_machine`] (a memory claiming two address ranges —
//! offsets touching it never compile to a threaded tape) and
//! [`migrating_kernel`] (addresses that abandon the first iteration's
//! address→memory partition — tripping the run-time guard / partition
//! fallback).

use crate::acadl::{Diagram, Latency};
use crate::ids::{OpId, RegId};
use crate::isa::LoopKernel;

use super::prop::Rng;

/// A randomized scalar machine: random fetch geometry, an optional
/// expression-latency pipeline stage, 1–3 memories with mixed fixed /
/// immediate-dependent latencies and port widths, and two FUs.
pub struct RandMachine {
    /// The finalized diagram.
    pub d: Diagram,
    /// `load` opcode (reads memory, writes a register).
    pub load: OpId,
    /// `store` opcode (reads a register, writes memory).
    pub store: OpId,
    /// `mac` opcode (register-only compute).
    pub mac: OpId,
    /// The register file's registers.
    pub regs: Vec<RegId>,
    /// Base address of each kernel-addressable region, in declaration
    /// order (for [`multirange_machine`] these are two ranges of *one*
    /// memory).
    pub mem_bases: Vec<u64>,
}

/// Draw a [`RandMachine`] (draw sequence is part of the seeded contract —
/// do not reorder).
pub fn random_machine(rng: &mut Rng) -> RandMachine {
    let mut d = Diagram::new("rand");
    let pw = rng.range_u32(1, 3);
    let (_im, ifs) = d.add_fetch(
        "imem",
        rng.range_u64(1, 2),
        pw,
        "ifs",
        rng.range_u64(1, 2),
        rng.range_u32(1, 4),
    );
    let es = d.add_execute_stage("es");
    let stage = rng.bool().then(|| {
        let lat = if rng.bool() {
            Latency::Fixed(rng.range_u64(0, 2))
        } else {
            Latency::parse("1 + imm0 % 3").unwrap()
        };
        d.add_stage("ps", lat)
    });
    let (rf, regs) = d.add_regfile("rf", "r", 4);
    let n_mems = rng.range_usize(1, 3);
    let mut mems = Vec::new();
    let mut mem_bases = Vec::new();
    for i in 0..n_mems {
        let base = (i as u64) << 20;
        let rl = if rng.bool() {
            Latency::Fixed(rng.range_u64(1, 6))
        } else {
            Latency::parse("2 + imm1 % 4").unwrap()
        };
        let wl = if rng.bool() {
            Latency::Fixed(rng.range_u64(1, 6))
        } else {
            Latency::parse("1 + imm0 % 2").unwrap()
        };
        let m = d.add_memory(
            &format!("mem{i}"),
            rl,
            wl,
            rng.range_u32(1, 4),
            rng.range_u32(1, 2),
            base,
            1 << 20,
        );
        mems.push(m);
        mem_bases.push(base);
    }
    let lsu_lat = if rng.bool() {
        Latency::Fixed(rng.range_u64(1, 2))
    } else {
        Latency::parse("1 + imm0 % 2").unwrap()
    };
    let lsu = d.add_fu(es, "lsu", lsu_lat, &["load", "store"]);
    let alu = d.add_fu(es, "alu", Latency::Fixed(rng.range_u64(1, 3)), &["mac"]);
    match stage {
        Some(s) => {
            d.forward(ifs, s);
            d.forward(s, es);
        }
        None => d.forward(ifs, es),
    }
    d.fu_reads(lsu, rf);
    d.fu_writes(lsu, rf);
    d.fu_reads(alu, rf);
    d.fu_writes(alu, rf);
    for &m in &mems {
        d.mem_reads(lsu, m);
        d.mem_writes(lsu, m);
    }
    let (load, store, mac) = (d.op("load"), d.op("store"), d.op("mac"));
    d.finalize().unwrap();
    RandMachine { d, load, store, mac, regs, mem_bases }
}

/// Template slot of a random §6.3 kernel: fixed op/registers/shape,
/// addresses strided by the iteration index, immediates varying per
/// iteration (exercising the dynamic-latency escape hatch).
#[derive(Clone, Copy)]
enum Slot {
    Load { w: usize, mem: usize, mem2: Option<usize>, na: u64, off: u64, stride: u64 },
    Store { r: usize, mem: usize, off: u64, stride: u64 },
    Mac { a: usize, b: usize, w: usize },
}

/// Draw a random template kernel of `k` iterations against `m` (draw
/// sequence is part of the seeded contract — do not reorder).
pub fn random_kernel(rng: &mut Rng, m: &RandMachine, k: u64) -> LoopKernel {
    let n_slots = rng.range_usize(2, 7);
    let mut slots = Vec::with_capacity(n_slots);
    for _ in 0..n_slots {
        let s = match rng.range_u32(0, 3) {
            0 | 1 => Slot::Load {
                w: rng.range_usize(0, m.regs.len() - 1),
                mem: rng.range_usize(0, m.mem_bases.len() - 1),
                mem2: (m.mem_bases.len() > 1 && rng.bool())
                    .then(|| rng.range_usize(0, m.mem_bases.len() - 1)),
                na: rng.range_u64(1, 4),
                off: rng.range_u64(0, 4096),
                stride: rng.range_u64(1, 8),
            },
            2 => Slot::Store {
                r: rng.range_usize(0, m.regs.len() - 1),
                mem: rng.range_usize(0, m.mem_bases.len() - 1),
                off: rng.range_u64(0, 4096),
                stride: rng.range_u64(1, 8),
            },
            _ => Slot::Mac {
                a: rng.range_usize(0, m.regs.len() - 1),
                b: rng.range_usize(0, m.regs.len() - 1),
                w: rng.range_usize(0, m.regs.len() - 1),
            },
        };
        slots.push(s);
    }
    let (load, store, mac) = (m.load, m.store, m.mac);
    let regs = m.regs.clone();
    let bases = m.mem_bases.clone();
    let n = slots.len();
    LoopKernel::new(
        "rand",
        k,
        n,
        Box::new(move |it, buf| {
            for s in &slots {
                match *s {
                    Slot::Load { w, mem, mem2, na, off, stride } => {
                        let mut b = buf
                            .instr(load)
                            .writes(&[regs[w]])
                            .read_mem_iter((0..na).map(|q| bases[mem] + off + stride * it + q));
                        if let Some(m2) = mem2 {
                            b = b.read_mem(&[bases[m2] + off + stride * it]);
                        }
                        b.imm((it % 3) as i64).imm((it % 5) as i64);
                    }
                    Slot::Store { r, mem, off, stride } => {
                        buf.instr(store)
                            .reads(&[regs[r]])
                            .write_mem(&[bases[mem] + off + stride * it])
                            .imm((it % 2) as i64)
                            .imm((it % 7) as i64);
                    }
                    Slot::Mac { a, b, w } => {
                        buf.instr(mac)
                            .reads(&[regs[a], regs[b]])
                            .writes(&[regs[w]])
                            .imm((it % 4) as i64);
                    }
                }
            }
        }),
    )
}

/// A deterministic machine whose single data memory claims **two** address
/// ranges (`[0, 2^20)` and `[2^20, 2^21)`). Memory nodes on it carry the
/// multi-range sentinel, so every offset with a memory access is
/// structurally non-fusible — the threaded evaluator must take the
/// node-table fallback there (compute-only offsets still fuse). Compatible
/// with [`random_kernel`]: `mem_bases` exposes both ranges as addressable
/// regions.
pub fn multirange_machine() -> RandMachine {
    let mut d = Diagram::new("multi");
    let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
    let es = d.add_execute_stage("es");
    let (rf, regs) = d.add_regfile("rf", "r", 4);
    let mem = d.add_memory("banked", 3, 2, 1, 1, 0, 1 << 20);
    d.add_memory_range(mem, 1 << 20, 1 << 20);
    let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load", "store"]);
    let alu = d.add_fu(es, "alu", Latency::Fixed(2), &["mac"]);
    d.forward(ifs, es);
    d.fu_reads(lsu, rf);
    d.fu_writes(lsu, rf);
    d.fu_reads(alu, rf);
    d.fu_writes(alu, rf);
    d.mem_reads(lsu, mem);
    d.mem_writes(lsu, mem);
    let (load, store, mac) = (d.op("load"), d.op("store"), d.op("mac"));
    d.finalize().unwrap();
    RandMachine { d, load, store, mac, regs, mem_bases: vec![0, 1 << 20] }
}

/// A kernel that violates the §6.3 address→memory partition: iteration 0
/// reads `[mem0, mem1]`, later iterations read two `mem1` addresses. The
/// lowered partition (and the threaded tape's folded guard, which is the
/// same check) fails from iteration 1 on — the serial evaluator falls back
/// to the full-scan node-table walk, the batch evaluator evicts the lane.
/// Requires a machine with at least two memories.
pub fn migrating_kernel(m: &RandMachine, k: u64) -> LoopKernel {
    assert!(m.mem_bases.len() >= 2, "migrating kernel needs two addressable regions");
    let load = m.load;
    let r0 = m.regs[0];
    let (b0, b1) = (m.mem_bases[0], m.mem_bases[1]);
    LoopKernel::new(
        "migrate",
        k,
        1,
        Box::new(move |it, buf| {
            let a0 = if it == 0 { b0 } else { b1 + 100 + it };
            buf.instr(load)
                .writes(&[r0])
                .read_mem(&[a0, b1 + it])
                .imm((it % 3) as i64)
                .imm((it % 5) as i64);
        }),
    )
}
