//! Random [`Description`] AST generation for the textual-ACADL round-trip
//! property: AST → pretty-print → parse → same AST. Uses the in-repo
//! [`Prop`]/[`Rng`] harness (proptest is not vendored offline).
//!
//! Generated trees stay inside the canonical-printable subset: literal
//! segments avoid `$`, negations never wrap constants directly (the parser
//! folds `-3` to `Const(-3)`), and `foreach` bounds avoid function calls
//! (the clause splitter treats `,` as a separator).

use crate::acadl::text::ast::{
    BinOp, Decl, DeclBody, Description, Fetch, ForRange, Func, PExpr, Param, Segment, Span,
    Spanned, Sweep, SweepDim, SweepItem, Template,
};

use super::prop::Rng;

const VARS: &[&str] = &["r", "c", "rows", "cols", "idx", "n", "depth_x"];
const OPS: &[&str] = &["mac", "load", "store", "conv_ext", "mvin", "route_in", "add"];
const LIT_CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_.[]";

fn ident(rng: &mut Rng) -> String {
    let mut s = String::new();
    s.push((b'a' + rng.range_u32(0, 25) as u8) as char);
    for _ in 0..rng.range_usize(0, 6) {
        let pool = b"abcdefghijklmnopqrstuvwxyz0123456789_";
        s.push(pool[rng.range_usize(0, pool.len() - 1)] as char);
    }
    s
}

fn lit_text(rng: &mut Rng) -> String {
    (0..rng.range_usize(1, 6))
        .map(|_| LIT_CHARS[rng.range_usize(0, LIT_CHARS.len() - 1)] as char)
        .collect()
}

/// A random parameter expression. `calls` gates `cdiv`/`max`/`min`.
pub fn arbitrary_pexpr(rng: &mut Rng, depth: usize, calls: bool) -> PExpr {
    if depth == 0 || rng.range_u32(0, 3) == 0 {
        return if rng.bool() {
            PExpr::Const(rng.range_u64(0, 99) as i64)
        } else {
            PExpr::Var(rng.pick(VARS).to_string())
        };
    }
    match rng.range_u32(0, if calls { 5 } else { 4 }) {
        0 => PExpr::Neg(Box::new(PExpr::Var(rng.pick(VARS).to_string()))),
        1 | 2 | 3 => {
            let op = *rng.pick(&[
                BinOp::Add,
                BinOp::Sub,
                BinOp::Mul,
                BinOp::Div,
                BinOp::Rem,
                BinOp::Eq,
                BinOp::Ne,
                BinOp::Lt,
                BinOp::Le,
                BinOp::Gt,
                BinOp::Ge,
                BinOp::And,
                BinOp::Or,
            ]);
            PExpr::Bin(
                op,
                Box::new(arbitrary_pexpr(rng, depth - 1, calls)),
                Box::new(arbitrary_pexpr(rng, depth - 1, calls)),
            )
        }
        _ => PExpr::Call(
            *rng.pick(&[Func::Cdiv, Func::Max, Func::Min]),
            Box::new(arbitrary_pexpr(rng, depth - 1, calls)),
            Box::new(arbitrary_pexpr(rng, depth - 1, calls)),
        ),
    }
}

/// A random interpolated template (alternating literal and `${}` segments).
pub fn arbitrary_template(rng: &mut Rng) -> Template {
    let mut segments = Vec::new();
    let mut want_lit = rng.bool();
    for _ in 0..rng.range_usize(1, 4) {
        if want_lit {
            segments.push(Segment::Lit(lit_text(rng)));
        } else {
            segments.push(Segment::Expr(arbitrary_pexpr(rng, 2, true)));
        }
        want_lit = !want_lit;
    }
    Template { segments, span: Span::default() }
}

fn sp<T>(node: T) -> Spanned<T> {
    Spanned::bare(node)
}

fn spanned_pexpr(rng: &mut Rng, calls: bool) -> Spanned<PExpr> {
    sp(arbitrary_pexpr(rng, 2, calls))
}

fn ops_list(rng: &mut Rng) -> Vec<Spanned<String>> {
    (0..rng.range_usize(0, 3)).map(|_| sp(rng.pick(OPS).to_string())).collect()
}

fn arbitrary_body(rng: &mut Rng) -> DeclBody {
    match rng.range_u32(0, 10) {
        0 => DeclBody::Stage { name: arbitrary_template(rng), latency: arbitrary_template(rng) },
        1 => DeclBody::ExecuteStage { name: arbitrary_template(rng) },
        2 => DeclBody::FunctionalUnit {
            name: arbitrary_template(rng),
            container: if rng.bool() { Some(arbitrary_template(rng)) } else { None },
            latency: arbitrary_template(rng),
            ops: ops_list(rng),
        },
        3 => DeclBody::RegisterFile {
            name: arbitrary_template(rng),
            prefix: arbitrary_template(rng),
            count: spanned_pexpr(rng, true),
        },
        4 => DeclBody::Memory {
            name: arbitrary_template(rng),
            read_latency: arbitrary_template(rng),
            write_latency: arbitrary_template(rng),
            port_width: spanned_pexpr(rng, true),
            max_concurrent: spanned_pexpr(rng, true),
            base: spanned_pexpr(rng, true),
            words: spanned_pexpr(rng, true),
        },
        5 => DeclBody::Forward { from: arbitrary_template(rng), to: arbitrary_template(rng) },
        6 => DeclBody::Contains { parent: arbitrary_template(rng), child: arbitrary_template(rng) },
        7 => DeclBody::Reads { fu: arbitrary_template(rng), rf: arbitrary_template(rng) },
        8 => DeclBody::Writes { fu: arbitrary_template(rng), rf: arbitrary_template(rng) },
        9 => DeclBody::MemRead { fu: arbitrary_template(rng), mem: arbitrary_template(rng) },
        _ => DeclBody::MemWrite { fu: arbitrary_template(rng), mem: arbitrary_template(rng) },
    }
}

/// A random `[sweep]` item. Expressions avoid the identifier `step` (absent
/// from [`VARS`]), which the range splitter treats as a keyword.
fn arbitrary_sweep_item(rng: &mut Rng) -> SweepItem {
    if rng.bool() {
        SweepItem::Scalar(arbitrary_pexpr(rng, 2, true))
    } else {
        SweepItem::Range {
            lo: arbitrary_pexpr(rng, 1, true),
            hi: arbitrary_pexpr(rng, 1, true),
            step: if rng.bool() { Some(arbitrary_pexpr(rng, 1, true)) } else { None },
        }
    }
}

/// A random `[sweep]` section over distinct dimension names.
fn arbitrary_sweep(rng: &mut Rng) -> Sweep {
    let n_dims = rng.range_usize(1, 3);
    let dims = (0..n_dims)
        .map(|i| SweepDim {
            // VARS entries are distinct; index by position for unique keys
            name: sp(VARS[(i * 2) % VARS.len()].to_string()),
            items: (0..rng.range_usize(1, 3)).map(|_| arbitrary_sweep_item(rng)).collect(),
            span: Span::default(),
        })
        .collect();
    Sweep {
        dims,
        when: if rng.bool() { Some(spanned_pexpr(rng, true)) } else { None },
        cap: if rng.bool() { Some(sp(rng.range_u64(1, 1 << 20) as i64)) } else { None },
        span: Span::default(),
    }
}

fn arbitrary_decl(rng: &mut Rng) -> Decl {
    let foreach = (0..rng.range_usize(0, 2))
        .map(|_| ForRange {
            var: sp(rng.pick(VARS).to_string()),
            // no calls: the foreach splitter treats `,` as a separator
            lo: sp(arbitrary_pexpr(rng, 1, false)),
            hi: sp(arbitrary_pexpr(rng, 1, false)),
        })
        .collect();
    let when = if rng.bool() { Some(spanned_pexpr(rng, true)) } else { None };
    Decl { body: arbitrary_body(rng), foreach, when, span: Span::default() }
}

/// A random description: always named with a fetch section, plus random
/// params, isa, mapper, and declarations.
pub fn arbitrary_description(rng: &mut Rng) -> Description {
    let n_params = rng.range_usize(0, 4);
    let mut params = Vec::new();
    for i in 0..n_params {
        params.push(Param {
            name: sp(format!("{}{i}", ident(rng))),
            value: sp(rng.range_u64(0, 1 << 40) as i64),
        });
    }
    Description {
        name: Some(arbitrary_template(rng)),
        params,
        isa: if rng.bool() { Some(ops_list(rng)) } else { None },
        fetch: Some(Fetch {
            imem: arbitrary_template(rng),
            imem_read_latency: spanned_pexpr(rng, true),
            imem_port_width: spanned_pexpr(rng, true),
            ifs: arbitrary_template(rng),
            ifs_latency: spanned_pexpr(rng, true),
            issue_buffer: spanned_pexpr(rng, true),
            span: Span::default(),
        }),
        mapper: if rng.bool() { Some(sp(ident(rng))) } else { None },
        sweep: if rng.range_u32(0, 3) == 0 { Some(arbitrary_sweep(rng)) } else { None },
        decls: (0..rng.range_usize(0, 6)).map(|_| arbitrary_decl(rng)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl::text::parse;
    use crate::testkit::Prop;

    #[test]
    fn description_roundtrips_through_pretty_printer() {
        Prop::new(0xACAD1).cases(256).run(|rng| {
            let ast = arbitrary_description(rng);
            let printed = ast.to_toml();
            let reparsed =
                parse(&printed).unwrap_or_else(|e| panic!("reparse failed: {e}\n---\n{printed}"));
            assert_eq!(ast, reparsed, "pretty-printed form:\n{printed}");
        });
    }

    #[test]
    fn pexpr_roundtrips_through_display() {
        Prop::new(0xACAD2).cases(512).run(|rng| {
            let e = arbitrary_pexpr(rng, 4, true);
            let printed = e.to_string();
            let reparsed = crate::acadl::text::parser::parse_pexpr(
                &printed,
                crate::acadl::text::Span::default(),
            )
            .unwrap_or_else(|d| panic!("reparse failed: {d}\n{printed}"));
            assert_eq!(e, reparsed, "printed: {printed}");
        });
    }
}
