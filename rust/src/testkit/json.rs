//! A minimal recursive-descent JSON parser for tests.
//!
//! The crate deliberately carries no serde; production code emits JSON by
//! hand (`obs::chrome`) and tests need to *check* that output is valid and
//! well-shaped. This parser accepts standard JSON (RFC 8259) with no
//! extensions and is not performance-sensitive — tests only.

use anyhow::{bail, ensure, Context as _};

use crate::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number, held as `f64`.
    Num(f64),
    /// A string (escapes decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in source order (keys may repeat; lookups take the first).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Object member lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {} (got {:?})",
            b as char,
            self.pos,
            self.peek().map(|c| c as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn eat_keyword(&mut self, kw: &str) -> Result<()> {
        ensure!(
            self.bytes[self.pos..].starts_with(kw.as_bytes()),
            "expected {kw:?} at byte {}",
            self.pos
        );
        self.pos += kw.len();
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_keyword("true").map(|()| Json::Bool(true)),
            Some(b'f') => self.eat_keyword("false").map(|()| Json::Bool(false)),
            Some(b'n') => self.eat_keyword("null").map(|()| Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string().context("object key")?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            members.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                other => {
                    bail!("expected ',' or '}}' at byte {} (got {:?})", self.pos, other)
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    bail!("expected ',' or ']' at byte {} (got {:?})", self.pos, other)
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .context("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).context("non-ascii \\u escape")?,
                                16,
                            )
                            .context("bad \\u escape")?;
                            // surrogate pairs are not needed by any test
                            // fixture; map them to the replacement char
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is a &str, so the
                    // byte stream is valid UTF-8 by construction)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).unwrap();
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text.parse().map_err(|_| anyhow::anyhow!("bad number {text:?}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_parse() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes_decode() {
        let v = Json::parse(r#""a\"b\\c\nd\tA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd\tA"));
    }

    #[test]
    fn containers_nest_and_lookups_work() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":null},"e":true}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::parse("[]").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1,}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn round_trips_a_chrome_style_event() {
        let src = r#"{"traceEvents":[{"name":"engine.kernel","cat":"obs","ph":"X","ts":2.500,"dur":1.500,"pid":1,"tid":3,"args":{"span_id":17,"parent":5,"kernel_hi":9,"note":"hit"}}],"displayTimeUnit":"ns"}"#;
        let v = Json::parse(src).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let ev = &events[0];
        assert_eq!(ev.get("name").and_then(Json::as_str), Some("engine.kernel"));
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("ts").and_then(Json::as_f64), Some(2.5));
        assert_eq!(ev.get("args").unwrap().get("note").and_then(Json::as_str), Some("hit"));
    }
}
