//! Fitting the stacked correction from paired (AIDG, DES) observations.
//!
//! Each class's correction is chosen from four candidate shapes by 2-fold
//! cross-validation (even/odd sample split), then refit on the full class
//! with a never-worse-than-identity guard: if the winner's in-sample error
//! exceeds the raw estimator's, the class keeps the identity correction.
//! Exact classes (every ratio exactly 1, e.g. the whole-graph regime on
//! in-order machines) short-circuit to identity with a zero-width residual
//! band, so calibrating an already-exact architecture changes nothing.

use std::collections::BTreeMap;

use super::features::PHI_DIM;
use super::model::{CalibrationModel, ClassModel, Correction, Mode};

/// One paired observation: an AIDG estimate and the DES ground truth for
/// the same (machine, kernel), plus the features the correction may use.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Architecture structural digest ([`crate::acadl::Diagram::content_digest`]).
    pub digest: u64,
    /// Estimator regime of the AIDG estimate.
    pub mode: Mode,
    /// Feature vector ([`super::features::phi`]).
    pub phi: [f64; PHI_DIM],
    /// Raw AIDG cycles.
    pub aidg: f64,
    /// DES ground-truth cycles.
    pub des: f64,
}

impl Sample {
    /// The correction target `DES / AIDG`.
    pub fn ratio(&self) -> f64 {
        self.des / self.aidg.max(1.0)
    }
}

/// Exact classes need at least this many samples to get their own model;
/// smaller groups fall through to the regime-pooled fit.
const MIN_CLASS_SAMPLES: usize = 3;
/// Safety margins widening the observed residual band: held-out kernels of
/// the same class may sit slightly outside the training min/max.
const LO_MARGIN: f64 = 0.90;
const HI_MARGIN: f64 = 1.10;
/// Ridge regularization of the linear candidate.
const RIDGE_LAMBDA: f64 = 1e-6;

/// Fit a [`CalibrationModel`] from a corpus: one model per exact class with
/// enough samples, one per estimator regime, and one global fallback.
pub fn train(samples: &[Sample]) -> CalibrationModel {
    crate::metrics::counters::CALIB_SAMPLES.add(samples.len() as u64);
    let mut by_class: BTreeMap<(u64, Mode), Vec<&Sample>> = BTreeMap::new();
    let mut by_mode: BTreeMap<Mode, Vec<&Sample>> = BTreeMap::new();
    for s in samples {
        by_class.entry((s.digest, s.mode)).or_default().push(s);
        by_mode.entry(s.mode).or_default().push(s);
    }
    let mut model = CalibrationModel::default();
    for (key, group) in &by_class {
        if group.len() >= MIN_CLASS_SAMPLES {
            model.classes.insert(*key, fit_class(group));
        }
    }
    for (mode, group) in &by_mode {
        model.modes.insert(*mode, fit_class(group));
    }
    if !samples.is_empty() {
        let all: Vec<&Sample> = samples.iter().collect();
        model.global = Some(fit_class(&all));
    }
    model
}

/// Candidate correction shapes, simplest first (ties in cross-validation
/// prefer the earlier candidate).
#[derive(Debug, Clone, Copy, PartialEq)]
enum Cand {
    Identity,
    Ratio,
    Piecewise,
    Linear,
}

const CANDIDATES: [Cand; 4] = [Cand::Identity, Cand::Ratio, Cand::Piecewise, Cand::Linear];

fn fit_class(group: &[&Sample]) -> ClassModel {
    // exact classes stay exact: identity with a zero-width band, so
    // calibrated == raw and ci_lo == ci_hi == cycles
    if group.iter().all(|s| (s.ratio() - 1.0).abs() < 1e-12) {
        return ClassModel {
            correction: Correction::Identity,
            lo: 1.0,
            hi: 1.0,
            samples: group.len(),
        };
    }

    // 2-fold cross-validation over an even/odd index split
    let fold_a: Vec<&Sample> = group.iter().step_by(2).copied().collect();
    let fold_b: Vec<&Sample> = group.iter().skip(1).step_by(2).copied().collect();
    let mut best = Cand::Identity;
    let mut best_err = f64::INFINITY;
    for cand in CANDIDATES {
        let mut err_sum = 0.0;
        let mut n = 0usize;
        let mut feasible = true;
        for (tr, te) in [(&fold_a, &fold_b), (&fold_b, &fold_a)] {
            let Some(corr) = fit_candidate(cand, tr) else {
                feasible = false;
                break;
            };
            for s in te.iter() {
                err_sum += pct_err(&corr, s);
                n += 1;
            }
        }
        if !feasible || n == 0 {
            continue;
        }
        let err = err_sum / n as f64;
        if err + 1e-9 < best_err {
            best_err = err;
            best = cand;
        }
    }

    // refit the winner on the whole class; guard: never worse than identity
    // in-sample
    let corr = fit_candidate(best, group).unwrap_or(Correction::Identity);
    let corr = if mean_err(&corr, group) <= mean_err(&Correction::Identity, group) {
        corr
    } else {
        Correction::Identity
    };

    // residual band: min/max of DES / calibrated with safety margins,
    // widened to include 1 so the interval always contains the point
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for s in group {
        let cal = (s.aidg * corr.predict(&s.phi)).max(1.0);
        let res = s.des / cal;
        lo = lo.min(res);
        hi = hi.max(res);
    }
    ClassModel {
        correction: corr,
        lo: (lo * LO_MARGIN).min(1.0),
        hi: (hi * HI_MARGIN).max(1.0),
        samples: group.len(),
    }
}

/// Absolute percentage error of a corrected estimate against the DES.
fn pct_err(corr: &Correction, s: &Sample) -> f64 {
    let cal = s.aidg * corr.predict(&s.phi);
    (cal - s.des).abs() / s.des.max(1.0)
}

fn mean_err(corr: &Correction, group: &[&Sample]) -> f64 {
    if group.is_empty() {
        return 0.0;
    }
    group.iter().map(|s| pct_err(corr, s)).sum::<f64>() / group.len() as f64
}

fn fit_candidate(cand: Cand, group: &[&Sample]) -> Option<Correction> {
    match cand {
        Cand::Identity => Some(Correction::Identity),
        Cand::Ratio => {
            if group.is_empty() {
                return None;
            }
            let mut ratios: Vec<f64> = group.iter().map(|s| s.ratio()).collect();
            ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let n = ratios.len();
            let median = if n % 2 == 1 {
                ratios[n / 2]
            } else {
                (ratios[n / 2 - 1] + ratios[n / 2]) / 2.0
            };
            Some(Correction::Ratio(median))
        }
        Cand::Piecewise => fit_piecewise(group),
        Cand::Linear => fit_linear(group),
    }
}

/// Up to three segments split at the terciles of `x = phi[1]`, each with
/// its own least-squares line `ratio ≈ a + b·x`.
fn fit_piecewise(group: &[&Sample]) -> Option<Correction> {
    if group.len() < 6 {
        return None;
    }
    let mut xs: Vec<(f64, f64)> = group.iter().map(|s| (s.phi[1], s.ratio())).collect();
    xs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n = xs.len();
    let mut cuts = vec![xs[n / 3].0, xs[2 * n / 3].0];
    cuts.dedup();
    // route each point through the same rule `predict` uses
    let seg_of = |x: f64| {
        let mut i = 0;
        while i < cuts.len() && x > cuts[i] {
            i += 1;
        }
        i
    };
    let mut lines = Vec::with_capacity(cuts.len() + 1);
    for seg in 0..=cuts.len() {
        let pts: Vec<(f64, f64)> = xs.iter().copied().filter(|&(x, _)| seg_of(x) == seg).collect();
        lines.push(line_fit(&pts));
    }
    Some(Correction::Piecewise { cuts, lines })
}

/// Least-squares line through `pts`; degenerate segments (under two points
/// or zero x-variance) fall back to a flat mean-ratio line.
fn line_fit(pts: &[(f64, f64)]) -> (f64, f64) {
    if pts.is_empty() {
        return (1.0, 0.0);
    }
    let n = pts.len() as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let sxx: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    if pts.len() < 2 || sxx < 1e-12 {
        return (my, 0.0);
    }
    let sxy: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Ridge least squares `(AᵀA + λI) w = Aᵀ r` over the full feature vector,
/// solved by Gaussian elimination with partial pivoting.
fn fit_linear(group: &[&Sample]) -> Option<Correction> {
    if group.len() < 8 {
        return None;
    }
    let mut ata = [[0.0f64; PHI_DIM]; PHI_DIM];
    let mut atr = [0.0f64; PHI_DIM];
    for s in group {
        let r = s.ratio();
        for i in 0..PHI_DIM {
            atr[i] += s.phi[i] * r;
            for j in 0..PHI_DIM {
                ata[i][j] += s.phi[i] * s.phi[j];
            }
        }
    }
    for (i, row) in ata.iter_mut().enumerate() {
        row[i] += RIDGE_LAMBDA;
    }
    solve(ata, atr).map(Correction::Linear)
}

fn solve(mut a: [[f64; PHI_DIM]; PHI_DIM], mut b: [f64; PHI_DIM]) -> Option<[f64; PHI_DIM]> {
    for col in 0..PHI_DIM {
        // partial pivot
        let mut piv = col;
        for row in col + 1..PHI_DIM {
            if a[row][col].abs() > a[piv][col].abs() {
                piv = row;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        for row in col + 1..PHI_DIM {
            let f = a[row][col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..PHI_DIM {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; PHI_DIM];
    for col in (0..PHI_DIM).rev() {
        let mut acc = b[col];
        for k in col + 1..PHI_DIM {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::features::phi_raw;

    fn sample(digest: u64, mode: Mode, insts: f64, aidg: f64, des: f64) -> Sample {
        Sample { digest, mode, phi: phi_raw(insts, 4.0, insts, 2.0, 1024.0), aidg, des }
    }

    #[test]
    fn exact_class_trains_to_identity_with_zero_band() {
        let samples: Vec<Sample> =
            (0..8).map(|i| sample(7, Mode::Whole, 100.0 * (i + 1) as f64, 500.0, 500.0)).collect();
        let m = train(&samples);
        let cm = m.lookup(7, Mode::Whole);
        assert_eq!(cm.correction, Correction::Identity);
        assert_eq!((cm.lo, cm.hi), (1.0, 1.0));
        let (cal, lo, hi) = cm.predict(&samples[0].phi, 12345);
        assert_eq!((cal, lo, hi), (12345, 12345, 12345));
    }

    #[test]
    fn constant_bias_is_corrected_by_a_ratio() {
        // AIDG systematically 20% under: ratio candidate must win and fix it
        let samples: Vec<Sample> = (0..10)
            .map(|i| {
                let a = 1000.0 + 50.0 * i as f64;
                sample(9, Mode::Fixed, a, a, a * 1.25)
            })
            .collect();
        let m = train(&samples);
        let cm = m.lookup(9, Mode::Fixed);
        let (cal, lo, hi) = cm.predict(&samples[0].phi, 1000);
        assert_eq!(cal, 1250);
        assert!(lo <= 1250 && 1250 <= hi);
        // in-sample error must beat raw
        let acc = crate::calib::evaluate(&m, &samples);
        assert!(acc.calibrated_mape < acc.raw_mape, "{acc:?}");
        assert_eq!(acc.ci_coverage, 1.0, "{acc:?}");
    }

    #[test]
    fn training_coverage_is_total_by_construction() {
        // noisy ratios: the residual band must still cover every training point
        let samples: Vec<Sample> = (0..20)
            .map(|i| {
                let a = 500.0 + 100.0 * i as f64;
                let noise = 1.0 + 0.15 * ((i * 37 % 11) as f64 - 5.0) / 5.0;
                sample(11, Mode::Fallback, a, a, a * noise)
            })
            .collect();
        let m = train(&samples);
        let acc = crate::calib::evaluate(&m, &samples);
        assert_eq!(acc.ci_coverage, 1.0, "{acc:?}");
        assert!(acc.calibrated_mape <= acc.raw_mape + 1e-9, "{acc:?}");
    }

    #[test]
    fn small_classes_fall_through_to_the_mode_model() {
        let mut samples: Vec<Sample> = (0..6)
            .map(|i| sample(21, Mode::Fixed, 100.0 * (i + 1) as f64, 1000.0, 1100.0))
            .collect();
        // a two-sample class: below MIN_CLASS_SAMPLES
        samples.push(sample(22, Mode::Fixed, 300.0, 1000.0, 1100.0));
        samples.push(sample(22, Mode::Fixed, 400.0, 1000.0, 1100.0));
        let m = train(&samples);
        assert!(m.classes.contains_key(&(21, Mode::Fixed)));
        assert!(!m.classes.contains_key(&(22, Mode::Fixed)));
        // digest 22 still gets corrected via the pooled Fixed model
        let cm = m.lookup(22, Mode::Fixed);
        assert!(cm.samples >= 8, "mode model pools everything: {cm:?}");
    }

    #[test]
    fn empty_corpus_trains_an_empty_model() {
        let m = train(&[]);
        assert_eq!(m.class_count(), 0);
        assert!(m.global.is_none());
        // lookup degrades to identity
        let (cal, lo, hi) = m.lookup(1, Mode::Whole).predict(&[1.0; PHI_DIM], 77);
        assert_eq!((cal, lo, hi), (77, 77, 77));
    }

    #[test]
    fn linear_solver_solves_a_known_system() {
        // diag(2) w = [2,4,6,8,10,12] -> w = [1,2,3,4,5,6]
        let mut a = [[0.0; PHI_DIM]; PHI_DIM];
        for (i, row) in a.iter_mut().enumerate() {
            row[i] = 2.0;
        }
        let b = [2.0, 4.0, 6.0, 8.0, 10.0, 12.0];
        let x = solve(a, b).unwrap();
        for (i, xi) in x.iter().enumerate() {
            assert!((xi - (i + 1) as f64).abs() < 1e-12);
        }
        // singular matrix is rejected
        assert!(solve([[0.0; PHI_DIM]; PHI_DIM], b).is_none());
    }
}
