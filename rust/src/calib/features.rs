//! Feature extraction: the kernel/architecture descriptors the correction
//! model is (piecewise-)linear in.
//!
//! Features are log-scaled (`log2(1 + x)`) — instruction counts and memory
//! footprints span six orders of magnitude across the corpus, and the
//! residual ratios the model predicts drift with *scale*, not with raw
//! counts. The leading constant 1 term makes every linear fit affine.

use crate::acadl::Diagram;
use crate::aidg::LayerEstimate;
use crate::isa::LoopKernel;

/// Number of terms in the feature vector [`phi`].
pub const PHI_DIM: usize = 6;

/// Memory words read + written by one loop iteration of `kernel`
/// (materializes the first iteration; the §6.3 template invariant makes it
/// representative of every iteration).
pub fn mem_accesses_per_iter(kernel: &LoopKernel) -> f64 {
    kernel
        .materialize(0..1)
        .iter()
        .map(|i| (i.read_addrs.len() + i.write_addrs.len()) as f64)
        .sum()
}

/// Feature vector of one layer estimate on `d`: constant term, then
/// log-scaled total instructions, instructions per iteration, memory
/// accesses, FU count, and memory words.
pub fn phi(e: &LayerEstimate, d: &Diagram, mem_accesses_per_iter: f64) -> [f64; PHI_DIM] {
    phi_raw(
        e.total_insts() as f64,
        e.insts_per_iter as f64,
        mem_accesses_per_iter * e.k as f64,
        d.fu_count() as f64,
        d.memory_words() as f64,
    )
}

/// [`phi`] from raw feature values (the bench path carries features without
/// keeping diagrams alive).
pub fn phi_raw(
    total_insts: f64,
    insts_per_iter: f64,
    mem_accesses: f64,
    fu_count: f64,
    mem_words: f64,
) -> [f64; PHI_DIM] {
    [1.0, lg(total_insts), lg(insts_per_iter), lg(mem_accesses), lg(fu_count), lg(mem_words)]
}

fn lg(x: f64) -> f64 {
    (1.0 + x.max(0.0)).log2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phi_is_log_scaled_with_affine_term() {
        let p = phi_raw(0.0, 0.0, 0.0, 0.0, 0.0);
        assert_eq!(p, [1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let p = phi_raw(1023.0, 3.0, 7.0, 1.0, 15.0);
        assert!((p[1] - 10.0).abs() < 1e-12);
        assert!((p[2] - 2.0).abs() < 1e-12);
        assert!((p[3] - 3.0).abs() < 1e-12);
        assert!((p[4] - 1.0).abs() < 1e-12);
        assert!((p[5] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn negative_inputs_clamp_to_zero() {
        assert_eq!(phi_raw(-5.0, -1.0, -1.0, -1.0, -1.0), phi_raw(0.0, 0.0, 0.0, 0.0, 0.0));
    }
}
