//! Calibrated estimates with error bars — an ANNETTE-style stacked
//! correction on top of the §6.3 AIDG estimator.
//!
//! The paper's headline claim is accuracy, and the repo's speed work (the
//! precompiled-program evaluator, lane batching) keeps rewriting the hot
//! path underneath it. This module keeps the claim checkable and *improves*
//! on the raw estimator where it is systematically biased:
//!
//! 1. [`sample`] draws a representative corpus of (machine × kernel) pairs
//!    — the paper architectures mapped over TC-ResNet8 plus seeded random
//!    scalar machines from the testkit generator family — and prices every
//!    pair through both the AIDG estimator and the independent
//!    cycle-accurate DES ([`crate::sim`]), the in-repo stand-in for the
//!    paper's RTL ground truth.
//! 2. [`train`] fits a per-class *stacked correction* (ANNETTE's trick,
//!    see PAPERS.md): samples are grouped by (architecture digest ×
//!    estimator regime), and each class gets the best of four candidate
//!    correction shapes — identity, a constant ratio, a piecewise-linear
//!    function of log-instruction-count, or a ridge least-squares model
//!    over the full feature vector — selected by 2-fold cross-validation
//!    with a never-worse-than-identity guard.
//! 3. [`model`] holds the fitted [`CalibrationModel`]: hierarchical class
//!    lookup (exact class → estimator regime → global → identity),
//!    multiplicative correction, and residual-quantile confidence bounds
//!    `[ci_lo, ci_hi]` stamped onto [`crate::aidg::LayerEstimate`].
//!
//! The engine ([`crate::engine::EstimationEngine::set_calibration`])
//! applies the model as a post-pass on the clones it hands out — cache
//! entries are never stamped, and with no model installed every estimate is
//! bit-identical to an uncalibrated build. `benches/perf_aidg.rs`'s
//! accuracy phase retrains on a fixed seed, evaluates on a held-out kernel
//! set, and emits `BENCH_accuracy.json`, which CI gates on raw/calibrated
//! MAPE and interval coverage. `docs/accuracy.md` documents the model and
//! the gate.

pub mod features;
pub mod model;
pub mod sample;
pub mod train;

pub use model::{CalibrationModel, ClassModel, Correction, Mode};
pub use sample::{paper_archs, sample_corpus, Corpus, SampleSpec};
pub use train::{train, Sample};

use crate::Result;

/// Accuracy of a model over a sample set: raw-AIDG vs calibrated MAPE
/// against the DES, and the fraction of DES cycle counts inside the
/// reported `[ci_lo, ci_hi]` intervals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Accuracy {
    /// MAPE of the raw AIDG estimates against the DES (percent).
    pub raw_mape: f64,
    /// MAPE of the calibrated estimates against the DES (percent).
    pub calibrated_mape: f64,
    /// Fraction of DES cycle counts inside `[ci_lo, ci_hi]` (0..=1).
    pub ci_coverage: f64,
    /// Samples evaluated.
    pub samples: usize,
}

/// Score `model` against a sample set (typically a held-out corpus drawn
/// with a different kernel seed than the one it was trained on).
pub fn evaluate(model: &CalibrationModel, samples: &[Sample]) -> Accuracy {
    let mut des = Vec::with_capacity(samples.len());
    let mut raw = Vec::with_capacity(samples.len());
    let mut cal = Vec::with_capacity(samples.len());
    let mut lo = Vec::with_capacity(samples.len());
    let mut hi = Vec::with_capacity(samples.len());
    for s in samples {
        let cm = model.lookup(s.digest, s.mode);
        let (c, l, h) = cm.predict(&s.phi, s.aidg.round() as u64);
        des.push(s.des);
        raw.push(s.aidg);
        cal.push(c as f64);
        lo.push(l as f64);
        hi.push(h as f64);
    }
    Accuracy {
        raw_mape: crate::metrics::mape(&des, &raw),
        calibrated_mape: crate::metrics::mape(&des, &cal),
        ci_coverage: crate::metrics::coverage(&des, &lo, &hi),
        samples: samples.len(),
    }
}

/// Sample a corpus with `spec`, train on it, and return both — the one-call
/// path behind the CLI's `calibrate` subcommand and `--calibrate` flag.
pub fn train_from_spec(spec: &SampleSpec) -> Result<(CalibrationModel, Corpus)> {
    let corpus = sample_corpus(spec)?;
    let model = train(&corpus.samples);
    Ok((model, corpus))
}
