//! Representative (machine × kernel) corpus sampling — the Jung et al.
//! performance-representatives idea (PAPERS.md): a small, seeded subset of
//! kernels suffices to characterize an architecture's estimator error.
//!
//! Two sources feed the corpus:
//!
//! - **The paper architectures × TC-ResNet8**: each of the five builder
//!   configurations is mapped over the zoo network and a seeded subset of
//!   its DES-affordable kernels is priced through both estimator and DES.
//! - **Random scalar machines**: the same generator family as the
//!   `aidg_vs_des` differential property test — random fetch widths, FU
//!   counts, latencies and memory ports — with random template kernels at
//!   both whole-graph-sized and extrapolation-sized iteration counts.
//!
//! Machines derive from `machine_seed` only, kernels from `kernel_seed` —
//! so a training corpus and a held-out corpus drawn with different kernel
//! seeds cover the *same* machine population (same digests, so exact-class
//! corrections transfer) on *disjoint* kernels.

use crate::acadl::{Diagram, Latency};
use crate::accel::{GemminiConfig, PlasticineConfig, SystolicConfig, UltraTrailConfig};
use crate::aidg::{estimate_layer, FixedPointConfig};
use crate::coordinator::Arch;
use crate::ids::{OpId, RegId};
use crate::isa::{Instruction, LoopKernel};
use crate::sim::simulate;
use crate::testkit::Rng;
use crate::Result;

use super::features::{mem_accesses_per_iter, phi};
use super::model::Mode;
use super::train::Sample;

/// Corpus shape. The defaults match the CI accuracy gate; anything
/// seed-like must stay fixed for the gate to be deterministic.
#[derive(Debug, Clone)]
pub struct SampleSpec {
    /// Seed of the random-machine population (shared between a training
    /// corpus and its held-out counterpart).
    pub machine_seed: u64,
    /// Seed of kernel generation/selection (varied to hold kernels out).
    pub kernel_seed: u64,
    /// Random scalar machines to generate.
    pub random_machines: usize,
    /// Random kernels per random machine (alternating small/large `k`).
    pub kernels_per_machine: usize,
    /// Kernels sampled per paper architecture from TC-ResNet8.
    pub paper_kernels_per_arch: usize,
    /// DES affordability cap: skip kernels above this instruction total.
    pub max_kernel_insts: u64,
}

impl Default for SampleSpec {
    fn default() -> Self {
        Self {
            machine_seed: 0xCA11B,
            kernel_seed: 0x7EA1,
            random_machines: 8,
            kernels_per_machine: 4,
            paper_kernels_per_arch: 5,
            max_kernel_insts: 200_000,
        }
    }
}

/// A sampled corpus: paired observations plus provenance counts.
#[derive(Debug, Clone, Default)]
pub struct Corpus {
    /// The paired (AIDG, DES) observations.
    pub samples: Vec<Sample>,
    /// Distinct machines observed (paper + random).
    pub machines: usize,
}

/// The five paper-architecture configurations the corpus samples from —
/// the same set the `aidg_vs_des` differential suite pins.
pub fn paper_archs() -> Vec<Arch> {
    vec![
        Arch::Systolic(SystolicConfig::new(2, 2)),
        Arch::Systolic(SystolicConfig::new(4, 4)),
        Arch::UltraTrail(UltraTrailConfig::default()),
        Arch::Gemmini(GemminiConfig::default()),
        Arch::Plasticine(PlasticineConfig::new(2, 3, 8)),
    ]
}

/// Draw a corpus per `spec`: deterministic given the seeds, including
/// iteration order (sample order only affects cross-validation fold
/// assignment, which is itself part of the pinned training procedure).
pub fn sample_corpus(spec: &SampleSpec) -> Result<Corpus> {
    let fp = FixedPointConfig::default();
    let mut corpus = Corpus::default();

    // --- paper architectures × TC-ResNet8 ---
    let net = crate::dnn::zoo::tc_resnet8();
    for (ai, arch) in paper_archs().iter().enumerate() {
        let mapper = arch.mapper()?;
        let d = mapper.diagram();
        let digest = d.content_digest();
        let mapped = mapper.map_network(&net)?;
        let kernels: Vec<&LoopKernel> = mapped
            .iter()
            .filter(|ml| !ml.fused)
            .flat_map(|ml| ml.kernels.iter())
            .filter(|k| k.total_insts() <= spec.max_kernel_insts)
            .collect();
        if kernels.is_empty() {
            continue;
        }
        corpus.machines += 1;
        let want = spec.paper_kernels_per_arch.min(kernels.len());
        let mut rng =
            Rng::new(spec.kernel_seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(ai as u64 + 1));
        let mut picked = std::collections::BTreeSet::new();
        // over-draw to collect `want` distinct indices deterministically
        for _ in 0..kernels.len() * 4 {
            if picked.len() >= want {
                break;
            }
            picked.insert(rng.range_usize(0, kernels.len() - 1));
        }
        for &i in &picked {
            corpus.samples.push(observe(d, digest, kernels[i], &fp)?);
        }
    }

    // --- random scalar machines × random template kernels ---
    for m in 0..spec.random_machines {
        let mut mrng = Rng::new(
            spec.machine_seed.wrapping_add((m as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)),
        );
        let (d, ops, regs) = random_machine(&mut mrng);
        let digest = d.content_digest();
        corpus.machines += 1;
        let mut krng = Rng::new(
            spec.kernel_seed.wrapping_add((m as u64 + 1).wrapping_mul(0xD1B54A32D192ED03)),
        );
        for j in 0..spec.kernels_per_machine {
            // alternate whole-graph-sized and extrapolation-sized kernels so
            // both the Whole and the Fixed/Fallback regimes get samples
            let kern = random_kernel(&mut krng, &ops, &regs, j % 2 == 1);
            corpus.samples.push(observe(&d, digest, &kern, &fp)?);
        }
    }
    Ok(corpus)
}

/// Price one kernel through the §6.3 estimator and the DES and package the
/// pair as a training observation.
fn observe(d: &Diagram, digest: u64, k: &LoopKernel, fp: &FixedPointConfig) -> Result<Sample> {
    let e = estimate_layer(d, k, fp)?;
    let des = simulate(d, k, 0..k.k)?.cycles;
    Ok(Sample {
        digest,
        mode: Mode::of(&e),
        phi: phi(&e, d, mem_accesses_per_iter(k)),
        aidg: e.cycles as f64,
        des: des as f64,
    })
}

/// A random in-order scalar machine — the `aidg_vs_des` property-test
/// generator family: random fetch port/buffer widths, 1–3 single-op FUs
/// with random fixed latencies, one memory with random port widths.
fn random_machine(rng: &mut Rng) -> (Diagram, Vec<OpId>, Vec<RegId>) {
    let mut d = Diagram::new("calib-rand");
    let p = rng.range_u32(1, 3);
    let ib = rng.range_u32(1, 4).max(p);
    let (_im, ifs) = d.add_fetch("imem", 1, p, "ifs", 1, ib);
    let n_fu = rng.range_usize(1, 3);
    let (rf, regs) = d.add_regfile("rf", "r", 6);
    let mem = d.add_memory(
        "m",
        rng.range_u64(1, 4),
        rng.range_u64(1, 4),
        rng.range_u32(1, 2),
        rng.range_u32(1, 2),
        0,
        1 << 20,
    );
    for i in 0..n_fu {
        let es = d.add_execute_stage(&format!("es{i}"));
        let fu = d.add_fu(
            es,
            &format!("fu{i}"),
            Latency::Fixed(rng.range_u64(1, 3)),
            &[&format!("op{i}"), &format!("ld{i}"), &format!("st{i}")],
        );
        d.forward(ifs, es);
        d.fu_reads(fu, rf);
        d.fu_writes(fu, rf);
        d.mem_reads(fu, mem);
        d.mem_writes(fu, mem);
    }
    let ops: Vec<OpId> = (0..n_fu)
        .flat_map(|i| {
            [d.op(&format!("op{i}")), d.op(&format!("ld{i}")), d.op(&format!("st{i}"))]
        })
        .collect();
    d.finalize().unwrap();
    (d, ops, regs)
}

/// A random template kernel over `ops`: 2–6 instruction prototypes in
/// register/load/store modes. `big` kernels run enough iterations for the
/// fixed-point extrapolation (or its fallback) to engage; small ones stay
/// in the whole-graph regime.
fn random_kernel(rng: &mut Rng, ops: &[OpId], regs: &[RegId], big: bool) -> LoopKernel {
    let n_instr = rng.range_usize(2, 6);
    let mut protos = Vec::new();
    for _ in 0..n_instr {
        let op = *rng.pick(ops);
        let r1 = regs[rng.range_usize(0, regs.len() - 1)];
        let r2 = regs[rng.range_usize(0, regs.len() - 1)];
        let mode = rng.range_u32(0, 2);
        protos.push((op, r1, r2, mode));
    }
    let k = if big { rng.range_u64(80, 400) } else { rng.range_u64(3, 40) };
    LoopKernel::new(
        "calib-rand",
        k,
        n_instr,
        Box::new(move |it, buf| {
            for (i, &(op, r1, r2, mode)) in protos.iter().enumerate() {
                let mut instr = Instruction::new(op);
                match mode {
                    0 => instr = instr.reads(&[r1]).writes(&[r2]),
                    1 => instr = instr.writes(&[r1]).read_mem(&[it * 8 + i as u64]),
                    _ => instr = instr.reads(&[r1]).write_mem(&[4096 + it * 8 + i as u64]),
                }
                buf.push(instr);
            }
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(kernel_seed: u64) -> SampleSpec {
        SampleSpec {
            kernel_seed,
            random_machines: 3,
            kernels_per_machine: 2,
            paper_kernels_per_arch: 1,
            ..Default::default()
        }
    }

    #[test]
    fn corpus_is_deterministic_for_a_seed() {
        let a = sample_corpus(&tiny_spec(0x7EA1)).unwrap();
        let b = sample_corpus(&tiny_spec(0x7EA1)).unwrap();
        assert!(!a.samples.is_empty());
        assert_eq!(a.machines, b.machines);
        assert_eq!(a.samples, b.samples);
    }

    #[test]
    fn kernel_seed_varies_kernels_but_not_machines() {
        let a = sample_corpus(&tiny_spec(0x7EA1)).unwrap();
        let b = sample_corpus(&tiny_spec(0xB0B0)).unwrap();
        let digests = |c: &Corpus| {
            let mut ds: Vec<u64> = c.samples.iter().map(|s| s.digest).collect();
            ds.dedup();
            ds
        };
        // same machine population (class models transfer to the held-out set)
        assert_eq!(digests(&a), digests(&b));
        // but not the same observations
        assert_ne!(a.samples, b.samples);
    }
}
