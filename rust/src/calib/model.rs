//! The fitted calibration model: per-class corrections, residual bounds,
//! hierarchical lookup, and a line-based text persistence format.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::Context as _;

use crate::acadl::Diagram;
use crate::aidg::LayerEstimate;
use crate::Result;

use super::features::{mem_accesses_per_iter, phi, PHI_DIM};
use crate::isa::LoopKernel;

/// Estimator regime of a layer estimate — half of the calibration class
/// key. The three §6.3 regimes have categorically different error shapes:
/// whole-graph evaluation is exact by construction, fixed-point
/// extrapolation carries the eq. 2 stride bias, and the fallback heuristic
/// (eqs. 9–13) averages over oscillation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Mode {
    /// All iterations evaluated (`whole_graph`).
    Whole,
    /// Fixed-point extrapolation (eq. 2).
    Fixed,
    /// Fallback heuristic (eqs. 9–13).
    Fallback,
}

impl Mode {
    /// The regime a layer estimate was produced under.
    pub fn of(e: &LayerEstimate) -> Mode {
        if e.whole_graph {
            Mode::Whole
        } else if e.used_fallback {
            Mode::Fallback
        } else {
            Mode::Fixed
        }
    }

    /// Stable serialization name.
    pub fn name(self) -> &'static str {
        match self {
            Mode::Whole => "whole",
            Mode::Fixed => "fixed",
            Mode::Fallback => "fallback",
        }
    }

    /// Inverse of [`Mode::name`].
    pub fn parse(s: &str) -> Option<Mode> {
        match s {
            "whole" => Some(Mode::Whole),
            "fixed" => Some(Mode::Fixed),
            "fallback" => Some(Mode::Fallback),
            _ => None,
        }
    }
}

/// Predicted ratios are clamped to this range — a fit extrapolated far
/// outside its training support must not produce absurd corrections.
const RATIO_CLAMP: (f64, f64) = (0.05, 20.0);

/// A correction function mapping a feature vector to a multiplicative
/// ratio: `calibrated = raw · predict(phi)`.
#[derive(Debug, Clone, PartialEq)]
pub enum Correction {
    /// No correction (ratio 1) — always a candidate, so calibration can
    /// never be selected into something worse than the raw estimate on the
    /// training set.
    Identity,
    /// A constant ratio (the class's median `DES / AIDG`).
    Ratio(f64),
    /// Piecewise-linear in `x = phi[1]` (log₂ total instructions): segment
    /// `i` applies while `x ≤ cuts[i]`, the last segment is unbounded.
    Piecewise {
        /// Segment upper bounds in `x` (`lines.len() - 1` entries).
        cuts: Vec<f64>,
        /// Per-segment `(intercept, slope)`.
        lines: Vec<(f64, f64)>,
    },
    /// Ridge least-squares over the full feature vector.
    Linear([f64; PHI_DIM]),
}

impl Correction {
    /// The multiplicative correction for a feature vector.
    pub fn predict(&self, phi: &[f64; PHI_DIM]) -> f64 {
        let r = match self {
            Correction::Identity => 1.0,
            Correction::Ratio(r) => *r,
            Correction::Piecewise { cuts, lines } => {
                let x = phi[1];
                let mut i = 0;
                while i < cuts.len() && x > cuts[i] {
                    i += 1;
                }
                let (a, b) = lines[i];
                a + b * x
            }
            Correction::Linear(w) => w.iter().zip(phi).map(|(w, p)| w * p).sum(),
        };
        r.clamp(RATIO_CLAMP.0, RATIO_CLAMP.1)
    }
}

/// One class's fitted correction plus its residual band. `lo`/`hi` bound
/// the ratio `DES / calibrated` observed in training (min/max with a safety
/// margin, widened to include 1), so on the training set every DES value
/// falls inside the emitted interval by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassModel {
    /// The correction function.
    pub correction: Correction,
    /// Lower residual bound (`≤ 1`).
    pub lo: f64,
    /// Upper residual bound (`≥ 1`).
    pub hi: f64,
    /// Training samples behind the fit.
    pub samples: usize,
}

/// The do-nothing class model: ratio 1, zero-width band.
const IDENTITY: ClassModel =
    ClassModel { correction: Correction::Identity, lo: 1.0, hi: 1.0, samples: 0 };

impl ClassModel {
    /// Calibrated cycles and `[ci_lo, ci_hi]` bounds for a raw estimate.
    /// The interval always contains the calibrated point.
    pub fn predict(&self, phi: &[f64; PHI_DIM], cycles: u64) -> (u64, u64, u64) {
        let r = self.correction.predict(phi);
        let cal = ((cycles as f64) * r).round().max(0.0) as u64;
        let lo = ((cal as f64) * self.lo).floor() as u64;
        let hi = ((cal as f64) * self.hi).ceil() as u64;
        (cal, lo.min(cal), hi.max(cal))
    }
}

/// The whole stacked correction model. Lookup is hierarchical: an exact
/// (architecture digest × regime) class if the corpus had enough samples of
/// it, else the regime-pooled model, else the global model, else identity —
/// so an architecture the model has never seen degrades gracefully instead
/// of failing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibrationModel {
    /// Exact (architecture digest, regime) classes.
    pub classes: BTreeMap<(u64, Mode), ClassModel>,
    /// Regime-pooled fallbacks for unseen architectures.
    pub modes: BTreeMap<Mode, ClassModel>,
    /// Last-resort model pooled over the whole corpus.
    pub global: Option<ClassModel>,
}

impl CalibrationModel {
    /// Number of exact (architecture, regime) classes.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// Hierarchical class lookup (exact → regime → global → identity).
    pub fn lookup(&self, digest: u64, mode: Mode) -> &ClassModel {
        self.classes
            .get(&(digest, mode))
            .or_else(|| self.modes.get(&mode))
            .or(self.global.as_ref())
            .unwrap_or(&IDENTITY)
    }

    /// Stamp `calibrated_cycles`/`ci_lo`/`ci_hi` onto a layer estimate
    /// (`ma_per_iter` from [`mem_accesses_per_iter`], computed before the
    /// kernel is moved into a worker on the pooled paths).
    pub fn apply(&self, d: &Diagram, ma_per_iter: f64, e: &mut LayerEstimate) {
        let p = phi(e, d, ma_per_iter);
        let (cal, lo, hi) = self.lookup(d.content_digest(), Mode::of(e)).predict(&p, e.cycles);
        e.calibrated_cycles = Some(cal);
        e.ci_lo = Some(lo);
        e.ci_hi = Some(hi);
        crate::metrics::counters::CALIB_LAYERS.add(1);
    }

    /// [`Self::apply`] computing the per-iteration memory accesses from the
    /// kernel directly (the serial engine path, where the kernel is still
    /// at hand).
    pub fn apply_kernel(&self, d: &Diagram, kern: &LoopKernel, e: &mut LayerEstimate) {
        self.apply(d, mem_accesses_per_iter(kern), e);
    }

    /// Serialize to the `acadl-calib v1` line format (deterministic:
    /// classes in `BTreeMap` order, floats via Rust's shortest round-trip
    /// `Display`).
    pub fn to_text(&self) -> String {
        let mut out = String::from("acadl-calib v1\n");
        for ((digest, mode), cm) in &self.classes {
            let _ = write!(out, "class {digest} {} ", mode.name());
            write_class(&mut out, cm);
        }
        for (mode, cm) in &self.modes {
            let _ = write!(out, "mode {} ", mode.name());
            write_class(&mut out, cm);
        }
        if let Some(cm) = &self.global {
            out.push_str("global ");
            write_class(&mut out, cm);
        }
        out
    }

    /// Parse the [`Self::to_text`] format.
    pub fn parse(src: &str) -> Result<Self> {
        let mut lines = src.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().unwrap_or("");
        if header.trim() != "acadl-calib v1" {
            anyhow::bail!("calibration file: expected 'acadl-calib v1' header, got {header:?}");
        }
        let mut model = CalibrationModel::default();
        for line in lines {
            let mut toks = line.split_whitespace();
            match toks.next() {
                Some("class") => {
                    let digest: u64 = next_num(&mut toks, "class digest")?;
                    let mode = toks
                        .next()
                        .and_then(Mode::parse)
                        .ok_or_else(|| anyhow::anyhow!("calibration file: bad mode in {line:?}"))?;
                    model.classes.insert((digest, mode), parse_class(&mut toks, line)?);
                }
                Some("mode") => {
                    let mode = toks
                        .next()
                        .and_then(Mode::parse)
                        .ok_or_else(|| anyhow::anyhow!("calibration file: bad mode in {line:?}"))?;
                    model.modes.insert(mode, parse_class(&mut toks, line)?);
                }
                Some("global") => {
                    model.global = Some(parse_class(&mut toks, line)?);
                }
                Some(other) => {
                    anyhow::bail!("calibration file: unknown record {other:?} in {line:?}")
                }
                None => {}
            }
        }
        Ok(model)
    }

    /// Write the model to `path` in the text format.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing calibration model {}", path.display()))
    }

    /// Load a model persisted with [`Self::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration model {}", path.display()))?;
        Self::parse(&text)
    }
}

fn write_class(out: &mut String, cm: &ClassModel) {
    let _ = write!(out, "{} {} {} ", cm.samples, cm.lo, cm.hi);
    match &cm.correction {
        Correction::Identity => out.push_str("identity"),
        Correction::Ratio(r) => {
            let _ = write!(out, "ratio {r}");
        }
        Correction::Piecewise { cuts, lines } => {
            let _ = write!(out, "pw {}", lines.len());
            for c in cuts {
                let _ = write!(out, " {c}");
            }
            for (a, b) in lines {
                let _ = write!(out, " {a} {b}");
            }
        }
        Correction::Linear(w) => {
            out.push_str("lin");
            for wi in w {
                let _ = write!(out, " {wi}");
            }
        }
    }
    out.push('\n');
}

fn next_num<T: std::str::FromStr>(
    toks: &mut std::str::SplitWhitespace<'_>,
    what: &str,
) -> Result<T> {
    toks.next()
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("calibration file: missing/bad {what}"))
}

fn parse_class(toks: &mut std::str::SplitWhitespace<'_>, line: &str) -> Result<ClassModel> {
    let samples: usize = next_num(toks, "sample count")?;
    let lo: f64 = next_num(toks, "lo bound")?;
    let hi: f64 = next_num(toks, "hi bound")?;
    let correction = match toks.next() {
        Some("identity") => Correction::Identity,
        Some("ratio") => Correction::Ratio(next_num(toks, "ratio")?),
        Some("pw") => {
            let n: usize = next_num(toks, "segment count")?;
            if n == 0 {
                anyhow::bail!("calibration file: empty piecewise correction in {line:?}");
            }
            let mut cuts = Vec::with_capacity(n - 1);
            for _ in 0..n - 1 {
                cuts.push(next_num(toks, "piecewise cut")?);
            }
            let mut lines = Vec::with_capacity(n);
            for _ in 0..n {
                lines.push((next_num(toks, "intercept")?, next_num(toks, "slope")?));
            }
            Correction::Piecewise { cuts, lines }
        }
        Some("lin") => {
            let mut w = [0.0; PHI_DIM];
            for wi in &mut w {
                *wi = next_num(toks, "linear weight")?;
            }
            Correction::Linear(w)
        }
        other => anyhow::bail!("calibration file: unknown correction {other:?} in {line:?}"),
    };
    Ok(ClassModel { correction, lo, hi, samples })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_falls_through_the_hierarchy() {
        let mut m = CalibrationModel::default();
        assert_eq!(m.lookup(1, Mode::Fixed), &IDENTITY);
        m.global = Some(ClassModel {
            correction: Correction::Ratio(2.0),
            lo: 0.9,
            hi: 1.1,
            samples: 4,
        });
        assert_eq!(m.lookup(1, Mode::Fixed).correction, Correction::Ratio(2.0));
        m.modes.insert(
            Mode::Fixed,
            ClassModel { correction: Correction::Ratio(3.0), lo: 0.9, hi: 1.1, samples: 4 },
        );
        assert_eq!(m.lookup(1, Mode::Fixed).correction, Correction::Ratio(3.0));
        assert_eq!(m.lookup(1, Mode::Whole).correction, Correction::Ratio(2.0));
        m.classes.insert(
            (1, Mode::Fixed),
            ClassModel { correction: Correction::Identity, lo: 1.0, hi: 1.0, samples: 4 },
        );
        assert_eq!(m.lookup(1, Mode::Fixed).correction, Correction::Identity);
    }

    #[test]
    fn predict_interval_contains_the_point() {
        let cm = ClassModel { correction: Correction::Ratio(1.5), lo: 0.8, hi: 1.3, samples: 9 };
        let p = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let (cal, lo, hi) = cm.predict(&p, 1000);
        assert_eq!(cal, 1500);
        assert!(lo <= cal && cal <= hi, "{lo} <= {cal} <= {hi}");
        assert_eq!(lo, 1200);
        assert_eq!(hi, 1950);
    }

    #[test]
    fn piecewise_routes_by_log_instructions() {
        let c = Correction::Piecewise {
            cuts: vec![2.0, 4.0],
            lines: vec![(1.0, 0.0), (2.0, 0.0), (0.0, 1.0)],
        };
        let at = |x: f64| c.predict(&[1.0, x, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(at(1.0), 1.0);
        assert_eq!(at(2.0), 1.0); // boundary belongs to the left segment
        assert_eq!(at(3.0), 2.0);
        assert_eq!(at(5.0), 5.0);
    }

    #[test]
    fn predict_clamps_extrapolated_ratios() {
        let c = Correction::Linear([1000.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(c.predict(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0]), RATIO_CLAMP.1);
        let c = Correction::Ratio(1e-9);
        assert_eq!(c.predict(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0]), RATIO_CLAMP.0);
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let mut m = CalibrationModel::default();
        m.classes.insert(
            (0xDEAD_BEEF, Mode::Fixed),
            ClassModel {
                correction: Correction::Piecewise {
                    cuts: vec![10.25],
                    lines: vec![(1.0125, -0.003), (0.97, 0.0001)],
                },
                lo: 0.8612345,
                hi: 1.19999,
                samples: 12,
            },
        );
        m.classes.insert(
            (1, Mode::Whole),
            ClassModel { correction: Correction::Identity, lo: 1.0, hi: 1.0, samples: 40 },
        );
        m.modes.insert(
            Mode::Fallback,
            ClassModel {
                correction: Correction::Linear([0.9, 0.01, -0.02, 0.0, 0.3, -0.125]),
                lo: 0.5,
                hi: 2.0,
                samples: 33,
            },
        );
        m.global = Some(ClassModel {
            correction: Correction::Ratio(1.0625),
            lo: 0.75,
            hi: 1.25,
            samples: 85,
        });
        let text = m.to_text();
        let back = CalibrationModel::parse(&text).unwrap();
        assert_eq!(back, m);
        // serialization is deterministic
        assert_eq!(back.to_text(), text);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(CalibrationModel::parse("").is_err());
        assert!(CalibrationModel::parse("not-a-header\n").is_err());
        assert!(CalibrationModel::parse("acadl-calib v1\nclass x fixed 1 1 1 identity\n").is_err());
        assert!(CalibrationModel::parse("acadl-calib v1\nclass 1 bogus 1 1 1 identity\n").is_err());
        assert!(CalibrationModel::parse("acadl-calib v1\nglobal 1 0.9 1.1 warp 3\n").is_err());
        assert!(CalibrationModel::parse("acadl-calib v1\nwhat 1\n").is_err());
        // truncated linear weights
        assert!(CalibrationModel::parse("acadl-calib v1\nglobal 1 0.9 1.1 lin 1 2 3\n").is_err());
    }
}
