//! Abstract instructions and loop kernels.
//!
//! ACADL is instruction-centric: any architectural state change is triggered
//! by an instruction. Instructions are *not* limited to fine-grained
//! operations — a single instruction may be a scalar `mac`, a tiled-GEMM
//! `compute`, or a whole fused `conv_ext` layer; the abstraction level of the
//! instruction stream must match the abstraction level of the ACADL model
//! (paper §4/§5).
//!
//! A DNN layer maps to a [`LoopKernel`]: a fixed instruction *template*
//! executed `k` times where consecutive iterations differ only in memory
//! addresses (dataflow-driven, no control flow — paper §6.3). The kernel
//! therefore carries a generator closure producing the concrete instructions
//! of iteration `it`.

use crate::ids::{Addr, OpId, RegId};

pub mod emit;

pub use emit::{EmitBuf, InstrBuilder, InstrView};

/// One abstract instruction occupying hardware modules as it propagates
/// through an ACADL object diagram.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Instruction {
    /// Mnemonic (interned in the diagram the stream targets).
    pub op: OpId,
    /// Registers read when the instruction executes.
    pub read_regs: Vec<RegId>,
    /// Registers written when the instruction executes.
    pub write_regs: Vec<RegId>,
    /// Memory addresses read (word granular).
    pub read_addrs: Vec<Addr>,
    /// Memory addresses written.
    pub write_addrs: Vec<Addr>,
    /// Immediate values; also the latency-expression inputs (`imm0`, ...).
    pub imms: Vec<i64>,
}

impl Instruction {
    /// A bare instruction of op `op`.
    pub fn new(op: OpId) -> Self {
        Self {
            op,
            read_regs: Vec::new(),
            write_regs: Vec::new(),
            read_addrs: Vec::new(),
            write_addrs: Vec::new(),
            imms: Vec::new(),
        }
    }

    /// Add register reads (builder style).
    pub fn reads(mut self, regs: &[RegId]) -> Self {
        self.read_regs.extend_from_slice(regs);
        self
    }

    /// Add register writes.
    pub fn writes(mut self, regs: &[RegId]) -> Self {
        self.write_regs.extend_from_slice(regs);
        self
    }

    /// Add memory reads (word addresses).
    pub fn read_mem(mut self, addrs: &[Addr]) -> Self {
        self.read_addrs.extend_from_slice(addrs);
        self
    }

    /// Add memory writes.
    pub fn write_mem(mut self, addrs: &[Addr]) -> Self {
        self.write_addrs.extend_from_slice(addrs);
        self
    }

    /// Append one immediate.
    pub fn imm(mut self, v: i64) -> Self {
        self.imms.push(v);
        self
    }

    /// Append several immediates.
    pub fn imms(mut self, vs: &[i64]) -> Self {
        self.imms.extend_from_slice(vs);
        self
    }

    /// True if the instruction touches memory at all.
    pub fn accesses_memory(&self) -> bool {
        !self.read_addrs.is_empty() || !self.write_addrs.is_empty()
    }

    /// Borrowed field-sliced view of this instruction (the arena-emitted
    /// form; see [`emit::InstrView`]).
    pub fn view(&self) -> InstrView<'_> {
        InstrView {
            op: self.op,
            read_regs: &self.read_regs,
            write_regs: &self.write_regs,
            read_addrs: &self.read_addrs,
            write_addrs: &self.write_addrs,
            imms: &self.imms,
        }
    }

    /// Stream every estimation-relevant field as `u64` words into `sink`
    /// (field lengths included, so adjacent fields cannot alias). This is
    /// the per-instruction ingredient of the engine's content-addressed
    /// kernel fingerprint ([`crate::engine`]): two instructions emitting the
    /// same word stream route and time identically on a given diagram.
    /// Delegates to [`InstrView::content_words`] so arena-emitted and
    /// materialized instructions share one stream definition.
    pub fn content_words(&self, sink: &mut impl FnMut(u64)) {
        self.view().content_words(sink);
    }
}

/// Generator of the concrete instructions of iteration `it` of a loop
/// kernel, emitting into a reusable [`EmitBuf`] arena (zero allocations per
/// iteration once the arena is warm).
pub type IterGen = Box<dyn Fn(u64, &mut EmitBuf) + Send + Sync>;

/// A mapped DNN layer: `k` iterations of a fixed instruction template.
pub struct LoopKernel {
    /// Human-readable label (layer name + mapping info).
    pub label: String,
    /// Total loop iterations `k` for the full layer.
    pub k: u64,
    /// Instructions per iteration `|I|` (constant across iterations).
    pub insts_per_iter: usize,
    /// Produces iteration `it`'s instructions (appends to the buffer).
    gen: IterGen,
}

impl LoopKernel {
    /// A kernel of `k` iterations emitting `insts_per_iter` instructions
    /// each through `gen`.
    pub fn new(label: impl Into<String>, k: u64, insts_per_iter: usize, gen: IterGen) -> Self {
        Self { label: label.into(), k, insts_per_iter, gen }
    }

    /// Append iteration `it`'s instructions to the emission arena — the
    /// evaluator's hot path (allocation-free once `buf`'s pools are warm).
    pub fn emit_into(&self, it: u64, buf: &mut EmitBuf) {
        let before = buf.len();
        (self.gen)(it, buf);
        debug_assert_eq!(
            buf.len() - before,
            self.insts_per_iter,
            "kernel {} emitted wrong instruction count at iter {}",
            self.label,
            it
        );
    }

    /// Append iteration `it`'s instructions to `buf` as owned
    /// [`Instruction`]s (compatibility path for the simulator and tests;
    /// allocates — use [`Self::emit_into`] on hot paths).
    pub fn emit(&self, it: u64, buf: &mut Vec<Instruction>) {
        let mut eb = EmitBuf::new();
        self.emit_into(it, &mut eb);
        buf.extend(eb.iter().map(|v| v.to_instruction()));
    }

    /// Materialize a range of iterations (mostly for tests / the simulator).
    pub fn materialize(&self, iters: std::ops::Range<u64>) -> Vec<Instruction> {
        let mut buf = Vec::with_capacity(self.insts_per_iter * (iters.end - iters.start) as usize);
        let mut eb = EmitBuf::new();
        for it in iters {
            eb.clear();
            self.emit_into(it, &mut eb);
            buf.extend(eb.iter().map(|v| v.to_instruction()));
        }
        buf
    }

    /// Total instructions over all `k` iterations.
    pub fn total_insts(&self) -> u64 {
        self.k * self.insts_per_iter as u64
    }

    /// Stream the instruction content of iterations `iters` into `sink`
    /// (see [`Instruction::content_words`]). The kernel's *label* is
    /// deliberately not part of the stream: identically shaped layers map
    /// to identical instruction streams under different labels, and the
    /// engine's deduplication keys on content, not names.
    pub fn content_words(&self, iters: std::ops::Range<u64>, sink: &mut impl FnMut(u64)) {
        let mut buf = EmitBuf::new();
        for it in iters {
            buf.clear();
            self.emit_into(it, &mut buf);
            for view in buf.iter() {
                view.content_words(sink);
            }
        }
    }
}

impl std::fmt::Debug for LoopKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LoopKernel")
            .field("label", &self.label)
            .field("k", &self.k)
            .field("insts_per_iter", &self.insts_per_iter)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let i = Instruction::new(OpId(3))
            .reads(&[RegId(1)])
            .writes(&[RegId(2)])
            .read_mem(&[10])
            .write_mem(&[20])
            .imm(7);
        assert_eq!(i.op, OpId(3));
        assert_eq!(i.read_regs, vec![RegId(1)]);
        assert_eq!(i.write_regs, vec![RegId(2)]);
        assert_eq!(i.read_addrs, vec![10]);
        assert_eq!(i.write_addrs, vec![20]);
        assert_eq!(i.imms, vec![7]);
        assert!(i.accesses_memory());
        assert!(!Instruction::new(OpId(0)).accesses_memory());
    }

    #[test]
    fn content_words_capture_all_fields() {
        let collect = |i: &Instruction| {
            let mut w = Vec::new();
            i.content_words(&mut |x| w.push(x));
            w
        };
        let base = Instruction::new(OpId(3)).reads(&[RegId(1)]).read_mem(&[10]);
        assert_eq!(collect(&base), collect(&base.clone()));
        // every field perturbs the stream, and length prefixes prevent
        // adjacent fields from aliasing (reg 1 + no addr != no reg + addr 1)
        assert_ne!(collect(&base), collect(&base.clone().imm(0)));
        assert_ne!(collect(&base), collect(&base.clone().writes(&[RegId(1)])));
        let a = Instruction::new(OpId(0)).reads(&[RegId(1)]);
        let b = Instruction::new(OpId(0)).read_mem(&[1]);
        assert_ne!(collect(&a), collect(&b));
    }

    #[test]
    fn kernel_materializes_iterations() {
        let k = LoopKernel::new(
            "t",
            4,
            2,
            Box::new(|it, buf| {
                buf.push(Instruction::new(OpId(0)).read_mem(&[it * 8]));
                buf.push(Instruction::new(OpId(1)).write_mem(&[100 + it * 8]));
            }),
        );
        let insts = k.materialize(0..4);
        assert_eq!(insts.len(), 8);
        assert_eq!(insts[0].read_addrs, vec![0]);
        assert_eq!(insts[6].read_addrs, vec![24]);
        assert_eq!(k.total_insts(), 8);
    }
}
