//! Reusable instruction-emission arena — the allocation-free counterpart of
//! `Vec<Instruction>`.
//!
//! A [`LoopKernel`](super::LoopKernel) generator runs once per evaluated
//! iteration, and with the old AoS representation every emitted
//! [`Instruction`](super::Instruction) heap-allocated five `Vec`s
//! (registers, addresses, immediates). [`EmitBuf`] stores the same data in
//! struct-of-arrays form: one flat pool per operand field plus per-
//! instruction end offsets, so a cleared buffer re-emits the next iteration
//! into already-allocated capacity. Operand slices are *interned* into the
//! pools by the builder returned from [`EmitBuf::instr`]; readers get them
//! back as borrowed [`InstrView`] slices without any per-instruction
//! indirection.
//!
//! The arena is the emission side of the precompiled iteration programs
//! (`crate::aidg::program`): the evaluator's steady-state loop reads operand
//! slices straight out of the pools, and `clear()` keeps capacity, so a
//! warmed-up evaluation performs zero heap allocations per iteration.

use crate::ids::{Addr, OpId, RegId};

use super::Instruction;

/// Struct-of-arrays instruction buffer with reusable capacity.
///
/// Filled by [`LoopKernel`](super::LoopKernel) generators through
/// [`EmitBuf::instr`] (allocation-free builder) or [`EmitBuf::push`]
/// (compatibility with code that already holds an [`Instruction`]).
#[derive(Debug, Default)]
pub struct EmitBuf {
    ops: Vec<OpId>,
    // Per-instruction exclusive end offsets into the flat pools below; the
    // i-th instruction's slice of a pool is `[end[i-1], end[i])` (0-based
    // start for the first instruction). Fields of one instruction are
    // contiguous by construction: the builder exclusively borrows the
    // buffer, so no other instruction can interleave appends.
    rr_end: Vec<u32>,
    wr_end: Vec<u32>,
    ra_end: Vec<u32>,
    wa_end: Vec<u32>,
    im_end: Vec<u32>,
    read_regs: Vec<RegId>,
    write_regs: Vec<RegId>,
    read_addrs: Vec<Addr>,
    write_addrs: Vec<Addr>,
    imms: Vec<i64>,
}

#[inline]
fn start_of(ends: &[u32], i: usize) -> usize {
    if i == 0 {
        0
    } else {
        ends[i - 1] as usize
    }
}

impl EmitBuf {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all instructions, keeping every pool's capacity.
    pub fn clear(&mut self) {
        self.ops.clear();
        self.rr_end.clear();
        self.wr_end.clear();
        self.ra_end.clear();
        self.wa_end.clear();
        self.im_end.clear();
        self.read_regs.clear();
        self.write_regs.clear();
        self.read_addrs.clear();
        self.write_addrs.clear();
        self.imms.clear();
    }

    /// Number of emitted instructions.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when no instruction has been emitted.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Start a new instruction of op `op`. The returned builder appends
    /// operands into the pools and seals the instruction when dropped (at
    /// the end of the statement), so the idiomatic form is one chained
    /// statement per instruction:
    ///
    /// ```text
    /// buf.instr(load).writes(&[r0]).read_mem(&[addr]);
    /// ```
    pub fn instr(&mut self, op: OpId) -> InstrBuilder<'_> {
        self.ops.push(op);
        InstrBuilder { buf: self }
    }

    /// Append an already-built [`Instruction`] (compatibility path; the
    /// instruction's own `Vec`s were already allocated by its builder).
    pub fn push(&mut self, i: Instruction) {
        self.instr(i.op)
            .reads(&i.read_regs)
            .writes(&i.write_regs)
            .read_mem(&i.read_addrs)
            .write_mem(&i.write_addrs)
            .imms(&i.imms);
    }

    fn seal(&mut self) {
        self.rr_end.push(self.read_regs.len() as u32);
        self.wr_end.push(self.write_regs.len() as u32);
        self.ra_end.push(self.read_addrs.len() as u32);
        self.wa_end.push(self.write_addrs.len() as u32);
        self.im_end.push(self.imms.len() as u32);
    }

    /// Borrowed view of instruction `i`.
    pub fn view(&self, i: usize) -> InstrView<'_> {
        InstrView {
            op: self.ops[i],
            read_regs: &self.read_regs[start_of(&self.rr_end, i)..self.rr_end[i] as usize],
            write_regs: &self.write_regs[start_of(&self.wr_end, i)..self.wr_end[i] as usize],
            read_addrs: &self.read_addrs[start_of(&self.ra_end, i)..self.ra_end[i] as usize],
            write_addrs: &self.write_addrs[start_of(&self.wa_end, i)..self.wa_end[i] as usize],
            imms: &self.imms[start_of(&self.im_end, i)..self.im_end[i] as usize],
        }
    }

    /// Iterate the emitted instructions as views.
    pub fn iter(&self) -> impl Iterator<Item = InstrView<'_>> {
        (0..self.len()).map(move |i| self.view(i))
    }
}

/// Builder of one instruction inside an [`EmitBuf`]. Appends operands into
/// the buffer's flat pools; the instruction record is sealed when the
/// builder drops (end of the emitting statement). The exclusive borrow of
/// the buffer guarantees the appended operand slices stay contiguous.
pub struct InstrBuilder<'a> {
    buf: &'a mut EmitBuf,
}

impl Drop for InstrBuilder<'_> {
    fn drop(&mut self) {
        self.buf.seal();
    }
}

impl InstrBuilder<'_> {
    /// Append register reads.
    pub fn reads(self, regs: &[RegId]) -> Self {
        self.buf.read_regs.extend_from_slice(regs);
        self
    }

    /// Append register reads from an iterator (no intermediate slice).
    pub fn reads_iter(self, regs: impl IntoIterator<Item = RegId>) -> Self {
        self.buf.read_regs.extend(regs);
        self
    }

    /// Append register writes.
    pub fn writes(self, regs: &[RegId]) -> Self {
        self.buf.write_regs.extend_from_slice(regs);
        self
    }

    /// Append register writes from an iterator.
    pub fn writes_iter(self, regs: impl IntoIterator<Item = RegId>) -> Self {
        self.buf.write_regs.extend(regs);
        self
    }

    /// Append memory reads (word addresses).
    pub fn read_mem(self, addrs: &[Addr]) -> Self {
        self.buf.read_addrs.extend_from_slice(addrs);
        self
    }

    /// Append memory reads from an iterator.
    pub fn read_mem_iter(self, addrs: impl IntoIterator<Item = Addr>) -> Self {
        self.buf.read_addrs.extend(addrs);
        self
    }

    /// Append memory writes.
    pub fn write_mem(self, addrs: &[Addr]) -> Self {
        self.buf.write_addrs.extend_from_slice(addrs);
        self
    }

    /// Append memory writes from an iterator.
    pub fn write_mem_iter(self, addrs: impl IntoIterator<Item = Addr>) -> Self {
        self.buf.write_addrs.extend(addrs);
        self
    }

    /// Append one immediate.
    pub fn imm(self, v: i64) -> Self {
        self.buf.imms.push(v);
        self
    }

    /// Append several immediates.
    pub fn imms(self, vs: &[i64]) -> Self {
        self.buf.imms.extend_from_slice(vs);
        self
    }
}

/// Borrowed, field-sliced view of one emitted instruction — the reading
/// counterpart of [`Instruction`], without owning any storage.
#[derive(Debug, Clone, Copy)]
pub struct InstrView<'a> {
    /// Mnemonic id.
    pub op: OpId,
    /// Registers read.
    pub read_regs: &'a [RegId],
    /// Registers written.
    pub write_regs: &'a [RegId],
    /// Memory addresses read.
    pub read_addrs: &'a [Addr],
    /// Memory addresses written.
    pub write_addrs: &'a [Addr],
    /// Immediates (latency-expression inputs).
    pub imms: &'a [i64],
}

impl InstrView<'_> {
    /// Stream every estimation-relevant field as `u64` words into `sink`
    /// (field lengths included, so adjacent fields cannot alias). This is
    /// the single definition of the engine's content-word stream;
    /// [`Instruction::content_words`] delegates here, so arena-emitted and
    /// materialized instructions fingerprint identically.
    pub fn content_words(&self, sink: &mut impl FnMut(u64)) {
        sink(self.op.0 as u64);
        sink(self.read_regs.len() as u64);
        for r in self.read_regs {
            sink(r.0 as u64);
        }
        sink(self.write_regs.len() as u64);
        for r in self.write_regs {
            sink(r.0 as u64);
        }
        sink(self.read_addrs.len() as u64);
        for &a in self.read_addrs {
            sink(a);
        }
        sink(self.write_addrs.len() as u64);
        for &a in self.write_addrs {
            sink(a);
        }
        sink(self.imms.len() as u64);
        for &v in self.imms {
            sink(v as u64);
        }
    }

    /// Materialize an owning [`Instruction`] (routing and the simulator
    /// want one; the evaluator's steady state never does).
    pub fn to_instruction(&self) -> Instruction {
        Instruction {
            op: self.op,
            read_regs: self.read_regs.to_vec(),
            write_regs: self.write_regs.to_vec(),
            read_addrs: self.read_addrs.to_vec(),
            write_addrs: self.write_addrs.to_vec(),
            imms: self.imms.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_seals_per_statement() {
        let mut b = EmitBuf::new();
        b.instr(OpId(1)).reads(&[RegId(2)]).writes(&[RegId(3)]).read_mem(&[10, 11]).imm(7);
        b.instr(OpId(2)).write_mem(&[20]);
        assert_eq!(b.len(), 2);
        let v0 = b.view(0);
        assert_eq!(v0.op, OpId(1));
        assert_eq!(v0.read_regs, &[RegId(2)]);
        assert_eq!(v0.write_regs, &[RegId(3)]);
        assert_eq!(v0.read_addrs, &[10, 11]);
        assert_eq!(v0.imms, &[7]);
        let v1 = b.view(1);
        assert_eq!(v1.op, OpId(2));
        assert!(v1.read_regs.is_empty());
        assert_eq!(v1.write_addrs, &[20]);
    }

    #[test]
    fn conditional_chains_stay_contiguous() {
        let mut b = EmitBuf::new();
        for extra in [false, true] {
            let mut i = b.instr(OpId(0)).reads(&[RegId(0)]);
            if extra {
                i = i.reads(&[RegId(1)]);
            }
            i.writes(&[RegId(9)]);
        }
        assert_eq!(b.view(0).read_regs, &[RegId(0)]);
        assert_eq!(b.view(1).read_regs, &[RegId(0), RegId(1)]);
        assert_eq!(b.view(1).write_regs, &[RegId(9)]);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut b = EmitBuf::new();
        b.instr(OpId(0)).read_mem_iter(0..64);
        let cap = b.read_addrs.capacity();
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.read_addrs.capacity(), cap);
        b.instr(OpId(1)).read_mem_iter(100..110);
        assert_eq!(b.view(0).read_addrs.len(), 10);
        assert_eq!(b.view(0).read_addrs[0], 100);
    }

    #[test]
    fn push_matches_builder_and_roundtrips() {
        let i = Instruction::new(OpId(4))
            .reads(&[RegId(1)])
            .writes(&[RegId(2)])
            .read_mem(&[10])
            .write_mem(&[20])
            .imm(-3);
        let mut b = EmitBuf::new();
        b.push(i.clone());
        let back = b.view(0).to_instruction();
        assert_eq!(back, i);
        // content words agree between the owned and the arena forms
        let mut w1 = Vec::new();
        i.content_words(&mut |x| w1.push(x));
        let mut w2 = Vec::new();
        b.view(0).content_words(&mut |x| w2.push(x));
        assert_eq!(w1, w2);
    }
}
