//! Precompiled iteration programs — the evaluator's allocation-free
//! steady-state hot path.
//!
//! §6.3 guarantees that consecutive iterations of a [`LoopKernel`]
//! (`crate::isa::LoopKernel`) execute the same instruction *template*: only
//! memory addresses (and latency-expression immediates) change. The
//! original evaluator nevertheless re-derived all template-invariant facts
//! on every instruction of every iteration — route tails, lock owners,
//! `ObjectKind` matches for latency dispatch, and a full `memory_of` binary
//! search per address per memory node. This module lowers each instruction
//! *offset* (position within the iteration) exactly once, on the first
//! iteration that reaches it, into a flat node table the interpreter in
//! [`super::eval::Evaluator`] replays with:
//!
//! - resolved lock-owner ring indices (no `Diagram::lock` calls),
//! - pre-evaluated fixed latencies with a dynamic escape hatch for
//!   immediate-dependent `Latency::Expr` objects,
//! - per-memory-node operand *positions* (which addresses of the
//!   instruction belong to this memory node) interned into one flat pool,
//! - no per-node `ObjectKind` matching and no allocation.
//!
//! Per-iteration operands (register ids, addresses, immediates) are read
//! from the emission arena ([`crate::isa::EmitBuf`]) each iteration, so the
//! program holds only what §6.3 makes invariant.
//!
//! ## Safety net: the partition check
//!
//! The one lowered fact that is *not* implied by route invariance is the
//! address→memory partition: an instruction touching two memories could in
//! principle redistribute its addresses between them in a later iteration
//! while keeping the same route. Before interpreting an instruction, the
//! evaluator runs [`IterProgram::partition_holds`]: every recorded position
//! is membership-checked against its memory's address range (two compares
//! for the ubiquitous single-range memories). If the check fails — or the
//! address-field lengths changed — the memory nodes of that instruction
//! fall back to the original full `memory_of` scan, reproducing the
//! reference evaluator bit-for-bit even for template-violating kernels.
//!
//! Route *invariance itself* is asserted the same way the original
//! evaluator asserted it: lowering derives the route from the first
//! iteration, and the `verify-routes` cargo feature (a dedicated cfg, off
//! by default so debug builds no longer pay a full routing pass per
//! instruction) re-derives and compares the route on every instruction.

use crate::acadl::{Diagram, ObjectKind, Route};
use crate::ids::{Addr, Cycle, ObjId};
use crate::isa::InstrView;

/// Sentinel for "no next node" in [`Node::next`].
pub(crate) const NO_LOCK: u32 = u32::MAX;

/// Lowered latency of one node.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Lat {
    /// Instruction-independent latency, evaluated at lowering time.
    Fix(Cycle),
    /// Immediate-dependent latency (`Latency::Expr`): re-evaluated against
    /// the current iteration's immediates through the object table. For
    /// memory nodes this is the *per-transaction* latency.
    Dyn(ObjId),
}

impl Lat {
    /// Residency latency of a stage/FU node for the current immediates.
    #[inline]
    pub(crate) fn eval(self, d: &Diagram, imms: &[i64]) -> Cycle {
        match self {
            Lat::Fix(c) => c,
            Lat::Dyn(obj) => d.object_latency_imms(obj, imms),
        }
    }
}

/// Kind-specific lowered data of one tail node.
#[derive(Debug, Clone, Copy)]
pub(crate) enum NodeKind {
    /// Intermediate pipeline stage.
    Stage {
        /// Residency latency.
        lat: Lat,
    },
    /// The functional unit node (register data dependencies).
    Fu {
        /// Execution latency.
        lat: Lat,
        /// Write registers anchor here (no writeBack node follows).
        anchors_writes: bool,
    },
    /// A memory node (address data dependencies).
    Mem {
        /// Write transaction (vs read).
        write: bool,
        /// Per-transaction latency.
        per_txn: Lat,
        /// Words per transaction.
        port: u32,
        /// `[start, end)` into [`IterProgram::positions`]: indices of this
        /// instruction's read/write addresses served by this memory.
        pos: (u32, u32),
        /// Single-range membership check `[base, end)`; `end == 0` marks a
        /// multi-range memory (checked through `Diagram::memory_of`).
        base: Addr,
        /// Exclusive end of the single-range check (0 = multi-range).
        end: Addr,
    },
    /// The writeBack pseudo-node (zero latency, unbounded lock).
    WriteBack,
}

/// One lowered tail node: everything Algorithm 1 needs that is invariant
/// across iterations, flat and `Copy` (the SoA pools — positions — live in
/// the owning [`IterProgram`]).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Node {
    /// The underlying object (traces, dynamic latency, slow-path scans).
    pub obj: ObjId,
    /// Lock-owner ring index of this node.
    pub owner: u32,
    /// Lock-owner ring index of the *next* tail node ([`NO_LOCK`] = last):
    /// `t_leave` stalls until the next object frees.
    pub next: u32,
    /// Kind-specific lowered data.
    pub kind: NodeKind,
}

/// Per-offset metadata: which slice of the node table interprets the j-th
/// instruction of an iteration, plus the template-shape facts the fast
/// memory path depends on.
#[derive(Debug, Clone, Copy)]
pub(crate) struct OffsetMeta {
    /// `[start, end)` into [`IterProgram::nodes`].
    pub nodes: (u32, u32),
    /// Lock-owner ring index of the first tail object (the IFS `t_leave`
    /// stalls on it).
    pub first_tail_lock: u32,
    /// `read_addrs` length at lowering time.
    pub ra_len: u32,
    /// `write_addrs` length at lowering time.
    pub wa_len: u32,
}

/// A compiled iteration program: one [`OffsetMeta`] per instruction offset,
/// a flat node table, and the interned memory-position pool. Grown
/// offset-by-offset as the first iteration streams through the evaluator;
/// steady-state iterations only read it.
#[derive(Debug, Default)]
pub(crate) struct IterProgram {
    /// Per-offset node ranges.
    pub offsets: Vec<OffsetMeta>,
    /// Flat tail-node table.
    pub nodes: Vec<Node>,
    /// Interned address-position pool (indices into an instruction's
    /// `read_addrs` / `write_addrs`).
    pub positions: Vec<u32>,
}

impl IterProgram {
    /// Number of lowered instruction offsets.
    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    /// The position slice of a memory node.
    #[inline]
    pub fn positions_of(&self, pos: (u32, u32)) -> &[u32] {
        &self.positions[pos.0 as usize..pos.1 as usize]
    }

    /// Single-range membership data of a memory object: `(base, end)` when
    /// the memory claims exactly one address range, the `(0, 0)` multi-range
    /// sentinel otherwise.
    fn range_check(d: &Diagram, mem: ObjId) -> (Addr, Addr) {
        if let ObjectKind::Memory { address_ranges, .. } = &d.object(mem).kind {
            if let [(base, end)] = address_ranges[..] {
                return (base, end);
            }
        }
        (0, 0)
    }

    /// Record the positions of `addrs` entries served by `mem` and build
    /// the memory node.
    fn lower_mem_node(
        &mut self,
        d: &Diagram,
        mem: ObjId,
        write: bool,
        addrs: &[Addr],
    ) -> NodeKind {
        let start = self.positions.len() as u32;
        for (i, &a) in addrs.iter().enumerate() {
            if d.memory_of(a) == Some(mem) {
                self.positions.push(i as u32);
            }
        }
        let end = self.positions.len() as u32;
        let (per_txn, port) =
            if let ObjectKind::Memory { read_latency, write_latency, port_width, .. } =
                &d.object(mem).kind
            {
                let lat = if write { write_latency } else { read_latency };
                let per = match lat {
                    crate::acadl::Latency::Fixed(c) => Lat::Fix(*c),
                    crate::acadl::Latency::Expr(_) => Lat::Dyn(mem),
                };
                (per, *port_width)
            } else {
                (Lat::Fix(0), 1)
            };
        let (base, range_end) = Self::range_check(d, mem);
        NodeKind::Mem { write, per_txn, port, pos: (start, end), base, end: range_end }
    }

    /// Lowered latency of a stage/FU object.
    fn lower_lat(d: &Diagram, obj: ObjId) -> Lat {
        match d.object(obj).fixed_latency() {
            Some(c) => Lat::Fix(c),
            None => Lat::Dyn(obj),
        }
    }

    /// Lower the next instruction offset from its first-iteration view and
    /// resolved route. Offsets must be lowered in order.
    pub fn lower_offset(&mut self, d: &Diagram, route: &Route, view: &InstrView<'_>) {
        let wb = d.writeback_obj();
        let node_start = self.nodes.len() as u32;

        // Assemble the tail object order once: stages…, FU, read mems…,
        // writeBack?, write mems… — mirroring the reference evaluator's
        // per-instruction scratch buffer.
        for &s in &route.stages {
            let kind = match &d.object(s).kind {
                ObjectKind::PipelineStage { .. } => NodeKind::Stage { lat: Self::lower_lat(d, s) },
                _ => NodeKind::Stage { lat: Lat::Fix(0) },
            };
            self.push_node(d, s, kind);
        }
        let fu_kind = match &d.object(route.fu).kind {
            ObjectKind::FunctionalUnit { .. } => NodeKind::Fu {
                lat: Self::lower_lat(d, route.fu),
                anchors_writes: !route.has_writeback,
            },
            _ => NodeKind::Fu { lat: Lat::Fix(0), anchors_writes: !route.has_writeback },
        };
        self.push_node(d, route.fu, fu_kind);
        for &m in &route.read_mems {
            let kind = self.lower_mem_node(d, m, false, view.read_addrs);
            self.push_node(d, m, kind);
        }
        if route.has_writeback {
            self.push_node(d, wb, NodeKind::WriteBack);
        }
        for &m in &route.write_mems {
            let kind = self.lower_mem_node(d, m, true, view.write_addrs);
            self.push_node(d, m, kind);
        }

        // Back-patch each node's `next` lock (the structural stall target).
        let node_end = self.nodes.len() as u32;
        for i in node_start..node_end.saturating_sub(1) {
            self.nodes[i as usize].next = self.nodes[i as usize + 1].owner;
        }
        let first_tail_lock =
            self.nodes.get(node_start as usize).map_or(NO_LOCK, |n| n.owner);
        self.offsets.push(OffsetMeta {
            nodes: (node_start, node_end),
            first_tail_lock,
            ra_len: view.read_addrs.len() as u32,
            wa_len: view.write_addrs.len() as u32,
        });
    }

    fn push_node(&mut self, d: &Diagram, obj: ObjId, kind: NodeKind) {
        self.nodes.push(Node {
            obj,
            owner: d.lock(obj).owner.idx() as u32,
            next: NO_LOCK,
            kind,
        });
    }

    /// True when the current iteration's addresses still obey the lowered
    /// address→memory partition (and field lengths), so the memory nodes
    /// can use their interned positions instead of a full `memory_of` scan.
    /// Every address position of the instruction is recorded under exactly
    /// one memory node — `Diagram::route` fails on any address no memory
    /// claims, so a lowered offset cannot have unmapped positions — and
    /// therefore checking all recorded positions covers the whole
    /// partition.
    #[inline]
    pub fn partition_holds(&self, d: &Diagram, meta: &OffsetMeta, view: &InstrView<'_>) -> bool {
        if view.read_addrs.len() != meta.ra_len as usize
            || view.write_addrs.len() != meta.wa_len as usize
        {
            return false;
        }
        for node in &self.nodes[meta.nodes.0 as usize..meta.nodes.1 as usize] {
            if let NodeKind::Mem { write, pos, base, end, .. } = node.kind {
                let addrs = if write { view.write_addrs } else { view.read_addrs };
                for &p in self.positions_of(pos) {
                    let a = addrs[p as usize];
                    let ok = if end > base {
                        a >= base && a < end
                    } else {
                        d.memory_of(a) == Some(node.obj)
                    };
                    if !ok {
                        return false;
                    }
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::aidg::reference::RefEvaluator;
    use crate::aidg::{DispatchMode, Evaluator};
    use crate::dnn::zoo;
    use crate::isa::LoopKernel;
    use crate::mapping::{
        gemm_tile::GemmTileMapper, plasticine_map::PlasticineMapper, scalar::ScalarMapper,
        tensor_op::TensorOpMapper, Mapper,
    };
    use crate::testkit::{
        migrating_kernel, multirange_machine, random_kernel, random_machine, Prop, Rng,
    };

    /// The headline differential property: both dispatch modes of the
    /// iteration-program interpreter are bit-identical to the retained
    /// reference evaluator across random architectures × random template
    /// kernels, including chunk boundaries (the §6.3 streaming contract)
    /// and dynamic latencies.
    #[test]
    fn property_program_matches_reference_on_random_machines() {
        Prop::new(0xA1D6).cases(30).run(|rng| {
            let m = random_machine(rng);
            let k = rng.range_u64(8, 48);
            let kernel = random_kernel(rng, &m, k);
            let mut threaded = Evaluator::new_with_dispatch(&m.d, DispatchMode::Threaded);
            let mut table = Evaluator::new_with_dispatch(&m.d, DispatchMode::NodeTable);
            let mut reference = RefEvaluator::new(&m.d);
            // chunk the fast paths so program reuse crosses run() calls
            let cut = rng.range_u64(1, k - 1);
            threaded.run(&kernel, 0..cut).unwrap();
            threaded.run(&kernel, cut..k).unwrap();
            table.run(&kernel, 0..cut).unwrap();
            table.run(&kernel, cut..k).unwrap();
            reference.run(&kernel, 0..k).unwrap();
            assert_eq!(threaded.iter_stats, reference.iter_stats, "threaded k={k}");
            assert_eq!(threaded.st.nodes, reference.nodes, "threaded k={k}");
            assert_eq!(threaded.dt_aidg(), reference.dt_aidg(), "threaded k={k}");
            assert_eq!(table.iter_stats, reference.iter_stats, "node-table k={k}");
            assert_eq!(table.st.nodes, reference.nodes, "node-table k={k}");
            assert_eq!(table.dt_aidg(), reference.dt_aidg(), "node-table k={k}");
        });
    }

    /// Structural fusion fallback: a multi-range memory never compiles to a
    /// tape, yet the threaded evaluator stays bit-identical to the
    /// reference (it walks the node table for those offsets) and reports
    /// the fallback in its dispatch stats.
    #[test]
    fn multirange_memory_falls_back_bit_identically() {
        let m = multirange_machine();
        // One memory offset (structurally non-fusible: "banked" spans two
        // ranges) and one compute offset (fusible) per iteration.
        let (load, mac) = (m.load, m.mac);
        let (r0, r1, r2) = (m.regs[0], m.regs[1], m.regs[2]);
        let (b0, b1) = (m.mem_bases[0], m.mem_bases[1]);
        let kernel = LoopKernel::new(
            "banked",
            24,
            2,
            Box::new(move |it, buf| {
                buf.instr(load).writes(&[r0]).read_mem(&[b0 + it, b1 + 2 * it]);
                buf.instr(mac).reads(&[r0, r1]).writes(&[r2]);
            }),
        );
        let mut threaded = Evaluator::new_with_dispatch(&m.d, DispatchMode::Threaded);
        let mut reference = RefEvaluator::new(&m.d);
        threaded.run(&kernel, 0..24).unwrap();
        reference.run(&kernel, 0..24).unwrap();
        assert_eq!(threaded.iter_stats, reference.iter_stats);
        assert_eq!(threaded.st.nodes, reference.nodes);
        let stats = threaded.dispatch_stats();
        assert!(stats.threaded_instrs > 0, "the mac offset must fuse: {stats:?}");
        assert!(stats.fallback_instrs > 0, "memory offsets must fall back: {stats:?}");
        let fusion = threaded.fusion_stats();
        assert!(
            fusion.fusible_offsets < fusion.offsets,
            "multi-range offsets must be non-fusible: {fusion:?}"
        );
    }

    /// Run-time fusion fallback: a partition-migrating kernel trips the
    /// folded address guard after iteration 0; the threaded evaluator must
    /// fall back to the full-scan node-table walk bit-identically.
    #[test]
    fn migrating_partition_falls_back_bit_identically() {
        let mut rng = Rng::new(0x917A);
        let m = loop {
            let m = random_machine(&mut rng);
            if m.mem_bases.len() >= 2 {
                break m;
            }
        };
        let kernel = migrating_kernel(&m, 6);
        let mut threaded = Evaluator::new_with_dispatch(&m.d, DispatchMode::Threaded);
        let mut reference = RefEvaluator::new(&m.d);
        threaded.run(&kernel, 0..6).unwrap();
        reference.run(&kernel, 0..6).unwrap();
        assert_eq!(threaded.iter_stats, reference.iter_stats);
        assert_eq!(threaded.st.nodes, reference.nodes);
        let stats = threaded.dispatch_stats();
        assert!(stats.threaded_instrs > 0, "iteration 0 must run on the tape: {stats:?}");
        assert!(stats.fallback_instrs > 0, "later iterations must fall back: {stats:?}");
    }

    /// Every real mapper's kernels (all four architectures × TC-ResNet8)
    /// evaluate bit-identically through the program interpreter and the
    /// reference evaluator.
    #[test]
    fn program_matches_reference_on_mapped_kernels() {
        let mappers: Vec<(&str, Box<dyn Mapper>)> = vec![
            (
                "systolic4x4",
                Box::new(ScalarMapper::new(Arc::new(
                    crate::accel::Systolic::new(crate::accel::SystolicConfig::new(4, 4))
                        .unwrap(),
                ))),
            ),
            (
                "gemmini",
                Box::new(GemmTileMapper::new(Arc::new(
                    crate::accel::Gemmini::new(crate::accel::GemminiConfig::default()).unwrap(),
                ))),
            ),
            (
                "ultratrail",
                Box::new(TensorOpMapper::new(Arc::new(
                    crate::accel::UltraTrail::new(crate::accel::UltraTrailConfig::default())
                        .unwrap(),
                ))),
            ),
            (
                "plasticine",
                Box::new(PlasticineMapper::new(Arc::new(
                    crate::accel::Plasticine::new(crate::accel::PlasticineConfig::new(2, 3, 8))
                        .unwrap(),
                ))),
            ),
        ];
        let net = zoo::tc_resnet8();
        for (name, mapper) in &mappers {
            let mapped = mapper.map_network(&net).unwrap();
            for ml in mapped.iter().filter(|l| !l.fused) {
                for kernel in &ml.kernels {
                    let iters = kernel.k.min(8);
                    let mut reference = RefEvaluator::new(mapper.diagram());
                    reference.run(kernel, 0..iters).unwrap();
                    for mode in [DispatchMode::Threaded, DispatchMode::NodeTable] {
                        let mut fast = Evaluator::new_with_dispatch(mapper.diagram(), mode);
                        fast.run(kernel, 0..iters).unwrap();
                        assert_eq!(
                            fast.iter_stats,
                            reference.iter_stats,
                            "{name}/{}: {}",
                            mode.name(),
                            kernel.label
                        );
                        assert_eq!(
                            fast.st.nodes,
                            reference.nodes,
                            "{name}/{}: {}",
                            mode.name(),
                            kernel.label
                        );
                    }
                }
            }
        }
    }

    /// Lowering compiles one node per tail object and interns memory
    /// positions; re-running more iterations grows nothing.
    #[test]
    fn lowering_is_one_shot_and_flat() {
        let m = {
            let mut rng = Rng::new(7);
            random_machine(&mut rng)
        };
        let kernel = {
            let mut rng = Rng::new(8);
            random_kernel(&mut rng, &m, 32)
        };
        let mut ev = Evaluator::new(&m.d);
        ev.run(&kernel, 0..2).unwrap();
        let offsets = ev_program_len(&ev);
        assert_eq!(offsets, kernel.insts_per_iter);
        ev.run(&kernel, 2..32).unwrap();
        assert_eq!(ev_program_len(&ev), offsets, "program must not re-lower");
    }

    fn ev_program_len(ev: &Evaluator<'_>) -> usize {
        ev.program_len()
    }
}
