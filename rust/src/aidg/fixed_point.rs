//! §6.3 — end-to-end latency of a whole DNN layer from a few evaluated
//! iterations.
//!
//! Consecutive loop iterations overlap in the pipeline, and loop-carried
//! dependencies make the first iterations atypical; after a *prolog* the
//! per-iteration end-to-end latency stabilizes. The estimator evaluates the
//! AIDG in `k_block`-sized chunks (eq. 3: the smallest iteration count whose
//! instruction total is divisible by the instruction memory port width, so
//! merged fetch nodes stay aligned), checks the fixed-point criterion
//! (eq. 5) between consecutive chunks, and extrapolates with
//!
//! ```text
//! Δt = Δt_prolog + (k − k_prolog) · (Δt_iteration − Δt_overlap)      (eq. 2)
//! ```
//!
//! If `Δt_iteration` oscillates and eq. 5 is never satisfied within
//! `fallback_frac` (default 1 %) of all iterations, the fallback heuristic
//! (eqs. 9–13) averages the per-iteration latency over the evaluated window
//! instead. Appendix A.1 motivates the 1 % default; Appendix A.2 analyzes
//! the residual error — reproduced by `benches/fig16_fallback_sweep.rs` and
//! `benches/fig17_oscillation.rs`.

use std::time::{Duration, Instant};

use crate::acadl::Diagram;
use crate::ids::Cycle;
use crate::isa::LoopKernel;
use crate::Result;

use super::eval::{Evaluator, IterStat};

/// Tunables of the fixed-point evaluation.
#[derive(Debug, Clone, Copy)]
pub struct FixedPointConfig {
    /// Fraction of `k` after which the fallback heuristic fires (paper: 1 %).
    pub fallback_frac: f64,
    /// Record the full per-iteration trace (Fig. 17 / Table 6 analyses).
    pub keep_trace: bool,
}

impl Default for FixedPointConfig {
    fn default() -> Self {
        Self { fallback_frac: 0.01, keep_trace: false }
    }
}

/// Where a [`LayerEstimate`] came from — provenance stamped by the unified
/// estimation engine ([`crate::engine`]). Direct `estimate_layer` calls
/// always produce [`Provenance::Computed`]; the engine re-stamps clones it
/// hands out from its cache or from intra-request deduplication. Provenance
/// never affects the numeric fields: a reused estimate is cycle-identical
/// to recomputing it (the cache key covers everything the estimator reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Provenance {
    /// Evaluated through the AIDG in this request.
    #[default]
    Computed,
    /// Reused from an identical kernel earlier in the same request.
    Deduped,
    /// Served from the cross-request estimate cache.
    CacheHit,
}

/// Result of estimating one mapped layer.
#[derive(Debug, Clone)]
pub struct LayerEstimate {
    /// The kernel's label (layer/kernel name).
    pub label: String,
    /// Total loop iterations of the layer.
    pub k: u64,
    /// Instructions per iteration.
    pub insts_per_iter: usize,
    /// Estimated end-to-end cycles `Δt̂`.
    pub cycles: Cycle,
    /// Iterations actually evaluated in the AIDG.
    pub evaluated_iters: u64,
    /// Fetch-phase block size (`lcm(|I|, port_width) / |I|`).
    pub k_block: u64,
    /// Iterations evaluated before the steady-state comparison window.
    pub k_prolog: u64,
    /// Last evaluated per-iteration latency Δt_iteration.
    pub dt_iteration: Cycle,
    /// Last evaluated inter-iteration overlap Δt_overlap.
    pub dt_overlap: i64,
    /// eq. 5 never satisfied; eqs. 9–13 used.
    pub used_fallback: bool,
    /// All iterations were evaluated (k too small for fixed point).
    pub whole_graph: bool,
    /// AIDG nodes processed.
    pub nodes: u64,
    /// Peak tracked evaluator state (bytes) — the Fig. 11/12 metric.
    pub peak_state_bytes: u64,
    /// Wall time of the estimation.
    pub runtime: Duration,
    /// How this estimate was obtained (see [`Provenance`]).
    pub provenance: Provenance,
    /// Per-iteration (min_enter, max_leave) when `keep_trace` is set.
    pub trace: Option<Vec<IterStat>>,
    /// Corrected cycle estimate, stamped by the engine when a
    /// [`crate::calib::CalibrationModel`] is installed; `None` otherwise
    /// (estimators themselves never set it — calibration off is
    /// bit-identical to a build without the subsystem).
    pub calibrated_cycles: Option<u64>,
    /// Lower confidence bound on the true (DES) cycles, from the
    /// calibration class's residual band. Set together with
    /// [`Self::calibrated_cycles`].
    pub ci_lo: Option<u64>,
    /// Upper confidence bound on the true (DES) cycles.
    pub ci_hi: Option<u64>,
}

impl LayerEstimate {
    /// Total instructions of the kernel (`k · |I|`).
    pub fn total_insts(&self) -> u64 {
        self.k * self.insts_per_iter as u64
    }
}

/// Greatest common divisor.
fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 { a } else { gcd(b, a % b) }
}

/// eq. 3: minimal iterations whose instruction count is divisible by the
/// instruction-memory port width.
pub fn k_block(insts_per_iter: u64, port_width: u64) -> u64 {
    let l = insts_per_iter / gcd(insts_per_iter, port_width) * port_width; // lcm
    l / insts_per_iter
}

/// Δt_overlap between the last two evaluated iterations (Fig. 9 semantics:
/// how far iteration `j` starts before iteration `j−1` ends).
pub(crate) fn overlap(stats: &[IterStat]) -> i64 {
    if stats.len() < 2 {
        return 0;
    }
    let prev = stats[stats.len() - 2];
    let last = stats[stats.len() - 1];
    prev.max_leave as i64 - last.min_enter as i64
}

/// Estimate the end-to-end latency of `kernel` on `diagram` (§6.3).
pub fn estimate_layer(
    diagram: &Diagram,
    kernel: &LoopKernel,
    cfg: &FixedPointConfig,
) -> Result<LayerEstimate> {
    let mut sp = crate::obs::span("aidg.estimate_layer");
    let start = Instant::now();
    let k = kernel.k;
    sp.arg("k", k);
    let p = diagram.fetch_config().port_width as u64;
    let kb = k_block(kernel.insts_per_iter as u64, p);
    let mut ev = Evaluator::new(diagram);

    let finish = |ev: Evaluator,
                  cycles: Cycle,
                  k_prolog: u64,
                  dt_iteration: Cycle,
                  dt_overlap: i64,
                  used_fallback: bool,
                  whole_graph: bool,
                  start: Instant,
                  cfg: &FixedPointConfig| {
        crate::metrics::counters::note_aidg(ev.st.nodes, ev.iter_stats.len() as u64);
        // evaluator phases are histogram-only aggregates (see Evaluator::run)
        crate::obs::record_duration("aidg.program.compile", ev.obs_compile_ns);
        crate::obs::record_duration(
            "aidg.evaluate",
            ev.obs_run_ns.saturating_sub(ev.obs_compile_ns),
        );
        LayerEstimate {
            label: kernel.label.clone(),
            k,
            insts_per_iter: kernel.insts_per_iter,
            cycles,
            evaluated_iters: ev.iter_stats.len() as u64,
            k_block: kb,
            k_prolog,
            dt_iteration,
            dt_overlap,
            used_fallback,
            whole_graph,
            nodes: ev.st.nodes,
            peak_state_bytes: ev.st.peak_bytes as u64,
            runtime: start.elapsed(),
            provenance: Provenance::Computed,
            trace: cfg.keep_trace.then_some(ev.iter_stats),
            calibrated_cycles: None,
            ci_lo: None,
            ci_hi: None,
        }
    };

    // k_block >= k or too few blocks for a fixed point: whole graph (§6.3).
    if kb >= k || 3 * kb > k {
        sp.note("whole");
        ev.run(kernel, 0..k)?;
        let cycles = ev.dt_aidg();
        let dt_it = ev.iter_stats.last().map_or(0, |s| s.span());
        let ov = overlap(&ev.iter_stats);
        return Ok(finish(ev, cycles, k, dt_it, ov, false, true, start, cfg));
    }

    // Evaluate chunk by chunk until eq. 5 (two consecutive chunks whose last
    // iterations have equal spans) or the fallback budget is exhausted.
    let budget = ((k as f64 * cfg.fallback_frac) as u64).max(3 * kb);
    let mut evaluated: u64 = 0;
    let mut prev_span: Option<Cycle> = None;
    let mut stable_at: Option<u64> = None; // iterations evaluated when eq.5 hit
    while evaluated < k {
        let next = (evaluated + kb).min(k);
        ev.run(kernel, evaluated..next)?;
        evaluated = next;
        let span = ev.iter_stats.last().unwrap().span();
        // The first k_block has no in-going structural dependencies from a
        // previous block, so its span is unrepresentative (§6.3): only start
        // comparing from the second block on.
        if evaluated >= 2 * kb {
            if let Some(prev) = prev_span {
                if prev == span && evaluated >= 3 * kb {
                    stable_at = Some(evaluated);
                    break;
                }
            }
        }
        prev_span = Some(span);
        if evaluated >= budget {
            break;
        }
    }

    if evaluated >= k {
        // ran through everything: exact result
        sp.note("whole");
        let cycles = ev.dt_aidg();
        let dt_it = ev.iter_stats.last().map_or(0, |s| s.span());
        let ov = overlap(&ev.iter_stats);
        return Ok(finish(ev, cycles, k, dt_it, ov, false, true, start, cfg));
    }

    if let Some(k_prolog) = stable_at {
        // eqs. 6–8 + eq. 2
        sp.note("fixed_point");
        let dt_prolog = ev.iter_stats.iter().map(|s| s.max_leave).max().unwrap();
        let dt_iteration = ev.iter_stats.last().unwrap().span();
        let ov = overlap(&ev.iter_stats);
        let stride = dt_iteration as i64 - ov;
        let cycles =
            (dt_prolog as i64 + (k - k_prolog) as i64 * stride).max(dt_prolog as i64) as Cycle;
        return Ok(finish(ev, cycles, k_prolog, dt_iteration, ov, false, false, start, cfg));
    }

    // Fallback heuristic (eqs. 9–13): Δt_iteration oscillates. Average the
    // per-iteration latency between k_prolog = ⌊k01/4⌋ and k01 = evaluated
    // iterations (1 % of k).
    sp.note("fallback");
    let k01 = evaluated;
    let k_prolog = (k01 / 4).max(1);
    let leave_at = |it: u64| ev.iter_stats[(it - 1) as usize].max_leave;
    let dt_window = leave_at(k01) - leave_at(k_prolog);
    let dt_iteration = ((dt_window as f64) / ((k01 - k_prolog) as f64)).round() as Cycle;
    let dt_prolog = leave_at(k_prolog);
    let cycles = dt_prolog + (k - k_prolog) * dt_iteration; // eq. 2 with overlap 0
    Ok(finish(ev, cycles, k_prolog, dt_iteration, 0, true, false, start, cfg))
}

/// Whole-graph evaluation of all `k` iterations (the Table 5 ground truth).
pub fn evaluate_whole(diagram: &Diagram, kernel: &LoopKernel) -> Result<LayerEstimate> {
    let mut sp = crate::obs::span("aidg.evaluate_whole");
    sp.arg("k", kernel.k);
    let start = Instant::now();
    let mut ev = Evaluator::new(diagram);
    ev.run(kernel, 0..kernel.k)?;
    crate::metrics::counters::note_aidg(ev.st.nodes, ev.iter_stats.len() as u64);
    crate::obs::record_duration("aidg.program.compile", ev.obs_compile_ns);
    crate::obs::record_duration(
        "aidg.evaluate",
        ev.obs_run_ns.saturating_sub(ev.obs_compile_ns),
    );
    let cycles = ev.dt_aidg();
    let dt_it = ev.iter_stats.last().map_or(0, |s| s.span());
    let ov = overlap(&ev.iter_stats);
    Ok(LayerEstimate {
        label: kernel.label.clone(),
        k: kernel.k,
        insts_per_iter: kernel.insts_per_iter,
        cycles,
        evaluated_iters: kernel.k,
        k_block: k_block(
            kernel.insts_per_iter as u64,
            diagram.fetch_config().port_width as u64,
        ),
        k_prolog: kernel.k,
        dt_iteration: dt_it,
        dt_overlap: ov,
        used_fallback: false,
        whole_graph: true,
        nodes: ev.st.nodes,
        peak_state_bytes: ev.st.peak_bytes as u64,
        runtime: start.elapsed(),
        provenance: Provenance::Computed,
        trace: None,
        calibrated_cycles: None,
        ci_lo: None,
        ci_hi: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl::Latency;
    use crate::ids::RegId;
    use crate::isa::Instruction;

    fn machine() -> (Diagram, Ops) {
        let mut d = Diagram::new("m");
        let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
        let es = d.add_execute_stage("es");
        let (rf, regs) = d.add_regfile("rf", "r", 4);
        let mem = d.add_memory("dmem", 4, 4, 1, 1, 0, 1 << 20);
        let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load", "store"]);
        let alu = d.add_fu(es, "alu", Latency::Fixed(1), &["mac"]);
        d.forward(ifs, es);
        d.fu_writes(lsu, rf);
        d.fu_reads(lsu, rf);
        d.fu_reads(alu, rf);
        d.fu_writes(alu, rf);
        d.mem_reads(lsu, mem);
        d.mem_writes(lsu, mem);
        let ops =
            Ops { load: d.op("load"), mac: d.op("mac"), store: d.op("store"), regs };
        d.finalize().unwrap();
        (d, ops)
    }

    struct Ops {
        load: crate::ids::OpId,
        mac: crate::ids::OpId,
        store: crate::ids::OpId,
        regs: Vec<RegId>,
    }

    fn lk(ops: &Ops, k: u64) -> LoopKernel {
        let (load, mac, store) = (ops.load, ops.mac, ops.store);
        let (r0, r1, r2) = (ops.regs[0], ops.regs[1], ops.regs[2]);
        LoopKernel::new(
            "t",
            k,
            4,
            Box::new(move |it, buf| {
                buf.push(Instruction::new(load).writes(&[r0]).read_mem(&[it]));
                buf.push(Instruction::new(load).writes(&[r1]).read_mem(&[1000 + it]));
                buf.push(Instruction::new(mac).reads(&[r0, r1]).writes(&[r2]));
                buf.push(Instruction::new(store).reads(&[r2]).write_mem(&[2000 + it]));
            }),
        )
    }

    #[test]
    fn k_block_lcm() {
        assert_eq!(k_block(4, 2), 1); // 4 insts, port 2: already divisible
        assert_eq!(k_block(3, 2), 2); // lcm(3,2)=6 -> 2 iterations
        assert_eq!(k_block(5, 4), 4);
        assert_eq!(k_block(8, 8), 1);
        assert_eq!(k_block(1, 3), 3);
    }

    #[test]
    fn fixed_point_matches_whole_graph() {
        // the paper's headline property: extrapolating from the prolog must
        // equal evaluating every iteration when Δt_iteration is stable
        let (d, ops) = machine();
        let kernel = lk(&ops, 2000);
        let fp = estimate_layer(&d, &kernel, &FixedPointConfig::default()).unwrap();
        let whole = evaluate_whole(&d, &kernel).unwrap();
        assert!(!fp.whole_graph);
        assert!(fp.evaluated_iters < 100, "evaluated {}", fp.evaluated_iters);
        assert_eq!(fp.cycles, whole.cycles, "fp={fp:?}");
    }

    #[test]
    fn small_k_goes_whole_graph() {
        let (d, ops) = machine();
        let kernel = lk(&ops, 2);
        let e = estimate_layer(&d, &kernel, &FixedPointConfig::default()).unwrap();
        assert!(e.whole_graph);
        assert_eq!(e.evaluated_iters, 2);
    }

    #[test]
    fn trace_recorded_when_requested() {
        let (d, ops) = machine();
        let kernel = lk(&ops, 50);
        let cfg = FixedPointConfig { keep_trace: true, ..Default::default() };
        let e = estimate_layer(&d, &kernel, &cfg).unwrap();
        let t = e.trace.as_ref().unwrap();
        assert_eq!(t.len() as u64, e.evaluated_iters);
    }

    #[test]
    fn estimate_scales_linearly_in_k() {
        let (d, ops) = machine();
        let e1 = estimate_layer(&d, &lk(&ops, 1000), &FixedPointConfig::default()).unwrap();
        let e2 = estimate_layer(&d, &lk(&ops, 2000), &FixedPointConfig::default()).unwrap();
        let stride = e1.dt_iteration as i64 - e1.dt_overlap;
        assert_eq!(e2.cycles as i64 - e1.cycles as i64, 1000 * stride);
    }

    #[test]
    fn fallback_fires_on_tiny_budget() {
        // force the fallback by shrinking the budget below stabilization
        let (d, ops) = machine();
        let kernel = lk(&ops, 100_000);
        let cfg = FixedPointConfig { fallback_frac: 0.0001, keep_trace: false };
        let e = estimate_layer(&d, &kernel, &cfg).unwrap();
        // either it stabilized within 10 iterations (k_block=1 machine) or
        // fell back; both must stay close to the whole-graph result
        let whole = evaluate_whole(&d, &kernel).unwrap();
        let err = (e.cycles as f64 - whole.cycles as f64).abs() / whole.cycles as f64;
        assert!(err < 0.05, "err {err}: fp {} vs whole {}", e.cycles, whole.cycles);
    }
}
