//! The pre-program Algorithm-1 evaluator, retained verbatim as the
//! differential-test reference (`#[cfg(test)]` only — it never ships).
//!
//! This is the evaluator as it existed before the precompiled iteration
//! programs ([`super::program`]): every instruction of every iteration
//! re-derives its route tail, lock owners and latency dispatch, probes a
//! hashmap address scoreboard, and allocates owned [`Instruction`]s. It is
//! deliberately *independent* of the optimized frontier containers — the
//! structural rings use a `BTreeMap` delta map, the buffer fill a hashmap
//! with the historical 4096-entry lazy compaction, and the address
//! scoreboard a plain hashmap — so a differential test between this and
//! [`super::eval::Evaluator`] exercises both the interpreter *and* the
//! rewritten state structures.

use std::collections::BTreeMap;

use crate::acadl::{Diagram, ObjectKind};
use crate::ids::{Addr, Cycle, FxHashMap, ObjId};
use crate::isa::{Instruction, LoopKernel};
use crate::Result;

/// Interval-occupancy tracker (reference form: `BTreeMap` delta map).
#[derive(Debug, Clone)]
enum RefRingRepr {
    Serial { last: Cycle },
    Concurrent { events: BTreeMap<Cycle, i64>, base_active: i64 },
    Unbounded,
}

#[derive(Debug, Clone)]
struct RefSlotRing {
    repr: RefRingRepr,
    capacity: u32,
}

impl RefSlotRing {
    fn new(capacity: u32) -> Self {
        let repr = match capacity {
            u32::MAX => RefRingRepr::Unbounded,
            1 => RefRingRepr::Serial { last: 0 },
            _ => RefRingRepr::Concurrent { events: BTreeMap::new(), base_active: 0 },
        };
        Self { repr, capacity }
    }

    fn gate(&self, t0: Cycle) -> Cycle {
        match &self.repr {
            RefRingRepr::Unbounded => t0,
            RefRingRepr::Serial { last } => t0.max(*last),
            RefRingRepr::Concurrent { events, base_active } => {
                let cap = self.capacity as i64;
                let mut active =
                    base_active + events.range(..=t0).map(|(_, d)| *d).sum::<i64>();
                if active < cap {
                    return t0;
                }
                for (&t, &d) in
                    events.range((std::ops::Bound::Excluded(t0), std::ops::Bound::Unbounded))
                {
                    active += d;
                    if active < cap {
                        return t;
                    }
                }
                unreachable!("occupancy never drains")
            }
        }
    }

    fn insert(&mut self, enter: Cycle, leave: Cycle, horizon: Cycle) {
        match &mut self.repr {
            RefRingRepr::Unbounded => {}
            RefRingRepr::Serial { last } => {
                if leave > *last {
                    *last = leave;
                }
            }
            RefRingRepr::Concurrent { events, base_active } => {
                if leave <= enter {
                    return;
                }
                *events.entry(enter).or_insert(0) += 1;
                *events.entry(leave).or_insert(0) -= 1;
                while let Some((&t, _)) = events.first_key_value() {
                    if t >= horizon {
                        break;
                    }
                    let d = events.remove(&t).unwrap();
                    *base_active += d;
                }
            }
        }
    }
}

/// Per-cycle fill counters (reference form: hashmap + lazy compaction).
#[derive(Debug, Default)]
struct RefBufferFill {
    counts: FxHashMap<Cycle, u32>,
    watermark: Cycle,
}

impl RefBufferFill {
    fn alloc(&mut self, t0: Cycle, cap: u32) -> Cycle {
        let t = self.probe(t0, cap);
        *self.counts.entry(t).or_insert(0) += 1;
        t
    }

    fn probe(&self, t0: Cycle, cap: u32) -> Cycle {
        let mut t = t0.max(self.watermark);
        loop {
            if self.counts.get(&t).copied().unwrap_or(0) < cap {
                return t;
            }
            t += 1;
        }
    }

    fn commit(&mut self, t: Cycle) {
        *self.counts.entry(t).or_insert(0) += 1;
    }

    fn prune_below(&mut self, t: Cycle) {
        if t > self.watermark {
            self.watermark = t;
            if self.counts.len() > 4096 {
                self.counts.retain(|&k, _| k >= t);
            }
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Tag {
    Stage,
    Fu,
    ReadMem,
    WriteBack,
    WriteMem,
}

/// The reference streaming evaluator (pre-program hot path).
pub(crate) struct RefEvaluator<'d> {
    d: &'d Diagram,
    obj_ring: Vec<RefSlotRing>,
    reg_last: Vec<Cycle>,
    addr_last: FxHashMap<Addr, Cycle>,
    b_enter: RefBufferFill,
    b_forward: RefBufferFill,
    instr_index: u64,
    group_slots: Vec<Cycle>,
    next_fetch_start: Cycle,
    last_ifs_enter: Cycle,
    horizon: Cycle,
    /// Total AIDG nodes processed (compared against the optimized path).
    pub nodes: u64,
    /// (min_enter, max_leave) per evaluated iteration, in order.
    pub iter_stats: Vec<super::IterStat>,
    buf: Vec<Instruction>,
    tail: Vec<(ObjId, Tag)>,
    routes: Vec<std::sync::Arc<crate::acadl::Route>>,
    p: u64,
    imem_read_lat: Cycle,
    ifs_lat: Cycle,
    issue_buf: u32,
    cur_min_enter: Cycle,
    cur_max_leave: Cycle,
}

impl<'d> RefEvaluator<'d> {
    pub fn new(d: &'d Diagram) -> Self {
        let f = d.fetch_config();
        Self {
            d,
            obj_ring: (0..d.num_objects())
                .map(|i| RefSlotRing::new(d.lock(ObjId(i as u32)).capacity))
                .collect(),
            reg_last: vec![0; d.num_regs()],
            addr_last: FxHashMap::default(),
            b_enter: RefBufferFill::default(),
            b_forward: RefBufferFill::default(),
            instr_index: 0,
            group_slots: Vec::new(),
            next_fetch_start: 0,
            last_ifs_enter: 0,
            horizon: 0,
            nodes: 0,
            iter_stats: Vec::new(),
            buf: Vec::new(),
            tail: Vec::new(),
            routes: Vec::new(),
            p: f.port_width as u64,
            imem_read_lat: f.read_latency,
            ifs_lat: f.ifs_latency,
            issue_buf: f.issue_buffer_size,
            cur_min_enter: Cycle::MAX,
            cur_max_leave: 0,
        }
    }

    pub fn run(&mut self, kernel: &LoopKernel, range: std::ops::Range<u64>) -> Result<()> {
        for it in range {
            self.buf.clear();
            kernel.emit(it, &mut self.buf);
            self.cur_min_enter = Cycle::MAX;
            self.cur_max_leave = 0;
            let buf = std::mem::take(&mut self.buf);
            let mut res = Ok(());
            for (j, instr) in buf.iter().enumerate() {
                res = self.process(instr, j);
                if res.is_err() {
                    break;
                }
            }
            self.buf = buf;
            res?;
            self.iter_stats.push(super::IterStat {
                min_enter: self.cur_min_enter,
                max_leave: self.cur_max_leave,
            });
        }
        Ok(())
    }

    pub fn dt_aidg(&self) -> Cycle {
        let min = self.iter_stats.first().map_or(0, |s| s.min_enter);
        let max = self.iter_stats.iter().map(|s| s.max_leave).max().unwrap_or(0);
        max - min
    }

    fn fetch_leave(&mut self) -> Cycle {
        let within = (self.instr_index % self.p) as usize;
        if within == 0 {
            let t_enter = self.next_fetch_start.max(self.last_ifs_enter);
            if t_enter < self.cur_min_enter {
                self.cur_min_enter = t_enter;
            }
            self.horizon = t_enter;
            let t_stop = t_enter + self.imem_read_lat;
            self.group_slots.clear();
            for _ in 0..self.p {
                let slot = self.b_forward.alloc(t_stop, self.issue_buf);
                self.group_slots.push(slot);
            }
            self.next_fetch_start = t_stop;
            self.b_forward.prune_below(t_enter);
            self.nodes += 1;
        }
        self.instr_index += 1;
        self.group_slots[within]
    }

    fn process(&mut self, instr: &Instruction, offset: usize) -> Result<()> {
        let route = if let Some(r) = self.routes.get(offset) {
            r.clone()
        } else {
            let r = self.d.route(instr)?;
            self.routes.push(r.clone());
            r
        };
        let fetch_leave = self.fetch_leave();

        let f = self.d.fetch_config();
        let wb = self.d.writeback_obj();

        let ifs_lock = self.d.lock(f.fetch_stage).owner;
        let mut t_enter = fetch_leave;
        loop {
            let tg = self.obj_ring[ifs_lock.idx()].gate(t_enter);
            let tb = self.b_enter.probe(tg, self.issue_buf);
            if tb == t_enter {
                break;
            }
            t_enter = tb;
        }
        self.b_enter.commit(t_enter);
        if t_enter < self.cur_min_enter {
            self.cur_min_enter = t_enter;
        }
        self.last_ifs_enter = t_enter;
        self.b_enter.prune_below(fetch_leave.saturating_sub(1));
        let mut t_stop = t_enter + self.ifs_lat;
        self.nodes += 1;

        let mut tail = std::mem::take(&mut self.tail);
        tail.clear();
        for &s in &route.stages {
            tail.push((s, Tag::Stage));
        }
        tail.push((route.fu, Tag::Fu));
        for &m in &route.read_mems {
            tail.push((m, Tag::ReadMem));
        }
        if route.has_writeback {
            tail.push((wb, Tag::WriteBack));
        }
        for &m in &route.write_mems {
            tail.push((m, Tag::WriteMem));
        }

        let first_lock = self.d.lock(tail[0].0).owner;
        let horizon = self.horizon;
        let mut t_leave = self.obj_ring[first_lock.idx()].gate(t_stop);
        self.obj_ring[ifs_lock.idx()].insert(t_enter, t_leave, horizon);
        let mut prev_leave = t_leave;

        for j in 0..tail.len() {
            let (obj, ref tag) = tail[j];
            let lock = self.d.lock(obj);
            t_enter = self.obj_ring[lock.owner.idx()].gate(prev_leave);

            let mut deps: Cycle = 0;
            let lat: Cycle = match tag {
                Tag::Stage => match &self.d.object(obj).kind {
                    ObjectKind::PipelineStage { latency } => latency.eval(instr),
                    _ => 0,
                },
                Tag::Fu => {
                    for r in instr.read_regs.iter().chain(instr.write_regs.iter()) {
                        deps = deps.max(self.reg_last[r.0 as usize]);
                    }
                    match &self.d.object(obj).kind {
                        ObjectKind::FunctionalUnit { latency, .. } => latency.eval(instr),
                        _ => 0,
                    }
                }
                Tag::ReadMem => {
                    let mut n = 0usize;
                    for &a in &instr.read_addrs {
                        if self.d.memory_of(a) == Some(obj) {
                            n += 1;
                            deps =
                                deps.max(self.addr_last.get(&a).copied().unwrap_or(0));
                        }
                    }
                    self.d.mem_latency(obj, n, false, instr)
                }
                Tag::WriteBack => 0,
                Tag::WriteMem => {
                    let mut n = 0usize;
                    for &a in &instr.write_addrs {
                        if self.d.memory_of(a) == Some(obj) {
                            n += 1;
                            deps =
                                deps.max(self.addr_last.get(&a).copied().unwrap_or(0));
                        }
                    }
                    self.d.mem_latency(obj, n, true, instr)
                }
            };

            t_stop = t_enter.max(deps) + lat;
            t_leave = if j + 1 < tail.len() {
                let next_lock = self.d.lock(tail[j + 1].0).owner;
                self.obj_ring[next_lock.idx()].gate(t_stop)
            } else {
                t_stop
            };
            self.obj_ring[lock.owner.idx()].insert(t_enter, t_leave, horizon);
            self.nodes += 1;

            match tag {
                Tag::Fu => {
                    for r in &instr.read_regs {
                        self.reg_last[r.0 as usize] = t_leave;
                    }
                    if !route.has_writeback {
                        for r in &instr.write_regs {
                            self.reg_last[r.0 as usize] = t_leave;
                        }
                    }
                }
                Tag::ReadMem => {
                    for &a in &instr.read_addrs {
                        if self.d.memory_of(a) == Some(obj) {
                            self.addr_last.insert(a, t_leave);
                        }
                    }
                }
                Tag::WriteBack => {
                    for r in &instr.write_regs {
                        self.reg_last[r.0 as usize] = t_leave;
                    }
                }
                Tag::WriteMem => {
                    for &a in &instr.write_addrs {
                        if self.d.memory_of(a) == Some(obj) {
                            self.addr_last.insert(a, t_leave);
                        }
                    }
                }
                Tag::Stage => {}
            }
            prev_leave = t_leave;
        }

        self.tail = tail;
        if prev_leave > self.cur_max_leave {
            self.cur_max_leave = prev_leave;
        }
        Ok(())
    }
}
