//! Lane-batched SoA evaluation: one iteration-program walk, N candidates.
//!
//! DSE sweeps spend their time re-interpreting the *same* iteration
//! program: the locality scheduler already groups candidates by
//! [`Diagram::content_digest`], and digest-equal diagrams have identical
//! object tables in identical ID order — so their kernels resolve to the
//! same routes and lower to the same [`IterProgram`]. This module exploits
//! that: the program (and its route templates) is lowered **once per digest
//! group**, and each instruction step advances N *lanes* in
//! structure-of-arrays layout. What stays per-lane is exactly what §6.3
//! says may vary between iterations of one kernel — and therefore between
//! digest-equal candidates: addresses, immediates, and the dynamic
//! latencies ([`Lat::Dyn`]) re-evaluated against each lane's own `Diagram`.
//!
//! Laned frontier state:
//! - [`SlotRing`]s become a flat `[object × lane]` matrix (`obj * n + lane`),
//! - the paged address plane becomes a [`LanePlane`]: shared page index and
//!   one-entry cache in front of word-major per-lane columns,
//! - `BufferFill`s, register scoreboards, clocks and per-iteration stats
//!   stay per-lane (they are small and trivially independent).
//!
//! Divergence handling: a lane whose digest or `insts_per_iter` differs
//! from the group's reference, whose route template mismatches at an
//! offset's first verification, or whose addresses stop obeying the lowered
//! address→memory partition is **evicted** — its partial batch state is
//! abandoned and the lane is re-estimated from scratch on the serial path,
//! which is bit-identical by construction. Surviving lanes are provably
//! serial-identical: route equality pins the node sequence, and the
//! per-iteration partition check pins every memory node's operand
//! positions to what the lane's own lowering would have produced.

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use crate::acadl::{Diagram, Route};
use crate::ids::{Cycle, ObjId};
use crate::isa::{EmitBuf, InstrView, LoopKernel};
use crate::metrics::counters;
use crate::Result;

use super::eval::IterStat;
use super::fixed_point::{
    estimate_layer, k_block, overlap, FixedPointConfig, LayerEstimate, Provenance,
};
use super::fuse;
use super::ops::{
    self, default_dispatch, DispatchMode, DispatchStats, FusionStats, LaneFrontier, ThreadCtx,
    ThreadedProgram,
};
use super::program::{IterProgram, Lat, NodeKind, NO_LOCK};
use super::state::{BufferFill, LanePlane, SlotRing};

/// Maximum lanes per batch chunk (re-exported from the laned plane:
/// per-page residency is a single `u64` bitmask). Larger digest groups are
/// evaluated in chunks of this size.
pub use super::state::MAX_LANES;

/// Where a lane stands in the batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneStatus {
    /// Advancing in lockstep.
    Active,
    /// Finished cleanly (its fixed-point plan retired it).
    Retired,
    /// Diverged from the group template; must be re-estimated serially.
    Evicted,
}

/// Per-lane carried state: everything the serial evaluator keeps in
/// `EvalState` that is *not* hoisted into a shared laned structure.
struct Lane<'d> {
    d: &'d Diagram,
    kernel: &'d LoopKernel,
    status: LaneStatus,
    iter_stats: Vec<IterStat>,
    reg_last: Vec<Cycle>,
    b_enter: BufferFill,
    b_forward: BufferFill,
    group_slots: Vec<Cycle>,
    instr_index: u64,
    next_fetch_start: Cycle,
    last_ifs_enter: Cycle,
    horizon: Cycle,
    cur_min_enter: Cycle,
    cur_max_leave: Cycle,
    nodes: u64,
    peak_bytes: usize,
    /// Offsets whose route this lane has checked against the template
    /// (monotone — offsets arrive in order within an iteration).
    routes_checked: usize,
}

/// Fetch-path constants (digest-invariant, copied from the reference lane).
#[derive(Clone, Copy)]
struct FetchConsts {
    ifs_lock: u32,
    p: u64,
    imem_read_lat: Cycle,
    ifs_lat: Cycle,
    issue_buf: u32,
}

/// N-lane lockstep evaluator over one shared iteration program.
///
/// Lane 0's diagram is the group *reference*: lanes whose
/// [`Diagram::content_digest`] or `insts_per_iter` differ are evicted at
/// construction. The program is lowered from the first live lane to reach
/// each offset; every other lane verifies its own route against the
/// template the first time it steps that offset and is evicted on
/// mismatch.
pub struct BatchEvaluator<'d> {
    lanes: Vec<Lane<'d>>,
    emits: Vec<EmitBuf>,
    program: IterProgram,
    /// Fused superinstruction tape, grown in lockstep with `program`.
    threaded: ThreadedProgram,
    /// How lowered offsets are interpreted (fixed at construction).
    dispatch: DispatchMode,
    /// Cumulative threaded-dispatch statistics (all lanes).
    stats: DispatchStats,
    /// Watermark of `stats` already flushed to the process counters.
    flushed: DispatchStats,
    routes: Vec<Arc<Route>>,
    /// SlotRing matrix, `[owner_obj * n_lanes + lane]`.
    rings: Vec<SlotRing>,
    plane: LanePlane,
    fetch: FetchConsts,
    next_iter: u64,
    evictions: u64,
    pub(crate) obs_run_ns: u64,
    pub(crate) obs_compile_ns: u64,
}

impl<'d> BatchEvaluator<'d> {
    /// A fresh batch over `members` (at most [`MAX_LANES`]); lane 0 is the
    /// structural reference. Uses the process-default dispatch mode.
    pub fn new(members: &[(&'d Diagram, &'d LoopKernel)]) -> Self {
        Self::new_with_dispatch(members, default_dispatch())
    }

    /// A fresh batch with an explicit dispatch mode (tests and benches
    /// compare modes without touching the process-global default).
    pub fn new_with_dispatch(
        members: &[(&'d Diagram, &'d LoopKernel)],
        dispatch: DispatchMode,
    ) -> Self {
        assert!(
            !members.is_empty() && members.len() <= MAX_LANES,
            "batch must hold 1..={MAX_LANES} lanes (got {})",
            members.len()
        );
        let n = members.len();
        let (d0, k0) = members[0];
        let f = d0.fetch_config();
        let digest0 = d0.content_digest();
        let mut evictions = 0u64;
        let lanes: Vec<Lane<'d>> = members
            .iter()
            .map(|&(d, kernel)| {
                let diverged =
                    d.content_digest() != digest0 || kernel.insts_per_iter != k0.insts_per_iter;
                if diverged {
                    evictions += 1;
                }
                Lane {
                    d,
                    kernel,
                    status: if diverged { LaneStatus::Evicted } else { LaneStatus::Active },
                    iter_stats: Vec::new(),
                    reg_last: vec![0; d.num_regs()],
                    b_enter: BufferFill::default(),
                    b_forward: BufferFill::default(),
                    group_slots: Vec::new(),
                    instr_index: 0,
                    next_fetch_start: 0,
                    last_ifs_enter: 0,
                    horizon: 0,
                    cur_min_enter: Cycle::MAX,
                    cur_max_leave: 0,
                    nodes: 0,
                    peak_bytes: 0,
                    routes_checked: 0,
                }
            })
            .collect();
        let num_objects = d0.num_objects();
        let mut rings = Vec::with_capacity(num_objects * n);
        for obj in 0..num_objects {
            let cap = d0.lock(ObjId(obj as u32)).capacity;
            for _ in 0..n {
                rings.push(SlotRing::new(cap));
            }
        }
        Self {
            lanes,
            emits: (0..n).map(|_| EmitBuf::new()).collect(),
            program: IterProgram::default(),
            threaded: ThreadedProgram::default(),
            dispatch,
            stats: DispatchStats::default(),
            flushed: DispatchStats::default(),
            routes: Vec::new(),
            rings,
            plane: LanePlane::new(n),
            fetch: FetchConsts {
                ifs_lock: d0.lock(f.fetch_stage).owner.idx() as u32,
                p: f.port_width as u64,
                imem_read_lat: f.read_latency,
                ifs_lat: f.ifs_latency,
                issue_buf: f.issue_buffer_size,
            },
            next_iter: 0,
            evictions,
            obs_run_ns: 0,
            obs_compile_ns: 0,
        }
    }

    /// Number of lanes in the batch (including evicted ones).
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Lanes still advancing.
    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.status == LaneStatus::Active).count()
    }

    /// Total evictions so far (construction-time divergence included).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Cumulative threaded-dispatch execution statistics (all lanes).
    pub fn dispatch_stats(&self) -> DispatchStats {
        self.stats
    }

    /// Static composition of the fused tape vs the shared node table.
    pub fn fusion_stats(&self) -> FusionStats {
        self.threaded.fusion_stats(self.program.nodes.len())
    }

    /// The dispatch mode this batch interprets with.
    pub fn dispatch_mode(&self) -> DispatchMode {
        self.dispatch
    }

    /// This lane's status.
    pub fn status(&self, lane: usize) -> LaneStatus {
        self.lanes[lane].status
    }

    /// Retire an active lane (its fixed-point plan is satisfied); it stops
    /// stepping but keeps its accumulated stats.
    pub fn retire(&mut self, lane: usize) {
        debug_assert_eq!(self.lanes[lane].status, LaneStatus::Active);
        self.lanes[lane].status = LaneStatus::Retired;
    }

    /// This lane's per-iteration stats so far.
    pub fn iter_stats(&self, lane: usize) -> &[IterStat] {
        &self.lanes[lane].iter_stats
    }

    /// AIDG nodes this lane has evaluated.
    pub fn nodes(&self, lane: usize) -> u64 {
        self.lanes[lane].nodes
    }

    /// This lane's peak tracked frontier bytes (serial-identical
    /// accounting — see [`LanePlane::lane_bytes`]).
    pub fn peak_bytes(&self, lane: usize) -> usize {
        self.lanes[lane].peak_bytes
    }

    /// Whole-graph end-to-end latency of one lane so far (eq. 1).
    pub fn dt_aidg(&self, lane: usize) -> Cycle {
        let stats = &self.lanes[lane].iter_stats;
        let min = stats.first().map_or(0, |s| s.min_enter);
        let max = stats.iter().map(|s| s.max_leave).max().unwrap_or(0);
        max - min
    }

    /// Pre-size every active lane's stats vector (mirrors the serial
    /// evaluator's `reserve` so the steady state stays allocation-free).
    pub fn reserve(&mut self, iters: usize) {
        for lane in &mut self.lanes {
            if lane.status == LaneStatus::Active {
                lane.iter_stats.reserve(iters);
            }
        }
    }

    /// Advance every active lane through iterations `range` in lockstep.
    /// Ranges must be contiguous across calls (chunked fixed-point
    /// driving), starting at 0.
    pub fn run(&mut self, range: Range<u64>) -> Result<()> {
        assert_eq!(range.start, self.next_iter, "batch iterations must be contiguous");
        let t_run = if crate::obs::enabled() { crate::obs::now_ns() } else { 0 };
        self.reserve(range.end.saturating_sub(range.start) as usize);
        let n_lanes = self.lanes.len();
        let fetch = self.fetch;
        let dispatch = self.dispatch;
        let Self {
            lanes,
            emits,
            program,
            threaded,
            stats,
            routes,
            rings,
            plane,
            evictions,
            obs_compile_ns,
            ..
        } = self;
        for it in range.clone() {
            // Emit phase: each active lane fills its own arena.
            let mut max_len = 0usize;
            for (lane, emit) in lanes.iter_mut().zip(emits.iter_mut()) {
                if lane.status != LaneStatus::Active {
                    continue;
                }
                emit.clear();
                lane.kernel.emit_into(it, emit);
                lane.cur_min_enter = Cycle::MAX;
                lane.cur_max_leave = 0;
                max_len = max_len.max(emit.len());
            }
            // Step phase: offset-major, lane-minor — the shared program and
            // rings stay hot while lanes stream their own operands.
            for offset in 0..max_len {
                for li in 0..n_lanes {
                    if lanes[li].status != LaneStatus::Active || offset >= emits[li].len() {
                        continue;
                    }
                    let view = emits[li].view(offset);
                    let ok = step_lane(
                        program,
                        threaded,
                        dispatch,
                        stats,
                        routes,
                        rings,
                        plane,
                        &mut lanes[li],
                        li,
                        n_lanes,
                        &fetch,
                        &view,
                        offset,
                        it,
                        obs_compile_ns,
                    )?;
                    if !ok {
                        lanes[li].status = LaneStatus::Evicted;
                        *evictions += 1;
                    }
                }
            }
            // Close the iteration per surviving lane.
            let num_objects = rings.len() / n_lanes;
            for (li, lane) in lanes.iter_mut().enumerate() {
                if lane.status != LaneStatus::Active {
                    continue;
                }
                lane.iter_stats.push(IterStat {
                    min_enter: lane.cur_min_enter,
                    max_leave: lane.cur_max_leave,
                });
                let mut live = lane.reg_last.len() * std::mem::size_of::<Cycle>()
                    + plane.lane_bytes(li)
                    + lane.b_enter.bytes()
                    + lane.b_forward.bytes();
                for obj in 0..num_objects {
                    live += rings[obj * n_lanes + li].bytes();
                }
                live += lane.iter_stats.len() * std::mem::size_of::<IterStat>();
                if live > lane.peak_bytes {
                    lane.peak_bytes = live;
                }
            }
        }
        self.next_iter = range.end;
        if t_run != 0 {
            self.obs_run_ns += crate::obs::now_ns().saturating_sub(t_run);
        }
        self.stats.flush(&mut self.flushed);
        Ok(())
    }
}

/// Step one lane through one instruction. Returns `Ok(false)` when the lane
/// diverged from the group template (caller evicts it); errors propagate
/// (the lane's serial re-run would fail identically).
///
/// This is a line-for-line transcription of the serial
/// `Evaluator::{fetch_leave, step}` with the frontier swapped for its laned
/// columns: `obj_ring[x]` → `rings[x * n_lanes + li]`, `addr_last` →
/// `plane.{get,set}(li, ..)`. Any behavioral edit here must be mirrored in
/// `eval.rs` (and vice versa) — the differential tests will catch drift.
#[allow(clippy::too_many_arguments)]
fn step_lane(
    program: &mut IterProgram,
    threaded: &mut ThreadedProgram,
    dispatch: DispatchMode,
    stats: &mut DispatchStats,
    routes: &mut Vec<Arc<Route>>,
    rings: &mut [SlotRing],
    plane: &mut LanePlane,
    lane: &mut Lane<'_>,
    li: usize,
    n_lanes: usize,
    fetch: &FetchConsts,
    view: &InstrView<'_>,
    offset: usize,
    _it: u64,
    obs_compile_ns: &mut u64,
) -> Result<bool> {
    // --- template lowering / verification --------------------------------
    if offset >= program.len() {
        debug_assert_eq!(offset, program.len(), "offsets must arrive in order");
        let t_lower = if crate::obs::enabled() { crate::obs::now_ns() } else { 0 };
        let instr = view.to_instruction();
        let route = lane.d.route(&instr)?;
        program.lower_offset(lane.d, &route, view);
        fuse::fuse_offset(program, offset, fetch.ifs_lock, threaded);
        routes.push(route);
        lane.routes_checked = lane.routes_checked.max(offset + 1);
        if t_lower != 0 {
            *obs_compile_ns += crate::obs::now_ns().saturating_sub(t_lower);
        }
    } else if offset >= lane.routes_checked {
        // First time this lane steps an offset lowered by another lane:
        // its own route must match the template or the shared node table
        // is not its node table.
        let r = lane.d.route(&view.to_instruction())?;
        lane.routes_checked = offset + 1;
        if *routes[offset] != *r {
            return Ok(false);
        }
    }
    let meta = program.offsets[offset];
    let tmeta = threaded.offsets[offset];
    let use_tape = dispatch == DispatchMode::Threaded && tmeta.fusible;
    // The batch has no slow memory path: a lane whose addresses stop
    // obeying the lowered partition is evicted (the serial re-run performs
    // the full-scan fallback bit-identically). On the tape the folded
    // address guard *is* the partition check (fusible tapes carry
    // single-range memberships only), so the eviction policy is identical.
    let holds = if use_tape {
        ops::guard_holds(
            &threaded.ops[tmeta.ops.0 as usize..tmeta.ops.1 as usize],
            &program.positions,
            &meta,
            view,
        )
    } else {
        program.partition_holds(lane.d, &meta, view)
    };
    if !holds {
        return Ok(false);
    }

    // --- merged fetch node (Algorithm 1 lines 36–42) ---------------------
    let within = (lane.instr_index % fetch.p) as usize;
    if within == 0 {
        let t_enter = lane.next_fetch_start.max(lane.last_ifs_enter);
        if t_enter < lane.cur_min_enter {
            lane.cur_min_enter = t_enter;
        }
        lane.horizon = t_enter;
        let t_stop = t_enter + fetch.imem_read_lat;
        lane.group_slots.clear();
        for _ in 0..fetch.p {
            let slot = lane.b_forward.alloc(t_stop, fetch.issue_buf);
            lane.group_slots.push(slot);
        }
        lane.next_fetch_start = t_stop;
        lane.b_forward.prune_below(t_enter);
        lane.nodes += 1;
    }
    lane.instr_index += 1;
    let fetch_leave = lane.group_slots[within];

    let ring = |x: u32| x as usize * n_lanes + li;

    // --- IFS node --------------------------------------------------------
    let mut t_enter = fetch_leave;
    loop {
        let tg = rings[ring(fetch.ifs_lock)].gate(t_enter);
        let tb = lane.b_enter.probe(tg, fetch.issue_buf);
        if tb == t_enter {
            break;
        }
        t_enter = tb;
    }
    lane.b_enter.commit(t_enter);
    if t_enter < lane.cur_min_enter {
        lane.cur_min_enter = t_enter;
    }
    lane.last_ifs_enter = t_enter;
    lane.b_enter.prune_below(fetch_leave.saturating_sub(1));
    let mut t_stop = t_enter + fetch.ifs_lat;
    lane.nodes += 1;

    let horizon = lane.horizon;
    let mut t_leave = rings[ring(meta.first_tail_lock)].gate(t_stop);
    rings[ring(fetch.ifs_lock)].insert(t_enter, t_leave, horizon);
    let mut prev_leave = t_leave;

    // --- tail nodes: threaded tape ---------------------------------------
    if use_tape {
        stats.threaded_instrs += 1;
        let ThreadedProgram { ops: tape, stages, memo, .. } = threaded;
        let mut f = LaneFrontier {
            rings,
            plane,
            reg_last: &mut lane.reg_last,
            li,
            n_lanes,
        };
        let mut ctx = ThreadCtx {
            f: &mut f,
            d: lane.d,
            view: *view,
            positions: &program.positions,
            stages,
            memo,
            horizon,
            prev_leave,
            nodes: 0,
            stats,
        };
        ops::execute(&mut ctx, &tape[tmeta.ops.0 as usize..tmeta.ops.1 as usize]);
        let (nodes, tape_leave) = (ctx.nodes, ctx.prev_leave);
        lane.nodes += nodes;
        if tape_leave > lane.cur_max_leave {
            lane.cur_max_leave = tape_leave;
        }
        return Ok(true);
    }
    if dispatch == DispatchMode::Threaded {
        // structural fallback: the offset never compiled to a tape
        stats.fallback_instrs += 1;
    }

    // --- tail nodes: node-table walk --------------------------------------
    for ni in meta.nodes.0..meta.nodes.1 {
        let node = program.nodes[ni as usize];
        t_enter = rings[ring(node.owner)].gate(prev_leave);

        let mut deps: Cycle = 0;
        let lat: Cycle = match node.kind {
            NodeKind::Stage { lat } => lat.eval(lane.d, view.imms),
            NodeKind::Fu { lat, .. } => {
                for r in view.read_regs.iter().chain(view.write_regs.iter()) {
                    deps = deps.max(lane.reg_last[r.0 as usize]);
                }
                lat.eval(lane.d, view.imms)
            }
            NodeKind::Mem { write, per_txn, port, pos, .. } => {
                let addrs = if write { view.write_addrs } else { view.read_addrs };
                for &p in program.positions_of(pos) {
                    deps = deps.max(plane.get(li, addrs[p as usize]));
                }
                let n = (pos.1 - pos.0) as usize;
                let per = match per_txn {
                    Lat::Fix(c) => c,
                    Lat::Dyn(m) => lane.d.mem_txn_latency_imms(m, write, view.imms),
                };
                per * (n as u64).div_ceil(port as u64).max(1)
            }
            NodeKind::WriteBack => 0,
        };

        t_stop = t_enter.max(deps) + lat;
        t_leave = if node.next != NO_LOCK { rings[ring(node.next)].gate(t_stop) } else { t_stop };
        rings[ring(node.owner)].insert(t_enter, t_leave, horizon);
        lane.nodes += 1;

        match node.kind {
            NodeKind::Fu { anchors_writes, .. } => {
                for r in view.read_regs {
                    lane.reg_last[r.0 as usize] = t_leave;
                }
                if anchors_writes {
                    for r in view.write_regs {
                        lane.reg_last[r.0 as usize] = t_leave;
                    }
                }
            }
            NodeKind::Mem { write, pos, .. } => {
                let addrs = if write { view.write_addrs } else { view.read_addrs };
                for &p in program.positions_of(pos) {
                    plane.set(li, addrs[p as usize], t_leave);
                }
            }
            NodeKind::WriteBack => {
                for r in view.write_regs {
                    lane.reg_last[r.0 as usize] = t_leave;
                }
            }
            NodeKind::Stage { .. } => {}
        }
        prev_leave = t_leave;
    }

    if prev_leave > lane.cur_max_leave {
        lane.cur_max_leave = prev_leave;
    }
    Ok(true)
}

/// Result of a batched layer estimation.
pub struct BatchOutcome {
    /// One estimate per input lane, in input order — bit-identical to what
    /// [`estimate_layer`] returns for that lane alone.
    pub estimates: Vec<LayerEstimate>,
    /// Lanes that diverged from the batch template and were re-estimated
    /// serially (construction-time digest mismatches included).
    pub evicted: u64,
}

/// How a lane's fixed-point plan concluded (mirrors the serial §6.3
/// driver's three exits).
#[derive(Clone, Copy)]
enum Done {
    Whole,
    Fixed { k_prolog: u64 },
    Fallback,
}

/// Batched [`estimate_layer`]: one estimate per lane, bit-identical to the
/// serial path per lane. Digest groups larger than [`MAX_LANES`] are
/// chunked; evicted lanes fall back to [`estimate_layer`] transparently.
pub fn estimate_layer_batch(
    lanes: &[(&Diagram, &LoopKernel)],
    cfg: &FixedPointConfig,
) -> Result<BatchOutcome> {
    let mut estimates = Vec::with_capacity(lanes.len());
    let mut evicted = 0u64;
    for chunk in lanes.chunks(MAX_LANES) {
        let (es, ev) = estimate_chunk(chunk, cfg)?;
        estimates.extend(es);
        evicted += ev;
    }
    Ok(BatchOutcome { estimates, evicted })
}

/// One ≤[`MAX_LANES`] chunk: drive every lane's §6.3 plan over a single
/// lockstep instruction walk, retiring lanes as their plans conclude.
///
/// The lockstep driver preserves the serial decision sequence exactly:
/// per-lane events fire at the same evaluated-iteration counts the serial
/// chunk loop would reach, with the same precedence (whole-graph beats
/// stability beats budget — see `fixed_point.rs`).
fn estimate_chunk(
    lanes: &[(&Diagram, &LoopKernel)],
    cfg: &FixedPointConfig,
) -> Result<(Vec<LayerEstimate>, u64)> {
    let n = lanes.len();
    let start = Instant::now();
    let mut sp = crate::obs::span("aidg.estimate_batch");
    sp.arg("lanes", n as u64);

    let mut batch = BatchEvaluator::new(lanes);
    counters::AIDG_BATCH_GROUPS.add(1);
    counters::AIDG_BATCH_LANES.add(n as u64);

    let d0 = lanes[0].0;
    let p = d0.fetch_config().port_width as u64;
    let kb = k_block(lanes[0].1.insts_per_iter as u64, p);

    // Per-lane fixed-point plan (None = chunked evaluation with a fallback
    // budget; Some(Done::Whole) at construction when the block already
    // covers the kernel).
    struct Plan {
        whole: bool,
        budget: u64,
        prev_span: Option<Cycle>,
    }
    let mut plans: Vec<Plan> = lanes
        .iter()
        .map(|&(_, kernel)| {
            let k = kernel.k;
            if kb >= k || 3 * kb > k {
                Plan { whole: true, budget: u64::MAX, prev_span: None }
            } else {
                let budget = ((k as f64 * cfg.fallback_frac) as u64).max(3 * kb);
                Plan { whole: false, budget, prev_span: None }
            }
        })
        .collect();
    let mut done: Vec<Option<Done>> = vec![None; n];

    let mut it = 0u64;
    loop {
        // Fire the events that land on `it`, in the serial precedence
        // order: reaching k retires whole-graph; a block boundary checks
        // stability, then updates the span window, then checks the budget.
        for li in 0..n {
            if batch.status(li) != LaneStatus::Active {
                continue;
            }
            let k = lanes[li].1.k;
            if it >= k {
                batch.retire(li);
                done[li] = Some(Done::Whole);
                continue;
            }
            if !plans[li].whole && it > 0 && it % kb == 0 {
                let span = batch.iter_stats(li).last().expect("ran ≥ kb iterations").span();
                if it >= 2 * kb && plans[li].prev_span == Some(span) && it >= 3 * kb {
                    batch.retire(li);
                    done[li] = Some(Done::Fixed { k_prolog: it });
                    continue;
                }
                plans[li].prev_span = Some(span);
                if it >= plans[li].budget {
                    batch.retire(li);
                    done[li] = Some(Done::Fallback);
                }
            }
        }
        // Next lockstep target: the earliest pending event of any lane.
        let mut target: Option<u64> = None;
        for li in 0..n {
            if batch.status(li) != LaneStatus::Active {
                continue;
            }
            let k = lanes[li].1.k;
            let ev = if plans[li].whole { k } else { ((it / kb) + 1) * kb }.min(k);
            target = Some(target.map_or(ev, |t| t.min(ev)));
        }
        let Some(target) = target else { break };
        debug_assert!(target > it);
        batch.run(it..target)?;
        it = target;
    }

    if crate::obs::enabled() {
        crate::obs::record_duration("aidg.program.compile", batch.obs_compile_ns);
        crate::obs::record_duration(
            "aidg.evaluate",
            batch.obs_run_ns.saturating_sub(batch.obs_compile_ns),
        );
    }

    // Assemble results: retired lanes finish from their own stats exactly
    // as the serial driver would; evicted lanes re-run serially from
    // scratch (their partial batch state is discarded).
    let mut out = Vec::with_capacity(n);
    let mut evicted = 0u64;
    for (li, &(d, kernel)) in lanes.iter().enumerate() {
        match done[li] {
            Some(dn) if batch.status(li) == LaneStatus::Retired => {
                out.push(assemble(&batch, li, kernel, dn, kb, cfg, &start));
            }
            _ => {
                evicted += 1;
                out.push(estimate_layer(d, kernel, cfg)?);
            }
        }
    }
    counters::AIDG_BATCH_EVICTIONS.add(evicted);
    sp.arg("evicted", evicted);
    Ok((out, evicted))
}

/// Produce one lane's [`LayerEstimate`] from its batch stats — field-level
/// mirror of the serial driver's `finish` closure.
fn assemble(
    batch: &BatchEvaluator<'_>,
    li: usize,
    kernel: &LoopKernel,
    done: Done,
    kb: u64,
    cfg: &FixedPointConfig,
    start: &Instant,
) -> LayerEstimate {
    let stats = batch.iter_stats(li);
    let k = kernel.k;
    counters::note_aidg(batch.nodes(li), stats.len() as u64);
    let (cycles, k_prolog, dt_iteration, dt_overlap, used_fallback, whole_graph) = match done {
        Done::Whole => {
            let cycles = batch.dt_aidg(li);
            let dt_it = stats.last().map_or(0, |s| s.span());
            (cycles, k, dt_it, overlap(stats), false, true)
        }
        Done::Fixed { k_prolog } => {
            let dt_prolog = stats.iter().map(|s| s.max_leave).max().unwrap_or(0);
            let dt_iteration = stats.last().map_or(0, |s| s.span());
            let ov = overlap(stats);
            let stride = dt_iteration as i64 - ov;
            let cycles = (dt_prolog as i64 + (k - k_prolog) as i64 * stride)
                .max(dt_prolog as i64) as Cycle;
            (cycles, k_prolog, dt_iteration, ov, false, false)
        }
        Done::Fallback => {
            let k01 = stats.len() as u64;
            let k_prolog = (k01 / 4).max(1);
            let leave_at = |it: u64| stats[(it - 1) as usize].max_leave;
            let dt_window = leave_at(k01) - leave_at(k_prolog);
            let dt_iteration = ((dt_window as f64) / ((k01 - k_prolog) as f64)).round() as Cycle;
            let dt_prolog = leave_at(k_prolog);
            let cycles = dt_prolog + (k - k_prolog) * dt_iteration;
            (cycles, k_prolog, dt_iteration, 0, true, false)
        }
    };
    LayerEstimate {
        label: kernel.label.clone(),
        k,
        insts_per_iter: kernel.insts_per_iter,
        cycles,
        evaluated_iters: stats.len() as u64,
        k_block: kb,
        k_prolog,
        dt_iteration,
        dt_overlap,
        used_fallback,
        whole_graph,
        nodes: batch.nodes(li),
        peak_state_bytes: batch.peak_bytes(li) as u64,
        runtime: start.elapsed(),
        provenance: Provenance::Computed,
        trace: cfg.keep_trace.then(|| stats.to_vec()),
        calibrated_cycles: None,
        ci_lo: None,
        ci_hi: None,
    }
}
