//! Superinstruction fusion: lower one node-table offset into its threaded
//! tape ([`super::ops`]).
//!
//! Runs once per instruction offset, immediately after
//! `IterProgram::lower_offset`, inside the compile-timing window — the
//! steady state never fuses. The pass is purely structural: it reads the
//! just-lowered node slice and emits ops whose replay is bit-identical to
//! the node-table walk (see the module docs of [`super::ops`] for the
//! elision proof and the fallback contract).
//!
//! Fusion preconditions, checked here:
//! - the offset has at least one tail node (always true for a routed
//!   instruction — the FU node — but checked for safety);
//! - every memory node carries a single-range membership check
//!   (`end > base`). A multi-range memory would need `memory_of` scans the
//!   folded guard cannot express, so the offset is marked non-fusible and
//!   permanently takes the node-table path (a *structural* fallback, with
//!   the normal partition check intact).

use crate::ids::Cycle;

use super::ops::{
    LatSlot, MemoKind, Op, StageEntry, TapeMeta, ThreadedProgram, FLAG_ANCHORS_WRITES,
    FLAG_PRE_GATED, FLAG_WRITE, OP_ADVANCE_CLOCK, OP_LOCKED_STEP, OP_MEM_STEP, OP_STAGE_STEP,
    OP_WRITE_BACK,
};
use super::program::{IterProgram, Lat, NodeKind};

/// True when this node is a fixed-latency pipeline stage (an `AdvanceClock`
/// run candidate).
fn fixed_stage(kind: &NodeKind) -> Option<Cycle> {
    match kind {
        NodeKind::Stage { lat: Lat::Fix(c) } => Some(*c),
        _ => None,
    }
}

/// Fuse the just-lowered `offset` of `program` onto the tape. Offsets must
/// be fused in lowering order, exactly once each.
pub(crate) fn fuse_offset(
    program: &IterProgram,
    offset: usize,
    ifs_lock: u32,
    tp: &mut ThreadedProgram,
) {
    debug_assert_eq!(offset, tp.offsets.len(), "offsets must be fused in order");
    let meta = program.offsets[offset];
    let nodes = &program.nodes[meta.nodes.0 as usize..meta.nodes.1 as usize];

    let fusible = !nodes.is_empty()
        && nodes.iter().all(|n| match n.kind {
            NodeKind::Mem { base, end, .. } => end > base,
            _ => true,
        });
    if !fusible {
        let at = tp.ops.len() as u32;
        tp.offsets.push(TapeMeta { ops: (at, at), fusible: false });
        return;
    }

    let op_start = tp.ops.len() as u32;
    // The gate preceding node 0 is the IFS look-ahead on `first_tail_lock`
    // (== owner of node 0); the only ring mutated in between is the IFS
    // lock's. For node i > 0 it is node i-1's look-ahead, with only
    // owner_{i-1}'s ring mutated in between. Either way the entry gate is
    // elidable iff the owner differs from the last-mutated ring.
    let mut prev_owner = ifs_lock;
    let mut i = 0usize;
    while i < nodes.len() {
        let node = nodes[i];

        // Run of >= 2 consecutive fixed-latency stages -> one AdvanceClock.
        if fixed_stage(&node.kind).is_some() {
            let mut j = i;
            while j < nodes.len() && fixed_stage(&nodes[j].kind).is_some() {
                j += 1;
            }
            if j - i >= 2 {
                let a = tp.stages.len() as u32;
                let mut total: Cycle = 0;
                for n in &nodes[i..j] {
                    let lat = fixed_stage(&n.kind).unwrap();
                    total += lat;
                    tp.stages.push(StageEntry {
                        owner: n.owner,
                        next: n.next,
                        lat,
                        pre_gated: n.owner != prev_owner,
                    });
                    prev_owner = n.owner;
                }
                tp.ops.push(Op {
                    code: OP_ADVANCE_CLOCK,
                    a,
                    b: tp.stages.len() as u32,
                    total_lat: total,
                    ..Op::DEFAULT
                });
                i = j;
                continue;
            }
        }

        let pre_gated = if node.owner != prev_owner { FLAG_PRE_GATED } else { 0 };
        let op = match node.kind {
            NodeKind::Stage { lat } => Op {
                code: OP_STAGE_STEP,
                flags: pre_gated,
                owner: node.owner,
                next: node.next,
                lat: match lat {
                    Lat::Fix(c) => LatSlot::Fix(c),
                    Lat::Dyn(obj) => tp.memo_slot(MemoKind::Object(obj)),
                },
                ..Op::DEFAULT
            },
            NodeKind::Fu { lat, anchors_writes } => Op {
                code: OP_LOCKED_STEP,
                flags: pre_gated | if anchors_writes { FLAG_ANCHORS_WRITES } else { 0 },
                owner: node.owner,
                next: node.next,
                lat: match lat {
                    Lat::Fix(c) => LatSlot::Fix(c),
                    Lat::Dyn(obj) => tp.memo_slot(MemoKind::Object(obj)),
                },
                ..Op::DEFAULT
            },
            NodeKind::Mem { write, per_txn, port, pos, base, end } => Op {
                code: OP_MEM_STEP,
                flags: pre_gated | if write { FLAG_WRITE } else { 0 },
                owner: node.owner,
                next: node.next,
                a: pos.0,
                b: pos.1,
                lat: match per_txn {
                    Lat::Fix(c) => LatSlot::Fix(c),
                    Lat::Dyn(m) => tp.memo_slot(MemoKind::MemTxn(m, write)),
                },
                port,
                base,
                end,
                ..Op::DEFAULT
            },
            NodeKind::WriteBack => Op {
                code: OP_WRITE_BACK,
                flags: pre_gated,
                owner: node.owner,
                next: node.next,
                ..Op::DEFAULT
            },
        };
        tp.ops.push(op);
        prev_owner = node.owner;
        i += 1;
    }

    tp.offsets.push(TapeMeta { ops: (op_start, tp.ops.len() as u32), fusible: true });
}
