//! Threaded-code dispatch: the fused superinstruction tape and its
//! fn-pointer interpreter.
//!
//! The node-table interpreter of [`super::program`] already replays
//! template-invariant facts, but it still pays a per-node `match` over
//! [`super::program::NodeKind`], a dynamic-latency expression walk per
//! iteration, and a ring `gate` per node even when the algebra proves the
//! gate is a no-op. This module lowers each compiled offset one stage
//! further into a dense *tape* of superinstruction [`Op`]s dispatched
//! through a per-opcode function-pointer table ([`Dispatch::TABLE`] — the
//! computed-goto idiom in safe Rust):
//!
//! - `AdvanceClock` collapses a run of ≥ 2 fixed-latency pipeline-stage
//!   nodes into one op over a [`StageEntry`] slice — one indirect call and
//!   zero kind matches for the whole run;
//! - `LockedStep` fuses an FU node's lock-acquire → compute → release
//!   triple (ring gate, register dependencies + latency, ring insert) into
//!   one op;
//! - `MemStep` folds the single-range address membership check into the
//!   access op itself: the pre-mutation [`guard_holds`] phase replays
//!   exactly the partition check the node table would have run;
//! - `Lat::Dyn` expression latencies are memoized per interned immediate
//!   tuple in a fixed-size [`MemoSite`] cache — once per `(expr, imms)`
//!   instead of once per iteration.
//!
//! ### The `pre_gated` elision
//!
//! Ring gates are pure and idempotent (`gate(x, gate(x, t)) == gate(x, t)`
//! while `x`'s ring is unchanged), and `insert` mutates only its own ring.
//! In the tail-node walk, node *i*'s leave time is already
//! `gate(owner_{i+1}, t_stop_i)` (the structural look-ahead), and the only
//! ring mutated before node *i+1*'s own gate is `owner_i`'s. When
//! `owner_{i+1} != owner_i` the entry gate is therefore provably the
//! identity and the tape skips it — computed per node at fuse time
//! ([`super::fuse`]), never guessed at run time.
//!
//! ### Bit-identity contract
//!
//! A fused tape executes the **same ring gate/insert, scoreboard read/write
//! and latency-evaluation sequence** as the node-table walk, minus only the
//! operations proven to be identities, so both paths (and the
//! `reference.rs` oracle) stay cycle-identical. Offsets that violate a
//! fusion precondition (multi-range memory membership) never get a tape;
//! iterations that break the folded address guard at run time fall back to
//! the node-table walk for that instruction with the partition already
//! known broken. Differential tests pin all of this.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::acadl::Diagram;
use crate::ids::{Addr, Cycle};
use crate::isa::InstrView;
use crate::metrics::counters;

use super::program::{OffsetMeta, NO_LOCK};
use super::state::{EvalState, LanePlane, SlotRing};

// ---------------------------------------------------------------------------
// Dispatch mode knob
// ---------------------------------------------------------------------------

/// How an evaluator walks a lowered iteration program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Fused superinstruction tape through the fn-pointer dispatch table
    /// (the default).
    #[default]
    Threaded = 0,
    /// The per-node `match`-and-index interpreter over the flat node table
    /// (the escape hatch, and the fallback target of the threaded path).
    NodeTable = 1,
}

impl DispatchMode {
    /// Parse a CLI spelling (`threaded` / `node-table`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "threaded" => Some(Self::Threaded),
            "node-table" => Some(Self::NodeTable),
            _ => None,
        }
    }

    /// The CLI spelling of this mode.
    pub fn name(self) -> &'static str {
        match self {
            Self::Threaded => "threaded",
            Self::NodeTable => "node-table",
        }
    }
}

/// Process-global default dispatch mode, read by evaluator constructors
/// (`--dispatch` writes it once at startup).
static DEFAULT_DISPATCH: AtomicU8 = AtomicU8::new(DispatchMode::Threaded as u8);

/// Set the process-global default dispatch mode (the `--dispatch` CLI knob;
/// tests and benches use the explicit `new_with_dispatch` constructors
/// instead to stay race-free under the parallel test harness).
pub fn set_default_dispatch(mode: DispatchMode) {
    DEFAULT_DISPATCH.store(mode as u8, Ordering::Relaxed);
}

/// The process-global default dispatch mode.
pub fn default_dispatch() -> DispatchMode {
    if DEFAULT_DISPATCH.load(Ordering::Relaxed) == DispatchMode::NodeTable as u8 {
        DispatchMode::NodeTable
    } else {
        DispatchMode::Threaded
    }
}

// ---------------------------------------------------------------------------
// Tape representation
// ---------------------------------------------------------------------------

/// Opcode: a run of fused fixed-latency stage nodes.
pub(crate) const OP_ADVANCE_CLOCK: u8 = 0;
/// Opcode: a single pipeline-stage node.
pub(crate) const OP_STAGE_STEP: u8 = 1;
/// Opcode: the FU lock-acquire → compute → release triple.
pub(crate) const OP_LOCKED_STEP: u8 = 2;
/// Opcode: a memory node with its address check folded into the guard.
pub(crate) const OP_MEM_STEP: u8 = 3;
/// Opcode: the writeBack pseudo-node.
pub(crate) const OP_WRITE_BACK: u8 = 4;
/// Number of opcodes (dispatch-table length).
pub(crate) const N_OPCODES: usize = 5;

/// Flag: the entry gate is provably the identity (see module docs).
pub(crate) const FLAG_PRE_GATED: u8 = 1;
/// Flag (`MemStep`): write transaction (vs read).
pub(crate) const FLAG_WRITE: u8 = 2;
/// Flag (`LockedStep`): write registers anchor here (no writeBack follows).
pub(crate) const FLAG_ANCHORS_WRITES: u8 = 4;

/// Lowered latency slot of one op: fixed, or memoized dynamic.
#[derive(Debug, Clone, Copy)]
pub(crate) enum LatSlot {
    /// Template-invariant latency, folded at fuse time.
    Fix(Cycle),
    /// Immediate-dependent latency, served through [`ThreadedProgram::memo`].
    Memo(u32),
}

/// One superinstruction on the tape. Dense and uniform: every handler reads
/// only the fields its opcode defines, so the stream stays branch-predictable.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Op {
    /// Opcode — index into [`Dispatch::TABLE`].
    pub code: u8,
    /// `FLAG_*` bit set.
    pub flags: u8,
    /// Lock-owner ring index of this node.
    pub owner: u32,
    /// Ring index of the next tail node ([`NO_LOCK`] = last): the
    /// structural look-ahead gate.
    pub next: u32,
    /// `AdvanceClock`: `[a, b)` into [`ThreadedProgram::stages`];
    /// `MemStep`: `[a, b)` into the program's interned position pool.
    pub a: u32,
    /// Exclusive end of the `a` range.
    pub b: u32,
    /// Residency latency (`MemStep`: per-transaction latency).
    pub lat: LatSlot,
    /// `MemStep`: words per transaction.
    pub port: u32,
    /// `MemStep`: folded single-range membership check `[base, end)`.
    pub base: Addr,
    /// Exclusive end of the folded membership check.
    pub end: Addr,
    /// `AdvanceClock`: precomputed sum of the fused fixed latencies (the
    /// total clock advance when no ring stalls — reported by
    /// [`FusionStats::fused_cycles`]).
    pub total_lat: Cycle,
}

impl Op {
    /// All-zero template for struct-update construction in the fuser.
    pub(crate) const DEFAULT: Op = Op {
        code: 0,
        flags: 0,
        owner: 0,
        next: NO_LOCK,
        a: 0,
        b: 0,
        lat: LatSlot::Fix(0),
        port: 1,
        base: 0,
        end: 0,
        total_lat: 0,
    };
}

/// One fused stage of an `AdvanceClock` run.
#[derive(Debug, Clone, Copy)]
pub(crate) struct StageEntry {
    /// Lock-owner ring index.
    pub owner: u32,
    /// Next node's ring ([`NO_LOCK`] = last node of the instruction).
    pub next: u32,
    /// Fixed residency latency.
    pub lat: Cycle,
    /// Entry gate provably elided (see module docs).
    pub pre_gated: bool,
}

/// Per-offset tape metadata.
#[derive(Debug, Clone, Copy)]
pub(crate) struct TapeMeta {
    /// `[start, end)` into [`ThreadedProgram::ops`].
    pub ops: (u32, u32),
    /// False: a fusion precondition failed at fuse time (multi-range
    /// memory); the offset permanently takes the node-table path.
    pub fusible: bool,
}

/// What a dynamic-latency memo site evaluates on a miss.
#[derive(Debug, Clone, Copy)]
pub(crate) enum MemoKind {
    /// Stage/FU residency latency of an object.
    Object(crate::ids::ObjId),
    /// Per-transaction memory latency (object, write?).
    MemTxn(crate::ids::ObjId, bool),
}

/// Immediate words a memo entry can key on inline; longer tuples bypass the
/// cache (counted as misses).
const MEMO_IMMS: usize = 6;
/// Direct-mapped ways per memo site (power of two).
const MEMO_WAYS: usize = 32;

/// One cached `(imms → latency)` way.
#[derive(Debug, Clone, Copy)]
struct MemoEntry {
    /// Valid immediate count; `u8::MAX` marks an empty way.
    len: u8,
    imms: [i64; MEMO_IMMS],
    lat: Cycle,
}

/// Direct-mapped memo cache of one `Lat::Dyn` site. Allocated once at fuse
/// time (the compile phase); steady-state lookups touch fixed storage only,
/// preserving the zero-allocation contract. Digest-equal batch lanes share
/// sites safely: equal digests pin equal latency expressions, so equal
/// immediate tuples yield equal latencies in every lane.
#[derive(Debug)]
pub(crate) struct MemoSite {
    kind: MemoKind,
    ways: Box<[MemoEntry; MEMO_WAYS]>,
}

impl MemoSite {
    pub(crate) fn new(kind: MemoKind) -> Self {
        Self {
            kind,
            ways: Box::new([MemoEntry { len: u8::MAX, imms: [0; MEMO_IMMS], lat: 0 }; MEMO_WAYS]),
        }
    }

    /// Evaluate this site's latency expression directly.
    #[inline]
    fn eval(&self, d: &Diagram, imms: &[i64]) -> Cycle {
        match self.kind {
            MemoKind::Object(obj) => d.object_latency_imms(obj, imms),
            MemoKind::MemTxn(mem, write) => d.mem_txn_latency_imms(mem, write, imms),
        }
    }

    /// Memoized latency for the current immediates.
    #[inline]
    fn lookup(&mut self, d: &Diagram, imms: &[i64], stats: &mut DispatchStats) -> Cycle {
        if imms.len() > MEMO_IMMS {
            stats.memo_misses += 1;
            return self.eval(d, imms);
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &v in imms {
            h = (h ^ v as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let e = &mut self.ways[(h as usize) & (MEMO_WAYS - 1)];
        if e.len as usize == imms.len() && e.imms[..imms.len()] == *imms {
            stats.memo_hits += 1;
            return e.lat;
        }
        stats.memo_misses += 1;
        let lat = match self.kind {
            MemoKind::Object(obj) => d.object_latency_imms(obj, imms),
            MemoKind::MemTxn(mem, write) => d.mem_txn_latency_imms(mem, write, imms),
        };
        e.len = imms.len() as u8;
        e.imms[..imms.len()].copy_from_slice(imms);
        e.lat = lat;
        lat
    }
}

/// The threaded-code lowering of one [`super::program::IterProgram`]: one
/// [`TapeMeta`] per offset, a flat op tape, the fused-stage pool, and the
/// dynamic-latency memo sites. Grown in lockstep with the node table by
/// [`super::fuse::fuse_offset`].
#[derive(Debug, Default)]
pub(crate) struct ThreadedProgram {
    /// Per-offset tape ranges.
    pub offsets: Vec<TapeMeta>,
    /// Flat superinstruction tape.
    pub ops: Vec<Op>,
    /// `AdvanceClock` stage-entry pool.
    pub stages: Vec<StageEntry>,
    /// Dynamic-latency memo sites, indexed by [`LatSlot::Memo`].
    pub memo: Vec<MemoSite>,
}

impl ThreadedProgram {
    /// Allocate a memo site and return its latency slot.
    pub(crate) fn memo_slot(&mut self, kind: MemoKind) -> LatSlot {
        let idx = self.memo.len() as u32;
        self.memo.push(MemoSite::new(kind));
        LatSlot::Memo(idx)
    }

    /// Static fusion composition vs a node table of `nodes` entries.
    pub(crate) fn fusion_stats(&self, nodes: usize) -> FusionStats {
        FusionStats {
            offsets: self.offsets.len(),
            fusible_offsets: self.offsets.iter().filter(|m| m.fusible).count(),
            ops: self.ops.len(),
            nodes,
            fused_cycles: self
                .ops
                .iter()
                .filter(|o| o.code == OP_ADVANCE_CLOCK)
                .map(|o| o.total_lat)
                .sum(),
        }
    }
}

// ---------------------------------------------------------------------------
// Statistics
// ---------------------------------------------------------------------------

/// Cumulative threaded-dispatch execution statistics of one evaluator.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DispatchStats {
    /// Instructions executed through the fused tape.
    pub threaded_instrs: u64,
    /// Instructions routed to the node-table walk instead (structural
    /// non-fusible offsets and run-time guard failures).
    pub fallback_instrs: u64,
    /// Superinstruction ops executed on the tape.
    pub fused_ops: u64,
    /// Dynamic-latency memo hits.
    pub memo_hits: u64,
    /// Dynamic-latency memo misses (cold fills and long-tuple bypasses).
    pub memo_misses: u64,
}

impl DispatchStats {
    /// Flush the delta since `flushed` into the process-global counters and
    /// advance the watermark (keeps `self` cumulative for introspection).
    pub(crate) fn flush(&self, flushed: &mut DispatchStats) {
        counters::note_dispatch(
            self.threaded_instrs - flushed.threaded_instrs,
            self.fallback_instrs - flushed.fallback_instrs,
            self.fused_ops - flushed.fused_ops,
            self.memo_hits - flushed.memo_hits,
            self.memo_misses - flushed.memo_misses,
        );
        *flushed = *self;
    }
}

/// Static composition of one evaluator's fused tape vs its node table.
#[derive(Debug, Clone, Copy, Default)]
pub struct FusionStats {
    /// Lowered instruction offsets.
    pub offsets: usize,
    /// Offsets that compiled to a fusible tape.
    pub fusible_offsets: usize,
    /// Superinstruction ops across all fusible tapes.
    pub ops: usize,
    /// Node-table nodes across all offsets (the unfused op count).
    pub nodes: usize,
    /// Fixed stage cycles folded into `AdvanceClock` superinstructions.
    pub fused_cycles: Cycle,
}

impl FusionStats {
    /// Fraction of node-table nodes eliminated by fusion on fusible tapes
    /// (`1 - ops/nodes`; 0 when nothing lowered).
    pub fn fusion_rate(&self) -> f64 {
        if self.nodes == 0 {
            0.0
        } else {
            1.0 - self.ops as f64 / self.nodes as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Frontier abstraction (serial EvalState vs one batch lane)
// ---------------------------------------------------------------------------

/// The mutable evaluation frontier a tape executes against — implemented by
/// the serial [`EvalState`] and by one lane's view of the batched SoA state
/// ([`LaneFrontier`]). Methods mirror the exact operations of the
/// node-table walk so the tape stays bit-identical by construction.
pub(crate) trait Frontier {
    /// Earliest `t' >= t` with a free slot on ring `x`.
    fn gate(&self, x: u32, t: Cycle) -> Cycle;
    /// Record an occupant over `[enter, leave)` on ring `x`.
    fn insert(&mut self, x: u32, enter: Cycle, leave: Cycle, horizon: Cycle);
    /// Last-accessor leave time of register `r`.
    fn reg_last(&self, r: u32) -> Cycle;
    /// Record `t` as the last-accessor leave time of register `r`.
    fn set_reg_last(&mut self, r: u32, t: Cycle);
    /// Last-accessor leave time of address `a`.
    fn addr_last(&mut self, a: Addr) -> Cycle;
    /// Record `t` as the last-accessor leave time of address `a`.
    fn set_addr_last(&mut self, a: Addr, t: Cycle);
}

impl Frontier for EvalState {
    #[inline]
    fn gate(&self, x: u32, t: Cycle) -> Cycle {
        self.obj_ring[x as usize].gate(t)
    }

    #[inline]
    fn insert(&mut self, x: u32, enter: Cycle, leave: Cycle, horizon: Cycle) {
        self.obj_ring[x as usize].insert(enter, leave, horizon);
    }

    #[inline]
    fn reg_last(&self, r: u32) -> Cycle {
        self.reg_last[r as usize]
    }

    #[inline]
    fn set_reg_last(&mut self, r: u32, t: Cycle) {
        self.reg_last[r as usize] = t;
    }

    #[inline]
    fn addr_last(&mut self, a: Addr) -> Cycle {
        self.addr_last.get(a)
    }

    #[inline]
    fn set_addr_last(&mut self, a: Addr, t: Cycle) {
        self.addr_last.set(a, t);
    }
}

/// One batch lane's frontier: the SoA ring matrix and laned address plane
/// addressed at a fixed lane index (`ring = obj * n_lanes + lane`), exactly
/// the indexing of `batch::step_lane`'s node-table walk.
pub(crate) struct LaneFrontier<'a> {
    /// SlotRing matrix slice, `[owner_obj * n_lanes + lane]`.
    pub rings: &'a mut [SlotRing],
    /// Shared laned address plane.
    pub plane: &'a mut LanePlane,
    /// This lane's register scoreboard.
    pub reg_last: &'a mut [Cycle],
    /// Lane index.
    pub li: usize,
    /// Lanes per ring row.
    pub n_lanes: usize,
}

impl Frontier for LaneFrontier<'_> {
    #[inline]
    fn gate(&self, x: u32, t: Cycle) -> Cycle {
        self.rings[x as usize * self.n_lanes + self.li].gate(t)
    }

    #[inline]
    fn insert(&mut self, x: u32, enter: Cycle, leave: Cycle, horizon: Cycle) {
        self.rings[x as usize * self.n_lanes + self.li].insert(enter, leave, horizon);
    }

    #[inline]
    fn reg_last(&self, r: u32) -> Cycle {
        self.reg_last[r as usize]
    }

    #[inline]
    fn set_reg_last(&mut self, r: u32, t: Cycle) {
        self.reg_last[r as usize] = t;
    }

    #[inline]
    fn addr_last(&mut self, a: Addr) -> Cycle {
        self.plane.get(self.li, a)
    }

    #[inline]
    fn set_addr_last(&mut self, a: Addr, t: Cycle) {
        self.plane.set(self.li, a, t);
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// Per-instruction execution context threaded through the handlers.
pub(crate) struct ThreadCtx<'a, 'v, F: Frontier> {
    /// The mutable frontier.
    pub f: &'a mut F,
    /// The diagram (dynamic-latency miss evaluation).
    pub d: &'a Diagram,
    /// The current instruction's operands.
    pub view: InstrView<'v>,
    /// The program's interned position pool (`MemStep` operand indices).
    pub positions: &'a [u32],
    /// `AdvanceClock` stage-entry pool.
    pub stages: &'a [StageEntry],
    /// Dynamic-latency memo sites.
    pub memo: &'a mut [MemoSite],
    /// Evaluation horizon (ring pruning bound).
    pub horizon: Cycle,
    /// Leave time of the previous node (IFS `t_leave` at tape entry; the
    /// instruction's final leave time at tape exit).
    pub prev_leave: Cycle,
    /// AIDG nodes executed by this tape (the caller folds it into its node
    /// counter).
    pub nodes: u64,
    /// Execution statistics accumulator.
    pub stats: &'a mut DispatchStats,
}

/// Handler signature: one opcode against the context.
pub(crate) type Handler<F> = fn(&mut ThreadCtx<'_, '_, F>, &Op);

/// The computed-goto surface: a frontier type carries its monomorphized
/// fn-pointer table as an associated const (an inner `const` cannot
/// reference the enclosing generics, a default associated const can).
pub(crate) trait Dispatch: Frontier + Sized {
    /// Per-opcode handler table, indexed by [`Op::code`].
    const TABLE: [Handler<Self>; N_OPCODES] = [
        op_advance_clock::<Self>,
        op_stage_step::<Self>,
        op_locked_step::<Self>,
        op_mem_step::<Self>,
        op_write_back::<Self>,
    ];
}

impl<F: Frontier> Dispatch for F {}

/// Execute one instruction's tape: a single indirect call per
/// superinstruction, no kind matching.
#[inline]
pub(crate) fn execute<F: Dispatch>(ctx: &mut ThreadCtx<'_, '_, F>, ops: &[Op]) {
    ctx.stats.fused_ops += ops.len() as u64;
    for op in ops {
        F::TABLE[op.code as usize](ctx, op);
    }
}

/// Pre-mutation fusion guard: field lengths plus every `MemStep`'s folded
/// single-range membership check. For a fusible tape this is exactly
/// [`super::program::IterProgram::partition_holds`] (fusible tapes contain
/// single-range memory nodes only), so a guard failure implies the
/// node-table fallback must run with the partition known broken.
#[inline]
pub(crate) fn guard_holds(
    ops: &[Op],
    positions: &[u32],
    meta: &OffsetMeta,
    view: &InstrView<'_>,
) -> bool {
    if view.read_addrs.len() != meta.ra_len as usize
        || view.write_addrs.len() != meta.wa_len as usize
    {
        return false;
    }
    for op in ops {
        if op.code == OP_MEM_STEP {
            let addrs =
                if op.flags & FLAG_WRITE != 0 { view.write_addrs } else { view.read_addrs };
            for &p in &positions[op.a as usize..op.b as usize] {
                let a = addrs[p as usize];
                if a < op.base || a >= op.end {
                    return false;
                }
            }
        }
    }
    true
}

/// Entry time of an op: the elided or explicit ring gate.
#[inline]
fn enter<F: Frontier>(ctx: &ThreadCtx<'_, '_, F>, op: &Op) -> Cycle {
    if op.flags & FLAG_PRE_GATED != 0 {
        ctx.prev_leave
    } else {
        ctx.f.gate(op.owner, ctx.prev_leave)
    }
}

/// Shared op epilogue: structural look-ahead gate, ring insert, node count.
#[inline]
fn close<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, op: &Op, t_enter: Cycle, t_stop: Cycle) -> Cycle {
    let t_leave = if op.next != NO_LOCK { ctx.f.gate(op.next, t_stop) } else { t_stop };
    ctx.f.insert(op.owner, t_enter, t_leave, ctx.horizon);
    ctx.nodes += 1;
    ctx.prev_leave = t_leave;
    t_leave
}

/// Resolve an op's latency slot against the current immediates.
#[inline]
fn lat_of<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, slot: LatSlot) -> Cycle {
    match slot {
        LatSlot::Fix(c) => c,
        LatSlot::Memo(i) => {
            let ThreadCtx { memo, stats, d, view, .. } = ctx;
            memo[i as usize].lookup(d, view.imms, stats)
        }
    }
}

/// `AdvanceClock`: replay a fused run of fixed-latency stage nodes.
fn op_advance_clock<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, op: &Op) {
    let horizon = ctx.horizon;
    let mut prev = ctx.prev_leave;
    for e in &ctx.stages[op.a as usize..op.b as usize] {
        let t_enter = if e.pre_gated { prev } else { ctx.f.gate(e.owner, prev) };
        let t_stop = t_enter + e.lat;
        let t_leave = if e.next != NO_LOCK { ctx.f.gate(e.next, t_stop) } else { t_stop };
        ctx.f.insert(e.owner, t_enter, t_leave, horizon);
        prev = t_leave;
    }
    ctx.nodes += (op.b - op.a) as u64;
    ctx.prev_leave = prev;
}

/// `StageStep`: one pipeline-stage node (possibly dynamic latency).
fn op_stage_step<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, op: &Op) {
    let t_enter = enter(ctx, op);
    let lat = lat_of(ctx, op.lat);
    close(ctx, op, t_enter, t_enter + lat);
}

/// `LockedStep`: the FU acquire → compute → release triple.
fn op_locked_step<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, op: &Op) {
    let view = ctx.view;
    let t_enter = enter(ctx, op);
    let mut deps: Cycle = 0;
    for r in view.read_regs.iter().chain(view.write_regs.iter()) {
        deps = deps.max(ctx.f.reg_last(r.0));
    }
    let lat = lat_of(ctx, op.lat);
    let t_leave = close(ctx, op, t_enter, t_enter.max(deps) + lat);
    for r in view.read_regs {
        ctx.f.set_reg_last(r.0, t_leave);
    }
    if op.flags & FLAG_ANCHORS_WRITES != 0 {
        for r in view.write_regs {
            ctx.f.set_reg_last(r.0, t_leave);
        }
    }
}

/// `MemStep`: one memory node over its interned operand positions (the
/// membership check already ran in [`guard_holds`]).
fn op_mem_step<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, op: &Op) {
    let view = ctx.view;
    let addrs = if op.flags & FLAG_WRITE != 0 { view.write_addrs } else { view.read_addrs };
    let (a, b) = (op.a as usize, op.b as usize);
    let t_enter = enter(ctx, op);
    let mut deps: Cycle = 0;
    for &p in &ctx.positions[a..b] {
        deps = deps.max(ctx.f.addr_last(addrs[p as usize]));
    }
    let per = lat_of(ctx, op.lat);
    let lat = per * ((b - a) as u64).div_ceil(op.port as u64).max(1);
    let t_leave = close(ctx, op, t_enter, t_enter.max(deps) + lat);
    for &p in &ctx.positions[a..b] {
        ctx.f.set_addr_last(addrs[p as usize], t_leave);
    }
}

/// `WriteBackStep`: the zero-latency writeBack pseudo-node (unbounded
/// lock); write registers anchor here.
fn op_write_back<F: Frontier>(ctx: &mut ThreadCtx<'_, '_, F>, op: &Op) {
    let view = ctx.view;
    let t_enter = enter(ctx, op);
    let t_leave = close(ctx, op, t_enter, t_enter);
    for r in view.write_regs {
        ctx.f.set_reg_last(r.0, t_leave);
    }
}
