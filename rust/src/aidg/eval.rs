//! Streaming AIDG construction + Algorithm-1 evaluation.
//!
//! Construction (§6.1) and evaluation (§6.2) are fused: nodes are created in
//! instruction order, and because every edge type (forward, structural,
//! data, buffer fill) points from an earlier-created node to a later one,
//! creation order *is* a topological order. Each node's `t_enter`/`t_leave`
//! can therefore be computed the moment it is created, after which only the
//! frontier state ([`super::state::EvalState`]) is needed — the node itself
//! is never stored. This gives O(|N|) evaluation (paper §6.2) with memory
//! bounded by the frontier.
//!
//! Node sequence per instruction (the object order `o⃗(i)`):
//!
//! ```text
//! [merged fetch: instrMemory+IMAU] → IFS → stages… → FU
//!        → read-memory nodes… → writeBack (if reads memory) → write-memory nodes…
//! ```
//!
//! Timing rules per Algorithm 1:
//! - merged fetch node: structural chain on the instruction-memory port;
//!   `p = port_width` forward slots allocated against `b_forward`.
//! - IFS node: `t_enter` = earliest slot `>= fetch_leave` with issue-buffer
//!   entry capacity (`b_enter`); `t_leave` stalls until the next object in
//!   the route frees (lines 32–35 — the n₆₃ worked example).
//! - FU node: data dependencies over registers; memory nodes: data
//!   dependencies over addresses; `t_stop = max(t_enter, deps) + latency`.
//! - every node's `t_leave = max(t_stop, structural-free time of the next
//!   object in the route)` — an instruction occupies a module until the
//!   next module accepts it.
//!
//! The per-instruction work is split between a one-time *lowering* pass
//! (first iteration of each offset: route resolution + template-invariant
//! facts compiled into an `IterProgram`) and a tight
//! steady-state interpreter over the lowered node table — see the module
//! docs of `super::program` for the design and its safety net. Iterations
//! are emitted into a reused [`EmitBuf`] arena, so a warmed-up evaluation
//! performs zero heap allocations per iteration.

use crate::acadl::Diagram;
use crate::ids::Cycle;
use crate::isa::{EmitBuf, InstrView, LoopKernel};
use crate::Result;

use super::fuse;
use super::ops::{
    self, default_dispatch, DispatchMode, DispatchStats, FusionStats, TapeMeta, ThreadCtx,
    ThreadedProgram,
};
use super::program::{IterProgram, Lat, NodeKind, OffsetMeta, NO_LOCK};
use super::state::EvalState;

/// Debug tracing flags, resolved once (env lookups are process-global locks
/// — far too slow for the per-node hot path).
static TRACE: once_cell::sync::Lazy<bool> =
    once_cell::sync::Lazy::new(|| std::env::var_os("ACADL_TRACE").is_some());
static TRACE_NODES: once_cell::sync::Lazy<bool> =
    once_cell::sync::Lazy::new(|| std::env::var_os("ACADL_TRACE_NODES").is_some());

/// Per-iteration timing record: `Δt_iteration = max_leave - min_enter`
/// (eq. 4); overlap/stride derive from consecutive records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IterStat {
    /// Earliest cycle any node of the iteration entered.
    pub min_enter: Cycle,
    /// Latest cycle any node of the iteration left.
    pub max_leave: Cycle,
}

impl IterStat {
    #[inline]
    /// `Δt_iteration = max_leave - min_enter` (eq. 4).
    pub fn span(&self) -> Cycle {
        self.max_leave - self.min_enter
    }
}

/// Streaming evaluator over one diagram + one loop kernel's instruction
/// stream.
///
/// An evaluator is bound to one kernel *template*: the iteration program
/// (and the route per offset) is lowered from the first iteration that
/// reaches each offset and reused for every later iteration — the §6.3
/// contract that consecutive iterations differ only in addresses. Chunked
/// [`Evaluator::run`] calls over the same kernel continue the same program;
/// the `verify-routes` cargo feature re-derives and checks routes on every
/// instruction for debugging.
pub struct Evaluator<'d> {
    d: &'d Diagram,
    /// Carried evaluation state (exposed for the memory-footprint metric).
    pub st: EvalState,
    /// (min_enter, max_leave) per evaluated iteration, in order.
    pub iter_stats: Vec<IterStat>,
    /// Reused emission arena (cleared, never shrunk, per iteration).
    emit: EmitBuf,
    /// Lowered iteration program, grown offset-by-offset on the first
    /// iteration (§6.3: the template is iteration-invariant).
    program: IterProgram,
    /// Fused superinstruction tape, grown in lockstep with `program`.
    threaded: ThreadedProgram,
    /// How lowered offsets are interpreted (fixed at construction).
    dispatch: DispatchMode,
    /// Cumulative threaded-dispatch statistics.
    stats: DispatchStats,
    /// Watermark of `stats` already flushed to the process counters.
    flushed: DispatchStats,
    /// Route per iteration offset, retained only for the `verify-routes`
    /// check (the lowered program otherwise subsumes the route).
    #[cfg(feature = "verify-routes")]
    routes: Vec<std::sync::Arc<crate::acadl::Route>>,
    // fetch constants
    ifs_lock: u32,
    p: u64,
    imem_read_lat: Cycle,
    ifs_lat: Cycle,
    issue_buf: u32,
    // current-iteration accumulation
    cur_min_enter: Cycle,
    cur_max_leave: Cycle,
    /// Wall time spent inside [`Evaluator::run`] (ns; 0 when tracing is
    /// disabled). Accumulated with raw clock reads — no spans on this path,
    /// so the steady state stays allocation-free and the ring unflooded.
    pub(crate) obs_run_ns: u64,
    /// Portion of `obs_run_ns` spent lowering the iteration program.
    pub(crate) obs_compile_ns: u64,
}

impl<'d> Evaluator<'d> {
    /// A fresh evaluator over `d` with empty carried state, using the
    /// process-default dispatch mode.
    pub fn new(d: &'d Diagram) -> Self {
        Self::new_with_dispatch(d, default_dispatch())
    }

    /// A fresh evaluator with an explicit dispatch mode (tests and benches
    /// compare modes without touching the process-global default).
    /// `ACADL_TRACE_NODES` forces the node-table walk — per-node tracing
    /// only exists there.
    pub fn new_with_dispatch(d: &'d Diagram, dispatch: DispatchMode) -> Self {
        let dispatch = if *TRACE_NODES { DispatchMode::NodeTable } else { dispatch };
        let f = d.fetch_config();
        let st = EvalState::new(d.num_objects(), d.num_regs(), |i| {
            d.lock(crate::ids::ObjId(i as u32)).capacity
        });
        Self {
            d,
            st,
            iter_stats: Vec::new(),
            emit: EmitBuf::new(),
            program: IterProgram::default(),
            threaded: ThreadedProgram::default(),
            dispatch,
            stats: DispatchStats::default(),
            flushed: DispatchStats::default(),
            #[cfg(feature = "verify-routes")]
            routes: Vec::new(),
            ifs_lock: d.lock(f.fetch_stage).owner.idx() as u32,
            p: f.port_width as u64,
            imem_read_lat: f.read_latency,
            ifs_lat: f.ifs_latency,
            issue_buf: f.issue_buffer_size,
            cur_min_enter: Cycle::MAX,
            cur_max_leave: 0,
            obs_run_ns: 0,
            obs_compile_ns: 0,
        }
    }

    /// Evaluate iterations `range` of `kernel`, appending to the carried
    /// state and per-iteration stats.
    pub fn run(&mut self, kernel: &LoopKernel, range: std::ops::Range<u64>) -> Result<()> {
        // phase timing by raw clock reads (no span, no ring event): chunked
        // runs would flood the ring, and the steady-state path must stay
        // allocation-free. 0 doubles as the "tracing off" sentinel.
        let t_run = if crate::obs::enabled() { crate::obs::now_ns() } else { 0 };
        self.iter_stats.reserve((range.end.saturating_sub(range.start)) as usize);
        for it in range {
            self.emit.clear();
            kernel.emit_into(it, &mut self.emit);
            self.cur_min_enter = Cycle::MAX;
            self.cur_max_leave = 0;
            // take() the arena to appease the borrow checker; instructions
            // are processed one at a time (the swap is allocation-free).
            let emit = std::mem::take(&mut self.emit);
            let mut res = Ok(());
            for j in 0..emit.len() {
                res = self.step(j, &emit.view(j));
                if res.is_err() {
                    break;
                }
            }
            self.emit = emit;
            res?;
            self.iter_stats.push(IterStat {
                min_enter: self.cur_min_enter,
                max_leave: self.cur_max_leave,
            });
            self.st.note_peak(self.iter_stats.len() * std::mem::size_of::<IterStat>());
        }
        if t_run != 0 {
            self.obs_run_ns += crate::obs::now_ns().saturating_sub(t_run);
        }
        self.stats.flush(&mut self.flushed);
        Ok(())
    }

    /// Number of lowered instruction offsets (test introspection).
    #[cfg(test)]
    pub(crate) fn program_len(&self) -> usize {
        self.program.len()
    }

    /// Cumulative threaded-dispatch execution statistics.
    pub fn dispatch_stats(&self) -> DispatchStats {
        self.stats
    }

    /// Static composition of the fused tape vs the node table.
    pub fn fusion_stats(&self) -> FusionStats {
        self.threaded.fusion_stats(self.program.nodes.len())
    }

    /// The dispatch mode this evaluator interprets with.
    pub fn dispatch_mode(&self) -> DispatchMode {
        self.dispatch
    }

    /// `verify-routes` builds: re-derive the instruction's route and assert
    /// it matches the lowered template.
    #[cfg(feature = "verify-routes")]
    fn verify_route(&self, offset: usize, view: &InstrView<'_>) -> Result<()> {
        let r = self.d.route(&view.to_instruction())?;
        assert_eq!(
            *self.routes[offset], *r,
            "route template changed at offset {offset}"
        );
        Ok(())
    }

    /// Default builds: route invariance is a §6.3 precondition, not
    /// re-checked per instruction.
    #[cfg(not(feature = "verify-routes"))]
    #[inline]
    fn verify_route(&self, _offset: usize, _view: &InstrView<'_>) -> Result<()> {
        Ok(())
    }

    /// Whole-graph end-to-end latency so far (eq. 1).
    pub fn dt_aidg(&self) -> Cycle {
        let min = self.iter_stats.first().map_or(0, |s| s.min_enter);
        let max = self.iter_stats.iter().map(|s| s.max_leave).max().unwrap_or(0);
        max - min
    }

    /// Fetch-path handling: merged instruction-memory node (port_width
    /// instructions per transaction, Algorithm 1 lines 36–42). Returns this
    /// instruction's fetch-leave time.
    fn fetch_leave(&mut self) -> Cycle {
        let within = (self.st.instr_index % self.p) as usize;
        if within == 0 {
            // New merged fetch node: structural chain on the memory port,
            // paced by the previous group's issue-buffer entry (the paper's
            // "fetch as long as the issue buffer is not full" backpressure —
            // in-flight instructions stay bounded by the buffer size).
            let t_enter = self.st.next_fetch_start.max(self.st.last_ifs_enter);
            if t_enter < self.cur_min_enter {
                self.cur_min_enter = t_enter;
            }
            self.st.horizon = t_enter;
            let t_stop = t_enter + self.imem_read_lat;
            self.st.group_slots.clear();
            for _ in 0..self.p {
                let slot = self.st.b_forward.alloc(t_stop, self.issue_buf);
                self.st.group_slots.push(slot);
            }
            self.st.next_fetch_start = t_stop;
            self.st.b_forward.prune_below(t_enter);
            self.st.nodes += 1;
        }
        self.st.instr_index += 1;
        self.st.group_slots[within]
    }

    /// Process one instruction: lower its offset on first encounter, then
    /// interpret the lowered node table per Algorithm 1 and update the
    /// frontier. `offset` is the instruction's position within its
    /// iteration.
    fn step(&mut self, offset: usize, view: &InstrView<'_>) -> Result<()> {
        if offset >= self.program.len() {
            debug_assert_eq!(offset, self.program.len(), "offsets must arrive in order");
            let t_lower = if crate::obs::enabled() { crate::obs::now_ns() } else { 0 };
            let instr = view.to_instruction();
            let route = self.d.route(&instr)?;
            self.program.lower_offset(self.d, &route, view);
            fuse::fuse_offset(&self.program, offset, self.ifs_lock, &mut self.threaded);
            #[cfg(feature = "verify-routes")]
            self.routes.push(route);
            if t_lower != 0 {
                self.obs_compile_ns += crate::obs::now_ns().saturating_sub(t_lower);
            }
        } else {
            // re-derive and compare the route on every later instruction
            // (the just-lowered offset would only compare itself)
            self.verify_route(offset, view)?;
        }
        let fetch_leave = self.fetch_leave();
        let meta = self.program.offsets[offset];

        // --- IFS node (in-forward from fetch + buffer fill edge) ----------
        // entry requires a free issue-buffer slot (interval occupancy on the
        // IFS lock, capacity = issue_buffer_size) AND a per-cycle entry slot
        // (Algorithm 1's b_enter); iterate the two monotone constraints to a
        // common fixpoint
        let mut t_enter = fetch_leave;
        loop {
            let tg = self.st.obj_ring[self.ifs_lock as usize].gate(t_enter);
            let tb = self.st.b_enter.probe(tg, self.issue_buf);
            if tb == t_enter {
                break;
            }
            t_enter = tb;
        }
        self.st.b_enter.commit(t_enter);
        if t_enter < self.cur_min_enter {
            self.cur_min_enter = t_enter;
        }
        self.st.last_ifs_enter = t_enter;
        self.st.b_enter.prune_below(fetch_leave.saturating_sub(1));
        let t_stop = t_enter + self.ifs_lat;
        self.st.nodes += 1;

        // t_leave of the IFS node: stall until the first tail object frees
        // (worked example n63: the store waits in the IFS for the store
        // unit).
        let horizon = self.st.horizon;
        let t_leave = self.st.obj_ring[meta.first_tail_lock as usize].gate(t_stop);
        self.st.obj_ring[self.ifs_lock as usize].insert(t_enter, t_leave, horizon);

        // --- tail nodes: threaded tape or node-table walk -----------------
        let tmeta = self.threaded.offsets[offset];
        let prev_leave = if self.dispatch == DispatchMode::Threaded && tmeta.fusible {
            if ops::guard_holds(
                &self.threaded.ops[tmeta.ops.0 as usize..tmeta.ops.1 as usize],
                &self.program.positions,
                &meta,
                view,
            ) {
                self.stats.threaded_instrs += 1;
                self.tape_tail(tmeta, view, horizon, t_leave)
            } else {
                // Run-time fallback. For a fusible tape the guard *is* the
                // partition check (single-range memberships only), so the
                // partition is known broken: walk the node table with full
                // `memory_of` scans, no recheck.
                self.stats.fallback_instrs += 1;
                self.table_tail(&meta, view, horizon, t_leave, false)
            }
        } else {
            if self.dispatch == DispatchMode::Threaded {
                // structural fallback: the offset never compiled to a tape
                self.stats.fallback_instrs += 1;
            }
            // The fast memory path is valid while the iteration's addresses
            // still obey the lowered address→memory partition; otherwise the
            // memory nodes of this instruction fall back to full scans.
            let fast_mem = self.program.partition_holds(self.d, &meta, view);
            self.table_tail(&meta, view, horizon, t_leave, fast_mem)
        };

        if prev_leave > self.cur_max_leave {
            self.cur_max_leave = prev_leave;
        }
        if *TRACE {
            eprintln!(
                "AIDG i{} op={} leave={}",
                self.st.instr_index - 1,
                self.d.op_name(view.op),
                prev_leave
            );
        }
        Ok(())
    }

    /// Interpret one instruction's tail through the fused superinstruction
    /// tape (the threaded path; the folded address guard already passed).
    fn tape_tail(
        &mut self,
        tmeta: TapeMeta,
        view: &InstrView<'_>,
        horizon: Cycle,
        prev_leave: Cycle,
    ) -> Cycle {
        let ThreadedProgram { ops, stages, memo, .. } = &mut self.threaded;
        let mut ctx = ThreadCtx {
            f: &mut self.st,
            d: self.d,
            view: *view,
            positions: &self.program.positions,
            stages,
            memo,
            horizon,
            prev_leave,
            nodes: 0,
            stats: &mut self.stats,
        };
        ops::execute(&mut ctx, &ops[tmeta.ops.0 as usize..tmeta.ops.1 as usize]);
        let (nodes, prev_leave) = (ctx.nodes, ctx.prev_leave);
        self.st.nodes += nodes;
        prev_leave
    }

    /// Interpret one instruction's tail through the node-table walk (the
    /// `NodeTable` mode and the threaded path's fallback target).
    ///
    /// NOTE: this loop and the tape handlers in `super::ops` implement the
    /// same Algorithm-1 semantics; any behavioral edit here must be
    /// mirrored there (and in `batch::step_lane`) — the differential suites
    /// pin all of them together.
    fn table_tail(
        &mut self,
        meta: &OffsetMeta,
        view: &InstrView<'_>,
        horizon: Cycle,
        mut prev_leave: Cycle,
        fast_mem: bool,
    ) -> Cycle {
        let mut t_enter;
        let mut t_stop;
        let mut t_leave;
        for ni in meta.nodes.0..meta.nodes.1 {
            let node = self.program.nodes[ni as usize];
            t_enter = self.st.obj_ring[node.owner as usize].gate(prev_leave);

            // data dependencies + latency per node kind
            let mut deps: Cycle = 0;
            let lat: Cycle = match node.kind {
                NodeKind::Stage { lat } => lat.eval(self.d, view.imms),
                NodeKind::Fu { lat, .. } => {
                    for r in view.read_regs.iter().chain(view.write_regs.iter()) {
                        deps = deps.max(self.st.reg_last[r.0 as usize]);
                    }
                    lat.eval(self.d, view.imms)
                }
                NodeKind::Mem { write, per_txn, port, pos, .. } => {
                    let addrs = if write { view.write_addrs } else { view.read_addrs };
                    let n = if fast_mem {
                        for &p in self.program.positions_of(pos) {
                            deps = deps.max(self.st.addr_last.get(addrs[p as usize]));
                        }
                        (pos.1 - pos.0) as usize
                    } else {
                        let mut n = 0usize;
                        for &a in addrs {
                            if self.d.memory_of(a) == Some(node.obj) {
                                n += 1;
                                deps = deps.max(self.st.addr_last.get(a));
                            }
                        }
                        n
                    };
                    let per = match per_txn {
                        Lat::Fix(c) => c,
                        Lat::Dyn(m) => self.d.mem_txn_latency_imms(m, write, view.imms),
                    };
                    per * (n as u64).div_ceil(port as u64).max(1)
                }
                NodeKind::WriteBack => 0,
            };

            t_stop = t_enter.max(deps) + lat;
            t_leave = if node.next != NO_LOCK {
                self.st.obj_ring[node.next as usize].gate(t_stop)
            } else {
                t_stop
            };
            if *TRACE_NODES {
                eprintln!(
                    "AIDG i{} node {} enter={} deps={} stop={} leave={}",
                    self.st.instr_index - 1,
                    self.d.object(node.obj).name,
                    t_enter,
                    deps,
                    t_stop,
                    t_leave
                );
            }
            self.st.obj_ring[node.owner as usize].insert(t_enter, t_leave, horizon);
            self.st.nodes += 1;

            // frontier updates (last accessor maps)
            match node.kind {
                NodeKind::Fu { anchors_writes, .. } => {
                    // read registers anchor here; write registers anchor here
                    // too unless a writeBack node follows (§6.1)
                    for r in view.read_regs {
                        self.st.reg_last[r.0 as usize] = t_leave;
                    }
                    if anchors_writes {
                        for r in view.write_regs {
                            self.st.reg_last[r.0 as usize] = t_leave;
                        }
                    }
                }
                NodeKind::Mem { write, pos, .. } => {
                    let addrs = if write { view.write_addrs } else { view.read_addrs };
                    if fast_mem {
                        for &p in self.program.positions_of(pos) {
                            self.st.addr_last.set(addrs[p as usize], t_leave);
                        }
                    } else {
                        for &a in addrs {
                            if self.d.memory_of(a) == Some(node.obj) {
                                self.st.addr_last.set(a, t_leave);
                            }
                        }
                    }
                }
                NodeKind::WriteBack => {
                    for r in view.write_regs {
                        self.st.reg_last[r.0 as usize] = t_leave;
                    }
                }
                NodeKind::Stage { .. } => {}
            }
            prev_leave = t_leave;
        }
        prev_leave
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl::Latency;
    use crate::ids::RegId;
    use crate::isa::Instruction;

    /// 1-FU scalar machine: fetch(p=2) → es{alu} with one RF and one memory.
    fn machine() -> (Diagram, TestOps) {
        let mut d = Diagram::new("m");
        let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
        let es = d.add_execute_stage("es");
        let (rf, regs) = d.add_regfile("rf", "r", 4);
        let mem = d.add_memory("dmem", 4, 4, 1, 1, 0, 4096);
        let load = d.add_fu(es, "lsu", Latency::Fixed(1), &["load", "store"]);
        let alu = d.add_fu(es, "alu", Latency::Fixed(1), &["mac"]);
        d.forward(ifs, es);
        d.fu_writes(load, rf);
        d.fu_reads(load, rf);
        d.fu_reads(alu, rf);
        d.fu_writes(alu, rf);
        d.mem_reads(load, mem);
        d.mem_writes(load, mem);
        let ops = TestOps { load: d.op("load"), mac: d.op("mac"), store: d.op("store"), regs };
        d.finalize().unwrap();
        (d, ops)
    }

    struct TestOps {
        load: crate::ids::OpId,
        mac: crate::ids::OpId,
        store: crate::ids::OpId,
        regs: Vec<RegId>,
    }

    fn lk(ops: &TestOps) -> LoopKernel {
        let (load, mac, store) = (ops.load, ops.mac, ops.store);
        let (r0, r1, r2) = (ops.regs[0], ops.regs[1], ops.regs[2]);
        LoopKernel::new(
            "t",
            16,
            4,
            Box::new(move |it, buf| {
                buf.push(Instruction::new(load).writes(&[r0]).read_mem(&[it]));
                buf.push(Instruction::new(load).writes(&[r1]).read_mem(&[256 + it]));
                buf.push(Instruction::new(mac).reads(&[r0, r1]).writes(&[r2]));
                buf.push(Instruction::new(store).reads(&[r2]).write_mem(&[512 + it]));
            }),
        )
    }

    #[test]
    fn evaluator_monotone_iterations() {
        let (d, ops) = machine();
        let kernel = lk(&ops);
        let mut ev = Evaluator::new(&d);
        ev.run(&kernel, 0..16).unwrap();
        assert_eq!(ev.iter_stats.len(), 16);
        // leave times strictly increase: RAW over r2 + store serialization
        for w in ev.iter_stats.windows(2) {
            assert!(w[1].max_leave > w[0].max_leave);
            assert!(w[1].min_enter >= w[0].min_enter);
        }
        assert!(ev.dt_aidg() > 0);
        assert!(ev.st.nodes > 16 * 4);
    }

    #[test]
    fn spans_stabilize() {
        let (d, ops) = machine();
        let kernel = lk(&ops);
        let mut ev = Evaluator::new(&d);
        ev.run(&kernel, 0..16).unwrap();
        // after warmup the per-iteration stride must become constant (no
        // oscillation in this simple serializing kernel)
        let strides: Vec<u64> = ev
            .iter_stats
            .windows(2)
            .map(|w| w[1].max_leave - w[0].max_leave)
            .collect();
        let tail = &strides[strides.len() - 4..];
        assert!(tail.iter().all(|&s| s == tail[0]), "strides: {strides:?}");
    }

    #[test]
    fn chunked_equals_whole() {
        // appending chunks must be bit-identical to one big run
        let (d, ops) = machine();
        let kernel = lk(&ops);
        let mut whole = Evaluator::new(&d);
        whole.run(&kernel, 0..16).unwrap();
        let mut chunked = Evaluator::new(&d);
        chunked.run(&kernel, 0..4).unwrap();
        chunked.run(&kernel, 4..10).unwrap();
        chunked.run(&kernel, 10..16).unwrap();
        assert_eq!(whole.iter_stats, chunked.iter_stats);
        assert_eq!(whole.dt_aidg(), chunked.dt_aidg());
    }

    #[test]
    fn data_dependency_stalls() {
        // mac depends on both loads; with read latency 4 the mac cannot
        // finish before the second load's writeback
        let (d, ops) = machine();
        let kernel = lk(&ops);
        let mut ev = Evaluator::new(&d);
        ev.run(&kernel, 0..1).unwrap();
        // lower bound: fetch(1) + ifs(1) + lsu(1) + mem(4) for each load
        // serialized on the single LSU; mac after writeback; store after mac
        assert!(ev.iter_stats[0].max_leave >= 12);
    }

    #[test]
    fn memory_concurrency_relaxes_serialization() {
        // same machine but dual-ported memory: the two loads' transactions
        // overlap, shortening the first iteration
        let build = |ports: u32| {
            let mut d = Diagram::new("m");
            let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
            let es0 = d.add_execute_stage("es0");
            let es1 = d.add_execute_stage("es1");
            let (rf, regs) = d.add_regfile("rf", "r", 4);
            let mem = d.add_memory("dmem", 4, 4, 1, ports, 0, 4096);
            let l0 = d.add_fu(es0, "lsu0", Latency::Fixed(1), &["load"]);
            let l1 = d.add_fu(es1, "lsu1", Latency::Fixed(1), &["load2"]);
            d.forward(ifs, es0);
            d.forward(ifs, es1);
            d.fu_writes(l0, rf);
            d.fu_writes(l1, rf);
            d.mem_reads(l0, mem);
            d.mem_reads(l1, mem);
            let load = d.op("load");
            let load2 = d.op("load2");
            d.finalize().unwrap();
            let (r0, r1) = (regs[0], regs[1]);
            let kernel = LoopKernel::new(
                "t",
                8,
                2,
                Box::new(move |it, buf| {
                    buf.push(Instruction::new(load).writes(&[r0]).read_mem(&[it]));
                    buf.push(Instruction::new(load2).writes(&[r1]).read_mem(&[256 + it]));
                }),
            );
            let mut ev = Evaluator::new(&d);
            ev.run(&kernel, 0..8).unwrap();
            ev.dt_aidg()
        };
        let single = build(1);
        let dual = build(2);
        assert!(dual < single, "dual {dual} should beat single {single}");
    }

    #[test]
    fn partition_fallback_matches_full_scan() {
        // a template-violating kernel whose addresses migrate between two
        // memories across iterations: the partition check must detect it
        // and fall back to the full memory_of scan (deps/updates land on
        // the right scoreboard entries either way)
        let mut d = Diagram::new("m");
        let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
        let es = d.add_execute_stage("es");
        let (rf, regs) = d.add_regfile("rf", "r", 2);
        let m0 = d.add_memory("m0", 2, 2, 1, 1, 0, 1024);
        let m1 = d.add_memory("m1", 7, 7, 1, 1, 4096, 1024);
        let lsu = d.add_fu(es, "lsu", Latency::Fixed(1), &["load"]);
        d.forward(ifs, es);
        d.fu_writes(lsu, rf);
        d.mem_reads(lsu, m0);
        d.mem_reads(lsu, m1);
        let load = d.op("load");
        d.finalize().unwrap();
        let r0 = regs[0];
        // iteration 0: [m0, m1]; iteration 1: both addresses in m1 — the
        // per-mem counts change while the route (mem set) stays the same
        let kernel = LoopKernel::new(
            "t",
            2,
            1,
            Box::new(move |it, buf| {
                let a0 = if it == 0 { 0 } else { 4096 + 100 };
                buf.push(Instruction::new(load).writes(&[r0]).read_mem(&[a0, 4096 + it]));
            }),
        );
        let mut ev = Evaluator::new(&d);
        ev.run(&kernel, 0..2).unwrap();
        // iteration 1 pays two m1 transactions (2 addrs / port 1 × lat 7)
        // on the m1 node and a single minimum transaction on the m0 node,
        // exactly like the pre-program evaluator's full scan
        assert_eq!(ev.iter_stats.len(), 2);
        assert!(ev.iter_stats[1].span() >= 14, "stats: {:?}", ev.iter_stats);
        // and the fallback is bit-identical to the reference evaluator
        let mut reference = crate::aidg::reference::RefEvaluator::new(&d);
        reference.run(&kernel, 0..2).unwrap();
        assert_eq!(ev.iter_stats, reference.iter_stats);
        assert_eq!(ev.st.nodes, reference.nodes);
    }
}
