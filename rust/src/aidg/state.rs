//! Carried evaluation state (the AIDG "frontier").
//!
//! Dependencies in an AIDG only ever point backwards to the *last* user of a
//! resource: the last structure user per object (§6.1), the last accessor
//! per register and memory address, the previous InstructionFetchStage node
//! (buffer fill level chain), and the per-time issue-buffer fill counters of
//! Algorithm 1. Holding exactly that state lets us construct and evaluate
//! the graph in a single streaming pass, appending `k_block` iterations at a
//! time (§6.3) with memory bounded by the *live* frontier instead of the
//! whole graph — the whole-graph evaluation of Table 5 is the same sweep run
//! to `k`.
//!
//! Every container here is engineered for the iteration-program hot path
//! (`crate::aidg::program`): steady-state operation touches only
//! preallocated storage — the address scoreboard is a paged dense plane
//! instead of a hashmap, the issue-buffer fill counters are a watermarked
//! ring instead of a hashmap probed one `t += 1` at a time, and concurrent
//! structural rings keep their occupancy deltas in a reused sorted deque
//! instead of a node-allocating `BTreeMap`.

use std::collections::VecDeque;

use crate::ids::{Addr, Cycle, FxHashMap};

/// Occupancy tracker of one structural lock (ACADL object or ExecuteStage
/// lock domain) holding at most `capacity` instructions.
///
/// Occupants may depart **out of order** (two stores parked in the issue
/// buffer waiting on slow data deps leave after later loads that flowed
/// straight through) and may *enter* far in the future relative to earlier
/// claims, so neither a FIFO of leave times nor an order statistic over
/// leave times is correct. The exact model is interval occupancy: each
/// occupant holds the object over `[enter, leave)`; the next claimant ready
/// at `t0` enters at the earliest `t ≥ t0` where fewer than `capacity`
/// intervals are active. Stored as a time-sorted delta sequence (+1 at
/// entry, −1 at leave; equal times merge), pruned below the evaluation
/// horizon (the current fetch time — no future claim can be gated earlier),
/// so the live window stays tiny and its deque capacity is reused across
/// iterations (no steady-state allocation).
#[derive(Debug, Clone)]
enum RingRepr {
    /// capacity == 1: claims serialize, the last leave time is the gate.
    Serial { last: Cycle },
    /// 1 < capacity < ∞: full interval-occupancy delta window.
    Concurrent {
        /// Time-sorted `(time, merged delta)` events at or after the
        /// horizon (zero-delta entries may persist until pruned, exactly
        /// like the entries a delta map would retain).
        events: VecDeque<(Cycle, i64)>,
        /// Active count just below the first retained event.
        base_active: i64,
    },
    /// writeBack: exempt from structural dependencies.
    Unbounded,
}

#[derive(Debug, Clone)]
/// Structural-lock occupancy tracker of one object, with an adaptive
/// representation: serial (capacity 1), bounded-concurrent, or unbounded
/// (the write-back pseudo-object).
pub struct SlotRing {
    repr: RingRepr,
    capacity: u32,
}

impl Default for SlotRing {
    fn default() -> Self {
        Self::new(1)
    }
}

impl SlotRing {
    /// A ring with `capacity` slots (`u32::MAX` = unbounded).
    pub fn new(capacity: u32) -> Self {
        let repr = match capacity {
            u32::MAX => RingRepr::Unbounded,
            1 => RingRepr::Serial { last: 0 },
            _ => RingRepr::Concurrent { events: VecDeque::new(), base_active: 0 },
        };
        Self { repr, capacity }
    }

    /// Earliest `t >= t0` at which a free slot exists.
    #[inline]
    pub fn gate(&self, t0: Cycle) -> Cycle {
        match &self.repr {
            RingRepr::Unbounded => t0,
            RingRepr::Serial { last } => t0.max(*last),
            RingRepr::Concurrent { events, base_active } => {
                let cap = self.capacity as i64;
                let mut active = *base_active;
                let mut i = 0;
                while i < events.len() {
                    let (t, d) = events[i];
                    if t > t0 {
                        break;
                    }
                    active += d;
                    i += 1;
                }
                if active < cap {
                    return t0;
                }
                while i < events.len() {
                    let (t, d) = events[i];
                    active += d;
                    if active < cap {
                        return t;
                    }
                    i += 1;
                }
                unreachable!("occupancy never drains: every interval carries its leave event")
            }
        }
    }

    /// Merge `delta` into the sorted event window at time `t`.
    fn bump(events: &mut VecDeque<(Cycle, i64)>, t: Cycle, delta: i64) {
        let i = events.partition_point(|&(et, _)| et < t);
        if let Some(e) = events.get_mut(i) {
            if e.0 == t {
                e.1 += delta;
                return;
            }
        }
        events.insert(i, (t, delta));
    }

    /// Record an occupant over `[enter, leave)` and prune events below
    /// `horizon` (no future gate query can start earlier).
    #[inline]
    pub fn insert(&mut self, enter: Cycle, leave: Cycle, horizon: Cycle) {
        match &mut self.repr {
            RingRepr::Unbounded => {}
            RingRepr::Serial { last } => {
                if leave > *last {
                    *last = leave;
                }
            }
            RingRepr::Concurrent { events, base_active } => {
                if leave <= enter {
                    return;
                }
                Self::bump(events, enter, 1);
                Self::bump(events, leave, -1);
                while let Some(&(t, d)) = events.front() {
                    if t >= horizon {
                        break;
                    }
                    *base_active += d;
                    events.pop_front();
                }
            }
        }
    }

    /// Tracked bytes of this ring's representation: the retained event
    /// entries at their true width (time + delta per entry).
    pub fn bytes(&self) -> usize {
        match &self.repr {
            RingRepr::Concurrent { events, .. } => {
                events.len() * (std::mem::size_of::<Cycle>() + std::mem::size_of::<i64>())
            }
            _ => 0,
        }
    }
}

/// Per-cycle fill counters for the issue buffer (Algorithm 1's `b_enter` /
/// `b_forward`): at most `cap` instructions may claim the same cycle;
/// `alloc` finds the earliest cycle `>= t0` with a free slot.
///
/// Stored as a power-of-two ring of counters over the live window
/// `[watermark, hi)` — times below the monotonic watermark can no longer be
/// allocated, so their slots are zeroed and reused in place instead of
/// retained in a hashmap until a bulk compaction (the old representation
/// over-reported `bytes()` by up to 4096 stale entries and paid a hash per
/// `t += 1` probe step).
#[derive(Debug, Default)]
pub struct BufferFill {
    /// Power-of-two counter ring; slot of time `t` is `t & (len - 1)`.
    counts: Vec<u32>,
    /// Times strictly below this can no longer be allocated (monotonic
    /// frontier); their slots are zero.
    watermark: Cycle,
    /// Exclusive upper bound of possibly-nonzero slots (`>= watermark`).
    hi: Cycle,
}

impl BufferFill {
    /// Earliest `t >= t0` with fewer than `cap` occupants; increments it.
    #[inline]
    pub fn alloc(&mut self, t0: Cycle, cap: u32) -> Cycle {
        let t = self.probe(t0, cap);
        self.commit(t);
        t
    }

    /// Earliest `t >= t0` with a free slot, without claiming it.
    #[inline]
    pub fn probe(&self, t0: Cycle, cap: u32) -> Cycle {
        let mut t = t0.max(self.watermark);
        if self.counts.is_empty() {
            return t;
        }
        let mask = self.counts.len() - 1;
        while t < self.hi {
            if self.counts[(t as usize) & mask] < cap {
                return t;
            }
            t += 1;
        }
        t
    }

    /// Claim a slot at `t` (previously validated with [`Self::probe`]).
    #[inline]
    pub fn commit(&mut self, t: Cycle) {
        if t < self.watermark {
            // A claim below the frontier can never be observed by `probe`
            // (which snaps to the watermark), so recording it is pointless.
            return;
        }
        self.ensure(t);
        let mask = self.counts.len() - 1;
        self.counts[(t as usize) & mask] += 1;
        if t + 1 > self.hi {
            self.hi = t + 1;
        }
    }

    /// Grow the ring so the window `[watermark, t]` fits. Growth doubles
    /// and re-places the live window, so it is amortized and stops entirely
    /// once the evaluation's fill spread stabilizes.
    fn ensure(&mut self, t: Cycle) {
        let needed = (t - self.watermark + 1) as usize;
        if needed <= self.counts.len() {
            return;
        }
        let new_len = needed.next_power_of_two().max(64);
        let mut next = vec![0u32; new_len];
        if !self.counts.is_empty() {
            let old_mask = self.counts.len() - 1;
            let new_mask = new_len - 1;
            let mut x = self.watermark;
            while x < self.hi {
                next[(x as usize) & new_mask] = self.counts[(x as usize) & old_mask];
                x += 1;
            }
        }
        self.counts = next;
    }

    /// Advance the frontier: allocations below `t` can no longer occur, so
    /// their slots are zeroed for reuse. Called with the oldest time still
    /// reachable (e.g. the previous fetch-group start).
    pub fn prune_below(&mut self, t: Cycle) {
        if t <= self.watermark {
            return;
        }
        if !self.counts.is_empty() {
            let mask = self.counts.len() - 1;
            let stop = t.min(self.hi);
            let mut x = self.watermark;
            while x < stop {
                self.counts[(x as usize) & mask] = 0;
                x += 1;
            }
        }
        self.watermark = t;
        if self.hi < t {
            self.hi = t;
        }
    }

    /// Tracked bytes of the buffer-fill window: the ring's actual counter
    /// storage (exact — stale times are zeroed in place, never retained).
    pub fn bytes(&self) -> usize {
        self.counts.len() * std::mem::size_of::<u32>()
    }
}

/// Page granularity of the address plane: 512 words (4 KiB of cycle stamps)
/// per page balances density on strided kernel address streams against
/// waste on scattered token regions.
const PAGE_SHIFT: u32 = 9;
/// Words per address-plane page.
const PAGE_WORDS: usize = 1 << PAGE_SHIFT;
const PAGE_MASK: usize = PAGE_WORDS - 1;

/// Last-accessor scoreboard over the global address space, stored as a
/// paged dense plane: the address's high bits select a page (resolved
/// through a small page index with a one-entry cache — kernel address
/// streams are strided, so consecutive accesses overwhelmingly hit the same
/// page), the low bits index a flat `[Cycle; 512]` page directly. Absent
/// addresses read 0, exactly like the hashmap it replaces, and pages are
/// only allocated when the footprint grows — steady-state iterations touch
/// existing pages only.
#[derive(Debug, Default)]
pub struct AddrPlane {
    index: FxHashMap<u64, u32>,
    pages: Vec<Box<[Cycle]>>,
    last_key: u64,
    last_slot: u32,
}

impl AddrPlane {
    /// Resolve a page key to its slab slot (one-entry cache in front of
    /// the index), refreshing the cache on an index hit.
    #[inline]
    fn lookup(&mut self, key: u64) -> Option<u32> {
        if !self.pages.is_empty() && self.last_key == key {
            return Some(self.last_slot);
        }
        let s = *self.index.get(&key)?;
        self.last_key = key;
        self.last_slot = s;
        Some(s)
    }

    /// Last-accessor leave time of `a` (0 when never accessed).
    #[inline]
    pub fn get(&mut self, a: Addr) -> Cycle {
        match self.lookup(a >> PAGE_SHIFT) {
            Some(slot) => self.pages[slot as usize][(a as usize) & PAGE_MASK],
            None => 0,
        }
    }

    /// Record `t` as the last-accessor leave time of `a`.
    #[inline]
    pub fn set(&mut self, a: Addr, t: Cycle) {
        let key = a >> PAGE_SHIFT;
        let slot = match self.lookup(key) {
            Some(s) => s,
            None => {
                let s = self.pages.len() as u32;
                self.pages.push(vec![0; PAGE_WORDS].into_boxed_slice());
                self.index.insert(key, s);
                self.last_key = key;
                self.last_slot = s;
                s
            }
        };
        self.pages[slot as usize][(a as usize) & PAGE_MASK] = t;
    }

    /// Number of resident pages.
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    /// Tracked bytes: resident pages at full width plus the page index.
    pub fn bytes(&self) -> usize {
        self.pages.len() * PAGE_WORDS * std::mem::size_of::<Cycle>()
            + self.index.len() * (std::mem::size_of::<u64>() + std::mem::size_of::<u32>())
    }
}

/// Maximum lanes of a [`LanePlane`] (and of the batch evaluator built on
/// it): per-page lane-residency is a single `u64` bitmask.
pub const MAX_LANES: usize = 64;

/// Laned last-accessor scoreboard: the [`AddrPlane`] layout generalized to
/// N evaluation lanes sharing one page index. Digest-equal DSE candidates
/// stride the same address regions, so their pages coincide; keeping one
/// index (and one one-entry cache) in front of word-major lane columns
/// amortizes the lookup machinery across the whole batch instead of
/// duplicating it per lane.
///
/// Byte accounting stays per-lane and serial-identical: a lane "owns" a
/// page only once it has *written* it (tracked in a per-page lane bitmask),
/// and [`LanePlane::lane_bytes`] charges exactly what a serial
/// [`AddrPlane`] would retain for that lane — resident pages at full width
/// plus their index entries. Reads of a page the lane never wrote return 0
/// without charging it, exactly like a serial miss.
#[derive(Debug)]
pub struct LanePlane {
    lanes: usize,
    index: FxHashMap<u64, u32>,
    /// Word-major lane columns: `pages[slot][word * lanes + lane]`.
    pages: Vec<Box<[Cycle]>>,
    /// Per-page bitmask of lanes that have written the page.
    touched: Vec<u64>,
    /// Per-lane count of pages written (serial-equivalent residency).
    resident: Vec<u32>,
    last_key: u64,
    last_slot: u32,
}

impl LanePlane {
    /// An empty plane over `lanes` evaluation lanes.
    pub fn new(lanes: usize) -> Self {
        assert!(
            (1..=MAX_LANES).contains(&lanes),
            "LanePlane supports 1..={MAX_LANES} lanes (got {lanes})"
        );
        Self {
            lanes,
            index: FxHashMap::default(),
            pages: Vec::new(),
            touched: Vec::new(),
            resident: vec![0; lanes],
            last_key: 0,
            last_slot: 0,
        }
    }

    /// Resolve a page key to its slab slot (shared one-entry cache — the
    /// key→slot map is lane-independent), refreshing the cache on a hit.
    #[inline]
    fn lookup(&mut self, key: u64) -> Option<u32> {
        if !self.pages.is_empty() && self.last_key == key {
            return Some(self.last_slot);
        }
        let s = *self.index.get(&key)?;
        self.last_key = key;
        self.last_slot = s;
        Some(s)
    }

    /// Last-accessor leave time of `a` in `lane` (0 when never written by
    /// this lane — pages resident for *other* lanes still read 0 here).
    #[inline]
    pub fn get(&mut self, lane: usize, a: Addr) -> Cycle {
        let lanes = self.lanes;
        match self.lookup(a >> PAGE_SHIFT) {
            Some(slot) => {
                self.pages[slot as usize][((a as usize) & PAGE_MASK) * lanes + lane]
            }
            None => 0,
        }
    }

    /// Record `t` as the last-accessor leave time of `a` in `lane`.
    #[inline]
    pub fn set(&mut self, lane: usize, a: Addr, t: Cycle) {
        let lanes = self.lanes;
        let key = a >> PAGE_SHIFT;
        let slot = match self.lookup(key) {
            Some(s) => s,
            None => {
                let s = self.pages.len() as u32;
                self.pages.push(vec![0; PAGE_WORDS * lanes].into_boxed_slice());
                self.touched.push(0);
                self.index.insert(key, s);
                self.last_key = key;
                self.last_slot = s;
                s
            }
        };
        let bit = 1u64 << lane;
        if self.touched[slot as usize] & bit == 0 {
            self.touched[slot as usize] |= bit;
            self.resident[lane] += 1;
        }
        self.pages[slot as usize][((a as usize) & PAGE_MASK) * lanes + lane] = t;
    }

    /// Pages this lane has written (what a serial plane would have
    /// resident).
    pub fn lane_pages(&self, lane: usize) -> usize {
        self.resident[lane] as usize
    }

    /// Serial-equivalent tracked bytes of one lane: its resident pages at
    /// full serial width plus their index entries — bit-identical to what
    /// [`AddrPlane::bytes`] reports for the same access trace.
    pub fn lane_bytes(&self, lane: usize) -> usize {
        self.resident[lane] as usize
            * (PAGE_WORDS * std::mem::size_of::<Cycle>()
                + std::mem::size_of::<u64>()
                + std::mem::size_of::<u32>())
    }
}

/// Full carried state of a streaming AIDG evaluation.
#[derive(Debug)]
pub struct EvalState {
    /// Structural rings, indexed by lock-owner object id.
    pub obj_ring: Vec<SlotRing>,
    /// Last-accessor leave time per register id.
    pub reg_last: Vec<Cycle>,
    /// Last-accessor leave time per memory address (paged dense plane).
    pub addr_last: AddrPlane,
    /// Issue-buffer entry fill (Algorithm 1 `b_enter`).
    pub b_enter: BufferFill,
    /// Issue-buffer forward fill (Algorithm 1 `b_forward`).
    pub b_forward: BufferFill,
    /// Global instruction counter (drives merged-fetch grouping).
    pub instr_index: u64,
    /// Fetch-leave slots of the current fetch group, consumed in order.
    pub group_slots: Vec<Cycle>,
    /// Structural chain of the instruction memory port: next fetch
    /// transaction may start at this time.
    pub next_fetch_start: Cycle,
    /// Issue-buffer entry time of the most recent instruction — paces the
    /// next fetch transaction ("fetch as long as the buffer is not full").
    pub last_ifs_enter: Cycle,
    /// Evaluation horizon: the current merged-fetch t_enter. No future gate
    /// query starts earlier, so rings prune their event windows below it.
    pub horizon: Cycle,
    /// Peak tracked-state footprint (bytes) seen so far.
    pub peak_bytes: usize,
    /// Total AIDG nodes processed.
    pub nodes: u64,
}

impl EvalState {
    /// Fresh state for a diagram with `num_objects` objects and
    /// `num_regs` registers; `capacities` yields each object's lock capacity.
    pub fn new(num_objects: usize, num_regs: usize, capacities: impl Fn(usize) -> u32) -> Self {
        Self {
            obj_ring: (0..num_objects).map(|i| SlotRing::new(capacities(i))).collect(),
            reg_last: vec![0; num_regs],
            addr_last: AddrPlane::default(),
            b_enter: BufferFill::default(),
            b_forward: BufferFill::default(),
            instr_index: 0,
            group_slots: Vec::new(),
            next_fetch_start: 0,
            last_ifs_enter: 0,
            horizon: 0,
            peak_bytes: 0,
            nodes: 0,
        }
    }

    /// Current tracked-state footprint in bytes (the Fig. 11/12 metric; see
    /// DESIGN.md — tracked evaluator state, not process RSS). Address
    /// scoreboard bytes are page-granular (resident 4 KiB pages), matching
    /// what the plane actually retains.
    pub fn live_bytes(&self) -> usize {
        let rings: usize = self.obj_ring.iter().map(|r| r.bytes()).sum();
        rings
            + self.reg_last.len() * std::mem::size_of::<Cycle>()
            + self.addr_last.bytes()
            + self.b_enter.bytes()
            + self.b_forward.bytes()
    }

    /// Fold the current footprint (plus `extra` transient bytes) into the peak.
    pub fn note_peak(&mut self, extra: usize) {
        let b = self.live_bytes() + extra;
        if b > self.peak_bytes {
            self.peak_bytes = b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_capacity_one_serializes() {
        let mut r = SlotRing::new(1);
        assert_eq!(r.gate(0), 0);
        r.insert(2, 10, 0);
        assert_eq!(r.gate(3), 10);
        assert_eq!(r.gate(10), 10); // interval is half-open
        r.insert(10, 25, 0);
        assert_eq!(r.gate(11), 25);
        assert_eq!(r.gate(30), 30);
    }

    #[test]
    fn ring_capacity_two_allows_overlap() {
        let mut r = SlotRing::new(2);
        r.insert(0, 10, 0);
        assert_eq!(r.gate(0), 0); // one slot still free
        r.insert(0, 20, 0);
        assert_eq!(r.gate(5), 10); // first departure frees a slot
        r.insert(10, 30, 0);
        assert_eq!(r.gate(12), 20);
    }

    #[test]
    fn ring_out_of_order_departures() {
        // occupant A [0, 100), occupant B [0, 4): B departs first, so a
        // capacity-2 object is free again at 4 — not at 100
        let mut r = SlotRing::new(2);
        r.insert(0, 100, 0);
        r.insert(0, 4, 0);
        assert_eq!(r.gate(0), 4);
    }

    #[test]
    fn ring_future_intervals_do_not_block_the_past() {
        // an occupant far in the future must not constrain earlier times
        // (capacity > 1 uses the interval model; capacity 1 keeps the
        // paper's last-structure-user program-order serialization)
        let mut r = SlotRing::new(2);
        r.insert(50, 60, 0);
        r.insert(52, 58, 0);
        assert_eq!(r.gate(0), 0);
        assert_eq!(r.gate(55), 58);
    }

    #[test]
    fn ring_prunes_below_horizon() {
        let mut r = SlotRing::new(1);
        for i in 0..100 {
            r.insert(i * 10, i * 10 + 5, i * 10);
        }
        assert!(r.bytes() <= 64, "bytes {}", r.bytes());
        // still correct after pruning
        assert_eq!(r.gate(992), 995);
    }

    #[test]
    fn ring_concurrent_prunes_and_reports_true_entry_width() {
        let mut r = SlotRing::new(2);
        for i in 0..100 {
            r.insert(i * 10, i * 10 + 5, i.saturating_sub(1) * 10);
        }
        // the pruned window holds a handful of events of 16 bytes each
        assert!(r.bytes() <= 8 * 16, "bytes {}", r.bytes());
        assert_eq!(r.gate(991), 991);
    }

    #[test]
    fn ring_unbounded_never_constrains() {
        let mut r = SlotRing::new(u32::MAX);
        r.insert(0, 10, 0);
        r.insert(0, 20, 0);
        assert_eq!(r.gate(0), 0);
        assert_eq!(r.bytes(), 0);
    }

    #[test]
    fn buffer_fill_respects_capacity() {
        let mut b = BufferFill::default();
        assert_eq!(b.alloc(5, 2), 5);
        assert_eq!(b.alloc(5, 2), 5);
        assert_eq!(b.alloc(5, 2), 6); // cycle 5 full
        assert_eq!(b.alloc(4, 2), 4); // cycle 4 still free
        assert_eq!(b.alloc(4, 2), 4);
        assert_eq!(b.alloc(4, 2), 6); // 4 and 5 full, 6 has one slot left
        assert_eq!(b.alloc(4, 2), 7);
    }

    #[test]
    fn buffer_fill_prunes() {
        let mut b = BufferFill::default();
        for t in 0..10_000 {
            b.alloc(t, 1);
        }
        b.prune_below(9_000);
        // allocations below the watermark snap up to it
        assert!(b.alloc(0, 1) >= 9_000);
        // zeroed slots below the watermark are reusable, and bytes reflect
        // the ring's actual storage (no stale retained entries)
        assert_eq!(b.bytes(), b.counts.len() * 4);
    }

    #[test]
    fn buffer_fill_far_future_commit_then_prune() {
        let mut b = BufferFill::default();
        assert_eq!(b.alloc(0, 1), 0);
        // a parked instruction commits far beyond the watermark
        b.commit(5_000);
        assert_eq!(b.probe(5_000, 1), 5_001);
        b.prune_below(6_000);
        assert_eq!(b.probe(0, 1), 6_000);
        assert_eq!(b.alloc(6_000, 1), 6_000);
        assert_eq!(b.alloc(6_000, 1), 6_001);
    }

    #[test]
    fn addr_plane_defaults_to_zero_and_overwrites() {
        let mut p = AddrPlane::default();
        assert_eq!(p.get(42), 0);
        p.set(42, 7);
        p.set(43, 9);
        assert_eq!(p.get(42), 7);
        assert_eq!(p.get(43), 9);
        p.set(42, 11);
        assert_eq!(p.get(42), 11);
        assert_eq!(p.pages(), 1);
        // a far-away address opens a second page; the first stays intact
        p.set(1 << 40, 3);
        assert_eq!(p.get(1 << 40), 3);
        assert_eq!(p.get(42), 11);
        assert_eq!(p.pages(), 2);
        assert!(p.bytes() >= 2 * 512 * 8);
    }

    #[test]
    fn state_tracks_peak() {
        let mut s = EvalState::new(4, 8, |_| 1);
        let base = s.live_bytes();
        s.addr_last.set(1, 1);
        s.addr_last.set(2, 1);
        s.note_peak(0);
        assert!(s.peak_bytes > base);
    }
}
