//! Carried evaluation state (the AIDG "frontier").
//!
//! Dependencies in an AIDG only ever point backwards to the *last* user of a
//! resource: the last structure user per object (§6.1), the last accessor
//! per register and memory address, the previous InstructionFetchStage node
//! (buffer fill level chain), and the per-time issue-buffer fill counters of
//! Algorithm 1. Holding exactly that state lets us construct and evaluate
//! the graph in a single streaming pass, appending `k_block` iterations at a
//! time (§6.3) with memory bounded by the *live* frontier instead of the
//! whole graph — the whole-graph evaluation of Table 5 is the same sweep run
//! to `k`.



use crate::ids::{Addr, Cycle, FxHashMap};

/// Occupancy tracker of one structural lock (ACADL object or ExecuteStage
/// lock domain) holding at most `capacity` instructions.
///
/// Occupants may depart **out of order** (two stores parked in the issue
/// buffer waiting on slow data deps leave after later loads that flowed
/// straight through) and may *enter* far in the future relative to earlier
/// claims, so neither a FIFO of leave times nor an order statistic over
/// leave times is correct. The exact model is interval occupancy: each
/// occupant holds the object over `[enter, leave)`; the next claimant ready
/// at `t0` enters at the earliest `t ≥ t0` where fewer than `capacity`
/// intervals are active. Stored as a time-sorted delta map (+1 at entry,
/// −1 at leave), pruned below the evaluation horizon (the current fetch
/// time — no future claim can be gated earlier), so the live window stays
/// tiny.
#[derive(Debug, Clone)]
enum RingRepr {
    /// capacity == 1: claims serialize, the last leave time is the gate.
    Serial { last: Cycle },
    /// 1 < capacity < ∞: full interval-occupancy delta map.
    Concurrent {
        /// Time-sorted occupancy deltas at or after the horizon.
        events: std::collections::BTreeMap<Cycle, i64>,
        /// Active count just below the first retained event.
        base_active: i64,
    },
    /// writeBack: exempt from structural dependencies.
    Unbounded,
}

#[derive(Debug, Clone)]
/// Structural-lock occupancy tracker of one object, with an adaptive
/// representation: serial (capacity 1), bounded-concurrent, or unbounded
/// (the write-back pseudo-object).
pub struct SlotRing {
    repr: RingRepr,
    capacity: u32,
}

impl Default for SlotRing {
    fn default() -> Self {
        Self::new(1)
    }
}

impl SlotRing {
    /// A ring with `capacity` slots (`u32::MAX` = unbounded).
    pub fn new(capacity: u32) -> Self {
        let repr = match capacity {
            u32::MAX => RingRepr::Unbounded,
            1 => RingRepr::Serial { last: 0 },
            _ => RingRepr::Concurrent {
                events: std::collections::BTreeMap::new(),
                base_active: 0,
            },
        };
        Self { repr, capacity }
    }

    /// Earliest `t >= t0` at which a free slot exists.
    #[inline]
    pub fn gate(&self, t0: Cycle) -> Cycle {
        match &self.repr {
            RingRepr::Unbounded => t0,
            RingRepr::Serial { last } => t0.max(*last),
            RingRepr::Concurrent { events, base_active } => {
                let cap = self.capacity as i64;
                let mut active =
                    base_active + events.range(..=t0).map(|(_, d)| *d).sum::<i64>();
                if active < cap {
                    return t0;
                }
                for (&t, &d) in
                    events.range((std::ops::Bound::Excluded(t0), std::ops::Bound::Unbounded))
                {
                    active += d;
                    if active < cap {
                        return t;
                    }
                }
                unreachable!("occupancy never drains: every interval carries its leave event")
            }
        }
    }

    /// Record an occupant over `[enter, leave)` and prune events below
    /// `horizon` (no future gate query can start earlier).
    #[inline]
    pub fn insert(&mut self, enter: Cycle, leave: Cycle, horizon: Cycle) {
        match &mut self.repr {
            RingRepr::Unbounded => {}
            RingRepr::Serial { last } => {
                if leave > *last {
                    *last = leave;
                }
            }
            RingRepr::Concurrent { events, base_active } => {
                if leave <= enter {
                    return;
                }
                *events.entry(enter).or_insert(0) += 1;
                *events.entry(leave).or_insert(0) -= 1;
                while let Some((&t, _)) = events.first_key_value() {
                    if t >= horizon {
                        break;
                    }
                    let d = events.remove(&t).unwrap();
                    *base_active += d;
                }
            }
        }
    }

    /// Tracked bytes of this ring's representation.
    pub fn bytes(&self) -> usize {
        match &self.repr {
            RingRepr::Concurrent { events, .. } => events.len() * 2 * std::mem::size_of::<Cycle>(),
            _ => 0,
        }
    }
}

/// Per-cycle fill counters for the issue buffer (Algorithm 1's `b_enter` /
/// `b_forward` hashmaps): at most `cap` instructions may claim the same
/// cycle; `alloc` finds the earliest cycle `>= t0` with a free slot.
#[derive(Debug, Default)]
pub struct BufferFill {
    counts: FxHashMap<Cycle, u32>,
    /// Times strictly below this can no longer be allocated (monotonic
    /// frontier) and are pruned.
    watermark: Cycle,
}

impl BufferFill {
    /// Earliest `t >= t0` with fewer than `cap` occupants; increments it.
    #[inline]
    pub fn alloc(&mut self, t0: Cycle, cap: u32) -> Cycle {
        let t = self.probe(t0, cap);
        *self.counts.entry(t).or_insert(0) += 1;
        t
    }

    /// Earliest `t >= t0` with a free slot, without claiming it.
    #[inline]
    pub fn probe(&self, t0: Cycle, cap: u32) -> Cycle {
        let mut t = t0.max(self.watermark);
        loop {
            if self.counts.get(&t).copied().unwrap_or(0) < cap {
                return t;
            }
            t += 1;
        }
    }

    /// Claim a slot at `t` (previously validated with [`Self::probe`]).
    #[inline]
    pub fn commit(&mut self, t: Cycle) {
        *self.counts.entry(t).or_insert(0) += 1;
    }

    /// Advance the frontier: allocations below `t` can no longer occur, so
    /// their counters are dropped. Called with the oldest time still
    /// reachable (e.g. the previous fetch-group start).
    pub fn prune_below(&mut self, t: Cycle) {
        if t > self.watermark {
            self.watermark = t;
            if self.counts.len() > 4096 {
                self.counts.retain(|&k, _| k >= t);
            }
        }
    }

    /// Tracked bytes of the buffer-fill window.
    pub fn bytes(&self) -> usize {
        self.counts.len() * (std::mem::size_of::<Cycle>() + std::mem::size_of::<u32>())
    }
}

/// Full carried state of a streaming AIDG evaluation.
#[derive(Debug)]
pub struct EvalState {
    /// Structural rings, indexed by lock-owner object id.
    pub obj_ring: Vec<SlotRing>,
    /// Last-accessor leave time per register id.
    pub reg_last: Vec<Cycle>,
    /// Last-accessor leave time per memory address.
    pub addr_last: FxHashMap<Addr, Cycle>,
    /// Issue-buffer entry fill (Algorithm 1 `b_enter`).
    pub b_enter: BufferFill,
    /// Issue-buffer forward fill (Algorithm 1 `b_forward`).
    pub b_forward: BufferFill,
    /// Global instruction counter (drives merged-fetch grouping).
    pub instr_index: u64,
    /// Fetch-leave slots of the current fetch group, consumed in order.
    pub group_slots: Vec<Cycle>,
    /// Structural chain of the instruction memory port: next fetch
    /// transaction may start at this time.
    pub next_fetch_start: Cycle,
    /// Issue-buffer entry time of the most recent instruction — paces the
    /// next fetch transaction ("fetch as long as the buffer is not full").
    pub last_ifs_enter: Cycle,
    /// Evaluation horizon: the current merged-fetch t_enter. No future gate
    /// query starts earlier, so rings prune their event windows below it.
    pub horizon: Cycle,
    /// Peak tracked-state footprint (bytes) seen so far.
    pub peak_bytes: usize,
    /// Total AIDG nodes processed.
    pub nodes: u64,
}

impl EvalState {
    /// Fresh state for a diagram with `num_objects` objects and
    /// `num_regs` registers; `capacities` yields each object's lock capacity.
    pub fn new(num_objects: usize, num_regs: usize, capacities: impl Fn(usize) -> u32) -> Self {
        Self {
            obj_ring: (0..num_objects).map(|i| SlotRing::new(capacities(i))).collect(),
            reg_last: vec![0; num_regs],
            addr_last: FxHashMap::default(),
            b_enter: BufferFill::default(),
            b_forward: BufferFill::default(),
            instr_index: 0,
            group_slots: Vec::new(),
            next_fetch_start: 0,
            last_ifs_enter: 0,
            horizon: 0,
            peak_bytes: 0,
            nodes: 0,
        }
    }

    /// Current tracked-state footprint in bytes (the Fig. 11/12 metric; see
    /// DESIGN.md — tracked evaluator state, not process RSS).
    pub fn live_bytes(&self) -> usize {
        let rings: usize = self.obj_ring.iter().map(|r| r.bytes()).sum();
        rings
            + self.reg_last.len() * std::mem::size_of::<Cycle>()
            + self.addr_last.len() * (std::mem::size_of::<Addr>() + std::mem::size_of::<Cycle>() + 8)
            + self.b_enter.bytes()
            + self.b_forward.bytes()
    }

    /// Fold the current footprint (plus `extra` transient bytes) into the peak.
    pub fn note_peak(&mut self, extra: usize) {
        let b = self.live_bytes() + extra;
        if b > self.peak_bytes {
            self.peak_bytes = b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_capacity_one_serializes() {
        let mut r = SlotRing::new(1);
        assert_eq!(r.gate(0), 0);
        r.insert(2, 10, 0);
        assert_eq!(r.gate(3), 10);
        assert_eq!(r.gate(10), 10); // interval is half-open
        r.insert(10, 25, 0);
        assert_eq!(r.gate(11), 25);
        assert_eq!(r.gate(30), 30);
    }

    #[test]
    fn ring_capacity_two_allows_overlap() {
        let mut r = SlotRing::new(2);
        r.insert(0, 10, 0);
        assert_eq!(r.gate(0), 0); // one slot still free
        r.insert(0, 20, 0);
        assert_eq!(r.gate(5), 10); // first departure frees a slot
        r.insert(10, 30, 0);
        assert_eq!(r.gate(12), 20);
    }

    #[test]
    fn ring_out_of_order_departures() {
        // occupant A [0, 100), occupant B [0, 4): B departs first, so a
        // capacity-2 object is free again at 4 — not at 100
        let mut r = SlotRing::new(2);
        r.insert(0, 100, 0);
        r.insert(0, 4, 0);
        assert_eq!(r.gate(0), 4);
    }

    #[test]
    fn ring_future_intervals_do_not_block_the_past() {
        // an occupant far in the future must not constrain earlier times
        // (capacity > 1 uses the interval model; capacity 1 keeps the
        // paper's last-structure-user program-order serialization)
        let mut r = SlotRing::new(2);
        r.insert(50, 60, 0);
        r.insert(52, 58, 0);
        assert_eq!(r.gate(0), 0);
        assert_eq!(r.gate(55), 58);
    }

    #[test]
    fn ring_prunes_below_horizon() {
        let mut r = SlotRing::new(1);
        for i in 0..100 {
            r.insert(i * 10, i * 10 + 5, i * 10);
        }
        assert!(r.bytes() <= 64, "bytes {}", r.bytes());
        // still correct after pruning
        assert_eq!(r.gate(992), 995);
    }

    #[test]
    fn ring_unbounded_never_constrains() {
        let mut r = SlotRing::new(u32::MAX);
        r.insert(0, 10, 0);
        r.insert(0, 20, 0);
        assert_eq!(r.gate(0), 0);
        assert_eq!(r.bytes(), 0);
    }

    #[test]
    fn buffer_fill_respects_capacity() {
        let mut b = BufferFill::default();
        assert_eq!(b.alloc(5, 2), 5);
        assert_eq!(b.alloc(5, 2), 5);
        assert_eq!(b.alloc(5, 2), 6); // cycle 5 full
        assert_eq!(b.alloc(4, 2), 4); // cycle 4 still free
        assert_eq!(b.alloc(4, 2), 4);
        assert_eq!(b.alloc(4, 2), 6); // 4 and 5 full, 6 has one slot left
        assert_eq!(b.alloc(4, 2), 7);
    }

    #[test]
    fn buffer_fill_prunes() {
        let mut b = BufferFill::default();
        for t in 0..10_000 {
            b.alloc(t, 1);
        }
        b.prune_below(9_000);
        assert!(b.counts.len() <= 10_000);
        // allocations below the watermark snap up to it
        assert!(b.alloc(0, 1) >= 9_000);
    }

    #[test]
    fn state_tracks_peak() {
        let mut s = EvalState::new(4, 8, |_| 1);
        let base = s.live_bytes();
        s.addr_last.insert(1, 1);
        s.addr_last.insert(2, 1);
        s.note_peak(0);
        assert!(s.peak_bytes > base);
    }
}
