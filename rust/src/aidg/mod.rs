//! The Architectural Instruction Dependency Graph (AIDG) — the paper's
//! performance model (§6).
//!
//! An AIDG's nodes are (instruction, ACADL object) pairs; edges are forward
//! (`f`), structural (`s`), data (`d`), and buffer-fill (`b`) dependencies.
//! This implementation fuses construction (§6.1) and Algorithm-1 evaluation
//! (§6.2) into one streaming topological sweep ([`eval::Evaluator`]), and
//! layers the §6.3 fixed-point estimator with its 1 % fallback heuristic on
//! top ([`fixed_point::estimate_layer`]). The evaluator compiles each
//! kernel's instruction template into a precompiled *iteration program*
//! (the crate-private `program` module) on the first iteration, so
//! steady-state iterations replay
//! a flat node table with zero heap allocations (the original
//! re-derive-everything evaluator survives as the differential-test
//! reference in `reference` under `#[cfg(test)]`). The node table is
//! further lowered into a fused superinstruction tape dispatched through a
//! function-pointer table (`ops` + `fuse` — the default
//! [`DispatchMode::Threaded`] path, with the node-table interpreter as the
//! bit-identical escape hatch and fallback). For DSE sweeps,
//! [`batch`] amortizes one such program walk across up to [`MAX_LANES`]
//! digest-equal candidates in structure-of-arrays lockstep.

pub mod batch;
pub mod eval;
pub mod fixed_point;
pub(crate) mod fuse;
pub(crate) mod ops;
pub(crate) mod program;
#[cfg(test)]
pub(crate) mod reference;
pub mod state;

pub use batch::{estimate_layer_batch, BatchEvaluator, BatchOutcome, LaneStatus, MAX_LANES};
pub use eval::{Evaluator, IterStat};
pub use fixed_point::{
    estimate_layer, evaluate_whole, k_block, FixedPointConfig, LayerEstimate, Provenance,
};
pub use ops::{
    default_dispatch, set_default_dispatch, DispatchMode, DispatchStats, FusionStats,
};
pub use state::EvalState;
