//! Nelder–Mead downhill simplex [19] — used exactly as in the paper:
//! fitting the Timeloop-like model's per-memory bandwidths against
//! simulator measurements (§7.2: "we used the simplex method using
//! Verilator measurements as input in order to find the best read and write
//! bandwidths for each memory").

/// Minimize `f` over `x0.len()` dimensions. Returns (argmin, min).
pub fn nelder_mead(
    f: impl Fn(&[f64]) -> f64,
    x0: &[f64],
    initial_step: f64,
    max_iters: usize,
) -> (Vec<f64>, f64) {
    let n = x0.len();
    assert!(n >= 1);
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);

    // initial simplex: x0 plus a step along each axis
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    simplex.push((x0.to_vec(), f(x0)));
    for i in 0..n {
        let mut x = x0.to_vec();
        x[i] += if x[i].abs() > 1e-12 { initial_step * x[i].abs() } else { initial_step };
        let fx = f(&x);
        simplex.push((x, fx));
    }

    for _ in 0..max_iters {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let best = simplex[0].1;
        let worst = simplex[n].1;
        let diameter: f64 = (0..n)
            .map(|i| {
                simplex
                    .iter()
                    .map(|(x, _)| (x[i] - simplex[0].0[i]).abs())
                    .fold(0.0, f64::max)
            })
            .fold(0.0, f64::max);
        if (worst - best).abs() <= 1e-12 * (1.0 + best.abs()) && diameter <= 1e-9 {
            break;
        }

        // centroid of all but the worst
        let mut c = vec![0.0; n];
        for (x, _) in simplex.iter().take(n) {
            for i in 0..n {
                c[i] += x[i] / n as f64;
            }
        }

        let xw = simplex[n].0.clone();
        let refl: Vec<f64> = (0..n).map(|i| c[i] + alpha * (c[i] - xw[i])).collect();
        let f_refl = f(&refl);

        if f_refl < simplex[0].1 {
            // expansion
            let exp: Vec<f64> = (0..n).map(|i| c[i] + gamma * (refl[i] - c[i])).collect();
            let f_exp = f(&exp);
            simplex[n] = if f_exp < f_refl { (exp, f_exp) } else { (refl, f_refl) };
        } else if f_refl < simplex[n - 1].1 {
            simplex[n] = (refl, f_refl);
        } else {
            // contraction
            let con: Vec<f64> = (0..n).map(|i| c[i] + rho * (xw[i] - c[i])).collect();
            let f_con = f(&con);
            if f_con < simplex[n].1 {
                simplex[n] = (con, f_con);
            } else {
                // shrink toward the best point
                let xb = simplex[0].0.clone();
                for item in simplex.iter_mut().skip(1) {
                    for i in 0..n {
                        item.0[i] = xb[i] + sigma * (item.0[i] - xb[i]);
                    }
                    item.1 = f(&item.0);
                }
            }
        }
    }
    simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    simplex.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_bowl() {
        let (x, fx) = nelder_mead(|v| (v[0] - 3.0).powi(2) + (v[1] + 1.0).powi(2), &[0.0, 0.0], 1.0, 500);
        assert!((x[0] - 3.0).abs() < 1e-4, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-4);
        assert!(fx < 1e-6);
    }

    #[test]
    fn rosenbrock_2d() {
        let rb = |v: &[f64]| (1.0 - v[0]).powi(2) + 100.0 * (v[1] - v[0] * v[0]).powi(2);
        let (x, fx) = nelder_mead(rb, &[-1.0, 1.0], 0.5, 5_000);
        assert!(fx < 1e-4, "fx={fx} at {x:?}");
    }

    #[test]
    fn one_dimensional() {
        let (x, _) = nelder_mead(|v| (v[0] - 42.0).abs(), &[1.0], 1.0, 2_000);
        assert!((x[0] - 42.0).abs() < 1e-3, "{x:?}");
    }
}
