//! Baseline estimators the paper compares against (§7): the refined
//! roofline model [28], a Timeloop-like analytical model [21] with
//! simplex-fitted bandwidths, and literature-reported regression constants
//! [5].

pub mod regression;
pub mod roofline;
pub mod simplex;
pub mod timeloop_like;

pub use regression::BOUZIDI_SVR_MAPE;
pub use roofline::{roofline_cycles, roofline_network, HwFeatures, LayerFeatures};
pub use simplex::nelder_mead;
pub use timeloop_like::{fit_bandwidths, TimeloopModel};
