//! Literature-reported regression baselines.
//!
//! The paper does not train regression models itself ("generating only
//! 10 000 samples would take two months" of RTL simulation) and instead
//! cites the best support-vector-regression MAPE from Bouzidi et al. [5];
//! every table carries that constant. We reproduce the same treatment.

/// Best SVR MAPE reported by Bouzidi et al. [5] (%, the tables' constant
/// "Regression model" row).
pub const BOUZIDI_SVR_MAPE: f64 = 7.67;

/// Range of regression MAPEs across the five estimators of [5] (%).
pub const BOUZIDI_MAPE_RANGE: (f64, f64) = (7.67, 14.73);

/// Samples per platform Bouzidi et al. collected to train their estimators —
/// the data-collection cost our approach avoids (§7).
pub const BOUZIDI_SAMPLES_PER_PLATFORM: u64 = 200_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_are_papers() {
        assert_eq!(BOUZIDI_SVR_MAPE, 7.67);
        assert!(BOUZIDI_MAPE_RANGE.0 <= BOUZIDI_MAPE_RANGE.1);
        assert_eq!(BOUZIDI_SAMPLES_PER_PLATFORM, 200_000);
    }
}
