//! Timeloop-like analytical model (paper §7.2's second baseline).
//!
//! Timeloop [21] evaluates loop-nest mappings against a coarse textual
//! architecture description: per-level memory bandwidths and a PE array,
//! **without pipeline stalls, resource conflicts, or instruction-level
//! parallelism** — the limitation the paper quantifies (accuracy as low as
//! 78 % / Table 2's −23.56 % PE). This module reproduces that modeling
//! power and those blind spots:
//!
//! - compute time assumes full PE-array utilization of the *tiled* loop
//!   nest (`⌈M/DIM⌉·⌈K/DIM⌉·⌈N/DIM⌉·DIM` array passes);
//! - each memory level contributes `words / bandwidth` cycles, all levels
//!   and compute overlapping perfectly (`max`);
//! - the paper's Gemmini model artifact is reproduced too: scratchpad and
//!   accumulator tiling are *coupled* (Timeloop cannot express parallel
//!   memories), adding a dependent traffic term;
//! - bandwidths are fitted with Nelder–Mead against simulator measurements
//!   ([`fit_bandwidths`]), mitigating the missing-stall problem exactly as
//!   the paper did.

use crate::dnn::Layer;
use crate::Result;

use super::simplex::nelder_mead;

/// Fitted/configured Timeloop-style model of a tiled-GEMM accelerator.
#[derive(Debug, Clone, Copy)]
pub struct TimeloopModel {
    /// PE array dimension (Gemmini DIM).
    pub dim: u32,
    /// DRAM→scratchpad read bandwidth (words/cycle).
    pub bw_in: f64,
    /// Weight-stream bandwidth (words/cycle).
    pub bw_w: f64,
    /// Accumulator→DRAM write bandwidth (words/cycle).
    pub bw_out: f64,
}

impl TimeloopModel {
    /// Datasheet-default model for a `dim`×`dim` array (one word per
    /// cycle per stream direction, before fitting).
    pub fn new(dim: u32) -> Self {
        // datasheet-style defaults before fitting: one word per cycle per
        // stream direction
        Self { dim, bw_in: 1.0, bw_w: 1.0, bw_out: 1.0 }
    }

    /// Analytical cycles for one layer (0 for layers Timeloop folds into the
    /// producing GEMM).
    pub fn layer_cycles(&self, layer: &Layer) -> f64 {
        let dim = self.dim as f64;
        let (m, k, n, reps) = match layer.gemm_dims() {
            Some((m, k, n)) => (m as f64, k as f64, n as f64, 1.0),
            None => match layer.kind {
                crate::dnn::LayerKind::DwConv2d { c, h, w, kh, kw, stride, pad } => {
                    let ho = crate::dnn::layer::out_dim(h, kh, stride, pad) as f64;
                    let wo = crate::dnn::layer::out_dim(w, kw, stride, pad) as f64;
                    (ho * wo, (kh * kw) as f64, 1.0, c as f64)
                }
                crate::dnn::LayerKind::Add { c, spatial }
                | crate::dnn::LayerKind::Mul { c, spatial } => {
                    // element-wise pass through the array at one row per cycle
                    let words = (c as f64) * (spatial as f64);
                    return (2.0 * words / self.bw_in).max(words / self.bw_out);
                }
                // activation/pooling fuse into the producing layer
                _ => return 0.0,
            },
        };

        // full-utilization compute: every tile pass streams DIM rows
        let tiles = (m / dim).ceil() * (k / dim).ceil() * (n / dim).ceil() * reps;
        let compute = tiles * dim;

        // memory streams (words / fitted bandwidth)
        let t_in = layer.in_words() as f64 / self.bw_in;
        let t_w = layer.weight_words() as f64 / self.bw_w;
        let t_out = layer.out_words() as f64 / self.bw_out;

        // the coupled scratchpad/accumulator artifact: C-tile traffic also
        // occupies the input stream (Timeloop's single-hierarchy limitation)
        let coupled = layer.out_words() as f64 / self.bw_in;

        compute.max(t_in + coupled).max(t_w).max(t_out)
    }

    /// Whole-network per-layer estimates.
    pub fn network_cycles(&self, layers: &[Layer]) -> Vec<f64> {
        layers.iter().map(|l| self.layer_cycles(l)).collect()
    }
}

/// Fit `(bw_in, bw_w, bw_out)` minimizing the MAPE against measured layer
/// cycles (the paper's simplex-on-Verilator-measurements step). Layers with
/// zero measured cycles (fused) are skipped.
pub fn fit_bandwidths(
    dim: u32,
    layers: &[Layer],
    measured: &[f64],
) -> Result<TimeloopModel> {
    anyhow::ensure!(layers.len() == measured.len(), "layer/measurement length mismatch");
    let objective = |bw: &[f64]| -> f64 {
        // penalize non-physical bandwidths
        if bw.iter().any(|&b| b <= 0.01 || b > 1024.0) {
            return 1e18;
        }
        let m = TimeloopModel { dim, bw_in: bw[0], bw_w: bw[1], bw_out: bw[2] };
        let mut acc = 0.0;
        let mut n = 0usize;
        for (l, &meas) in layers.iter().zip(measured) {
            if meas > 0.0 {
                let est = m.layer_cycles(l);
                acc += ((meas - est) / meas).abs();
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            acc / n as f64
        }
    };
    let (bw, _) = nelder_mead(objective, &[2.0, 2.0, 2.0], 1.0, 400);
    Ok(TimeloopModel { dim, bw_in: bw[0], bw_w: bw[1], bw_out: bw[2] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{Layer, LayerKind};

    fn conv() -> Layer {
        Layer::new(
            "c",
            LayerKind::Conv2d { c_in: 16, h: 16, w: 16, c_out: 32, kh: 3, kw: 3, stride: 1, pad: true },
        )
    }

    #[test]
    fn compute_floor_scales_with_dim() {
        let small = TimeloopModel { dim: 8, bw_in: 100.0, bw_w: 100.0, bw_out: 100.0 };
        let big = TimeloopModel { dim: 32, bw_in: 100.0, bw_w: 100.0, bw_out: 100.0 };
        assert!(big.layer_cycles(&conv()) < small.layer_cycles(&conv()));
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        let starved = TimeloopModel { dim: 16, bw_in: 0.1, bw_w: 0.1, bw_out: 0.1 };
        let fed = TimeloopModel { dim: 16, bw_in: 64.0, bw_w: 64.0, bw_out: 64.0 };
        assert!(starved.layer_cycles(&conv()) > 10.0 * fed.layer_cycles(&conv()));
    }

    #[test]
    fn act_layers_are_free() {
        let m = TimeloopModel::new(16);
        let act = Layer::new("a", LayerKind::Act {
            kind: crate::dnn::ActKind::Relu,
            c: 64,
            spatial: 64,
        });
        assert_eq!(m.layer_cycles(&act), 0.0);
    }

    #[test]
    fn fit_recovers_consistent_bandwidths() {
        // synthesize measurements from a known model; the fit must estimate
        // layers with low error afterwards
        let truth = TimeloopModel { dim: 16, bw_in: 3.0, bw_w: 5.0, bw_out: 2.0 };
        let layers: Vec<Layer> = vec![
            conv(),
            Layer::new("fc", LayerKind::Dense { c_in: 1024, c_out: 256 }),
            Layer::new(
                "c2",
                LayerKind::Conv2d { c_in: 64, h: 8, w: 8, c_out: 64, kh: 3, kw: 3, stride: 1, pad: true },
            ),
            Layer::new("add", LayerKind::Add { c: 64, spatial: 64 }),
        ];
        let measured: Vec<f64> = layers.iter().map(|l| truth.layer_cycles(l)).collect();
        let fitted = fit_bandwidths(16, &layers, &measured).unwrap();
        for (l, &meas) in layers.iter().zip(&measured) {
            let est = fitted.layer_cycles(l);
            let err = ((est - meas) / meas).abs();
            assert!(err < 0.05, "{}: est {est} meas {meas}", l.name);
        }
    }
}
