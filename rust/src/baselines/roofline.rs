//! Refined roofline model (after Wess et al. [28]; the paper's analytical
//! baseline in every results table).
//!
//! The *refined* roofline replaces the classic model's peak compute rate by
//! the rate achievable with the layer's **actual unroll factors** (UR_C ×
//! UR_K PEs active) and models memory transaction-granularly
//! (`⌈words / port_width⌉ · latency`). Compute and memory streams overlap
//! (`max`), the pipeline fill does not (additive). It still assumes a
//! *constant* utilization efficiency — the blind spot the paper exploits:
//! pipeline stalls, loop-carried dependencies, and oscillating iteration
//! latencies are invisible to it (§7.3, Fig. 13b).
//!
//! This module is the native mirror of `python/compile/kernels/ref.py`; the
//! AOT-compiled JAX/Pallas estimator in `artifacts/roofline.hlo.txt`
//! evaluates the same formula batched (see [`crate::runtime`]), and
//! `python/tests/test_kernel.py` pins the two against each other.

use crate::dnn::Layer;
use crate::mapping::MappedLayer;

/// Layer feature vector (mirror of python/compile/features.py, indices
/// L_MACS..L_K_ITERS).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerFeatures {
    /// Multiply-accumulate count.
    pub macs: f64,
    /// Input activation words.
    pub in_words: f64,
    /// Weight words.
    pub w_words: f64,
    /// Output words.
    pub out_words: f64,
    /// Achieved unroll along input channels.
    pub ur_c: f64,
    /// Achieved unroll along output channels.
    pub ur_k: f64,
    /// Loop iterations of the mapped kernel.
    pub k_iters: f64,
}

impl LayerFeatures {
    /// Extract features from a layer + its mapping.
    pub fn from_mapping(layer: &Layer, mapped: &MappedLayer) -> Self {
        let (in_w, w_w, out_w) = mapped.traffic.unwrap_or((
            layer.in_words(),
            layer.weight_words(),
            layer.out_words(),
        ));
        Self {
            macs: layer.macs() as f64,
            in_words: in_w as f64,
            w_words: w_w as f64,
            out_words: out_w as f64,
            ur_c: mapped.ur_c.max(1) as f64,
            ur_k: mapped.ur_k.max(1) as f64,
            k_iters: mapped.total_iters().max(1) as f64,
        }
    }

    /// Row layout of the AOT roofline artifact (features.py `LF`).
    pub fn to_row(self) -> [f64; 8] {
        [
            self.macs,
            self.in_words,
            self.w_words,
            self.out_words,
            self.ur_c,
            self.ur_k,
            self.k_iters,
            0.0,
        ]
    }
}

/// Hardware feature vector (features.py `HF`): `[rows, cols, port_width,
/// read_lat, write_lat, mac_lat, fetch_overhead, reserved]` — produced by
/// [`crate::mapping::Mapper::hw_features`].
pub type HwFeatures = [f64; 8];

/// Refined-roofline cycle estimate of one layer (must match ref.py /
/// kernels/roofline.py bit-for-bit on integer-valued f64 inputs).
pub fn roofline_cycles(l: &LayerFeatures, hw: &HwFeatures) -> f64 {
    let pw = hw[2].max(1.0);
    let read_lat = hw[3];
    let write_lat = hw[4];
    let mac_lat = hw[5].max(1.0);
    let fetch = hw[6];

    let compute = (l.macs / (l.ur_c.max(1.0) * l.ur_k.max(1.0))).ceil() * mac_lat;
    let reads = ((l.in_words / pw).ceil() + (l.w_words / pw).ceil()) * read_lat;
    let writes = (l.out_words / pw).ceil() * write_lat;
    let mem = reads + writes;
    let prolog = read_lat + mac_lat + write_lat + fetch * l.k_iters.max(1.0);
    compute.max(mem) + prolog
}

/// Whole-network roofline: per-layer estimates (fused layers cost 0).
pub fn roofline_network(
    layers: &[Layer],
    mapped: &[MappedLayer],
    hw: &HwFeatures,
) -> Vec<f64> {
    layers
        .iter()
        .zip(mapped)
        .map(|(l, m)| {
            if m.fused {
                0.0
            } else {
                roofline_cycles(&LayerFeatures::from_mapping(l, m), hw)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::LayerKind;

    fn feats() -> LayerFeatures {
        LayerFeatures {
            macs: 10_000.0,
            in_words: 400.0,
            w_words: 1_200.0,
            out_words: 240.0,
            ur_c: 4.0,
            ur_k: 4.0,
            k_iters: 100.0,
        }
    }

    #[test]
    fn compute_bound_layer() {
        let hw: HwFeatures = [4.0, 4.0, 8.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let l = feats();
        // compute = ceil(10000/16) = 625; mem = (50+150)*1 + 30*1 = 230
        let c = roofline_cycles(&l, &hw);
        assert_eq!(c, 625.0 + 3.0);
    }

    #[test]
    fn memory_bound_layer() {
        let hw: HwFeatures = [4.0, 4.0, 1.0, 4.0, 4.0, 1.0, 0.0, 0.0];
        let l = feats();
        // mem = (400+1200)*4 + 240*4 = 7360 > compute 625
        let c = roofline_cycles(&l, &hw);
        assert_eq!(c, 7360.0 + 9.0);
    }

    #[test]
    fn port_width_monotone() {
        // the Fig. 13 property: wider ports never increase the estimate
        let l = feats();
        let mut prev = f64::INFINITY;
        for pw in 1..=13 {
            let hw: HwFeatures = [12.0, 12.0, pw as f64, 4.0, 4.0, 1.0, 0.0, 0.0];
            let c = roofline_cycles(&l, &hw);
            assert!(c <= prev, "pw={pw}");
            prev = c;
        }
    }

    #[test]
    fn underutilization_raises_estimate() {
        // wide port => compute bound, so utilization dominates
        let hw: HwFeatures = [12.0, 12.0, 64.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let full = LayerFeatures { ur_c: 12.0, ur_k: 12.0, ..feats() };
        let under = LayerFeatures { ur_c: 10.0, ur_k: 10.0, ..feats() };
        assert!(roofline_cycles(&under, &hw) > roofline_cycles(&full, &hw));
    }

    #[test]
    fn network_skips_fused() {
        let layers = vec![
            Layer::new("c", LayerKind::Dense { c_in: 64, c_out: 64 }),
            Layer::new("a", LayerKind::Act {
                kind: crate::dnn::ActKind::Relu,
                c: 64,
                spatial: 1,
            }),
        ];
        let mapped = vec![
            MappedLayer {
                layer_name: "c".into(),
                kernels: vec![],
                fused: false,
                ur_c: 8,
                ur_k: 8,
                traffic: None,
            },
            crate::mapping::MappedLayer::fused("a"),
        ];
        let hw: HwFeatures = [8.0, 8.0, 2.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let v = roofline_network(&layers, &mapped, &hw);
        assert!(v[0] > 0.0);
        assert_eq!(v[1], 0.0);
    }
}
