//! Table and figure renderers: markdown tables matching the paper's rows
//! and CSV series for the figures. Benches write both to stdout and to
//! `target/reports/`.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::Result;

/// A simple column-aligned markdown table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells (one `Vec` per row).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with `headers`.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render as a column-aligned markdown table.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "### {}", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut l = String::from("|");
            for i in 0..ncol {
                let _ = write!(l, " {:<width$} |", cells[i], width = w[i]);
            }
            l
        };
        let _ = writeln!(s, "{}", line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<width$}|", "", width = w + 2);
        }
        let _ = writeln!(s, "{sep}");
        for r in &self.rows {
            let _ = writeln!(s, "{}", line(r, &widths));
        }
        s
    }

    /// Print to stdout and persist under `target/reports/<stem>.md`.
    pub fn emit(&self, stem: &str) -> Result<()> {
        let md = self.to_markdown();
        println!("{md}");
        let path = reports_dir().join(format!("{stem}.md"));
        std::fs::write(&path, md)?;
        Ok(())
    }
}

/// CSV series writer for figure data.
pub struct Csv {
    path: PathBuf,
    buf: String,
}

impl Csv {
    /// A CSV report named `<stem>.csv` under the reports directory.
    pub fn new(stem: &str, headers: &[&str]) -> Self {
        let mut buf = String::new();
        let _ = writeln!(buf, "{}", headers.join(","));
        Self { path: reports_dir().join(format!("{stem}.csv")), buf }
    }

    /// Append one row.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        let _ = writeln!(self.buf, "{}", cells.join(","));
        self
    }

    /// Write the file and return its path.
    pub fn finish(self) -> Result<PathBuf> {
        std::fs::write(&self.path, self.buf)?;
        Ok(self.path)
    }
}

/// Render an [`crate::obs::ObsSnapshot`]'s span summaries as a profile
/// table: top span names by total time, with count / total / self / p50 /
/// p95 / max columns. Printed by `estimate --profile` and the perf bench.
pub fn profile(snap: &crate::obs::ObsSnapshot) -> Table {
    let dur = |ns: u64| crate::bench_harness::fmt_dur(std::time::Duration::from_nanos(ns));
    let mut spans = snap.spans.clone();
    spans.sort_by(|a, b| {
        b.summary.total_ns.cmp(&a.summary.total_ns).then(a.name.cmp(b.name))
    });
    let mut t = Table::new(
        format!(
            "profile: {} spans, {} events ({} dropped)",
            spans.len(),
            snap.events_recorded,
            snap.events_dropped
        ),
        &["span", "count", "total", "self", "p50", "p95", "max"],
    );
    for s in &spans {
        t.row(&[
            s.name.to_string(),
            s.summary.count.to_string(),
            dur(s.summary.total_ns),
            dur(s.summary.self_ns),
            dur(s.summary.p50_ns),
            dur(s.summary.p95_ns),
            dur(s.summary.max_ns),
        ]);
    }
    t
}

/// `target/reports/`, created on demand.
pub fn reports_dir() -> PathBuf {
    let p = Path::new("target").join("reports");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Thousands-separated cycle counts (matches the paper's table style).
pub fn fmt_cycles(c: u64) -> String {
    let s = c.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(' ');
        }
        out.push(ch);
    }
    out
}

/// Format a percentage with two decimals.
pub fn fmt_pct(v: f64) -> String {
    format!("{v:.2}%")
}

/// Human-readable byte count (GiB/MiB/KiB).
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_is_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines[1].len(), lines[2].len());
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_cycles(22484), "22 484");
        assert_eq!(fmt_cycles(7), "7");
        assert_eq!(fmt_cycles(1_866_213_921), "1 866 213 921");
        assert_eq!(fmt_pct(7.5), "7.50%");
        assert_eq!(fmt_bytes(146 * 1024 * 1024), "146.00 MiB");
        assert_eq!(fmt_bytes(512), "512 B");
    }

    #[test]
    fn profile_table_sorts_by_total_time() {
        use crate::obs::{HistSummary, ObsSnapshot, SpanSummary};
        let mk = |name, total_ns| SpanSummary {
            name,
            summary: HistSummary {
                count: 2,
                total_ns,
                self_ns: total_ns / 2,
                max_ns: total_ns,
                p50_ns: total_ns / 2,
                p95_ns: total_ns,
            },
        };
        let snap = ObsSnapshot {
            enabled: true,
            events_recorded: 4,
            events_dropped: 0,
            counters: vec![],
            gauges: vec![],
            spans: vec![mk("small.span", 1_000), mk("big.span", 2_000_000)],
        };
        let t = profile(&snap);
        assert_eq!(t.headers[0], "span");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "big.span", "largest total first");
        assert_eq!(t.rows[1][0], "small.span");
        assert_eq!(t.rows[0][1], "2");
        let md = t.to_markdown();
        assert!(md.contains("4 events"));
    }

    #[test]
    fn csv_writes() {
        let mut c = Csv::new("test_csv", &["x", "y"]);
        c.row(&["1".into(), "2".into()]);
        let p = c.finish().unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(s, "x,y\n1,2\n");
    }
}
