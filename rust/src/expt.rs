//! Experiment drivers shared by the `benches/` reproduction targets: the
//! estimator-comparison harness behind Tables 1–4 and the systolic sweep
//! behind Table 5 / Figs. 12, 16, 17 / Tables 6–7.

use std::sync::Arc;
use std::time::Duration;

use crate::accel::{Systolic, SystolicConfig};
use crate::aidg::{estimate_layer, evaluate_whole, FixedPointConfig, IterStat, Provenance};
use crate::baselines::roofline_network;
use crate::coordinator::EstimateStats;
use crate::dnn::Network;
use crate::engine::{ArchDigest, EstimationEngine};
use crate::mapping::{scalar::ScalarMapper, MappedLayer, Mapper};
use crate::metrics::{mape, percentage_error};
use crate::report::{fmt_cycles, Table};
use crate::{sim, Result};

/// Per-estimator outcome of a comparison run.
#[derive(Debug, Clone)]
pub struct EstimatorResult {
    /// Estimator display name.
    pub name: String,
    /// Wall time the estimator took.
    pub runtime: Duration,
    /// Per-layer cycles (fused layers 0).
    pub layers: Vec<f64>,
}

impl EstimatorResult {
    /// Whole-network cycles (sum of per-layer cycles).
    pub fn total(&self) -> f64 {
        self.layers.iter().sum()
    }
}

/// A full Tables-1–4-style comparison on one architecture + network:
/// AIDG fixed point, refined roofline, optional simplex-fitted
/// Timeloop-like model, and the DES ground truth.
pub struct Comparison {
    /// Workload name.
    pub network: String,
    /// Architecture name.
    pub arch: String,
    /// AIDG fixed-point estimator result.
    pub aidg: EstimatorResult,
    /// Refined-roofline baseline result.
    pub roofline: EstimatorResult,
    /// Simplex-fitted Timeloop-like baseline (Gemmini tables only).
    pub timeloop: Option<EstimatorResult>,
    /// DES ground truth.
    pub des: EstimatorResult,
    /// Iterations the fixed-point estimator actually evaluated.
    pub evaluated_iters: u64,
    /// Total loop iterations across all kernels.
    pub total_iters: u64,
    /// Total instructions across all kernels.
    pub total_insts: u64,
    /// Engine-level kernel accounting of the AIDG pass (unique vs total
    /// kernels, cache reuse within this comparison).
    pub estimate_stats: EstimateStats,
}

impl Comparison {
    /// Run the full comparison on one mapped network: AIDG through a
    /// fresh private engine, refined roofline, DES ground truth, and (when
    /// `timeloop_dim` is set) the simplex-fitted Timeloop-like model.
    pub fn run(
        mapper: &(impl Mapper + ?Sized),
        net: &Network,
        mapped: &[MappedLayer],
        timeloop_dim: Option<u32>,
    ) -> Result<Self> {
        // AIDG fixed point, through a fresh (cold) engine: repeated kernel
        // shapes across the network's layers are evaluated once and reused,
        // while the reported runtime stays a faithful cold-start number
        // (sharing the global engine would let earlier runs warm the cache
        // and distort the paper tables' runtime column).
        let fp = FixedPointConfig::default();
        // capacity 16× the kernel count: the cache is sharded 16 ways with
        // per-shard bounds, so this guarantees no eviction mid-comparison
        // (every distinct kernel is evaluated exactly once)
        let total_kernels: usize = mapped.iter().map(|m| m.kernels.len()).sum();
        let engine = EstimationEngine::new(16 * total_kernels.max(1));
        let digest = ArchDigest::of(mapper.diagram());
        let t0 = std::time::Instant::now();
        let mut aidg_layers = Vec::with_capacity(mapped.len());
        let mut evaluated = 0;
        let mut total_iters = 0;
        let mut total_insts = 0;
        let mut estimate_stats = EstimateStats::default();
        for ml in mapped {
            if ml.fused {
                aidg_layers.push(0.0);
                continue;
            }
            let mut cycles = 0;
            for e in engine.estimate_kernels(mapper.diagram(), digest, &ml.kernels, &fp)? {
                cycles += e.cycles;
                // reused estimates count like the serial reference path
                // counted them (per kernel slot), keeping the paper tables'
                // "evaluated iterations" column comparable across PRs
                evaluated += e.evaluated_iters;
                total_iters += e.k;
                total_insts += e.total_insts();
                // the engine is private to this comparison, so a cache hit
                // here is cross-*layer* reuse within one request — account
                // it as dedup, matching `EstimateStats`' field definitions
                estimate_stats.count(match e.provenance {
                    Provenance::CacheHit => Provenance::Deduped,
                    p => p,
                });
            }
            aidg_layers.push(cycles as f64);
        }
        // fresh engine: every distinct key was evaluated exactly once
        estimate_stats.unique_kernels = estimate_stats.evaluated;
        let aidg = EstimatorResult {
            name: "AIDG fixed point".into(),
            runtime: t0.elapsed(),
            layers: aidg_layers,
        };

        // refined roofline (native mirror of the AOT XLA estimator)
        let t1 = std::time::Instant::now();
        let roof = roofline_network(&net.layers, mapped, &mapper.hw_features());
        let roofline = EstimatorResult {
            name: "Refined roofline [28]".into(),
            runtime: t1.elapsed(),
            layers: roof,
        };

        // DES ground truth
        let t2 = std::time::Instant::now();
        let mut des_layers = Vec::with_capacity(mapped.len());
        for ml in mapped {
            if ml.fused {
                des_layers.push(0.0);
            } else {
                des_layers
                    .push(sim::simulate_layer(mapper.diagram(), &ml.kernels)?.cycles as f64);
            }
        }
        let des = EstimatorResult {
            name: "DES (RTL stand-in)".into(),
            runtime: t2.elapsed(),
            layers: des_layers.clone(),
        };

        // Timeloop-like with simplex-fitted bandwidths (paper §7.2)
        let timeloop = match timeloop_dim {
            Some(dim) => {
                let t3 = std::time::Instant::now();
                let model = crate::baselines::fit_bandwidths(dim, &net.layers, &des_layers)?;
                Some(EstimatorResult {
                    name: "Timeloop-like [21]".into(),
                    runtime: t3.elapsed(),
                    layers: model.network_cycles(&net.layers),
                })
            }
            None => None,
        };

        Ok(Self {
            network: net.name.clone(),
            arch: mapper.diagram().name.clone(),
            aidg,
            roofline,
            timeloop,
            des,
            evaluated_iters: evaluated,
            total_iters,
            total_insts,
            estimate_stats,
        })
    }

    /// Render the paper-style comparison table.
    pub fn table(&self, title: &str) -> Table {
        let des_total = self.des.total();
        let mut t =
            Table::new(title, &["estimator", "runtime", "estimated cycles", "PE", "MAPE"]);
        let mut push = |r: &EstimatorResult| {
            t.row(&[
                r.name.clone(),
                crate::bench_harness::fmt_dur(r.runtime),
                fmt_cycles(r.total() as u64),
                format!("{:.2}%", percentage_error(r.total(), des_total)),
                format!("{:.2}%", mape(&self.des.layers, &r.layers)),
            ]);
        };
        push(&self.aidg);
        push(&self.roofline);
        if let Some(tl) = &self.timeloop {
            push(tl);
        }
        t.row(&[
            "Regression model [5]".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{:.2}%", crate::baselines::BOUZIDI_SVR_MAPE),
        ]);
        t.row(&[
            self.des.name.clone(),
            crate::bench_harness::fmt_dur(self.des.runtime),
            fmt_cycles(des_total as u64),
            "ground truth".into(),
            "".into(),
        ]);
        t
    }
}

/// One layer's outcome within a systolic sweep (Table 5 / Table 6 data).
#[derive(Debug, Clone)]
pub struct SweepLayer {
    /// Layer name.
    pub name: String,
    /// True when the layer was fused into its predecessor (zero cycles).
    pub fused: bool,
    /// Fixed-point estimated cycles.
    pub est_cycles: u64,
    /// Whole-graph evaluated cycles (the measured column).
    pub whole_cycles: u64,
    /// Refined-roofline cycles.
    pub roofline_cycles: f64,
    /// Iterations the fixed-point run evaluated.
    pub evaluated_iters: u64,
    /// Total loop iterations.
    pub total_iters: u64,
    /// Total instructions.
    pub total_insts: u64,
    /// True when the 1 % fallback heuristic was used.
    pub used_fallback: bool,
    /// Peak tracked evaluator state (bytes).
    pub peak_state_bytes: u64,
    /// Per-iteration traces of the *whole-graph* run per kernel (for the
    /// Δt_iteration/Δt_overlap variance analyses), when requested.
    pub traces: Vec<Vec<IterStat>>,
    /// Iteration index at which the fixed-point evaluation stopped, per
    /// kernel (k_stop of Appendix A.2).
    pub k_stops: Vec<u64>,
}

/// Sweep result for one (array size, network) pair.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Array rows.
    pub rows: u32,
    /// Array columns.
    pub cols: u32,
    /// Workload name.
    pub network: String,
    /// Per-layer outcomes.
    pub layers: Vec<SweepLayer>,
    /// Cumulative fixed-point estimation wall time.
    pub fp_runtime: Duration,
    /// Cumulative whole-graph evaluation wall time.
    pub whole_runtime: Duration,
}

impl SweepPoint {
    /// Whole-network fixed-point cycles.
    pub fn total_est(&self) -> u64 {
        self.layers.iter().map(|l| l.est_cycles).sum()
    }

    /// Whole-network whole-graph cycles (the measured total).
    pub fn total_whole(&self) -> u64 {
        self.layers.iter().map(|l| l.whole_cycles).sum()
    }

    /// Whole-network refined-roofline cycles.
    pub fn total_roofline(&self) -> f64 {
        self.layers.iter().map(|l| l.roofline_cycles).sum()
    }

    /// Iterations evaluated across all layers.
    pub fn evaluated_iters(&self) -> u64 {
        self.layers.iter().map(|l| l.evaluated_iters).sum()
    }

    /// Total loop iterations across all layers.
    pub fn total_iters(&self) -> u64 {
        self.layers.iter().map(|l| l.total_iters).sum()
    }

    /// Total instructions across all layers.
    pub fn total_insts(&self) -> u64 {
        self.layers.iter().map(|l| l.total_insts).sum()
    }

    /// MAPE of the fixed-point estimate against whole-graph (eq. 16).
    pub fn mape_est(&self) -> f64 {
        let meas: Vec<f64> = self.layers.iter().map(|l| l.whole_cycles as f64).collect();
        let est: Vec<f64> = self.layers.iter().map(|l| l.est_cycles as f64).collect();
        mape(&meas, &est)
    }

    /// MAPE of the roofline estimate against whole-graph.
    pub fn mape_roofline(&self) -> f64 {
        let meas: Vec<f64> = self.layers.iter().map(|l| l.whole_cycles as f64).collect();
        let est: Vec<f64> = self.layers.iter().map(|l| l.roofline_cycles).collect();
        mape(&meas, &est)
    }

    /// Whole-network percentage error of the fixed-point estimate (eq. 15).
    pub fn pe_est(&self) -> f64 {
        percentage_error(self.total_est() as f64, self.total_whole() as f64)
    }

    /// Whole-network percentage error of the roofline estimate.
    pub fn pe_roofline(&self) -> f64 {
        percentage_error(self.total_roofline(), self.total_whole() as f64)
    }

    /// Fraction of (non-fused) layers estimated with the fallback heuristic.
    pub fn fallback_pct(&self) -> f64 {
        let n = self.layers.iter().filter(|l| !l.fused).count();
        if n == 0 {
            return 0.0;
        }
        let f = self.layers.iter().filter(|l| !l.fused && l.used_fallback).count();
        f as f64 / n as f64 * 100.0
    }
}

/// Run one systolic sweep point: AIDG fixed point + whole-graph ground
/// truth (the paper's Table 5 methodology: the whole-graph AIDG evaluation
/// *is* the measured-cycles column) + refined roofline. `keep_traces`
/// retains per-iteration whole-graph traces for the variance analyses.
pub fn systolic_sweep_point(
    rows: u32,
    cols: u32,
    net: &Network,
    keep_traces: bool,
) -> Result<SweepPoint> {
    let sys = Arc::new(Systolic::new(SystolicConfig::new(rows, cols))?);
    let mapper = ScalarMapper::new(sys);
    let mapped = mapper.map_network(net)?;
    let hw = mapper.hw_features();
    let fp = FixedPointConfig::default();
    let mut layers = Vec::with_capacity(mapped.len());
    let mut fp_runtime = Duration::ZERO;
    let mut whole_runtime = Duration::ZERO;
    for (layer, ml) in net.layers.iter().zip(&mapped) {
        if ml.fused {
            layers.push(SweepLayer {
                name: ml.layer_name.clone(),
                fused: true,
                est_cycles: 0,
                whole_cycles: 0,
                roofline_cycles: 0.0,
                evaluated_iters: 0,
                total_iters: 0,
                total_insts: 0,
                used_fallback: false,
                peak_state_bytes: 0,
                traces: Vec::new(),
                k_stops: Vec::new(),
            });
            continue;
        }
        let mut sl = SweepLayer {
            name: ml.layer_name.clone(),
            fused: false,
            est_cycles: 0,
            whole_cycles: 0,
            roofline_cycles: roofline_network(
                std::slice::from_ref(layer),
                std::slice::from_ref(ml),
                &hw,
            )[0],
            evaluated_iters: 0,
            total_iters: 0,
            total_insts: 0,
            used_fallback: false,
            peak_state_bytes: 0,
            traces: Vec::new(),
            k_stops: Vec::new(),
        };
        for kern in &ml.kernels {
            let e = estimate_layer(mapper.diagram(), kern, &fp)?;
            fp_runtime += e.runtime;
            sl.est_cycles += e.cycles;
            sl.evaluated_iters += e.evaluated_iters;
            sl.total_iters += e.k;
            sl.total_insts += e.total_insts();
            sl.used_fallback |= e.used_fallback;
            sl.peak_state_bytes = sl.peak_state_bytes.max(e.peak_state_bytes);
            sl.k_stops.push(e.evaluated_iters);

            if keep_traces {
                let mut ev = crate::aidg::Evaluator::new(mapper.diagram());
                let t0 = std::time::Instant::now();
                ev.run(kern, 0..kern.k)?;
                whole_runtime += t0.elapsed();
                sl.whole_cycles += ev.dt_aidg();
                sl.traces.push(ev.iter_stats);
            } else {
                let w = evaluate_whole(mapper.diagram(), kern)?;
                whole_runtime += w.runtime;
                sl.whole_cycles += w.cycles;
            }
        }
        layers.push(sl);
    }
    Ok(SweepPoint {
        rows,
        cols,
        network: net.name.clone(),
        layers,
        fp_runtime,
        whole_runtime,
    })
}

/// Δt_iteration series of a per-iteration trace (eq. 4 per iteration).
pub fn dt_iteration_series(trace: &[IterStat]) -> Vec<f64> {
    trace.iter().map(|s| s.span() as f64).collect()
}

/// Δt_overlap series (Fig. 9 semantics between consecutive iterations).
pub fn dt_overlap_series(trace: &[IterStat]) -> Vec<f64> {
    trace
        .windows(2)
        .map(|w| w[0].max_leave as f64 - w[1].min_enter as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::zoo;

    #[test]
    fn sweep_point_consistency() {
        let net = zoo::tc_resnet8();
        let p = systolic_sweep_point(2, 2, &net, false).unwrap();
        // fixed point matches whole graph on the 2×2 array (Table 5 row 1)
        assert_eq!(p.total_est(), p.total_whole());
        assert!(p.evaluated_iters() < p.total_iters() / 100);
        assert!(p.mape_est() < 0.5, "mape {}", p.mape_est());
    }

    #[test]
    fn comparison_runs_on_ultratrail() {
        use crate::accel::{UltraTrail, UltraTrailConfig};
        use crate::mapping::tensor_op::TensorOpMapper;
        let net = zoo::tc_resnet8();
        let mapper =
            TensorOpMapper::new(Arc::new(UltraTrail::new(UltraTrailConfig::default()).unwrap()));
        let mapped = mapper.map_network(&net).unwrap();
        let c = Comparison::run(&mapper, &net, &mapped, None).unwrap();
        // AIDG within a couple percent of the DES
        let pe = percentage_error(c.aidg.total(), c.des.total()).abs();
        assert!(pe < 2.0, "PE {pe}");
        let t = c.table("test");
        assert!(t.to_markdown().contains("AIDG"));
        // engine accounting is consistent and saw every kernel slot
        let s = &c.estimate_stats;
        assert_eq!(s.total_kernels, s.evaluated + s.cache_hits + s.deduped, "{s:?}");
        assert!(s.total_kernels > 0 && s.unique_kernels <= s.total_kernels, "{s:?}");
    }

    #[test]
    fn trace_series_shapes() {
        let net = zoo::tc_resnet8();
        let small: Network = Network {
            name: "mini".into(),
            layers: net.layers[..2].to_vec(),
        };
        let p = systolic_sweep_point(2, 2, &small, true).unwrap();
        let l = &p.layers[0];
        assert!(!l.traces.is_empty());
        let dt = dt_iteration_series(&l.traces[0]);
        let ov = dt_overlap_series(&l.traces[0]);
        assert_eq!(dt.len(), ov.len() + 1);
    }
}
