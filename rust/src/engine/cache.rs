//! The sharded, LRU-bounded, concurrent estimate cache.
//!
//! Keys are [`KernelKey`]s (content-addressed — see [`super::key`]); values
//! are `Arc<LayerEstimate>`s with `trace: None` (trace-carrying requests
//! bypass the cache entirely), so each entry is a few hundred bytes.
//! Shard count is fixed at construction; capacity is a soft total bound
//! enforced per shard (`ceil(cap / shards)`, minimum 1), so the real bound
//! is `capacity` rounded up to shard granularity. Eviction is
//! least-recently-used within the shard, driven by a global monotonic tick.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::aidg::LayerEstimate;

use super::key::KernelKey;

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Live entries.
    pub entries: usize,
    /// Entry bound (0 = caching disabled).
    pub capacity: usize,
    /// Lookup hits.
    pub hits: u64,
    /// Lookup misses.
    pub misses: u64,
    /// Insertions.
    pub inserts: u64,
    /// Entries evicted by the LRU bound.
    pub evictions: u64,
}

struct Entry {
    est: Arc<LayerEstimate>,
    last_used: u64,
}

/// Concurrent LRU cache of layer estimates.
pub struct EstimateCache {
    shards: Vec<Mutex<HashMap<KernelKey, Entry>>>,
    capacity: AtomicUsize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
    /// Publish per-shard occupancy to [`crate::obs::gauge`] after every
    /// mutating operation. Off by default: the gauge registry is
    /// process-global, so only one cache (the global engine's) should own
    /// it.
    gauged: AtomicBool,
}

impl EstimateCache {
    /// Create a cache bounded to ~`capacity` entries. `capacity == 0`
    /// disables caching (gets always miss, inserts are dropped) while
    /// keeping intra-request deduplication in the engine intact.
    pub fn new(capacity: usize) -> Self {
        const SHARDS: usize = 16;
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            capacity: AtomicUsize::new(capacity),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            gauged: AtomicBool::new(false),
        }
    }

    /// Start publishing this cache's per-shard occupancy to the
    /// process-global [`crate::obs::gauge`] registry. Call on at most one
    /// cache per process (the global engine enables it for its own).
    pub fn enable_gauges(&self) {
        self.gauged.store(true, Ordering::Relaxed);
        for (i, shard) in self.shards.iter().enumerate() {
            crate::obs::gauge::set_cache_shard(i, shard.lock().unwrap().len());
        }
    }

    #[inline]
    fn publish_shard(&self, idx: usize, len: usize) {
        if self.gauged.load(Ordering::Relaxed) {
            crate::obs::gauge::set_cache_shard(idx, len);
        }
    }

    fn per_shard_cap(&self) -> usize {
        self.capacity.load(Ordering::Relaxed).div_ceil(self.shards.len())
    }

    /// Look up an estimate, refreshing its recency on a hit.
    pub fn get(&self, key: &KernelKey) -> Option<Arc<LayerEstimate>> {
        let mut shard = self.shards[key.shard_of(self.shards.len())].lock().unwrap();
        match shard.get_mut(key) {
            Some(e) => {
                e.last_used = self.tick.fetch_add(1, Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.est))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) an estimate, evicting LRU entries past the
    /// shard's capacity share.
    pub fn insert(&self, key: KernelKey, est: Arc<LayerEstimate>) {
        let cap = self.per_shard_cap();
        if cap == 0 {
            return;
        }
        let idx = key.shard_of(self.shards.len());
        let mut shard = self.shards[idx].lock().unwrap();
        let last_used = self.tick.fetch_add(1, Ordering::Relaxed);
        shard.insert(key, Entry { est, last_used });
        self.inserts.fetch_add(1, Ordering::Relaxed);
        Self::trim(&mut shard, cap, &self.evictions);
        let len = shard.len();
        drop(shard);
        self.publish_shard(idx, len);
    }

    fn trim(shard: &mut HashMap<KernelKey, Entry>, cap: usize, evictions: &AtomicU64) {
        while shard.len() > cap.max(1) {
            // O(shard len) scan; shards hold `cap/16` entries and eviction
            // only fires on insert past capacity, so this stays cheap
            // relative to a single kernel evaluation.
            let lru = shard
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("non-empty shard");
            shard.remove(&lru);
            evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Adjust the capacity bound, trimming immediately if it shrank.
    pub fn set_capacity(&self, capacity: usize) {
        self.capacity.store(capacity, Ordering::Relaxed);
        let cap = self.per_shard_cap();
        for (idx, shard) in self.shards.iter().enumerate() {
            let mut shard = shard.lock().unwrap();
            if cap == 0 {
                let n = shard.len() as u64;
                shard.clear();
                self.evictions.fetch_add(n, Ordering::Relaxed);
            } else {
                Self::trim(&mut shard, cap, &self.evictions);
            }
            let len = shard.len();
            drop(shard);
            self.publish_shard(idx, len);
        }
    }

    /// Current entry bound.
    pub fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Relaxed)
    }

    /// Live entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when no entry is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (tests; memory pressure).
    pub fn clear(&self) {
        for (idx, shard) in self.shards.iter().enumerate() {
            shard.lock().unwrap().clear();
            self.publish_shard(idx, 0);
        }
    }

    /// Every live entry, in no particular order. Used to backfill a
    /// newly attached [`super::store::EstimateStore`] with the warm state
    /// already in memory; not a hot-path operation.
    pub fn snapshot_entries(&self) -> Vec<(KernelKey, Arc<LayerEstimate>)> {
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            out.extend(shard.iter().map(|(k, e)| (*k, Arc::clone(&e.est))));
        }
        out
    }

    /// Point-in-time statistics snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            entries: self.len(),
            capacity: self.capacity(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aidg::Provenance;

    fn key(n: u64) -> KernelKey {
        KernelKey { arch: 1, kernel_hi: n, kernel_lo: n.wrapping_mul(0x9E37), fp_bits: 0 }
    }

    fn est(cycles: u64) -> Arc<LayerEstimate> {
        Arc::new(LayerEstimate {
            label: "t".into(),
            k: 1,
            insts_per_iter: 1,
            cycles,
            evaluated_iters: 1,
            k_block: 1,
            k_prolog: 1,
            dt_iteration: 0,
            dt_overlap: 0,
            used_fallback: false,
            whole_graph: true,
            nodes: 1,
            peak_state_bytes: 0,
            runtime: std::time::Duration::ZERO,
            provenance: Provenance::Computed,
            trace: None,
            calibrated_cycles: None,
            ci_lo: None,
            ci_hi: None,
        })
    }

    #[test]
    fn get_insert_roundtrip_and_counters() {
        let c = EstimateCache::new(64);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), est(42));
        assert_eq!(c.get(&key(1)).unwrap().cycles, 42);
        assert!(c.get(&key(2)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.inserts, s.entries), (1, 2, 1, 1));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        // single-shard-sized view: capacity 16 -> 1 per shard; find two keys
        // landing in the same shard and verify the untouched one is evicted
        let c = EstimateCache::new(16);
        let shard_of = |n: u64| key(n).shard_of(16);
        let a = key(1);
        let b = (2..200).map(key).find(|k| k.shard_of(16) == shard_of(1)).unwrap();
        c.insert(a, est(1));
        c.insert(b, est(2));
        assert_eq!(c.len(), 1, "same shard, cap 1 -> evicted down to 1");
        assert!(c.stats().evictions >= 1);
        // the more recent insert survives
        assert_eq!(c.get(&b).unwrap().cycles, 2);
        assert!(c.get(&a).is_none());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = EstimateCache::new(0);
        c.insert(key(1), est(1));
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn set_capacity_trims() {
        let c = EstimateCache::new(1 << 16);
        for n in 0..256 {
            c.insert(key(n), est(n));
        }
        assert_eq!(c.len(), 256);
        c.set_capacity(16);
        assert!(c.len() <= 16, "len {} after shrink", c.len());
        c.set_capacity(0);
        assert_eq!(c.len(), 0);
    }
}
