//! The unified estimation engine — the one hot path every estimation
//! request routes through.
//!
//! The paper's headline trick deduplicates work in *time* (evaluate a few
//! loop iterations, extrapolate to billions of instructions). This module
//! deduplicates the remaining work in *space*: real networks repeat
//! identical kernel shapes across layers (residual blocks), and DSE sweeps
//! or serve fleets re-price the same `(architecture, kernel)` pair
//! thousands of times. The engine
//!
//! 1. fingerprints every kernel with a content-addressed [`KernelKey`]
//!    (architecture structural digest × kernel decision-prefix hash ×
//!    fixed-point config — see [`key`] for why key equality implies
//!    cycle-identical estimates),
//! 2. plans a network estimate as a deduplicated set of kernel work items,
//! 3. consults the sharded, LRU-bounded [`EstimateCache`] before
//!    evaluating anything,
//! 4. fans cache misses out at *kernel* granularity over the generic
//!    [`Pool`](crate::coordinator::Pool) (one large request no longer pins
//!    a single worker), and
//! 5. reassembles per-layer/network results with hit/miss/dedup counters
//!    ([`crate::coordinator::EstimateStats`], mirrored into
//!    [`crate::metrics::counters`]).
//!
//! The uncached reference path ([`crate::coordinator::estimate_network`])
//! stays available; `rust/tests/engine_cache.rs` pins the engine
//! cycle-identical to it, cold and warm, across all four paper
//! architectures. Requests with `keep_trace` set bypass the cache (traces
//! are large and per-request) but keep working.

pub mod cache;
pub mod key;
pub mod store;

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::time::Instant;

use crate::acadl::Diagram;
use crate::aidg::{
    estimate_layer, estimate_layer_batch, FixedPointConfig, LayerEstimate, Provenance,
};
use crate::calib::CalibrationModel;
use crate::coordinator::job::{Arch, EstimateStats, LayerOutcome, NetworkEstimate};
use crate::coordinator::pool::Pool;
use crate::dnn::Network;
use crate::isa::LoopKernel;
use crate::mapping::Mapper;
use crate::Result;

pub use cache::{CacheStats, EstimateCache};
pub use key::{decision_prefix, kernel_key, ArchDigest, KernelKey};
pub use store::{EstimateStore, GcOutcome, StoreStats};

/// Default entry bound of the global engine's cache (`--cache-cap`
/// overrides; entries are a few hundred bytes each).
pub const DEFAULT_CACHE_CAP: usize = 4096;

/// Point-in-time engine statistics (cache state + request counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Cache state.
    pub cache: CacheStats,
    /// Network estimates served.
    pub requests: u64,
    /// Kernel slots seen across all requests.
    pub kernels_total: u64,
    /// Kernels actually evaluated through the AIDG.
    pub kernels_evaluated: u64,
    /// Kernel slots reused from an identical kernel in the same request.
    pub kernels_deduped: u64,
}

/// The shared estimation engine. Cheap to share (`&'static` via
/// [`EstimationEngine::global`] or `Arc`); all methods take `&self` and are
/// safe to call from many threads at once.
pub struct EstimationEngine {
    cache: EstimateCache,
    /// Optional persistent store layered *under* the cache: a cache miss
    /// probes the store and promotes hits back into memory; evaluated
    /// kernels are written through. `None` (the default) keeps the engine
    /// purely in-memory.
    store: RwLock<Option<Arc<EstimateStore>>>,
    /// In-flight single-flight table: one entry per kernel currently being
    /// evaluated on behalf of concurrent identical requests.
    inflight: Mutex<HashMap<KernelKey, Arc<Flight>>>,
    /// Optional calibration model applied as a post-pass on every resolved
    /// estimate (never on the cached `Arc`s themselves — with calibration
    /// off, results stay bit-identical to an engine that never saw a
    /// model).
    calibration: RwLock<Option<Arc<CalibrationModel>>>,
    requests: AtomicU64,
    kernels_total: AtomicU64,
    kernels_evaluated: AtomicU64,
    kernels_deduped: AtomicU64,
}

/// One in-flight kernel evaluation that concurrent identical requests
/// park on. `done` transitions once: `None` → `Some(outcome)`, where a
/// `Some(est)` outcome is the leader's result and `None` means the leader
/// failed (waiters then evaluate for themselves — errors are per-request,
/// not broadcast).
#[derive(Default)]
struct Flight {
    done: Mutex<Option<Option<Arc<LayerEstimate>>>>,
    cv: Condvar,
}

impl EstimationEngine {
    /// An engine with its own cache bounded at `cache_capacity` entries.
    pub fn new(cache_capacity: usize) -> Self {
        Self {
            cache: EstimateCache::new(cache_capacity),
            store: RwLock::new(None),
            inflight: Mutex::new(HashMap::new()),
            calibration: RwLock::new(None),
            requests: AtomicU64::new(0),
            kernels_total: AtomicU64::new(0),
            kernels_evaluated: AtomicU64::new(0),
            kernels_deduped: AtomicU64::new(0),
        }
    }

    /// The process-wide engine used by the coordinator (`run_request`, the
    /// serve loop, the CLI).
    pub fn global() -> &'static EstimationEngine {
        static GLOBAL: OnceLock<EstimationEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
            // the process-global engine owns the process-global cache gauges
            engine.cache.enable_gauges();
            engine
        })
    }

    /// Adjust the cache's entry bound (0 disables cross-request caching;
    /// intra-request deduplication keeps working).
    pub fn set_cache_capacity(&self, capacity: usize) {
        self.cache.set_capacity(capacity);
    }

    /// Drop all cached estimates (tests; memory pressure).
    pub fn clear_cache(&self) {
        self.cache.clear();
    }

    /// Attach (or with `None`, detach) a persistent estimate store. The
    /// store layers *under* the in-memory cache: lookups that miss the
    /// cache probe the store and promote hits, and every evaluated kernel
    /// is written through. On attach, warm in-memory state is backfilled
    /// into the store so a `serve` session's pre-store work is not lost.
    /// Flushing to disk stays the caller's responsibility
    /// ([`EstimateStore::flush`] / [`EstimateStore::flush_if_dirty`]).
    pub fn attach_store(&self, store: Option<Arc<EstimateStore>>) {
        if let Some(s) = &store {
            for (key, est) in self.cache.snapshot_entries() {
                s.put(key, est);
            }
        }
        *self.store.write().unwrap() = store;
    }

    /// The currently attached persistent store, if any.
    pub fn store(&self) -> Option<Arc<EstimateStore>> {
        self.store.read().unwrap().clone()
    }

    /// Cache lookup with store fallback: a cache miss probes the attached
    /// store (if any) and promotes the hit back into memory.
    fn probe(&self, key: &KernelKey) -> Option<Arc<LayerEstimate>> {
        if let Some(a) = self.cache.get(key) {
            return Some(a);
        }
        let store = self.store.read().unwrap().clone()?;
        let a = store.get(key)?;
        self.cache.insert(*key, Arc::clone(&a));
        Some(a)
    }

    /// Record one freshly evaluated kernel in the cache and write it
    /// through to the attached store (if any).
    fn fill(&self, key: KernelKey, est: &Arc<LayerEstimate>) {
        self.cache.insert(key, Arc::clone(est));
        if let Some(s) = self.store.read().unwrap().as_ref() {
            s.put(key, Arc::clone(est));
        }
    }

    /// Evaluate `key` exactly once across concurrent identical requests:
    /// the first caller (the leader) runs `eval` while later callers park
    /// on the in-flight entry and receive the leader's `Arc`. If the
    /// leader fails, its error stays its own — each waiter retries
    /// locally so errors are attributed to the request that hit them.
    fn single_flight<F>(&self, key: KernelKey, eval: F) -> Result<Arc<LayerEstimate>>
    where
        F: FnOnce() -> Result<LayerEstimate>,
    {
        // a racing leader may have landed the result since our caller's
        // cache miss — re-probe before enqueueing any work
        if let Some(a) = self.probe(&key) {
            return Ok(a);
        }
        let existing = {
            let mut map = self.inflight.lock().unwrap();
            match map.get(&key) {
                Some(f) => Some(Arc::clone(f)),
                None => {
                    map.insert(key, Arc::new(Flight::default()));
                    None
                }
            }
        };
        match existing {
            None => {
                // leader: evaluate, publish to cache/store, wake waiters
                let result = eval().map(Arc::new);
                if let Ok(a) = &result {
                    self.fill(key, a);
                }
                let flight = self.inflight.lock().unwrap().remove(&key);
                if let Some(f) = flight {
                    *f.done.lock().unwrap() = Some(result.as_ref().ok().map(Arc::clone));
                    f.cv.notify_all();
                }
                result
            }
            Some(f) => {
                crate::metrics::counters::SERVE_INFLIGHT_WAITS.add(1);
                let mut done = f.done.lock().unwrap();
                while done.is_none() {
                    done = f.cv.wait(done).unwrap();
                }
                match done.as_ref().unwrap() {
                    Some(a) => Ok(Arc::clone(a)),
                    None => {
                        drop(done);
                        let a = Arc::new(eval()?);
                        self.fill(key, &a);
                        Ok(a)
                    }
                }
            }
        }
    }

    /// Install (or with `None`, remove) the calibration model. While a
    /// model is installed every estimate leaving the engine carries
    /// `calibrated_cycles` + `[ci_lo, ci_hi]`; cached entries are never
    /// stamped, so clearing the model restores bit-identical raw output.
    pub fn set_calibration(&self, model: Option<Arc<CalibrationModel>>) {
        *self.calibration.write().unwrap() = model;
    }

    /// The currently installed calibration model, if any.
    pub fn calibration(&self) -> Option<Arc<CalibrationModel>> {
        self.calibration.read().unwrap().clone()
    }

    /// Live cached estimates.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Point-in-time engine statistics.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            cache: self.cache.stats(),
            requests: self.requests.load(Ordering::Relaxed),
            kernels_total: self.kernels_total.load(Ordering::Relaxed),
            kernels_evaluated: self.kernels_evaluated.load(Ordering::Relaxed),
            kernels_deduped: self.kernels_deduped.load(Ordering::Relaxed),
        }
    }

    /// Fold one batch's kernel accounting into the engine's counters and
    /// the process-wide [`crate::metrics::counters`].
    fn note_kernels(&self, stats: &EstimateStats) {
        self.kernels_total.fetch_add(stats.total_kernels, Ordering::Relaxed);
        self.kernels_evaluated.fetch_add(stats.evaluated, Ordering::Relaxed);
        self.kernels_deduped.fetch_add(stats.deduped, Ordering::Relaxed);
        crate::metrics::counters::note_engine_kernels(
            stats.total_kernels,
            stats.evaluated,
            stats.cache_hits,
            stats.deduped,
        );
    }

    fn note_request(&self, stats: &EstimateStats) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        crate::metrics::counters::ENGINE_REQUESTS.add(1);
        self.note_kernels(stats);
    }

    /// Estimate a batch of kernels against one diagram, serially, with
    /// cache + intra-call deduplication. Returned estimates carry the
    /// requesting kernel's label and a [`Provenance`] stamp; counting the
    /// stamps recovers hit/dedup totals. This is the building block
    /// [`crate::expt::Comparison`] and the serial network path share.
    pub fn estimate_kernels(
        &self,
        d: &Diagram,
        arch: ArchDigest,
        kernels: &[LoopKernel],
        fp: &FixedPointConfig,
    ) -> Result<Vec<LayerEstimate>> {
        let calib = self.calibration();
        let mut local: HashMap<KernelKey, Arc<LayerEstimate>> = HashMap::new();
        let mut out = Vec::with_capacity(kernels.len());
        let mut stats = EstimateStats::default();
        for kern in kernels {
            let e = self.resolve_serial(d, arch, kern, fp, calib.as_deref(), &mut local)?;
            stats.count(e.provenance);
            out.push(e);
        }
        // kernel-batch calls are not whole requests, but their kernel work
        // still counts toward the engine's and the process's totals
        self.note_kernels(&stats);
        Ok(out)
    }

    fn resolve_serial(
        &self,
        d: &Diagram,
        arch: ArchDigest,
        kern: &LoopKernel,
        fp: &FixedPointConfig,
        calib: Option<&CalibrationModel>,
        local: &mut HashMap<KernelKey, Arc<LayerEstimate>>,
    ) -> Result<LayerEstimate> {
        let mut sp = crate::obs::span("engine.kernel");
        if fp.keep_trace {
            // traces are per-request artifacts; never cached or reused
            sp.note("trace");
            let mut e = estimate_layer(d, kern, fp)?;
            if let Some(m) = calib {
                m.apply_kernel(d, kern, &mut e);
            }
            return Ok(e);
        }
        let key = kernel_key(arch, d, kern, fp);
        sp.arg("kernel_hi", key.kernel_hi);
        let (est, provenance) = if let Some(a) = local.get(&key) {
            sp.note("dedup");
            (Arc::clone(a), Provenance::Deduped)
        } else if let Some(a) = self.probe(&key) {
            sp.note("hit");
            local.insert(key, Arc::clone(&a));
            (a, Provenance::CacheHit)
        } else {
            sp.note("evaluated");
            let a = self.single_flight(key, || estimate_layer(d, kern, fp))?;
            local.insert(key, Arc::clone(&a));
            (a, Provenance::Computed)
        };
        let mut e = (*est).clone();
        e.label = kern.label.clone();
        e.provenance = provenance;
        // calibration stamps only this request's clone, never the cached Arc
        if let Some(m) = calib {
            m.apply_kernel(d, kern, &mut e);
        }
        Ok(e)
    }

    /// Estimate a whole network serially (map → plan → cache-aware
    /// evaluate → reassemble). Cycle-identical to the uncached
    /// [`crate::coordinator::estimate_network`] reference path.
    ///
    /// ```
    /// use acadl_perf::accel::SystolicConfig;
    /// use acadl_perf::aidg::FixedPointConfig;
    /// use acadl_perf::coordinator::Arch;
    /// use acadl_perf::engine::EstimationEngine;
    ///
    /// let engine = EstimationEngine::new(4096);
    /// let arch = Arch::Systolic(SystolicConfig::new(2, 2));
    /// let net = acadl_perf::dnn::zoo::tc_resnet8();
    /// let fp = FixedPointConfig::default();
    /// let cold = engine.estimate_network(&arch, &net, &fp).unwrap();
    /// assert!(cold.total_cycles() > 0);
    /// // a second run is served entirely from the cache, cycle-identical
    /// let warm = engine.estimate_network(&arch, &net, &fp).unwrap();
    /// assert_eq!(warm.stats.evaluated, 0);
    /// assert_eq!(warm.total_cycles(), cold.total_cycles());
    /// ```
    pub fn estimate_network(
        &self,
        arch: &Arch,
        net: &Network,
        fp: &FixedPointConfig,
    ) -> Result<NetworkEstimate> {
        let mut sp = crate::obs::span("engine.estimate_network");
        let t0 = Instant::now();
        let mapper = arch.mapper()?;
        let d = mapper.diagram();
        let digest = ArchDigest::of(d);
        let mapped = mapper.map_network(net)?;
        let calib = self.calibration();
        let mut local: HashMap<KernelKey, Arc<LayerEstimate>> = HashMap::new();
        let mut stats = EstimateStats::default();
        let mut layers = Vec::with_capacity(mapped.len());
        for ml in &mapped {
            if ml.fused {
                layers.push(LayerOutcome { layer_name: ml.layer_name.clone(), estimate: None });
                continue;
            }
            let mut ests = Vec::with_capacity(ml.kernels.len());
            for kern in &ml.kernels {
                let e = self.resolve_serial(d, digest, kern, fp, calib.as_deref(), &mut local)?;
                stats.count(e.provenance);
                ests.push(e);
            }
            layers.push(LayerOutcome { layer_name: ml.layer_name.clone(), estimate: Some(ests) });
        }
        stats.unique_kernels = if fp.keep_trace {
            stats.total_kernels
        } else {
            local.len() as u64
        };
        sp.arg("kernels", stats.total_kernels);
        sp.arg("evaluated", stats.evaluated);
        self.note_request(&stats);
        Ok(NetworkEstimate {
            network: net.name.clone(),
            arch: d.name.clone(),
            layers,
            runtime: t0.elapsed(),
            stats,
        })
    }

    /// Estimate a whole network with cache misses fanned out at kernel
    /// granularity over `pool`. Produces the same `NetworkEstimate` (same
    /// cycles, same stats) as [`Self::estimate_network`] — only the wall
    /// time differs. Trace-carrying requests fall back to the serial path.
    ///
    /// Must be called from *outside* `pool`'s own workers (the caller
    /// blocks on results; a worker calling in would wait on jobs queued
    /// behind itself). The typed request path (`Pool::run_all` →
    /// `run_request`) uses the serial engine inside workers for exactly
    /// this reason.
    pub fn estimate_network_pooled(
        &self,
        arch: &Arch,
        net: &Network,
        fp: &FixedPointConfig,
        pool: &Pool,
    ) -> Result<NetworkEstimate> {
        if fp.keep_trace {
            return self.estimate_network(arch, net, fp);
        }
        let mut sp = crate::obs::span("engine.estimate_network_pooled");
        let t0 = Instant::now();
        let mapper: Arc<dyn Mapper + Send + Sync> = Arc::from(arch.mapper()?);
        let digest = ArchDigest::of(mapper.diagram());
        let mapped = mapper.map_network(net)?;
        let calib = self.calibration();

        // ---- plan: dedup kernel slots against the cache and each other ----
        enum Slot {
            Cached(Arc<LayerEstimate>),
            /// Index into the pending work-item list.
            Pending(usize),
        }
        struct PlannedLayer {
            name: String,
            /// `None` = fused layer. Each slot carries the kernel's memory
            /// accesses per iteration (0.0 with calibration off), captured
            /// at plan time while the kernel is still in hand.
            slots: Option<Vec<(String, Slot, Provenance, f64)>>,
        }
        let mut stats = EstimateStats::default();
        let mut planned: Vec<PlannedLayer> = Vec::with_capacity(mapped.len());
        let mut pending: Vec<(KernelKey, LoopKernel)> = Vec::new();
        let mut pending_of: HashMap<KernelKey, usize> = HashMap::new();
        // cache hits already resolved in this request (a repeat of one is a
        // Deduped slot, matching the serial path's accounting)
        let mut hit_of: HashMap<KernelKey, Arc<LayerEstimate>> = HashMap::new();
        for ml in mapped {
            if ml.fused {
                planned.push(PlannedLayer { name: ml.layer_name, slots: None });
                continue;
            }
            let mut slots = Vec::with_capacity(ml.kernels.len());
            for kern in ml.kernels {
                let mut psp = crate::obs::span("engine.kernel.plan");
                let key = kernel_key(digest, mapper.diagram(), &kern, fp);
                psp.arg("kernel_hi", key.kernel_hi);
                let label = kern.label.clone();
                let ma = if calib.is_some() {
                    crate::calib::features::mem_accesses_per_iter(&kern)
                } else {
                    0.0
                };
                let (slot, provenance) = if let Some(&i) = pending_of.get(&key) {
                    (Slot::Pending(i), Provenance::Deduped)
                } else if let Some(a) = hit_of.get(&key) {
                    (Slot::Cached(Arc::clone(a)), Provenance::Deduped)
                } else if let Some(a) = self.probe(&key) {
                    hit_of.insert(key, Arc::clone(&a));
                    (Slot::Cached(a), Provenance::CacheHit)
                } else {
                    let i = pending.len();
                    pending_of.insert(key, i);
                    pending.push((key, kern));
                    (Slot::Pending(i), Provenance::Computed)
                };
                psp.note(match provenance {
                    Provenance::Computed => "evaluated",
                    Provenance::CacheHit => "hit",
                    Provenance::Deduped => "dedup",
                });
                stats.count(provenance);
                slots.push((label, slot, provenance, ma));
            }
            planned.push(PlannedLayer { name: ml.layer_name, slots: Some(slots) });
        }
        stats.unique_kernels = (pending_of.len() + hit_of.len()) as u64;

        // ---- evaluate the misses: one pool work item per unique kernel ----
        // Jobs on the *global* engine route through `single_flight`, so N
        // concurrent sessions estimating the same kernel share one
        // evaluation; a closure can only reach an engine from inside a
        // `'static` pool job when the engine itself is `'static`.
        let global: Option<&'static EstimationEngine> =
            std::ptr::eq(self, Self::global()).then(Self::global);
        let n_pending = pending.len();
        let (tx, rx) = channel::<(usize, Result<Arc<LayerEstimate>>)>();
        for (i, (key, kern)) in pending.iter_mut().enumerate() {
            // move the kernel into the worker; the key stays for cache fill
            let kern = std::mem::replace(
                kern,
                LoopKernel::new("<taken>", 0, 0, Box::new(|_, _| {})),
            );
            let key = *key;
            let tx = tx.clone();
            let m = Arc::clone(&mapper);
            let fp = *fp;
            pool.spawn(move || {
                let eval = || {
                    let mut ksp = crate::obs::span("engine.kernel");
                    ksp.arg("kernel_hi", key.kernel_hi);
                    ksp.note("evaluated");
                    estimate_layer(m.diagram(), &kern, &fp)
                };
                let r = match global {
                    Some(engine) => engine.single_flight(key, eval),
                    None => eval().map(Arc::new),
                };
                let _ = tx.send((i, r));
            })?;
        }
        drop(tx);
        let mut results: Vec<Option<Arc<LayerEstimate>>> = (0..n_pending).map(|_| None).collect();
        let mut received = 0usize;
        while received < n_pending {
            let Ok((i, r)) = rx.recv() else { break };
            let est = r?;
            self.fill(pending[i].0, &est);
            results[i] = Some(est);
            received += 1;
        }
        if received < n_pending {
            anyhow::bail!(
                "worker pool hung up after {received}/{n_pending} kernel evaluations \
                 (a worker died or the pool was shut down)"
            );
        }

        // ---- reassemble per-layer outcomes in network order ----
        let mut layers = Vec::with_capacity(planned.len());
        for pl in planned {
            let estimate = match pl.slots {
                None => None,
                Some(slots) => {
                    let mut ests = Vec::with_capacity(slots.len());
                    for (label, slot, provenance, ma) in slots {
                        let arc = match slot {
                            Slot::Cached(a) => a,
                            Slot::Pending(i) => {
                                Arc::clone(results[i].as_ref().expect("all results received"))
                            }
                        };
                        let mut e = (*arc).clone();
                        e.label = label;
                        e.provenance = provenance;
                        if let Some(m) = &calib {
                            m.apply(mapper.diagram(), ma, &mut e);
                        }
                        ests.push(e);
                    }
                    Some(ests)
                }
            };
            layers.push(LayerOutcome { layer_name: pl.name, estimate });
        }
        sp.arg("kernels", stats.total_kernels);
        sp.arg("evaluated", stats.evaluated);
        self.note_request(&stats);
        Ok(NetworkEstimate {
            network: net.name.clone(),
            arch: mapper.diagram().name.clone(),
            layers,
            runtime: t0.elapsed(),
            stats,
        })
    }

    /// Estimate one network against a whole digest group of candidate
    /// architectures at once, driving cache misses through the lane-batched
    /// evaluator ([`crate::aidg::estimate_layer_batch`]): the j-th kernel of
    /// the j-th layer forms one lane group across candidates, sharing a
    /// single iteration-program walk. Results are bit-identical to calling
    /// [`Self::estimate_network_pooled`] per candidate in order (lanes that
    /// diverge inside a group — e.g. a digest-mismatched candidate — are
    /// evicted to the serial path transparently), and the per-candidate
    /// `EstimateStats` match that sequential schedule's accounting.
    ///
    /// Trace-carrying and single-candidate requests fall back to the
    /// per-candidate paths. Like `estimate_network_pooled`, this must be
    /// called from *outside* `pool`'s own workers.
    pub fn estimate_batch(
        &self,
        archs: &[&Arch],
        net: &Network,
        fp: &FixedPointConfig,
        pool: &Pool,
    ) -> Result<Vec<NetworkEstimate>> {
        if archs.is_empty() {
            return Ok(Vec::new());
        }
        if fp.keep_trace || archs.len() == 1 {
            return archs
                .iter()
                .map(|a| self.estimate_network_pooled(a, net, fp, pool))
                .collect();
        }
        let mut sp = crate::obs::span("engine.estimate_batch");
        sp.arg("lanes", archs.len() as u64);
        let t0 = Instant::now();
        let n = archs.len();
        let mut mappers: Vec<Arc<dyn Mapper + Send + Sync>> = Vec::with_capacity(n);
        for a in archs {
            mappers.push(Arc::from(a.mapper()?));
        }
        let digests: Vec<ArchDigest> = mappers.iter().map(|m| ArchDigest::of(m.diagram())).collect();
        let calib = self.calibration();

        // ---- plan all lanes, mirroring the sequential-serial accounting ----
        enum Slot {
            Cached(Arc<LayerEstimate>),
            /// Index into the cross-lane pending work-item list.
            Pending(usize),
        }
        struct PlannedLayer {
            name: String,
            /// `None` = fused layer. Each slot carries the kernel's memory
            /// accesses per iteration (0.0 with calibration off), captured
            /// at plan time while the kernel is still in hand.
            slots: Option<Vec<(String, Slot, Provenance, f64)>>,
        }
        struct PendingEntry {
            key: KernelKey,
            kern: LoopKernel,
            lane: usize,
            /// Mapped-layer position — lanes' j-th kernels of the j-th
            /// layer batch together.
            layer: usize,
            kidx: usize,
        }
        let mut per_lane_planned: Vec<Vec<PlannedLayer>> = Vec::with_capacity(n);
        let mut per_lane_stats: Vec<EstimateStats> = (0..n).map(|_| EstimateStats::default()).collect();
        let mut pending: Vec<PendingEntry> = Vec::new();
        // cross-lane maps: a key pending from (or cache-resolved by) an
        // earlier lane would sit in the cache by the time a sequential
        // schedule reached this lane — count it as a CacheHit here too.
        let mut pending_of: HashMap<KernelKey, usize> = HashMap::new();
        let mut hit_of: HashMap<KernelKey, Arc<LayerEstimate>> = HashMap::new();
        for (lane, m) in mappers.iter().enumerate() {
            let mapped = m.map_network(net)?;
            let mut local_seen: HashSet<KernelKey> = HashSet::new();
            let mut planned: Vec<PlannedLayer> = Vec::with_capacity(mapped.len());
            for (layer, ml) in mapped.into_iter().enumerate() {
                if ml.fused {
                    planned.push(PlannedLayer { name: ml.layer_name, slots: None });
                    continue;
                }
                let mut slots = Vec::with_capacity(ml.kernels.len());
                for (kidx, kern) in ml.kernels.into_iter().enumerate() {
                    let mut psp = crate::obs::span("engine.kernel.plan");
                    let key = kernel_key(digests[lane], m.diagram(), &kern, fp);
                    psp.arg("kernel_hi", key.kernel_hi);
                    let label = kern.label.clone();
                    let ma = if calib.is_some() {
                        crate::calib::features::mem_accesses_per_iter(&kern)
                    } else {
                        0.0
                    };
                    let first_in_lane = local_seen.insert(key);
                    let (slot, provenance) = if !first_in_lane {
                        let slot = if let Some(&i) = pending_of.get(&key) {
                            Slot::Pending(i)
                        } else {
                            Slot::Cached(Arc::clone(&hit_of[&key]))
                        };
                        (slot, Provenance::Deduped)
                    } else if let Some(&i) = pending_of.get(&key) {
                        (Slot::Pending(i), Provenance::CacheHit)
                    } else if let Some(a) = hit_of.get(&key) {
                        (Slot::Cached(Arc::clone(a)), Provenance::CacheHit)
                    } else if let Some(a) = self.probe(&key) {
                        hit_of.insert(key, Arc::clone(&a));
                        (Slot::Cached(a), Provenance::CacheHit)
                    } else {
                        let i = pending.len();
                        pending_of.insert(key, i);
                        pending.push(PendingEntry { key, kern, lane, layer, kidx });
                        (Slot::Pending(i), Provenance::Computed)
                    };
                    psp.note(match provenance {
                        Provenance::Computed => "evaluated",
                        Provenance::CacheHit => "hit",
                        Provenance::Deduped => "dedup",
                    });
                    per_lane_stats[lane].count(provenance);
                    slots.push((label, slot, provenance, ma));
                }
                planned.push(PlannedLayer { name: ml.layer_name, slots: Some(slots) });
            }
            per_lane_stats[lane].unique_kernels = local_seen.len() as u64;
            per_lane_planned.push(planned);
        }

        // ---- group the misses: lanes' matching kernel slots batch together ----
        let mut groups: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
        for (i, pe) in pending.iter().enumerate() {
            // lanes plan in order, so each group's members are lane-ordered
            groups.entry((pe.layer, pe.kidx)).or_default().push(i);
        }
        let n_jobs = groups.len();
        let (tx, rx) = channel::<(Vec<usize>, Result<Vec<LayerEstimate>>)>();
        for (_, idxs) in groups {
            let members: Vec<(Arc<dyn Mapper + Send + Sync>, LoopKernel)> = idxs
                .iter()
                .map(|&i| {
                    let kern = std::mem::replace(
                        &mut pending[i].kern,
                        LoopKernel::new("<taken>", 0, 0, Box::new(|_, _| {})),
                    );
                    (Arc::clone(&mappers[pending[i].lane]), kern)
                })
                .collect();
            let tx = tx.clone();
            let fp = *fp;
            pool.spawn(move || {
                let r = if members.len() == 1 {
                    // singleton group: the plain serial path, no lane setup
                    let mut ksp = crate::obs::span("engine.kernel");
                    ksp.note("evaluated");
                    estimate_layer(members[0].0.diagram(), &members[0].1, &fp).map(|e| vec![e])
                } else {
                    let mut ksp = crate::obs::span("engine.kernel.batch");
                    ksp.arg("lanes", members.len() as u64);
                    let lanes: Vec<(&Diagram, &LoopKernel)> =
                        members.iter().map(|(m, k)| (m.diagram(), k)).collect();
                    estimate_layer_batch(&lanes, &fp).map(|o| o.estimates)
                };
                let _ = tx.send((idxs, r));
            })?;
        }
        drop(tx);
        let mut results: Vec<Option<Arc<LayerEstimate>>> = (0..pending.len()).map(|_| None).collect();
        let mut received = 0usize;
        while received < n_jobs {
            let Ok((idxs, r)) = rx.recv() else { break };
            let ests = r?;
            debug_assert_eq!(ests.len(), idxs.len());
            for (&i, e) in idxs.iter().zip(ests) {
                let est = Arc::new(e);
                // no single-flight here: grouped lane evaluation does not
                // decompose into per-kernel closures, and DSE sweeps run
                // on private engines anyway — probe/fill still give the
                // batch path full store read/write-through
                self.fill(pending[i].key, &est);
                results[i] = Some(est);
            }
            received += 1;
        }
        if received < n_jobs {
            anyhow::bail!(
                "worker pool hung up after {received}/{n_jobs} kernel groups \
                 (a worker died or the pool was shut down)"
            );
        }

        // ---- reassemble per-lane network estimates in input order ----
        let mut out = Vec::with_capacity(n);
        for (lane, planned) in per_lane_planned.into_iter().enumerate() {
            let mut layers = Vec::with_capacity(planned.len());
            for pl in planned {
                let estimate = match pl.slots {
                    None => None,
                    Some(slots) => {
                        let mut ests = Vec::with_capacity(slots.len());
                        for (label, slot, provenance, ma) in slots {
                            let arc = match slot {
                                Slot::Cached(a) => a,
                                Slot::Pending(i) => {
                                    Arc::clone(results[i].as_ref().expect("all results received"))
                                }
                            };
                            let mut e = (*arc).clone();
                            e.label = label;
                            e.provenance = provenance;
                            if let Some(m) = &calib {
                                m.apply(mappers[lane].diagram(), ma, &mut e);
                            }
                            ests.push(e);
                        }
                        Some(ests)
                    }
                };
                layers.push(LayerOutcome { layer_name: pl.name, estimate });
            }
            let stats = per_lane_stats[lane];
            self.note_request(&stats);
            out.push(NetworkEstimate {
                network: net.name.clone(),
                arch: mappers[lane].diagram().name.clone(),
                layers,
                runtime: t0.elapsed(),
                stats,
            });
        }
        sp.arg("evaluated", out.iter().map(|e| e.stats.evaluated).sum::<u64>());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::SystolicConfig;

    #[test]
    fn serial_engine_dedups_within_a_network() {
        let engine = EstimationEngine::new(1 << 10);
        let arch = Arch::Systolic(SystolicConfig::new(2, 2));
        let net = crate::dnn::zoo::tc_resnet8();
        let fp = FixedPointConfig::default();
        let e = engine.estimate_network(&arch, &net, &fp).unwrap();
        // TC-ResNet8 repeats clip-layer shapes inside each residual block
        assert!(
            e.stats.unique_kernels < e.stats.total_kernels,
            "expected dedup: {:?}",
            e.stats
        );
        assert!(e.stats.deduped > 0, "{:?}", e.stats);
        assert_eq!(
            e.stats.evaluated + e.stats.cache_hits + e.stats.deduped,
            e.stats.total_kernels
        );
        // a second run is served entirely from cache, cycle-identical
        let warm = engine.estimate_network(&arch, &net, &fp).unwrap();
        assert_eq!(warm.stats.evaluated, 0, "{:?}", warm.stats);
        assert_eq!(warm.total_cycles(), e.total_cycles());
        assert_eq!(engine.stats().requests, 2);
    }

    #[test]
    fn single_flight_runs_one_evaluation_for_racing_identical_requests() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Barrier;

        let engine = EstimationEngine::new(1 << 10);
        let key = KernelKey { arch: 1, kernel_hi: 2, kernel_lo: 3, fp_bits: 4 };
        let evals = AtomicUsize::new(0);
        let barrier = Barrier::new(8);
        let mut cycles = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        barrier.wait();
                        engine
                            .single_flight(key, || {
                                evals.fetch_add(1, Ordering::SeqCst);
                                // slow evaluation: give every racer time
                                // to park on the in-flight entry
                                std::thread::sleep(std::time::Duration::from_millis(20));
                                Ok(probe_est(4242))
                            })
                            .unwrap()
                            .cycles
                    })
                })
                .collect();
            for h in handles {
                cycles.push(h.join().unwrap());
            }
        });
        assert_eq!(evals.load(Ordering::SeqCst), 1, "exactly one evaluation for 8 racers");
        assert!(cycles.iter().all(|&c| c == 4242));
        assert_eq!(engine.cache_len(), 1);
    }

    #[test]
    fn single_flight_leader_failure_lets_waiters_retry() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Barrier;

        let engine = EstimationEngine::new(1 << 10);
        let key = KernelKey { arch: 9, kernel_hi: 9, kernel_lo: 9, fp_bits: 9 };
        let attempts = AtomicUsize::new(0);
        let barrier = Barrier::new(2);
        std::thread::scope(|s| {
            let results: Vec<_> = (0..2)
                .map(|_| {
                    s.spawn(|| {
                        barrier.wait();
                        engine.single_flight(key, || {
                            let n = attempts.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            if n == 0 {
                                anyhow::bail!("transient failure");
                            }
                            Ok(probe_est(7))
                        })
                    })
                })
                .collect();
            let outcomes: Vec<_> = results.into_iter().map(|h| h.join().unwrap()).collect();
            // whichever thread lost the leader race (or retried after the
            // leader's failure) must still land a correct estimate
            assert!(outcomes.iter().any(|r| r.is_ok()), "{outcomes:?}");
            for r in outcomes.into_iter().flatten() {
                assert_eq!(r.cycles, 7);
            }
        });
    }

    fn probe_est(cycles: u64) -> LayerEstimate {
        LayerEstimate {
            label: "t".into(),
            k: 1,
            insts_per_iter: 1,
            cycles,
            evaluated_iters: 1,
            k_block: 1,
            k_prolog: 1,
            dt_iteration: 0,
            dt_overlap: 0,
            used_fallback: false,
            whole_graph: true,
            nodes: 1,
            peak_state_bytes: 0,
            runtime: std::time::Duration::ZERO,
            provenance: Provenance::Computed,
            trace: None,
            calibrated_cycles: None,
            ci_lo: None,
            ci_hi: None,
        }
    }

    #[test]
    fn store_layers_under_the_cache_with_promote_and_write_through() {
        let dir = std::env::temp_dir()
            .join(format!("acadl-engine-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let arch = Arch::Systolic(SystolicConfig::new(2, 2));
        let net = crate::dnn::zoo::tc_resnet8();
        let fp = FixedPointConfig::default();

        // first engine evaluates everything and writes through
        let e1 = EstimationEngine::new(1 << 10);
        e1.attach_store(Some(EstimateStore::open(&dir).unwrap()));
        let cold = e1.estimate_network(&arch, &net, &fp).unwrap();
        assert!(cold.stats.evaluated > 0);
        e1.store().unwrap().flush().unwrap();

        // second engine (cold cache, same store dir) must evaluate nothing
        let e2 = EstimationEngine::new(1 << 10);
        e2.attach_store(Some(EstimateStore::open(&dir).unwrap()));
        let warm = e2.estimate_network(&arch, &net, &fp).unwrap();
        assert_eq!(warm.stats.evaluated, 0, "store must serve every kernel: {:?}", warm.stats);
        assert_eq!(warm.total_cycles(), cold.total_cycles(), "store path must be bit-identical");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn keep_trace_bypasses_the_cache() {
        let engine = EstimationEngine::new(1 << 10);
        let arch = Arch::Systolic(SystolicConfig::new(2, 2));
        let mut net = crate::dnn::zoo::tc_resnet8();
        net.layers.truncate(2);
        let fp = FixedPointConfig { keep_trace: true, ..Default::default() };
        let e = engine.estimate_network(&arch, &net, &fp).unwrap();
        assert_eq!(engine.cache_len(), 0);
        let traced = e.layers.iter().filter_map(|l| l.estimate.as_ref()).flatten();
        for est in traced {
            assert!(est.trace.is_some(), "trace must survive the engine");
        }
    }
}
