//! Content-addressed kernel identity: the [`KernelKey`] fingerprint that
//! makes estimate reuse *safe by construction*.
//!
//! A fixed-point layer estimate ([`crate::aidg::estimate_layer`]) is a pure
//! function of four inputs:
//!
//! 1. the **architecture** — every routing/timing-relevant primitive of the
//!    finalized [`Diagram`] ([`Diagram::content_digest`]). For described
//!    architectures this subsumes the text frontend's source-keyed
//!    [`ArchRegistry`](crate::acadl::text::ArchRegistry): equal sources
//!    compile to one shared diagram, and — stronger — a description and a
//!    hand builder that produce structurally identical diagrams digest
//!    equally and share cache entries;
//! 2. the **kernel shape** — `k` and `insts_per_iter`;
//! 3. the **instruction stream of the decision prefix** — the estimator
//!    only ever *evaluates* a deterministic prefix of the iteration space
//!    (whole graph when `k` is small, otherwise `k_block`-sized chunks up
//!    to the fallback budget). [`decision_prefix`] computes the exact upper
//!    bound of that prefix, and the fingerprint hashes every instruction in
//!    it. Iterations beyond the prefix influence the estimate only through
//!    `k` (the eq. 2 extrapolation), which is hashed separately;
//! 4. the **fixed-point configuration** — `fallback_frac` (hashed both as
//!    raw bits and implicitly through the prefix length).
//!
//! Two kernels with equal [`KernelKey`]s therefore produce cycle-identical
//! estimates up to a 128-bit hash collision of *different* prefix streams —
//! there is no sampling shortcut that could silently alias two genuinely
//! different kernels.

use crate::acadl::Diagram;
use crate::aidg::{k_block, FixedPointConfig};
use crate::isa::LoopKernel;

/// Fingerprint-format version; bump when the word stream changes so stale
/// keys can never alias across releases.
const KEY_VERSION: u64 = 1;

/// Architecture fingerprint (a [`Diagram::content_digest`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArchDigest(pub u64);

impl ArchDigest {
    /// Digest a finalized diagram.
    pub fn of(d: &Diagram) -> Self {
        Self(d.content_digest())
    }
}

/// Cache key of one `(architecture, kernel, fixed-point config)` estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelKey {
    /// Architecture structural digest.
    pub arch: u64,
    /// High lane of the kernel-stream hash.
    pub kernel_hi: u64,
    /// Low lane of the kernel-stream hash.
    pub kernel_lo: u64,
    /// Raw bits of the fixed-point fallback fraction.
    pub fp_bits: u64,
}

impl KernelKey {
    /// Shard selector for the concurrent cache.
    #[inline]
    pub(crate) fn shard_of(&self, shards: usize) -> usize {
        (self.kernel_lo ^ self.arch.rotate_left(17)) as usize % shards
    }
}

/// Upper bound on the iterations [`crate::aidg::estimate_layer`] can
/// evaluate for a kernel with `k` iterations of `insts_per_iter`
/// instructions on a fetch port of `port_width`, under fallback fraction
/// `frac`. Mirrors the estimator's control flow exactly: whole graph when
/// `k_block >= k` or `3·k_block > k`; otherwise chunks of `k_block` until
/// the budget `max(k·frac, 3·k_block)` is reached (the stability early-exit
/// can only shorten the evaluated range, never extend it).
pub fn decision_prefix(k: u64, insts_per_iter: u64, port_width: u64, frac: f64) -> u64 {
    if k == 0 {
        return 0;
    }
    let kb = k_block(insts_per_iter, port_width);
    if kb >= k || 3 * kb > k {
        return k;
    }
    let budget = ((k as f64 * frac) as u64).max(3 * kb);
    (budget.div_ceil(kb) * kb).min(k)
}

/// 128-bit streaming mixer (two decorrelated multiply-rotate-xor lanes with
/// a murmur-style finalizer). Not cryptographic — keys live only inside one
/// process — but wide enough that accidental collisions between different
/// kernel streams are negligible (~2⁻¹²⁸·n² birthday bound).
struct Mix128 {
    a: u64,
    b: u64,
}

impl Mix128 {
    fn new() -> Self {
        // first 128 bits of pi's fractional part, split across the lanes
        Self { a: 0x243F_6A88_85A3_08D3, b: 0x1319_8A2E_0370_7344 }
    }

    #[inline]
    fn word(&mut self, w: u64) {
        self.a = (self.a.rotate_left(25) ^ w).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.b = (self.b.rotate_left(13) ^ w.wrapping_mul(0xA24B_AED4_963E_E407))
            .wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    }

    fn finish(self) -> (u64, u64) {
        fn avalanche(mut x: u64) -> u64 {
            x ^= x >> 33;
            x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            x ^= x >> 33;
            x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
            x ^ (x >> 33)
        }
        (avalanche(self.a), avalanche(self.b ^ self.a.rotate_left(32)))
    }
}

/// Compute the content-addressed key of one kernel estimate.
///
/// The evaluator's dispatch mode ([`crate::aidg::DispatchMode`]) is
/// deliberately **not** part of the key: the threaded tape and the
/// node-table walk are pinned bit-identical by the dispatch differential
/// suite, so an estimate cached under one mode is valid under the other.
pub fn kernel_key(
    arch: ArchDigest,
    d: &Diagram,
    kernel: &LoopKernel,
    fp: &FixedPointConfig,
) -> KernelKey {
    let port_width = d.fetch_config().port_width as u64;
    let prefix = decision_prefix(
        kernel.k,
        kernel.insts_per_iter as u64,
        port_width,
        fp.fallback_frac,
    );
    let mut mix = Mix128::new();
    mix.word(KEY_VERSION);
    mix.word(kernel.k);
    mix.word(kernel.insts_per_iter as u64);
    mix.word(prefix);
    kernel.content_words(0..prefix, &mut |w| mix.word(w));
    let (kernel_hi, kernel_lo) = mix.finish();
    KernelKey { arch: arch.0, kernel_hi, kernel_lo, fp_bits: fp.fallback_frac.to_bits() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::OpId;
    use crate::isa::Instruction;

    #[test]
    fn decision_prefix_mirrors_estimator() {
        // whole graph: k_block >= k or fewer than 3 blocks fit
        assert_eq!(decision_prefix(2, 4, 2, 0.01), 2);
        assert_eq!(decision_prefix(5, 3, 2, 0.01), 5); // kb=2, 3*2 > 5
        assert_eq!(decision_prefix(0, 4, 2, 0.01), 0);
        // chunked: kb=1 (4 insts, port 2), budget = max(1% of k, 3)
        assert_eq!(decision_prefix(2000, 4, 2, 0.01), 20);
        assert_eq!(decision_prefix(100, 4, 2, 0.01), 3); // budget floor 3*kb
        // kb=2 (3 insts, port 2): budget 20 rounds to a kb multiple
        assert_eq!(decision_prefix(2000, 3, 2, 0.01), 20);
        assert_eq!(decision_prefix(2100, 3, 2, 0.01), 22); // 21 -> ceil to 22
        // budget can never exceed k
        assert_eq!(decision_prefix(2000, 4, 2, 2.0), 2000);
    }

    fn kernel(k: u64, base: u64) -> LoopKernel {
        LoopKernel::new(
            "anything",
            k,
            2,
            Box::new(move |it, buf| {
                buf.push(Instruction::new(OpId(0)).read_mem(&[base + it]));
                buf.push(Instruction::new(OpId(1)).write_mem(&[base + 100 + it]));
            }),
        )
    }

    #[test]
    fn keys_are_content_addressed() {
        let mut d = Diagram::new("m");
        let (_im, ifs) = d.add_fetch("imem", 1, 2, "ifs", 1, 4);
        let es = d.add_execute_stage("es");
        let (rf, _regs) = d.add_regfile("rf", "r", 2);
        let mem = d.add_memory("dmem", 1, 1, 1, 1, 0, 1 << 20);
        let fu = d.add_fu(es, "fu", crate::acadl::Latency::Fixed(1), &["a", "b"]);
        d.forward(ifs, es);
        d.fu_reads(fu, rf);
        d.mem_reads(fu, mem);
        d.mem_writes(fu, mem);
        d.finalize().unwrap();
        let arch = ArchDigest::of(&d);
        let fp = FixedPointConfig::default();

        // identical content, different labels -> same key (dedup across layers)
        let a = kernel_key(arch, &d, &kernel(1000, 0), &fp);
        let mut named = kernel(1000, 0);
        named.label = "other_layer::compute".into();
        assert_eq!(a, kernel_key(arch, &d, &named, &fp));

        // shape, addresses, k, fp, and arch all perturb the key
        assert_ne!(a, kernel_key(arch, &d, &kernel(1001, 0), &fp));
        assert_ne!(a, kernel_key(arch, &d, &kernel(1000, 7), &fp));
        let fp2 = FixedPointConfig { fallback_frac: 0.02, ..fp };
        assert_ne!(a, kernel_key(arch, &d, &kernel(1000, 0), &fp2));
        let other_arch = ArchDigest(arch.0 ^ 1);
        assert_ne!(a, kernel_key(other_arch, &d, &kernel(1000, 0), &fp));
    }
}
