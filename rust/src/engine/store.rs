//! §Store — a persistent, content-addressed estimate store.
//!
//! The in-memory [`EstimateCache`](super::EstimateCache) dies with the
//! process; this module gives warm state a disk form that survives
//! restarts and can be shipped between replicas. The layout is a
//! directory of **append-only segment files** (`seg-NNNNNN.est`), each
//!
//! ```text
//! [8-byte magic "ACPSTOR1"]
//! [record]*      where record = [len: u32 LE][crc64: u64 LE][payload]
//! ```
//!
//! and every payload starts with a kind byte: `1` is one
//! [`LayerEstimate`] keyed by its [`KernelKey`] (all fields are exact
//! integers — cached estimates never carry traces or calibration stamps,
//! so the round-trip is bit-identical by construction), `2` is a DSE
//! Pareto frontier keyed by *sweep-space digest × network digest* so a
//! repeated `sweep` resumes from the prior frontier. Records are
//! checksummed (FNV-1a 64 over the payload); a corrupt or short tail —
//! the signature of a crash mid-append — is truncated away on open and
//! everything before it is served normally. New entries accumulate in
//! memory and are flushed as a *new* segment via write-temp-then-rename,
//! so readers of the directory never observe a half-written file. Later
//! records shadow earlier ones on load, which is what makes `gc`
//! (rewrite live entries into one compacted segment, drop the rest)
//! safe: an interrupted gc leaves the old segments behind, and the next
//! open simply reads both generations.
//!
//! Reference management is generational: `open` stamps
//! `open_gen = max(stored last_ref) + 1`, and every `get`/`put` touches
//! the entry's `last_ref` to the current generation. [`EstimateStore::gc`]
//! drops entries whose `last_ref` predates the current generation —
//! i.e. everything loaded from disk but never referenced since.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::key::KernelKey;
use crate::aidg::{LayerEstimate, Provenance};
use crate::dse::SweepPoint;
use crate::metrics::counters;

/// Segment-file magic: "ACadl Perf STORe" v1.
const MAGIC: [u8; 8] = *b"ACPSTOR1";
/// Records larger than this are treated as corruption, not data.
const MAX_RECORD: u32 = 16 * 1024 * 1024;
/// Payload kind byte for a keyed [`LayerEstimate`].
const KIND_ESTIMATE: u8 = 1;
/// Payload kind byte for a DSE frontier snapshot.
const KIND_FRONTIER: u8 = 2;

/// FNV-1a 64 — the record checksum (and the digest helper for frontier
/// keys). Not cryptographic; it only needs to catch torn writes.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Digest of a network's identity for frontier keying: name plus the
/// ordered layer-name list (layer hyper-parameters are already captured
/// by the sweep outcome's kernel keys; the frontier key only needs to
/// tell *workloads* apart).
pub fn net_digest(net: &crate::dnn::Network) -> u64 {
    let mut text = String::with_capacity(64);
    text.push_str(&net.name);
    for l in &net.layers {
        text.push('\n');
        text.push_str(&l.name);
    }
    fnv64(text.as_bytes())
}

/// One stored estimate plus its generational reference stamp.
struct StoredEntry {
    est: Arc<LayerEstimate>,
    last_ref: u64,
}

/// One stored frontier plus its generational reference stamp.
struct FrontierEntry {
    points: Vec<SweepPoint>,
    last_ref: u64,
}

/// Mutable store state behind one mutex (lookups are a hash probe; the
/// hot path through the engine only reaches here on a cache *miss*).
struct Inner {
    entries: HashMap<KernelKey, StoredEntry>,
    frontiers: HashMap<(u64, u64), FrontierEntry>,
    /// Generation stamp of this open; entries touched this run carry it.
    open_gen: u64,
    /// Keys inserted since the last flush (always present in `entries`).
    dirty: Vec<KernelKey>,
    /// Frontier keys written since the last flush.
    dirty_frontiers: Vec<(u64, u64)>,
    /// Next segment file number.
    next_seg: u64,
}

/// Aggregate store counters for `store stats` and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    /// Estimates resident (loaded + inserted).
    pub entries: usize,
    /// Frontier snapshots resident.
    pub frontiers: usize,
    /// Records not yet flushed to a segment.
    pub dirty: usize,
    /// Segment files currently in the directory.
    pub segments: usize,
    /// Generation stamp of this open.
    pub open_gen: u64,
}

/// Result of one [`EstimateStore::gc`] pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcOutcome {
    /// Records kept (referenced since the current generation).
    pub kept: usize,
    /// Records dropped as unreferenced.
    pub dropped: usize,
}

/// A content-addressed on-disk estimate store. See the module docs for
/// the format; see [`EstimationEngine::attach_store`]
/// (super::EstimationEngine::attach_store) for how it layers *under* the
/// in-memory cache (miss → store probe → promote).
pub struct EstimateStore {
    dir: PathBuf,
    inner: Mutex<Inner>,
}

impl EstimateStore {
    /// Open (creating if needed) the store at `dir`, loading every
    /// segment in file order. Corrupt tails are truncated; a segment
    /// with a foreign magic is skipped whole.
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating store directory {}", dir.display()))?;
        let mut segs: Vec<(u64, PathBuf)> = Vec::new();
        for entry in
            fs::read_dir(&dir).with_context(|| format!("listing store {}", dir.display()))?
        {
            let path = entry?.path();
            if let Some(n) = segment_number(&path) {
                segs.push((n, path));
            }
        }
        segs.sort();
        let mut inner = Inner {
            entries: HashMap::new(),
            frontiers: HashMap::new(),
            open_gen: 1,
            dirty: Vec::new(),
            dirty_frontiers: Vec::new(),
            next_seg: 0,
        };
        let mut max_ref = 0u64;
        for (n, path) in &segs {
            load_segment(path, &mut inner, &mut max_ref)
                .with_context(|| format!("loading segment {}", path.display()))?;
            inner.next_seg = inner.next_seg.max(n + 1);
        }
        inner.open_gen = max_ref + 1;
        Ok(Arc::new(Self { dir, inner: Mutex::new(inner) }))
    }

    /// The directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look up one estimate; a hit refreshes the entry's generation
    /// stamp (it is "referenced" for gc purposes).
    pub fn get(&self, key: &KernelKey) -> Option<Arc<LayerEstimate>> {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.open_gen;
        match inner.entries.get_mut(key) {
            Some(e) => {
                e.last_ref = gen;
                counters::STORE_HITS.add(1);
                Some(Arc::clone(&e.est))
            }
            None => {
                counters::STORE_MISSES.add(1);
                None
            }
        }
    }

    /// Insert one estimate. Content addressing makes overwrites
    /// meaningless (same key ⇒ same cycles), so an existing entry is
    /// only touched, not re-written. Returns whether the entry was new.
    pub fn put(&self, key: KernelKey, est: Arc<LayerEstimate>) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.open_gen;
        if let Some(e) = inner.entries.get_mut(&key) {
            e.last_ref = gen;
            return false;
        }
        inner.entries.insert(key, StoredEntry { est, last_ref: gen });
        inner.dirty.push(key);
        counters::STORE_WRITES.add(1);
        true
    }

    /// Look up the persisted frontier for one sweep-space × network
    /// digest pair.
    pub fn frontier_get(&self, space_digest: u64, net_digest: u64) -> Option<Vec<SweepPoint>> {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.open_gen;
        match inner.frontiers.get_mut(&(space_digest, net_digest)) {
            Some(f) => {
                f.last_ref = gen;
                counters::STORE_HITS.add(1);
                Some(f.points.clone())
            }
            None => {
                counters::STORE_MISSES.add(1);
                None
            }
        }
    }

    /// Replace the persisted frontier for one sweep-space × network
    /// digest pair (frontiers evolve, unlike estimates, so this *does*
    /// overwrite — the newest record shadows older ones on load).
    pub fn frontier_put(&self, space_digest: u64, net_digest: u64, points: Vec<SweepPoint>) {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.open_gen;
        inner
            .frontiers
            .insert((space_digest, net_digest), FrontierEntry { points, last_ref: gen });
        if !inner.dirty_frontiers.contains(&(space_digest, net_digest)) {
            inner.dirty_frontiers.push((space_digest, net_digest));
        }
        counters::STORE_WRITES.add(1);
    }

    /// Number of resident estimates.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Whether the store holds no estimates.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate counters for `store stats`.
    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        StoreStats {
            entries: inner.entries.len(),
            frontiers: inner.frontiers.len(),
            dirty: inner.dirty.len() + inner.dirty_frontiers.len(),
            segments: self.segment_count(),
            open_gen: inner.open_gen,
        }
    }

    /// Segment files currently on disk.
    fn segment_count(&self) -> usize {
        fs::read_dir(&self.dir)
            .map(|rd| rd.flatten().filter(|e| segment_number(&e.path()).is_some()).count())
            .unwrap_or(0)
    }

    /// Flush unwritten records as one new segment (write-temp-then-
    /// rename, so a crash never leaves a half-visible segment). Returns
    /// the number of records written; a clean store is a no-op.
    pub fn flush(&self) -> Result<usize> {
        let mut inner = self.inner.lock().unwrap();
        if inner.dirty.is_empty() && inner.dirty_frontiers.is_empty() {
            return Ok(0);
        }
        let mut buf = MAGIC.to_vec();
        let mut written = 0usize;
        for key in &inner.dirty {
            let e = &inner.entries[key];
            write_record(&mut buf, &encode_estimate(key, e.last_ref, &e.est));
            written += 1;
        }
        for fk in &inner.dirty_frontiers {
            let f = &inner.frontiers[fk];
            write_record(&mut buf, &encode_frontier(*fk, f.last_ref, &f.points));
            written += 1;
        }
        self.swap_in_segment(&mut inner, &buf)?;
        inner.dirty.clear();
        inner.dirty_frontiers.clear();
        Ok(written)
    }

    /// Flush when at least `threshold` records are pending — the serve
    /// loop's cheap periodic persistence hook.
    pub fn flush_if_dirty(&self, threshold: usize) -> Result<usize> {
        let pending = {
            let inner = self.inner.lock().unwrap();
            inner.dirty.len() + inner.dirty_frontiers.len()
        };
        if pending >= threshold.max(1) {
            self.flush()
        } else {
            Ok(0)
        }
    }

    /// Drop every record not referenced since this open's generation
    /// stamp and compact the survivors into a single fresh segment,
    /// deleting the old ones. Unreferenced means: loaded from disk and
    /// never hit by `get`/`put`/`frontier_get` in this process.
    pub fn gc(&self) -> Result<GcOutcome> {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.open_gen;
        let before = inner.entries.len() + inner.frontiers.len();
        inner.entries.retain(|_, e| e.last_ref >= gen);
        inner.frontiers.retain(|_, f| f.last_ref >= gen);
        let kept = inner.entries.len() + inner.frontiers.len();
        let dropped = before - kept;

        let mut buf = MAGIC.to_vec();
        for (key, e) in &inner.entries {
            write_record(&mut buf, &encode_estimate(key, e.last_ref, &e.est));
        }
        for (fk, f) in &inner.frontiers {
            write_record(&mut buf, &encode_frontier(*fk, f.last_ref, &f.points));
        }
        let new_seg = self.swap_in_segment(&mut inner, &buf)?;
        // compaction persisted everything live; nothing is pending
        inner.dirty.clear();
        inner.dirty_frontiers.clear();
        // drop every segment but the compacted one
        for entry in fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(n) = segment_number(&path) {
                if n != new_seg {
                    let _ = fs::remove_file(&path);
                }
            }
        }
        counters::STORE_GC_DROPPED.add(dropped as u64);
        Ok(GcOutcome { kept, dropped })
    }

    /// Write `buf` as the next segment via temp + atomic rename; returns
    /// the new segment number.
    fn swap_in_segment(&self, inner: &mut Inner, buf: &[u8]) -> Result<u64> {
        let seg = inner.next_seg;
        let tmp = self.dir.join(format!("seg-{seg:06}.tmp"));
        let dst = self.dir.join(format!("seg-{seg:06}.est"));
        fs::write(&tmp, buf).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &dst)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), dst.display()))?;
        inner.next_seg += 1;
        Ok(seg)
    }
}

/// Parse `seg-NNNNNN.est` into its segment number.
fn segment_number(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    let digits = name.strip_prefix("seg-")?.strip_suffix(".est")?;
    digits.parse().ok()
}

/// Load one segment into `inner`, truncating any corrupt tail in place.
fn load_segment(path: &Path, inner: &mut Inner, max_ref: &mut u64) -> Result<()> {
    let bytes = fs::read(path)?;
    if bytes.len() < MAGIC.len() || bytes[..MAGIC.len()] != MAGIC {
        // foreign or hopeless file: leave it alone, serve nothing from it
        return Ok(());
    }
    let mut off = MAGIC.len();
    loop {
        let Some(rec_end) = record_bounds(&bytes, off) else {
            // short header, oversized length, bad checksum, or a payload
            // that fails to decode: crash-torn tail — truncate to the
            // last good record so the next append starts clean
            if off < bytes.len() {
                truncate_file(path, off as u64);
            }
            return Ok(());
        };
        let payload = &bytes[off + 12..rec_end];
        match payload.first() {
            Some(&KIND_ESTIMATE) => match decode_estimate(&payload[1..]) {
                Ok((key, last_ref, est)) => {
                    *max_ref = (*max_ref).max(last_ref);
                    inner.entries.insert(key, StoredEntry { est: Arc::new(est), last_ref });
                }
                Err(_) => {
                    truncate_file(path, off as u64);
                    return Ok(());
                }
            },
            Some(&KIND_FRONTIER) => match decode_frontier(&payload[1..]) {
                Ok((fk, last_ref, points)) => {
                    *max_ref = (*max_ref).max(last_ref);
                    inner.frontiers.insert(fk, FrontierEntry { points, last_ref });
                }
                Err(_) => {
                    truncate_file(path, off as u64);
                    return Ok(());
                }
            },
            // unknown kind: a future format extension — skip the record
            // (it passed its checksum, so the frame is trustworthy)
            _ => {}
        }
        off = rec_end;
        if off == bytes.len() {
            return Ok(());
        }
    }
}

/// If the record at `off` is whole and checksums clean, return its end
/// offset; `None` marks the corrupt-tail boundary.
fn record_bounds(bytes: &[u8], off: usize) -> Option<usize> {
    let header = bytes.get(off..off + 12)?;
    let len = u32::from_le_bytes(header[..4].try_into().unwrap());
    if len == 0 || len > MAX_RECORD {
        return None;
    }
    let crc = u64::from_le_bytes(header[4..12].try_into().unwrap());
    let payload = bytes.get(off + 12..off + 12 + len as usize)?;
    (fnv64(payload) == crc).then_some(off + 12 + len as usize)
}

/// Best-effort physical truncation of a segment's corrupt tail.
fn truncate_file(path: &Path, len: u64) {
    if let Ok(f) = fs::OpenOptions::new().write(true).open(path) {
        let _ = f.set_len(len);
    }
}

/// Frame one payload as `[len][crc][payload]`.
fn write_record(out: &mut Vec<u8>, payload: &[u8]) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv64(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Little-endian payload writer.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(kind: u8) -> Self {
        Self { buf: vec![kind] }
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Little-endian payload reader (every getter fails cleanly on a short
/// or malformed buffer — the caller treats that as a corrupt tail).
struct Dec<'a> {
    b: &'a [u8],
}

impl Dec<'_> {
    fn u64(&mut self) -> Result<u64> {
        if self.b.len() < 8 {
            bail!("record truncated");
        }
        let (head, rest) = self.b.split_at(8);
        self.b = rest;
        Ok(u64::from_le_bytes(head.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        if self.b.len() < n {
            bail!("record truncated");
        }
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(std::str::from_utf8(head).context("stored string not UTF-8")?.to_string())
    }
    fn done(&self) -> Result<()> {
        if !self.b.is_empty() {
            bail!("{} trailing bytes in record", self.b.len());
        }
        Ok(())
    }
}

/// Encode one estimate record. Only exact fields are persisted: cached
/// estimates never carry traces, and calibration is stamped at read
/// time by the engine, never stored — that is what keeps
/// calibration-off bit-identical through the store path.
fn encode_estimate(key: &KernelKey, last_ref: u64, est: &LayerEstimate) -> Vec<u8> {
    let mut e = Enc::new(KIND_ESTIMATE);
    e.u64(key.arch);
    e.u64(key.kernel_hi);
    e.u64(key.kernel_lo);
    e.u64(key.fp_bits);
    e.u64(last_ref);
    e.str(&est.label);
    e.u64(est.k);
    e.u64(est.insts_per_iter as u64);
    e.u64(est.cycles);
    e.u64(est.evaluated_iters);
    e.u64(est.k_block);
    e.u64(est.k_prolog);
    e.u64(est.dt_iteration);
    e.u64(est.dt_overlap as u64);
    e.u64(est.used_fallback as u64 | (est.whole_graph as u64) << 1);
    e.u64(est.nodes);
    e.u64(est.peak_state_bytes);
    e.u64(est.runtime.as_nanos() as u64);
    e.buf
}

/// Decode one estimate payload (after the kind byte).
fn decode_estimate(payload: &[u8]) -> Result<(KernelKey, u64, LayerEstimate)> {
    let mut d = Dec { b: payload };
    let key = KernelKey {
        arch: d.u64()?,
        kernel_hi: d.u64()?,
        kernel_lo: d.u64()?,
        fp_bits: d.u64()?,
    };
    let last_ref = d.u64()?;
    let label = d.str()?;
    let k = d.u64()?;
    let insts_per_iter = d.u64()? as usize;
    let cycles = d.u64()?;
    let evaluated_iters = d.u64()?;
    let k_block = d.u64()?;
    let k_prolog = d.u64()?;
    let dt_iteration = d.u64()?;
    let dt_overlap = d.u64()? as i64;
    let flags = d.u64()?;
    let nodes = d.u64()?;
    let peak_state_bytes = d.u64()?;
    let runtime = Duration::from_nanos(d.u64()?);
    d.done()?;
    Ok((
        key,
        last_ref,
        LayerEstimate {
            label,
            k,
            insts_per_iter,
            cycles,
            evaluated_iters,
            k_block,
            k_prolog,
            dt_iteration,
            dt_overlap,
            used_fallback: flags & 1 != 0,
            whole_graph: flags & 2 != 0,
            nodes,
            peak_state_bytes,
            runtime,
            provenance: Provenance::Computed,
            trace: None,
            calibrated_cycles: None,
            ci_lo: None,
            ci_hi: None,
        },
    ))
}

/// Encode one frontier record.
fn encode_frontier(fk: (u64, u64), last_ref: u64, points: &[SweepPoint]) -> Vec<u8> {
    let mut e = Enc::new(KIND_FRONTIER);
    e.u64(fk.0);
    e.u64(fk.1);
    e.u64(last_ref);
    e.u64(points.len() as u64);
    for p in points {
        e.str(&p.label);
        e.str(&p.arch_name);
        e.u64(p.assignment.len() as u64);
        for (name, v) in &p.assignment {
            e.str(name);
            e.u64(*v as u64);
        }
        e.u64(p.digest);
        e.u64(p.pe_count);
        e.u64(p.mem_words);
        e.u64(p.roofline_cycles.to_bits());
        match p.aidg_cycles {
            Some(c) => {
                e.u64(1);
                e.u64(c);
            }
            None => e.u64(0),
        }
        e.u64(p.on_frontier as u64);
    }
    e.buf
}

/// Decode one frontier payload (after the kind byte).
fn decode_frontier(payload: &[u8]) -> Result<((u64, u64), u64, Vec<SweepPoint>)> {
    let mut d = Dec { b: payload };
    let fk = (d.u64()?, d.u64()?);
    let last_ref = d.u64()?;
    let count = d.u64()? as usize;
    if count > 1_000_000 {
        bail!("implausible frontier size {count}");
    }
    let mut points = Vec::with_capacity(count);
    for _ in 0..count {
        let label = d.str()?;
        let arch_name = d.str()?;
        let n_assign = d.u64()? as usize;
        if n_assign > 10_000 {
            bail!("implausible assignment size {n_assign}");
        }
        let mut assignment = Vec::with_capacity(n_assign);
        for _ in 0..n_assign {
            let name = d.str()?;
            let v = d.u64()? as i64;
            assignment.push((name, v));
        }
        let digest = d.u64()?;
        let pe_count = d.u64()?;
        let mem_words = d.u64()?;
        let roofline_cycles = f64::from_bits(d.u64()?);
        let aidg_cycles = if d.u64()? != 0 { Some(d.u64()?) } else { None };
        let on_frontier = d.u64()? != 0;
        points.push(SweepPoint {
            label,
            assignment,
            arch_name,
            digest,
            pe_count,
            mem_words,
            roofline_cycles,
            aidg_cycles,
            on_frontier,
        });
    }
    d.done()?;
    Ok((fk, last_ref, points))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn scratch_dir(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "acadl-store-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn key(n: u64) -> KernelKey {
        KernelKey { arch: n, kernel_hi: n.wrapping_mul(3), kernel_lo: n ^ 0xFF, fp_bits: 7 }
    }

    fn est(label: &str, cycles: u64) -> LayerEstimate {
        LayerEstimate {
            label: label.into(),
            k: 64,
            insts_per_iter: 7,
            cycles,
            evaluated_iters: 9,
            k_block: 2,
            k_prolog: 3,
            dt_iteration: 11,
            dt_overlap: -4,
            used_fallback: false,
            whole_graph: true,
            nodes: 123,
            peak_state_bytes: 456,
            runtime: Duration::from_micros(5),
            provenance: Provenance::Computed,
            trace: None,
            calibrated_cycles: None,
            ci_lo: None,
            ci_hi: None,
        }
    }

    fn point(label: &str, cycles: u64) -> SweepPoint {
        SweepPoint {
            label: label.into(),
            assignment: vec![("rows".into(), 4), ("cols".into(), -2)],
            arch_name: "systolic".into(),
            digest: 0xABCD,
            pe_count: 16,
            mem_words: 1024,
            roofline_cycles: 123.5,
            aidg_cycles: Some(cycles),
            on_frontier: true,
        }
    }

    #[test]
    fn estimate_record_round_trips_bit_identically() {
        let k = key(42);
        let e0 = est("conv1/k0", 98765);
        let payload = encode_estimate(&k, 3, &e0);
        assert_eq!(payload[0], KIND_ESTIMATE);
        let (k1, last_ref, e1) = decode_estimate(&payload[1..]).unwrap();
        assert_eq!(k1, k);
        assert_eq!(last_ref, 3);
        assert_eq!(e1.label, e0.label);
        assert_eq!(e1.cycles, e0.cycles);
        assert_eq!(e1.dt_overlap, e0.dt_overlap);
        assert_eq!(e1.whole_graph, e0.whole_graph);
        assert_eq!(e1.used_fallback, e0.used_fallback);
        assert_eq!(e1.runtime, e0.runtime);
        assert!(e1.trace.is_none() && e1.calibrated_cycles.is_none());
    }

    #[test]
    fn save_reopen_serves_identical_estimates() {
        let dir = scratch_dir("roundtrip");
        {
            let store = EstimateStore::open(&dir).unwrap();
            assert!(store.put(key(1), Arc::new(est("a", 100))));
            assert!(store.put(key(2), Arc::new(est("b", 200))));
            // duplicate put is a touch, not a rewrite
            assert!(!store.put(key(1), Arc::new(est("a", 100))));
            assert_eq!(store.flush().unwrap(), 2);
            assert_eq!(store.flush().unwrap(), 0, "clean store must not grow segments");
        }
        let store = EstimateStore::open(&dir).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(&key(1)).unwrap().cycles, 100);
        assert_eq!(store.get(&key(2)).unwrap().label, "b");
        assert!(store.get(&key(3)).is_none());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_tail_is_truncated_and_prefix_served() {
        let dir = scratch_dir("corrupt");
        {
            let store = EstimateStore::open(&dir).unwrap();
            store.put(key(1), Arc::new(est("good", 100)));
            store.flush().unwrap();
        }
        // simulate a crash mid-append: garbage after the good record
        let seg = dir.join("seg-000000.est");
        let clean_len = fs::metadata(&seg).unwrap().len();
        let mut bytes = fs::read(&seg).unwrap();
        bytes.extend_from_slice(&[0xDE, 0xAD, 0xBE, 0xEF, 0x01, 0x02, 0x03]);
        fs::write(&seg, &bytes).unwrap();

        let store = EstimateStore::open(&dir).unwrap();
        assert_eq!(store.len(), 1, "clean prefix must survive");
        assert_eq!(store.get(&key(1)).unwrap().cycles, 100);
        assert_eq!(
            fs::metadata(&seg).unwrap().len(),
            clean_len,
            "corrupt tail must be physically truncated"
        );

        // flipping a byte inside the record kills its checksum: the
        // whole record is the tail now
        let mut bytes = fs::read(&seg).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();
        let store = EstimateStore::open(&dir).unwrap();
        assert_eq!(store.len(), 0, "checksum-failing record must be dropped");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_keeps_referenced_and_drops_unreferenced() {
        let dir = scratch_dir("gc");
        {
            let store = EstimateStore::open(&dir).unwrap();
            store.put(key(1), Arc::new(est("kept", 100)));
            store.put(key(2), Arc::new(est("dropped", 200)));
            store.flush().unwrap();
        }
        {
            let store = EstimateStore::open(&dir).unwrap();
            // reference only key(1) in this generation
            assert!(store.get(&key(1)).is_some());
            let out = store.gc().unwrap();
            assert_eq!(out, GcOutcome { kept: 1, dropped: 1 });
            assert_eq!(store.stats().segments, 1, "gc must compact to one segment");
        }
        let store = EstimateStore::open(&dir).unwrap();
        assert!(store.get(&key(1)).is_some(), "referenced entry survives gc + reopen");
        assert!(store.get(&key(2)).is_none(), "unreferenced entry is gone");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn frontier_round_trips_through_reopen() {
        let dir = scratch_dir("frontier");
        let pts = vec![point("rows=4,cols=2", 1000), point("rows=2,cols=4", 1200)];
        {
            let store = EstimateStore::open(&dir).unwrap();
            store.frontier_put(0x51, 0x52, pts.clone());
            store.flush().unwrap();
        }
        let store = EstimateStore::open(&dir).unwrap();
        let got = store.frontier_get(0x51, 0x52).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].label, pts[0].label);
        assert_eq!(got[0].assignment, pts[0].assignment);
        assert_eq!(got[0].roofline_cycles, pts[0].roofline_cycles);
        assert_eq!(got[1].aidg_cycles, pts[1].aidg_cycles);
        assert!(store.frontier_get(0x51, 0x53).is_none());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn new_records_land_in_fresh_segments_and_later_shadow_earlier() {
        let dir = scratch_dir("shadow");
        {
            let store = EstimateStore::open(&dir).unwrap();
            store.frontier_put(9, 9, vec![point("old", 1)]);
            store.flush().unwrap();
            store.frontier_put(9, 9, vec![point("new", 2), point("new2", 3)]);
            store.flush().unwrap();
            assert_eq!(store.stats().segments, 2);
        }
        let store = EstimateStore::open(&dir).unwrap();
        let got = store.frontier_get(9, 9).unwrap();
        assert_eq!(got.len(), 2, "newest frontier record must shadow the older one");
        assert_eq!(got[0].label, "new");
        fs::remove_dir_all(&dir).unwrap();
    }
}
