//! Integer ids + string interning.
//!
//! All hot-path structures (routing, AIDG state, the cycle simulator) key by
//! dense integer ids instead of strings: `OpId` for instruction mnemonics,
//! `RegId` for register names, `ObjId` for ACADL objects. Interners live in
//! the [`crate::acadl::Diagram`] so ids are stable per architecture model.

use std::collections::HashMap;

/// Instruction mnemonic id (e.g. `load`, `mac`, `conv_ext`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

/// Register name id (e.g. `pe[0][1].acc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegId(pub u32);

/// ACADL object id (index into the diagram's object table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjId(pub u32);

impl ObjId {
    #[inline]
    /// The id as a table index.
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Memory address (single global address space per diagram; each `Memory`
/// object claims disjoint `address_ranges` within it).
pub type Addr = u64;

/// Clock cycle count.
pub type Cycle = u64;

/// Fast non-cryptographic hasher for integer keys on the evaluation hot
/// path (FxHash-style multiply-xor; SipHash dominates the profile on the
/// address scoreboards otherwise).
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl std::hash::Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u64(b as u64);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.hash = (self.hash.rotate_left(5) ^ n).wrapping_mul(0x517CC1B727220A95);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

/// `HashMap` with [`FxHasher`] (hot-path integer keys).
pub type FxHashMap<K, V> = HashMap<K, V, std::hash::BuildHasherDefault<FxHasher>>;

/// A simple string interner mapping names to dense u32 ids.
#[derive(Debug, Default, Clone)]
pub struct Interner {
    names: Vec<String>,
    map: HashMap<String, u32>,
}

impl Interner {
    /// An empty interner.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `name`, returning its id (existing or fresh).
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.map.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.map.insert(name.to_string(), id);
        id
    }

    /// Look up an already-interned name.
    pub fn get(&self, name: &str) -> Option<u32> {
        self.map.get(name).copied()
    }

    /// Resolve an id back to its name.
    pub fn name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when nothing is interned.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut i = Interner::new();
        let a = i.intern("mac");
        let b = i.intern("load");
        let a2 = i.intern("mac");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(i.name(a), "mac");
        assert_eq!(i.name(b), "load");
        assert_eq!(i.len(), 2);
    }

    #[test]
    fn get_without_intern() {
        let mut i = Interner::new();
        assert_eq!(i.get("x"), None);
        let id = i.intern("x");
        assert_eq!(i.get("x"), Some(id));
    }
}
