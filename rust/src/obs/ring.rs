//! Fixed-capacity lock-free ring buffer of span events.
//!
//! Writers claim a slot with a single `fetch_add` on the global write
//! cursor, then publish through a per-slot sequence word tagged with the
//! claim position (crossbeam-style seqlock: odd = in progress, `2·pos+2` =
//! published). Readers validate the sequence before *and* after copying a
//! slot, so a concurrent overwrite is detected and the slot skipped rather
//! than returned torn. When the ring is full the oldest events are
//! overwritten first; [`SpanRing::snapshot`] reports how many were lost.
//!
//! Events are plain-old-data — interned `u32` name indices, integer ids
//! and nanosecond timestamps — so recording is store-only: no allocation,
//! no locks, no drop glue.

use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::NO_NAME;

/// One completed span, as recorded in the ring. All-integer POD; resolve
/// names with [`crate::obs::resolve_name`] or the [`SpanEvent::name`]
/// helper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Interned span name index.
    pub name_idx: u32,
    /// Recording thread's [`crate::obs::thread_id`].
    pub tid: u32,
    /// Process-unique span id (never 0).
    pub id: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Start timestamp, ns since the tracing epoch.
    pub start_ns: u64,
    /// Duration in ns.
    pub dur_ns: u64,
    /// First argument's interned key ([`NO_NAME`] = unset).
    pub arg0_key: u32,
    /// First argument's value.
    pub arg0_val: u64,
    /// Second argument's interned key ([`NO_NAME`] = unset).
    pub arg1_key: u32,
    /// Second argument's value.
    pub arg1_val: u64,
    /// Interned provenance note ([`NO_NAME`] = none), e.g. `"hit"`.
    pub note_idx: u32,
}

impl SpanEvent {
    /// The span's resolved name.
    pub fn name(&self) -> &'static str {
        super::resolve_name(self.name_idx)
    }

    /// The provenance note, if any.
    pub fn note(&self) -> Option<&'static str> {
        (self.note_idx != NO_NAME).then(|| super::resolve_name(self.note_idx))
    }
}

/// One ring slot: a seqlock word plus the event fields, all atomics so the
/// whole structure is safe Rust with no `UnsafeCell`.
struct Slot {
    /// `2·pos+1` while the claim at `pos` is being written, `2·pos+2` once
    /// published, 0 when never written.
    seq: AtomicU64,
    name_idx: AtomicU64,
    tid: AtomicU64,
    id: AtomicU64,
    parent: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    arg0_key: AtomicU64,
    arg0_val: AtomicU64,
    arg1_key: AtomicU64,
    arg1_val: AtomicU64,
    note_idx: AtomicU64,
}

impl Slot {
    fn new() -> Self {
        Self {
            seq: AtomicU64::new(0),
            name_idx: AtomicU64::new(0),
            tid: AtomicU64::new(0),
            id: AtomicU64::new(0),
            parent: AtomicU64::new(0),
            start_ns: AtomicU64::new(0),
            dur_ns: AtomicU64::new(0),
            arg0_key: AtomicU64::new(0),
            arg0_val: AtomicU64::new(0),
            arg1_key: AtomicU64::new(0),
            arg1_val: AtomicU64::new(0),
            note_idx: AtomicU64::new(0),
        }
    }
}

/// Fixed-capacity multi-writer ring of [`SpanEvent`]s.
pub struct SpanRing {
    cap: u64,
    next: AtomicU64,
    slots: Vec<Slot>,
}

impl SpanRing {
    /// A ring holding the most recent `cap` events (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            cap: cap as u64,
            next: AtomicU64::new(0),
            slots: (0..cap).map(|_| Slot::new()).collect(),
        }
    }

    /// The ring's capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Record one event. Lock-free and allocation-free; overwrites the
    /// oldest event when full.
    pub fn record(&self, ev: &SpanEvent) {
        let pos = self.next.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(pos % self.cap) as usize];
        slot.seq.store(2 * pos + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        slot.name_idx.store(ev.name_idx as u64, Ordering::Relaxed);
        slot.tid.store(ev.tid as u64, Ordering::Relaxed);
        slot.id.store(ev.id, Ordering::Relaxed);
        slot.parent.store(ev.parent, Ordering::Relaxed);
        slot.start_ns.store(ev.start_ns, Ordering::Relaxed);
        slot.dur_ns.store(ev.dur_ns, Ordering::Relaxed);
        slot.arg0_key.store(ev.arg0_key as u64, Ordering::Relaxed);
        slot.arg0_val.store(ev.arg0_val, Ordering::Relaxed);
        slot.arg1_key.store(ev.arg1_key as u64, Ordering::Relaxed);
        slot.arg1_val.store(ev.arg1_val, Ordering::Relaxed);
        slot.note_idx.store(ev.note_idx as u64, Ordering::Relaxed);
        slot.seq.store(2 * pos + 2, Ordering::Release);
    }

    /// Copy out the retained events oldest-first, plus
    /// `(events_recorded, events_dropped)` totals. Slots concurrently being
    /// overwritten are skipped, never returned torn.
    pub fn snapshot(&self) -> (Vec<SpanEvent>, u64, u64) {
        let recorded = self.next.load(Ordering::Acquire);
        let start = recorded.saturating_sub(self.cap);
        let mut out = Vec::with_capacity((recorded - start) as usize);
        for pos in start..recorded {
            let slot = &self.slots[(pos % self.cap) as usize];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 != 2 * pos + 2 {
                continue; // unpublished or already overwritten
            }
            let ev = SpanEvent {
                name_idx: slot.name_idx.load(Ordering::Relaxed) as u32,
                tid: slot.tid.load(Ordering::Relaxed) as u32,
                id: slot.id.load(Ordering::Relaxed),
                parent: slot.parent.load(Ordering::Relaxed),
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
                arg0_key: slot.arg0_key.load(Ordering::Relaxed) as u32,
                arg0_val: slot.arg0_val.load(Ordering::Relaxed),
                arg1_key: slot.arg1_key.load(Ordering::Relaxed) as u32,
                arg1_val: slot.arg1_val.load(Ordering::Relaxed),
                note_idx: slot.note_idx.load(Ordering::Relaxed) as u32,
            };
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) == s1 {
                out.push(ev);
            }
        }
        (out, recorded, start)
    }
}

/// Default capacity of the process-global ring.
pub const DEFAULT_RING_CAP: usize = 16_384;

static RING_CAP: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAP);
static RING: OnceLock<SpanRing> = OnceLock::new();

fn global_ring() -> &'static SpanRing {
    RING.get_or_init(|| SpanRing::new(RING_CAP.load(Ordering::Relaxed)))
}

/// Set the global ring's capacity. Returns `false` (no effect) once the
/// ring has been used — capacity must be chosen before the first span.
pub fn set_ring_capacity(cap: usize) -> bool {
    if RING.get().is_some() {
        return false;
    }
    RING_CAP.store(cap.max(1), Ordering::Relaxed);
    RING.get().is_none()
}

/// Record into the global ring (allocates only on the very first call,
/// which constructs the ring — warmup, not steady state).
pub(crate) fn record_global(ev: &SpanEvent) {
    global_ring().record(ev);
}

/// `(events_recorded, events_dropped)` for the global ring.
pub fn global_stats() -> (u64, u64) {
    match RING.get() {
        Some(r) => {
            let next = r.next.load(Ordering::Relaxed);
            (next, next.saturating_sub(r.cap))
        }
        None => (0, 0),
    }
}

/// Snapshot the global ring's retained events, oldest-first.
pub fn events() -> Vec<SpanEvent> {
    match RING.get() {
        Some(r) => r.snapshot().0,
        None => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64) -> SpanEvent {
        SpanEvent {
            name_idx: 7,
            tid: 1,
            id,
            parent: id.saturating_sub(1),
            start_ns: id * 100,
            dur_ns: 50,
            arg0_key: NO_NAME,
            arg0_val: 0,
            arg1_key: NO_NAME,
            arg1_val: 0,
            note_idx: NO_NAME,
        }
    }

    #[test]
    fn wraparound_drops_oldest_first() {
        let ring = SpanRing::new(4);
        for id in 0..7 {
            ring.record(&ev(id));
        }
        let (events, recorded, dropped) = ring.snapshot();
        assert_eq!(recorded, 7);
        assert_eq!(dropped, 3);
        assert_eq!(events.len(), 4);
        let ids: Vec<u64> = events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![3, 4, 5, 6], "oldest events evicted, order preserved");
    }

    #[test]
    fn under_capacity_returns_everything_in_order() {
        let ring = SpanRing::new(16);
        for id in 0..5 {
            ring.record(&ev(id));
        }
        let (events, recorded, dropped) = ring.snapshot();
        assert_eq!((recorded, dropped), (5, 0));
        assert_eq!(events.iter().map(|e| e.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert_eq!(events[0].start_ns, 0);
        assert_eq!(events[4].start_ns, 400);
    }

    #[test]
    fn concurrent_writers_never_produce_torn_events() {
        use std::sync::Arc;
        let ring = Arc::new(SpanRing::new(8));
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let r = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        let id = t * 1000 + i;
                        // fields correlated so tearing is detectable
                        let e = SpanEvent { start_ns: id * 100, dur_ns: id, ..ev(id) };
                        r.record(&e);
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            for e in ring.snapshot().0 {
                assert_eq!(e.start_ns, e.id * 100, "torn event: {e:?}");
                assert_eq!(e.dur_ns, e.id);
            }
        }
        for w in writers {
            w.join().unwrap();
        }
        let (events, recorded, dropped) = ring.snapshot();
        assert_eq!(recorded, 2000);
        assert_eq!(dropped, 1992);
        assert_eq!(events.len(), 8);
    }

    #[test]
    fn capacity_is_clamped_to_at_least_one() {
        let ring = SpanRing::new(0);
        assert_eq!(ring.capacity(), 1);
        ring.record(&ev(1));
        ring.record(&ev(2));
        let (events, recorded, dropped) = ring.snapshot();
        assert_eq!((recorded, dropped), (2, 1));
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].id, 2);
    }
}
