//! Instantaneous-level gauges: pool queue depth, in-flight jobs, and
//! per-shard [`EstimateCache`](crate::engine::EstimateCache) occupancy.
//!
//! Unlike [`crate::metrics::counters`] (monotonic totals) a gauge moves in
//! both directions, so it can drift if an increment's matching decrement is
//! lost to a panic — [`Gauge::raii`] returns a guard whose `Drop` restores
//! the level even when the guarded job unwinds. Gauges are always live
//! (plain atomics, no enable check): they cost the same as the check would.

use std::sync::atomic::{AtomicI64, Ordering};

/// A named signed instantaneous level.
pub struct Gauge {
    name: &'static str,
    value: AtomicI64,
}

impl Gauge {
    /// A gauge at level 0.
    pub const fn new(name: &'static str) -> Self {
        Self { name, value: AtomicI64::new(0) }
    }

    /// The gauge's dotted name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Move the level by `delta` (may be negative).
    #[inline]
    pub fn add(&self, delta: i64) {
        self.value.fetch_add(delta, Ordering::Relaxed);
    }

    /// Set the level absolutely.
    #[inline]
    pub fn set(&self, value: i64) {
        self.value.store(value, Ordering::Relaxed);
    }

    /// The current level.
    #[inline]
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Increment now, decrement when the returned guard drops — panic-safe
    /// occupancy tracking for scopes that may unwind.
    pub fn raii(&'static self) -> GaugeGuard {
        self.add(1);
        GaugeGuard { gauge: self }
    }
}

/// Decrements its gauge on drop (including during unwinding).
pub struct GaugeGuard {
    gauge: &'static Gauge,
}

impl Drop for GaugeGuard {
    fn drop(&mut self) {
        self.gauge.add(-1);
    }
}

/// Jobs accepted by [`crate::coordinator::Pool::spawn`] but not yet picked
/// up by a worker.
pub static POOL_QUEUE_DEPTH: Gauge = Gauge::new("pool.queue_depth");

/// Jobs currently executing on pool workers.
pub static POOL_INFLIGHT: Gauge = Gauge::new("pool.inflight");

/// TCP serve sessions currently connected (see
/// [`crate::coordinator::net`]).
pub static SERVE_ACTIVE_SESSIONS: Gauge = Gauge::new("serve.active_sessions");

/// Shard count mirrored from the engine's `EstimateCache`.
pub const CACHE_SHARDS: usize = 16;

/// Per-shard entry counts for the global engine's estimate cache, updated
/// after every mutating cache operation when gauging is enabled.
static CACHE_SHARD_ENTRIES: [AtomicI64; CACHE_SHARDS] =
    [const { AtomicI64::new(0) }; CACHE_SHARDS];

/// Publish one cache shard's entry count.
#[inline]
pub fn set_cache_shard(idx: usize, entries: usize) {
    if let Some(g) = CACHE_SHARD_ENTRIES.get(idx) {
        g.store(entries as i64, Ordering::Relaxed);
    }
}

/// All cache shard levels, by shard index.
pub fn cache_shards_snapshot() -> [i64; CACHE_SHARDS] {
    std::array::from_fn(|i| CACHE_SHARD_ENTRIES[i].load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_moves_both_directions() {
        static G: Gauge = Gauge::new("obs.test.gauge");
        assert_eq!(G.name(), "obs.test.gauge");
        G.add(3);
        G.add(-1);
        assert_eq!(G.get(), 2);
        G.set(0);
        assert_eq!(G.get(), 0);
    }

    #[test]
    fn raii_guard_restores_level_on_panic() {
        static G: Gauge = Gauge::new("obs.test.raii");
        {
            let _g = G.raii();
            assert_eq!(G.get(), 1);
        }
        assert_eq!(G.get(), 0);
        let unwound = std::panic::catch_unwind(|| {
            let _g = G.raii();
            panic!("job failed");
        });
        assert!(unwound.is_err());
        assert_eq!(G.get(), 0, "guard must decrement during unwinding");
    }

    #[test]
    fn cache_shard_levels_round_trip() {
        set_cache_shard(3, 42);
        set_cache_shard(CACHE_SHARDS, 99); // out of range: ignored
        let snap = cache_shards_snapshot();
        assert_eq!(snap[3], 42);
        set_cache_shard(3, 0);
    }
}
