//! Low-overhead structured tracing and telemetry for the estimation stack.
//!
//! The paper's headline claim is attributional — a few evaluated loop
//! iterations standing in for billions of instructions — and this module
//! makes the attribution *observable*: where an estimate's wall time goes
//! (mapping vs. lowering vs. steady-state evaluation vs. cache lookup),
//! how the worker pool breathes (queue depth, in-flight jobs), and how the
//! estimate cache fills per shard. Four primitives:
//!
//! 1. **Timed spans** ([`span`] / [`SpanGuard`]) — thread-local nesting
//!    with explicit parent propagation across pool threads
//!    ([`span_with_parent`] + [`current_span_id`]). Every span drop feeds
//!    the histogram registry and the event ring.
//! 2. **Latency histograms** ([`Histogram`]) — per-span-name power-of-two
//!    nanosecond buckets with count / p50 / p95 / max and a *self-time*
//!    column (total minus child spans on the same thread).
//! 3. **A fixed-capacity lock-free event ring** ([`SpanRing`]) — writers
//!    claim slots with one `fetch_add` and publish via a per-slot sequence
//!    counter; when full, the oldest events are overwritten first.
//! 4. **Gauges** ([`gauge`]) — pool queue depth, in-flight jobs, per-shard
//!    [`EstimateCache`](crate::engine::EstimateCache) occupancy.
//!
//! One [`snapshot`] joins all of it with the existing
//! [`crate::metrics::counters`]; [`write_chrome_trace`] exports the ring as
//! Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! **Overhead contract.** Tracing is disabled by default. Disabled, a span
//! is a handful of branches (one relaxed atomic load, no clock read, no
//! interning, no TLS mutation); enabled, the steady-state evaluator path
//! stays allocation-free (`rust/tests/eval_alloc.rs` proves both modes) and
//! estimates are bit-identical because the instrumentation only *reads*
//! clocks — `rust/tests/obs_trace.rs` pins cycle-identity across all four
//! paper architectures. See `docs/observability.md` for the span taxonomy.

pub mod chrome;
pub mod gauge;
pub mod hist;
pub mod ring;
pub mod span;

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{OnceLock, RwLock};
use std::time::Instant;

pub use chrome::{chrome_trace_string, write_chrome_trace};
pub use gauge::Gauge;
pub use hist::{HistSummary, Histogram};
pub use ring::{SpanEvent, SpanRing};
pub use span::{current_span_id, record_duration, span, span_with_parent, SpanGuard};

/// Process-wide enable flag. All span/histogram/ring recording is gated on
/// it; gauges and [`crate::metrics::counters`] stay live regardless (they
/// are plain atomics, as cheap as the flag check itself).
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Enable or disable tracing process-wide. Spans opened while enabled
/// record on drop even if tracing is disabled in between.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// True when tracing is enabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the process's tracing epoch (the first call). All
/// span timestamps share this epoch, so cross-thread event ordering is
/// meaningful. Never allocates.
#[inline]
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

thread_local! {
    /// Cached per-thread id (0 = not yet assigned).
    static TID: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// A small, stable, process-unique id for the calling thread (assigned on
/// first use; `ThreadId` has no stable integer accessor).
pub fn thread_id() -> u32 {
    static NEXT_TID: AtomicU32 = AtomicU32::new(1);
    TID.with(|t| {
        let mut id = t.get();
        if id == 0 {
            id = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(id);
        }
        id
    })
}

/// Interned span/arg name table: names are `&'static str`, events store a
/// `u32` index. Registration (write lock + `Vec` growth) happens once per
/// distinct name; steady-state lookups take the read lock only.
static STRINGS: RwLock<Vec<&'static str>> = RwLock::new(Vec::new());

/// Sentinel index meaning "no name/arg/note".
pub const NO_NAME: u32 = u32::MAX;

/// Intern a static string, returning its stable index.
pub fn intern(s: &'static str) -> u32 {
    {
        let v = STRINGS.read().unwrap();
        if let Some(i) = v.iter().position(|&t| std::ptr::eq(t, s) || t == s) {
            return i as u32;
        }
    }
    let mut v = STRINGS.write().unwrap();
    if let Some(i) = v.iter().position(|&t| t == s) {
        return i as u32;
    }
    v.push(s);
    (v.len() - 1) as u32
}

/// Resolve an interned index back to its string (`"?"` when unknown).
pub fn resolve_name(idx: u32) -> &'static str {
    if idx == NO_NAME {
        return "?";
    }
    STRINGS.read().unwrap().get(idx as usize).copied().unwrap_or("?")
}

/// One span name's aggregate latency summary.
#[derive(Debug, Clone, Copy)]
pub struct SpanSummary {
    /// The span's name.
    pub name: &'static str,
    /// Count/total/self/p50/p95/max over every recorded instance.
    pub summary: HistSummary,
}

/// Point-in-time join of every telemetry surface: the enable flag, ring
/// accounting, the process-wide monotonic counters, gauges, and one
/// latency summary per span name (sorted by name for stable output).
#[derive(Debug, Clone)]
pub struct ObsSnapshot {
    /// Whether tracing is currently enabled.
    pub enabled: bool,
    /// Span events recorded into the ring since process start.
    pub events_recorded: u64,
    /// Events overwritten by ring wraparound (oldest-first).
    pub events_dropped: u64,
    /// Every [`crate::metrics::counters`] counter, in registration order.
    pub counters: Vec<(&'static str, u64)>,
    /// Gauges: pool queue depth / in-flight, per-shard cache occupancy.
    pub gauges: Vec<(String, i64)>,
    /// Per-span-name latency summaries, sorted by name.
    pub spans: Vec<SpanSummary>,
}

/// Serializes unit tests that toggle the process-global enable flag, so
/// concurrently running tests cannot observe each other's toggles.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Take an [`ObsSnapshot`].
pub fn snapshot() -> ObsSnapshot {
    let (recorded, dropped) = ring::global_stats();
    let mut gauges: Vec<(String, i64)> = vec![
        (gauge::POOL_QUEUE_DEPTH.name().to_string(), gauge::POOL_QUEUE_DEPTH.get()),
        (gauge::POOL_INFLIGHT.name().to_string(), gauge::POOL_INFLIGHT.get()),
        (
            gauge::SERVE_ACTIVE_SESSIONS.name().to_string(),
            gauge::SERVE_ACTIVE_SESSIONS.get(),
        ),
    ];
    let shards = gauge::cache_shards_snapshot();
    gauges.push(("cache.entries".to_string(), shards.iter().sum()));
    for (i, v) in shards.iter().enumerate() {
        gauges.push((format!("cache.shard{i:02}.entries"), *v));
    }
    let mut spans: Vec<SpanSummary> = hist::summaries()
        .into_iter()
        .map(|(name, summary)| SpanSummary { name, summary })
        .collect();
    spans.sort_by_key(|s| s.name);
    ObsSnapshot {
        enabled: enabled(),
        events_recorded: recorded,
        events_dropped: dropped,
        counters: crate::metrics::counters::snapshot(),
        gauges,
        spans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_stable_and_content_addressed() {
        let a = intern("obs.test.interning");
        let b = intern("obs.test.interning");
        assert_eq!(a, b);
        assert_eq!(resolve_name(a), "obs.test.interning");
        assert_eq!(resolve_name(NO_NAME), "?");
        assert_eq!(resolve_name(u32::MAX - 1), "?");
        let c = intern("obs.test.other");
        assert_ne!(a, c);
    }

    #[test]
    fn thread_ids_are_stable_per_thread_and_distinct() {
        let here = thread_id();
        assert_eq!(here, thread_id());
        let other = std::thread::spawn(thread_id).join().unwrap();
        assert_ne!(here, other);
        assert!(here > 0 && other > 0);
    }

    #[test]
    fn clock_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn snapshot_joins_counters_and_gauges() {
        let s = snapshot();
        assert!(s.counters.iter().any(|(n, _)| *n == "engine.requests"));
        assert!(s.gauges.iter().any(|(n, _)| n == "pool.queue_depth"));
        assert!(s.gauges.iter().any(|(n, _)| n == "cache.entries"));
        // 16 shards + aggregate + 2 pool gauges
        assert_eq!(s.gauges.len(), 3 + gauge::CACHE_SHARDS);
    }
}
