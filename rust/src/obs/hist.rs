//! Per-span-name latency histograms with power-of-two nanosecond buckets.
//!
//! A [`Histogram`] is 69 atomics — cheap enough to keep one per span name
//! for the life of the process. Bucket 0 holds exactly `{0}` and bucket
//! `i ≥ 1` holds `[2^(i-1), 2^i - 1]`, so `observe` is a `leading_zeros`
//! and one `fetch_add`; quantiles come back as the bucket's upper bound
//! clamped to the recorded maximum (never over-reporting).
//!
//! The registry maps interned span-name indices to `&'static Histogram`s
//! leaked at registration. Registration (once per distinct name, during
//! warmup) takes a write lock and allocates; steady-state lookups take the
//! read lock and scan a short vector — no allocation, which is what lets
//! `rust/tests/eval_alloc.rs` pass with tracing enabled.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Number of buckets: `{0}` plus one per power of two up to `2^63`.
pub const BUCKETS: usize = 65;

/// Lock-free latency histogram (nanoseconds, power-of-two buckets).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    self_ns: AtomicU64,
    max_ns: AtomicU64,
}

/// Bucket index for a duration: 0 for 0 ns, else `64 - leading_zeros`
/// (1 → 1, 2..=3 → 2, `u64::MAX` → 64).
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        64 - ns.leading_zeros() as usize
    }
}

/// Largest duration that lands in bucket `i`.
pub fn bucket_upper_bound(i: usize) -> u64 {
    match i {
        0 => 0,
        1..=63 => (1u64 << i) - 1,
        _ => u64::MAX,
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            self_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one span instance: total duration and its self time (total
    /// minus same-thread child spans). Lock-free, allocation-free.
    #[inline]
    pub fn observe(&self, dur_ns: u64, self_ns: u64) {
        self.buckets[bucket_index(dur_ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(dur_ns, Ordering::Relaxed);
        self.self_ns.fetch_add(self_ns, Ordering::Relaxed);
        self.max_ns.fetch_max(dur_ns, Ordering::Relaxed);
    }

    /// Aggregate view (count, totals, max, p50/p95).
    pub fn summary(&self) -> HistSummary {
        let count = self.count.load(Ordering::Relaxed);
        let max_ns = self.max_ns.load(Ordering::Relaxed);
        HistSummary {
            count,
            total_ns: self.sum_ns.load(Ordering::Relaxed),
            self_ns: self.self_ns.load(Ordering::Relaxed),
            max_ns,
            p50_ns: self.quantile(0.50, count, max_ns),
            p95_ns: self.quantile(0.95, count, max_ns),
        }
    }

    /// Upper-bound quantile: the upper edge of the bucket containing the
    /// rank-`ceil(q·count)` observation, clamped to the recorded max.
    fn quantile(&self, q: f64, count: u64, max_ns: u64) -> u64 {
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper_bound(i).min(max_ns);
            }
        }
        max_ns
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One histogram's aggregate numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSummary {
    /// Recorded span instances.
    pub count: u64,
    /// Sum of all durations (ns).
    pub total_ns: u64,
    /// Sum of self times (duration minus same-thread children, ns).
    pub self_ns: u64,
    /// Largest single duration (ns).
    pub max_ns: u64,
    /// Median (bucket upper bound, clamped to max).
    pub p50_ns: u64,
    /// 95th percentile (bucket upper bound, clamped to max).
    pub p95_ns: u64,
}

/// name-index → histogram registry. Short linear-scan vector: the span
/// taxonomy is a couple dozen names, and scans hold only the read lock.
static REGISTRY: RwLock<Vec<(u32, &'static Histogram)>> = RwLock::new(Vec::new());

/// The histogram for an interned span name, registering (and leaking) it
/// on first use. Steady-state calls never allocate.
pub fn for_name(name_idx: u32) -> &'static Histogram {
    {
        let reg = REGISTRY.read().unwrap();
        if let Some((_, h)) = reg.iter().find(|(i, _)| *i == name_idx) {
            return h;
        }
    }
    let mut reg = REGISTRY.write().unwrap();
    if let Some((_, h)) = reg.iter().find(|(i, _)| *i == name_idx) {
        return h;
    }
    let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
    reg.push((name_idx, h));
    h
}

/// Summaries for every registered span name (unsorted registration order).
pub fn summaries() -> Vec<(&'static str, HistSummary)> {
    REGISTRY
        .read()
        .unwrap()
        .iter()
        .map(|(idx, h)| (super::resolve_name(*idx), h.summary()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper_bound(0), 0);
        assert_eq!(bucket_upper_bound(1), 1);
        assert_eq!(bucket_upper_bound(2), 3);
        assert_eq!(bucket_upper_bound(10), 1023);
        assert_eq!(bucket_upper_bound(64), u64::MAX);
        // every value lands in a bucket whose bound contains it
        for ns in [0u64, 1, 2, 3, 7, 8, 1 << 20, u64::MAX - 1, u64::MAX] {
            let i = bucket_index(ns);
            assert!(ns <= bucket_upper_bound(i));
            if i > 0 {
                assert!(ns > bucket_upper_bound(i - 1));
            }
        }
    }

    #[test]
    fn summary_quantiles_clamp_to_max() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.observe(1000, 900);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.total_ns, 100_000);
        assert_eq!(s.self_ns, 90_000);
        assert_eq!(s.max_ns, 1000);
        // bucket upper bound would be 1023; max clamps it
        assert_eq!(s.p50_ns, 1000);
        assert_eq!(s.p95_ns, 1000);
    }

    #[test]
    fn summary_of_empty_histogram_is_zero() {
        let s = Histogram::new().summary();
        assert_eq!(
            s,
            HistSummary { count: 0, total_ns: 0, self_ns: 0, max_ns: 0, p50_ns: 0, p95_ns: 0 }
        );
    }

    #[test]
    fn quantiles_walk_buckets() {
        let h = Histogram::new();
        // 90 fast (≤15ns bucket), 10 slow (≤1023ns bucket)
        for _ in 0..90 {
            h.observe(10, 10);
        }
        for _ in 0..10 {
            h.observe(600, 600);
        }
        let s = h.summary();
        assert_eq!(s.p50_ns, 15); // bucket [8,15]
        assert_eq!(s.p95_ns, 600); // bucket [512,1023] clamped to max
        assert_eq!(s.max_ns, 600);
    }

    #[test]
    fn zero_duration_observations_count() {
        let h = Histogram::new();
        h.observe(0, 0);
        let s = h.summary();
        assert_eq!(s.count, 1);
        assert_eq!(s.p50_ns, 0);
        assert_eq!(s.max_ns, 0);
    }

    #[test]
    fn registry_returns_same_histogram_for_same_name() {
        let idx = super::super::intern("obs.test.hist_registry");
        let a = for_name(idx) as *const Histogram;
        let b = for_name(idx) as *const Histogram;
        assert_eq!(a, b);
        for_name(idx).observe(5, 5);
        assert!(summaries()
            .iter()
            .any(|(n, s)| *n == "obs.test.hist_registry" && s.count >= 1));
    }
}
