//! Timed spans with thread-local nesting and explicit cross-thread
//! parenting.
//!
//! [`span`] opens a guard that records on drop: total duration into the
//! per-name [`Histogram`](super::Histogram) (together with *self time* —
//! duration minus same-thread child spans) and one [`SpanEvent`] into the
//! global ring. Nesting is a fixed-depth thread-local stack, so opening a
//! span never allocates; work shipped to another thread keeps its logical
//! parent by capturing [`current_span_id`] at submission and opening the
//! job's span with [`span_with_parent`].
//!
//! When tracing is disabled ([`super::enabled`]), constructing and
//! dropping a guard is a few branches: one relaxed flag load, no clock
//! read, no interning, no thread-local traffic.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use super::ring::SpanEvent;
use super::{enabled, hist, now_ns, ring, thread_id, NO_NAME};

/// Maximum same-thread span nesting tracked for self-time accounting.
/// Deeper spans still record, but attribute their time to no parent.
pub const MAX_DEPTH: usize = 64;

/// Process-unique span ids, starting at 1 (0 = "no parent").
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

#[derive(Clone, Copy)]
struct Frame {
    id: u64,
    child_ns: u64,
}

struct StackState {
    depth: usize,
    frames: [Frame; MAX_DEPTH],
}

thread_local! {
    static STACK: RefCell<StackState> = const {
        RefCell::new(StackState { depth: 0, frames: [Frame { id: 0, child_ns: 0 }; MAX_DEPTH] })
    };
}

/// The id of the innermost open span on this thread (0 if none). Capture
/// it before handing work to another thread, then open the remote side
/// with [`span_with_parent`].
pub fn current_span_id() -> u64 {
    if !enabled() {
        return 0;
    }
    STACK.with(|s| {
        let s = s.borrow();
        if s.depth == 0 {
            0
        } else {
            s.frames[s.depth - 1].id
        }
    })
}

/// Open a timed span nested under this thread's innermost open span.
#[must_use = "a span records when the guard drops; binding it to _ ends it immediately"]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard::inert();
    }
    let parent = current_span_id();
    SpanGuard::open(name, parent)
}

/// Open a timed span with an explicit parent id (use 0 for a root). This
/// is the cross-thread variant: the span still joins this thread's nesting
/// stack for self-time accounting, but its recorded parent is `parent`.
#[must_use = "a span records when the guard drops; binding it to _ ends it immediately"]
pub fn span_with_parent(name: &'static str, parent: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard::inert();
    }
    SpanGuard::open(name, parent)
}

/// Record a duration measured externally (no guard, no ring event) into
/// `name`'s histogram. Used for phases timed with raw [`now_ns`] reads on
/// allocation-critical paths where even a ring write is unwanted.
#[inline]
pub fn record_duration(name: &'static str, dur_ns: u64) {
    if !enabled() {
        return;
    }
    hist::for_name(super::intern(name)).observe(dur_ns, dur_ns);
}

/// Live timed span; records on drop. Obtain via [`span`] /
/// [`span_with_parent`].
pub struct SpanGuard {
    active: bool,
    pushed: bool,
    name_idx: u32,
    note_idx: u32,
    id: u64,
    parent: u64,
    start_ns: u64,
    args: [(u32, u64); 2],
    n_args: u8,
}

impl SpanGuard {
    fn inert() -> Self {
        Self {
            active: false,
            pushed: false,
            name_idx: NO_NAME,
            note_idx: NO_NAME,
            id: 0,
            parent: 0,
            start_ns: 0,
            args: [(NO_NAME, 0); 2],
            n_args: 0,
        }
    }

    fn open(name: &'static str, parent: u64) -> Self {
        let name_idx = super::intern(name);
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let pushed = STACK.with(|s| {
            let mut s = s.borrow_mut();
            if s.depth < MAX_DEPTH {
                let d = s.depth;
                s.frames[d] = Frame { id, child_ns: 0 };
                s.depth = d + 1;
                true
            } else {
                false
            }
        });
        Self {
            active: true,
            pushed,
            name_idx,
            note_idx: NO_NAME,
            id,
            parent,
            start_ns: now_ns(),
            args: [(NO_NAME, 0); 2],
            n_args: 0,
        }
    }

    /// This span's id (0 when tracing was disabled at open).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Attach a `key=value` integer argument (up to two per span; extra
    /// arguments are dropped). No-op on an inert guard.
    pub fn arg(&mut self, key: &'static str, value: u64) {
        if self.active && (self.n_args as usize) < self.args.len() {
            self.args[self.n_args as usize] = (super::intern(key), value);
            self.n_args += 1;
        }
    }

    /// Attach a provenance note (e.g. `"hit"`, `"evaluated"`), replacing
    /// any earlier one. No-op on an inert guard.
    pub fn note(&mut self, note: &'static str) {
        if self.active {
            self.note_idx = super::intern(note);
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let dur_ns = now_ns().saturating_sub(self.start_ns);
        let mut child_ns = 0;
        if self.pushed {
            // pop our frame; credit our duration to the new top's children
            let _ = STACK.try_with(|s| {
                let mut s = s.borrow_mut();
                if s.depth > 0 {
                    let d = s.depth - 1;
                    child_ns = s.frames[d].child_ns;
                    s.depth = d;
                    if d > 0 {
                        s.frames[d - 1].child_ns += dur_ns;
                    }
                }
            });
        }
        hist::for_name(self.name_idx).observe(dur_ns, dur_ns.saturating_sub(child_ns));
        ring::record_global(&SpanEvent {
            name_idx: self.name_idx,
            tid: thread_id(),
            id: self.id,
            parent: self.parent,
            start_ns: self.start_ns,
            dur_ns,
            arg0_key: self.args[0].0,
            arg0_val: self.args[0].1,
            arg1_key: self.args[1].0,
            arg1_val: self.args[1].1,
            note_idx: self.note_idx,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        let _lock = super::super::test_lock();
        super::super::set_enabled(false);
        let mut g = span("obs.test.inert");
        assert_eq!(g.id(), 0);
        g.arg("k", 1);
        g.note("n");
        assert_eq!(current_span_id(), 0);
        drop(g);
        assert!(
            !hist::summaries().iter().any(|(n, _)| *n == "obs.test.inert"),
            "inert span must not register a histogram"
        );
    }

    #[test]
    fn nesting_attributes_self_time_and_parents() {
        let _lock = super::super::test_lock();
        super::super::set_enabled(true);
        let events_before = ring::global_stats().0;
        let (outer_id, inner_id);
        {
            let outer = span("obs.test.outer");
            outer_id = outer.id();
            assert_eq!(current_span_id(), outer_id);
            {
                let mut inner = span("obs.test.inner");
                inner_id = inner.id();
                inner.arg("k", 42);
                inner.note("evaluated");
                assert_eq!(current_span_id(), inner_id);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            assert_eq!(current_span_id(), outer_id);
        }
        assert!(outer_id > 0 && inner_id > outer_id);
        assert!(ring::global_stats().0 >= events_before + 2);

        let events = ring::events();
        let inner_ev = events.iter().find(|e| e.id == inner_id).expect("inner recorded");
        let outer_ev = events.iter().find(|e| e.id == outer_id).expect("outer recorded");
        assert_eq!(inner_ev.parent, outer_id);
        assert_eq!(inner_ev.name(), "obs.test.inner");
        assert_eq!(inner_ev.note(), Some("evaluated"));
        assert_eq!(super::super::resolve_name(inner_ev.arg0_key), "k");
        assert_eq!(inner_ev.arg0_val, 42);
        assert_eq!(outer_ev.note(), None);
        assert!(outer_ev.dur_ns >= inner_ev.dur_ns);
        assert!(inner_ev.start_ns >= outer_ev.start_ns);

        // outer's self time excludes inner's duration
        let summaries = hist::summaries();
        let outer_sum = summaries.iter().find(|(n, _)| *n == "obs.test.outer").unwrap().1;
        assert!(outer_sum.self_ns <= outer_sum.total_ns);
        assert!(
            outer_sum.total_ns - outer_sum.self_ns >= 1_000_000,
            "inner's ~2ms must be attributed to outer's children"
        );
        super::super::set_enabled(false);
    }

    #[test]
    fn explicit_parent_crosses_threads() {
        let _lock = super::super::test_lock();
        super::super::set_enabled(true);
        let root = span("obs.test.xthread_root");
        let root_id = root.id();
        let child_id = std::thread::spawn(move || {
            let g = span_with_parent("obs.test.xthread_child", root_id);
            g.id()
        })
        .join()
        .unwrap();
        drop(root);
        let events = ring::events();
        let child = events.iter().find(|e| e.id == child_id).expect("child recorded");
        assert_eq!(child.parent, root_id);
        let root_ev = events.iter().find(|e| e.id == root_id).expect("root recorded");
        assert_ne!(child.tid, root_ev.tid);
        super::super::set_enabled(false);
    }
}
