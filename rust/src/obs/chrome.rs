//! Chrome trace-event JSON export of the span ring.
//!
//! Emits the "JSON Object Format" understood by Perfetto and
//! `chrome://tracing`: a `traceEvents` array of complete (`"ph":"X"`)
//! duration events with microsecond timestamps. Span ids, parents, integer
//! arguments and provenance notes ride along in each event's `args`, so a
//! pooled estimate's cross-thread structure is recoverable in the viewer.
//! Hand-rolled serialization — the crate deliberately has no serde.

use std::io::{self, Write};

use super::ring::{self, SpanEvent};
use super::NO_NAME;

/// Append a JSON-escaped string literal (with quotes) to `out`.
fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nanoseconds → microseconds with three decimals, as exact decimal text
/// (Chrome's `ts`/`dur` unit is µs; three decimals preserves full ns
/// resolution without float rounding).
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

fn push_event(out: &mut String, ev: &SpanEvent) {
    out.push_str("{\"name\":");
    push_json_str(out, ev.name());
    out.push_str(",\"cat\":\"obs\",\"ph\":\"X\",\"ts\":");
    out.push_str(&us(ev.start_ns));
    out.push_str(",\"dur\":");
    out.push_str(&us(ev.dur_ns));
    out.push_str(",\"pid\":1,\"tid\":");
    out.push_str(&ev.tid.to_string());
    out.push_str(",\"args\":{\"span_id\":");
    out.push_str(&ev.id.to_string());
    out.push_str(",\"parent\":");
    out.push_str(&ev.parent.to_string());
    for (key, val) in [(ev.arg0_key, ev.arg0_val), (ev.arg1_key, ev.arg1_val)] {
        if key != NO_NAME {
            out.push(',');
            push_json_str(out, super::resolve_name(key));
            out.push(':');
            out.push_str(&val.to_string());
        }
    }
    if ev.note_idx != NO_NAME {
        out.push_str(",\"note\":");
        push_json_str(out, super::resolve_name(ev.note_idx));
    }
    out.push_str("}}");
}

/// The global ring's retained events as a Chrome trace JSON document.
pub fn chrome_trace_string() -> String {
    let events = ring::events();
    let mut out = String::with_capacity(64 + events.len() * 160);
    out.push_str("{\"traceEvents\":[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event(&mut out, ev);
    }
    out.push_str("],\"displayTimeUnit\":\"ns\"}\n");
    out
}

/// Write the global ring's retained events as Chrome trace JSON.
pub fn write_chrome_trace(w: &mut impl Write) -> io::Result<()> {
    w.write_all(chrome_trace_string().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_string_escaping() {
        let mut s = String::new();
        push_json_str(&mut s, "a\"b\\c\nd\te\u{1}");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\te\\u0001\"");
    }

    #[test]
    fn microsecond_formatting_is_exact() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(1), "0.001");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(1000), "1.000");
        assert_eq!(us(1_234_567), "1234.567");
    }

    #[test]
    fn events_serialize_with_required_keys() {
        let ev = SpanEvent {
            name_idx: super::super::intern("obs.test.chrome"),
            tid: 3,
            id: 17,
            parent: 5,
            start_ns: 2500,
            dur_ns: 1500,
            arg0_key: super::super::intern("k"),
            arg0_val: 9,
            arg1_key: NO_NAME,
            arg1_val: 0,
            note_idx: super::super::intern("hit"),
        };
        let mut s = String::new();
        push_event(&mut s, &ev);
        assert!(s.contains("\"name\":\"obs.test.chrome\""));
        assert!(s.contains("\"ph\":\"X\""));
        assert!(s.contains("\"ts\":2.500"));
        assert!(s.contains("\"dur\":1.500"));
        assert!(s.contains("\"pid\":1"));
        assert!(s.contains("\"tid\":3"));
        assert!(s.contains("\"span_id\":17"));
        assert!(s.contains("\"parent\":5"));
        assert!(s.contains("\"k\":9"));
        assert!(s.contains("\"note\":\"hit\""));
    }

    #[test]
    fn trace_document_wraps_events() {
        let doc = chrome_trace_string();
        assert!(doc.starts_with("{\"traceEvents\":["));
        assert!(doc.trim_end().ends_with("],\"displayTimeUnit\":\"ns\"}"));
    }
}
