//! DNN layer IR.
//!
//! Only layer *hyper-parameters* matter for performance estimation: the
//! instruction streams of the paper's mappings are data independent (§6.3),
//! so the IR carries shapes, channels, kernels, and strides — never weights.
//! Covered layer types (paper §7): 1D/2D/depth-wise convolution,
//! fully-connected, average/max pooling, ReLU/clip activation, element-wise
//! add/mul (residual connections appear as Add layers).

/// Activation function of an [`LayerKind::Act`] layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    /// Rectified linear unit.
    Relu,
    /// Clipping activation (UltraTrail / TC-ResNet style).
    Clip,
}

/// Pooling operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    /// Max pooling.
    Max,
    /// Average pooling.
    Avg,
}

/// Layer hyper-parameters. Spatial sizes are *output-producing* inputs
/// (already padded where `pad` says so).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    /// 1D convolution over (c_in, l_in) producing (c_out, l_out).
    Conv1d {
        /// Input channels.
        c_in: u32,
        /// Input length.
        l_in: u32,
        /// Output channels.
        c_out: u32,
        /// Kernel width.
        kernel: u32,
        /// Stride.
        stride: u32,
        /// Same-padding (pad by `kernel - 1`).
        pad: bool,
    },
    /// 2D convolution over (c_in, h, w).
    Conv2d {
        /// Input channels.
        c_in: u32,
        /// Input height.
        h: u32,
        /// Input width.
        w: u32,
        /// Output channels.
        c_out: u32,
        /// Kernel height.
        kh: u32,
        /// Kernel width.
        kw: u32,
        /// Stride.
        stride: u32,
        /// Same-padding (pad by `kernel - 1`).
        pad: bool,
    },
    /// Depth-wise 2D convolution (one filter per channel).
    DwConv2d {
        /// Channels (preserved).
        c: u32,
        /// Input height.
        h: u32,
        /// Input width.
        w: u32,
        /// Kernel height.
        kh: u32,
        /// Kernel width.
        kw: u32,
        /// Stride.
        stride: u32,
        /// Same-padding (pad by `kernel - 1`).
        pad: bool,
    },
    /// Fully connected: c_in → c_out.
    Dense {
        /// Input features.
        c_in: u32,
        /// Output features.
        c_out: u32,
    },
    /// 2D pooling over (c, h, w).
    Pool2d {
        /// Max or average.
        kind: PoolKind,
        /// Channels.
        c: u32,
        /// Input height.
        h: u32,
        /// Input width.
        w: u32,
        /// Window size.
        k: u32,
        /// Stride.
        stride: u32,
    },
    /// 1D pooling over (c, l).
    Pool1d {
        /// Max or average.
        kind: PoolKind,
        /// Channels.
        c: u32,
        /// Input length.
        l: u32,
        /// Window size.
        k: u32,
        /// Stride.
        stride: u32,
    },
    /// Element-wise activation over `c` channels × `spatial` positions.
    Act {
        /// Activation function.
        kind: ActKind,
        /// Channels.
        c: u32,
        /// Spatial positions per channel.
        spatial: u32,
    },
    /// Element-wise addition of two (c, spatial) tensors (residual join).
    Add {
        /// Channels.
        c: u32,
        /// Spatial positions per channel.
        spatial: u32,
    },
    /// Element-wise multiplication (e.g. squeeze-excite scaling).
    Mul {
        /// Channels.
        c: u32,
        /// Spatial positions per channel.
        spatial: u32,
    },
}

/// A named layer instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// Layer name.
    pub name: String,
    /// Hyper-parameters.
    pub kind: LayerKind,
}

/// Output length of a conv/pool window along one axis.
pub fn out_dim(i: u32, k: u32, stride: u32, pad: bool) -> u32 {
    let eff = if pad { i + (k - 1) } else { i };
    if eff < k {
        return 0;
    }
    (eff - k) / stride + 1
}

impl Layer {
    /// A named layer.
    pub fn new(name: impl Into<String>, kind: LayerKind) -> Self {
        Self { name: name.into(), kind }
    }

    /// Multiply-accumulate operations (element-wise ops count one op per
    /// element; pooling counts one op per covered input element).
    pub fn macs(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv1d { c_in, l_in, c_out, kernel, stride, pad } => {
                let lo = out_dim(*l_in, *kernel, *stride, *pad) as u64;
                *c_in as u64 * *c_out as u64 * *kernel as u64 * lo
            }
            LayerKind::Conv2d { c_in, h, w, c_out, kh, kw, stride, pad } => {
                let ho = out_dim(*h, *kh, *stride, *pad) as u64;
                let wo = out_dim(*w, *kw, *stride, *pad) as u64;
                *c_in as u64 * *c_out as u64 * *kh as u64 * *kw as u64 * ho * wo
            }
            LayerKind::DwConv2d { c, h, w, kh, kw, stride, pad } => {
                let ho = out_dim(*h, *kh, *stride, *pad) as u64;
                let wo = out_dim(*w, *kw, *stride, *pad) as u64;
                *c as u64 * *kh as u64 * *kw as u64 * ho * wo
            }
            LayerKind::Dense { c_in, c_out } => *c_in as u64 * *c_out as u64,
            LayerKind::Pool2d { c, h, w, k, stride, .. } => {
                let ho = out_dim(*h, *k, *stride, false) as u64;
                let wo = out_dim(*w, *k, *stride, false) as u64;
                *c as u64 * ho * wo * (*k as u64 * *k as u64)
            }
            LayerKind::Pool1d { c, l, k, stride, .. } => {
                let lo = out_dim(*l, *k, *stride, false) as u64;
                *c as u64 * lo * *k as u64
            }
            LayerKind::Act { c, spatial, .. }
            | LayerKind::Add { c, spatial }
            | LayerKind::Mul { c, spatial } => *c as u64 * *spatial as u64,
        }
    }

    /// Input activation words.
    pub fn in_words(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv1d { c_in, l_in, .. } => *c_in as u64 * *l_in as u64,
            LayerKind::Conv2d { c_in, h, w, .. } => *c_in as u64 * *h as u64 * *w as u64,
            LayerKind::DwConv2d { c, h, w, .. } => *c as u64 * *h as u64 * *w as u64,
            LayerKind::Dense { c_in, .. } => *c_in as u64,
            LayerKind::Pool2d { c, h, w, .. } => *c as u64 * *h as u64 * *w as u64,
            LayerKind::Pool1d { c, l, .. } => *c as u64 * *l as u64,
            LayerKind::Act { c, spatial, .. } => *c as u64 * *spatial as u64,
            // two operands
            LayerKind::Add { c, spatial } | LayerKind::Mul { c, spatial } => {
                2 * *c as u64 * *spatial as u64
            }
        }
    }

    /// Weight words (0 for weight-less layers).
    pub fn weight_words(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv1d { c_in, c_out, kernel, .. } => {
                *c_in as u64 * *c_out as u64 * *kernel as u64
            }
            LayerKind::Conv2d { c_in, c_out, kh, kw, .. } => {
                *c_in as u64 * *c_out as u64 * *kh as u64 * *kw as u64
            }
            LayerKind::DwConv2d { c, kh, kw, .. } => *c as u64 * *kh as u64 * *kw as u64,
            LayerKind::Dense { c_in, c_out } => *c_in as u64 * *c_out as u64,
            _ => 0,
        }
    }

    /// Output words.
    pub fn out_words(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv1d { l_in, c_out, kernel, stride, pad, .. } => {
                *c_out as u64 * out_dim(*l_in, *kernel, *stride, *pad) as u64
            }
            LayerKind::Conv2d { h, w, c_out, kh, kw, stride, pad, .. } => {
                let ho = out_dim(*h, *kh, *stride, *pad) as u64;
                let wo = out_dim(*w, *kw, *stride, *pad) as u64;
                *c_out as u64 * ho * wo
            }
            LayerKind::DwConv2d { c, h, w, kh, kw, stride, pad } => {
                let ho = out_dim(*h, *kh, *stride, *pad) as u64;
                let wo = out_dim(*w, *kw, *stride, *pad) as u64;
                *c as u64 * ho * wo
            }
            LayerKind::Dense { c_out, .. } => *c_out as u64,
            LayerKind::Pool2d { c, h, w, k, stride, .. } => {
                let ho = out_dim(*h, *k, *stride, false) as u64;
                let wo = out_dim(*w, *k, *stride, false) as u64;
                *c as u64 * ho * wo
            }
            LayerKind::Pool1d { c, l, k, stride, .. } => {
                *c as u64 * out_dim(*l, *k, *stride, false) as u64
            }
            LayerKind::Act { c, spatial, .. }
            | LayerKind::Add { c, spatial }
            | LayerKind::Mul { c, spatial } => *c as u64 * *spatial as u64,
        }
    }

    /// True for layers that lower to a GEMM (conv via im2col, dense
    /// directly).
    pub fn is_gemm_like(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv1d { .. } | LayerKind::Conv2d { .. } | LayerKind::Dense { .. }
        )
    }

    /// GEMM dimensions (M, K, N) after im2col: M = output positions,
    /// K = c_in × kernel volume, N = output channels. Depth-wise conv maps
    /// per-channel (M = positions, K = kernel volume, N = 1) × c channels.
    pub fn gemm_dims(&self) -> Option<(u64, u64, u64)> {
        match &self.kind {
            LayerKind::Conv1d { c_in, l_in, c_out, kernel, stride, pad } => {
                let m = out_dim(*l_in, *kernel, *stride, *pad) as u64;
                Some((m, *c_in as u64 * *kernel as u64, *c_out as u64))
            }
            LayerKind::Conv2d { c_in, h, w, c_out, kh, kw, stride, pad } => {
                let m = out_dim(*h, *kh, *stride, *pad) as u64
                    * out_dim(*w, *kw, *stride, *pad) as u64;
                Some((m, *c_in as u64 * *kh as u64 * *kw as u64, *c_out as u64))
            }
            LayerKind::Dense { c_in, c_out } => Some((1, *c_in as u64, *c_out as u64)),
            _ => None,
        }
    }
}

/// An ordered network of layers. Residual topology is already flattened:
/// joins appear as `Add` layers with their operand shapes.
#[derive(Debug, Clone)]
pub struct Network {
    /// Network name.
    pub name: String,
    /// Layers in order (residual joins flattened to `Add`).
    pub layers: Vec<Layer>,
}

impl Network {
    /// An empty network named `name`.
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), layers: Vec::new() }
    }

    /// Append a layer (builder style).
    pub fn push(&mut self, layer: Layer) -> &mut Self {
        self.layers.push(layer);
        self
    }

    /// Total multiply-accumulate operations.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Number of layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dim_padding_and_stride() {
        assert_eq!(out_dim(32, 3, 1, true), 32); // same-pad
        assert_eq!(out_dim(32, 3, 1, false), 30);
        assert_eq!(out_dim(224, 11, 4, false), 54);
        assert_eq!(out_dim(2, 3, 1, false), 0); // too small
    }

    #[test]
    fn conv2d_macs_hand_calc() {
        // AlexNet conv1 (no pad): 3*96*11*11*54*54
        let l = Layer::new(
            "conv1",
            LayerKind::Conv2d { c_in: 3, h: 224, w: 224, c_out: 96, kh: 11, kw: 11, stride: 4, pad: false },
        );
        assert_eq!(l.macs(), 3 * 96 * 11 * 11 * 54 * 54);
        assert_eq!(l.out_words(), 96 * 54 * 54);
        assert_eq!(l.weight_words(), 3 * 96 * 11 * 11);
        assert_eq!(l.gemm_dims(), Some((54 * 54, 3 * 11 * 11, 96)));
    }

    #[test]
    fn dense_is_degenerate_gemm() {
        let l = Layer::new("fc", LayerKind::Dense { c_in: 256, c_out: 10 });
        assert_eq!(l.macs(), 2560);
        assert_eq!(l.gemm_dims(), Some((1, 256, 10)));
        assert!(l.is_gemm_like());
    }

    #[test]
    fn dwconv_macs() {
        let l = Layer::new(
            "dw",
            LayerKind::DwConv2d { c: 32, h: 16, w: 16, kh: 3, kw: 3, stride: 1, pad: true },
        );
        assert_eq!(l.macs(), 32 * 9 * 16 * 16);
        assert_eq!(l.gemm_dims(), None);
    }

    #[test]
    fn elementwise_words() {
        let a = Layer::new("add", LayerKind::Add { c: 24, spatial: 13 });
        assert_eq!(a.macs(), 24 * 13);
        assert_eq!(a.in_words(), 2 * 24 * 13);
        assert_eq!(a.out_words(), 24 * 13);
        assert!(!a.is_gemm_like());
    }

    #[test]
    fn network_aggregates() {
        let mut n = Network::new("n");
        n.push(Layer::new("a", LayerKind::Dense { c_in: 4, c_out: 4 }));
        n.push(Layer::new("b", LayerKind::Dense { c_in: 4, c_out: 2 }));
        assert_eq!(n.total_macs(), 16 + 8);
        assert_eq!(n.num_layers(), 2);
    }
}
