//! Shape inference and semantic validation of expanded network
//! descriptions — and, when clean, compilation into a [`Network`].
//!
//! Every check reports a [`Diagnostic`] with the span of the offending
//! field, so `acadl-perf check` prints `file:line:col: error: ...` lines.
//! Checked here (errors unless noted):
//!
//! - **references**: `from`/`with` naming a layer or input that does not
//!   exist (or is declared later — only backward references resolve),
//!   duplicate layer/input names, a first layer with nothing to chain from;
//! - **shapes**: 1-D layers on 2-D/flat tensors (and vice versa), windows
//!   that produce no output (`kernel` exceeding the padded input),
//!   `add`/`mul` operands whose channels differ or whose spatial sizes
//!   neither match nor broadcast;
//! - **values**: non-positive or out-of-`u32`-range channels / kernels /
//!   strides / feature counts, unknown parameters in expressions, division
//!   by zero;
//! - **structure**: a description with no layers, a missing `[net]`
//!   section, duplicate parameters, (warning) parameters shadowing builtin
//!   shape names, (warning) inputs no layer consumes.
//!
//! Shape inference threads a tensor shape (1-D, 2-D, or flat) through the
//! layer chain; the builtins `in_channels` / `in_len` / `in_h` / `in_w` /
//! `in_spatial` / `in_features` expose the inferred input of each layer to
//! its attribute expressions. A layer that fails any check *poisons* its
//! output shape: consumers are skipped silently instead of cascading
//! secondary diagnostics.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::fmt;

use crate::acadl::text::Diagnostic;
use crate::dnn::layer::{out_dim, Layer, LayerKind, Network};

use super::ast::{InputShape, LayerBody, LayerDecl, NetDescription, PExpr, Span, Spanned, Template};
use super::compile::LayerInstance;

/// Expression names reserved for the per-layer shape builtins.
pub const SHAPE_BUILTINS: &[&str] =
    &["in_channels", "in_len", "in_h", "in_w", "in_spatial", "in_features"];

/// An inferred tensor shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// Channels × length (1-D convolutional layout).
    OneD {
        /// Channels.
        c: u32,
        /// Length.
        l: u32,
    },
    /// Channels × height × width.
    TwoD {
        /// Channels.
        c: u32,
        /// Height.
        h: u32,
        /// Width.
        w: u32,
    },
    /// Channel vector with no spatial extent (dense outputs).
    Flat {
        /// Channels.
        c: u32,
    },
}

impl Shape {
    /// Channel count.
    pub fn channels(&self) -> u32 {
        match self {
            Shape::OneD { c, .. } | Shape::TwoD { c, .. } | Shape::Flat { c } => *c,
        }
    }

    /// Product of the spatial dimensions (1 for flat tensors).
    pub fn spatial(&self) -> u64 {
        match self {
            Shape::OneD { l, .. } => *l as u64,
            Shape::TwoD { h, w, .. } => *h as u64 * *w as u64,
            Shape::Flat { .. } => 1,
        }
    }

    /// Total element count (`channels × spatial`) — what `dense` flattens.
    pub fn features(&self) -> u64 {
        self.channels() as u64 * self.spatial()
    }

    /// Value of one shape builtin, if defined for this shape.
    fn builtin(&self, name: &str) -> Option<i64> {
        match (name, self) {
            ("in_channels", s) => Some(s.channels() as i64),
            ("in_spatial", s) => Some(s.spatial() as i64),
            ("in_features", s) => Some(s.features() as i64),
            ("in_len", Shape::OneD { l, .. }) => Some(*l as i64),
            ("in_h", Shape::TwoD { h, .. }) => Some(*h as i64),
            ("in_w", Shape::TwoD { w, .. }) => Some(*w as i64),
            _ => None,
        }
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Shape::OneD { c, l } => write!(f, "{c}x{l} (1-D)"),
            Shape::TwoD { c, h, w } => write!(f, "{c}x{h}x{w} (2-D)"),
            Shape::Flat { c } => write!(f, "{c} (flat)"),
        }
    }
}

/// Infer shapes over the expanded layer list and build the [`Network`].
/// Appends every diagnostic to `diags`; returns `Some` iff `diags` holds
/// no error at all afterwards — pre-existing expansion errors also poison
/// the result (their instances are missing, so the network would be
/// silently truncated).
pub fn infer(
    desc: &NetDescription,
    instances: &[LayerInstance<'_>],
    diags: &mut Vec<Diagnostic>,
) -> Option<Network> {
    // ---- parameters ---------------------------------------------------------
    let mut params: BTreeMap<String, i64> = BTreeMap::new();
    for p in &desc.params {
        if SHAPE_BUILTINS.contains(&p.name.node.as_str()) {
            diags.push(Diagnostic::warning(
                p.name.span,
                format!("parameter `{}` shadows a builtin shape name", p.name.node),
            ));
        }
        if params.insert(p.name.node.clone(), p.value.node).is_some() {
            diags.push(Diagnostic::error(
                p.name.span,
                format!("duplicate parameter `{}`", p.name.node),
            ));
        }
    }

    // ---- network name -------------------------------------------------------
    let name = match &desc.name {
        Some(t) => match t.render(&|n| params.get(n).copied()) {
            Ok(n) => n,
            Err(e) => {
                diags.push(Diagnostic::error(t.span, e));
                "net".to_string()
            }
        },
        None => {
            diags.push(Diagnostic::error(
                Span::default(),
                "missing [net] section with `name = \"...\"`",
            ));
            "net".to_string()
        }
    };

    // ---- inputs -------------------------------------------------------------
    // name → inferred shape; `None` marks a poisoned (errored) producer
    let mut shapes: HashMap<String, Option<Shape>> = HashMap::new();
    let mut input_names: Vec<(String, Span)> = Vec::new();
    for input in &desc.inputs {
        let iname = match input.name.render(&|n| params.get(n).copied()) {
            Ok(n) => n,
            Err(e) => {
                diags.push(Diagnostic::error(input.name.span, e));
                continue;
            }
        };
        let shape = (|| -> Option<Shape> {
            let c = eval_dim(&input.channels, "channels", &params, diags)?;
            match &input.shape {
                InputShape::OneD { length } => {
                    let l = eval_dim(length, "length", &params, diags)?;
                    Some(Shape::OneD { c, l })
                }
                InputShape::TwoD { height, width } => {
                    let h = eval_dim(height, "height", &params, diags)?;
                    let w = eval_dim(width, "width", &params, diags)?;
                    Some(Shape::TwoD { c, h, w })
                }
            }
        })();
        if shapes.contains_key(&iname) {
            diags.push(Diagnostic::error(
                input.name.span,
                format!("duplicate input name `{iname}`"),
            ));
            continue;
        }
        shapes.insert(iname.clone(), shape);
        input_names.push((iname, input.span));
    }

    // ---- layers -------------------------------------------------------------
    let mut used: HashSet<String> = HashSet::new();
    let mut layers: Vec<Layer> = Vec::new();
    // (name, shape) of the most recently declared layer — the implicit input
    let mut prev: Option<(String, Option<Shape>)> = None;

    for inst in instances {
        let decl = inst.decl;
        let lookup = |n: &str| -> Option<i64> {
            if let Some(&(_, v)) = inst.vars.iter().rev().find(|(name, _)| name == n) {
                return Some(v);
            }
            if n == "idx" {
                return Some(inst.idx);
            }
            params.get(n).copied()
        };

        let lname = match decl.name.render(&lookup) {
            Ok(n) => n,
            Err(e) => {
                diags.push(Diagnostic::error(decl.name.span, e));
                prev = Some((format!("<unnamed layer at {}>", decl.span), None));
                continue;
            }
        };

        // resolve the first operand
        let in_shape = match &decl.from {
            Some(t) => resolve_ref(t, &lookup, &shapes, &mut used, diags),
            None => match &prev {
                Some((pname, shape)) => {
                    used.insert(pname.clone());
                    *shape // None = poisoned producer, already diagnosed
                }
                None => match input_names.first() {
                    Some((iname, _)) => {
                        used.insert(iname.clone());
                        shapes.get(iname).copied().flatten()
                    }
                    None => {
                        diags.push(Diagnostic::error(
                            decl.span,
                            format!(
                                "layer `{lname}` has nothing to chain from \
                                 (declare an [[input]] or set `from`)"
                            ),
                        ));
                        None
                    }
                },
            },
        };

        // resolve the second operand (add/mul)
        let with_shape = decl
            .with
            .as_ref()
            .map(|t| resolve_ref(t, &lookup, &shapes, &mut used, diags));

        let out_shape = build_layer(
            decl,
            &lname,
            in_shape,
            with_shape,
            &lookup,
            &mut layers,
            diags,
        );

        if shapes.contains_key(&lname) {
            diags.push(Diagnostic::error(
                decl.name.span,
                format!("duplicate layer name `{lname}`"),
            ));
        } else {
            shapes.insert(lname.clone(), out_shape);
        }
        prev = Some((lname, out_shape));
    }

    if instances.is_empty() {
        diags.push(Diagnostic::error(Span::default(), "description declares no layers"));
    }
    for (iname, span) in &input_names {
        if !used.contains(iname) {
            diags.push(Diagnostic::warning(
                *span,
                format!("input `{iname}` is never consumed by a layer"),
            ));
        }
    }

    if diags.iter().any(|d| d.is_error()) {
        return None;
    }
    let mut net = Network::new(name);
    net.layers = layers;
    Some(net)
}

/// Evaluate an input dimension with params-only lookup; 1..=u32::MAX.
fn eval_dim(
    e: &Spanned<PExpr>,
    what: &str,
    params: &BTreeMap<String, i64>,
    diags: &mut Vec<Diagnostic>,
) -> Option<u32> {
    match e.node.eval(&|n| params.get(n).copied()) {
        Ok(v) if (1..=u32::MAX as i64).contains(&v) => Some(v as u32),
        Ok(v) => {
            diags.push(Diagnostic::error(
                e.span,
                format!("`{what}` must be in 1..=2^32-1, got {v}"),
            ));
            None
        }
        Err(msg) => {
            diags.push(Diagnostic::error(e.span, msg));
            None
        }
    }
}

/// Resolve a `from`/`with` reference to a declared layer or input. Marks
/// the producer as used; unknown names are errors, poisoned producers
/// resolve to `None` without a diagnostic.
fn resolve_ref(
    t: &Template,
    lookup: &dyn Fn(&str) -> Option<i64>,
    shapes: &HashMap<String, Option<Shape>>,
    used: &mut HashSet<String>,
    diags: &mut Vec<Diagnostic>,
) -> Option<Shape> {
    let rname = match t.render(lookup) {
        Ok(n) => n,
        Err(e) => {
            diags.push(Diagnostic::error(t.span, e));
            return None;
        }
    };
    match shapes.get(&rname) {
        Some(shape) => {
            used.insert(rname);
            *shape
        }
        None => {
            diags.push(Diagnostic::error(
                t.span,
                format!(
                    "unknown layer or input `{rname}` \
                     (only inputs and earlier layers can be referenced)"
                ),
            ));
            None
        }
    }
}

/// Evaluate one attribute expression against loop vars, params, and the
/// layer's input-shape builtins; require the value in `lo..=u32::MAX`.
fn eval_attr(
    e: &Spanned<PExpr>,
    what: &str,
    lo: i64,
    lookup: &dyn Fn(&str) -> Option<i64>,
    in_shape: &Shape,
    diags: &mut Vec<Diagnostic>,
) -> Option<u32> {
    let full = |n: &str| -> Option<i64> {
        // loop variables and parameters win over builtins (shadowing is
        // warned about at the [params] declaration)
        lookup(n).or_else(|| in_shape.builtin(n))
    };
    match e.node.eval(&full) {
        Ok(v) if (lo..=u32::MAX as i64).contains(&v) => Some(v as u32),
        Ok(v) => {
            diags.push(Diagnostic::error(
                e.span,
                format!("`{what}` must be in {lo}..=2^32-1, got {v}"),
            ));
            None
        }
        Err(msg) => {
            diags.push(Diagnostic::error(e.span, msg));
            None
        }
    }
}

/// Check one layer instance against its operand shapes, push the compiled
/// [`Layer`], and return its output shape (`None` = poisoned).
#[allow(clippy::too_many_arguments)]
fn build_layer(
    decl: &LayerDecl,
    lname: &str,
    in_shape: Option<Shape>,
    with_shape: Option<Option<Shape>>,
    lookup: &dyn Fn(&str) -> Option<i64>,
    layers: &mut Vec<Layer>,
    diags: &mut Vec<Diagnostic>,
) -> Option<Shape> {
    let kind = decl.body.kind_name();
    // a poisoned operand: skip silently (its producer already diagnosed)
    let input = in_shape?;
    if decl.body.takes_with() && with_shape.as_ref().is_some_and(|w| w.is_none()) {
        return None;
    }

    // helpers take `diags` explicitly (capturing it would hold a mutable
    // borrow across the eval_attr calls below)
    let need = |ok: bool, what: &str, diags: &mut Vec<Diagnostic>| -> Option<()> {
        if ok {
            Some(())
        } else {
            diags.push(Diagnostic::error(
                decl.span,
                format!("{kind} needs a {what} input, but `{lname}` receives {input}"),
            ));
            None
        }
    };
    let window =
        |i: u32, k: u32, stride: u32, pad: bool, what: &str, diags: &mut Vec<Diagnostic>| {
            let o = out_dim(i, k, stride, pad);
            if o == 0 {
                diags.push(Diagnostic::error(
                    decl.span,
                    format!(
                        "{kind} window (kernel {k}, stride {stride}{}) produces no output on \
                         {what} {i}",
                        if pad { ", padded" } else { "" }
                    ),
                ));
                return None;
            }
            Some(o)
        };

    match &decl.body {
        LayerBody::Conv1d { out_channels, kernel, stride, pad } => {
            need(matches!(input, Shape::OneD { .. }), "1-D", diags)?;
            let Shape::OneD { c, l } = input else { unreachable!() };
            let c_out = eval_attr(out_channels, "out_channels", 1, lookup, &input, diags)?;
            let k = eval_attr(kernel, "kernel", 1, lookup, &input, diags)?;
            let s = eval_attr(stride, "stride", 1, lookup, &input, diags)?;
            let lo = window(l, k, s, pad.node, "length", diags)?;
            layers.push(Layer::new(
                lname,
                LayerKind::Conv1d { c_in: c, l_in: l, c_out, kernel: k, stride: s, pad: pad.node },
            ));
            Some(Shape::OneD { c: c_out, l: lo })
        }
        LayerBody::Conv2d { out_channels, kernel, stride, pad } => {
            need(matches!(input, Shape::TwoD { .. }), "2-D", diags)?;
            let Shape::TwoD { c, h, w } = input else { unreachable!() };
            let c_out = eval_attr(out_channels, "out_channels", 1, lookup, &input, diags)?;
            let k = eval_attr(kernel, "kernel", 1, lookup, &input, diags)?;
            let s = eval_attr(stride, "stride", 1, lookup, &input, diags)?;
            let ho = window(h, k, s, pad.node, "height", diags)?;
            let wo = window(w, k, s, pad.node, "width", diags)?;
            layers.push(Layer::new(
                lname,
                LayerKind::Conv2d {
                    c_in: c,
                    h,
                    w,
                    c_out,
                    kh: k,
                    kw: k,
                    stride: s,
                    pad: pad.node,
                },
            ));
            Some(Shape::TwoD { c: c_out, h: ho, w: wo })
        }
        LayerBody::DwConv2d { kernel, stride, pad } => {
            need(matches!(input, Shape::TwoD { .. }), "2-D", diags)?;
            let Shape::TwoD { c, h, w } = input else { unreachable!() };
            let k = eval_attr(kernel, "kernel", 1, lookup, &input, diags)?;
            let s = eval_attr(stride, "stride", 1, lookup, &input, diags)?;
            let ho = window(h, k, s, pad.node, "height", diags)?;
            let wo = window(w, k, s, pad.node, "width", diags)?;
            layers.push(Layer::new(
                lname,
                LayerKind::DwConv2d { c, h, w, kh: k, kw: k, stride: s, pad: pad.node },
            ));
            Some(Shape::TwoD { c, h: ho, w: wo })
        }
        LayerBody::Dense { out_channels, in_features } => {
            let c_out = eval_attr(out_channels, "out_channels", 1, lookup, &input, diags)?;
            let c_in = match in_features {
                Some(f) => eval_attr(f, "in_features", 1, lookup, &input, diags)?,
                None => {
                    let f = input.features();
                    if f > u32::MAX as u64 {
                        diags.push(Diagnostic::error(
                            decl.span,
                            format!(
                                "flattened input of `{lname}` has {f} features \
                                 (exceeds 2^32-1); set `in_features` explicitly"
                            ),
                        ));
                        return None;
                    }
                    f as u32
                }
            };
            layers.push(Layer::new(lname, LayerKind::Dense { c_in, c_out }));
            Some(Shape::Flat { c: c_out })
        }
        LayerBody::Pool1d { pool, kernel, stride } => {
            need(matches!(input, Shape::OneD { .. }), "1-D", diags)?;
            let Shape::OneD { c, l } = input else { unreachable!() };
            let k = eval_attr(kernel, "kernel", 1, lookup, &input, diags)?;
            let s = eval_attr(stride, "stride", 1, lookup, &input, diags)?;
            let lo = window(l, k, s, false, "length", diags)?;
            layers.push(Layer::new(
                lname,
                LayerKind::Pool1d { kind: *pool, c, l, k, stride: s },
            ));
            Some(Shape::OneD { c, l: lo })
        }
        LayerBody::Pool2d { pool, kernel, stride } => {
            need(matches!(input, Shape::TwoD { .. }), "2-D", diags)?;
            let Shape::TwoD { c, h, w } = input else { unreachable!() };
            let k = eval_attr(kernel, "kernel", 1, lookup, &input, diags)?;
            let s = eval_attr(stride, "stride", 1, lookup, &input, diags)?;
            let ho = window(h, k, s, false, "height", diags)?;
            let wo = window(w, k, s, false, "width", diags)?;
            layers.push(Layer::new(
                lname,
                LayerKind::Pool2d { kind: *pool, c, h, w, k, stride: s },
            ));
            Some(Shape::TwoD { c, h: ho, w: wo })
        }
        LayerBody::Act { act } => {
            let spatial = narrow_spatial(input.spatial(), lname, decl.span, diags)?;
            layers.push(Layer::new(
                lname,
                LayerKind::Act { kind: *act, c: input.channels(), spatial },
            ));
            Some(input)
        }
        LayerBody::Add | LayerBody::Mul => {
            // parser guarantees `with` is present for add/mul
            let rhs = with_shape.flatten()?;
            if input.channels() != rhs.channels() {
                diags.push(Diagnostic::error(
                    decl.span,
                    format!(
                        "{kind} operand channels differ: `{lname}` receives {input} and {rhs}"
                    ),
                ));
                return None;
            }
            let (sa, sb) = (input.spatial(), rhs.spatial());
            let out = if sa == sb || sb == 1 {
                input
            } else if sa == 1 {
                rhs
            } else {
                diags.push(Diagnostic::error(
                    decl.span,
                    format!(
                        "{kind} operand spatial sizes differ ({sa} vs {sb}) and neither \
                         broadcasts (one side must have spatial size 1)"
                    ),
                ));
                return None;
            };
            let spatial = narrow_spatial(out.spatial(), lname, decl.span, diags)?;
            let lk = if matches!(decl.body, LayerBody::Add) {
                LayerKind::Add { c: out.channels(), spatial }
            } else {
                LayerKind::Mul { c: out.channels(), spatial }
            };
            layers.push(Layer::new(lname, lk));
            Some(out)
        }
    }
}

fn narrow_spatial(
    spatial: u64,
    lname: &str,
    span: Span,
    diags: &mut Vec<Diagnostic>,
) -> Option<u32> {
    if spatial > u32::MAX as u64 {
        diags.push(Diagnostic::error(
            span,
            format!("spatial size {spatial} of `{lname}` exceeds 2^32-1"),
        ));
        return None;
    }
    Some(spatial as u32)
}

#[cfg(test)]
mod tests {
    use super::super::compile::check_net_source;
    use super::*;
    use crate::dnn::layer::PoolKind;

    fn errors_of(src: &str) -> Vec<String> {
        let (_, diags) = check_net_source(src);
        diags.iter().filter(|d| d.is_error()).map(|d| d.to_string()).collect()
    }

    const HEAD: &str = "[net]\nname = \"t\"\n\n[[input]]\nchannels = 8\nlength = 16\n\n";

    #[test]
    fn sequential_chain_infers_shapes() {
        let src = format!(
            "{HEAD}[[layer]]\nname = \"c\"\nkind = \"conv1d\"\nout_channels = 4\n\
             kernel = 3\nstride = 2\npad = true\n\n\
             [[layer]]\nname = \"a\"\nkind = \"clip\"\n\n\
             [[layer]]\nname = \"p\"\nkind = \"avgpool1d\"\nkernel = \"in_len\"\n\n\
             [[layer]]\nname = \"fc\"\nkind = \"dense\"\nout_channels = 2\n"
        );
        let (net, diags) = check_net_source(&src);
        assert!(diags.is_empty(), "{diags:?}");
        let net = net.unwrap();
        assert_eq!(net.name, "t");
        // conv: (16-1)/2+1 = 8 positions
        assert_eq!(
            net.layers[0].kind,
            LayerKind::Conv1d { c_in: 8, l_in: 16, c_out: 4, kernel: 3, stride: 2, pad: true }
        );
        assert_eq!(net.layers[1].kind, LayerKind::Act {
            kind: crate::dnn::layer::ActKind::Clip,
            c: 4,
            spatial: 8
        });
        // global pool via the in_len builtin
        assert_eq!(net.layers[2].kind, LayerKind::Pool1d {
            kind: PoolKind::Avg,
            c: 4,
            l: 8,
            k: 8,
            stride: 1
        });
        // dense flattens 4x1
        assert_eq!(net.layers[3].kind, LayerKind::Dense { c_in: 4, c_out: 2 });
    }

    #[test]
    fn dimensionality_mismatches_are_errors() {
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"c\"\nkind = \"conv2d\"\nout_channels = 4\nkernel = 3\n"
        ));
        assert!(e.iter().any(|m| m.contains("conv2d needs a 2-D input")), "{e:?}");
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"fc\"\nkind = \"dense\"\nout_channels = 2\n\n\
             [[layer]]\nname = \"p\"\nkind = \"maxpool1d\"\nkernel = 2\n"
        ));
        assert!(e.iter().any(|m| m.contains("maxpool1d needs a 1-D input")), "{e:?}");
    }

    #[test]
    fn oversized_window_is_an_error() {
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"c\"\nkind = \"conv1d\"\nout_channels = 4\nkernel = 17\n"
        ));
        assert!(e.iter().any(|m| m.contains("produces no output")), "{e:?}");
    }

    #[test]
    fn unknown_and_forward_references_are_errors() {
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"a\"\nkind = \"clip\"\nfrom = \"ghost\"\n"
        ));
        assert!(e.iter().any(|m| m.contains("unknown layer or input `ghost`")), "{e:?}");
        // forward reference: `b` is declared after `a`
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"a\"\nkind = \"clip\"\nfrom = \"b\"\n\n\
             [[layer]]\nname = \"b\"\nkind = \"clip\"\nfrom = \"input\"\n"
        ));
        assert!(e.iter().any(|m| m.contains("unknown layer or input `b`")), "{e:?}");
    }

    #[test]
    fn add_shape_rules() {
        // channels differ
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"c\"\nkind = \"conv1d\"\nout_channels = 4\nkernel = 1\n\n\
             [[layer]]\nname = \"s\"\nkind = \"add\"\nwith = \"input\"\n"
        ));
        assert!(e.iter().any(|m| m.contains("operand channels differ")), "{e:?}");
        // non-broadcastable spatial
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"c\"\nkind = \"conv1d\"\nout_channels = 8\nkernel = 1\n\
             stride = 2\n\n[[layer]]\nname = \"s\"\nkind = \"add\"\nwith = \"input\"\n"
        ));
        assert!(e.iter().any(|m| m.contains("spatial sizes differ")), "{e:?}");
        // broadcast: flat x 1-D multiplies fine (squeeze-excite shape)
        let src = format!(
            "{HEAD}[[layer]]\nname = \"fc\"\nkind = \"dense\"\nout_channels = 8\n\
             in_features = \"in_channels\"\n\n\
             [[layer]]\nname = \"scale\"\nkind = \"mul\"\nwith = \"input\"\n"
        );
        let (net, diags) = check_net_source(&src);
        assert!(diags.is_empty(), "{diags:?}");
        let net = net.unwrap();
        assert_eq!(net.layers[1].kind, LayerKind::Mul { c: 8, spatial: 16 });
    }

    #[test]
    fn duplicates_and_empty_bodies_are_errors() {
        let e = errors_of(&format!(
            "{HEAD}[[layer]]\nname = \"a\"\nkind = \"clip\"\n\n\
             [[layer]]\nname = \"a\"\nkind = \"clip\"\n"
        ));
        assert!(e.iter().any(|m| m.contains("duplicate layer name `a`")), "{e:?}");
        let e = errors_of("[net]\nname = \"t\"\n");
        assert!(e.iter().any(|m| m.contains("declares no layers")), "{e:?}");
        let e = errors_of("[net]\nname = \"t\"\n\n[[layer]]\nname = \"a\"\nkind = \"clip\"\n");
        assert!(e.iter().any(|m| m.contains("nothing to chain from")), "{e:?}");
    }

    #[test]
    fn unused_input_is_a_warning() {
        let src = format!(
            "{HEAD}[[input]]\nname = \"aux\"\nchannels = 2\nlength = 2\n\n\
             [[layer]]\nname = \"a\"\nkind = \"clip\"\n"
        );
        let (net, diags) = check_net_source(&src);
        assert!(net.is_some());
        assert!(
            diags.iter().any(|d| !d.is_error() && d.message.contains("never consumed")),
            "{diags:?}"
        );
    }

    #[test]
    fn poisoned_shapes_do_not_cascade() {
        // the conv fails (bad window); its consumers must not add errors
        let (net, diags) = check_net_source(&format!(
            "{HEAD}[[layer]]\nname = \"c\"\nkind = \"conv1d\"\nout_channels = 4\nkernel = 99\n\n\
             [[layer]]\nname = \"a\"\nkind = \"clip\"\n\n\
             [[layer]]\nname = \"s\"\nkind = \"add\"\nwith = \"a\"\n"
        ));
        assert!(net.is_none());
        let errors: Vec<_> = diags.iter().filter(|d| d.is_error()).collect();
        assert_eq!(errors.len(), 1, "{errors:?}");
    }
}
