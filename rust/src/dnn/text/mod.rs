//! Textual DNN network frontend: parse, validate, and compile network
//! descriptions from TOML-flavored files (see `net/README.md` for a tour,
//! `docs/net-format.md` for the full grammar, and `net/*.toml` for the
//! three paper workloads).
//!
//! Pipeline (the workload-side mirror of [`crate::acadl::text`]):
//!
//! ```text
//! source ──parser──▶ NetDescription (template AST)
//!        ──expand──▶ Vec<LayerInstance> (ordered, after foreach/when/${}
//!                    replication — iteration-major over [[foreach]] groups)
//!        ──infer───▶ shape inference + Vec<Diagnostic> (unknown refs,
//!                    dimensionality mismatches, dead windows, ... with
//!                    file/line spans)
//!        ──build───▶ dnn::Network (the same IR the zoo builders produce)
//! ```
//!
//! The tokenizer, expression language, `${}` interpolation, and `foreach`
//! syntax are shared with the ACADL frontend — one grammar, two description
//! languages. [`NetRegistry`] caches compiled networks keyed by description
//! content; beyond that, the engine's content-addressed
//! [`KernelKey`](crate::engine::KernelKey) means a described network that
//! compiles to the same layers as a hand-written builder shares its
//! estimate-cache entries too — `rust/tests/described_nets.rs` pins
//! `net/*.toml` cycle-identical to `dnn::zoo` across all four paper
//! architectures.

pub mod ast;
pub mod compile;
pub mod parser;
pub mod registry;
pub mod validate;

pub use ast::{NetDescription, Span, Spanned, Template};
pub use compile::{check_net_source, compile_net_source, expand, LayerInstance};
pub use parser::parse_net;
pub use registry::NetRegistry;
pub use validate::{infer, Shape};

// one diagnostics type across both description languages
pub use crate::acadl::text::{Diagnostic, Severity};
