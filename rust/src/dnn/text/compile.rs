//! Expansion and compilation: template AST → ordered [`LayerInstance`]
//! list → shape inference ([`super::validate`]) → [`crate::dnn::Network`].
//!
//! Expansion is **iteration-major** over `[[foreach]]` groups: every member
//! layer of iteration `b` is emitted before any layer of iteration `b + 1`,
//! so the implicit previous-layer chain threads through whole block
//! instances. Attribute expressions are *not* evaluated here — they are
//! deferred to shape inference, where the `in_*` builtins of each layer's
//! inferred input tensor are in scope.

use anyhow::{bail, Context as _};

use crate::acadl::text::Diagnostic;
use crate::dnn::layer::Network;
use crate::Result;

use super::ast::{ForRange, Item, LayerDecl, NetDescription, Span, Spanned};
use super::parser::parse_net;
use super::validate::infer;

/// Replication safety cap: loop iterations per `[[layer]]`/`[[foreach]]`
/// item (matches the ACADL frontend's per-declaration cap).
const MAX_INSTANCES_PER_ITEM: usize = 1 << 20;

/// One expanded layer occurrence: the declaration plus its frozen loop
/// bindings. Attribute evaluation happens later, against these bindings
/// plus the inferred input shape.
#[derive(Debug, Clone)]
pub struct LayerInstance<'d> {
    /// The `[[layer]]` declaration this instance came from.
    pub decl: &'d LayerDecl,
    /// Group and per-layer loop bindings, outermost first.
    pub vars: Vec<(String, i64)>,
    /// Ordinal among this declaration's emitted (guard-passing) instances.
    pub idx: i64,
}

/// Per-item iteration budget: bounds *loop iterations*, not just
/// guard-passing instances, so a huge range with a narrow `when` still
/// terminates. Reports once; the sentinel stops the range loops.
struct Budget {
    visited: usize,
    span: Span,
}

impl Budget {
    fn new(span: Span) -> Self {
        Self { visited: 0, span }
    }

    /// Count one iteration; false once the cap is blown (diagnosing the
    /// first overrun).
    fn tick(&mut self, diags: &mut Vec<Diagnostic>) -> bool {
        self.visited += 1;
        if self.visited > MAX_INSTANCES_PER_ITEM {
            if self.visited == MAX_INSTANCES_PER_ITEM + 1 {
                diags.push(Diagnostic::error(
                    self.span,
                    format!("declaration iterates more than {MAX_INSTANCES_PER_ITEM} times"),
                ));
            }
            return false;
        }
        true
    }

    fn blown(&self) -> bool {
        self.visited > MAX_INSTANCES_PER_ITEM
    }

    fn blow(&mut self) {
        self.visited = MAX_INSTANCES_PER_ITEM + 2;
    }
}

/// Expand `foreach`/`when` templates into the ordered layer-instance list.
/// Collects diagnostics instead of failing fast; on errors the returned
/// list is best-effort (do not compile it).
pub fn expand(desc: &NetDescription) -> (Vec<LayerInstance<'_>>, Vec<Diagnostic>) {
    let mut params = std::collections::BTreeMap::new();
    for p in &desc.params {
        // duplicate params are diagnosed by shape inference; first wins here
        params.entry(p.name.node.clone()).or_insert(p.value.node);
    }
    let mut out = Vec::new();
    let mut diags = Vec::new();
    for item in &desc.items {
        match item {
            Item::Layer(decl) => {
                let mut budget = Budget::new(decl.span);
                let mut vars = Vec::new();
                let mut idx = 0i64;
                expand_layer(decl, &params, &mut vars, &mut idx, &mut budget, &mut out, &mut diags);
            }
            Item::Group(g) => {
                let mut budget = Budget::new(g.span);
                let mut vars = Vec::new();
                // per-member-decl idx counters persist across group iterations
                let mut idxs = vec![0i64; g.layers.len()];
                expand_group(g, 0, &params, &mut vars, &mut idxs, &mut budget, &mut out, &mut diags);
            }
        }
    }
    (out, diags)
}

fn lookup_in<'a>(
    params: &'a std::collections::BTreeMap<String, i64>,
    vars: &'a [(String, i64)],
) -> impl Fn(&str) -> Option<i64> + 'a {
    move |name: &str| {
        if let Some(&(_, v)) = vars.iter().rev().find(|(n, _)| n == name) {
            return Some(v);
        }
        params.get(name).copied()
    }
}

fn eval_spanned(
    e: &Spanned<super::ast::PExpr>,
    params: &std::collections::BTreeMap<String, i64>,
    vars: &[(String, i64)],
) -> std::result::Result<i64, Diagnostic> {
    e.node.eval(&lookup_in(params, vars)).map_err(|msg| Diagnostic::error(e.span, msg))
}

/// Evaluate one `foreach` range's bounds; a failure halts the whole item.
fn range_bounds(
    r: &ForRange,
    params: &std::collections::BTreeMap<String, i64>,
    vars: &[(String, i64)],
    budget: &mut Budget,
    diags: &mut Vec<Diagnostic>,
) -> Option<(i64, i64)> {
    match (eval_spanned(&r.lo, params, vars), eval_spanned(&r.hi, params, vars)) {
        (Ok(lo), Ok(hi)) => Some((lo, hi)),
        (Err(d), _) | (_, Err(d)) => {
            // bounds that error once error for every surrounding iteration;
            // report once and halt this item's expansion
            diags.push(d);
            budget.blow();
            None
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn expand_group<'d>(
    g: &'d super::ast::Group,
    depth: usize,
    params: &std::collections::BTreeMap<String, i64>,
    vars: &mut Vec<(String, i64)>,
    idxs: &mut [i64],
    budget: &mut Budget,
    out: &mut Vec<LayerInstance<'d>>,
    diags: &mut Vec<Diagnostic>,
) {
    if depth == g.ranges.len() {
        if !budget.tick(diags) {
            return;
        }
        if let Some(w) = &g.when {
            match eval_spanned(w, params, vars) {
                Ok(0) => return,
                Ok(_) => {}
                Err(d) => {
                    diags.push(d);
                    budget.blow();
                    return;
                }
            }
        }
        for (i, decl) in g.layers.iter().enumerate() {
            expand_layer(decl, params, vars, &mut idxs[i], budget, out, diags);
            if budget.blown() {
                return;
            }
        }
        return;
    }
    let range = &g.ranges[depth];
    let Some((lo, hi)) = range_bounds(range, params, vars, budget, diags) else { return };
    for v in lo..hi {
        if !budget.tick(diags) {
            return;
        }
        vars.push((range.var.node.clone(), v));
        expand_group(g, depth + 1, params, vars, idxs, budget, out, diags);
        vars.pop();
        if budget.blown() {
            return;
        }
    }
}

fn expand_layer<'d>(
    decl: &'d LayerDecl,
    params: &std::collections::BTreeMap<String, i64>,
    vars: &mut Vec<(String, i64)>,
    idx: &mut i64,
    budget: &mut Budget,
    out: &mut Vec<LayerInstance<'d>>,
    diags: &mut Vec<Diagnostic>,
) {
    expand_layer_ranges(decl, 0, params, vars, idx, budget, out, diags);
}

#[allow(clippy::too_many_arguments)]
fn expand_layer_ranges<'d>(
    decl: &'d LayerDecl,
    depth: usize,
    params: &std::collections::BTreeMap<String, i64>,
    vars: &mut Vec<(String, i64)>,
    idx: &mut i64,
    budget: &mut Budget,
    out: &mut Vec<LayerInstance<'d>>,
    diags: &mut Vec<Diagnostic>,
) {
    if depth == decl.foreach.len() {
        if !budget.tick(diags) {
            return;
        }
        if let Some(w) = &decl.when {
            match eval_spanned(w, params, vars) {
                Ok(0) => return,
                Ok(_) => {}
                Err(d) => {
                    diags.push(d);
                    budget.blow();
                    return;
                }
            }
        }
        out.push(LayerInstance { decl, vars: vars.clone(), idx: *idx });
        *idx += 1;
        return;
    }
    let range = &decl.foreach[depth];
    let Some((lo, hi)) = range_bounds(range, params, vars, budget, diags) else { return };
    for v in lo..hi {
        if !budget.tick(diags) {
            return;
        }
        vars.push((range.var.node.clone(), v));
        expand_layer_ranges(decl, depth + 1, params, vars, idx, budget, out, diags);
        vars.pop();
        if budget.blown() {
            return;
        }
    }
}

// ---- front doors -----------------------------------------------------------

/// Parse + expand + shape-infer, returning the compiled network (when
/// error-free) and every diagnostic. This is what `acadl-perf check` drives
/// for `net/*.toml` files.
pub fn check_net_source(src: &str) -> (Option<Network>, Vec<Diagnostic>) {
    let desc = match parse_net(src) {
        Ok(d) => d,
        Err(diag) => return (None, vec![diag]),
    };
    let (instances, mut diags) = expand(&desc);
    let net = infer(&desc, &instances, &mut diags);
    (net, diags)
}

/// Compile a network description source, failing with the first
/// diagnostics formatted into the error message.
pub fn compile_net_source(src: &str, origin: &str) -> Result<Network> {
    let (net, diags) = check_net_source(src);
    let errors: Vec<&Diagnostic> = diags.iter().filter(|d| d.is_error()).collect();
    if !errors.is_empty() {
        let shown: Vec<String> = errors.iter().take(5).map(|d| d.render(origin)).collect();
        bail!(
            "{} error(s) in network description:\n{}",
            errors.len(),
            shown.join("\n")
        );
    }
    net.context("network description did not parse")
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A tiny but complete description (three-layer 1-D net with a skip).
    pub(crate) const TINY_NET: &str = r#"
[net]
name = "tiny${c}"

[params]
c = 8

[[input]]
channels = "c"
length = 16

[[layer]]
name = "conv"
kind = "conv1d"
out_channels = "c"
kernel = 3
stride = 1
pad = true

[[layer]]
name = "skip"
kind = "add"
with = "input"

[[layer]]
name = "act"
kind = "relu"
"#;

    #[test]
    fn tiny_net_compiles() {
        let net = compile_net_source(TINY_NET, "tiny").unwrap();
        assert_eq!(net.name, "tiny8");
        assert_eq!(net.num_layers(), 3);
        assert_eq!(net.layers[1].kind, crate::dnn::layer::LayerKind::Add { c: 8, spatial: 16 });
    }

    #[test]
    fn groups_expand_iteration_major() {
        let src = r#"
[net]
name = "g"

[[input]]
channels = 4
length = 32

[[foreach]]
range = "b in 0..2"

[[layer]]
name = "c${b}"
kind = "conv1d"
out_channels = "4 * (b + 1)"
kernel = 3
stride = 2
pad = true

[[layer]]
name = "a${b}"
kind = "clip"

[[end]]
"#;
        let (net, diags) = check_net_source(src);
        assert!(diags.is_empty(), "{diags:?}");
        let net = net.unwrap();
        let names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        // iteration-major: c0 a0 c1 a1 — the implicit chain threads blocks
        assert_eq!(names, vec!["c0", "a0", "c1", "a1"]);
        // c1 consumes a0's output (8 channels, length 16)
        assert_eq!(
            net.layers[2].kind,
            crate::dnn::layer::LayerKind::Conv1d {
                c_in: 4 * 1,
                l_in: 16,
                c_out: 8,
                kernel: 3,
                stride: 2,
                pad: true
            }
        );
    }

    #[test]
    fn when_guards_and_idx_work() {
        let src = r#"
[net]
name = "w"

[[input]]
channels = 2
length = 8

[[layer]]
name = "l${i}_at${idx}"
kind = "clip"
foreach = "i in 0..5"
when = "i % 2 == 0"
"#;
        let (net, diags) = check_net_source(src);
        assert!(diags.is_empty(), "{diags:?}");
        let names: Vec<&str> = net.unwrap().layers.iter().map(|l| l.name.as_str()).collect();
        // filtered instances do not consume idx
        assert_eq!(names, vec!["l0_at0", "l2_at1", "l4_at2"]);
    }

    #[test]
    fn expansion_errors_carry_spans_and_halt() {
        let src = "[net]\nname = \"x\"\n\n[[layer]]\nname = \"a\"\nkind = \"clip\"\n\
                   foreach = \"i in 0..missing\"\n";
        let (net, diags) = check_net_source(src);
        assert!(net.is_none());
        assert!(
            diags.iter().any(|d| d.message.contains("unknown parameter `missing`")),
            "{diags:?}"
        );
        // the bad bound is reported exactly once
        let n = diags.iter().filter(|d| d.message.contains("unknown parameter")).count();
        assert_eq!(n, 1, "{diags:?}");
    }

    #[test]
    fn runaway_replication_is_capped() {
        // the guard filters every instance, but the cap bounds *loop
        // iterations*, so the runaway range is still stopped (and the test
        // stays fast: no instances reach shape inference)
        let src = "[net]\nname = \"x\"\n\n[[input]]\nchannels = 1\nlength = 1\n\n\
                   [[layer]]\nname = \"l${i}_${j}\"\nkind = \"clip\"\n\
                   foreach = \"i in 0..4096, j in 0..4096\"\nwhen = \"i < 0\"\n";
        let (net, diags) = check_net_source(src);
        assert!(net.is_none());
        assert!(
            diags.iter().any(|d| d.message.contains("iterates more than")),
            "{diags:?}"
        );
    }

    #[test]
    fn compile_net_source_reports_diagnostics() {
        let e = compile_net_source("[net]\nname = \"x${missing}\"\n", "inline").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("inline:2:"), "{msg}");
        assert!(msg.contains("unknown parameter"), "{msg}");
    }
}
