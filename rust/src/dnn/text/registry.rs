//! The network registry: a content-keyed cache of compiled network
//! descriptions, so hot paths (`acadl-perf serve` request loops, repeated
//! `net:<file>` estimates) never re-lex, re-expand, or re-infer an
//! unchanged description.
//!
//! Keys are the full description source (the map's hash is over the
//! content, and equality on the content rules out collisions). Compiled
//! [`Network`]s are shared as `Arc`s. This is the workload-side sibling of
//! [`crate::acadl::text::ArchRegistry`] — and estimate reuse goes further:
//! the engine's [`KernelKey`](crate::engine::KernelKey) is content-
//! addressed over *kernels*, so a described network that compiles to the
//! same layers as a zoo builder shares its estimate-cache entries too.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::dnn::layer::Network;
use crate::Result;

use super::compile::compile_net_source;

/// Content-keyed cache of compiled network descriptions.
#[derive(Default)]
pub struct NetRegistry {
    cache: Mutex<HashMap<Arc<str>, Arc<Network>>>,
    compiles: AtomicU64,
}

impl NetRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide registry used by the coordinator.
    pub fn global() -> &'static NetRegistry {
        static GLOBAL: OnceLock<NetRegistry> = OnceLock::new();
        GLOBAL.get_or_init(NetRegistry::new)
    }

    /// Compile `source` (or return the cached network for identical
    /// content). `origin` labels diagnostics, e.g. a file path or
    /// `<inline>`. Failed compiles are not cached.
    pub fn get_or_compile(&self, source: &str, origin: &str) -> Result<Arc<Network>> {
        if let Some(hit) = self.cache.lock().unwrap().get(source) {
            return Ok(Arc::clone(hit));
        }
        // compile outside the lock: a slow description must not stall
        // unrelated requests. Two racing misses both compile; the first
        // insert wins and both results are equivalent (compilation is
        // deterministic).
        self.compiles.fetch_add(1, Ordering::Relaxed);
        let compiled = Arc::new(compile_net_source(source, origin)?);
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.entry(Arc::from(source)).or_insert_with(|| Arc::clone(&compiled));
        Ok(Arc::clone(entry))
    }

    /// Number of actual compilations performed (cache misses).
    pub fn compile_count(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Number of cached descriptions.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all cached networks (tests; memory pressure).
    pub fn clear(&self) {
        self.cache.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::compile::tests::TINY_NET;
    use super::*;

    #[test]
    fn identical_content_compiles_once() {
        let reg = NetRegistry::new();
        let a = reg.get_or_compile(TINY_NET, "tiny").unwrap();
        assert_eq!(reg.compile_count(), 1);
        assert_eq!(reg.len(), 1);
        let b = reg.get_or_compile(TINY_NET, "tiny").unwrap();
        assert_eq!(reg.compile_count(), 1, "cache hit must not recompile");
        assert!(Arc::ptr_eq(&a, &b), "cache hit must return the shared network");
        // changed content (even just a comment) is a different key
        let changed = format!("{TINY_NET}\n# tweaked\n");
        reg.get_or_compile(&changed, "tiny").unwrap();
        assert_eq!(reg.compile_count(), 2);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn failed_compiles_are_not_cached() {
        let reg = NetRegistry::new();
        let broken = "[net]\nname = \"x\"\n";
        assert!(reg.get_or_compile(broken, "broken").is_err());
        assert_eq!(reg.compile_count(), 1);
        assert!(reg.get_or_compile(broken, "broken").is_err());
        assert_eq!(reg.compile_count(), 2, "errors are never cached");
        assert!(reg.is_empty());
    }
}
