//! Parser: token stream → [`NetDescription`] AST.
//!
//! The tokenizer ([`crate::acadl::text::lexer`]) and the string-level
//! sub-parsers for `${}` templates, parameter expressions, and `foreach`
//! ranges ([`crate::acadl::text::parser`]) are shared with the textual
//! ACADL frontend — this module only owns the section grammar of network
//! descriptions: `[net]`, `[params]`, `[[input]]`, `[[layer]]`, and the
//! `[[foreach]]` ... `[[end]]` group brackets.

use crate::acadl::text::lexer::{lex, Token, TokenKind};
use crate::acadl::text::parser::{parse_foreach, parse_pexpr, parse_template};
use crate::acadl::text::Diagnostic;
use crate::dnn::layer::{ActKind, PoolKind};

use super::ast::{
    Group, InputDecl, InputShape, Item, LayerBody, LayerDecl, NetDescription, Param, PExpr, Span,
    Spanned, Template,
};

/// Parse a network description source file.
pub fn parse_net(src: &str) -> Result<NetDescription, Diagnostic> {
    let toks = lex(src)?;
    Parser { toks, pos: 0 }.description()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

/// A raw `key = value` pair within one section.
#[derive(Debug, Clone)]
struct RawPair {
    key: String,
    key_span: Span,
    value: Val,
}

#[derive(Debug, Clone)]
enum Val {
    Int(i64, Span),
    Str(String, Span),
    Bool(bool, Span),
}

impl Val {
    fn span(&self) -> Span {
        match self {
            Val::Int(_, s) | Val::Str(_, s) | Val::Bool(_, s) => *s,
        }
    }
}

impl Parser {
    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Token> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn here(&self) -> Span {
        self.peek()
            .map(|t| t.span)
            .unwrap_or_else(|| self.toks.last().map(|t| t.span).unwrap_or_default())
    }

    fn skip_newlines(&mut self) {
        while matches!(self.peek().map(|t| &t.kind), Some(TokenKind::Newline)) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, kind: &TokenKind, what: &str) -> Result<Span, Diagnostic> {
        match self.next() {
            Some(t) if t.kind == *kind => Ok(t.span),
            Some(t) => Err(Diagnostic::error(
                t.span,
                format!("expected {what}, found {}", t.kind.describe()),
            )),
            None => {
                Err(Diagnostic::error(self.here(), format!("expected {what}, found end of file")))
            }
        }
    }

    /// `[name]` or `[[name]]` header; returns (name, is_array, span).
    fn header(&mut self) -> Result<(String, bool, Span), Diagnostic> {
        let span = self.expect(&TokenKind::LBracket, "`[`")?;
        let is_array = matches!(self.peek().map(|t| &t.kind), Some(TokenKind::LBracket));
        if is_array {
            self.pos += 1;
        }
        let name = match self.next() {
            Some(Token { kind: TokenKind::Ident(n), .. }) => n,
            Some(t) => {
                return Err(Diagnostic::error(
                    t.span,
                    format!("expected section name, found {}", t.kind.describe()),
                ))
            }
            None => return Err(Diagnostic::error(span, "expected section name")),
        };
        self.expect(&TokenKind::RBracket, "`]`")?;
        if is_array {
            self.expect(&TokenKind::RBracket, "`]]`")?;
        }
        self.expect(&TokenKind::Newline, "end of line after section header")?;
        Ok((name, is_array, span))
    }

    /// Key-value pairs up to the next section header or end of file.
    fn pairs(&mut self) -> Result<Vec<RawPair>, Diagnostic> {
        let mut out = Vec::new();
        loop {
            self.skip_newlines();
            match self.peek().map(|t| &t.kind) {
                None | Some(TokenKind::LBracket) => return Ok(out),
                Some(TokenKind::Ident(_)) => {}
                Some(k) => {
                    let span = self.here();
                    return Err(Diagnostic::error(
                        span,
                        format!("expected `key = value`, found {}", k.describe()),
                    ));
                }
            }
            let (key, key_span) = match self.next() {
                Some(Token { kind: TokenKind::Ident(k), span }) => (k, span),
                _ => unreachable!("peeked an identifier"),
            };
            self.expect(&TokenKind::Equals, "`=`")?;
            let value = self.value()?;
            self.expect(&TokenKind::Newline, "end of line after value")?;
            out.push(RawPair { key, key_span, value });
        }
    }

    fn value(&mut self) -> Result<Val, Diagnostic> {
        match self.next() {
            Some(Token { kind: TokenKind::Int(v), span }) => Ok(Val::Int(v, span)),
            Some(Token { kind: TokenKind::Str(s), span }) => Ok(Val::Str(s, span)),
            Some(Token { kind: TokenKind::Ident(w), span }) if w == "true" => {
                Ok(Val::Bool(true, span))
            }
            Some(Token { kind: TokenKind::Ident(w), span }) if w == "false" => {
                Ok(Val::Bool(false, span))
            }
            Some(t) => Err(Diagnostic::error(
                t.span,
                format!(
                    "expected an integer, string, or true/false, found {}",
                    t.kind.describe()
                ),
            )),
            None => Err(Diagnostic::error(self.here(), "expected a value, found end of file")),
        }
    }

    fn description(&mut self) -> Result<NetDescription, Diagnostic> {
        let mut desc = NetDescription::default();
        // the currently open [[foreach]] group, if any
        let mut open: Option<Group> = None;
        // explicit seen-tracking: an *empty* first [params] section must
        // still make a second one a duplicate
        let mut seen_net = false;
        let mut seen_params = false;
        loop {
            self.skip_newlines();
            if self.peek().is_none() {
                if let Some(g) = &open {
                    return Err(Diagnostic::error(
                        g.span,
                        "[[foreach]] group not closed with [[end]] before end of file",
                    ));
                }
                return Ok(desc);
            }
            let (section, is_array, span) = self.header()?;
            let pairs = self.pairs()?;
            if !is_array {
                let already = match section.as_str() {
                    "net" => std::mem::replace(&mut seen_net, true),
                    "params" => std::mem::replace(&mut seen_params, true),
                    _ => false,
                };
                if already {
                    return Err(Diagnostic::error(span, format!("duplicate section [{section}]")));
                }
            }
            match (section.as_str(), is_array) {
                ("net", false) => {
                    let mut p = PairSet::new(pairs, span, "net")?;
                    desc.name = Some(p.template("name")?);
                    p.finish()?;
                }
                ("params", false) => {
                    for pair in pairs {
                        match pair.value {
                            Val::Int(v, vspan) => desc.params.push(Param {
                                name: Spanned::new(pair.key, pair.key_span),
                                value: Spanned::new(v, vspan),
                            }),
                            other => {
                                return Err(Diagnostic::error(
                                    other.span(),
                                    "parameters must be integers",
                                ))
                            }
                        }
                    }
                }
                ("input", true) => {
                    if open.is_some() {
                        return Err(Diagnostic::error(
                            span,
                            "[[input]] cannot appear inside a [[foreach]] group",
                        ));
                    }
                    desc.inputs.push(self.input(span, pairs)?);
                }
                ("layer", true) => {
                    let layer = self.layer(span, pairs)?;
                    match &mut open {
                        Some(g) => g.layers.push(layer),
                        None => desc.items.push(Item::Layer(layer)),
                    }
                }
                ("foreach", true) => {
                    if open.is_some() {
                        return Err(Diagnostic::error(
                            span,
                            "nested [[foreach]] groups are not supported",
                        ));
                    }
                    let mut p = PairSet::new(pairs, span, "foreach")?;
                    let (ranges_src, rspan) = p.string("range")?;
                    let ranges = parse_foreach(&ranges_src, rspan)?;
                    let when = p.when_opt()?;
                    p.finish()?;
                    open = Some(Group { ranges, when, layers: Vec::new(), span });
                }
                ("end", true) => {
                    if !pairs.is_empty() {
                        return Err(Diagnostic::error(
                            pairs[0].key_span,
                            "[[end]] takes no keys",
                        ));
                    }
                    match open.take() {
                        Some(g) => desc.items.push(Item::Group(g)),
                        None => {
                            return Err(Diagnostic::error(
                                span,
                                "[[end]] without an open [[foreach]] group",
                            ))
                        }
                    }
                }
                (other, true) => {
                    return Err(Diagnostic::error(
                        span,
                        format!(
                            "unknown declaration `[[{other}]]` (input|layer|foreach|end)"
                        ),
                    ))
                }
                (other, false) => {
                    return Err(Diagnostic::error(
                        span,
                        format!(
                            "unknown section `[{other}]` (net|params, or a `[[...]]` declaration)"
                        ),
                    ))
                }
            }
        }
    }

    fn input(&mut self, span: Span, pairs: Vec<RawPair>) -> Result<InputDecl, Diagnostic> {
        let mut p = PairSet::new(pairs, span, "input")?;
        let name = p.template_opt("name")?.unwrap_or_else(|| Template::lit("input"));
        let channels = p.pexpr("channels")?;
        let length = p.pexpr_opt("length")?;
        let height = p.pexpr_opt("height")?;
        let width = p.pexpr_opt("width")?;
        p.finish()?;
        let shape = match (length, height, width) {
            (Some(length), None, None) => InputShape::OneD { length },
            (None, Some(height), Some(width)) => InputShape::TwoD { height, width },
            _ => {
                return Err(Diagnostic::error(
                    span,
                    "[[input]] needs either `length` (1-D) or `height` and `width` (2-D)",
                ))
            }
        };
        Ok(InputDecl { name, channels, shape, span })
    }

    fn layer(&mut self, span: Span, pairs: Vec<RawPair>) -> Result<LayerDecl, Diagnostic> {
        let mut p = PairSet::new(pairs, span, "layer")?;
        let name = p.template("name")?;
        let (kind, kind_span) = p.string("kind")?;
        let from = p.template_opt("from")?;
        let body = match kind.as_str() {
            "conv1d" => LayerBody::Conv1d {
                out_channels: p.pexpr("out_channels")?,
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
                pad: p.bool_default("pad", false)?,
            },
            "conv2d" => LayerBody::Conv2d {
                out_channels: p.pexpr("out_channels")?,
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
                pad: p.bool_default("pad", false)?,
            },
            "dwconv2d" => LayerBody::DwConv2d {
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
                pad: p.bool_default("pad", false)?,
            },
            "dense" => LayerBody::Dense {
                out_channels: p.pexpr("out_channels")?,
                in_features: p.pexpr_opt("in_features")?,
            },
            "maxpool1d" => LayerBody::Pool1d {
                pool: PoolKind::Max,
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
            },
            "avgpool1d" => LayerBody::Pool1d {
                pool: PoolKind::Avg,
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
            },
            "maxpool2d" => LayerBody::Pool2d {
                pool: PoolKind::Max,
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
            },
            "avgpool2d" => LayerBody::Pool2d {
                pool: PoolKind::Avg,
                kernel: p.pexpr("kernel")?,
                stride: p.pexpr_default("stride", 1)?,
            },
            "relu" => LayerBody::Act { act: ActKind::Relu },
            "clip" => LayerBody::Act { act: ActKind::Clip },
            "add" => LayerBody::Add,
            "mul" => LayerBody::Mul,
            other => {
                return Err(Diagnostic::error(
                    kind_span,
                    format!(
                        "unknown layer kind {other:?} (conv1d|conv2d|dwconv2d|dense|\
                         maxpool1d|avgpool1d|maxpool2d|avgpool2d|relu|clip|add|mul)"
                    ),
                ))
            }
        };
        let with = if body.takes_with() {
            match p.template_opt("with")? {
                Some(w) => Some(w),
                None => {
                    return Err(Diagnostic::error(
                        span,
                        format!(
                            "[layer] kind {:?} needs `with = \"<layer>\"` (second operand)",
                            body.kind_name()
                        ),
                    ))
                }
            }
        } else {
            // `with` on a one-operand kind falls through to finish()'s
            // unknown-key diagnostic
            None
        };
        let foreach = match p.take("foreach") {
            Some(pair) => match pair.value {
                Val::Str(s, vspan) => parse_foreach(&s, vspan)?,
                other => {
                    return Err(Diagnostic::error(other.span(), "foreach must be a string"))
                }
            },
            None => Vec::new(),
        };
        let when = p.when_opt()?;
        p.finish()?;
        Ok(LayerDecl { name, body, from, with, foreach, when, span })
    }
}

/// Typed accessor over one section's raw pairs, with duplicate/unknown-key
/// detection (the network-grammar sibling of the ACADL parser's pair set;
/// this one also understands `true`/`false` values).
struct PairSet {
    pairs: Vec<Option<RawPair>>,
    section_span: Span,
    section: String,
}

impl PairSet {
    fn new(pairs: Vec<RawPair>, section_span: Span, section: &str) -> Result<Self, Diagnostic> {
        for (i, a) in pairs.iter().enumerate() {
            if pairs[..i].iter().any(|b| b.key == a.key) {
                return Err(Diagnostic::error(
                    a.key_span,
                    format!("duplicate key `{}` in [{section}]", a.key),
                ));
            }
        }
        Ok(Self {
            pairs: pairs.into_iter().map(Some).collect(),
            section_span,
            section: section.into(),
        })
    }

    fn take(&mut self, key: &str) -> Option<RawPair> {
        self.pairs
            .iter_mut()
            .find(|p| p.as_ref().is_some_and(|p| p.key == key))
            .and_then(Option::take)
    }

    fn required(&mut self, key: &str) -> Result<RawPair, Diagnostic> {
        self.take(key).ok_or_else(|| {
            Diagnostic::error(
                self.section_span,
                format!("[{}] is missing required key `{key}`", self.section),
            )
        })
    }

    fn template(&mut self, key: &str) -> Result<Template, Diagnostic> {
        let pair = self.required(key)?;
        val_template(pair.value)
    }

    fn template_opt(&mut self, key: &str) -> Result<Option<Template>, Diagnostic> {
        match self.take(key) {
            Some(pair) => Ok(Some(val_template(pair.value)?)),
            None => Ok(None),
        }
    }

    fn pexpr(&mut self, key: &str) -> Result<Spanned<PExpr>, Diagnostic> {
        let pair = self.required(key)?;
        val_pexpr(pair.value, key)
    }

    fn pexpr_opt(&mut self, key: &str) -> Result<Option<Spanned<PExpr>>, Diagnostic> {
        match self.take(key) {
            Some(pair) => Ok(Some(val_pexpr(pair.value, key)?)),
            None => Ok(None),
        }
    }

    fn pexpr_default(&mut self, key: &str, default: i64) -> Result<Spanned<PExpr>, Diagnostic> {
        Ok(self
            .pexpr_opt(key)?
            .unwrap_or_else(|| Spanned::new(PExpr::Const(default), self.section_span)))
    }

    fn bool_default(&mut self, key: &str, default: bool) -> Result<Spanned<bool>, Diagnostic> {
        match self.take(key) {
            Some(RawPair { value: Val::Bool(b, span), .. }) => Ok(Spanned::new(b, span)),
            Some(pair) => Err(Diagnostic::error(
                pair.value.span(),
                format!("`{key}` must be true or false"),
            )),
            None => Ok(Spanned::new(default, self.section_span)),
        }
    }

    fn string(&mut self, key: &str) -> Result<(String, Span), Diagnostic> {
        let pair = self.required(key)?;
        match pair.value {
            Val::Str(s, span) => Ok((s, span)),
            other => Err(Diagnostic::error(other.span(), format!("`{key}` must be a string"))),
        }
    }

    fn when_opt(&mut self) -> Result<Option<Spanned<PExpr>>, Diagnostic> {
        match self.take("when") {
            Some(pair) => match pair.value {
                Val::Str(s, vspan) => Ok(Some(Spanned::new(parse_pexpr(&s, vspan)?, vspan))),
                other => Err(Diagnostic::error(other.span(), "when must be a string")),
            },
            None => Ok(None),
        }
    }

    fn finish(self) -> Result<(), Diagnostic> {
        if let Some(extra) = self.pairs.into_iter().flatten().next() {
            return Err(Diagnostic::error(
                extra.key_span,
                format!("unknown key `{}` in [{}]", extra.key, self.section),
            ));
        }
        Ok(())
    }
}

fn val_template(val: Val) -> Result<Template, Diagnostic> {
    match val {
        Val::Str(s, span) => parse_template(&s, span),
        Val::Int(v, span) => {
            let mut t = Template::lit(v.to_string());
            t.span = span;
            Ok(t)
        }
        Val::Bool(_, span) => Err(Diagnostic::error(span, "expected a string, found boolean")),
    }
}

fn val_pexpr(val: Val, key: &str) -> Result<Spanned<PExpr>, Diagnostic> {
    match val {
        Val::Int(v, span) => Ok(Spanned::new(PExpr::Const(v), span)),
        Val::Str(s, span) => Ok(Spanned::new(parse_pexpr(&s, span)?, span)),
        Val::Bool(_, span) => {
            Err(Diagnostic::error(span, format!("`{key}` must be an integer or expression")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_network() {
        let src = r#"
[net]
name = "tiny"

[params]
c = 8

[[input]]
channels = "c"
length = 16

[[layer]]
name = "conv"
kind = "conv1d"
out_channels = 4
kernel = 3
pad = true

[[layer]]
name = "act"
kind = "relu"
"#;
        let d = parse_net(src).unwrap();
        assert_eq!(d.params.len(), 1);
        assert_eq!(d.inputs.len(), 1);
        assert_eq!(d.inputs[0].name.source(), "input"); // default name
        assert_eq!(d.items.len(), 2);
        let Item::Layer(conv) = &d.items[0] else { panic!("expected layer") };
        // stride defaulted, pad explicit
        assert!(matches!(
            &conv.body,
            LayerBody::Conv1d { stride, pad, .. }
                if stride.node == PExpr::Const(1) && pad.node
        ));
    }

    #[test]
    fn parses_foreach_groups_iteration_major() {
        let src = r#"
[net]
name = "g"

[[input]]
channels = 4
length = 8

[[foreach]]
range = "b in 0..3"
when = "b != 1"

[[layer]]
name = "c${b}"
kind = "clip"

[[end]]
"#;
        let d = parse_net(src).unwrap();
        assert_eq!(d.items.len(), 1);
        let Item::Group(g) = &d.items[0] else { panic!("expected group") };
        assert_eq!(g.ranges.len(), 1);
        assert!(g.when.is_some());
        assert_eq!(g.layers.len(), 1);
    }

    #[test]
    fn group_bracket_errors() {
        let base = "[net]\nname = \"x\"\n";
        // end without foreach
        assert!(parse_net(&format!("{base}[[end]]\n")).is_err());
        // unclosed group
        let open = format!("{base}[[foreach]]\nrange = \"i in 0..2\"\n");
        let e = parse_net(&open).unwrap_err();
        assert!(e.message.contains("not closed"), "{e}");
        // nested groups
        let nested = format!("{open}[[foreach]]\nrange = \"j in 0..2\"\n[[end]]\n[[end]]\n");
        let e = parse_net(&nested).unwrap_err();
        assert!(e.message.contains("nested"), "{e}");
        // input inside a group
        let inp = format!("{open}[[input]]\nchannels = 1\nlength = 1\n[[end]]\n");
        assert!(parse_net(&inp).is_err());
    }

    #[test]
    fn add_requires_with_and_rejects_with_elsewhere() {
        let base = "[net]\nname = \"x\"\n[[layer]]\nname = \"a\"\n";
        let e = parse_net(&format!("{base}kind = \"add\"\n")).unwrap_err();
        assert!(e.message.contains("needs `with"), "{e}");
        let e = parse_net(&format!("{base}kind = \"relu\"\nwith = \"b\"\n")).unwrap_err();
        assert!(e.message.contains("unknown key `with`"), "{e}");
    }

    #[test]
    fn rejects_unknown_kind_and_bad_values() {
        let base = "[net]\nname = \"x\"\n[[layer]]\nname = \"a\"\n";
        let e = parse_net(&format!("{base}kind = \"softmax\"\n")).unwrap_err();
        assert!(e.message.contains("unknown layer kind"), "{e}");
        let e = parse_net(&format!(
            "{base}kind = \"conv1d\"\nout_channels = 4\nkernel = 3\npad = 1\n"
        ))
        .unwrap_err();
        assert!(e.message.contains("must be true or false"), "{e}");
        // booleans are not valid generic values
        assert!(parse_net("[net]\nname = true\n").is_err());
    }

    #[test]
    fn input_shape_must_be_1d_or_2d() {
        let mk = |body: &str| format!("[net]\nname = \"x\"\n[[input]]\n{body}");
        assert!(parse_net(&mk("channels = 3\nlength = 8\n")).is_ok());
        assert!(parse_net(&mk("channels = 3\nheight = 8\nwidth = 8\n")).is_ok());
        assert!(parse_net(&mk("channels = 3\n")).is_err());
        assert!(parse_net(&mk("channels = 3\nlength = 8\nheight = 8\n")).is_err());
        assert!(parse_net(&mk("channels = 3\nheight = 8\n")).is_err());
    }

    #[test]
    fn duplicate_sections_and_keys_error() {
        assert!(parse_net("[net]\nname = \"a\"\n[net]\nname = \"b\"\n").is_err());
        // an empty first [params] still makes the second a duplicate
        assert!(parse_net("[net]\nname = \"a\"\n[params]\n[params]\nc = 8\n").is_err());
        assert!(parse_net("[net]\nname = \"a\"\nname = \"b\"\n").is_err());
        assert!(parse_net("[bogus]\nx = 1\n").is_err());
        assert!(parse_net("[[bogus]]\nx = 1\n").is_err());
    }
}
