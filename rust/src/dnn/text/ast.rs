//! AST of the textual DNN network description language.
//!
//! A network description is a TOML-flavored document (see `net/README.md`
//! and `docs/net-format.md`) listing named input tensors and an ordered
//! sequence of layers. Layers may be *templates*: replicated over integer
//! index ranges (`foreach`), filtered by guards (`when`), with `${expr}`
//! interpolation in names and input references. Consecutive layers chain
//! implicitly (each takes the previous layer's output); `from`/`with`
//! override that with **named inputs** — the mechanism behind residual skip
//! paths and squeeze-excite scaling.
//!
//! The expression language, interpolation syntax, spans, and `[params]`
//! section are shared with the textual ACADL frontend
//! ([`crate::acadl::text::ast`]): one grammar, two description languages.
//! As there, [`Span`] equality is vacuous so the pretty-print → parse
//! round-trip property can compare whole ASTs structurally.

use std::fmt::Write as _;

pub use crate::acadl::text::ast::{
    ForRange, Param, PExpr, Segment, Span, Spanned, Template,
};
use crate::dnn::layer::{ActKind, PoolKind};

/// One named input tensor (`[[input]]`).
#[derive(Debug, Clone, PartialEq)]
pub struct InputDecl {
    /// Tensor name referenced by `from`/`with` (default `input`).
    pub name: Template,
    /// Channel count.
    pub channels: Spanned<PExpr>,
    /// Spatial extent: 1-D (`length`) or 2-D (`height`/`width`).
    pub shape: InputShape,
    /// Span of the `[[input]]` header.
    pub span: Span,
}

/// The spatial part of an input declaration.
#[derive(Debug, Clone, PartialEq)]
pub enum InputShape {
    /// 1-D tensor: `length = ...`.
    OneD {
        /// Spatial length.
        length: Spanned<PExpr>,
    },
    /// 2-D tensor: `height = ...`, `width = ...`.
    TwoD {
        /// Spatial height.
        height: Spanned<PExpr>,
        /// Spatial width.
        width: Spanned<PExpr>,
    },
}

/// Kind-specific hyper-parameters of one `[[layer]]` declaration.
///
/// Integer fields are [`PExpr`]s evaluated during shape inference, where
/// the builtins `in_channels` / `in_len` / `in_h` / `in_w` / `in_spatial` /
/// `in_features` describe the layer's (inferred) input tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerBody {
    /// `kind = "conv1d"`: 1-D convolution.
    Conv1d {
        /// Output channels.
        out_channels: Spanned<PExpr>,
        /// Kernel width.
        kernel: Spanned<PExpr>,
        /// Stride (default 1).
        stride: Spanned<PExpr>,
        /// Same-padding (default false).
        pad: Spanned<bool>,
    },
    /// `kind = "conv2d"`: 2-D convolution (square kernel).
    Conv2d {
        /// Output channels.
        out_channels: Spanned<PExpr>,
        /// Kernel extent (square).
        kernel: Spanned<PExpr>,
        /// Stride (default 1).
        stride: Spanned<PExpr>,
        /// Same-padding (default false).
        pad: Spanned<bool>,
    },
    /// `kind = "dwconv2d"`: depth-wise 2-D convolution (channels preserved).
    DwConv2d {
        /// Kernel extent (square).
        kernel: Spanned<PExpr>,
        /// Stride (default 1).
        stride: Spanned<PExpr>,
        /// Same-padding (default false).
        pad: Spanned<bool>,
    },
    /// `kind = "dense"`: fully connected. The input is flattened unless
    /// `in_features` overrides the feature count (squeeze-excite layers
    /// consume pooled channels: `in_features = "in_channels"`).
    Dense {
        /// Output features.
        out_channels: Spanned<PExpr>,
        /// Input-feature override (default: flattened input).
        in_features: Option<Spanned<PExpr>>,
    },
    /// `kind = "maxpool1d" | "avgpool1d"`: 1-D pooling.
    Pool1d {
        /// Max or average.
        pool: PoolKind,
        /// Window size.
        kernel: Spanned<PExpr>,
        /// Stride (default 1).
        stride: Spanned<PExpr>,
    },
    /// `kind = "maxpool2d" | "avgpool2d"`: 2-D pooling (square window).
    Pool2d {
        /// Max or average.
        pool: PoolKind,
        /// Window size (square).
        kernel: Spanned<PExpr>,
        /// Stride (default 1).
        stride: Spanned<PExpr>,
    },
    /// `kind = "relu" | "clip"`: element-wise activation.
    Act {
        /// Activation function.
        act: ActKind,
    },
    /// `kind = "add"`: element-wise addition of `from` and `with`.
    Add,
    /// `kind = "mul"`: element-wise multiplication of `from` and `with`
    /// (spatial broadcast allowed — squeeze-excite scaling).
    Mul,
}

impl LayerBody {
    /// The `kind = "..."` string of this body.
    pub fn kind_name(&self) -> &'static str {
        match self {
            LayerBody::Conv1d { .. } => "conv1d",
            LayerBody::Conv2d { .. } => "conv2d",
            LayerBody::DwConv2d { .. } => "dwconv2d",
            LayerBody::Dense { .. } => "dense",
            LayerBody::Pool1d { pool: PoolKind::Max, .. } => "maxpool1d",
            LayerBody::Pool1d { pool: PoolKind::Avg, .. } => "avgpool1d",
            LayerBody::Pool2d { pool: PoolKind::Max, .. } => "maxpool2d",
            LayerBody::Pool2d { pool: PoolKind::Avg, .. } => "avgpool2d",
            LayerBody::Act { act: ActKind::Relu } => "relu",
            LayerBody::Act { act: ActKind::Clip } => "clip",
            LayerBody::Add => "add",
            LayerBody::Mul => "mul",
        }
    }

    /// True for the two-operand element-wise kinds (which require `with`).
    pub fn takes_with(&self) -> bool {
        matches!(self, LayerBody::Add | LayerBody::Mul)
    }
}

/// One `[[layer]]` declaration (possibly replicated via `foreach`).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDecl {
    /// Layer name template (must be unique after expansion).
    pub name: Template,
    /// Kind-specific hyper-parameters.
    pub body: LayerBody,
    /// First operand: a layer or input name (default: the previous layer).
    pub from: Option<Template>,
    /// Second operand of `add`/`mul`.
    pub with: Option<Template>,
    /// Per-layer replication ranges.
    pub foreach: Vec<ForRange>,
    /// Per-layer guard.
    pub when: Option<Spanned<PExpr>>,
    /// Span of the `[[layer]]` header.
    pub span: Span,
}

/// A replication group: `[[foreach]] range = "b in 1..4"` ... `[[end]]`.
/// Member layers expand *iteration-major* (all of iteration `b = 1`, then
/// all of `b = 2`, ...), so the implicit previous-layer chain threads
/// through whole block instances — the residual/SE block template
/// mechanism.
#[derive(Debug, Clone, PartialEq)]
pub struct Group {
    /// `range = "var in lo..hi, ..."` clauses.
    pub ranges: Vec<ForRange>,
    /// Optional per-iteration guard.
    pub when: Option<Spanned<PExpr>>,
    /// Member layers, in declaration order.
    pub layers: Vec<LayerDecl>,
    /// Span of the `[[foreach]]` header.
    pub span: Span,
}

/// One ordered body item: a single layer or a replication group.
#[derive(Debug, Clone, PartialEq)]
pub enum Item {
    /// A single layer declaration.
    Layer(LayerDecl),
    /// A `[[foreach]]` replication group.
    Group(Group),
}

/// A parsed network description (template form).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetDescription {
    /// Network name template (`[net] name = "..."`).
    pub name: Option<Template>,
    /// `[params]` in declaration order.
    pub params: Vec<Param>,
    /// `[[input]]` tensors in declaration order (the first one starts the
    /// implicit layer chain).
    pub inputs: Vec<InputDecl>,
    /// Layers and groups in declaration order.
    pub items: Vec<Item>,
}

impl NetDescription {
    /// Canonical TOML pretty-printer. The output reparses to an AST equal
    /// to `self` (spans excepted — they compare vacuously). Optional fields
    /// with defaults (`stride`, `pad`) are printed explicitly, so parsing
    /// the output fills them identically.
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        if let Some(name) = &self.name {
            let _ = writeln!(out, "[net]");
            let _ = writeln!(out, "name = {}", quote(&name.source()));
            out.push('\n');
        }
        if !self.params.is_empty() {
            let _ = writeln!(out, "[params]");
            for p in &self.params {
                let _ = writeln!(out, "{} = {}", p.name.node, p.value.node);
            }
            out.push('\n');
        }
        for i in &self.inputs {
            let _ = writeln!(out, "[[input]]");
            let _ = writeln!(out, "name = {}", quote(&i.name.source()));
            let _ = writeln!(out, "channels = {}", pexpr_value(&i.channels.node));
            match &i.shape {
                InputShape::OneD { length } => {
                    let _ = writeln!(out, "length = {}", pexpr_value(&length.node));
                }
                InputShape::TwoD { height, width } => {
                    let _ = writeln!(out, "height = {}", pexpr_value(&height.node));
                    let _ = writeln!(out, "width = {}", pexpr_value(&width.node));
                }
            }
            out.push('\n');
        }
        for item in &self.items {
            match item {
                Item::Layer(l) => print_layer(&mut out, l),
                Item::Group(g) => {
                    let _ = writeln!(out, "[[foreach]]");
                    let ranges: Vec<String> = g
                        .ranges
                        .iter()
                        .map(|r| format!("{} in {}..{}", r.var.node, r.lo.node, r.hi.node))
                        .collect();
                    let _ = writeln!(out, "range = {}", quote(&ranges.join(", ")));
                    if let Some(w) = &g.when {
                        let _ = writeln!(out, "when = {}", quote(&w.node.to_string()));
                    }
                    out.push('\n');
                    for l in &g.layers {
                        print_layer(&mut out, l);
                    }
                    let _ = writeln!(out, "[[end]]");
                    out.push('\n');
                }
            }
        }
        out
    }
}

fn print_layer(out: &mut String, l: &LayerDecl) {
    let _ = writeln!(out, "[[layer]]");
    let _ = writeln!(out, "name = {}", quote(&l.name.source()));
    let _ = writeln!(out, "kind = {}", quote(l.body.kind_name()));
    if let Some(f) = &l.from {
        let _ = writeln!(out, "from = {}", quote(&f.source()));
    }
    if let Some(w) = &l.with {
        let _ = writeln!(out, "with = {}", quote(&w.source()));
    }
    match &l.body {
        LayerBody::Conv1d { out_channels, kernel, stride, pad }
        | LayerBody::Conv2d { out_channels, kernel, stride, pad } => {
            let _ = writeln!(out, "out_channels = {}", pexpr_value(&out_channels.node));
            let _ = writeln!(out, "kernel = {}", pexpr_value(&kernel.node));
            let _ = writeln!(out, "stride = {}", pexpr_value(&stride.node));
            let _ = writeln!(out, "pad = {}", pad.node);
        }
        LayerBody::DwConv2d { kernel, stride, pad } => {
            let _ = writeln!(out, "kernel = {}", pexpr_value(&kernel.node));
            let _ = writeln!(out, "stride = {}", pexpr_value(&stride.node));
            let _ = writeln!(out, "pad = {}", pad.node);
        }
        LayerBody::Dense { out_channels, in_features } => {
            let _ = writeln!(out, "out_channels = {}", pexpr_value(&out_channels.node));
            if let Some(f) = in_features {
                let _ = writeln!(out, "in_features = {}", pexpr_value(&f.node));
            }
        }
        LayerBody::Pool1d { kernel, stride, .. } | LayerBody::Pool2d { kernel, stride, .. } => {
            let _ = writeln!(out, "kernel = {}", pexpr_value(&kernel.node));
            let _ = writeln!(out, "stride = {}", pexpr_value(&stride.node));
        }
        LayerBody::Act { .. } | LayerBody::Add | LayerBody::Mul => {}
    }
    if !l.foreach.is_empty() {
        let ranges: Vec<String> = l
            .foreach
            .iter()
            .map(|r| format!("{} in {}..{}", r.var.node, r.lo.node, r.hi.node))
            .collect();
        let _ = writeln!(out, "foreach = {}", quote(&ranges.join(", ")));
    }
    if let Some(w) = &l.when {
        let _ = writeln!(out, "when = {}", quote(&w.node.to_string()));
    }
    out.push('\n');
}

/// Print a [`PExpr`] as a TOML value: bare integer for constants, quoted
/// expression string otherwise.
fn pexpr_value(e: &PExpr) -> String {
    match e {
        PExpr::Const(v) => v.to_string(),
        other => quote(&other.to_string()),
    }
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_cover_every_body() {
        let one = Spanned::bare(PExpr::Const(1));
        let bodies = [
            LayerBody::Conv1d {
                out_channels: one.clone(),
                kernel: one.clone(),
                stride: one.clone(),
                pad: Spanned::bare(false),
            },
            LayerBody::Dense { out_channels: one.clone(), in_features: None },
            LayerBody::Pool1d { pool: PoolKind::Avg, kernel: one.clone(), stride: one.clone() },
            LayerBody::Pool2d { pool: PoolKind::Max, kernel: one.clone(), stride: one },
            LayerBody::Act { act: ActKind::Clip },
            LayerBody::Add,
            LayerBody::Mul,
        ];
        let names: Vec<&str> = bodies.iter().map(|b| b.kind_name()).collect();
        assert_eq!(names, vec!["conv1d", "dense", "avgpool1d", "maxpool2d", "clip", "add", "mul"]);
        assert!(LayerBody::Add.takes_with() && LayerBody::Mul.takes_with());
        assert!(!LayerBody::Act { act: ActKind::Relu }.takes_with());
    }

    #[test]
    fn printer_emits_defaults_explicitly() {
        let desc = NetDescription {
            name: Some(Template::lit("n")),
            params: Vec::new(),
            inputs: vec![InputDecl {
                name: Template::lit("input"),
                channels: Spanned::bare(PExpr::Const(3)),
                shape: InputShape::TwoD {
                    height: Spanned::bare(PExpr::Const(8)),
                    width: Spanned::bare(PExpr::Const(8)),
                },
                span: Span::default(),
            }],
            items: vec![Item::Layer(LayerDecl {
                name: Template::lit("c"),
                body: LayerBody::Conv2d {
                    out_channels: Spanned::bare(PExpr::Const(4)),
                    kernel: Spanned::bare(PExpr::Const(3)),
                    stride: Spanned::bare(PExpr::Const(1)),
                    pad: Spanned::bare(true),
                },
                from: None,
                with: None,
                foreach: Vec::new(),
                when: None,
                span: Span::default(),
            })],
        };
        let toml = desc.to_toml();
        assert!(toml.contains("stride = 1"), "{toml}");
        assert!(toml.contains("pad = true"), "{toml}");
        assert!(toml.contains("height = 8"), "{toml}");
    }
}
