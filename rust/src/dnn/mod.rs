//! DNN layer IR, the model zoo (paper §5/§7 workloads), and the textual
//! network frontend.
//!
//! Workloads reach the estimator two ways, producing the same [`Network`]
//! IR:
//!
//! - [`zoo`] — hardcoded Rust builders for the paper's three edge-AI
//!   networks (TC-ResNet8, AlexNet, EfficientNet) plus reduced variants;
//! - [`text`] — the textual frontend compiling TOML-flavored descriptions
//!   (`net/*.toml`, `net:<path>` specs, the server's `network describe`
//!   command), so serve traffic can estimate arbitrary user networks
//!   without recompiling Rust.

pub mod layer;
pub mod text;
pub mod zoo;

pub use layer::{ActKind, Layer, LayerKind, Network, PoolKind};
