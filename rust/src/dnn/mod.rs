//! DNN layer IR + model zoo (paper §5/§7 workloads).

pub mod layer;
pub mod zoo;

pub use layer::{ActKind, Layer, LayerKind, Network, PoolKind};
