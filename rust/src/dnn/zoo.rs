//! The model zoo: the paper's three edge-AI workloads (§7) plus reduced
//! variants used where whole-graph ground truth must fit the session budget
//! (see DESIGN.md §3 scaling note).
//!
//! - **TC-ResNet8** [10]: 1D temporal convolutions for keyword spotting —
//!   the UltraTrail workload.
//! - **AlexNet** [15]: classic 2D CNN with large conv + FC layers.
//! - **EfficientNet(-B0-style)** [24]: MBConv blocks with depth-wise convs,
//!   squeeze-excite multiplies, and residual adds.
//!
//! Activation/add layers are explicit (the paper's Appendix A.2 analyzes
//! clip/add mappings of TC-ResNet8 separately).

use super::layer::{ActKind, Layer, LayerKind, Network, PoolKind};

/// TC-ResNet8 for keyword spotting: conv1 + 3 residual blocks
/// (k=9 temporal convs) + avg-pool + FC. Input: 40 MFCC channels × 100
/// frames (channels-as-features 1D layout, as in the TC-ResNet paper).
pub fn tc_resnet8() -> Network {
    let mut n = Network::new("tc_resnet8");
    let mut t = 100u32; // frames
    let mut c = 40u32; // channels

    n.push(Layer::new(
        "conv1",
        LayerKind::Conv1d { c_in: c, l_in: t, c_out: 16, kernel: 3, stride: 1, pad: true },
    ));
    c = 16;
    n.push(Layer::new("clip1", LayerKind::Act { kind: ActKind::Clip, c, spatial: t }));

    for (b, c_out) in [(1u32, 24u32), (2, 32), (3, 48)] {
        let t_in = t;
        let c_in = c;
        let t_out = (t_in + 1) / 2; // stride-2 same-pad
        n.push(Layer::new(
            format!("block{b}_conv1"),
            LayerKind::Conv1d { c_in, l_in: t_in, c_out, kernel: 9, stride: 2, pad: true },
        ));
        n.push(Layer::new(
            format!("block{b}_clip1"),
            LayerKind::Act { kind: ActKind::Clip, c: c_out, spatial: t_out },
        ));
        n.push(Layer::new(
            format!("block{b}_conv2"),
            LayerKind::Conv1d { c_in: c_out, l_in: t_out, c_out, kernel: 9, stride: 1, pad: true },
        ));
        // residual 1×1 conv on the skip path (stride 2)
        n.push(Layer::new(
            format!("block{b}_res"),
            LayerKind::Conv1d { c_in, l_in: t_in, c_out, kernel: 1, stride: 2, pad: false },
        ));
        n.push(Layer::new(format!("block{b}_add"), LayerKind::Add { c: c_out, spatial: t_out }));
        n.push(Layer::new(
            format!("block{b}_clip2"),
            LayerKind::Act { kind: ActKind::Clip, c: c_out, spatial: t_out },
        ));
        t = t_out;
        c = c_out;
    }

    n.push(Layer::new("avgpool", LayerKind::Pool1d { kind: PoolKind::Avg, c, l: t, k: t, stride: 1 }));
    n.push(Layer::new("fc", LayerKind::Dense { c_in: c, c_out: 12 }));
    n
}

/// Full-size AlexNet (227×227 input, the canonical 9216-wide fc6). LRN
/// layers are omitted (negligible and unsupported by all four modeled
/// accelerators, as in the paper's mappings).
pub fn alexnet() -> Network {
    alexnet_at(227)
}

/// Reduced-resolution AlexNet used where whole-graph / DES ground truth
/// must fit the session budget. Same layer structure, 67×67 input.
pub fn alexnet_reduced() -> Network {
    alexnet_at(67)
}

fn alexnet_at(input: u32) -> Network {
    let name = if input == 227 { "alexnet".to_string() } else { format!("alexnet_{input}") };
    let mut n = Network::new(name);
    let mut s = input;

    n.push(Layer::new(
        "conv1",
        LayerKind::Conv2d { c_in: 3, h: s, w: s, c_out: 96, kh: 11, kw: 11, stride: 4, pad: false },
    ));
    s = (s - 11) / 4 + 1;
    n.push(Layer::new("relu1", LayerKind::Act { kind: ActKind::Relu, c: 96, spatial: s * s }));
    n.push(Layer::new("pool1", LayerKind::Pool2d { kind: PoolKind::Max, c: 96, h: s, w: s, k: 3, stride: 2 }));
    s = (s - 3) / 2 + 1;

    n.push(Layer::new(
        "conv2",
        LayerKind::Conv2d { c_in: 96, h: s, w: s, c_out: 256, kh: 5, kw: 5, stride: 1, pad: true },
    ));
    n.push(Layer::new("relu2", LayerKind::Act { kind: ActKind::Relu, c: 256, spatial: s * s }));
    n.push(Layer::new("pool2", LayerKind::Pool2d { kind: PoolKind::Max, c: 256, h: s, w: s, k: 3, stride: 2 }));
    s = (s - 3) / 2 + 1;

    n.push(Layer::new(
        "conv3",
        LayerKind::Conv2d { c_in: 256, h: s, w: s, c_out: 384, kh: 3, kw: 3, stride: 1, pad: true },
    ));
    n.push(Layer::new("relu3", LayerKind::Act { kind: ActKind::Relu, c: 384, spatial: s * s }));
    n.push(Layer::new(
        "conv4",
        LayerKind::Conv2d { c_in: 384, h: s, w: s, c_out: 384, kh: 3, kw: 3, stride: 1, pad: true },
    ));
    n.push(Layer::new("relu4", LayerKind::Act { kind: ActKind::Relu, c: 384, spatial: s * s }));
    n.push(Layer::new(
        "conv5",
        LayerKind::Conv2d { c_in: 384, h: s, w: s, c_out: 256, kh: 3, kw: 3, stride: 1, pad: true },
    ));
    n.push(Layer::new("relu5", LayerKind::Act { kind: ActKind::Relu, c: 256, spatial: s * s }));
    n.push(Layer::new("pool5", LayerKind::Pool2d { kind: PoolKind::Max, c: 256, h: s, w: s, k: 3, stride: 2 }));
    s = (s - 3) / 2 + 1;

    let flat = 256 * s * s;
    n.push(Layer::new("fc6", LayerKind::Dense { c_in: flat, c_out: 4096 }));
    n.push(Layer::new("relu6", LayerKind::Act { kind: ActKind::Relu, c: 4096, spatial: 1 }));
    n.push(Layer::new("fc7", LayerKind::Dense { c_in: 4096, c_out: 4096 }));
    n.push(Layer::new("relu7", LayerKind::Act { kind: ActKind::Relu, c: 4096, spatial: 1 }));
    n.push(Layer::new("fc8", LayerKind::Dense { c_in: 4096, c_out: 1000 }));
    n
}

/// One MBConv block: expand (1×1) → dwconv → squeeze-excite (two small
/// dense + mul) → project (1×1) (+ residual add when shapes match).
#[allow(clippy::too_many_arguments)]
fn mbconv(
    n: &mut Network,
    idx: u32,
    c_in: u32,
    c_out: u32,
    s_in: u32,
    expand: u32,
    k: u32,
    stride: u32,
    se: bool,
) -> u32 {
    let c_mid = c_in * expand;
    let s_out = if stride == 1 { s_in } else { s_in.div_ceil(stride) };
    if expand != 1 {
        n.push(Layer::new(
            format!("mb{idx}_expand"),
            LayerKind::Conv2d { c_in, h: s_in, w: s_in, c_out: c_mid, kh: 1, kw: 1, stride: 1, pad: false },
        ));
        n.push(Layer::new(
            format!("mb{idx}_expand_act"),
            LayerKind::Act { kind: ActKind::Relu, c: c_mid, spatial: s_in * s_in },
        ));
    }
    n.push(Layer::new(
        format!("mb{idx}_dw"),
        LayerKind::DwConv2d { c: c_mid, h: s_in, w: s_in, kh: k, kw: k, stride, pad: true },
    ));
    n.push(Layer::new(
        format!("mb{idx}_dw_act"),
        LayerKind::Act { kind: ActKind::Relu, c: c_mid, spatial: s_out * s_out },
    ));
    if se {
        let c_se = (c_in / 4).max(1);
        n.push(Layer::new(format!("mb{idx}_se_reduce"), LayerKind::Dense { c_in: c_mid, c_out: c_se }));
        n.push(Layer::new(format!("mb{idx}_se_expand"), LayerKind::Dense { c_in: c_se, c_out: c_mid }));
        n.push(Layer::new(
            format!("mb{idx}_se_scale"),
            LayerKind::Mul { c: c_mid, spatial: s_out * s_out },
        ));
    }
    n.push(Layer::new(
        format!("mb{idx}_project"),
        LayerKind::Conv2d { c_in: c_mid, h: s_out, w: s_out, c_out, kh: 1, kw: 1, stride: 1, pad: false },
    ));
    if stride == 1 && c_in == c_out {
        n.push(Layer::new(
            format!("mb{idx}_add"),
            LayerKind::Add { c: c_out, spatial: s_out * s_out },
        ));
    }
    s_out
}

/// EfficientNet-B0-style edge network (full size, 224×224).
pub fn efficientnet() -> Network {
    efficientnet_cfg("efficientnet", 224, &B0_BLOCKS)
}

/// Reduced EfficientNet (56×56 input, half the block repeats) for
/// ground-truth-bounded experiments.
pub fn efficientnet_reduced() -> Network {
    efficientnet_cfg("efficientnet_56", 56, &TINY_BLOCKS)
}

/// (expand, c_out, repeats, stride, kernel, se)
type BlockCfg = (u32, u32, u32, u32, u32, bool);

const B0_BLOCKS: [BlockCfg; 7] = [
    (1, 16, 1, 1, 3, true),
    (6, 24, 2, 2, 3, true),
    (6, 40, 2, 2, 5, true),
    (6, 80, 3, 2, 3, true),
    (6, 112, 3, 1, 5, true),
    (6, 192, 4, 2, 5, true),
    (6, 320, 1, 1, 3, true),
];

const TINY_BLOCKS: [BlockCfg; 5] = [
    (1, 16, 1, 1, 3, true),
    (6, 24, 1, 2, 3, true),
    (6, 40, 1, 2, 5, true),
    (6, 80, 2, 2, 3, true),
    (6, 112, 1, 1, 5, true),
];

fn efficientnet_cfg(name: &str, input: u32, blocks: &[BlockCfg]) -> Network {
    let mut n = Network::new(name);
    let mut s = input;
    // stem
    n.push(Layer::new(
        "stem",
        LayerKind::Conv2d { c_in: 3, h: s, w: s, c_out: 32, kh: 3, kw: 3, stride: 2, pad: true },
    ));
    s = s.div_ceil(2);
    n.push(Layer::new("stem_act", LayerKind::Act { kind: ActKind::Relu, c: 32, spatial: s * s }));

    let mut c = 32u32;
    let mut idx = 0u32;
    for &(expand, c_out, repeats, stride, k, se) in blocks {
        for r in 0..repeats {
            let st = if r == 0 { stride } else { 1 };
            s = mbconv(&mut n, idx, c, c_out, s, expand, k, st, se);
            c = c_out;
            idx += 1;
        }
    }

    // head
    n.push(Layer::new(
        "head",
        LayerKind::Conv2d { c_in: c, h: s, w: s, c_out: 1280, kh: 1, kw: 1, stride: 1, pad: false },
    ));
    n.push(Layer::new("head_act", LayerKind::Act { kind: ActKind::Relu, c: 1280, spatial: s * s }));
    n.push(Layer::new(
        "avgpool",
        LayerKind::Pool2d { kind: PoolKind::Avg, c: 1280, h: s, w: s, k: s, stride: 1 },
    ));
    n.push(Layer::new("fc", LayerKind::Dense { c_in: 1280, c_out: 1000 }));
    n
}

/// Look up a network by name (CLI / coordinator interface).
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "tc_resnet8" | "tc-resnet8" => Some(tc_resnet8()),
        "alexnet" => Some(alexnet()),
        "alexnet_reduced" | "alexnet_67" => Some(alexnet_reduced()),
        "efficientnet" => Some(efficientnet()),
        "efficientnet_reduced" | "efficientnet_56" => Some(efficientnet_reduced()),
        _ => None,
    }
}

/// All zoo entries (full + reduced).
pub fn all_names() -> &'static [&'static str] {
    &["tc_resnet8", "alexnet", "alexnet_reduced", "efficientnet", "efficientnet_reduced"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::LayerKind;

    #[test]
    fn tc_resnet8_structure() {
        let n = tc_resnet8();
        // conv1 + clip + 3 blocks × 6 + pool + fc = 22
        assert_eq!(n.num_layers(), 22);
        // all 1D / elementwise / dense
        assert!(n.layers.iter().all(|l| !matches!(l.kind, LayerKind::Conv2d { .. })));
        // ~1-10 MMACs: keyword-spotting scale
        let m = n.total_macs();
        assert!(m > 500_000 && m < 20_000_000, "macs {m}");
    }

    #[test]
    fn alexnet_matches_reference_macs() {
        let n = alexnet();
        // canonical AlexNet ≈ 0.7-1.2 GMACs (54×54 conv1 variant)
        let m = n.total_macs();
        assert!(m > 600_000_000 && m < 1_500_000_000, "macs {m}");
        // fc6 dominates the FC part: 9216×4096
        let fc6 = n.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.macs(), 9216 * 4096);
    }

    #[test]
    fn alexnet_reduced_is_much_smaller() {
        assert!(alexnet_reduced().total_macs() < alexnet().total_macs() / 5);
        // same structure
        assert_eq!(alexnet_reduced().num_layers(), alexnet().num_layers());
    }

    #[test]
    fn efficientnet_has_dw_and_se() {
        let n = efficientnet();
        assert!(n.layers.iter().any(|l| matches!(l.kind, LayerKind::DwConv2d { .. })));
        assert!(n.layers.iter().any(|l| matches!(l.kind, LayerKind::Mul { .. })));
        assert!(n.layers.iter().any(|l| matches!(l.kind, LayerKind::Add { .. })));
        // B0 ≈ 0.39 GMACs; our variant should be same order
        let m = n.total_macs();
        assert!(m > 100_000_000 && m < 1_000_000_000, "macs {m}");
        assert!(n.num_layers() > 60, "layers {}", n.num_layers());
    }

    #[test]
    fn zoo_lookup() {
        for name in all_names() {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("vgg").is_none());
    }

    #[test]
    fn shapes_chain_consistently() {
        // every consecutive (conv → act) pair must agree on element count
        for net in [tc_resnet8(), alexnet(), efficientnet()] {
            for w in net.layers.windows(2) {
                if let (l, Layer { kind: LayerKind::Act { c, spatial, .. }, .. }) = (&w[0], &w[1])
                {
                    if l.is_gemm_like() || matches!(l.kind, LayerKind::DwConv2d { .. }) {
                        assert_eq!(
                            l.out_words(),
                            *c as u64 * *spatial as u64,
                            "{}/{} mismatch in {}",
                            w[0].name,
                            w[1].name,
                            net.name
                        );
                    }
                }
            }
        }
    }
}
