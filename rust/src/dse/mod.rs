//! Architecture-generic design-space exploration (paper §7.4, Fig. 15).
//!
//! The paper's end goal is pre-RTL exploration: exclude losing accelerator
//! designs with cheap estimates *before* paying for accurate ones — and
//! never write RTL for any of them. Related work (ANNETTE's mixed models,
//! the "performance representatives" benchmarking line) reaches the same
//! conclusion: a small number of representative evaluations can rank large
//! design spaces. This module turns that loop architecture-generic:
//!
//! 1. **Space** ([`space`]) — any described architecture (`arch/*.toml`)
//!    declares its design space in a `[sweep]` section over its own
//!    `${}`/`[params]` template parameters; the space compiles with spanned
//!    diagnostics and a combinatorial cap.
//! 2. **Enumerate** ([`enumerate`]) — candidates stream out lazily in
//!    deterministic row-major order, `when` guards applied.
//! 3. **Pre-filter** — every candidate gets a whole-network refined
//!    roofline estimate ([`RooflineBackend`], XLA-batched when artifacts
//!    are built); only the best `keep_frac` survive.
//! 4. **Schedule** ([`schedule`]) — survivors are ordered to maximize
//!    [`KernelKey`](crate::engine::KernelKey) reuse: candidates whose swept
//!    parameters leave `Diagram::content_digest`-relevant structure
//!    unchanged are grouped adjacently so the LRU-bounded estimate cache
//!    stays warm across thousands of design points.
//! 5. **Accurate pass + frontier** ([`frontier`]) — survivors get full
//!    AIDG fixed-point estimates through the engine + worker pool, and the
//!    Pareto frontier of (cycles, PE count, memory words) is marked for
//!    reporting through [`crate::report`].
//!
//! The legacy Plasticine grid API lives on in [`crate::coordinator::dse`]
//! as a compatibility shim over [`explore_candidates`].

pub mod enumerate;
pub mod frontier;
pub mod schedule;
pub mod space;

use std::time::{Duration, Instant};

use crate::baselines::roofline::{roofline_cycles, LayerFeatures};
use crate::coordinator::job::{Arch, EstimateStats};
use crate::coordinator::pool::Pool;
use crate::dnn::Network;
use crate::engine::{ArchDigest, EstimationEngine};
use crate::metrics::counters;
use crate::Result;

pub use enumerate::CandidateIter;
pub use frontier::{mark_frontier, merge_frontier};
pub use schedule::{plan_groups, plan_order, Schedule};
pub use space::{Candidate, SweepSpace};

/// Roofline batch source: XLA executable or the native mirror.
pub enum RooflineBackend {
    /// Batched through the AOT XLA executable.
    Xla(crate::runtime::RooflineExec),
    /// The native Rust mirror.
    Native,
}

impl RooflineBackend {
    /// Load the XLA backend, falling back to the native mirror when the
    /// artifacts are not built.
    pub fn auto() -> Self {
        match crate::runtime::RooflineExec::load() {
            Ok(x) => RooflineBackend::Xla(x),
            Err(_) => RooflineBackend::Native,
        }
    }

    /// Estimate a batch of layers on one hardware configuration.
    pub fn estimate(
        &self,
        layers: &[LayerFeatures],
        hw: &crate::baselines::roofline::HwFeatures,
    ) -> Result<Vec<f64>> {
        match self {
            RooflineBackend::Xla(x) => x.estimate(layers, hw),
            RooflineBackend::Native => {
                Ok(layers.iter().map(|l| roofline_cycles(l, hw)).collect())
            }
        }
    }
}

/// Exploration knobs.
#[derive(Debug, Clone, Copy)]
pub struct SweepOptions {
    /// Fraction of candidates surviving the roofline pre-filter into the
    /// accurate pass (1.0 = estimate everything, as Fig. 15 plots).
    pub keep_frac: f64,
    /// Fixed-point estimator configuration.
    pub fp: crate::aidg::FixedPointConfig,
    /// Accurate-pass ordering (default: cache-locality grouping).
    pub schedule: Schedule,
    /// Dispatch multi-candidate digest groups through the lane-batched
    /// evaluator ([`EstimationEngine::estimate_batch`]); singleton groups
    /// and trace-carrying sweeps always take the per-candidate path.
    /// Bit-identical either way — `--no-batch` (or `batch: false`) exists
    /// for perf comparison and serial-cache experiments.
    pub batch: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            keep_frac: 1.0,
            fp: crate::aidg::FixedPointConfig::default(),
            schedule: Schedule::Locality,
            batch: true,
        }
    }
}

/// One candidate ready to estimate: a display label, the instantiable
/// architecture, and the sweep assignment that produced it.
pub struct CandidateArch {
    /// Compact `rows=4,cols=8` label.
    pub label: String,
    /// The architecture (described candidates compile through the global
    /// registry; the legacy shim passes hand builders).
    pub arch: Arch,
    /// `(param, value)` pairs in dimension order.
    pub assignment: Vec<(String, i64)>,
}

/// One explored design point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Compact assignment label (`rows=4,cols=8`).
    pub label: String,
    /// The sweep assignment in dimension order.
    pub assignment: Vec<(String, i64)>,
    /// Compiled architecture name (e.g. `systolic4x8`).
    pub arch_name: String,
    /// Structural architecture digest
    /// ([`crate::acadl::Diagram::content_digest`]) — the
    /// locality-scheduling group key.
    pub digest: u64,
    /// Functional-unit count (PE cost proxy).
    pub pe_count: u64,
    /// Total memory words claimed (memory cost proxy).
    pub mem_words: u64,
    /// Whole-network refined-roofline cycles (phase 1).
    pub roofline_cycles: f64,
    /// Whole-network AIDG cycles (phase 2; `None` if pre-filtered out).
    pub aidg_cycles: Option<u64>,
    /// On the Pareto frontier of (cycles, PE count, memory words).
    pub on_frontier: bool,
}

/// The result of one exploration: every point (survivors sorted
/// best-AIDG-first, then pre-filtered points by roofline) plus run-level
/// accounting.
#[derive(Debug)]
pub struct SweepOutcome {
    /// Explored points, best first.
    pub points: Vec<SweepPoint>,
    /// Candidates enumerated (including unmappable ones).
    pub enumerated: u64,
    /// Candidates skipped because the architecture could not be
    /// instantiated (e.g. degenerate grids) or their guard failed to
    /// evaluate at that assignment.
    pub skipped: u64,
    /// Candidates that received an accurate AIDG estimate.
    pub estimated: u64,
    /// Aggregate engine accounting over the accurate pass.
    pub stats: EstimateStats,
    /// Wall time of the whole exploration.
    pub wall: Duration,
}

impl SweepOutcome {
    /// Fraction of kernel slots in the accurate pass served from the
    /// cross-candidate estimate cache (the locality scheduler's win; 0.0
    /// when nothing was estimated).
    pub fn warm_hit_rate(&self) -> f64 {
        self.stats.cache_hits as f64 / self.stats.total_kernels.max(1) as f64
    }

    /// Fraction of kernel slots reused from *anywhere* (cache or
    /// intra-candidate dedup).
    pub fn reuse_rate(&self) -> f64 {
        (self.stats.cache_hits + self.stats.deduped) as f64
            / self.stats.total_kernels.max(1) as f64
    }

    /// Points on the Pareto frontier, best-cycles-first.
    pub fn frontier(&self) -> Vec<&SweepPoint> {
        self.points.iter().filter(|p| p.on_frontier).collect()
    }
}

/// Explore a compiled sweep space against one network: enumerate, roofline
/// pre-filter, locality-schedule, accurately estimate, and mark the Pareto
/// frontier.
pub fn explore_space(
    space: &SweepSpace,
    net: &Network,
    opts: &SweepOptions,
    pool: &Pool,
    backend: &RooflineBackend,
    engine: &EstimationEngine,
) -> Result<SweepOutcome> {
    let mut cands = Vec::new();
    let mut guard_failures = 0u64;
    let mut first_guard_err: Option<anyhow::Error> = None;
    let mut enum_sp = crate::obs::span("dse.enumerate");
    for c in space.candidates() {
        match c {
            Ok(c) => cands.push(CandidateArch {
                label: c.label(),
                arch: space.candidate_arch(&c),
                assignment: c.assignment,
            }),
            Err(e) => {
                // a guard that fails at one assignment (e.g. divides by
                // zero there) excludes that point, not the whole sweep —
                // it surfaces through the skipped count
                guard_failures += 1;
                if first_guard_err.is_none() {
                    first_guard_err = Some(e);
                }
            }
        }
    }
    enum_sp.arg("candidates", cands.len() as u64);
    enum_sp.arg("guard_failures", guard_failures);
    drop(enum_sp);
    if cands.is_empty() {
        if let Some(e) = first_guard_err {
            return Err(e);
        }
    }
    let mut outcome = explore_candidates(cands, net, opts, pool, backend, engine)?;
    if guard_failures > 0 {
        outcome.enumerated += guard_failures;
        outcome.skipped += guard_failures;
        counters::DSE_POINTS_ENUMERATED.add(guard_failures);
    }
    Ok(outcome)
}

/// [`explore_space`] over pre-built candidates (the legacy Plasticine shim
/// and tests construct these directly).
pub fn explore_candidates(
    cands: Vec<CandidateArch>,
    net: &Network,
    opts: &SweepOptions,
    pool: &Pool,
    backend: &RooflineBackend,
    engine: &EstimationEngine,
) -> Result<SweepOutcome> {
    anyhow::ensure!(
        opts.keep_frac.is_finite() && (0.0..=1.0).contains(&opts.keep_frac),
        "keep_frac must be a finite fraction in 0..=1 (got {})",
        opts.keep_frac
    );
    let mut sp = crate::obs::span("dse.explore");
    let t0 = Instant::now();

    // ---- phase 1: roofline everything ----------------------------------
    let prefilter_sp = crate::obs::span("dse.prefilter");
    let mut points: Vec<SweepPoint> = Vec::new();
    let mut archs: Vec<Arch> = Vec::new();
    let mut enumerated = 0u64;
    let mut skipped = 0u64;
    for cand in cands {
        enumerated += 1;
        counters::DSE_POINTS_ENUMERATED.add(1);
        let mapper = match cand.arch.mapper() {
            Ok(m) => m,
            Err(_) => {
                // degenerate design point (e.g. a 1×1 grid); exploration
                // excludes it rather than failing the whole sweep
                skipped += 1;
                continue;
            }
        };
        let mapped = mapper.map_network(net)?;
        let feats: Vec<LayerFeatures> = net
            .layers
            .iter()
            .zip(&mapped)
            .filter(|(_, m)| !m.fused)
            .map(|(l, m)| LayerFeatures::from_mapping(l, m))
            .collect();
        let hw = mapper.hw_features();
        let cycles = backend.estimate(&feats, &hw)?;
        let d = mapper.diagram();
        points.push(SweepPoint {
            label: cand.label,
            assignment: cand.assignment,
            arch_name: d.name.clone(),
            digest: ArchDigest::of(d).0,
            pe_count: d.fu_count() as u64,
            mem_words: d.memory_words(),
            roofline_cycles: cycles.iter().sum(),
            aidg_cycles: None,
            on_frontier: false,
        });
        archs.push(cand.arch);
    }
    // the funnel: enumerated (all) >= prefiltered (mappable, roofline
    // evaluated) >= estimated (survived keep_frac into the accurate pass)
    counters::DSE_POINTS_PREFILTERED.add(points.len() as u64);
    drop(prefilter_sp);

    // ---- phase 2: survivors, locality-ordered, accurately estimated ----
    let estimate_sp = crate::obs::span("dse.estimate");
    let keep =
        ((points.len() as f64 * opts.keep_frac).ceil() as usize).clamp(1, points.len().max(1));
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| points[a].roofline_cycles.total_cmp(&points[b].roofline_cycles));
    let survivors: Vec<usize> = order.into_iter().take(keep).collect();
    let digests: Vec<u64> = survivors.iter().map(|&i| points[i].digest).collect();
    let plan = plan_order(&digests, opts.schedule);
    let groups = plan_groups(&digests, opts.schedule);

    let mut stats = EstimateStats::default();
    let mut estimated = 0u64;
    let mut note = |i: usize,
                    e: &crate::coordinator::job::NetworkEstimate,
                    points: &mut Vec<SweepPoint>| {
        points[i].aidg_cycles = Some(e.total_cycles());
        stats.total_kernels += e.stats.total_kernels;
        stats.unique_kernels += e.stats.unique_kernels;
        stats.cache_hits += e.stats.cache_hits;
        stats.deduped += e.stats.deduped;
        stats.evaluated += e.stats.evaluated;
        estimated += 1;
        counters::DSE_POINTS_ESTIMATED.add(1);
    };
    for g in groups {
        let members = &plan[g];
        if opts.batch && members.len() > 1 && !opts.fp.keep_trace {
            // whole digest group: one lane-batched dispatch (divergent
            // lanes are evicted to the serial path inside the engine)
            let group_archs: Vec<&Arch> = members.iter().map(|&s| &archs[survivors[s]]).collect();
            let ests = engine.estimate_batch(&group_archs, net, &opts.fp, pool)?;
            debug_assert_eq!(ests.len(), members.len());
            for (&s, e) in members.iter().zip(&ests) {
                note(survivors[s], e, &mut points);
            }
        } else {
            for &s in members {
                let i = survivors[s];
                let e = engine.estimate_network_pooled(&archs[i], net, &opts.fp, pool)?;
                note(i, &e, &mut points);
            }
        }
    }
    drop(estimate_sp);
    sp.arg("enumerated", enumerated);
    sp.arg("estimated", estimated);

    // survivors best-AIDG-first, then pre-filtered points by roofline
    points.sort_by(|a, b| match (a.aidg_cycles, b.aidg_cycles) {
        (Some(x), Some(y)) => x.cmp(&y),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.roofline_cycles.total_cmp(&b.roofline_cycles),
    });
    mark_frontier(&mut points);
    Ok(SweepOutcome { points, enumerated, skipped, estimated, stats, wall: t0.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::PlasticineConfig;
    use crate::engine::DEFAULT_CACHE_CAP;

    fn grid_candidates() -> Vec<CandidateArch> {
        let mut cands = Vec::new();
        for (r, c) in [(2u32, 2u32), (2, 3), (3, 2)] {
            cands.push(CandidateArch {
                label: format!("rows={r},cols={c}"),
                arch: Arch::Plasticine(PlasticineConfig::new(r, c, 8)),
                assignment: vec![("rows".into(), r as i64), ("cols".into(), c as i64)],
            });
        }
        cands
    }

    #[test]
    fn explore_candidates_ranks_and_marks_frontier() {
        let net = crate::dnn::zoo::tc_resnet8();
        let pool = Pool::new(2);
        let engine = EstimationEngine::new(DEFAULT_CACHE_CAP);
        let outcome = explore_candidates(
            grid_candidates(),
            &net,
            &SweepOptions::default(),
            &pool,
            &RooflineBackend::Native,
            &engine,
        )
        .unwrap();
        assert_eq!(outcome.enumerated, 3);
        assert_eq!(outcome.estimated, 3);
        assert!(outcome.points.iter().all(|p| p.aidg_cycles.is_some()));
        let cycles: Vec<u64> = outcome.points.iter().filter_map(|p| p.aidg_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] <= w[1]), "{cycles:?}");
        assert!(!outcome.frontier().is_empty());
        // the best-cycles point always survives dominance on the cycle axis
        assert!(outcome.points[0].on_frontier);
        assert!(outcome.points.iter().all(|p| p.pe_count > 0 && p.mem_words > 0));
    }

    #[test]
    fn keep_frac_is_validated() {
        let net = crate::dnn::zoo::tc_resnet8();
        let pool = Pool::new(1);
        let engine = EstimationEngine::new(16);
        for bad in [f64::NAN, -0.1, 1.1] {
            let opts = SweepOptions { keep_frac: bad, ..Default::default() };
            assert!(
                explore_candidates(
                    grid_candidates(),
                    &net,
                    &opts,
                    &pool,
                    &RooflineBackend::Native,
                    &engine
                )
                .is_err(),
                "keep_frac {bad} must be rejected"
            );
        }
    }
}
