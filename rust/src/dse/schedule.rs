//! Cache-locality scheduling of the accurate pass.
//!
//! Phase 2 estimates survivors sequentially (each one fans out at kernel
//! granularity over the worker pool), so the *order* of candidates decides
//! how warm the content-addressed estimate cache stays: two candidates
//! share [`KernelKey`](crate::engine::KernelKey)s only when their diagrams
//! digest equally (swept parameters that never touch
//! `Diagram::content_digest`-relevant structure — name-only or
//! mapper-binding-only params), and an LRU-bounded cache forgets a group's
//! kernels if unrelated candidates run in between. Grouping same-digest
//! candidates adjacently therefore maximizes the warm hit rate across
//! thousands of design points without growing the cache.
//!
//! The same digest groups feed the lane-batched evaluator
//! ([`crate::aidg::batch`]): [`plan_groups`] exposes the contiguous
//! same-digest runs of a planned order so the dispatcher can hand whole
//! groups to `estimate_batch` instead of re-scanning the flat order.

use std::ops::Range;

/// How to order phase-2 survivors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Group candidates by architecture digest; groups (and members within
    /// a group) keep the roofline best-first order. The default.
    Locality,
    /// Keep the roofline best-first order untouched.
    Enumerated,
    /// Deterministic pseudo-random permutation of the digest *groups* (the
    /// locality baseline `rust/tests/dse_generic.rs` measures against).
    /// Members within a group keep their order and stay adjacent — point-
    /// wise shuffling would leave the batch dispatcher with singleton
    /// groups only and the estimate cache cold (see docs/dse.md).
    Shuffled(u64),
}

/// Plan the estimation order over survivors with the given architecture
/// `digests` (one per survivor, in roofline best-first order). Returns the
/// indices in execution order. Pure and deterministic for every variant.
pub fn plan_order(digests: &[u64], schedule: Schedule) -> Vec<usize> {
    let n = digests.len();
    let mut order: Vec<usize> = (0..n).collect();
    match schedule {
        Schedule::Enumerated => order,
        Schedule::Locality => {
            // first-appearance rank of each digest; stable sort keeps the
            // roofline order both across groups and within each group
            let mut group_rank: Vec<(u64, usize)> = Vec::new();
            let mut rank_of = |d: u64| -> usize {
                if let Some(&(_, r)) = group_rank.iter().find(|(g, _)| *g == d) {
                    return r;
                }
                let r = group_rank.len();
                group_rank.push((d, r));
                r
            };
            let ranks: Vec<usize> = digests.iter().map(|&d| rank_of(d)).collect();
            order.sort_by_key(|&i| ranks[i]);
            order
        }
        Schedule::Shuffled(seed) => {
            // Collect digest groups in first-appearance order, then
            // Fisher–Yates over the *groups* with an xorshift64* stream (no
            // RNG crate in the offline image; determinism is the point
            // anyway). All-distinct digests degrade to the classic
            // point-wise shuffle.
            let mut groups: Vec<(u64, Vec<usize>)> = Vec::new();
            for (i, &d) in digests.iter().enumerate() {
                if let Some((_, members)) = groups.iter_mut().find(|(g, _)| *g == d) {
                    members.push(i);
                } else {
                    groups.push((d, vec![i]));
                }
            }
            let mut state = seed | 1;
            let mut next = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                state.wrapping_mul(0x2545_F491_4F6C_DD1D)
            };
            for i in (1..groups.len()).rev() {
                let j = (next() % (i as u64 + 1)) as usize;
                groups.swap(i, j);
            }
            groups.into_iter().flat_map(|(_, members)| members).collect()
        }
    }
}

/// The contiguous same-digest runs of `plan_order(digests, schedule)`, as
/// ranges into that order (concatenated they cover `0..digests.len()`).
/// Under [`Schedule::Locality`] and [`Schedule::Shuffled`] each digest
/// appears in exactly one run; [`Schedule::Enumerated`] splits a digest
/// interleaved with others into multiple runs (the order is not
/// rearranged, so only already-adjacent members batch together).
pub fn plan_groups(digests: &[u64], schedule: Schedule) -> Vec<Range<usize>> {
    let order = plan_order(digests, schedule);
    let mut groups = Vec::new();
    let mut start = 0usize;
    for i in 1..=order.len() {
        if i == order.len() || digests[order[i]] != digests[order[start]] {
            groups.push(start..i);
            start = i;
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_groups_by_digest_stably() {
        // interleaved groups A/B/A/B/A with a C tail
        let digests = [7, 9, 7, 9, 7, 3];
        let order = plan_order(&digests, Schedule::Locality);
        assert_eq!(order, vec![0, 2, 4, 1, 3, 5]);
        // enumerated keeps the input order
        assert_eq!(plan_order(&digests, Schedule::Enumerated), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn shuffle_is_a_deterministic_permutation() {
        let digests = [1, 2, 3, 4, 5, 6, 7];
        let a = plan_order(&digests, Schedule::Shuffled(42));
        let b = plan_order(&digests, Schedule::Shuffled(42));
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<_>>());
        assert_ne!(a, plan_order(&digests, Schedule::Shuffled(43)));
    }

    #[test]
    fn shuffle_keeps_digest_groups_adjacent() {
        // three interleaved groups; any seed must keep each group's members
        // contiguous and in first-appearance order
        let digests = [1, 2, 3, 1, 2, 3, 1, 2, 3];
        for seed in 0..32u64 {
            let order = plan_order(&digests, Schedule::Shuffled(seed));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..digests.len()).collect::<Vec<_>>());
            let groups = plan_groups(&digests, Schedule::Shuffled(seed));
            assert_eq!(groups.len(), 3, "each digest forms exactly one run");
            for g in groups {
                let d = digests[order[g.start]];
                let members: Vec<usize> = order[g].iter().copied().collect();
                assert!(members.windows(2).all(|w| w[0] < w[1]), "members keep input order");
                assert!(members.iter().all(|&i| digests[i] == d));
                assert_eq!(members.len(), 3);
            }
        }
    }

    #[test]
    fn plan_groups_covers_the_order_contiguously() {
        let digests = [7, 9, 7, 9, 7, 3];
        let groups = plan_groups(&digests, Schedule::Locality);
        assert_eq!(groups, vec![0..3, 3..5, 5..6]);
        // enumerated: interleaved digests split into singleton runs
        let runs = plan_groups(&digests, Schedule::Enumerated);
        assert_eq!(runs, vec![0..1, 1..2, 2..3, 3..4, 4..5, 5..6]);
        // adjacent duplicates still merge without reordering
        assert_eq!(plan_groups(&[4, 4, 8], Schedule::Enumerated), vec![0..2, 2..3]);
        assert!(plan_groups(&[], Schedule::Locality).is_empty());
    }

    #[test]
    fn empty_and_singleton_orders() {
        assert!(plan_order(&[], Schedule::Locality).is_empty());
        assert_eq!(plan_order(&[5], Schedule::Shuffled(0)), vec![0]);
    }
}
