//! Cache-locality scheduling of the accurate pass.
//!
//! Phase 2 estimates survivors sequentially (each one fans out at kernel
//! granularity over the worker pool), so the *order* of candidates decides
//! how warm the content-addressed estimate cache stays: two candidates
//! share [`KernelKey`](crate::engine::KernelKey)s only when their diagrams
//! digest equally (swept parameters that never touch
//! `Diagram::content_digest`-relevant structure — name-only or
//! mapper-binding-only params), and an LRU-bounded cache forgets a group's
//! kernels if unrelated candidates run in between. Grouping same-digest
//! candidates adjacently therefore maximizes the warm hit rate across
//! thousands of design points without growing the cache.

/// How to order phase-2 survivors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Group candidates by architecture digest; groups (and members within
    /// a group) keep the roofline best-first order. The default.
    Locality,
    /// Keep the roofline best-first order untouched.
    Enumerated,
    /// Deterministic pseudo-random permutation of the given seed (the
    /// locality baseline `rust/tests/dse_generic.rs` measures against).
    Shuffled(u64),
}

/// Plan the estimation order over survivors with the given architecture
/// `digests` (one per survivor, in roofline best-first order). Returns the
/// indices in execution order. Pure and deterministic for every variant.
pub fn plan_order(digests: &[u64], schedule: Schedule) -> Vec<usize> {
    let n = digests.len();
    let mut order: Vec<usize> = (0..n).collect();
    match schedule {
        Schedule::Enumerated => order,
        Schedule::Locality => {
            // first-appearance rank of each digest; stable sort keeps the
            // roofline order both across groups and within each group
            let mut group_rank: Vec<(u64, usize)> = Vec::new();
            let mut rank_of = |d: u64| -> usize {
                if let Some(&(_, r)) = group_rank.iter().find(|(g, _)| *g == d) {
                    return r;
                }
                let r = group_rank.len();
                group_rank.push((d, r));
                r
            };
            let ranks: Vec<usize> = digests.iter().map(|&d| rank_of(d)).collect();
            order.sort_by_key(|&i| ranks[i]);
            order
        }
        Schedule::Shuffled(seed) => {
            // Fisher–Yates over an xorshift64* stream (no RNG crate in the
            // offline image; determinism is the point anyway)
            let mut state = seed | 1;
            let mut next = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                state.wrapping_mul(0x2545_F491_4F6C_DD1D)
            };
            for i in (1..n).rev() {
                let j = (next() % (i as u64 + 1)) as usize;
                order.swap(i, j);
            }
            order
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_groups_by_digest_stably() {
        // interleaved groups A/B/A/B/A with a C tail
        let digests = [7, 9, 7, 9, 7, 3];
        let order = plan_order(&digests, Schedule::Locality);
        assert_eq!(order, vec![0, 2, 4, 1, 3, 5]);
        // enumerated keeps the input order
        assert_eq!(plan_order(&digests, Schedule::Enumerated), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn shuffle_is_a_deterministic_permutation() {
        let digests = [1, 2, 3, 4, 5, 6, 7];
        let a = plan_order(&digests, Schedule::Shuffled(42));
        let b = plan_order(&digests, Schedule::Shuffled(42));
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<_>>());
        assert_ne!(a, plan_order(&digests, Schedule::Shuffled(43)));
    }

    #[test]
    fn empty_and_singleton_orders() {
        assert!(plan_order(&[], Schedule::Locality).is_empty());
        assert_eq!(plan_order(&[5], Schedule::Shuffled(0)), vec![0]);
    }
}
