//! Lazy candidate enumeration over a [`SweepSpace`].
//!
//! Order is deterministic: row-major over the dimensions in declaration
//! order, the **last dimension varying fastest** (the natural reading of
//! nested loops, and the order `rust/tests/dse_generic.rs` pins).
//! `when`-guarded combinations are skipped without being materialized, so
//! a huge grid with a narrow guard still enumerates lazily; the
//! combinatorial cap was already enforced when the space compiled.

use crate::Result;

use super::space::{Candidate, SweepSpace};

/// Lazy iterator over a sweep space's surviving candidates.
pub struct CandidateIter<'a> {
    space: &'a SweepSpace,
    /// Per-dimension value cursor; `None` once exhausted.
    idx: Option<Vec<usize>>,
}

impl SweepSpace {
    /// Enumerate the space's candidates (guards applied) in deterministic
    /// row-major order. Items are `Err` only when the `when` guard itself
    /// fails to evaluate (e.g. division by zero at a specific assignment).
    pub fn candidates(&self) -> CandidateIter<'_> {
        CandidateIter { space: self, idx: Some(vec![0; self.sweep.dims.len()]) }
    }
}

impl CandidateIter<'_> {
    /// Advance the cursor one step (row-major); `false` at the end.
    fn advance(idx: &mut [usize], sizes: &[usize]) -> bool {
        for d in (0..idx.len()).rev() {
            idx[d] += 1;
            if idx[d] < sizes[d] {
                return true;
            }
            idx[d] = 0;
        }
        false
    }
}

impl Iterator for CandidateIter<'_> {
    type Item = Result<Candidate>;

    fn next(&mut self) -> Option<Self::Item> {
        let sweep = &self.space.sweep;
        let sizes: Vec<usize> = sweep.dims.iter().map(|d| d.values.len()).collect();
        loop {
            let idx = self.idx.as_mut()?;
            let assignment: Vec<(String, i64)> = sweep
                .dims
                .iter()
                .zip(idx.iter())
                .map(|(d, &i)| (d.name.clone(), d.values[i]))
                .collect();
            if !Self::advance(idx, &sizes) {
                self.idx = None;
            }
            if let Some(w) = &sweep.when {
                let lookup = |n: &str| {
                    assignment
                        .iter()
                        .find(|(name, _)| name == n)
                        .map(|(_, v)| *v)
                        .or_else(|| self.space.params().get(n).copied())
                };
                match w.node.eval(&lookup) {
                    Ok(0) => continue,
                    Ok(_) => {}
                    Err(msg) => {
                        let c = Candidate { assignment };
                        return Some(Err(anyhow::anyhow!(
                            "sweep guard failed at {}: {msg}",
                            c.label()
                        )));
                    }
                }
            }
            return Some(Ok(Candidate { assignment }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SWEPT: &str = r#"
[arch]
name = "t${rows}x${cols}"

[params]
rows = 2
cols = 2

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = 1
ifs = "ifs"
ifs_latency = 1
issue_buffer = 1

[sweep]
rows = "2, 4"
cols = "2..7 step 2"
when = "rows <= cols"
"#;

    #[test]
    fn enumeration_is_row_major_guarded_and_deterministic() {
        let space = SweepSpace::from_source(SWEPT, "inline", None).unwrap();
        let labels = |space: &SweepSpace| -> Vec<String> {
            space.candidates().map(|c| c.unwrap().label()).collect()
        };
        let first = labels(&space);
        // cols varies fastest; rows=4/cols=2 is guarded out
        assert_eq!(
            first,
            vec![
                "rows=2,cols=2",
                "rows=2,cols=4",
                "rows=2,cols=6",
                "rows=4,cols=4",
                "rows=4,cols=6",
            ]
        );
        assert_eq!(first, labels(&space), "enumeration must be deterministic");
    }

    #[test]
    fn guard_eval_errors_surface_per_candidate() {
        let src = SWEPT.replace("rows <= cols", "rows / (cols - 2) >= 0");
        let space = SweepSpace::from_source(&src, "inline", None).unwrap();
        let results: Vec<Result<Candidate>> = space.candidates().collect();
        // cols=2 assignments divide by zero; the others still enumerate
        assert!(results.iter().any(|r| r.is_err()));
        assert!(results.iter().any(|r| r.is_ok()));
        let msg = format!("{:#}", results[0].as_ref().unwrap_err());
        assert!(msg.contains("sweep guard failed at rows=2,cols=2"), "{msg}");
    }
}
