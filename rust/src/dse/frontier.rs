//! Pareto-frontier extraction over explored design points.
//!
//! The paper's Fig. 15 ranks designs by estimated cycles alone; real
//! pre-RTL exploration trades cycles against cost. We report the frontier
//! of (cycles, PE count, memory words): a point is dominated when another
//! point is no worse on every axis and strictly better on at least one.
//! Only points that received an accurate (AIDG) estimate participate —
//! pre-filtered points are never reported as winners.

use super::{SweepOutcome, SweepPoint};

/// Mark `on_frontier` on every point: true iff the point has an accurate
/// estimate and no other estimated point dominates it on
/// (cycles, PE count, memory words). O(n²), deterministic.
pub fn mark_frontier(points: &mut [SweepPoint]) {
    let axes: Vec<Option<(u64, u64, u64)>> = points
        .iter()
        .map(|p| p.aidg_cycles.map(|c| (c, p.pe_count, p.mem_words)))
        .collect();
    for i in 0..points.len() {
        points[i].on_frontier = match axes[i] {
            None => false,
            Some(a) => !axes
                .iter()
                .enumerate()
                .any(|(j, b)| j != i && b.is_some_and(|b| dominates(b, a))),
        };
    }
}

/// True when `a` is no worse than `b` on every axis and strictly better on
/// at least one (all axes minimized). Equal points do not dominate each
/// other, so ties stay on the frontier together.
fn dominates(a: (u64, u64, u64), b: (u64, u64, u64)) -> bool {
    a.0 <= b.0 && a.1 <= b.1 && a.2 <= b.2 && (a.0 < b.0 || a.1 < b.1 || a.2 < b.2)
}

/// Fold a prior run's persisted frontier into a fresh [`SweepOutcome`]:
/// points the fresh sweep did not re-enumerate (shrunk `keep=`/`cap=`, a
/// cheaper pre-filter) stay eligible, duplicates prefer the fresh copy,
/// and the merged set is re-ranked and re-marked. Sorting matches
/// [`super::explore_space`]'s final order (accurate estimates first,
/// ascending; roofline-only points after, by projected cycles) so the
/// reply's `best=` token and the `frontier` listing stay consistent with
/// an unmerged sweep.
pub fn merge_frontier(prior: Vec<SweepPoint>, outcome: &mut SweepOutcome) {
    use std::cmp::Ordering::{Greater, Less};
    let fresh: std::collections::HashSet<u64> =
        outcome.points.iter().map(|p| p.digest).collect();
    outcome.points.extend(prior.into_iter().filter(|p| !fresh.contains(&p.digest)));
    outcome.points.sort_by(|a, b| match (a.aidg_cycles, b.aidg_cycles) {
        (Some(x), Some(y)) => x.cmp(&y),
        (Some(_), None) => Less,
        (None, Some(_)) => Greater,
        (None, None) => a.roofline_cycles.total_cmp(&b.roofline_cycles),
    });
    mark_frontier(&mut outcome.points);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(cycles: Option<u64>, pe: u64, mem: u64) -> SweepPoint {
        SweepPoint {
            label: String::new(),
            assignment: Vec::new(),
            arch_name: String::new(),
            digest: 0,
            pe_count: pe,
            mem_words: mem,
            roofline_cycles: 0.0,
            aidg_cycles: cycles,
            on_frontier: false,
        }
    }

    #[test]
    fn frontier_keeps_tradeoffs_drops_dominated() {
        let mut pts = vec![
            point(Some(100), 4, 10),  // fast but big
            point(Some(200), 2, 10),  // slower but half the PEs
            point(Some(250), 4, 10),  // dominated by the first
            point(Some(100), 4, 10),  // exact tie with the first: kept
            point(None, 1, 1),        // never estimated: off-frontier
        ];
        mark_frontier(&mut pts);
        let on: Vec<bool> = pts.iter().map(|p| p.on_frontier).collect();
        assert_eq!(on, vec![true, true, false, true, false]);
    }

    #[test]
    fn single_estimated_point_is_the_frontier() {
        let mut pts = vec![point(Some(5), 1, 1)];
        mark_frontier(&mut pts);
        assert!(pts[0].on_frontier);
    }

    #[test]
    fn merge_frontier_resumes_prior_points_and_prefers_fresh() {
        let tag = |mut p: SweepPoint, digest: u64, label: &str| {
            p.digest = digest;
            p.label = label.to_string();
            p
        };
        let mut outcome = SweepOutcome {
            points: vec![
                tag(point(Some(300), 4, 10), 1, "fresh-slow"),
                tag(point(Some(100), 8, 20), 2, "fresh-fast"),
            ],
            enumerated: 2,
            skipped: 0,
            estimated: 2,
            stats: Default::default(),
            wall: std::time::Duration::ZERO,
        };
        let prior = vec![
            // same digest as a fresh point but stale cycles: dropped
            tag(point(Some(999), 4, 10), 1, "stale-dup"),
            // only the prior run saw this trade-off: resumed, on frontier
            tag(point(Some(200), 2, 5), 3, "prior-small"),
        ];
        merge_frontier(prior, &mut outcome);
        let labels: Vec<&str> = outcome.points.iter().map(|p| p.label.as_str()).collect();
        // explore_space order: accurate estimates ascending by cycles
        assert_eq!(labels, vec!["fresh-fast", "prior-small", "fresh-slow"]);
        let frontier: Vec<&str> =
            outcome.frontier().into_iter().map(|p| p.label.as_str()).collect();
        // fresh-slow (300 cy, 4 PE, 10 words) is dominated by prior-small
        assert_eq!(frontier, vec!["fresh-fast", "prior-small"]);
    }
}
