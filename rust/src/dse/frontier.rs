//! Pareto-frontier extraction over explored design points.
//!
//! The paper's Fig. 15 ranks designs by estimated cycles alone; real
//! pre-RTL exploration trades cycles against cost. We report the frontier
//! of (cycles, PE count, memory words): a point is dominated when another
//! point is no worse on every axis and strictly better on at least one.
//! Only points that received an accurate (AIDG) estimate participate —
//! pre-filtered points are never reported as winners.

use super::SweepPoint;

/// Mark `on_frontier` on every point: true iff the point has an accurate
/// estimate and no other estimated point dominates it on
/// (cycles, PE count, memory words). O(n²), deterministic.
pub fn mark_frontier(points: &mut [SweepPoint]) {
    let axes: Vec<Option<(u64, u64, u64)>> = points
        .iter()
        .map(|p| p.aidg_cycles.map(|c| (c, p.pe_count, p.mem_words)))
        .collect();
    for i in 0..points.len() {
        points[i].on_frontier = match axes[i] {
            None => false,
            Some(a) => !axes
                .iter()
                .enumerate()
                .any(|(j, b)| j != i && b.is_some_and(|b| dominates(b, a))),
        };
    }
}

/// True when `a` is no worse than `b` on every axis and strictly better on
/// at least one (all axes minimized). Equal points do not dominate each
/// other, so ties stay on the frontier together.
fn dominates(a: (u64, u64, u64), b: (u64, u64, u64)) -> bool {
    a.0 <= b.0 && a.1 <= b.1 && a.2 <= b.2 && (a.0 < b.0 || a.1 < b.1 || a.2 < b.2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(cycles: Option<u64>, pe: u64, mem: u64) -> SweepPoint {
        SweepPoint {
            label: String::new(),
            assignment: Vec::new(),
            arch_name: String::new(),
            digest: 0,
            pe_count: pe,
            mem_words: mem,
            roofline_cycles: 0.0,
            aidg_cycles: cycles,
            on_frontier: false,
        }
    }

    #[test]
    fn frontier_keeps_tradeoffs_drops_dominated() {
        let mut pts = vec![
            point(Some(100), 4, 10),  // fast but big
            point(Some(200), 2, 10),  // slower but half the PEs
            point(Some(250), 4, 10),  // dominated by the first
            point(Some(100), 4, 10),  // exact tie with the first: kept
            point(None, 1, 1),        // never estimated: off-frontier
        ];
        mark_frontier(&mut pts);
        let on: Vec<bool> = pts.iter().map(|p| p.on_frontier).collect();
        assert_eq!(on, vec![true, true, false, true, false]);
    }

    #[test]
    fn single_estimated_point_is_the_frontier() {
        let mut pts = vec![point(Some(5), 1, 1)];
        mark_frontier(&mut pts);
        assert!(pts[0].on_frontier);
    }
}
