//! The compiled sweep space: a parsed architecture description plus its
//! evaluated `[sweep]` dimensions, from which candidate architectures are
//! rendered on demand.
//!
//! A candidate is an assignment of one value per sweep dimension. Its
//! architecture is the base description with `[params]` overridden by the
//! assignment (and the `[sweep]` section stripped), rendered back to
//! canonical TOML — so candidates flow through the exact same
//! [`ArchRegistry`](crate::acadl::text::ArchRegistry)-cached compile path
//! as any other described architecture, and identical candidates share one
//! compiled model.

use std::collections::BTreeMap;

use anyhow::{bail, Context as _};

use crate::acadl::text::ast::Param;
use crate::acadl::text::compile::FlatSweep;
use crate::acadl::text::{check_source, parse, Description, Diagnostic, Spanned};
use crate::coordinator::{Arch, DescribedArch};
use crate::Result;

/// A compiled `[sweep]` design space over one architecture description.
pub struct SweepSpace {
    /// Diagnostic label of the source (file path or `@name`).
    pub origin: String,
    /// The base description with `[sweep]` stripped (candidates patch its
    /// `[params]`).
    base: Description,
    /// Base parameter values (guard fallback for unswept params).
    params: BTreeMap<String, i64>,
    /// The evaluated sweep (dimensions, guard, cap).
    pub sweep: FlatSweep,
}

/// One enumerated design point: a value per sweep dimension, in dimension
/// declaration order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Candidate {
    /// `(param, value)` pairs in dimension order.
    pub assignment: Vec<(String, i64)>,
}

impl Candidate {
    /// Compact `rows=4,cols=8` rendering (point labels in reports).
    pub fn label(&self) -> String {
        let parts: Vec<String> =
            self.assignment.iter().map(|(n, v)| format!("{n}={v}")).collect();
        parts.join(",")
    }

    /// The assigned value of `param`, if swept.
    pub fn value(&self, param: &str) -> Option<i64> {
        self.assignment.iter().find(|(n, _)| n == param).map(|(_, v)| *v)
    }
}

impl SweepSpace {
    /// Compile a sweep space from description source text. Fails with
    /// rendered diagnostics when the description (or its `[sweep]`) has
    /// errors, and with a clear message when there is no `[sweep]` at all.
    /// `cap_override` replaces the description's combinatorial cap (the
    /// CLI's `--sweep-cap`).
    pub fn from_source(src: &str, origin: &str, cap_override: Option<usize>) -> Result<Self> {
        let desc = match parse(src) {
            Ok(d) => d,
            Err(diag) => bail!("{}", diag.render(origin)),
        };
        // diagnose against the *original* text first so line/column numbers
        // match the user's file (from_description re-renders the tree, which
        // strips comments and reorders sections). The only diagnostic a cap
        // override can change is the blow-up error, so that one is deferred
        // to the post-override check.
        let (_, diags) = check_source(src);
        let errors: Vec<&Diagnostic> = diags
            .iter()
            .filter(|d| {
                d.is_error()
                    && !(cap_override.is_some() && d.message.contains("exceeding the cap"))
            })
            .collect();
        if !errors.is_empty() {
            let shown: Vec<String> = errors.iter().take(5).map(|d| d.render(origin)).collect();
            bail!(
                "{} error(s) in architecture description:\n{}",
                errors.len(),
                shown.join("\n")
            );
        }
        Self::from_description(desc, origin, cap_override)
    }

    /// [`SweepSpace::from_source`] over an already-parsed description
    /// (tests and the compatibility shim construct these directly).
    pub fn from_description(
        mut desc: Description,
        origin: &str,
        cap_override: Option<usize>,
    ) -> Result<Self> {
        let Some(sweep_ast) = desc.sweep.as_mut() else {
            bail!(
                "{origin} has no [sweep] section — declare one to run a design-space \
                 exploration (see docs/dse.md)"
            );
        };
        if let Some(cap) = cap_override {
            anyhow::ensure!(cap >= 1, "--sweep-cap must be >= 1 (got {cap})");
            // the override replaces the description's own cap *before*
            // evaluation, so it can both tighten and relax the bound.
            // Saturate instead of wrapping: a cap past i64::MAX is already
            // unreachable (len_bound saturates at usize::MAX anyway).
            sweep_ast.cap = Some(Spanned::bare(cap.min(i64::MAX as usize) as i64));
        }
        // re-render so diagnostics reflect exactly the space being built
        // (from_description callers may have patched the parsed tree).
        // Positions in the re-render don't correspond to any file the user
        // can open, so cap-exceeded errors (the one class an override can
        // introduce) are reported message-only; everything else was already
        // span-checked against the original text by from_source.
        let src = desc.to_toml();
        let (flat, diags) = check_source(&src);
        let errors: Vec<&Diagnostic> = diags.iter().filter(|d| d.is_error()).collect();
        if !errors.is_empty() {
            let shown: Vec<String> = errors
                .iter()
                .take(5)
                .map(|d| {
                    if d.message.contains("exceeding the cap") {
                        format!("{origin}: {}", d.message)
                    } else {
                        d.render(origin)
                    }
                })
                .collect();
            bail!(
                "{} error(s) in architecture description:\n{}",
                errors.len(),
                shown.join("\n")
            );
        }
        let flat = flat.context("description did not parse")?;
        let sweep = flat
            .sweep
            .with_context(|| format!("{origin}: [sweep] section did not evaluate"))?;
        let mut base = desc;
        base.sweep = None;
        Ok(Self { origin: origin.to_string(), base, params: flat.params, sweep })
    }

    /// Base parameter values (the description's own `[params]`).
    pub fn params(&self) -> &BTreeMap<String, i64> {
        &self.params
    }

    /// Upper bound on the candidate count (guards only shrink it).
    pub fn len_bound(&self) -> usize {
        self.sweep.len_bound()
    }

    /// Render one candidate's description source: the base description
    /// with its `[params]` overridden by the assignment. Deterministic, so
    /// identical candidates are content-deduplicated by the registry.
    pub fn candidate_source(&self, c: &Candidate) -> String {
        let mut desc = self.base.clone();
        for (name, value) in &c.assignment {
            match desc.params.iter_mut().find(|p| p.name.node == *name) {
                Some(p) => p.value = Spanned::bare(*value),
                None => desc.params.push(Param {
                    name: Spanned::bare(name.clone()),
                    value: Spanned::bare(*value),
                }),
            }
        }
        desc.to_toml()
    }

    /// The candidate as an estimable architecture (an inline described
    /// arch, compiled through the global registry on first use).
    pub fn candidate_arch(&self, c: &Candidate) -> Arch {
        let label = format!("{}[{}]", self.origin, c.label());
        Arch::Described(DescribedArch::inline(label, self.candidate_source(c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SWEPT: &str = r#"
[arch]
name = "t${rows}x${cols}"

[params]
rows = 2
cols = 2

[fetch]
imem = "imem"
imem_read_latency = 1
imem_port_width = 1
ifs = "ifs"
ifs_latency = 1
issue_buffer = 1

[sweep]
rows = "2, 4"
cols = "2..7 step 2"
when = "rows <= cols"
"#;

    #[test]
    fn space_compiles_and_renders_candidates() {
        let space = SweepSpace::from_source(SWEPT, "inline", None).unwrap();
        assert_eq!(space.len_bound(), 6);
        let c = Candidate { assignment: vec![("rows".into(), 4), ("cols".into(), 6)] };
        assert_eq!(c.label(), "rows=4,cols=6");
        let src = space.candidate_source(&c);
        assert!(src.contains("rows = 4"), "{src}");
        assert!(src.contains("cols = 6"), "{src}");
        assert!(!src.contains("[sweep]"), "sweep must be stripped:\n{src}");
        // the rendered candidate is itself a valid description
        let (_, diags) = check_source(&src);
        assert!(diags.iter().all(|d| !d.is_error()), "{diags:?}");
    }

    #[test]
    fn missing_sweep_and_cap_overrides_error() {
        let no_sweep = SWEPT.split("[sweep]").next().unwrap();
        let e = SweepSpace::from_source(no_sweep, "inline", None).unwrap_err();
        assert!(format!("{e:#}").contains("no [sweep] section"), "{e:#}");
        let e = SweepSpace::from_source(SWEPT, "inline", Some(3)).unwrap_err();
        assert!(format!("{e:#}").contains("exceeding the cap of 3"), "{e:#}");
        assert!(SweepSpace::from_source(SWEPT, "inline", Some(0)).is_err());
        assert!(SweepSpace::from_source(SWEPT, "inline", Some(6)).is_ok());
    }
}
