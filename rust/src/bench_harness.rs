//! Minimal benchmark harness (criterion is not vendored in this offline
//! image; see DESIGN.md). `cargo bench` targets use `harness = false` and
//! drive this module: warmup, N timed samples, median/mean/min reporting in
//! criterion-style rows, plus helpers to print the paper's tables.

use std::time::{Duration, Instant};

/// One measured statistic set.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Median sample.
    pub median: Duration,
    /// Mean sample.
    pub mean: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Slowest sample.
    pub max: Duration,
    /// Number of measured samples.
    pub samples: usize,
}

/// Run `f` `samples` times after `warmup` unmeasured runs and report.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let stats = Stats {
        median,
        mean,
        min: *times.first().unwrap(),
        max: *times.last().unwrap(),
        samples: times.len(),
    };
    println!(
        "{name:<48} time: [{} {} {}]  ({} samples)",
        fmt_dur(stats.min),
        fmt_dur(stats.median),
        fmt_dur(stats.max),
        stats.samples
    );
    stats
}

/// Time a single invocation (for long end-to-end runs where repeated
/// sampling is impractical — e.g. whole-graph ground truth).
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    let dt = t0.elapsed();
    println!("{name:<48} time: {}", fmt_dur(dt));
    (v, dt)
}

/// Human duration: ns/µs/ms/s with 3 significant figures.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Group separator for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// True when the bench was invoked with `--smoke` (CI's fast pass: run the
/// cheap phases only, but still emit the JSON artifacts so their shape can
/// be asserted).
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let s = bench("noop", 1, 5, || n += 1);
        assert_eq!(s.samples, 5);
        assert_eq!(n, 6);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(12)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(12)).ends_with('s'));
    }
}
