//! # acadl-perf
//!
//! Reproduction of *Automatic Generation of Fast and Accurate Performance
//! Models for Deep Neural Network Accelerators* (Lübeck et al., 2024,
//! DOI 10.1145/3715122) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate implements:
//!
//! - [`acadl`] — the Abstract Computer Architecture Description Language:
//!   an object model of accelerator architectures (pipeline stages, execute
//!   stages, functional units, register files, memories) with a precise,
//!   instruction-centric latency semantic, including latency *expressions*
//!   evaluated against instruction immediates (UltraTrail's CONV-EXT
//!   analytical model, Gemmini's DRAM burst model).
//! - [`isa`] — abstract instructions and loop kernels at any abstraction
//!   level (scalar `load`/`mac`/`store`, tiled-GEMM `mvin`/`compute`,
//!   fused-tensor `conv_ext`).
//! - [`dnn`] — the DNN layer IR, the model zoo (TC-ResNet8, AlexNet,
//!   EfficientNet-edge and reduced variants), and the textual network
//!   frontend ([`dnn::text`]): TOML-flavored network descriptions
//!   (`net/*.toml`) with shape inference, compiled to the same IR.
//! - [`mapping`] — DNN-layer → loop-kernel lowering per abstraction level
//!   (weight-stationary scalar unrolling, im2col + tiled GEMM, fused tensor
//!   ops, Plasticine parallel-GEMM partitioning).
//! - [`aidg`] — the Architectural Instruction Dependency Graph: streaming
//!   construction (§6.1), Algorithm-1 evaluation (§6.2), and the §6.3
//!   fixed-point layer estimator with the 1 % fallback heuristic.
//! - [`sim`] — an independent cycle-accurate discrete-event simulator over
//!   the same ACADL diagrams (the in-repo stand-in for the paper's
//!   Verilator/Xcelium RTL ground truth).
//! - [`accel`] — object-diagram builders for the four paper architectures.
//! - [`calib`] — ANNETTE-style stacked calibration: a per-class correction
//!   model trained against the DES on a seeded representative corpus,
//!   attaching `calibrated_cycles` + `[ci_lo, ci_hi]` error bars to every
//!   estimate, with a CI-gated accuracy harness (`docs/accuracy.md`).
//! - [`baselines`] — refined roofline (native mirror of the AOT-compiled
//!   JAX/Pallas estimator) and a Timeloop-like analytical model.
//! - [`runtime`] — PJRT loader executing the AOT artifacts from Rust.
//! - [`engine`] — the unified estimation engine: content-addressed kernel
//!   fingerprints, a sharded LRU cache of layer estimates, and
//!   kernel-granular parallel scheduling. Every estimation path routes
//!   through it; repeated kernel shapes (residual blocks, serve fleets,
//!   DSE sweeps) are priced once.
//! - [`dse`] — architecture-generic design-space exploration: `[sweep]`
//!   spaces declared in description files, lazy guarded enumeration, the
//!   roofline pre-filter, cache-locality scheduling of the accurate pass,
//!   and Pareto-frontier reporting (paper §7.4, Fig. 15).
//! - [`coordinator`] — the estimation service: job types, the generic
//!   worker pool, the request server, and the legacy Plasticine DSE shim
//!   over [`dse`].
//! - [`metrics`] / [`report`] — PE/MAPE/variance/Pearson, the paper's
//!   table/figure renderers, and process-wide engine counters.
//! - [`obs`] — structured tracing: timed spans with cross-thread nesting,
//!   per-span latency histograms, a lock-free event ring with Chrome
//!   trace-event export, and pool/cache gauges, all behind a runtime
//!   enable flag that keeps the layer free when off.
//!
//! The `docs/` book covers the system for operators and description
//! authors: `docs/architecture.md` (module map + the §6.3 estimator),
//! `docs/arch-format.md` / `docs/net-format.md` (the two description
//! grammars), `docs/serve-protocol.md`, and `docs/performance.md`.

#![warn(missing_docs)]

pub mod acadl;
pub mod accel;
pub mod aidg;
pub mod baselines;
pub mod bench_harness;
pub mod calib;
pub mod coordinator;
pub mod dnn;
pub mod dse;
pub mod engine;
pub mod expt;
pub mod ids;
pub mod isa;
pub mod mapping;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testkit;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
