//! Fused-tensor mapping onto UltraTrail (paper §4.3 Fig. 5, §5, §7.1).
//!
//! Each convolutional / fully-connected layer lowers to a **single**
//! `conv_ext` / `dense_ext` instruction whose immediates parameterize the
//! analytical latency model of the `macArrayAndOPU` FunctionalUnit.
//! Activation and pooling layers are executed by the OPU *fused* into the
//! preceding tensor op (zero additional instructions — the paper's CONV-EXT
//! semantics); residual additions lower to `add_ext` on the MAC array.
//!
//! Layer operands ping-pong between FMEM0 and FMEM1 through per-layer token
//! addresses, giving the AIDG the read-after-write chain that serializes
//! consecutive layers exactly like the real accelerator's memory reuse.
//! UltraTrail processes 1-dimensional data only: 2D layers are rejected
//! (the paper runs only TC-ResNet8 on it for the same reason).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::bail;

use crate::accel::ultratrail::{UltraTrail, BMEM_BASE, FMEM0_BASE, FMEM1_BASE, FMEM2_BASE, WMEM_BASE};
use crate::acadl::Diagram;
use crate::dnn::{Layer, LayerKind};
use crate::ids::Addr;
use crate::isa::LoopKernel;
use crate::Result;

use super::{MappedLayer, Mapper};

/// The UltraTrail tensor-op mapper. Holds a layer sequence counter so the
/// per-layer FMEM ping-pong tokens chain across `map_network` calls.
pub struct TensorOpMapper {
    ut: Arc<UltraTrail>,
    seq: AtomicU64,
}

impl TensorOpMapper {
    /// A mapper over the given UltraTrail model.
    pub fn new(ut: Arc<UltraTrail>) -> Self {
        Self { ut, seq: AtomicU64::new(0) }
    }

    /// Feature-memory token of sequence step `j` (ping-pong FMEM0/FMEM1).
    fn fmem_token(j: u64) -> Addr {
        if j % 2 == 0 {
            FMEM0_BASE + j
        } else {
            FMEM1_BASE + j
        }
    }

    /// One tensor instruction as a k=1 loop kernel.
    fn tensor_kernel(
        &self,
        layer: &Layer,
        op: crate::ids::OpId,
        imms: [i64; 7],
        extra_read: Option<Addr>,
        weighted: bool,
    ) -> MappedLayer {
        let j = self.seq.fetch_add(1, Ordering::Relaxed);
        let seq_in = Self::fmem_token(j);
        let seq_out = Self::fmem_token(j + 1);
        let w_token = WMEM_BASE + j;
        let b_token = BMEM_BASE + j;
        let label = format!("{}::tensor", layer.name);
        let kernel = LoopKernel::new(
            label,
            1,
            1,
            Box::new(move |_it, buf| {
                let mut i = buf.instr(op).imms(&imms).read_mem(&[seq_in]);
                if weighted {
                    i = i.read_mem(&[w_token, b_token]);
                }
                if let Some(a) = extra_read {
                    i = i.read_mem(&[a]);
                }
                i.write_mem(&[seq_out]);
            }),
        );
        let n = self.ut.cfg.array_dim;
        MappedLayer {
            layer_name: layer.name.clone(),
            kernels: vec![kernel],
            fused: false,
            ur_c: n.min(imms[0].max(1) as u32),
            ur_k: n.min(imms[2].max(1) as u32),
            traffic: None,
        }
    }
}

impl Mapper for TensorOpMapper {
    fn diagram(&self) -> &Diagram {
        &self.ut.diagram
    }

    fn obs_name(&self) -> &'static str {
        "mapping.tensor_op"
    }

    fn map_layer(&self, layer: &Layer) -> Result<MappedLayer> {
        let ops = self.ut.ops;
        match layer.kind {
            LayerKind::Conv1d { c_in, l_in, c_out, kernel, stride, pad } => {
                let out = crate::dnn::layer::out_dim(l_in, kernel, stride, pad);
                if out == 0 {
                    bail!("layer {} has empty output", layer.name);
                }
                Ok(self.tensor_kernel(
                    layer,
                    ops.conv_ext,
                    [
                        c_in as i64,
                        l_in as i64,
                        c_out as i64,
                        kernel as i64,
                        stride as i64,
                        pad as i64,
                        out as i64,
                    ],
                    None,
                    true,
                ))
            }
            LayerKind::Dense { c_in, c_out } => Ok(self.tensor_kernel(
                layer,
                ops.dense_ext,
                [c_in as i64, 1, c_out as i64, 1, 1, 0, 1],
                None,
                true,
            )),
            LayerKind::Add { c, spatial } => Ok(self.tensor_kernel(
                layer,
                ops.add_ext,
                [c as i64, spatial as i64, c as i64, 0, 0, 0, spatial as i64],
                Some(FMEM2_BASE + c as u64), // the skip-path operand
                false,
            )),
            // OPU work: fused into the preceding tensor op (CONV-EXT)
            LayerKind::Act { .. } | LayerKind::Pool1d { .. } => {
                Ok(MappedLayer::fused(layer.name.clone()))
            }
            // UltraTrail is 1-D only (paper §7.1)
            LayerKind::Conv2d { .. }
            | LayerKind::DwConv2d { .. }
            | LayerKind::Pool2d { .. }
            | LayerKind::Mul { .. } => {
                bail!(
                    "layer {} ({:?}-like) is not executable on UltraTrail (1-D architecture)",
                    layer.name,
                    std::mem::discriminant(&layer.kind)
                )
            }
        }
    }

    fn hw_features(&self) -> [f64; 8] {
        let n = self.ut.cfg.array_dim as f64;
        // rows=cols=N; 8-word fmem ports; 1-cycle memories; 1-cycle MAC wave;
        // fetch overhead ~2 cycles/instruction (imem + IFS)
        [n, n, 8.0, 1.0, 1.0, 1.0, 2.0, 0.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::ultratrail::UltraTrailConfig;
    use crate::dnn::zoo;

    fn mapper() -> TensorOpMapper {
        TensorOpMapper::new(Arc::new(UltraTrail::new(UltraTrailConfig::default()).unwrap()))
    }

    #[test]
    fn tc_resnet8_maps_fully() {
        let m = mapper();
        let net = zoo::tc_resnet8();
        let mapped = m.map_network(&net).unwrap();
        assert_eq!(mapped.len(), net.num_layers());
        // clips and the avgpool fuse into the OPU
        let fused = mapped.iter().filter(|l| l.fused).count();
        assert_eq!(fused, 8); // 7 clips + 1 avgpool
        // everything else is exactly one instruction
        for ml in mapped.iter().filter(|l| !l.fused) {
            assert_eq!(ml.total_insts(), 1, "{}", ml.layer_name);
        }
    }

    #[test]
    fn layers_chain_through_fmem_tokens() {
        let m = mapper();
        let net = zoo::tc_resnet8();
        let mapped = m.map_network(&net).unwrap();
        let actual: Vec<&MappedLayer> = mapped.iter().filter(|l| !l.fused).collect();
        // the write token of layer i is the read token of layer i+1
        let insts_of = |ml: &MappedLayer| ml.kernels[0].materialize(0..1);
        for w in actual.windows(2) {
            let a = insts_of(w[0]);
            let b = insts_of(w[1]);
            assert!(
                b[0].read_addrs.contains(&a[0].write_addrs[0]),
                "{} -> {} not chained",
                w[0].layer_name,
                w[1].layer_name
            );
        }
    }

    #[test]
    fn two_d_layers_rejected() {
        let m = mapper();
        for net in [zoo::alexnet(), zoo::efficientnet()] {
            assert!(m.map_network(&net).is_err(), "{} should not map", net.name);
        }
    }

    #[test]
    fn instructions_route() {
        let m = mapper();
        for ml in m.map_network(&zoo::tc_resnet8()).unwrap() {
            for k in &ml.kernels {
                for i in k.materialize(0..k.k) {
                    m.diagram().route(&i).unwrap();
                }
            }
        }
    }
}
