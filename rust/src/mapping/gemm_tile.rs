//! Tiled-GEMM mapping onto Gemmini (paper §5, §7.2).
//!
//! Convolutional layers are turned into GEMM via the im2col transformation
//! and split into DIM×DIM tiles matching the array; fully-connected layers
//! tile directly. The per-(m,n)-output-tile loop kernel mirrors the paper's
//! tiled-GEMM implementation built from the public `gemmini_*` intrinsics:
//!
//! ```text
//! iteration (m, n):
//!   mvin_acc D(m,n)                       # bias / zero the accumulator tile
//!   for kk in 0..nk:
//!     mvin A(m,kk)    DRAM → scratchpad   # linear burst latency
//!     mvin B(kk,n)    DRAM → scratchpad
//!     preload B(kk,n)       → array       # writes the array-state register
//!     compute_accumulated A·B → acc(m,n)  # WAW chain over the acc token
//!   mvout C(m,n)      acc → DRAM          # fused activation/pooling
//! ```
//!
//! Scratchpad tiles live in a bounded pool of slot tokens; slot reuse
//! produces the structural serialization the real scratchpad capacity
//! enforces. Activation and pooling layers following a GEMM-like layer are
//! fused into `mvout` (Gemmini's on-device layer fusion); element-wise
//! add/mul layers lower to accumulator moves.

use std::sync::Arc;

use anyhow::bail;

use crate::accel::gemmini::{Gemmini, ACC_BASE, DRAM_BASE, SPAD_BASE};
use crate::acadl::Diagram;
use crate::dnn::{Layer, LayerKind};
use crate::ids::Addr;
use crate::isa::LoopKernel;
use crate::Result;

use super::{MappedLayer, Mapper};

/// Scratchpad capacity in DIM×DIM tile slots (256 KiB @ DIM=16, 1 KiB/tile
/// in the shipped configuration; split between the A and B streams).
const SPAD_SLOTS: u64 = 128;

/// DRAM token-region offsets per operand.
const DRAM_A_OFF: Addr = 0;
const DRAM_B_OFF: Addr = 1 << 32;
const DRAM_C_OFF: Addr = 2 << 32;
const DRAM_D_OFF: Addr = 3 << 32;

/// The Gemmini tiled-GEMM mapper.
pub struct GemmTileMapper {
    g: Arc<Gemmini>,
}

impl GemmTileMapper {
    /// A mapper over the given Gemmini model.
    pub fn new(g: Arc<Gemmini>) -> Self {
        Self { g }
    }

    /// Per-layer `config_ex`/`config_ld`/`config_st` preamble.
    fn config_kernel(&self, layer: &Layer) -> LoopKernel {
        let g = Arc::clone(&self.g);
        LoopKernel::new(
            format!("{}::config", layer.name),
            1,
            3,
            Box::new(move |_it, buf| {
                for op in [g.ops.config_ex, g.ops.config_ld, g.ops.config_st] {
                    buf.instr(op).reads(&[g.cfg_reg]).writes(&[g.cfg_reg]);
                }
            }),
        )
    }

    /// Tiled GEMM of (M, K, N), repeated `reps` times (depth-wise convs run
    /// one small GEMM per channel).
    fn gemm_kernels(&self, layer: &Layer, m: u64, k: u64, n: u64, reps: u64) -> MappedLayer {
        let g = &self.g;
        let dim = g.cfg.dim as u64;
        let words = dim * dim;
        let nm = m.div_ceil(dim);
        let nk = k.div_ceil(dim);
        let nn = n.div_ceil(dim);
        let iters = reps * nm * nn;
        let insts = (4 * nk + 2) as usize;

        let g2 = Arc::clone(g);
        let kernel = LoopKernel::new(
            format!("{}::gemm", layer.name),
            iters,
            insts,
            Box::new(move |it, buf| {
                let ops = &g2.ops;
                let nmnn = nm * nn;
                let rep = it / nmnn;
                let within = it % nmnn;
                let mt = within / nn;
                let nt = within % nn;
                // operand tile ids (globally unique per rep so DRAM burst
                // start addresses vary like a real layout)
                let a_row_base = rep * nm * nk + mt * nk;
                let b_col_base = rep * nk * nn + nt;
                let c_id = rep * nmnn + within;

                // accumulator token of the output tile
                let acc_tok = ACC_BASE + (c_id % 64);
                // bias / zero the tile
                buf.instr(ops.mvin_acc)
                    .imms(&[words as i64, ((c_id * words) % 4096) as i64])
                    .reads(&[g2.cfg_reg])
                    .read_mem(&[DRAM_BASE + DRAM_D_OFF + c_id])
                    .write_mem(&[acc_tok]);
                for kk in 0..nk {
                    let a_id = a_row_base + kk;
                    let b_id = b_col_base + kk * nn;
                    let a_slot = SPAD_BASE + (a_id % SPAD_SLOTS);
                    let b_slot = SPAD_BASE + SPAD_SLOTS + (b_id % SPAD_SLOTS);
                    buf.instr(ops.mvin)
                        .imms(&[words as i64, ((a_id * words) % 4096) as i64])
                        .reads(&[g2.cfg_reg])
                        .read_mem(&[DRAM_BASE + DRAM_A_OFF + a_id])
                        .write_mem(&[a_slot]);
                    buf.instr(ops.mvin)
                        .imms(&[words as i64, ((b_id * words) % 4096) as i64])
                        .reads(&[g2.cfg_reg])
                        .read_mem(&[DRAM_BASE + DRAM_B_OFF + b_id])
                        .write_mem(&[b_slot]);
                    buf.instr(ops.preload)
                        .reads(&[g2.cfg_reg])
                        .writes(&[g2.b_tile_reg])
                        .read_mem(&[b_slot]);
                    buf.instr(ops.compute_accumulated)
                        .reads(&[g2.b_tile_reg, g2.cfg_reg])
                        .read_mem(&[a_slot, acc_tok])
                        .write_mem(&[acc_tok]);
                }
                buf.instr(ops.mvout)
                    .imms(&[words as i64, ((c_id * words) % 4096) as i64])
                    .reads(&[g2.cfg_reg])
                    .read_mem(&[acc_tok])
                    .write_mem(&[DRAM_BASE + DRAM_C_OFF + c_id]);
            }),
        );

        // streamed DRAM traffic including tile re-reads: per output tile,
        // nk A-tiles + nk B-tiles in, a D tile in, a C tile out
        let traffic = (
            iters * nk * words + iters * words, // A stream + D bias
            iters * nk * words,                 // B stream
            iters * words,                      // C write-back
        );
        MappedLayer {
            layer_name: layer.name.clone(),
            kernels: vec![self.config_kernel(layer), kernel],
            fused: false,
            ur_c: (k.min(dim)) as u32,
            ur_k: (n.min(dim)) as u32,
            traffic: Some(traffic),
        }
    }

    /// Element-wise layers via accumulator moves: `mvin_acc` both operands
    /// (the second accumulates on device), `mvout` the result.
    fn elementwise(&self, layer: &Layer, elems: u64, two_operand: bool) -> MappedLayer {
        let g = &self.g;
        let dim = g.cfg.dim as u64;
        let words = dim * dim;
        let tiles = elems.div_ceil(words);
        let insts = if two_operand { 3 } else { 2 };
        let g2 = Arc::clone(g);
        let kernel = LoopKernel::new(
            format!("{}::ew", layer.name),
            tiles,
            insts,
            Box::new(move |it, buf| {
                let ops = &g2.ops;
                let acc_tok = ACC_BASE + (it % 64);
                buf.instr(ops.mvin_acc)
                    .imms(&[words as i64, ((it * words) % 4096) as i64])
                    .reads(&[g2.cfg_reg])
                    .read_mem(&[DRAM_BASE + DRAM_A_OFF + it])
                    .write_mem(&[acc_tok]);
                if two_operand {
                    buf.instr(ops.mvin_acc)
                        .imms(&[words as i64, ((it * words) % 4096) as i64])
                        .reads(&[g2.cfg_reg])
                        .read_mem(&[DRAM_BASE + DRAM_B_OFF + it])
                        .write_mem(&[acc_tok]);
                }
                buf.instr(ops.mvout)
                    .imms(&[words as i64, ((it * words) % 4096) as i64])
                    .reads(&[g2.cfg_reg])
                    .read_mem(&[acc_tok])
                    .write_mem(&[DRAM_BASE + DRAM_C_OFF + it]);
            }),
        );
        MappedLayer {
            layer_name: layer.name.clone(),
            kernels: vec![self.config_kernel(layer), kernel],
            fused: false,
            ur_c: dim as u32,
            ur_k: dim as u32,
            traffic: Some((tiles * words * if two_operand { 2 } else { 1 }, 0, tiles * words)),
        }
    }
}

impl Mapper for GemmTileMapper {
    fn diagram(&self) -> &Diagram {
        &self.g.diagram
    }

    fn obs_name(&self) -> &'static str {
        "mapping.gemm_tile"
    }

    fn map_layer(&self, layer: &Layer) -> Result<MappedLayer> {
        if let Some((m, k, n)) = layer.gemm_dims() {
            if m == 0 {
                bail!("layer {} has empty output", layer.name);
            }
            return Ok(self.gemm_kernels(layer, m, k, n, 1));
        }
        match layer.kind {
            LayerKind::DwConv2d { c, h, w, kh, kw, stride, pad } => {
                let ho = crate::dnn::layer::out_dim(h, kh, stride, pad) as u64;
                let wo = crate::dnn::layer::out_dim(w, kw, stride, pad) as u64;
                // one (pos × taps × 1) GEMM per channel
                Ok(self.gemm_kernels(layer, ho * wo, (kh * kw) as u64, 1, c as u64))
            }
            // fused into the preceding GEMM's mvout (activation / pooling
            // configured via config_st — Gemmini's on-device fusion)
            LayerKind::Act { .. } | LayerKind::Pool2d { .. } | LayerKind::Pool1d { .. } => {
                Ok(MappedLayer::fused(layer.name.clone()))
            }
            LayerKind::Add { c, spatial } | LayerKind::Mul { c, spatial } => {
                Ok(self.elementwise(layer, c as u64 * spatial as u64, true))
            }
            _ => unreachable!("gemm-like layers handled above"),
        }
    }

    fn hw_features(&self) -> [f64; 8] {
        let c = &self.g.cfg;
        let words = c.dim as f64 * c.dim as f64;
        // effective per-transaction DRAM latency of the burst model at tile
        // granularity, normalized per port-width beat
        let tile_lat = c.dram_base_latency as f64 + words / c.dram_words_per_beat as f64;
        let per_beat = tile_lat / (words / c.dram_words_per_beat as f64);
        [
            c.dim as f64,
            c.dim as f64,
            c.dram_words_per_beat as f64,
            per_beat,
            per_beat,
            // array occupancy per DIM-wide MAC wave: a DIM³ tile takes
            // compute_cycles(DIM) for DIM waves
            Gemmini::compute_cycles(c.dim) as f64 / c.dim as f64,
            2.0,
            0.0,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::GemminiConfig;
    use crate::dnn::zoo;

    fn mapper() -> GemmTileMapper {
        GemmTileMapper::new(Arc::new(Gemmini::new(GemminiConfig::default()).unwrap()))
    }

    #[test]
    fn conv_tiling_counts() {
        let m = mapper();
        // 16×16 GEMM tiles: conv with M=100, K=360, N=24 -> nm=7, nk=23, nn=2
        let l = Layer::new(
            "c",
            LayerKind::Conv1d { c_in: 40, l_in: 100, c_out: 24, kernel: 9, stride: 1, pad: true },
        );
        let ml = m.map_layer(&l).unwrap();
        let gemm = &ml.kernels[1];
        assert_eq!(gemm.k, 7 * 2);
        assert_eq!(gemm.insts_per_iter, (4 * 23 + 2) as usize);
    }

    #[test]
    fn all_networks_map() {
        let m = mapper();
        for net in [zoo::tc_resnet8(), zoo::alexnet(), zoo::efficientnet()] {
            let mapped = m.map_network(&net).unwrap();
            assert_eq!(mapped.len(), net.num_layers());
            assert!(mapped.iter().any(|l| !l.fused));
        }
    }

    #[test]
    fn instructions_route() {
        let m = mapper();
        for ml in m.map_network(&zoo::tc_resnet8()).unwrap() {
            for k in &ml.kernels {
                for i in k.materialize(0..2.min(k.k)) {
                    m.diagram().route(&i).unwrap_or_else(|e| panic!("{}: {e}", k.label));
                }
            }
        }
    }

    #[test]
    fn act_and_pool_fuse() {
        let m = mapper();
        let act = Layer::new("a", LayerKind::Act {
            kind: crate::dnn::ActKind::Relu,
            c: 8,
            spatial: 8,
        });
        assert!(m.map_layer(&act).unwrap().fused);
        let pool = Layer::new("p", LayerKind::Pool2d {
            kind: crate::dnn::PoolKind::Max,
            c: 8,
            h: 8,
            w: 8,
            k: 2,
            stride: 2,
        });
        assert!(m.map_layer(&pool).unwrap().fused);
    }

    #[test]
    fn add_uses_accumulator_path() {
        let m = mapper();
        let l = Layer::new("add", LayerKind::Add { c: 32, spatial: 100 });
        let ml = m.map_layer(&l).unwrap();
        // 3200 elements / 256 words per tile = 13 tiles
        assert_eq!(ml.kernels[1].k, 13);
        assert_eq!(ml.kernels[1].insts_per_iter, 3);
    }

    #[test]
    fn dwconv_repeats_per_channel() {
        let m = mapper();
        let l = Layer::new(
            "dw",
            LayerKind::DwConv2d { c: 32, h: 16, w: 16, kh: 3, kw: 3, stride: 1, pad: true },
        );
        let ml = m.map_layer(&l).unwrap();
        // per channel: M=256 -> nm=16, nk=1, nn=1; × 32 channels
        assert_eq!(ml.kernels[1].k, 32 * 16);
    }

    #[test]
    fn bigger_dim_needs_fewer_iterations() {
        let small = mapper();
        let big = GemmTileMapper::new(Arc::new(
            Gemmini::new(GemminiConfig::default().with_dim(32)).unwrap(),
        ));
        let l = Layer::new("fc", LayerKind::Dense { c_in: 256, c_out: 256 });
        let ks = small.map_layer(&l).unwrap().kernels[1].total_insts();
        let kb = big.map_layer(&l).unwrap().kernels[1].total_insts();
        assert!(kb < ks);
    }
}
