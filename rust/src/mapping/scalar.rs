//! Scalar mapping onto the parameterizable systolic array (paper §5, §7.3).
//!
//! Convolutions and fully-connected layers lower to a weight-stationary
//! dataflow: input channels unroll over PE rows, output channels over PE
//! columns (the paper's TVM-TIR partial unrolling — here a native loop-nest
//! unroller with the unroll factors extracted from the ACADL diagram).
//! Each layer yields two uniform loop kernels:
//!
//! 1. a *weight-load* kernel (`loadw` column transactions — the Fig. 13
//!    port-width knob) executed once per (c-tile, k-tile, tap), and
//! 2. a *compute* kernel per output position: row activation loads,
//!    `mov_r` operand propagation, a `mac` wave with psums flowing down the
//!    columns, `mov_d` pass-through over idle rows, and a read-modify-write
//!    `store_acc` per column accumulating into the psum address (the
//!    loop-carried dependency that produces the paper's pipeline effects).
//!
//! Element-wise layers (act/add/mul), pooling, and depth-wise convolutions
//! use only the first PE row (no data reuse — paper Appendix A.2), with the
//! unroll factor limited to divisors of the channel dimension: non-divisible
//! channels underutilize the array exactly as the paper describes.

use std::sync::Arc;

use anyhow::bail;

use crate::accel::systolic::{Systolic, ACT_BASE, OUT_BASE, PSUM_BASE, WEIGHT_BASE};
use crate::acadl::Diagram;
use crate::dnn::{Layer, LayerKind};
use crate::ids::Addr;
use crate::isa::LoopKernel;
use crate::Result;

use super::{unroll_factor, MappedLayer, Mapper};

/// Geometry of a conv-like / windowed layer (1D layers use `in_h = 1`).
#[derive(Debug, Clone, Copy)]
struct Geom {
    c: u32,
    k: u32,
    kh: u32,
    kw: u32,
    stride: u32,
    pad_h: i64,
    pad_w: i64,
    in_h: u32,
    in_w: u32,
    out_h: u32,
    out_w: u32,
}

impl Geom {
    fn taps(&self) -> u32 {
        self.kh * self.kw
    }

    fn out_pos(&self) -> u32 {
        self.out_h * self.out_w
    }

    /// Input activation address for (channel, tap, output position);
    /// padded positions clamp to the tensor edge (timing-equivalent).
    fn act_addr(&self, ch: u32, tap: u32, o: u32) -> Addr {
        let (fh, fw) = (tap / self.kw, tap % self.kw);
        let (oh, ow) = (o / self.out_w, o % self.out_w);
        let ih = ((oh * self.stride + fh) as i64 - self.pad_h)
            .clamp(0, self.in_h as i64 - 1) as u64;
        let iw = ((ow * self.stride + fw) as i64 - self.pad_w)
            .clamp(0, self.in_w as i64 - 1) as u64;
        ACT_BASE + (ch as u64 * self.in_h as u64 + ih) * self.in_w as u64 + iw
    }

    fn w_addr(&self, ch: u32, kout: u32, tap: u32) -> Addr {
        WEIGHT_BASE
            + ((kout as u64 * self.c as u64 + ch as u64) * self.taps() as u64 + tap as u64)
    }

    fn psum_addr(&self, kout: u32, o: u32) -> Addr {
        PSUM_BASE + kout as u64 * self.out_pos() as u64 + o as u64
    }
}

fn conv_geom(layer: &Layer) -> Option<Geom> {
    match layer.kind {
        LayerKind::Conv1d { c_in, l_in, c_out, kernel, stride, pad } => Some(Geom {
            c: c_in,
            k: c_out,
            kh: 1,
            kw: kernel,
            stride,
            pad_h: 0,
            pad_w: if pad { (kernel / 2) as i64 } else { 0 },
            in_h: 1,
            in_w: l_in,
            out_h: 1,
            out_w: crate::dnn::layer::out_dim(l_in, kernel, stride, pad),
        }),
        LayerKind::Conv2d { c_in, h, w, c_out, kh, kw, stride, pad } => Some(Geom {
            c: c_in,
            k: c_out,
            kh,
            kw,
            stride,
            pad_h: if pad { (kh / 2) as i64 } else { 0 },
            pad_w: if pad { (kw / 2) as i64 } else { 0 },
            in_h: h,
            in_w: w,
            out_h: crate::dnn::layer::out_dim(h, kh, stride, pad),
            out_w: crate::dnn::layer::out_dim(w, kw, stride, pad),
        }),
        LayerKind::Dense { c_in, c_out } => Some(Geom {
            c: c_in,
            k: c_out,
            kh: 1,
            kw: 1,
            stride: 1,
            pad_h: 0,
            pad_w: 0,
            in_h: 1,
            in_w: c_in, // activations laid out linearly; ch indexes them
            out_h: 1,
            out_w: 1,
        }),
        _ => None,
    }
}

/// The systolic-array mapper.
pub struct ScalarMapper {
    sys: Arc<Systolic>,
}

impl ScalarMapper {
    /// A mapper over the given systolic model.
    pub fn new(sys: Arc<Systolic>) -> Self {
        Self { sys }
    }

    fn conv_like(&self, layer: &Layer, g: Geom) -> MappedLayer {
        let sys = &self.sys;
        let rows = sys.cfg.rows;
        let cols = sys.cfg.cols;
        let ur_c = unroll_factor(g.c, rows);
        let ur_k = unroll_factor(g.k, cols);
        let c_tiles = g.c / ur_c;
        let k_tiles = g.k / ur_k;
        let taps = g.taps();
        let out_pos = g.out_pos();

        // ---- weight-load kernel ----
        let k_w = c_tiles as u64 * k_tiles as u64 * taps as u64;
        let s1 = Arc::clone(sys);
        let weight_kernel = LoopKernel::new(
            format!("{}::weights", layer.name),
            k_w,
            ur_k as usize,
            Box::new(move |it, buf| {
                let tap = (it % taps as u64) as u32;
                let k_tile = ((it / taps as u64) % k_tiles as u64) as u32;
                let c_tile = (it / (taps as u64 * k_tiles as u64)) as u32;
                for j in 0..ur_k {
                    buf.instr(s1.ops.loadw)
                        .writes_iter((0..ur_c).map(|r| s1.pe[r as usize][j as usize].r_w))
                        .read_mem_iter(
                            (0..ur_c)
                                .map(|r| g.w_addr(c_tile * ur_c + r, k_tile * ur_k + j, tap)),
                        );
                }
            }),
        );

        // ---- compute kernel ----
        let k_c = k_w * out_pos as u64;
        let insts = (ur_c // loads
            + ur_c * (ur_k - 1) // mov_r
            + ur_c * ur_k // mac
            + (rows - ur_c) * ur_k // mov_d pass-through over idle rows
            + ur_k) as usize; // store_acc
        let s2 = Arc::clone(sys);
        let compute_kernel = LoopKernel::new(
            format!("{}::compute", layer.name),
            k_c,
            insts,
            Box::new(move |it, buf| {
                let o = (it % out_pos as u64) as u32;
                let rest = it / out_pos as u64;
                let tap = (rest % taps as u64) as u32;
                let k_tile = ((rest / taps as u64) % k_tiles as u64) as u32;
                let c_tile = (rest / (taps as u64 * k_tiles as u64)) as u32;
                let pe = &s2.pe;
                let ops = &s2.ops;
                // activation loads down the left edge
                for r in 0..ur_c as usize {
                    buf.instr(ops.load)
                        .writes(&[pe[r][0].r_in])
                        .read_mem(&[g.act_addr(c_tile * ur_c + r as u32, tap, o)]);
                }
                // operand propagation to the right
                for j in 1..ur_k as usize {
                    for r in 0..ur_c as usize {
                        buf.instr(ops.mov_r)
                            .reads(&[pe[r][j - 1].r_in])
                            .writes(&[pe[r][j].r_in]);
                    }
                }
                // mac wave: psums flow down the columns
                for r in 0..ur_c as usize {
                    for j in 0..ur_k as usize {
                        let mut i = buf.instr(ops.mac).reads(&[pe[r][j].r_in, pe[r][j].r_w]);
                        if r > 0 {
                            i = i.reads(&[pe[r - 1][j].r_acc]);
                        }
                        i.writes(&[pe[r][j].r_acc]);
                    }
                }
                // pass psums through idle rows to the store units
                for rr in ur_c as usize..s2.cfg.rows as usize {
                    for j in 0..ur_k as usize {
                        buf.instr(ops.mov_d)
                            .reads(&[pe[rr - 1][j].r_acc])
                            .writes(&[pe[rr][j].r_acc]);
                    }
                }
                // accumulate into psum memory (read-modify-write)
                let last = s2.cfg.rows as usize - 1;
                for j in 0..ur_k as usize {
                    let a = g.psum_addr(k_tile * ur_k + j as u32, o);
                    buf.instr(ops.store_acc)
                        .reads(&[pe[last][j].r_acc])
                        .read_mem(&[a])
                        .write_mem(&[a]);
                }
            }),
        );

        MappedLayer {
            layer_name: layer.name.clone(),
            kernels: vec![weight_kernel, compute_kernel],
            fused: false,
            ur_c,
            ur_k,
            traffic: None,
        }
    }

    /// Element-wise / pooling / depth-wise mapping on the first PE row.
    /// `window` = input elements reduced per output (1 for act/add/mul),
    /// `two_operand` adds a second operand load, `weighted` loads a weight
    /// per channel (depth-wise conv).
    #[allow(clippy::too_many_arguments)]
    fn row_mapped(
        &self,
        layer: &Layer,
        op: crate::ids::OpId,
        c: u32,
        out_elems: u32,
        window: u32,
        two_operand: bool,
        weighted: bool,
        geom: Option<Geom>,
    ) -> MappedLayer {
        let sys = &self.sys;
        let rows = sys.cfg.rows;
        let u = unroll_factor(c, sys.cfg.cols);
        let c_tiles = c / u;
        let k = c_tiles as u64 * out_elems as u64;
        let spatial = out_elems;

        let mut kernels = Vec::new();
        if weighted {
            // per-channel weight kernel (taps words per column transaction)
            let s0 = Arc::clone(sys);
            let g = geom.expect("weighted row mapping needs geometry");
            kernels.push(LoopKernel::new(
                format!("{}::weights", layer.name),
                c_tiles as u64,
                u as usize,
                Box::new(move |it, buf| {
                    let c_tile = it as u32;
                    for j in 0..u {
                        let ch = c_tile * u + j;
                        buf.instr(s0.ops.loadw)
                            .writes(&[s0.pe[0][j as usize].r_w])
                            .read_mem_iter((0..g.taps()).map(|t| g.w_addr(0, ch, t)));
                    }
                }),
            ));
        }

        let insts = (u * window // loads
            + if two_operand { u } else { 0 } // second operand
            + u * window // the op per loaded element
            + (rows - 1) * u // mov_d chain to the store row
            + u) as usize; // stores
        let s1 = Arc::clone(sys);
        kernels.push(LoopKernel::new(
            format!("{}::compute", layer.name),
            k,
            insts,
            Box::new(move |it, buf| {
                let o = (it % spatial as u64) as u32;
                let c_tile = (it / spatial as u64) as u32;
                let pe = &s1.pe;
                let ops = &s1.ops;
                for j in 0..u as usize {
                    let ch = c_tile * u + j as u32;
                    for t in 0..window {
                        let a = match geom {
                            Some(g) => g.act_addr(ch, t, o),
                            None => ACT_BASE + ch as u64 * spatial as u64 + o as u64,
                        };
                        buf.instr(ops.loade).writes(&[pe[0][j].r_in]).read_mem(&[a]);
                        if two_operand && t == 0 {
                            let b = ACT_BASE
                                + (c_tiles * u) as u64 * spatial as u64
                                + ch as u64 * spatial as u64
                                + o as u64;
                            buf.instr(ops.loade2).writes(&[pe[0][j].r_in2]).read_mem(&[b]);
                        }
                        // the op consumes the loaded element (accumulating
                        // ops chain through r_acc)
                        let mut i = buf.instr(op).reads(&[pe[0][j].r_in]);
                        if two_operand {
                            i = i.reads(&[pe[0][j].r_in2]);
                        }
                        if window > 1 || op == ops.ew_mac {
                            i = i.reads(&[pe[0][j].r_acc]); // self-accumulate
                        }
                        if op == ops.ew_mac {
                            i = i.reads(&[pe[0][j].r_w]);
                        }
                        i.writes(&[pe[0][j].r_acc]);
                    }
                }
                // results flow down to the bottom store row
                for rr in 1..s1.cfg.rows as usize {
                    for j in 0..u as usize {
                        buf.instr(ops.mov_d)
                            .reads(&[pe[rr - 1][j].r_acc])
                            .writes(&[pe[rr][j].r_acc]);
                    }
                }
                let last = s1.cfg.rows as usize - 1;
                for j in 0..u as usize {
                    let ch = c_tile * u + j as u32;
                    buf.instr(ops.store)
                        .reads(&[pe[last][j].r_acc])
                        .write_mem(&[OUT_BASE + ch as u64 * spatial as u64 + o as u64]);
                }
            }),
        ));

        MappedLayer { layer_name: layer.name.clone(), kernels, fused: false, ur_c: 1, ur_k: u, traffic: None }
    }
}

impl Mapper for ScalarMapper {
    fn diagram(&self) -> &Diagram {
        &self.sys.diagram
    }

    fn obs_name(&self) -> &'static str {
        "mapping.scalar"
    }

    fn map_layer(&self, layer: &Layer) -> Result<MappedLayer> {
        if let Some(g) = conv_geom(layer) {
            if g.out_pos() == 0 {
                bail!("layer {} has empty output", layer.name);
            }
            return Ok(self.conv_like(layer, g));
        }
        let ops = self.sys.ops;
        match layer.kind {
            LayerKind::Act { kind, c, spatial } => {
                let op = match kind {
                    crate::dnn::ActKind::Relu => ops.ew_relu,
                    crate::dnn::ActKind::Clip => ops.ew_clip,
                };
                Ok(self.row_mapped(layer, op, c, spatial, 1, false, false, None))
            }
            LayerKind::Add { c, spatial } => {
                Ok(self.row_mapped(layer, ops.ew_add, c, spatial, 1, true, false, None))
            }
            LayerKind::Mul { c, spatial } => {
                Ok(self.row_mapped(layer, ops.ew_mul, c, spatial, 1, true, false, None))
            }
            LayerKind::Pool1d { c, l, k, stride, .. } => {
                let g = Geom {
                    c,
                    k: c,
                    kh: 1,
                    kw: k,
                    stride,
                    pad_h: 0,
                    pad_w: 0,
                    in_h: 1,
                    in_w: l,
                    out_h: 1,
                    out_w: crate::dnn::layer::out_dim(l, k, stride, false),
                };
                Ok(self.row_mapped(layer, ops.ew_acc, c, g.out_pos(), k, false, false, Some(g)))
            }
            LayerKind::Pool2d { c, h, w, k, stride, .. } => {
                let g = Geom {
                    c,
                    k: c,
                    kh: k,
                    kw: k,
                    stride,
                    pad_h: 0,
                    pad_w: 0,
                    in_h: h,
                    in_w: w,
                    out_h: crate::dnn::layer::out_dim(h, k, stride, false),
                    out_w: crate::dnn::layer::out_dim(w, k, stride, false),
                };
                Ok(self.row_mapped(
                    layer,
                    ops.ew_acc,
                    c,
                    g.out_pos(),
                    k * k,
                    false,
                    false,
                    Some(g),
                ))
            }
            LayerKind::DwConv2d { c, h, w, kh, kw, stride, pad } => {
                let g = Geom {
                    c: 1,
                    k: c,
                    kh,
                    kw,
                    stride,
                    pad_h: if pad { (kh / 2) as i64 } else { 0 },
                    pad_w: if pad { (kw / 2) as i64 } else { 0 },
                    in_h: h,
                    in_w: w,
                    out_h: crate::dnn::layer::out_dim(h, kh, stride, pad),
                    out_w: crate::dnn::layer::out_dim(w, kw, stride, pad),
                };
                // per-channel windowed MAC with a stationary channel weight
                Ok(self.row_mapped(
                    layer,
                    ops.ew_mac,
                    c,
                    g.out_pos(),
                    kh * kw,
                    false,
                    true,
                    Some(g),
                ))
            }
            _ => unreachable!("conv-like handled above"),
        }
    }

    fn hw_features(&self) -> [f64; 8] {
        let c = &self.sys.cfg;
        [
            c.rows as f64,
            c.cols as f64,
            c.port_width as f64,
            c.mem_read_latency as f64,
            c.mem_write_latency as f64,
            // per-wave latency of one unrolled MAC step: the psum chain down
            // the rows plus load/mov_r/store_acc overhead — the "utilization
            // efficiency" knob of the refined roofline. It assumes this is
            // CONSTANT per design point, which is exactly the blind spot the
            // paper exploits (§7.3: oscillation, underutilized mappings).
            (c.rows + 5) as f64,
            0.0, // fetch overhead folded into the pipeline
            0.0,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::systolic::SystolicConfig;
    use crate::dnn::{ActKind, Layer, LayerKind};

    fn mapper(rows: u32, cols: u32) -> ScalarMapper {
        ScalarMapper::new(Arc::new(Systolic::new(SystolicConfig::new(rows, cols)).unwrap()))
    }

    fn conv1d(c: u32, l: u32, k: u32, f: u32) -> Layer {
        Layer::new(
            "conv",
            LayerKind::Conv1d { c_in: c, l_in: l, c_out: k, kernel: f, stride: 1, pad: false },
        )
    }

    #[test]
    fn conv_kernel_counts() {
        let m = mapper(2, 2);
        let ml = m.map_layer(&conv1d(4, 10, 4, 3)).unwrap();
        assert_eq!(ml.kernels.len(), 2);
        assert_eq!(ml.ur_c, 2);
        assert_eq!(ml.ur_k, 2);
        // weights: c_tiles(2) * k_tiles(2) * taps(3) = 12 iterations
        assert_eq!(ml.kernels[0].k, 12);
        // compute: 12 * out_pos(8)
        assert_eq!(ml.kernels[1].k, 96);
        // per-iter: 2 loads + 2 mov_r + 4 mac + 0 mov_d + 2 store = 10
        assert_eq!(ml.kernels[1].insts_per_iter, 10);
    }

    #[test]
    fn kernel_instructions_route() {
        // every emitted instruction must route through the diagram
        let m = mapper(4, 4);
        for layer in [
            conv1d(8, 16, 8, 3),
            Layer::new("act", LayerKind::Act { kind: ActKind::Clip, c: 8, spatial: 16 }),
            Layer::new("add", LayerKind::Add { c: 7, spatial: 16 }),
            Layer::new(
                "dw",
                LayerKind::DwConv2d { c: 8, h: 8, w: 8, kh: 3, kw: 3, stride: 1, pad: true },
            ),
            Layer::new(
                "pool",
                LayerKind::Pool2d {
                    kind: crate::dnn::PoolKind::Max,
                    c: 8,
                    h: 8,
                    w: 8,
                    k: 2,
                    stride: 2,
                },
            ),
            Layer::new("fc", LayerKind::Dense { c_in: 16, c_out: 8 }),
        ] {
            let ml = m.map_layer(&layer).unwrap();
            for kernel in &ml.kernels {
                for instr in kernel.materialize(0..2.min(kernel.k)) {
                    m.diagram().route(&instr).unwrap_or_else(|e| {
                        panic!("{} kernel {}: {e}", layer.name, kernel.label)
                    });
                }
            }
        }
    }

    #[test]
    fn underutilized_mapping_uses_divisor() {
        // the Fig. 13b case: C=20, K=70 on 12×12 -> 10×10 active
        let m = mapper(12, 12);
        let ml = m.map_layer(&conv1d(20, 30, 70, 3)).unwrap();
        assert_eq!(ml.ur_c, 10);
        assert_eq!(ml.ur_k, 10);
        // idle rows add mov_d pass-through work
        assert_eq!(
            ml.kernels[1].insts_per_iter,
            (10 + 10 * 9 + 100 + 2 * 10 + 10) as usize
        );
    }

    #[test]
    fn add_with_prime_channels_uses_one_pe() {
        let m = mapper(4, 4);
        let ml = m
            .map_layer(&Layer::new("add", LayerKind::Add { c: 13, spatial: 10 }))
            .unwrap();
        assert_eq!(ml.ur_k, 1); // 13 prime, > 4
        assert_eq!(ml.kernels[0].k, 13 * 10);
    }

    #[test]
    fn iterations_shrink_with_array_size() {
        let small = mapper(2, 2).map_layer(&conv1d(16, 32, 16, 3)).unwrap();
        let big = mapper(4, 4).map_layer(&conv1d(16, 32, 16, 3)).unwrap();
        assert_eq!(small.kernels[1].k, big.kernels[1].k * 4);
    }

    #[test]
    fn addresses_stay_in_regions() {
        let m = mapper(2, 2);
        let ml = m.map_layer(&conv1d(4, 10, 4, 3)).unwrap();
        for instr in ml.kernels[1].materialize(0..ml.kernels[1].k) {
            for &a in &instr.read_addrs {
                assert!(a < PSUM_BASE + (1 << 32));
            }
            for &a in &instr.write_addrs {
                assert!((PSUM_BASE..OUT_BASE + (1 << 32)).contains(&a));
            }
        }
    }

    #[test]
    fn dense_maps_as_degenerate_conv() {
        let m = mapper(4, 4);
        let ml = m.map_layer(&Layer::new("fc", LayerKind::Dense { c_in: 16, c_out: 8 })).unwrap();
        assert_eq!(ml.ur_c, 4);
        assert_eq!(ml.ur_k, 4);
        // compute iterations = (16/4)*(8/4)*1*1 = 8
        assert_eq!(ml.kernels[1].k, 8);
    }
}
