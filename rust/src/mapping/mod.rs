//! DNN → loop-kernel lowering (paper §5).
//!
//! The granularity of the instruction stream must match the abstraction
//! level of the ACADL model: scalar `load`/`mac`/`store` streams for the
//! systolic array ([`scalar`]), im2col + DIM×DIM-tiled GEMM streams for
//! Gemmini ([`gemm_tile`]), fused `conv_ext` tensor instructions for
//! UltraTrail ([`tensor_op`]), and parallel tiled GEMM across PCUs for the
//! Plasticine-derived architecture ([`plasticine_map`]).
//!
//! Every mapper returns [`MappedLayer`]s: one or more uniform
//! [`LoopKernel`]s per DNN layer plus the achieved unroll factors (the
//! refined-roofline features). Layers an architecture executes fused into
//! their predecessor (e.g. activations in UltraTrail's OPU) come back with
//! `fused = true` and no kernels.

pub mod gemm_tile;
pub mod plasticine_map;
pub mod scalar;
pub mod tensor_op;

use crate::acadl::Diagram;
use crate::dnn::{Layer, Network};
use crate::isa::LoopKernel;
use crate::Result;

/// A DNN layer lowered onto one architecture.
pub struct MappedLayer {
    /// The mapped layer's name.
    pub layer_name: String,
    /// Uniform loop kernels; the layer's latency is the sum of their
    /// estimates (e.g. weight-load kernel + compute kernel).
    pub kernels: Vec<LoopKernel>,
    /// Executed fused into the preceding layer (zero additional cost).
    pub fused: bool,
    /// Achieved unroll along input channels (refined-roofline feature).
    pub ur_c: u32,
    /// Achieved unroll along output channels.
    pub ur_k: u32,
    /// Streamed memory traffic of the mapping `(in, weights, out)` in words,
    /// *including tile re-reads* (im2col/tiling amplification). `None` means
    /// the mapping streams each word once (use the layer's tensor sizes).
    pub traffic: Option<(u64, u64, u64)>,
}

impl MappedLayer {
    /// A fused (zero-cost) placeholder mapping named `layer_name`.
    pub fn fused(layer_name: impl Into<String>) -> Self {
        Self {
            layer_name: layer_name.into(),
            kernels: Vec::new(),
            fused: true,
            ur_c: 1,
            ur_k: 1,
            traffic: None,
        }
    }

    /// Total loop iterations over all kernels.
    pub fn total_iters(&self) -> u64 {
        self.kernels.iter().map(|k| k.k).sum()
    }

    /// Total instructions over all kernels.
    pub fn total_insts(&self) -> u64 {
        self.kernels.iter().map(|k| k.total_insts()).sum()
    }
}

impl std::fmt::Debug for MappedLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedLayer")
            .field("layer_name", &self.layer_name)
            .field("kernels", &self.kernels)
            .field("fused", &self.fused)
            .field("ur", &(self.ur_c, self.ur_k))
            .finish()
    }
}

/// Architecture-specific DNN lowering.
pub trait Mapper {
    /// The ACADL object diagram instructions are routed through.
    fn diagram(&self) -> &Diagram;

    /// Lower one layer.
    fn map_layer(&self, layer: &Layer) -> Result<MappedLayer>;

    /// Span name for this mapper's [`Mapper::map_network`] in
    /// [`crate::obs`] traces (e.g. `"mapping.scalar"`).
    fn obs_name(&self) -> &'static str {
        "mapping.map_network"
    }

    /// Lower a whole network in order.
    fn map_network(&self, net: &Network) -> Result<Vec<MappedLayer>> {
        let mut sp = crate::obs::span(self.obs_name());
        sp.arg("layers", net.layers.len() as u64);
        net.layers.iter().map(|l| self.map_layer(l)).collect()
    }

    /// Hardware feature vector for the refined-roofline baseline
    /// (mirrors python/compile/features.py HW_FEATS).
    fn hw_features(&self) -> [f64; 8];
}

/// Largest unroll factor `u <= limit` that divides `dim` (the paper's
/// underutilization rule: a 12×12 array runs a C=20 layer at u=10, leaving
/// rows idle — Appendix A.2 / Fig. 13b).
pub fn unroll_factor(dim: u32, limit: u32) -> u32 {
    if dim == 0 {
        return 1;
    }
    let mut best = 1;
    for u in 1..=limit.min(dim) {
        if dim % u == 0 {
            best = u;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unroll_divisor_rule() {
        assert_eq!(unroll_factor(12, 12), 12); // divisible: full array
        assert_eq!(unroll_factor(20, 12), 10); // paper's Fig.13b case
        assert_eq!(unroll_factor(70, 12), 10);
        assert_eq!(unroll_factor(7, 4), 1); // prime > limit: single PE
        assert_eq!(unroll_factor(16, 4), 4);
        assert_eq!(unroll_factor(0, 4), 1);
        assert_eq!(unroll_factor(3, 8), 3); // dim smaller than array
    }
}
