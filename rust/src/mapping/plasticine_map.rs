//! Parallel tiled-GEMM mapping onto the Plasticine-derived architecture
//! (paper §7.4).
//!
//! Convolutional layers run as im2col GEMM, tiled T×T to the PCU GEMM tile
//! size; fully-connected layers tile directly. The mapper *maximizes the
//! amount of parallel GEMM and matrix additions* (the paper's DNN mapper):
//! output tiles (m, n) are distributed round-robin over all PCUs, and each
//! loop-kernel iteration is one **wave** — every PCU processes one output
//! tile, streaming its nk reduction steps:
//!
//! ```text
//! per PCU and wave:            route_in A(kk) ┐
//!                              route_in B(kk) ├ × nk   (switch hops paid
//!                              gemm_tile      ┘         per move)
//!                              route_out C
//! ```
//!
//! Operand tiles live in PMUs round-robin; the hop count of each move is the
//! Manhattan distance between the PCU and the PMU holding the tile, so
//! *larger grids pay more communication* — the effect that makes small
//! TC-ResNet8 layers prefer small grids in Fig. 15.
//!
//! A remainder wave (fewer active PCUs) becomes a second kernel so every
//! kernel keeps a constant instruction count per iteration.

use std::sync::Arc;

use anyhow::bail;

use crate::accel::plasticine::Plasticine;
use crate::acadl::Diagram;
use crate::dnn::{Layer, LayerKind};
use crate::isa::{EmitBuf, LoopKernel};
use crate::Result;

use super::{MappedLayer, Mapper};

/// The Plasticine parallel-GEMM mapper.
pub struct PlasticineMapper {
    p: Arc<Plasticine>,
}

impl PlasticineMapper {
    /// A mapper over the given Plasticine model.
    pub fn new(p: Arc<Plasticine>) -> Self {
        Self { p }
    }

    /// Wave kernels over `items` output tiles with `nk` reduction steps
    /// each. Returns (full-waves kernel, remainder kernel).
    fn wave_kernels(
        &self,
        layer: &Layer,
        items: u64,
        nk: u64,
        gemm: bool,
    ) -> Vec<LoopKernel> {
        let p = &self.p;
        let n_pcus = p.pcus.len() as u64;
        let t = p.cfg.tile as i64;
        let full_waves = items / n_pcus;
        let rem = items % n_pcus;
        let insts_per_pcu = (3 * nk + 1) as usize;

        let emit_wave = {
            let p = Arc::clone(p);
            move |wave: u64, active: u64, buf: &mut EmitBuf| {
                let ops = &p.ops;
                let n_pmus = p.pmus.len() as u64;
                for pc in 0..active as usize {
                    let pcu = p.pcus[pc];
                    let item = wave * (p.pcus.len() as u64) + pc as u64;
                    for kk in 0..nk {
                        // operand tokens round-robin over PMUs
                        let a_id = item * nk + kk;
                        let b_id = item + kk * 7919; // distinct stream
                        let a_pmu = (a_id % n_pmus) as usize;
                        let b_pmu = (b_id % n_pmus) as usize;
                        let a_hops =
                            Plasticine::hops(pcu.pos, p.pmus[a_pmu].pos) as i64;
                        let b_hops =
                            Plasticine::hops(pcu.pos, p.pmus[b_pmu].pos) as i64;
                        buf.instr(ops.route_in)
                            .writes(&[pcu.r_a])
                            .read_mem(&[p.pmus[a_pmu].base + (a_id / n_pmus) % 1024])
                            .imms(&[t, a_hops]);
                        buf.instr(ops.route_in)
                            .writes(&[pcu.r_b])
                            .read_mem(&[p.pmus[b_pmu].base + 1024 + (b_id / n_pmus) % 1024])
                            .imms(&[t, b_hops]);
                        let op = if gemm { ops.gemm_tile } else { ops.add_tile };
                        buf.instr(op)
                            .reads(&[pcu.r_a, pcu.r_b, pcu.r_out])
                            .writes(&[pcu.r_out])
                            .imms(&[t]);
                    }
                    let c_pmu = (item % n_pmus) as usize;
                    let c_hops = Plasticine::hops(pcu.pos, p.pmus[c_pmu].pos) as i64;
                    buf.instr(ops.route_out)
                        .reads(&[pcu.r_out])
                        .write_mem(&[p.pmus[c_pmu].base + 2048 + (item / n_pmus) % 1024])
                        .imms(&[t, c_hops]);
                }
            }
        };

        let mut kernels = Vec::new();
        if full_waves > 0 {
            let ew = emit_wave.clone();
            kernels.push(LoopKernel::new(
                format!("{}::waves", layer.name),
                full_waves,
                insts_per_pcu * n_pcus as usize,
                Box::new(move |it, buf| ew(it, n_pcus, buf)),
            ));
        }
        if rem > 0 {
            kernels.push(LoopKernel::new(
                format!("{}::rem", layer.name),
                1,
                insts_per_pcu * rem as usize,
                Box::new(move |_it, buf| emit_wave(full_waves, rem, buf)),
            ));
        }
        kernels
    }

    fn gemm_layer(&self, layer: &Layer, m: u64, k: u64, n: u64, reps: u64) -> MappedLayer {
        let t = self.p.cfg.tile as u64;
        let nm = m.div_ceil(t);
        let nk = k.div_ceil(t);
        let nn = n.div_ceil(t);
        let items = reps * nm * nn;
        MappedLayer {
            layer_name: layer.name.clone(),
            kernels: self.wave_kernels(layer, items, nk, true),
            fused: false,
            ur_c: (k.min(t)) as u32,
            ur_k: (n.min(t)) as u32,
            traffic: Some((items * nk * t * t, items * nk * t * t, items * t * t)),
        }
    }

    fn add_layer(&self, layer: &Layer, elems: u64) -> MappedLayer {
        let t = self.p.cfg.tile as u64;
        let items = elems.div_ceil(t * t);
        MappedLayer {
            layer_name: layer.name.clone(),
            kernels: self.wave_kernels(layer, items, 1, false),
            fused: false,
            ur_c: 1,
            ur_k: t as u32,
            traffic: Some((2 * items * t * t, 0, items * t * t)),
        }
    }
}

impl Mapper for PlasticineMapper {
    fn diagram(&self) -> &Diagram {
        &self.p.diagram
    }

    fn obs_name(&self) -> &'static str {
        "mapping.plasticine"
    }

    fn map_layer(&self, layer: &Layer) -> Result<MappedLayer> {
        if let Some((m, k, n)) = layer.gemm_dims() {
            if m == 0 {
                bail!("layer {} has empty output", layer.name);
            }
            return Ok(self.gemm_layer(layer, m, k, n, 1));
        }
        match layer.kind {
            LayerKind::DwConv2d { c, h, w, kh, kw, stride, pad } => {
                let ho = crate::dnn::layer::out_dim(h, kh, stride, pad) as u64;
                let wo = crate::dnn::layer::out_dim(w, kw, stride, pad) as u64;
                Ok(self.gemm_layer(layer, ho * wo, (kh * kw) as u64, 1, c as u64))
            }
            // SIMD-tail fusion on the producing PCU
            LayerKind::Act { .. } => Ok(MappedLayer::fused(layer.name.clone())),
            LayerKind::Add { c, spatial } | LayerKind::Mul { c, spatial } => {
                Ok(self.add_layer(layer, c as u64 * spatial as u64))
            }
            // pooling reduces tiles element-wise on the SIMD pipeline
            LayerKind::Pool2d { c, h, w, k, stride, .. } => {
                let ho = crate::dnn::layer::out_dim(h, k, stride, false) as u64;
                let wo = crate::dnn::layer::out_dim(w, k, stride, false) as u64;
                Ok(self.add_layer(layer, c as u64 * ho * wo * (k as u64 * k as u64)))
            }
            LayerKind::Pool1d { c, l, k, stride, .. } => {
                let lo = crate::dnn::layer::out_dim(l, k, stride, false) as u64;
                Ok(self.add_layer(layer, c as u64 * lo * k as u64))
            }
            _ => unreachable!("gemm-like layers handled above"),
        }
    }

    fn hw_features(&self) -> [f64; 8] {
        let c = &self.p.cfg;
        let n_pcus = self.p.pcus.len() as f64;
        let t = c.tile as f64;
        [
            // the roofline sees T×T-parallel MACs (the ur features cap at T)
            t,
            t,
            c.switch_width as f64,
            1.0,
            1.0,
            // per-wave rate: one T×T×T tile costs gemm_tile_cycles over T
            // waves on one PCU, divided across all PCUs — communication
            // (switch hops) is invisible to the roofline, which is why it
            // misses the small-layer-on-big-grid penalty of Fig. 15
            Plasticine::gemm_tile_cycles(c, c.tile) as f64 / (t * n_pcus),
            c.pipe_depth as f64,
            0.0,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::plasticine::PlasticineConfig;
    use crate::dnn::zoo;

    fn mapper(rows: u32, cols: u32, tile: u32) -> PlasticineMapper {
        PlasticineMapper::new(Arc::new(
            Plasticine::new(PlasticineConfig::new(rows, cols, tile)).unwrap(),
        ))
    }

    #[test]
    fn wave_partitioning() {
        let m = mapper(3, 6, 16); // 9 PCUs
        // GEMM 100×360×24 @ T=16: nm=7, nk=23, nn=2 -> 14 tiles = 1 full
        // wave + remainder 5
        let l = Layer::new(
            "c",
            LayerKind::Conv1d { c_in: 40, l_in: 100, c_out: 24, kernel: 9, stride: 1, pad: true },
        );
        let ml = m.map_layer(&l).unwrap();
        assert_eq!(ml.kernels.len(), 2);
        assert_eq!(ml.kernels[0].k, 1);
        assert_eq!(ml.kernels[0].insts_per_iter, (3 * 23 + 1) * 9);
        assert_eq!(ml.kernels[1].k, 1);
        assert_eq!(ml.kernels[1].insts_per_iter, (3 * 23 + 1) * 5);
    }

    #[test]
    fn all_networks_map() {
        let m = mapper(3, 6, 16);
        for net in [zoo::tc_resnet8(), zoo::alexnet_reduced(), zoo::efficientnet_reduced()] {
            let mapped = m.map_network(&net).unwrap();
            assert_eq!(mapped.len(), net.num_layers());
        }
    }

    #[test]
    fn instructions_route() {
        let m = mapper(2, 3, 8);
        for ml in m.map_network(&zoo::tc_resnet8()).unwrap() {
            for k in &ml.kernels {
                for i in k.materialize(0..2.min(k.k)) {
                    m.diagram().route(&i).unwrap_or_else(|e| panic!("{}: {e}", k.label));
                }
            }
        }
    }

    #[test]
    fn more_pcus_fewer_waves() {
        let l = Layer::new("fc", LayerKind::Dense { c_in: 512, c_out: 512 });
        let small = mapper(2, 2, 16).map_layer(&l).unwrap(); // 2 PCUs
        let big = mapper(4, 6, 16).map_layer(&l).unwrap(); // 12 PCUs
        let waves = |ml: &MappedLayer| ml.kernels.iter().map(|k| k.k).sum::<u64>();
        assert!(waves(&big) < waves(&small));
    }

    #[test]
    fn act_fuses() {
        let m = mapper(2, 2, 8);
        let act = Layer::new(
            "a",
            LayerKind::Act { kind: crate::dnn::ActKind::Relu, c: 8, spatial: 64 },
        );
        assert!(m.map_layer(&act).unwrap().fused);
    }
}
