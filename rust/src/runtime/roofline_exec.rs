//! Typed wrapper over the AOT roofline artifact: `[B, LF]` layer features ×
//! `[HF]` hardware features → `[B]` cycles, padding/splitting arbitrary
//! batch sizes to the fixed AOT batch (features.py `ROOFLINE_BATCH`).
//!
//! The coordinator's design-space-exploration driver pushes whole sweeps
//! through this executable (one XLA call covers `ROOFLINE_BATCH` design
//! points); the native mirror in [`crate::baselines::roofline`] computes the
//! same formula and the two are pinned against each other in tests.

use anyhow::Context;

use crate::baselines::roofline::{HwFeatures, LayerFeatures};
use crate::Result;

use super::artifact::{artifacts_dir, Artifact};

/// Fixed AOT batch (mirror of features.py ROOFLINE_BATCH).
pub const ROOFLINE_BATCH: usize = 1024;
/// Layer-feature width (features.py LF).
pub const LF: usize = 8;
/// Hardware-feature width (features.py HF).
pub const HF: usize = 8;

/// The loaded roofline estimator.
pub struct RooflineExec {
    art: Artifact,
}

impl RooflineExec {
    /// Load `artifacts/roofline.hlo.txt` (or `$ACADL_ARTIFACTS`).
    pub fn load() -> Result<Self> {
        Ok(Self { art: Artifact::load(artifacts_dir(), "roofline")? })
    }

    /// Load the artifact from an explicit directory.
    pub fn load_from(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self { art: Artifact::load(dir, "roofline")? })
    }

    /// Estimate cycles for `layers` under `hw`, batching through the AOT
    /// executable in ROOFLINE_BATCH chunks (the tail is zero-padded).
    pub fn estimate(&self, layers: &[LayerFeatures], hw: &HwFeatures) -> Result<Vec<f64>> {
        let hw_lit = xla::Literal::vec1(&hw[..]);
        let mut out = Vec::with_capacity(layers.len());
        for chunk in layers.chunks(ROOFLINE_BATCH) {
            let mut rows = vec![0f64; ROOFLINE_BATCH * LF];
            for (i, lf) in chunk.iter().enumerate() {
                rows[i * LF..(i + 1) * LF].copy_from_slice(&lf.to_row());
            }
            let layers_lit = xla::Literal::vec1(&rows)
                .reshape(&[ROOFLINE_BATCH as i64, LF as i64])
                .context("reshaping roofline batch")?;
            let result = self.art.execute(&[layers_lit, hw_lit.clone()])?;
            let cycles = result.to_vec::<f64>()?;
            out.extend_from_slice(&cycles[..chunk.len()]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::roofline::roofline_cycles;

    fn have_artifacts() -> bool {
        artifacts_dir().join("roofline.hlo.txt").exists()
    }

    fn lf(i: u64) -> LayerFeatures {
        LayerFeatures {
            macs: 1_000.0 + i as f64 * 97.0,
            in_words: 100.0 + i as f64,
            w_words: 300.0 + i as f64 * 3.0,
            out_words: 60.0,
            ur_c: 1.0 + (i % 8) as f64,
            ur_k: 1.0 + (i % 4) as f64,
            k_iters: 10.0 + i as f64,
        }
    }

    #[test]
    fn xla_matches_native_mirror() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let exec = RooflineExec::load().unwrap();
        let hw: HwFeatures = [8.0, 8.0, 4.0, 2.0, 3.0, 1.0, 1.0, 0.0];
        let layers: Vec<LayerFeatures> = (0..100).map(lf).collect();
        let xla_cycles = exec.estimate(&layers, &hw).unwrap();
        assert_eq!(xla_cycles.len(), 100);
        for (l, &x) in layers.iter().zip(&xla_cycles) {
            let native = roofline_cycles(l, &hw);
            assert!(
                (x - native).abs() < 1e-9,
                "xla {x} vs native {native} for {l:?}"
            );
        }
    }

    #[test]
    fn batches_beyond_aot_size_split() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let exec = RooflineExec::load().unwrap();
        let hw: HwFeatures = [4.0, 4.0, 2.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let layers: Vec<LayerFeatures> = (0..(ROOFLINE_BATCH as u64 + 100)).map(lf).collect();
        let cycles = exec.estimate(&layers, &hw).unwrap();
        assert_eq!(cycles.len(), ROOFLINE_BATCH + 100);
        // chunk boundary must be seamless: same formula everywhere
        let native = roofline_cycles(&layers[ROOFLINE_BATCH + 1], &hw);
        assert!((cycles[ROOFLINE_BATCH + 1] - native).abs() < 1e-9);
    }
}
