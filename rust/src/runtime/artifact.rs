//! AOT artifact loading: HLO *text* → PJRT executable.
//!
//! HLO text (not serialized `HloModuleProto`) is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and `python/compile/aot.py`). Artifacts are
//! produced once by `make artifacts`; Python never runs at estimation time.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::Result;

use super::client::with_client;

/// A compiled AOT artifact.
pub struct Artifact {
    /// Artifact stem (e.g. `roofline`).
    pub name: String,
    /// Path of the loaded HLO text.
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Load `<dir>/<stem>.hlo.txt` and compile it on the shared CPU client.
    pub fn load(dir: impl AsRef<Path>, stem: &str) -> Result<Self> {
        let path = dir.as_ref().join(format!("{stem}.hlo.txt"));
        anyhow::ensure!(
            path.exists(),
            "artifact {} missing — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|c| {
            c.compile(&comp)
                .with_context(|| format!("compiling artifact {stem}"))
        })?;
        Ok(Self { name: stem.to_string(), path, exe })
    }

    /// Execute with literals; the AOT pipeline lowers with
    /// `return_tuple=True`, so the single output is a 1-tuple that is
    /// unwrapped here.
    pub fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

/// Default artifacts directory: `$ACADL_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("ACADL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("gemm.hlo.txt").exists()
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let e = match Artifact::load(artifacts_dir(), "nonexistent") {
            Err(e) => e,
            Ok(_) => panic!("loading a nonexistent artifact must fail"),
        };
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn gemm_artifact_round_trips() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let art = Artifact::load(artifacts_dir(), "gemm").unwrap();
        // identity × A = A on the AOT shape (256×256 f32)
        let n = 256usize;
        let mut eye = vec![0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let a: Vec<f32> = (0..n * n).map(|i| (i % 17) as f32).collect();
        let lit_eye = xla::Literal::vec1(&eye).reshape(&[n as i64, n as i64]).unwrap();
        let lit_a = xla::Literal::vec1(&a).reshape(&[n as i64, n as i64]).unwrap();
        let out = art.execute(&[lit_eye, lit_a]).unwrap();
        let v = out.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), n * n);
        for i in (0..n * n).step_by(n * 37 + 1) {
            assert!((v[i] - a[i]).abs() < 1e-4, "i={i}: {} vs {}", v[i], a[i]);
        }
    }
}
