//! No-`xla` stand-ins for the PJRT runtime surface.
//!
//! Everything here fails softly: `RooflineExec::load()` returns an error, so
//! `RooflineBackend::auto()` selects the native mirror, and the CLI's `info`
//! command reports the runtime as unavailable instead of dying.

use std::path::{Path, PathBuf};

use crate::baselines::roofline::{HwFeatures, LayerFeatures};
use crate::Result;

/// Mirror of `roofline_exec::ROOFLINE_BATCH` (features.py `ROOFLINE_BATCH`).
pub const ROOFLINE_BATCH: usize = 1024;

/// Default artifacts directory: `$ACADL_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("ACADL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Platform info string for diagnostics.
pub fn platform_info() -> Result<String> {
    anyhow::bail!("built without the `xla` feature (PJRT runtime disabled)")
}

/// Stub of the AOT roofline executable; never loads.
pub struct RooflineExec {
    _private: (),
}

impl RooflineExec {
    /// Always fails: built without the `xla` feature.
    pub fn load() -> Result<Self> {
        anyhow::bail!("built without the `xla` feature (PJRT runtime disabled)")
    }

    /// Always fails: built without the `xla` feature.
    pub fn load_from(_dir: impl AsRef<Path>) -> Result<Self> {
        Self::load()
    }

    /// Unreachable (the stub cannot be constructed).
    pub fn estimate(&self, _layers: &[LayerFeatures], _hw: &HwFeatures) -> Result<Vec<f64>> {
        unreachable!("stub RooflineExec cannot be constructed")
    }
}
