//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text lowered from the L2 JAX model / L1 Pallas kernels) and runs
//! them from the estimation hot path. Python never executes at runtime.

pub mod artifact;
pub mod client;
pub mod roofline_exec;

pub use artifact::{artifacts_dir, Artifact};
pub use client::{platform_info, with_client};
pub use roofline_exec::{RooflineExec, ROOFLINE_BATCH};
