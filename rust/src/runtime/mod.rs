//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text lowered from the L2 JAX model / L1 Pallas kernels) and runs
//! them from the estimation hot path. Python never executes at runtime.
//!
//! The PJRT path needs the `xla` bindings, which are not vendored in this
//! offline image: it is gated behind the off-by-default `xla` cargo feature.
//! Without the feature, [`stub`] provides the same surface — `load()` fails
//! cleanly and callers (e.g. `RooflineBackend::auto`) fall back to the
//! native roofline mirror in [`crate::baselines::roofline`].

#[cfg(feature = "xla")]
pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod roofline_exec;

#[cfg(feature = "xla")]
pub use artifact::{artifacts_dir, Artifact};
#[cfg(feature = "xla")]
pub use client::{platform_info, with_client};
#[cfg(feature = "xla")]
pub use roofline_exec::{RooflineExec, ROOFLINE_BATCH};

#[cfg(not(feature = "xla"))]
pub mod stub;

#[cfg(not(feature = "xla"))]
pub use stub::{artifacts_dir, platform_info, RooflineExec, ROOFLINE_BATCH};
