//! PJRT CPU client (the `xla` crate wrapper).
//!
//! `PjRtClient` is reference-counted internally (`Rc`) and therefore
//! thread-confined; each thread that touches XLA gets one lazily-created
//! client. The coordinator keeps all XLA work on a single service thread
//! ([`crate::coordinator`]), so in practice one client exists.

use once_cell::unsync::OnceCell;

use crate::Result;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's PJRT CPU client.
pub fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|c| {
        let client = c.get_or_try_init(|| xla::PjRtClient::cpu().map_err(anyhow::Error::from))?;
        f(client)
    })
}

/// Platform info string for diagnostics.
pub fn platform_info() -> Result<String> {
    with_client(|c| Ok(format!("{} ({} devices)", c.platform_name(), c.device_count())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_cpu_platform() {
        assert!(platform_info().unwrap().to_lowercase().contains("cpu"));
    }

    #[test]
    fn client_is_reused_within_thread() {
        let a = with_client(|c| Ok(c as *const _ as usize)).unwrap();
        let b = with_client(|c| Ok(c as *const _ as usize)).unwrap();
        assert_eq!(a, b);
    }
}
